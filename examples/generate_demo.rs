//! Generation demo: sample text from the dense checkpoint and from
//! progressively harder-compressed versions of it — a qualitative view of
//! the degradation the perplexity tables quantify.
//!
//! ```bash
//! make artifacts && cargo run --release --example generate_demo
//! ```
//!
//! Requires a trained `small` checkpoint (`repro train --model small`);
//! trains a short one on the fly if absent.

use std::sync::Arc;

use awp::compress::awp::AwpHyper;
use awp::compress::traits::CompressionSpec;
use awp::config::RunConfig;
use awp::coordinator::{calibrate, compress_model, make_compressor, Method};
use awp::data::{Batcher, SyntheticCorpus};
use awp::eval::generate;
use awp::model::Checkpoint;
use awp::runtime::{Manifest, Runtime};
use awp::trainer::{self, TrainConfig};

fn main() -> anyhow::Result<()> {
    let cfg = RunConfig::default();
    let manifest = Arc::new(Manifest::load(&cfg.paths.artifacts)?);
    let runtime = Runtime::start()?;
    let handle = runtime.handle();
    let model = "small";
    let mcfg = manifest.model(model)?.config.clone();
    let corpus = SyntheticCorpus::generate(cfg.corpus.clone());
    let batcher = Batcher::new(&corpus, mcfg.batch, mcfg.seq_len);

    let ck_path = cfg.paths.checkpoint_file(model);
    let ck = if ck_path.exists() {
        Checkpoint::load(&ck_path)?
    } else {
        eprintln!("(no checkpoint; quick-training 200 steps)");
        let tc = TrainConfig { steps: 200, warmup: 20, log_every: 50, ..Default::default() };
        trainer::train(&handle, &manifest, model, &batcher, &tc)?.0
    };

    let prompt = "The ";
    println!("=== dense ===");
    println!("{}\n", generate(&handle, &manifest, model, &ck, prompt, 100)?);

    let batches = batcher.calibration_set(cfg.calib_batches, 0xCA11B);
    let grams = calibrate(&handle, &manifest, model, &ck, &batches)?;
    let hyper = AwpHyper { group: manifest.awp_group, chunk: manifest.awp_chunk,
                           ..AwpHyper::default() };

    for (label, spec) in [
        ("AWP 50% pruned", CompressionSpec::prune(0.5)),
        ("AWP INT4", CompressionSpec::quant(4, manifest.awp_group)),
        ("AWP 90% pruned", CompressionSpec::prune(0.9)),
    ] {
        let compressor = make_compressor(Method::AwpCpu, hyper, None)?;
        let out = compress_model(&ck, &grams, compressor.as_ref(), &spec, false)?;
        println!("=== {label} ===");
        println!("{}\n", generate(&handle, &manifest, model, &out.checkpoint,
                                   prompt, 100)?);
    }
    println!("(expect: 50%/INT4 still corpus-like; 90% visibly degraded)");
    Ok(())
}
