//! The §4.3 story in miniature: is "INT4 + 75% pruning" really better than
//! "INT2"? (Both are ~2 bits/weight once the mask bit is counted.)
//!
//! ```bash
//! cargo run --release --example joint_compression
//! ```
//!
//! Runs on synthetic layers (no artifacts needed) and prints the
//! activation-aware loss AND the real storage cost of each operating
//! point, using the bit-packed formats from `awp::quant::pack` /
//! `awp::sparse` so the bits-per-weight accounting is measured, not
//! notional.

use awp::compress::traits::{CompressionSpec, LayerCompressor};
use awp::compress::AwpCpu;
use awp::quant::{packed_size_bytes, quantize, QuantSpec};
use awp::sparse::csr_from_dense;
use awp::tensor::Matrix;

/// Storage bytes for a joint (sparse + quantized) layer: packed codes for
/// the survivors + per-group scales/zps + 1 mask bit per weight.
fn joint_storage_bytes(theta: &Matrix, bits: u8, group: usize) -> usize {
    let nnz = theta.nnz();
    let n = theta.data.len();
    let codes = packed_size_bytes(nnz, bits);
    let groups = n / group;
    let scales_zps = groups * 8; // f32 scale + f32 zp
    let mask = n / 8;
    codes + scales_zps + mask
}

fn main() -> anyhow::Result<()> {
    let w = Matrix::randn(256, 256, 7);
    let c = Matrix::randn_gram(256, 8);
    let n = w.data.len();
    let dense_bytes = 4 * n;
    let awp = AwpCpu::default();

    println!("layer 256x256, dense f32 = {} KiB\n", dense_bytes / 1024);
    println!("{:28} {:>12} {:>10} {:>8}", "operating point", "act-loss",
             "size KiB", "bits/w");

    // INT2 straight quantization
    let int2 = awp.compress(&w, &c, &CompressionSpec::quant(2, 32))?;
    let q2 = quantize(&int2.theta, QuantSpec::new(2, 32));
    let b2 = packed_size_bytes(q2.codes.len(), 2) + (n / 32) * 8;
    println!("{:28} {:>12.2} {:>10.1} {:>8.2}", "AWP INT2", int2.stats.final_loss,
             b2 as f64 / 1024.0, 8.0 * b2 as f64 / n as f64);

    // INT4 + pruning at each §4.3 ratio
    for ratio in [0.25, 0.5, 0.75] {
        let spec = CompressionSpec::joint(ratio, 4, 32);
        let out = awp.compress(&w, &c, &spec)?;
        let bytes = joint_storage_bytes(&out.theta, 4, 32);
        println!("{:28} {:>12.2} {:>10.1} {:>8.2}",
                 format!("AWP INT4 + {:.0}% pruned", ratio * 100.0),
                 out.stats.final_loss, bytes as f64 / 1024.0,
                 8.0 * bytes as f64 / n as f64);
    }

    // CSR export of the 75% point (what a sparse engine would load)
    let out = awp.compress(&w, &c, &CompressionSpec::joint(0.75, 4, 32))?;
    let csr = csr_from_dense(&out.theta);
    println!("\nCSR export of the 75% point: {} nnz, {} KiB (f32 values)",
             csr.nnz(), csr.size_bytes() / 1024);
    println!("\npaper's §4.3 finding to reproduce: the INT4+75% row should have \
              LOWER loss than the INT2 row at comparable bits/weight.");
    Ok(())
}
