//! End-to-end driver (DESIGN.md §6): train a transformer LM from scratch
//! via the AOT train-step executable, evaluate dense perplexity, run the
//! full AWP compression pipeline (production HLO backend), re-evaluate,
//! and generate a sample — all layers of the stack composing.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_compress_eval
//! ```
//!
//! Uses the `tiny` model and short training so the whole demo finishes in
//! a couple of minutes; `repro e2e` runs the same flow on `small` with the
//! fully trained checkpoint.

use std::sync::Arc;

use awp::compress::awp::AwpHyper;
use awp::compress::traits::CompressionSpec;
use awp::config::RunConfig;
use awp::coordinator::{calibrate, compress_model, make_compressor, Method};
use awp::data::{Batcher, Split, SyntheticCorpus};
use awp::eval::{generate, perplexity};
use awp::runtime::{Manifest, Runtime};
use awp::trainer::{self, TrainConfig};

fn main() -> anyhow::Result<()> {
    let cfg = RunConfig::default();
    let manifest = Arc::new(Manifest::load(&cfg.paths.artifacts)?);
    let runtime = Runtime::start()?;
    let handle = runtime.handle();
    let model = "tiny";
    let mcfg = manifest.model(model)?.config.clone();

    println!("[1/5] generating corpus + training {model} ({} params)…",
             mcfg.param_count());
    let corpus = SyntheticCorpus::generate(cfg.corpus.clone());
    let batcher = Batcher::new(&corpus, mcfg.batch, mcfg.seq_len);
    let tc = TrainConfig { steps: 300, warmup: 30, log_every: 50, ..Default::default() };
    let (ck, curve) = trainer::train(&handle, &manifest, model, &batcher, &tc)?;
    println!("      loss curve: {:?}",
             curve.iter().map(|(s, l)| format!("{s}:{l:.2}")).collect::<Vec<_>>());

    println!("[2/5] dense perplexity…");
    let dense = perplexity(&handle, &manifest, model, &ck, &batcher, Split::Val, 30)?;
    println!("      dense ppl = {:.3} over {} tokens", dense.ppl, dense.tokens);

    println!("[3/5] calibrating ({} batches)…", cfg.calib_batches);
    let batches = batcher.calibration_set(cfg.calib_batches, 0xCA11B);
    let grams = calibrate(&handle, &manifest, model, &ck, &batches)?;

    println!("[4/5] AWP joint 50% + INT4 over the production HLO backend…");
    let hyper = AwpHyper { group: manifest.awp_group, chunk: manifest.awp_chunk,
                           ..AwpHyper::default() };
    let compressor = make_compressor(Method::AwpHlo, hyper, Some((&handle, &manifest)))?;
    let spec = CompressionSpec::joint(0.5, 4, manifest.awp_group);
    let out = compress_model(&ck, &grams, compressor.as_ref(), &spec, true)?;
    let ppl = perplexity(&handle, &manifest, model, &out.checkpoint, &batcher,
                         Split::Val, 30)?;
    println!("      compressed ppl = {:.3}  (dense {:.3}); pipeline {:.1}s, {} layers",
             ppl.ppl, dense.ppl, out.seconds, out.reports.len());

    println!("[5/5] sampling from the compressed model…");
    let text = generate(&handle, &manifest, model, &out.checkpoint, "The ", 80)?;
    println!("      {text:?}");

    let stats = handle.stats()?;
    println!("\nruntime: {} executions ({:.1}s exec, {:.1}s compile, {} programs)",
             stats.executions, stats.exec_seconds, stats.compile_seconds,
             stats.compilations);
    Ok(())
}
