//! Quickstart: compress ONE linear layer with AWP and every baseline,
//! entirely on synthetic data — no artifacts, no training, runs in seconds.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! This is the paper's Algorithm 1 in its smallest form: given a weight
//! matrix `W` and the Gram matrix `C = XXᵀ/n` of its input activations,
//! find `Θ` in the constraint set minimising `‖WC½ − ΘC½‖_F`.

use awp::compress::traits::{CompressionSpec, LayerCompressor};
use awp::compress::{
    awq::AwqQuant, gptq::Gptq, magnitude::MagnitudePrune, rtn::RtnQuant,
    sparsegpt::SparseGpt, wanda::WandaPrune, AwpCpu,
};
use awp::tensor::Matrix;

fn main() -> anyhow::Result<()> {
    // A layer the size of our `small` model's attention projections, with a
    // realistically anisotropic activation Gram (log-normal channel scales).
    let w = Matrix::randn(256, 256, 42);
    let c = Matrix::randn_gram(256, 43);

    println!("== pruning at 50% / 70% / 90% (activation-aware loss, lower is better)\n");
    let pruners: Vec<(&str, Box<dyn LayerCompressor>)> = vec![
        ("magnitude", Box::new(MagnitudePrune)),
        ("wanda", Box::new(WandaPrune)),
        ("sparsegpt", Box::new(SparseGpt::default())),
        ("awp", Box::<AwpCpu>::default()),
    ];
    print!("{:12}", "method");
    for r in [0.5, 0.7, 0.9] {
        print!("  {:>10}", format!("{:.0}%", r * 100.0));
    }
    println!();
    for (name, m) in &pruners {
        print!("{name:12}");
        for ratio in [0.5, 0.7, 0.9] {
            let out = m.compress(&w, &c, &CompressionSpec::prune(ratio))?;
            print!("  {:>10.2}", out.stats.final_loss);
        }
        println!();
    }

    println!("\n== quantization INT4 / INT3 / INT2 (group=32)\n");
    let quants: Vec<(&str, Box<dyn LayerCompressor>)> = vec![
        ("rtn", Box::new(RtnQuant)),
        ("gptq", Box::new(Gptq::default())),
        ("awq", Box::new(AwqQuant::default())),
        ("awp", Box::<AwpCpu>::default()),
    ];
    print!("{:12}", "method");
    for b in [4, 3, 2] {
        print!("  {:>10}", format!("INT{b}"));
    }
    println!();
    for (name, m) in &quants {
        print!("{name:12}");
        for bits in [4u8, 3, 2] {
            let out = m.compress(&w, &c, &CompressionSpec::quant(bits, 32))?;
            print!("  {:>10.2}", out.stats.final_loss);
        }
        println!();
    }

    println!("\n== joint 50% + INT4 (AWP §4.3 schedule)\n");
    let out = AwpCpu::default().compress(&w, &c, &CompressionSpec::joint(0.5, 4, 32))?;
    let stats = awp::sparse::SparsityStats::of(&out.theta);
    println!("awp joint: loss {:.2}, sparsity {:.2}, row-uniform {}",
             out.stats.final_loss, stats.ratio(), stats.is_row_uniform());
    Ok(())
}
