//! The packed execution path's external contract: the streaming dequant
//! GEMM and the survivor-only N:M sparse GEMM agree **bit-for-bit** with
//! the dense kernels on the decoded weights, and quality numbers
//! recomputed from packed sites (`repro eval --from-artifact`) reproduce
//! the pipeline's recorded numbers bit-for-bit — across every compressor
//! family the artifact store serves.

mod common;

use awp::artifact::PackedLinear;
use awp::compress::magnitude::MagnitudePrune;
use awp::compress::rtn::RtnQuant;
use awp::compress::traits::{CompressionSpec, LayerCompressor};
use awp::compress::AwpCpu;
use awp::eval::recompute_report;
use awp::proj::{NmStructured, ProjScratch, Projection};
use awp::tensor::{ops, Matrix};

use common::assert_bits_eq;

#[test]
fn streaming_gemm_is_bit_identical_across_shapes_and_modes() {
    // shapes straddle the KB=64 k-panel and the 4-quad remainder
    for &(m, k, n) in &[(7usize, 64usize, 9usize), (16, 128, 33), (5, 96, 17)] {
        let b = Matrix::randn(k, n, 1000 + k as u64);
        // grouped-int
        let q = awp::quant::project_qmax(&Matrix::randn(m, k, k as u64), 15.0, 32);
        let p = PackedLinear::encode(&q, &CompressionSpec::quant(4, 32));
        assert_bits_eq(&p.matmul(&b), &ops::matmul(&p.decode(), &b),
                       &format!("int {m}x{k}x{n}"));
        // n:m mask
        let mut nm = Matrix::randn(m, k, 7 * k as u64 + 1);
        NmStructured::new(2, 4).project_rows(&mut nm, &mut ProjScratch::new());
        let p = PackedLinear::encode(&nm, &CompressionSpec::structured_nm(2, 4));
        assert_bits_eq(&p.matmul(&b), &ops::matmul(&nm, &b),
                       &format!("mask {m}x{k}x{n}"));
        assert_bits_eq(&p.matmul_sparse(&b), &ops::matmul(&nm, &b),
                       &format!("sparse {m}x{k}x{n}"));
    }
}

#[test]
fn sparse_gemm_handles_tail_columns_and_empty_rows() {
    // k = 70: a 64-panel, one quad, then a 2-column tail; row 0 fully pruned
    let mut theta = Matrix::randn(4, 70, 3);
    for v in theta.row_mut(0) {
        *v = 0.0;
    }
    NmStructured::new(1, 4).project_rows(&mut theta, &mut ProjScratch::new());
    let p = PackedLinear::encode(&theta, &CompressionSpec::structured_nm(1, 4));
    assert_eq!(p.mode_name(), "mask");
    let b = Matrix::randn(70, 11, 4);
    assert_bits_eq(&p.matmul_sparse(&b), &ops::matmul(&theta, &b), "tail");
    assert_bits_eq(&p.matmul(&b), &ops::matmul(&theta, &b), "tail streaming");
}

/// The `eval --from-artifact` invariant, per compressor family: pack the
/// compressor's Θ, decode it, recompute the quality report — every number
/// the pipeline recorded is reproduced bit-for-bit from the packed bytes.
#[test]
fn packed_eval_reproduces_compressor_stats_bitwise() {
    let w = Matrix::randn(16, 64, 11);
    let c = Matrix::randn_gram(64, 12);
    let cases: Vec<(&str, Box<dyn LayerCompressor>, CompressionSpec)> = vec![
        ("magnitude/prune", Box::new(MagnitudePrune), CompressionSpec::prune(0.5)),
        ("magnitude/nm", Box::new(MagnitudePrune),
         CompressionSpec::structured_nm(2, 4)),
        ("rtn/quant", Box::new(RtnQuant), CompressionSpec::quant(4, 32)),
        ("awp-cpu/prune", Box::<AwpCpu>::default(), CompressionSpec::prune(0.5)),
        ("awp-cpu/quant", Box::<AwpCpu>::default(), CompressionSpec::quant(4, 32)),
        ("awp-cpu/joint", Box::<AwpCpu>::default(),
         CompressionSpec::joint(0.5, 4, 32)),
        ("awp-cpu/nm", Box::<AwpCpu>::default(),
         CompressionSpec::structured_nm(4, 8)),
    ];
    for (name, compressor, spec) in cases {
        let out = compressor.compress(&w, &c, &spec).unwrap();
        let packed = PackedLinear::encode(&out.theta, &spec);
        assert!(packed.reconstructs(&out.theta), "{name}: lossy pack");
        assert!(packed.packed_bytes() < packed.dense_bytes(),
                "{name}: {} !< {}", packed.packed_bytes(), packed.dense_bytes());
        let decoded = packed.decode();
        let rep = recompute_report("site", &w, &decoded, &c,
                                   out.stats.iterations, out.stats.seconds);
        assert_eq!(rep.rel_loss.to_bits(), out.stats.rel_loss.to_bits(),
                   "{name}: rel_loss diverged ({} vs {})", rep.rel_loss,
                   out.stats.rel_loss);
    }
}

#[test]
fn packed_gemm_agrees_after_full_pipeline_assembly() {
    // decode → matmul equals matmul → decode through a joint compressor,
    // i.e. the packed path can stand in for the dense weights anywhere
    let w = Matrix::randn(8, 64, 21);
    let c = Matrix::randn_gram(64, 22);
    let spec = CompressionSpec::joint(0.5, 4, 32);
    let out = AwpCpu::default().compress(&w, &c, &spec).unwrap();
    let packed = PackedLinear::encode(&out.theta, &spec);
    let x = Matrix::randn(64, 13, 23);
    assert_bits_eq(&packed.matmul(&x), &ops::matmul(&out.theta, &x), "joint gemm");
}
