//! The parallel pipeline's contract with the sequential one: running the
//! layer jobs on the executor's worker pool must change *nothing* about
//! the output — checkpoint bytes, report vector and its order are
//! identical at any worker count — and a mid-plan failure must still name
//! the failing site.
//!
//! (The `AWP_THREADS` env-knob variant of the bit-identity check lives in
//! its own binary, `awp_threads_env.rs`, because mutating the environment
//! is only safe in a process whose other threads don't read it.)

mod common;

use std::collections::HashMap;

use anyhow::Result;
use awp::compress::traits::{CompressedLayer, CompressionSpec, LayerCompressor};
use awp::compress::AwpCpu;
use awp::coordinator::calibrate::Grams;
use awp::coordinator::{compress_model_with, plan_jobs, Executor};
use awp::model::{Checkpoint, GramKey};
use awp::tensor::Matrix;

// d_model/d_ff of the shared tiny config are multiples of the quant group
// (32), so the joint-spec verify pass can re-project every site
use common::tiny_cfg as cfg;

fn setup() -> (Checkpoint, Grams) {
    let cfg = cfg();
    let ck = awp::trainer::init_checkpoint(&cfg, 11);
    let mut map = HashMap::new();
    for l in 0..cfg.n_layers {
        for key in [GramKey::AttnIn, GramKey::AttnOutIn, GramKey::MlpIn] {
            map.insert((key, l),
                       Matrix::randn_gram(cfg.d_model, 5 * l as u64 + key.index() as u64));
        }
        map.insert((GramKey::MlpDownIn, l), Matrix::randn_gram(cfg.d_ff, 55 + l as u64));
    }
    (ck, Grams { map, tokens: 2048 })
}

fn assert_checkpoints_bitwise_equal(a: &Checkpoint, b: &Checkpoint, tag: &str) {
    assert_eq!(a.tensors.len(), b.tensors.len(), "{tag}");
    for ((n1, s1, d1), (n2, s2, d2)) in a.tensors.iter().zip(&b.tensors) {
        assert_eq!(n1, n2, "{tag}");
        assert_eq!(s1, s2, "{tag}: {n1}");
        assert_eq!(d1.len(), d2.len(), "{tag}: {n1}");
        for (i, (x, y)) in d1.iter().zip(d2.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}: {n1}[{i}]: {x} vs {y}");
        }
    }
    assert_eq!(a.meta, b.meta, "{tag}");
}

fn assert_runs_identical(compressor: &dyn LayerCompressor, spec: &CompressionSpec,
                         tag: &str) {
    let (ck, grams) = setup();
    let seq = compress_model_with(&ck, &grams, compressor, spec, true,
                                  &Executor::with_workers(1))
        .unwrap();
    let par = compress_model_with(&ck, &grams, compressor, spec, true,
                                  &Executor::with_workers(4))
        .unwrap();
    assert_checkpoints_bitwise_equal(&seq.checkpoint, &par.checkpoint, tag);
    // report vector: same order, same values (seconds is wall-clock, skip)
    assert_eq!(seq.reports.len(), par.reports.len(), "{tag}");
    for (r1, r2) in seq.reports.iter().zip(&par.reports) {
        assert_eq!(r1.param, r2.param, "{tag}");
        assert_eq!(r1.rel_loss.to_bits(), r2.rel_loss.to_bits(), "{tag}: {}", r1.param);
        assert_eq!(r1.sparsity.to_bits(), r2.sparsity.to_bits(), "{tag}: {}", r1.param);
        assert_eq!(r1.iterations, r2.iterations, "{tag}: {}", r1.param);
    }
    // telemetry is labelled in plan order on both paths
    let plan = plan_jobs(&ck.config);
    for (job, (s1, s2)) in plan.jobs.iter()
        .zip(seq.job_stats.iter().zip(&par.job_stats)) {
        assert_eq!(s1.label, job.site.param, "{tag}");
        assert_eq!(s2.label, job.site.param, "{tag}");
    }
}

#[test]
fn parallel_pipeline_is_bit_identical_to_sequential() {
    // iterative PGD method — the realistic workload
    assert_runs_identical(&AwpCpu::default(), &CompressionSpec::prune(0.6), "awp");
    // one-shot joint spec exercises the verify path's spec rewrite too
    assert_runs_identical(&AwpCpu::default(), &CompressionSpec::joint(0.5, 4, 32),
                          "awp-joint");
}

/// Fails on every `w_down` site (the only sites with `d_in == d_ff`).
struct FailOnMlpDown;

impl LayerCompressor for FailOnMlpDown {
    fn name(&self) -> &'static str {
        "fail-on-mlp-down"
    }

    fn compress(&self, w: &Matrix, c: &Matrix, spec: &CompressionSpec)
        -> Result<CompressedLayer> {
        if w.cols == cfg().d_ff {
            anyhow::bail!("synthetic mid-plan failure");
        }
        awp::compress::magnitude::MagnitudePrune.compress(w, c, spec)
    }
}

#[test]
fn mid_plan_failure_surfaces_the_site_param() {
    let (ck, grams) = setup();
    let spec = CompressionSpec::prune(0.5);
    for workers in [1usize, 4] {
        let err = compress_model_with(&ck, &grams, &FailOnMlpDown, &spec, false,
                                      &Executor::with_workers(workers))
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("w_down"), "workers={workers}: {msg}");
        assert!(msg.contains("synthetic mid-plan failure"),
                "workers={workers}: {msg}");
    }
}
