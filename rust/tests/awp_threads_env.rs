//! The `AWP_THREADS` env knob: pipeline outputs must be bit-identical at
//! `AWP_THREADS=1` and `AWP_THREADS=4` through the ambient (env-sized)
//! executor.
//!
//! This is deliberately the *only* test in this binary: integration-test
//! files compile to separate processes, and `std::env::set_var` is only
//! safe when no other thread in the process reads the environment
//! concurrently (glibc `setenv` vs `getenv` races are UB). Within this
//! single test the mutations happen strictly between pipeline runs, while
//! all worker threads are joined.

use std::collections::HashMap;

use awp::compress::traits::CompressionSpec;
use awp::compress::AwpCpu;
use awp::coordinator::calibrate::Grams;
use awp::coordinator::compress_model;
use awp::model::{Checkpoint, GramKey, ModelConfig};
use awp::tensor::Matrix;

fn setup() -> (Checkpoint, Grams) {
    let cfg = ModelConfig {
        name: "t".into(), vocab: 64, d_model: 32, n_heads: 2, n_layers: 2,
        d_ff: 64, seq_len: 16, batch: 1, decode_len: 8, rope_theta: 1e4,
    };
    let ck = awp::trainer::init_checkpoint(&cfg, 11);
    let mut map = HashMap::new();
    for l in 0..cfg.n_layers {
        for key in [GramKey::AttnIn, GramKey::AttnOutIn, GramKey::MlpIn] {
            map.insert((key, l),
                       Matrix::randn_gram(cfg.d_model, 5 * l as u64 + key.index() as u64));
        }
        map.insert((GramKey::MlpDownIn, l), Matrix::randn_gram(cfg.d_ff, 55 + l as u64));
    }
    (ck, Grams { map, tokens: 2048 })
}

#[test]
fn awp_threads_env_matches_across_settings() {
    let (ck, grams) = setup();
    let spec = CompressionSpec::prune(0.5);
    let compressor = AwpCpu::default();
    std::env::set_var("AWP_THREADS", "1");
    let one = compress_model(&ck, &grams, &compressor, &spec, true).unwrap();
    std::env::set_var("AWP_THREADS", "4");
    let four = compress_model(&ck, &grams, &compressor, &spec, true).unwrap();
    std::env::remove_var("AWP_THREADS");

    assert_eq!(one.checkpoint.tensors.len(), four.checkpoint.tensors.len());
    for ((n1, s1, d1), (n2, s2, d2)) in
        one.checkpoint.tensors.iter().zip(&four.checkpoint.tensors) {
        assert_eq!(n1, n2);
        assert_eq!(s1, s2, "{n1}");
        for (i, (x, y)) in d1.iter().zip(d2.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{n1}[{i}]: {x} vs {y}");
        }
    }
    assert_eq!(one.checkpoint.meta, four.checkpoint.meta);
    for (r1, r2) in one.reports.iter().zip(&four.reports) {
        assert_eq!(r1.param, r2.param);
        assert_eq!(r1.rel_loss.to_bits(), r2.rel_loss.to_bits(), "{}", r1.param);
        assert_eq!(r1.sparsity.to_bits(), r2.sparsity.to_bits(), "{}", r1.param);
    }
}
