//! Differential test harness for the native packed-inference engine
//! (`awp::infer`): the forward pass over `PackedLinear` sites must be
//! **bit-identical** to the same pass over the dense weights — logits,
//! NLL, perplexity and greedy generation — for every spec family the
//! artifact codec serves, with **zero** decode-to-dense assemblies on the
//! packed route, and deterministic across thread budgets (1 vs 4).

mod common;

use awp::artifact::{ArtifactSite, ModelArtifact, PackedLinear};
use awp::compress::traits::CompressionSpec;
use awp::data::{Batcher, CorpusConfig, Split, SyntheticCorpus};
use awp::eval::{native_generate, native_perplexity, LayerReport};
use awp::infer::NativeModel;
use awp::model::{sites, Checkpoint, ModelConfig};
use awp::proj::ProjScratch;
use awp::util::parallel::with_thread_budget;

use common::{assert_bits_eq, lm_cfg, tiny_cfg};

/// The four mode families the harness sweeps (ISSUE: int4 grouped, 2:4,
/// nm:4:8, joint).
fn spec_families() -> Vec<(&'static str, CompressionSpec)> {
    vec![
        ("int4-g32", CompressionSpec::quant(4, 32)),
        ("2:4", CompressionSpec::structured_nm(2, 4)),
        ("nm:4:8", CompressionSpec::structured_nm(4, 8)),
        ("joint", CompressionSpec::joint(0.5, 4, 32)),
    ]
}

/// Project every site of `ck` onto `spec`'s constraint set; returns the
/// compressed dense checkpoint (the reference side) and a packed artifact
/// over the same Θ (the packed side), with every site decode-verified.
fn compress_and_pack(ck: &Checkpoint, spec: &CompressionSpec)
    -> (Checkpoint, ModelArtifact) {
    let mut dense = ck.with_tensors(Vec::new()).unwrap();
    let mut packed_sites = Vec::new();
    for s in sites::enumerate_sites(&ck.config) {
        let mut theta = ck.matrix(&s.param).unwrap();
        spec.projection(theta.cols)
            .project_rows(&mut theta, &mut ProjScratch::new());
        let packed = PackedLinear::encode(&theta, spec);
        assert!(packed.reconstructs(&theta), "{}: lossy pack", s.param);
        packed_sites.push(ArtifactSite {
            param: s.param.clone(),
            packed,
            report: LayerReport {
                param: s.param.clone(),
                d_out: s.d_out,
                d_in: s.d_in,
                rel_loss: 0.0,
                sparsity: 0.0,
                row_uniform: false,
                iterations: 0,
                seconds: 0.0,
            },
        });
        dense.set(&s.param, theta.data).unwrap();
    }
    let art = ModelArtifact {
        model: ck.config.name.clone(),
        checkpoint: ck.fingerprint(),
        calib: 0,
        method: "proj".into(),
        spec: spec.fingerprint(),
        spec_desc: spec.describe(),
        params: 0,
        compressed_with: "proj".into(),
        sites: packed_sites,
    };
    (dense, art)
}

fn synthetic_tokens(cfg: &ModelConfig, batch: usize, seq: usize, seed: u64)
    -> Vec<i32> {
    let mut rng = awp::util::Rng::new(seed);
    (0..batch * seq).map(|_| rng.below(cfg.vocab) as i32).collect()
}

#[test]
fn packed_forward_logits_and_nll_are_bit_identical_across_modes() {
    for seed in 0..3u64 {
        let ck = awp::trainer::init_checkpoint(&tiny_cfg(), seed);
        let tokens = synthetic_tokens(&ck.config, 2, 8, 100 + seed);
        for (name, spec) in spec_families() {
            let (dense_ck, art) = compress_and_pack(&ck, &spec);
            let dense = NativeModel::from_checkpoint(&dense_ck).unwrap();
            let packed = NativeModel::from_artifact(&ck, &art).unwrap();
            // the packed route assembles no f32 site weights at all
            assert_eq!(packed.dense_site_count(), 0, "{name}");
            assert_eq!(packed.packed_site_count(), 12, "{name}");
            let a = dense.forward(&tokens, 2, 8).unwrap();
            let b = packed.forward(&tokens, 2, 8).unwrap();
            assert_bits_eq(&a, &b, &format!("seed={seed} {name} logits"));
            let (na, ca) = dense.nll(&tokens, 2, 8).unwrap();
            let (nb, cb) = packed.nll(&tokens, 2, 8).unwrap();
            assert_eq!(na.to_bits(), nb.to_bits(), "seed={seed} {name} nll");
            assert_eq!(ca, cb);
        }
    }
}

#[test]
fn packed_perplexity_is_bit_identical_across_modes() {
    // full protocol: sequential non-overlapping val windows over a real
    // (byte-token) corpus, so the model needs the full byte vocabulary
    let cfg = lm_cfg();
    let ck = awp::trainer::init_checkpoint(&cfg, 7);
    let corpus = SyntheticCorpus::generate(CorpusConfig {
        total_bytes: 64 << 10,
        ..Default::default()
    });
    let batcher = Batcher::new(&corpus, cfg.batch, cfg.seq_len);
    for (name, spec) in spec_families() {
        let (dense_ck, art) = compress_and_pack(&ck, &spec);
        let dense = NativeModel::from_checkpoint(&dense_ck).unwrap();
        let packed = NativeModel::from_artifact(&ck, &art).unwrap();
        let a = native_perplexity(&dense, &batcher, Split::Val, 4).unwrap();
        let b = native_perplexity(&packed, &batcher, Split::Val, 4).unwrap();
        assert_eq!(a.ppl.to_bits(), b.ppl.to_bits(),
                   "{name}: ppl {} vs {}", a.ppl, b.ppl);
        assert_eq!(a.nll_per_token.to_bits(), b.nll_per_token.to_bits(), "{name}");
        assert_eq!((a.tokens, a.batches), (b.tokens, b.batches), "{name}");
        assert!(a.ppl.is_finite() && a.ppl > 1.0, "{name}: ppl {}", a.ppl);
    }
}

#[test]
fn forward_is_deterministic_across_thread_budgets() {
    let ck = awp::trainer::init_checkpoint(&tiny_cfg(), 11);
    let (dense_ck, art) = compress_and_pack(&ck, &CompressionSpec::quant(4, 32));
    let dense = NativeModel::from_checkpoint(&dense_ck).unwrap();
    let packed = NativeModel::from_artifact(&ck, &art).unwrap();
    let tokens = synthetic_tokens(&ck.config, 2, 8, 500);
    let one = with_thread_budget(1, || dense.forward(&tokens, 2, 8).unwrap());
    let four = with_thread_budget(4, || dense.forward(&tokens, 2, 8).unwrap());
    assert_bits_eq(&one, &four, "dense 1 vs 4 threads");
    let pone = with_thread_budget(1, || packed.forward(&tokens, 2, 8).unwrap());
    let pfour = with_thread_budget(4, || packed.forward(&tokens, 2, 8).unwrap());
    assert_bits_eq(&pone, &pfour, "packed 1 vs 4 threads");
    assert_bits_eq(&one, &pone, "dense vs packed");
}

#[test]
fn native_generate_is_deterministic_across_threads_and_representations() {
    // byte prompts need the byte vocabulary
    let cfg = lm_cfg();
    let ck = awp::trainer::init_checkpoint(&cfg, 13);
    let (dense_ck, art) = compress_and_pack(&ck, &CompressionSpec::joint(0.5, 4, 32));
    let dense = NativeModel::from_checkpoint(&dense_ck).unwrap();
    let packed = NativeModel::from_artifact(&ck, &art).unwrap();
    // prompt shorter than decode_len: exercises the tokenizer-pad window
    let a1 = with_thread_budget(1, || native_generate(&dense, "The ", 12).unwrap());
    let a4 = with_thread_budget(4, || native_generate(&dense, "The ", 12).unwrap());
    assert_eq!(a1, a4, "dense generate 1 vs 4 threads");
    let b1 = with_thread_budget(1, || native_generate(&packed, "The ", 12).unwrap());
    let b4 = with_thread_budget(4, || native_generate(&packed, "The ", 12).unwrap());
    assert_eq!(b1, b4, "packed generate 1 vs 4 threads");
    // identical logits ⇒ identical greedy text across representations
    assert_eq!(a1, b1, "dense vs packed generation");
    assert!(a1.starts_with("The "));
}

#[test]
fn from_artifact_rejects_incomplete_artifacts() {
    let ck = awp::trainer::init_checkpoint(&tiny_cfg(), 1);
    let (_, mut art) = compress_and_pack(&ck, &CompressionSpec::prune(0.5));
    art.sites.pop();
    let err = NativeModel::from_artifact(&ck, &art).unwrap_err();
    assert!(format!("{err:#}").contains("artifact misses site"),
            "{err:#}");
}
