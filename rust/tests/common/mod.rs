//! Shared scaffolding for the integration suites — the tiny synthetic
//! model/checkpoint builders, cache/artifact key builders, temp cache-dir
//! helper and bitwise assertion helpers that were previously copy-pasted
//! across `artifact_store.rs`, `packed_exec.rs`, `gram_cache.rs` and
//! `cross_model_sweep.rs` (and that `native_forward.rs` now reuses).
//!
//! Each integration test is its own crate, so not every binary uses every
//! helper — hence the module-wide `dead_code` allowance.
#![allow(dead_code)]

use awp::artifact::ArtifactKey;
use awp::compress::traits::CompressionSpec;
use awp::config::RunConfig;
use awp::coordinator::cache::{CalibSpec, GramCacheKey};
use awp::coordinator::calibrate::Grams;
use awp::coordinator::{Method, TableSpec};
use awp::model::{Checkpoint, ModelConfig};
use awp::tensor::Matrix;
use awp::util::tempdir::TempDir;

/// The suites' standard tiny model: 2 blocks, 32-wide, vocab 64.
pub fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "t".into(),
        vocab: 64,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        d_ff: 64,
        seq_len: 16,
        batch: 1,
        decode_len: 8,
        rope_theta: 1e4,
    }
}

/// [`tiny_cfg`] with the full byte vocabulary and a 2-row batch — what the
/// native-forward suites use so corpus tokens (bytes) stay in range.
pub fn lm_cfg() -> ModelConfig {
    ModelConfig { name: "lm".into(), vocab: 256, batch: 2, ..tiny_cfg() }
}

/// Deterministic untrained checkpoint over [`tiny_cfg`].
pub fn tiny_checkpoint(seed: u64) -> Checkpoint {
    awp::trainer::init_checkpoint(&tiny_cfg(), seed)
}

/// Unique temp cache/store directory (auto-removed on drop).
pub fn temp_cache_dir(tag: &str) -> TempDir {
    TempDir::new(tag).unwrap()
}

/// Gram-cache key for `ck` under the default run config.
pub fn gram_key_for(ck: &Checkpoint, provider: &str) -> GramCacheKey {
    let rc = RunConfig::default();
    GramCacheKey {
        model: ck.config.name.clone(),
        checkpoint: ck.fingerprint(),
        calib: CalibSpec::from_run(&rc, &ck.config, provider).fingerprint(),
    }
}

/// Artifact key for `(ck, method, spec)` with a fixed calib fingerprint.
pub fn artifact_key_for(ck: &Checkpoint, method: &str, spec: &CompressionSpec)
    -> ArtifactKey {
    ArtifactKey::new(
        GramCacheKey {
            model: ck.config.name.clone(),
            checkpoint: ck.fingerprint(),
            calib: 42,
        },
        method,
        spec,
    )
}

/// Two-cell magnitude-prune table over `model` (sweep-scheduling suites).
pub fn prune_table(name: &str, model: &str) -> TableSpec {
    TableSpec {
        name: name.into(),
        model: model.into(),
        col_header: "method".into(),
        columns: vec!["50%".into(), "70%".into()],
        methods: vec![Method::Magnitude],
        specs: vec![CompressionSpec::prune(0.5), CompressionSpec::prune(0.7)],
        title_prefix: format!("{name} title"),
        title_extra: String::new(),
    }
}

/// Bitwise matrix equality with an entry-indexed failure message.
pub fn assert_bits_eq(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what} entry {i}: {x} vs {y}");
    }
}

/// Bitwise checkpoint equality across names, shapes and tensor bits.
pub fn assert_ck_bits_equal(a: &Checkpoint, b: &Checkpoint) {
    assert_eq!(a.tensors.len(), b.tensors.len());
    for ((n1, s1, d1), (n2, s2, d2)) in a.tensors.iter().zip(&b.tensors) {
        assert_eq!((n1, s1), (n2, s2));
        for (x, y) in d1.iter().zip(d2) {
            assert_eq!(x.to_bits(), y.to_bits(), "{n1}");
        }
    }
}

/// Bitwise Gram-set equality (token counts, keys, every Gram entry).
pub fn assert_grams_bit_equal(a: &Grams, b: &Grams) {
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.map.len(), b.map.len());
    for (k, m) in &a.map {
        let n = b.map.get(k).unwrap_or_else(|| panic!("missing {k:?}"));
        assert_eq!(m.shape(), n.shape(), "{k:?}");
        for (i, (x, y)) in m.data.iter().zip(&n.data).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{k:?}[{i}]");
        }
    }
}
