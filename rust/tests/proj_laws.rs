//! Projection-operator laws and refactor bit-identity pins.
//!
//! Two layers of guarantees for the `proj` subsystem:
//!
//! 1. **Laws** every operator must satisfy (seeded sweeps, no proptest
//!    crate on the image): idempotence, per-group cardinality bounds for
//!    N:M across odd shapes/tail groups, zero-survival through the
//!    intersection, determinism of tie-breaking.
//! 2. **Bit-identity pins**: the projection-routed pipeline must produce
//!    outputs *identical* to the pre-refactor code — both at the operator
//!    level (vs `topk::hard_threshold_rows`, `sparse::project_2_4`,
//!    `quant::project_qmax`, the inline joint composition) and at the
//!    driver level, vs a reference reimplementation of the old
//!    four-chunk-method `AwpBackend` semantics for every historical
//!    `CompressionMode` on fixed seeds.

use awp::compress::awp::AwpHyper;
use awp::compress::traits::{check_constraints, CompressionSpec, LayerCompressor};
use awp::compress::{wanda, AwpCpu, AwpDriver, CpuBackend};
use awp::proj::{
    GroupedIntGrid, Intersect, NmStructured, PgdWorkspace, ProjScratch, Projection,
    RowTopK,
};
use awp::quant;
use awp::sparse;
use awp::tensor::{ops, topk, Matrix};
use awp::util::Rng;

const SWEEPS: u64 = 16;

fn apply(p: &dyn Projection, z: &Matrix) -> Matrix {
    let mut out = z.clone();
    p.project_rows(&mut out, &mut ProjScratch::new());
    out
}

// ---------------------------------------------------------------- laws --

#[test]
fn law_idempotence_all_operators() {
    for seed in 0..SWEEPS {
        let mut rng = Rng::new(seed);
        let m = 4 + rng.below(20);
        let n = 16 * (1 + rng.below(4));
        let z = Matrix::randn(m, n, seed + 10);
        let k = 1 + rng.below(n);
        let ops_list: Vec<Box<dyn Projection>> = vec![
            Box::new(RowTopK::new(k)),
            Box::new(NmStructured::new(2, 4)),
            Box::new(NmStructured::new(4, 8)),
            Box::new(NmStructured::new(1, 4)),
        ];
        for p in &ops_list {
            let once = apply(p.as_ref(), &z);
            let twice = apply(p.as_ref(), &once);
            assert_eq!(once.data, twice.data, "seed={seed} {}", p.describe());
            p.check(&once).unwrap_or_else(|e| {
                panic!("seed={seed} {}: own output fails check: {e}", p.describe())
            });
        }
        // grid + intersect are idempotent up to refit rounding (same
        // tolerance the historical quantize_dequantize idempotence used)
        let grid = GroupedIntGrid::new(15.0, 16);
        let once = apply(&grid, &z);
        let twice = apply(&grid, &once);
        for (a, b) in once.data.iter().zip(&twice.data) {
            assert!((a - b).abs() < 1e-5, "seed={seed} grid: {a} vs {b}");
        }
        let ix = Intersect::new(RowTopK::new(k), GroupedIntGrid::new(7.0, 16));
        let once = apply(&ix, &z);
        let twice = apply(&ix, &once);
        for (a, b) in once.data.iter().zip(&twice.data) {
            assert!((a - b).abs() < 1e-5, "seed={seed} intersect: {a} vs {b}");
        }
        ix.check(&once).unwrap();
    }
}

#[test]
fn law_nm_group_cardinality_odd_shapes_and_tails() {
    // per-group nnz ≤ n across ragged widths, including tail groups
    for seed in 0..SWEEPS {
        let mut rng = Rng::new(seed);
        let rows = 1 + rng.below(12);
        let cols = 3 + rng.below(61); // deliberately not aligned to m
        let m = 2 + rng.below(7);
        let n = 1 + rng.below(m);
        let nm = NmStructured::new(n, m);
        let z = Matrix::randn(rows, cols, seed + 100);
        let p = apply(&nm, &z);
        nm.check(&p).unwrap_or_else(|e| {
            panic!("seed={seed} {rows}x{cols} {}: {e}", nm.describe())
        });
        for i in 0..rows {
            for g in (0..cols).step_by(m) {
                let end = (g + m).min(cols);
                let nnz = p.row(i)[g..end].iter().filter(|&&v| v != 0.0).count();
                assert!(nnz <= n, "seed={seed} row {i} group {g}: {nnz} > {n}");
                // full groups keep exactly min(n, group) on dense input
                if end - g == m {
                    assert_eq!(nnz, n.min(end - g), "seed={seed} row {i} group {g}");
                }
            }
        }
        // kept entries are unchanged
        for (a, b) in z.data.iter().zip(&p.data) {
            assert!(*b == 0.0 || a == b, "seed={seed}");
        }
    }
}

#[test]
fn law_intersect_zero_survival_on_grid() {
    // entries zeroed by the sparsity half must come out of the grid as
    // exact zeros — for both row-top-k and N:M sparsity halves
    for seed in 0..SWEEPS {
        let mut rng = Rng::new(seed);
        let rows = 2 + rng.below(10);
        let cols = 32 * (1 + rng.below(3));
        let k = 1 + rng.below(cols / 2);
        let z = Matrix::randn(rows, cols, seed + 200);
        let qmax = [1.0f32, 3.0, 15.0][rng.below(3)];

        let row_half = RowTopK::new(k);
        let sparse_only = apply(&row_half, &z);
        let joint = apply(&Intersect::new(row_half, GroupedIntGrid::new(qmax, 32)), &z);
        for (i, (s, j)) in sparse_only.data.iter().zip(&joint.data).enumerate() {
            if *s == 0.0 {
                assert_eq!(*j, 0.0, "seed={seed} entry {i} resurrected by the grid");
            }
        }

        let nm_half = NmStructured::new(2, 4);
        let sparse_only = apply(&nm_half, &z);
        let joint = apply(&Intersect::new(nm_half, GroupedIntGrid::new(qmax, 32)), &z);
        for (i, (s, j)) in sparse_only.data.iter().zip(&joint.data).enumerate() {
            if *s == 0.0 {
                assert_eq!(*j, 0.0, "seed={seed} entry {i} resurrected by the grid");
            }
        }
        assert!(sparse::check_2_4(&joint), "seed={seed}");
    }
}

// -------------------------------------------- operator bit-identity pins --

#[test]
fn pin_row_topk_equals_hard_threshold_rows() {
    for seed in 0..SWEEPS {
        let mut rng = Rng::new(seed);
        let m = 1 + rng.below(24);
        let n = 8 + rng.below(72);
        let z = Matrix::randn(m, n, seed + 300);
        for k in [0, 1, n / 2, n - 1, n, n + 3] {
            let want = topk::hard_threshold_rows(&z, k);
            let got = apply(&RowTopK::new(k), &z);
            assert_eq!(got.data, want.data, "seed={seed} k={k}");
        }
    }
}

#[test]
fn pin_nm_24_equals_project_2_4() {
    for seed in 0..SWEEPS {
        let mut rng = Rng::new(seed);
        let m = 1 + rng.below(24);
        let n = 4 * (1 + rng.below(24));
        let z = Matrix::randn(m, n, seed + 400);
        let want = sparse::project_2_4(&z);
        let got = apply(&NmStructured::new(2, 4), &z);
        assert_eq!(got.data, want.data, "seed={seed}");
    }
}

#[test]
fn pin_grid_equals_project_qmax() {
    for seed in 0..SWEEPS {
        let mut rng = Rng::new(seed);
        let m = 1 + rng.below(16);
        let group = [8usize, 16, 32][rng.below(3)];
        let n = group * (1 + rng.below(4));
        let z = Matrix::randn(m, n, seed + 500);
        for bits in [1u32, 2, 3, 4, 8] {
            let qmax = (1u32 << bits) as f32 - 1.0;
            let want = quant::project_qmax(&z, qmax, group);
            let got = apply(&GroupedIntGrid::new(qmax, group), &z);
            assert_eq!(got.data, want.data, "seed={seed} bits={bits} group={group}");
        }
    }
}

#[test]
fn pin_intersect_equals_inline_joint_composition() {
    for seed in 0..SWEEPS {
        let mut rng = Rng::new(seed);
        let m = 1 + rng.below(16);
        let n = 32 * (1 + rng.below(3));
        let k = 1 + rng.below(n);
        let z = Matrix::randn(m, n, seed + 600);
        // the exact composition awp_cpu::joint_chunk used to inline
        let zp = topk::hard_threshold_rows(&z, k);
        let mut want = quant::project_qmax(&zp, 15.0, 32.min(zp.cols));
        for (q, p) in want.data.iter_mut().zip(&zp.data) {
            if *p == 0.0 {
                *q = 0.0;
            }
        }
        let got = apply(&Intersect::new(RowTopK::new(k), GroupedIntGrid::new(15.0, 32)),
                        &z);
        assert_eq!(got.data, want.data, "seed={seed} k={k}");
    }
}

// ------------------------------------- driver-level bit-identity pins --
//
// Reference reimplementation of the pre-refactor driver: the old
// `AwpBackend` four chunk methods (fresh allocations per iteration) plus
// the old `run_prune`/`run_quant`/`run_joint`/`run_prune24` loops, kept
// verbatim so the workspace-routed driver can be diffed against it.

fn ref_stats(w: &Matrix, th: &Matrix, c: &Matrix) -> (f64, f64) {
    let wn = w.frob_norm().max(1e-30);
    (ops::grad_frob_norm(w, th, c) / wn,
     ops::activation_loss(w, th, c).sqrt() / wn)
}

fn ref_prune_chunk(w: &Matrix, theta: &Matrix, c: &Matrix, eta: f32, k: usize,
                   iters: usize) -> (Matrix, f64, f64) {
    let mut th = theta.clone();
    for _ in 0..iters {
        let z = ops::pgd_step(w, &th, c, eta);
        th = topk::hard_threshold_rows(&z, k);
    }
    let (g, l) = ref_stats(w, &th, c);
    (th, g, l)
}

fn ref_quant_chunk(w: &Matrix, theta: &Matrix, c: &Matrix, eta: f32, qmax: f32,
                   group: usize, iters: usize) -> (Matrix, f64, f64) {
    let mut th = theta.clone();
    for _ in 0..iters {
        let z = ops::pgd_step(w, &th, c, eta);
        th = quant::project_qmax(&z, qmax, group.min(z.cols));
    }
    let (g, l) = ref_stats(w, &th, c);
    (th, g, l)
}

fn ref_joint_chunk(w: &Matrix, theta: &Matrix, c: &Matrix, eta: f32, k: usize,
                   qmax: f32, group: usize, iters: usize) -> (Matrix, f64, f64) {
    let mut th = theta.clone();
    for _ in 0..iters {
        let z = ops::pgd_step(w, &th, c, eta);
        let zp = topk::hard_threshold_rows(&z, k);
        th = if qmax > 0.0 {
            let mut zq = quant::project_qmax(&zp, qmax.max(1.0), group.min(zp.cols));
            for (q, p) in zq.data.iter_mut().zip(&zp.data) {
                if *p == 0.0 {
                    *q = 0.0;
                }
            }
            zq
        } else {
            zp
        };
    }
    let (g, l) = ref_stats(w, &th, c);
    (th, g, l)
}

fn ref_prune24_chunk(w: &Matrix, theta: &Matrix, c: &Matrix, eta: f32,
                     iters: usize) -> (Matrix, f64, f64) {
    let mut th = theta.clone();
    for _ in 0..iters {
        let z = ops::pgd_step(w, &th, c, eta);
        th = sparse::project_2_4(&z);
    }
    let (g, l) = ref_stats(w, &th, c);
    (th, g, l)
}

/// old `run_iht`: chunked steps, stop at rel-grad < tol or the cap.
fn ref_iht<S>(w: &Matrix, h: &AwpHyper, init: Matrix, step: S) -> (Matrix, usize)
where
    S: Fn(&Matrix, usize) -> (Matrix, f64, f64),
{
    let mut theta = init;
    let chunk = h.chunk.max(1);
    let mut iters = 0usize;
    while iters < h.prune_max_iters {
        let n = chunk.min(h.prune_max_iters - iters);
        let (t2, rel_grad, _rel_loss) = step(&theta, n);
        theta = t2;
        iters += n;
        if rel_grad < h.prune_tol {
            break;
        }
    }
    (theta, iters)
}

fn ref_driver_prune(w: &Matrix, c: &Matrix, k: usize, h: &AwpHyper)
    -> (Matrix, usize) {
    let eta = (h.prune_eta_scale / c.frob_norm().max(1e-30)) as f32;
    ref_iht(w, h, wanda::wanda_prune(w, c, k),
            |th, n| ref_prune_chunk(w, th, c, eta, k, n))
}

fn ref_driver_prune24(w: &Matrix, c: &Matrix, h: &AwpHyper) -> (Matrix, usize) {
    let eta = (h.prune_eta_scale / c.frob_norm().max(1e-30)) as f32;
    ref_iht(w, h, wanda::wanda_prune_2_4(w, c),
            |th, n| ref_prune24_chunk(w, th, c, eta, n))
}

fn ref_driver_quant(w: &Matrix, c: &Matrix, qmax: f32, h: &AwpHyper) -> Matrix {
    let eta = (h.quant_eta_scale / c.frob_norm().max(1e-30)) as f32;
    let bits = (qmax + 1.0).log2().round() as u8;
    let spec = quant::QuantSpec::new(bits, h.group);
    let rel = |th: &Matrix| {
        ops::activation_loss(w, th, c).sqrt() / w.frob_norm().max(1e-30)
    };
    let mut theta = quant::quantize_dequantize(w, spec);
    let mut best = theta.clone();
    let mut best_loss = rel(&theta);
    for _ in 0..h.quant_iters {
        let (t2, _g, rel_loss) = ref_quant_chunk(w, &theta, c, eta, qmax, h.group, 1);
        theta = t2;
        if rel_loss < best_loss {
            best_loss = rel_loss;
            best = theta.clone();
        }
    }
    best
}

fn ref_driver_joint(w: &Matrix, c: &Matrix, k: usize, qmax: f32, h: &AwpHyper)
    -> Matrix {
    use awp::compress::schedule::JointPhase;
    let eta = (h.quant_eta_scale / c.frob_norm().max(1e-30)) as f32;
    let mut theta = w.clone();
    let mut best: Option<(f64, Matrix)> = None;
    let mut it = 0usize;
    while it < h.joint.total_iters {
        let phase = h.joint.phase(it);
        let k_now = h.joint.k_at(it, w.cols, k);
        if phase == JointPhase::Ramp {
            theta = wanda::wanda_prune(w, c, k_now);
            it += 1;
            continue;
        }
        let step = match phase {
            JointPhase::Ramp => unreachable!(),
            JointPhase::PruneHold => h.chunk.min(h.joint.prune_only_iters - it),
            JointPhase::Joint => h.chunk.min(h.joint.total_iters - it),
        };
        let q_now = if phase == JointPhase::Joint { qmax } else { 0.0 };
        let (t2, _g, rel_loss) =
            ref_joint_chunk(w, &theta, c, eta, k_now, q_now, h.group, step);
        theta = t2;
        it += step;
        if phase == JointPhase::Joint
            && best.as_ref().map_or(true, |(b, _)| rel_loss < *b)
        {
            best = Some((rel_loss, theta.clone()));
        }
    }
    best.map(|(_, t)| t).unwrap_or(theta)
}

fn problem(seed: u64, rows: usize, cols: usize) -> (Matrix, Matrix) {
    (Matrix::randn(rows, cols, seed), Matrix::randn_gram(cols, seed + 5000))
}

#[test]
fn pin_driver_prune_identical_to_pre_refactor() {
    let h = AwpHyper::default();
    for seed in 0..4u64 {
        let (w, c) = problem(seed + 700, 16, 64);
        let spec = CompressionSpec::prune(0.5);
        let out = AwpCpu::default().compress(&w, &c, &spec).unwrap();
        let (want, want_iters) =
            ref_driver_prune(&w, &c, spec.keep_k(w.cols).unwrap(), &h);
        assert_eq!(out.theta.data, want.data, "seed={seed}");
        assert_eq!(out.stats.iterations, want_iters, "seed={seed}");
    }
}

#[test]
fn pin_driver_structured24_identical_to_pre_refactor() {
    let h = AwpHyper::default();
    for seed in 0..4u64 {
        let (w, c) = problem(seed + 800, 12, 32);
        let out = AwpCpu::default()
            .compress(&w, &c, &CompressionSpec::structured24())
            .unwrap();
        let (want, want_iters) = ref_driver_prune24(&w, &c, &h);
        assert_eq!(out.theta.data, want.data, "seed={seed}");
        assert_eq!(out.stats.iterations, want_iters, "seed={seed}");
    }
}

#[test]
fn pin_driver_quant_identical_to_pre_refactor() {
    let h = AwpHyper::default();
    for seed in 0..4u64 {
        let (w, c) = problem(seed + 900, 12, 64);
        for bits in [2u8, 4] {
            let spec = CompressionSpec::quant(bits, 32);
            let out = AwpCpu::default().compress(&w, &c, &spec).unwrap();
            let want = ref_driver_quant(&w, &c, (1u32 << bits) as f32 - 1.0, &h);
            assert_eq!(out.theta.data, want.data, "seed={seed} bits={bits}");
        }
    }
}

#[test]
fn pin_driver_joint_identical_to_pre_refactor() {
    let h = AwpHyper::default();
    for seed in 0..3u64 {
        let (w, c) = problem(seed + 1000, 12, 64);
        let spec = CompressionSpec::joint(0.5, 4, 32);
        let out = AwpCpu::default().compress(&w, &c, &spec).unwrap();
        let want = ref_driver_joint(&w, &c, spec.keep_k(w.cols).unwrap(), 15.0, &h);
        assert_eq!(out.theta.data, want.data, "seed={seed}");
    }
}

// ------------------------------------------------- allocation behaviour --

#[test]
fn pgd_inner_loop_is_allocation_free_after_warmup() {
    // the tentpole's perf contract: once the workspace and projection
    // scratch are warm, stepping allocates nothing — across every operator
    let w = Matrix::randn(24, 64, 42);
    let c = Matrix::randn_gram(64, 43);
    let projections: Vec<Box<dyn Projection>> = vec![
        Box::new(RowTopK::new(16)),
        Box::new(NmStructured::new(2, 4)),
        Box::new(GroupedIntGrid::new(15.0, 32)),
        Box::new(Intersect::new(RowTopK::new(16), GroupedIntGrid::new(15.0, 32))),
        Box::new(Intersect::new(NmStructured::new(4, 8),
                                GroupedIntGrid::new(15.0, 32))),
    ];
    for p in &projections {
        let mut ws = PgdWorkspace::new(w.clone());
        ws.step(&w, &c, 0.01, p.as_ref()); // warm-up
        let warmed = ws.alloc_events();
        for _ in 0..100 {
            ws.step(&w, &c, 0.01, p.as_ref());
        }
        assert_eq!(ws.alloc_events(), warmed,
                   "{} allocated after warm-up", p.describe());
    }
}

// -------------------------------------------------- N:M end-to-end runs --

#[test]
fn nm_48_end_to_end_through_driver_and_verifier() {
    let (w, c) = problem(1100, 16, 64);
    for spec in [CompressionSpec::structured_nm(4, 8),
                 CompressionSpec::joint_nm(4, 8, 4, 32)] {
        let out = AwpCpu::default().compress(&w, &c, &spec).unwrap();
        check_constraints(&out.theta, &spec)
            .unwrap_or_else(|e| panic!("{spec:?}: {e}"));
        // the projection the spec resolves to accepts its own pipeline output
        spec.projection(w.cols).check(&out.theta).unwrap();
        let stats = sparse::SparsityStats::of(&out.theta);
        assert!(stats.ratio() >= 0.45, "{spec:?}: {}", stats.ratio());
    }
}

#[test]
fn nm_48_not_worse_than_wanda_nm_init() {
    let mut ok = 0;
    for seed in 0..5u64 {
        let (w, c) = problem(seed + 1200, 16, 64);
        let out = AwpCpu::default()
            .compress(&w, &c, &CompressionSpec::structured_nm(4, 8))
            .unwrap();
        let init = wanda::wanda_prune_nm(&w, &c, 4, 8);
        if out.stats.final_loss <= ops::activation_loss(&w, &init, &c) * 1.0001 {
            ok += 1;
        }
    }
    assert!(ok >= 4, "improved on wanda-4:8 only {ok}/5");
}

#[test]
fn fig1_series_still_tracks_under_projection_routing() {
    // series collection is opt-in (run_quant no longer builds it
    // unconditionally) but must still work when requested
    let (w, c) = problem(1300, 12, 64);
    let hyper = AwpHyper { track_series: true, ..AwpHyper::default() };
    let drv = AwpDriver::with_hyper(CpuBackend, hyper);
    let quant = drv.compress(&w, &c, &CompressionSpec::quant(4, 32)).unwrap();
    assert_eq!(quant.stats.loss_series.len(), hyper.quant_iters + 1);
    let hyper2 = AwpHyper { track_series: false, ..AwpHyper::default() };
    let drv2 = AwpDriver::with_hyper(CpuBackend, hyper2);
    let quiet = drv2.compress(&w, &c, &CompressionSpec::quant(4, 32)).unwrap();
    assert!(quiet.stats.loss_series.is_empty());
    // identical outputs with and without tracking
    assert_eq!(quant.theta.data, quiet.theta.data);
}
