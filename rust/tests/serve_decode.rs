//! Differential + end-to-end coverage for the serving stack: KV-cached
//! incremental decode must be **bit-identical** to the full-window forward
//! at the reference tier (dense and packed sites, any thread budget),
//! within the KERNELS.md tolerance at the fast tier; session eviction must
//! follow the LRU contract; and a real `serve::Server` on a loopback
//! socket must answer `/healthz` and `/v1/generate` — including an exact
//! session continuation — over the wire.

mod common;

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};

use awp::artifact::{ArtifactSite, ModelArtifact, PackedLinear};
use awp::compress::traits::CompressionSpec;
use awp::coordinator::Executor;
use awp::data::ByteTokenizer;
use awp::eval::{argmax, LayerReport};
use awp::infer::{DecodeSession, NativeModel};
use awp::model::{sites, Checkpoint, ModelConfig};
use awp::proj::ProjScratch;
use awp::serve::{Server, ServeInfo, ServeState, SessionStore, TakeError};
use awp::tensor::KernelTier;
use awp::util::json::Json;
use awp::util::parallel::with_thread_budget;

use common::{lm_cfg, tiny_cfg};

/// Dense and packed models over the same projected weights (the
/// `native_forward.rs` idiom) — the two site representations the decode
/// differential sweeps.
fn dense_and_packed(cfg: &ModelConfig, spec: &CompressionSpec, seed: u64)
    -> (NativeModel, NativeModel) {
    let ck = awp::trainer::init_checkpoint(cfg, seed);
    let mut dense_ck = ck.with_tensors(Vec::new()).unwrap();
    let mut packed_sites = Vec::new();
    for s in sites::enumerate_sites(cfg) {
        let mut theta = ck.matrix(&s.param).unwrap();
        spec.projection(theta.cols)
            .project_rows(&mut theta, &mut ProjScratch::new());
        let packed = PackedLinear::encode(&theta, spec);
        assert!(packed.reconstructs(&theta), "{}: lossy pack", s.param);
        packed_sites.push(ArtifactSite {
            param: s.param.clone(),
            packed,
            report: LayerReport {
                param: s.param.clone(),
                d_out: s.d_out,
                d_in: s.d_in,
                rel_loss: 0.0,
                sparsity: 0.0,
                row_uniform: false,
                iterations: 0,
                seconds: 0.0,
            },
        });
        dense_ck.set(&s.param, theta.data).unwrap();
    }
    let art = ModelArtifact {
        model: ck.config.name.clone(),
        checkpoint: ck.fingerprint(),
        calib: 0,
        method: "proj".into(),
        spec: spec.fingerprint(),
        spec_desc: spec.describe(),
        params: 0,
        compressed_with: "proj".into(),
        sites: packed_sites,
    };
    (NativeModel::from_checkpoint(&dense_ck).unwrap(),
     NativeModel::from_artifact(&ck, &art).unwrap())
}

fn synthetic_tokens(cfg: &ModelConfig, n: usize, seed: u64) -> Vec<i32> {
    let mut rng = awp::util::Rng::new(seed);
    (0..n).map(|_| rng.below(cfg.vocab) as i32).collect()
}

/// Every per-position logit vector of a token-by-token KV decode.
fn decode_trace(m: &NativeModel, tokens: &[i32]) -> Vec<Vec<f32>> {
    let mut sess = m.new_session(tokens.len());
    let mut out = vec![m.prefill(&mut sess, &tokens[..1]).unwrap()];
    for &t in &tokens[1..] {
        out.push(m.decode_step(&mut sess, t).unwrap());
    }
    out
}

#[test]
fn kv_decode_is_bit_identical_to_full_window_dense_and_packed() {
    let cfg = tiny_cfg();
    let specs = [("int4-g32", CompressionSpec::quant(4, 32)),
                 ("nm:2:4", CompressionSpec::structured_nm(2, 4))];
    for (name, spec) in specs {
        let (dense, packed) = dense_and_packed(&cfg, &spec, 21);
        assert_eq!(packed.dense_site_count(), 0, "{name}");
        let tokens = synthetic_tokens(&cfg, 10, 300);
        for m in [&dense, &packed] {
            let trace = decode_trace(m, &tokens);
            for (i, got) in trace.iter().enumerate() {
                let full = m.forward(&tokens[..=i], 1, i + 1).unwrap();
                for (j, (a, b)) in got.iter().zip(full.row(i)).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "{name} pos {i} logit {j}: {a} vs {b}");
                }
            }
        }
        // and packed ≡ dense on the cached path itself
        let dt = decode_trace(&dense, &tokens);
        let pt = decode_trace(&packed, &tokens);
        for (i, (a, b)) in dt.iter().zip(&pt).enumerate() {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "{name} dense≠packed @{i}");
            }
        }
    }
}

#[test]
fn kv_decode_is_thread_count_invariant() {
    let cfg = tiny_cfg();
    let (dense, packed) =
        dense_and_packed(&cfg, &CompressionSpec::quant(4, 32), 22);
    let tokens = synthetic_tokens(&cfg, 9, 301);
    for m in [&dense, &packed] {
        let one = with_thread_budget(1, || decode_trace(m, &tokens));
        let four = with_thread_budget(4, || decode_trace(m, &tokens));
        for (i, (a, b)) in one.iter().zip(&four).enumerate() {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "1 vs 4 threads @{i}");
            }
        }
    }
}

#[test]
fn fast_tier_kv_decode_stays_within_tolerance_and_thread_invariant() {
    let cfg = tiny_cfg();
    let (_, mut fast) =
        dense_and_packed(&cfg, &CompressionSpec::quant(4, 32), 23);
    let (_, reference) =
        dense_and_packed(&cfg, &CompressionSpec::quant(4, 32), 23);
    fast.set_tier(KernelTier::Fast);
    let tokens = synthetic_tokens(&cfg, 8, 302);
    let ft = decode_trace(&fast, &tokens);
    let rt = decode_trace(&reference, &tokens);
    for (i, (a, b)) in ft.iter().zip(&rt).enumerate() {
        for (j, (x, y)) in a.iter().zip(b).enumerate() {
            let tol = 1e-4 * (1.0 + x.abs() + y.abs());
            assert!((x - y).abs() <= tol, "pos {i} logit {j}: {x} vs {y}");
        }
    }
    // the fast tier's cached path is still bitwise thread-invariant
    let one = with_thread_budget(1, || decode_trace(&fast, &tokens));
    let four = with_thread_budget(4, || decode_trace(&fast, &tokens));
    for (a, b) in one.iter().zip(&four) {
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.to_bits(), y.to_bits(), "fast tier 1 vs 4 threads");
        }
    }
}

#[test]
fn chunked_prefill_matches_one_shot_on_packed_sites() {
    let cfg = tiny_cfg();
    let (_, packed) =
        dense_and_packed(&cfg, &CompressionSpec::structured_nm(2, 4), 24);
    let tokens = synthetic_tokens(&cfg, 12, 303);
    let one_shot = packed.logits_last(&tokens).unwrap();
    for split in [1, 5, 11] {
        let mut sess = packed.new_session(tokens.len());
        packed.prefill(&mut sess, &tokens[..split]).unwrap();
        let chunked = packed.prefill(&mut sess, &tokens[split..]).unwrap();
        for (a, b) in one_shot.iter().zip(&chunked) {
            assert_eq!(a.to_bits(), b.to_bits(), "split at {split}");
        }
    }
}

#[test]
fn session_store_checkout_and_lru_eviction() {
    let cfg = tiny_cfg();
    let (dense, _) = dense_and_packed(&cfg, &CompressionSpec::quant(4, 32), 25);
    let store = SessionStore::new(2);
    // create → busy until put
    let (a, sa) = store.create(dense.new_session(8));
    assert_eq!(store.take(&a).unwrap_err(), TakeError::Busy);
    store.put(&a, sa);
    // fill past the cap: the oldest idle session goes
    let (b, sb) = store.create(dense.new_session(8));
    store.put(&b, sb);
    let (c, sc) = store.create(dense.new_session(8));
    store.put(&c, sc);
    assert_eq!(store.len(), 2);
    assert_eq!(store.evicted(), 1);
    assert_eq!(store.take(&a).unwrap_err(), TakeError::Unknown);
    // surviving sessions still check out and carry their KV state
    let mut sb = store.take(&b).unwrap();
    dense.prefill(&mut sb.kv, &[1, 2, 3]).unwrap();
    sb.tokens.extend_from_slice(&[1, 2, 3]);
    store.put(&b, sb);
    let sb = store.take(&b).unwrap();
    assert_eq!(sb.kv.len(), 3);
    assert_eq!(sb.tokens, [1, 2, 3]);
}

// ----------------------------------------------------------------- loopback

/// Minimal HTTP/1.1 client for the loopback tests: one request per
/// connection, returns (status, parsed JSON body).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str)
    -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(stream,
           "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\
            \r\n{body}",
           body.len())
        .unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap(); // server closes after response
    let status: u16 = raw
        .strip_prefix("HTTP/1.1 ")
        .and_then(|r| r.split(' ').next())
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad response: {raw:?}"));
    let json = Json::parse(raw.split("\r\n\r\n").nth(1).unwrap()).unwrap();
    (status, json)
}

fn lm_state(ck: &Checkpoint, max_ctx: usize, max_sessions: usize) -> ServeState {
    let model = NativeModel::from_checkpoint(ck).unwrap();
    let info = ServeInfo {
        model: ck.config.name.clone(),
        source: "loopback-test".into(),
        method: "proj".into(),
        spec: "dense".into(),
        packed_bytes: 0,
    };
    ServeState::new(model, info, Executor::with_workers(2), max_ctx,
                    max_sessions)
}

/// Replay the `/v1/generate` handler's exact greedy loop locally.
fn expected_generation(model: &NativeModel, sess: &mut DecodeSession,
                       prompt: &str, max_tokens: usize) -> String {
    let tok = ByteTokenizer;
    let prompt_tokens: Vec<i32> = tok.encode(prompt.as_bytes());
    let mut logits = model.prefill(sess, &prompt_tokens).unwrap();
    let mut generated = Vec::new();
    for _ in 0..max_tokens {
        let next = argmax(&logits);
        generated.push(next);
        logits = model.decode_step(sess, next).unwrap();
    }
    tok.decode_lossy_string(&generated)
}

#[test]
fn loopback_server_answers_healthz_and_generate() {
    let cfg = lm_cfg(); // full byte vocab so arbitrary prompts stay in range
    let ck = awp::trainer::init_checkpoint(&cfg, 31);
    let server = Server::new(lm_state(&ck, 64, 4), Executor::with_workers(2));
    let oracle = NativeModel::from_checkpoint(&ck).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let handle = scope.spawn(|| server.serve(listener, &stop).unwrap());
        // healthz
        let (status, v) = http(addr, "GET", "/healthz", "");
        assert_eq!(status, 200);
        assert!(v.expect("ok").unwrap().as_bool().unwrap());
        assert_eq!(v.expect("model").unwrap().as_str().unwrap(), "lm");
        // inspect
        let (status, v) = http(addr, "GET", "/v1/inspect", "");
        assert_eq!(status, 200);
        assert_eq!(v.expect("max_ctx").unwrap().as_usize().unwrap(), 64);
        // generate: a fresh session, then an exact continuation of it
        let (status, v) = http(addr, "POST", "/v1/generate",
                               r#"{"prompt":"ab","max_tokens":4}"#);
        assert_eq!(status, 200, "{v:?}");
        let sid = v.expect("session").unwrap().as_str().unwrap().to_string();
        let text1 = v.expect("text").unwrap().as_str().unwrap().to_string();
        let body = format!(
            r#"{{"prompt":"cd","max_tokens":3,"session":"{sid}"}}"#);
        let (status, v) = http(addr, "POST", "/v1/generate", &body);
        assert_eq!(status, 200, "{v:?}");
        let text2 = v.expect("text").unwrap().as_str().unwrap().to_string();
        assert_eq!(v.expect("context_tokens").unwrap().as_usize().unwrap(),
                   2 + 4 + 2 + 3);
        // both responses must equal a local replay over one shared session
        let mut sess = oracle.new_session(64);
        assert_eq!(text1, expected_generation(&oracle, &mut sess, "ab", 4));
        assert_eq!(text2, expected_generation(&oracle, &mut sess, "cd", 3));
        // perplexity endpoint
        let (status, v) = http(addr, "POST", "/v1/perplexity",
                               r#"{"text":"the quick brown fox"}"#);
        assert_eq!(status, 200, "{v:?}");
        assert!(v.expect("ppl").unwrap().as_f64().unwrap() > 1.0);
        // error paths over the wire
        assert_eq!(http(addr, "GET", "/nope", "").0, 404);
        assert_eq!(http(addr, "POST", "/healthz", "").0, 405);
        assert_eq!(
            http(addr, "POST", "/v1/generate",
                 r#"{"prompt":"x","session":"s-404"}"#).0, 404);
        assert_eq!(http(addr, "POST", "/v1/generate", "not json").0, 400);
        // graceful stop: serve() drains and returns the request count
        stop.store(true, Ordering::SeqCst);
        let served = handle.join().unwrap();
        assert!(served >= 9, "served {served}");
    });
    // the session survives in the state after shutdown (drained, not killed)
    assert_eq!(server.state().sessions.len(), 1);
}

#[test]
fn loopback_server_evicts_lru_sessions_at_cap() {
    let cfg = lm_cfg();
    let ck = awp::trainer::init_checkpoint(&cfg, 32);
    let server = Server::new(lm_state(&ck, 32, 1), Executor::with_workers(1));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let handle = scope.spawn(|| server.serve(listener, &stop).unwrap());
        let (_, v1) = http(addr, "POST", "/v1/generate",
                           r#"{"prompt":"a","max_tokens":2}"#);
        let s1 = v1.expect("session").unwrap().as_str().unwrap().to_string();
        let (_, v2) = http(addr, "POST", "/v1/generate",
                           r#"{"prompt":"b","max_tokens":2}"#);
        let s2 = v2.expect("session").unwrap().as_str().unwrap().to_string();
        assert_ne!(s1, s2);
        // cap is 1: the older session was evicted, the newer one still works
        let gone = format!(r#"{{"prompt":"c","session":"{s1}"}}"#);
        assert_eq!(http(addr, "POST", "/v1/generate", &gone).0, 404);
        let alive = format!(
            r#"{{"prompt":"c","max_tokens":1,"session":"{s2}"}}"#);
        assert_eq!(http(addr, "POST", "/v1/generate", &alive).0, 200);
        // a request that cannot fit the context window is a clean 422
        let too_big = format!(
            r#"{{"prompt":"d","max_tokens":999,"session":"{s2}"}}"#);
        assert_eq!(http(addr, "POST", "/v1/generate", &too_big).0, 422);
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    });
    assert_eq!(server.state().sessions.evicted(), 1);
}
