//! Differential + end-to-end coverage for the serving stack: KV-cached
//! incremental decode must be **bit-identical** to the full-window forward
//! at the reference tier (dense and packed sites, any thread budget),
//! within the KERNELS.md tolerance at the fast tier; the fused
//! multi-session `decode_step_batch` must be bit-identical per session to
//! serial `decode_step` at the reference tier on ragged batches; session
//! eviction must follow the LRU contract; and a real `serve::Server` on a
//! loopback socket must answer `/healthz` and `/v1/generate` — including
//! an exact session continuation, N concurrent clients whose generations
//! each match a serial replay, keep-alive connection reuse, and chunked
//! token streaming — over the wire.

mod common;

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};

use awp::artifact::{ArtifactSite, ModelArtifact, PackedLinear};
use awp::compress::traits::CompressionSpec;
use awp::coordinator::Executor;
use awp::data::ByteTokenizer;
use awp::eval::{argmax, LayerReport};
use awp::infer::{DecodeSession, NativeModel};
use awp::model::{sites, Checkpoint, ModelConfig};
use awp::proj::ProjScratch;
use awp::serve::{ServeInfo, ServeLimits, ServeState, Server, SessionStore,
                 TakeError};
use awp::tensor::KernelTier;
use awp::util::json::Json;
use awp::util::parallel::with_thread_budget;

use common::{lm_cfg, tiny_cfg};

/// Dense and packed models over the same projected weights (the
/// `native_forward.rs` idiom) — the two site representations the decode
/// differential sweeps.
fn dense_and_packed(cfg: &ModelConfig, spec: &CompressionSpec, seed: u64)
    -> (NativeModel, NativeModel) {
    let ck = awp::trainer::init_checkpoint(cfg, seed);
    let mut dense_ck = ck.with_tensors(Vec::new()).unwrap();
    let mut packed_sites = Vec::new();
    for s in sites::enumerate_sites(cfg) {
        let mut theta = ck.matrix(&s.param).unwrap();
        spec.projection(theta.cols)
            .project_rows(&mut theta, &mut ProjScratch::new());
        let packed = PackedLinear::encode(&theta, spec);
        assert!(packed.reconstructs(&theta), "{}: lossy pack", s.param);
        packed_sites.push(ArtifactSite {
            param: s.param.clone(),
            packed,
            report: LayerReport {
                param: s.param.clone(),
                d_out: s.d_out,
                d_in: s.d_in,
                rel_loss: 0.0,
                sparsity: 0.0,
                row_uniform: false,
                iterations: 0,
                seconds: 0.0,
            },
        });
        dense_ck.set(&s.param, theta.data).unwrap();
    }
    let art = ModelArtifact {
        model: ck.config.name.clone(),
        checkpoint: ck.fingerprint(),
        calib: 0,
        method: "proj".into(),
        spec: spec.fingerprint(),
        spec_desc: spec.describe(),
        params: 0,
        compressed_with: "proj".into(),
        sites: packed_sites,
    };
    (NativeModel::from_checkpoint(&dense_ck).unwrap(),
     NativeModel::from_artifact(&ck, &art).unwrap())
}

fn synthetic_tokens(cfg: &ModelConfig, n: usize, seed: u64) -> Vec<i32> {
    let mut rng = awp::util::Rng::new(seed);
    (0..n).map(|_| rng.below(cfg.vocab) as i32).collect()
}

/// Every per-position logit vector of a token-by-token KV decode.
fn decode_trace(m: &NativeModel, tokens: &[i32]) -> Vec<Vec<f32>> {
    let mut sess = m.new_session(tokens.len());
    let mut out = vec![m.prefill(&mut sess, &tokens[..1]).unwrap()];
    for &t in &tokens[1..] {
        out.push(m.decode_step(&mut sess, t).unwrap());
    }
    out
}

#[test]
fn kv_decode_is_bit_identical_to_full_window_dense_and_packed() {
    let cfg = tiny_cfg();
    let specs = [("int4-g32", CompressionSpec::quant(4, 32)),
                 ("nm:2:4", CompressionSpec::structured_nm(2, 4))];
    for (name, spec) in specs {
        let (dense, packed) = dense_and_packed(&cfg, &spec, 21);
        assert_eq!(packed.dense_site_count(), 0, "{name}");
        let tokens = synthetic_tokens(&cfg, 10, 300);
        for m in [&dense, &packed] {
            let trace = decode_trace(m, &tokens);
            for (i, got) in trace.iter().enumerate() {
                let full = m.forward(&tokens[..=i], 1, i + 1).unwrap();
                for (j, (a, b)) in got.iter().zip(full.row(i)).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "{name} pos {i} logit {j}: {a} vs {b}");
                }
            }
        }
        // and packed ≡ dense on the cached path itself
        let dt = decode_trace(&dense, &tokens);
        let pt = decode_trace(&packed, &tokens);
        for (i, (a, b)) in dt.iter().zip(&pt).enumerate() {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "{name} dense≠packed @{i}");
            }
        }
    }
}

#[test]
fn kv_decode_is_thread_count_invariant() {
    let cfg = tiny_cfg();
    let (dense, packed) =
        dense_and_packed(&cfg, &CompressionSpec::quant(4, 32), 22);
    let tokens = synthetic_tokens(&cfg, 9, 301);
    for m in [&dense, &packed] {
        let one = with_thread_budget(1, || decode_trace(m, &tokens));
        let four = with_thread_budget(4, || decode_trace(m, &tokens));
        for (i, (a, b)) in one.iter().zip(&four).enumerate() {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "1 vs 4 threads @{i}");
            }
        }
    }
}

#[test]
fn fast_tier_kv_decode_stays_within_tolerance_and_thread_invariant() {
    let cfg = tiny_cfg();
    let (_, mut fast) =
        dense_and_packed(&cfg, &CompressionSpec::quant(4, 32), 23);
    let (_, reference) =
        dense_and_packed(&cfg, &CompressionSpec::quant(4, 32), 23);
    fast.set_tier(KernelTier::Fast);
    let tokens = synthetic_tokens(&cfg, 8, 302);
    let ft = decode_trace(&fast, &tokens);
    let rt = decode_trace(&reference, &tokens);
    for (i, (a, b)) in ft.iter().zip(&rt).enumerate() {
        for (j, (x, y)) in a.iter().zip(b).enumerate() {
            let tol = 1e-4 * (1.0 + x.abs() + y.abs());
            assert!((x - y).abs() <= tol, "pos {i} logit {j}: {x} vs {y}");
        }
    }
    // the fast tier's cached path is still bitwise thread-invariant
    let one = with_thread_budget(1, || decode_trace(&fast, &tokens));
    let four = with_thread_budget(4, || decode_trace(&fast, &tokens));
    for (a, b) in one.iter().zip(&four) {
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.to_bits(), y.to_bits(), "fast tier 1 vs 4 threads");
        }
    }
}

#[test]
fn chunked_prefill_matches_one_shot_on_packed_sites() {
    let cfg = tiny_cfg();
    let (_, packed) =
        dense_and_packed(&cfg, &CompressionSpec::structured_nm(2, 4), 24);
    let tokens = synthetic_tokens(&cfg, 12, 303);
    let one_shot = packed.logits_last(&tokens).unwrap();
    for split in [1, 5, 11] {
        let mut sess = packed.new_session(tokens.len());
        packed.prefill(&mut sess, &tokens[..split]).unwrap();
        let chunked = packed.prefill(&mut sess, &tokens[split..]).unwrap();
        for (a, b) in one_shot.iter().zip(&chunked) {
            assert_eq!(a.to_bits(), b.to_bits(), "split at {split}");
        }
    }
}

#[test]
fn session_store_checkout_and_lru_eviction() {
    let cfg = tiny_cfg();
    let (dense, _) = dense_and_packed(&cfg, &CompressionSpec::quant(4, 32), 25);
    let store = SessionStore::new(2);
    // create → busy until put
    let (a, sa) = store.create(dense.new_session(8)).unwrap();
    assert_eq!(store.take(&a).unwrap_err(), TakeError::Busy);
    store.put(&a, sa);
    // fill past the cap: the oldest idle session goes
    let (b, sb) = store.create(dense.new_session(8)).unwrap();
    store.put(&b, sb);
    let (c, sc) = store.create(dense.new_session(8)).unwrap();
    store.put(&c, sc);
    assert_eq!(store.len(), 2);
    assert_eq!(store.evicted(), 1);
    assert_eq!(store.take(&a).unwrap_err(), TakeError::Unknown);
    // surviving sessions still check out and carry their KV state
    let mut sb = store.take(&b).unwrap();
    dense.prefill(&mut sb.kv, &[1, 2, 3]).unwrap();
    sb.tokens.extend_from_slice(&[1, 2, 3]);
    store.put(&b, sb);
    let sb = store.take(&b).unwrap();
    assert_eq!(sb.kv.len(), 3);
    assert_eq!(sb.tokens, [1, 2, 3]);
}

// ----------------------------------------------------------------- loopback

/// Minimal HTTP/1.1 client for the loopback tests: one request per
/// connection (`Connection: close` so the server hands the socket back
/// immediately instead of holding it for keep-alive), returns
/// (status, parsed JSON body).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str)
    -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(stream,
           "{method} {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\
            Content-Length: {}\r\n\r\n{body}",
           body.len())
        .unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap(); // server closes after response
    let status: u16 = raw
        .strip_prefix("HTTP/1.1 ")
        .and_then(|r| r.split(' ').next())
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad response: {raw:?}"));
    let json = Json::parse(raw.split("\r\n\r\n").nth(1).unwrap()).unwrap();
    (status, json)
}

/// Read exactly one HTTP response (status line + headers + a
/// `Content-Length`-framed body) off a persistent connection, leaving the
/// stream open for the next request. Returns (status, headers, body).
fn read_response(reader: &mut BufReader<TcpStream>) -> (u16, String, String) {
    let mut head = String::new();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        if line == "\r\n" || line.is_empty() {
            break;
        }
        head.push_str(&line);
    }
    let status: u16 = head
        .strip_prefix("HTTP/1.1 ")
        .and_then(|r| r.split(' ').next())
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad response head: {head:?}"));
    let len: usize = head
        .lines()
        .find_map(|l| {
            let lower = l.to_ascii_lowercase();
            lower
                .strip_prefix("content-length:")
                .map(|v| v.trim().parse::<usize>().unwrap())
        })
        .expect("response has no Content-Length");
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).unwrap();
    (status, head, String::from_utf8_lossy(&body).into_owned())
}

fn lm_state(ck: &Checkpoint, max_ctx: usize, max_sessions: usize,
            max_batch: usize) -> ServeState {
    let model = NativeModel::from_checkpoint(ck).unwrap();
    let info = ServeInfo {
        model: ck.config.name.clone(),
        source: "loopback-test".into(),
        method: "proj".into(),
        spec: "dense".into(),
        packed_bytes: 0,
    };
    ServeState::new(model, info, Executor::with_workers(2), ServeLimits {
        max_ctx,
        max_sessions,
        max_batch,
        ..ServeLimits::default()
    })
}

/// Replay the `/v1/generate` handler's exact greedy loop locally.
fn expected_generation(model: &NativeModel, sess: &mut DecodeSession,
                       prompt: &str, max_tokens: usize) -> String {
    let tok = ByteTokenizer;
    let prompt_tokens: Vec<i32> = tok.encode(prompt.as_bytes());
    let mut logits = model.prefill(sess, &prompt_tokens).unwrap();
    let mut generated = Vec::new();
    for _ in 0..max_tokens {
        let next = argmax(&logits);
        generated.push(next);
        logits = model.decode_step(sess, next).unwrap();
    }
    tok.decode_lossy_string(&generated)
}

#[test]
fn loopback_server_answers_healthz_and_generate() {
    let cfg = lm_cfg(); // full byte vocab so arbitrary prompts stay in range
    let ck = awp::trainer::init_checkpoint(&cfg, 31);
    let server = Server::new(lm_state(&ck, 64, 4, 4), Executor::with_workers(2));
    let oracle = NativeModel::from_checkpoint(&ck).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let handle = scope.spawn(|| server.serve(listener, &stop).unwrap());
        // healthz
        let (status, v) = http(addr, "GET", "/healthz", "");
        assert_eq!(status, 200);
        assert!(v.expect("ok").unwrap().as_bool().unwrap());
        assert_eq!(v.expect("model").unwrap().as_str().unwrap(), "lm");
        // inspect
        let (status, v) = http(addr, "GET", "/v1/inspect", "");
        assert_eq!(status, 200);
        assert_eq!(v.expect("max_ctx").unwrap().as_usize().unwrap(), 64);
        // generate: a fresh session, then an exact continuation of it
        let (status, v) = http(addr, "POST", "/v1/generate",
                               r#"{"prompt":"ab","max_tokens":4}"#);
        assert_eq!(status, 200, "{v:?}");
        let sid = v.expect("session").unwrap().as_str().unwrap().to_string();
        let text1 = v.expect("text").unwrap().as_str().unwrap().to_string();
        let body = format!(
            r#"{{"prompt":"cd","max_tokens":3,"session":"{sid}"}}"#);
        let (status, v) = http(addr, "POST", "/v1/generate", &body);
        assert_eq!(status, 200, "{v:?}");
        let text2 = v.expect("text").unwrap().as_str().unwrap().to_string();
        assert_eq!(v.expect("context_tokens").unwrap().as_usize().unwrap(),
                   2 + 4 + 2 + 3);
        // both responses must equal a local replay over one shared session
        let mut sess = oracle.new_session(64);
        assert_eq!(text1, expected_generation(&oracle, &mut sess, "ab", 4));
        assert_eq!(text2, expected_generation(&oracle, &mut sess, "cd", 3));
        // perplexity endpoint
        let (status, v) = http(addr, "POST", "/v1/perplexity",
                               r#"{"text":"the quick brown fox"}"#);
        assert_eq!(status, 200, "{v:?}");
        assert!(v.expect("ppl").unwrap().as_f64().unwrap() > 1.0);
        // error paths over the wire
        assert_eq!(http(addr, "GET", "/nope", "").0, 404);
        assert_eq!(http(addr, "POST", "/healthz", "").0, 405);
        assert_eq!(
            http(addr, "POST", "/v1/generate",
                 r#"{"prompt":"x","session":"s-404"}"#).0, 404);
        assert_eq!(http(addr, "POST", "/v1/generate", "not json").0, 400);
        // graceful stop: serve() drains and returns the request count
        stop.store(true, Ordering::SeqCst);
        let served = handle.join().unwrap();
        assert!(served >= 9, "served {served}");
    });
    // the session survives in the state after shutdown (drained, not killed)
    assert_eq!(server.state().sessions.len(), 1);
}

#[test]
fn loopback_server_evicts_lru_sessions_at_cap() {
    let cfg = lm_cfg();
    let ck = awp::trainer::init_checkpoint(&cfg, 32);
    let server = Server::new(lm_state(&ck, 32, 1, 4), Executor::with_workers(1));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let handle = scope.spawn(|| server.serve(listener, &stop).unwrap());
        let (_, v1) = http(addr, "POST", "/v1/generate",
                           r#"{"prompt":"a","max_tokens":2}"#);
        let s1 = v1.expect("session").unwrap().as_str().unwrap().to_string();
        let (_, v2) = http(addr, "POST", "/v1/generate",
                           r#"{"prompt":"b","max_tokens":2}"#);
        let s2 = v2.expect("session").unwrap().as_str().unwrap().to_string();
        assert_ne!(s1, s2);
        // cap is 1: the older session was evicted, the newer one still works
        let gone = format!(r#"{{"prompt":"c","session":"{s1}"}}"#);
        assert_eq!(http(addr, "POST", "/v1/generate", &gone).0, 404);
        let alive = format!(
            r#"{{"prompt":"c","max_tokens":1,"session":"{s2}"}}"#);
        assert_eq!(http(addr, "POST", "/v1/generate", &alive).0, 200);
        // a request that cannot fit the context window is a clean 422
        let too_big = format!(
            r#"{{"prompt":"d","max_tokens":999,"session":"{s2}"}}"#);
        assert_eq!(http(addr, "POST", "/v1/generate", &too_big).0, 422);
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    });
    assert_eq!(server.state().sessions.evicted(), 1);
}

// ------------------------------------------------------ continuous batching

#[test]
fn batched_decode_is_bitwise_serial_on_ragged_batches_at_reference_tier() {
    let cfg = tiny_cfg();
    let specs = [("int4-g32", CompressionSpec::quant(4, 32)),
                 ("nm:2:4", CompressionSpec::structured_nm(2, 4))];
    for (name, spec) in specs {
        let (dense, packed) = dense_and_packed(&cfg, &spec, 26);
        for (kind, m) in [("dense", &dense), ("packed", &packed)] {
            for budget in [1usize, 4] {
                with_thread_budget(budget, || {
                    // ragged: different prompt lengths → different KV
                    // depths and RoPE offsets per row of the fused step
                    let prompts: [&[i32]; 3] =
                        [&[1, 2, 3], &[4], &[5, 6, 7, 8, 9]];
                    let ticks: [[i32; 3]; 2] =
                        [[10, 11, 12], [13, 14, 15]];
                    // serial oracle: one decode_step per session per tick
                    let mut serial: Vec<DecodeSession> = prompts
                        .iter()
                        .map(|p| {
                            let mut s = m.new_session(16);
                            m.prefill(&mut s, p).unwrap();
                            s
                        })
                        .collect();
                    let mut serial_logits: Vec<Vec<Vec<f32>>> =
                        vec![Vec::new(); prompts.len()];
                    for toks in &ticks {
                        for (i, s) in serial.iter_mut().enumerate() {
                            serial_logits[i]
                                .push(m.decode_step(s, toks[i]).unwrap());
                        }
                    }
                    // fused: one decode_step_batch per tick
                    let mut batched: Vec<DecodeSession> = prompts
                        .iter()
                        .map(|p| {
                            let mut s = m.new_session(16);
                            m.prefill(&mut s, p).unwrap();
                            s
                        })
                        .collect();
                    for (t, toks) in ticks.iter().enumerate() {
                        let mut refs: Vec<&mut DecodeSession> =
                            batched.iter_mut().collect();
                        let logits =
                            m.decode_step_batch(&mut refs, toks).unwrap();
                        for (i, got) in logits.iter().enumerate() {
                            for (j, (a, b)) in
                                got.iter().zip(&serial_logits[i][t]).enumerate()
                            {
                                assert_eq!(
                                    a.to_bits(), b.to_bits(),
                                    "{name} {kind} budget={budget} sess {i} \
                                     tick {t} logit {j}: {a} vs {b}");
                            }
                        }
                    }
                    // KV state advanced identically too
                    for (i, (s, b)) in
                        serial.iter().zip(&batched).enumerate()
                    {
                        assert_eq!(s.len(), b.len(), "{name} {kind} sess {i}");
                        assert_eq!(s.len(), prompts[i].len() + ticks.len());
                    }
                });
            }
        }
    }
}

#[test]
fn fast_tier_batched_decode_stays_within_tolerance() {
    let cfg = tiny_cfg();
    let (_, mut fast) =
        dense_and_packed(&cfg, &CompressionSpec::quant(4, 32), 27);
    let (_, reference) =
        dense_and_packed(&cfg, &CompressionSpec::quant(4, 32), 27);
    fast.set_tier(KernelTier::Fast);
    let prompts: [&[i32]; 3] = [&[1, 2, 3], &[4], &[5, 6, 7, 8, 9]];
    let ticks: [[i32; 3]; 2] = [[10, 11, 12], [13, 14, 15]];
    let mut serial: Vec<DecodeSession> = prompts
        .iter()
        .map(|p| {
            let mut s = reference.new_session(16);
            reference.prefill(&mut s, p).unwrap();
            s
        })
        .collect();
    let mut batched: Vec<DecodeSession> = prompts
        .iter()
        .map(|p| {
            let mut s = fast.new_session(16);
            fast.prefill(&mut s, p).unwrap();
            s
        })
        .collect();
    for toks in &ticks {
        let serial_logits: Vec<Vec<f32>> = serial
            .iter_mut()
            .enumerate()
            .map(|(i, s)| reference.decode_step(s, toks[i]).unwrap())
            .collect();
        let mut refs: Vec<&mut DecodeSession> = batched.iter_mut().collect();
        let fast_logits = fast.decode_step_batch(&mut refs, toks).unwrap();
        for (i, (f, r)) in fast_logits.iter().zip(&serial_logits).enumerate() {
            for (j, (x, y)) in f.iter().zip(r).enumerate() {
                let tol = 1e-4 * (1.0 + x.abs() + y.abs());
                assert!((x - y).abs() <= tol,
                        "sess {i} logit {j}: {x} vs {y}");
            }
        }
    }
}

#[test]
fn concurrent_clients_each_match_a_serial_replay_bitwise() {
    let cfg = lm_cfg();
    let ck = awp::trainer::init_checkpoint(&cfg, 34);
    // max_batch 4: the four in-flight decodes may fuse into shared ticks;
    // the contract is that fusion is invisible per session
    let server =
        Server::new(lm_state(&ck, 64, 8, 4), Executor::with_workers(4));
    let oracle = NativeModel::from_checkpoint(&ck).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = AtomicBool::new(false);
    let prompts = ["ab", "cde", "f", "ghij"];
    let mut results: Vec<(u16, Json)> = Vec::new();
    std::thread::scope(|scope| {
        let handle = scope.spawn(|| server.serve(listener, &stop).unwrap());
        let clients: Vec<_> = prompts
            .iter()
            .map(|p| {
                scope.spawn(move || {
                    let body =
                        format!(r#"{{"prompt":"{p}","max_tokens":6}}"#);
                    http(addr, "POST", "/v1/generate", &body)
                })
            })
            .collect();
        results = clients.into_iter().map(|c| c.join().unwrap()).collect();
        stop.store(true, Ordering::SeqCst);
        let served = handle.join().unwrap();
        assert!(served >= prompts.len() as u64, "served {served}");
    });
    // each concurrent generation is bit-identical to its serial replay,
    // whatever batch shapes the scheduler happened to fuse
    for (&p, (status, v)) in prompts.iter().zip(&results) {
        assert_eq!(*status, 200, "prompt {p}: {v:?}");
        let text = v.expect("text").unwrap().as_str().unwrap();
        let mut sess = oracle.new_session(64);
        assert_eq!(text, expected_generation(&oracle, &mut sess, p, 6),
                   "prompt {p}");
    }
    assert_eq!(server.state().sessions.len(), 4);
}

#[test]
fn keep_alive_connection_serves_multiple_requests() {
    let cfg = lm_cfg();
    let ck = awp::trainer::init_checkpoint(&cfg, 33);
    let server =
        Server::new(lm_state(&ck, 64, 4, 4), Executor::with_workers(1));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let handle = scope.spawn(|| server.serve(listener, &stop).unwrap());
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        // no Connection header: HTTP/1.1 defaults to keep-alive
        write!(stream, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let (status, head, body) = read_response(&mut reader);
        assert_eq!(status, 200);
        assert!(head.contains("Connection: keep-alive"), "{head:?}");
        assert!(body.contains("\"ok\":true"));
        // second request rides the same connection
        let gen = r#"{"prompt":"ab","max_tokens":2}"#;
        write!(stream,
               "POST /v1/generate HTTP/1.1\r\nHost: t\r\n\
                Content-Length: {}\r\n\r\n{gen}",
               gen.len())
            .unwrap();
        let (status, head, body) = read_response(&mut reader);
        assert_eq!(status, 200, "{body:?}");
        assert!(head.contains("Connection: keep-alive"), "{head:?}");
        assert!(body.contains("\"session\""));
        // an explicit close is honoured
        write!(stream,
               "GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
            .unwrap();
        let (status, head, _) = read_response(&mut reader);
        assert_eq!(status, 200);
        assert!(head.contains("Connection: close"), "{head:?}");
        // the server really closed: the next read sees EOF
        let mut rest = String::new();
        stream.set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        reader.read_to_string(&mut rest).unwrap();
        assert!(rest.is_empty());
        stop.store(true, Ordering::SeqCst);
        // all three requests shared one connection
        assert_eq!(handle.join().unwrap(), 3);
    });
}

#[test]
fn streamed_generate_emits_exact_tokens_over_chunked_wire() {
    let cfg = lm_cfg();
    let ck = awp::trainer::init_checkpoint(&cfg, 35);
    let server =
        Server::new(lm_state(&ck, 64, 4, 4), Executor::with_workers(1));
    let oracle = NativeModel::from_checkpoint(&ck).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let handle = scope.spawn(|| server.serve(listener, &stop).unwrap());
        let body = r#"{"prompt":"ab","max_tokens":4}"#;
        let mut stream = TcpStream::connect(addr).unwrap();
        write!(stream,
               "POST /v1/generate?stream=true HTTP/1.1\r\nHost: t\r\n\
                Connection: close\r\nContent-Length: {}\r\n\r\n{body}",
               body.len())
            .unwrap();
        let mut buf = Vec::new();
        stream.read_to_end(&mut buf).unwrap();
        let raw = String::from_utf8_lossy(&buf);
        assert!(raw.starts_with("HTTP/1.1 200 OK"), "{raw:?}");
        assert!(raw.contains("Transfer-Encoding: chunked"), "{raw:?}");
        assert!(raw.contains("Connection: close"), "{raw:?}");
        assert!(raw.ends_with("0\r\n\r\n"), "{raw:?}");
        assert!(raw.contains("\"done\":true"), "{raw:?}");
        assert!(raw.contains("\"generated_tokens\":4"), "{raw:?}");
        // the streamed token ids are exactly the serial greedy loop's
        let prompt_tokens: Vec<i32> = ByteTokenizer.encode("ab".as_bytes());
        let mut sess = oracle.new_session(64);
        let mut logits = oracle.prefill(&mut sess, &prompt_tokens).unwrap();
        let mut expected = Vec::new();
        for _ in 0..4 {
            let next = argmax(&logits);
            expected.push(next);
            logits = oracle.decode_step(&mut sess, next).unwrap();
        }
        let got: Vec<i32> = raw
            .lines()
            .filter(|l| l.starts_with('{') && l.contains("\"token\":"))
            .map(|l| {
                Json::parse(l).unwrap().expect("token").unwrap()
                    .as_usize().unwrap() as i32
            })
            .collect();
        assert_eq!(got, expected);
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    });
    // the streamed session was put back just like a buffered one
    assert_eq!(server.state().sessions.len(), 1);
}

#[test]
fn unsupported_body_framing_closes_instead_of_desyncing_keep_alive() {
    let cfg = lm_cfg();
    let ck = awp::trainer::init_checkpoint(&cfg, 36);
    let server =
        Server::new(lm_state(&ck, 64, 4, 4), Executor::with_workers(1));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let handle = scope.spawn(|| server.serve(listener, &stop).unwrap());
        // a chunked request body: the pre-fix parser ignored the
        // Transfer-Encoding header, took the body length as 0 and then
        // read the chunk bytes as the *next* request — the smuggled
        // "GET /v1/inspect" below would have been answered 200. The fix
        // refuses the framing outright: typed 501, connection closed,
        // smuggled bytes never parsed.
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        write!(stream,
               "POST /v1/generate HTTP/1.1\r\nHost: t\r\n\
                Transfer-Encoding: chunked\r\n\r\n\
                2\r\n{{}}\r\n0\r\n\r\n\
                GET /v1/inspect HTTP/1.1\r\nHost: t\r\n\r\n")
            .unwrap();
        let (status, head, body) = read_response(&mut reader);
        assert_eq!(status, 501, "{body:?}");
        assert!(body.contains("Transfer-Encoding"), "{body:?}");
        assert!(head.contains("Connection: close"), "{head:?}");
        let mut rest = String::new();
        stream.set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        // EOF, or a reset because the server closed on unread smuggled
        // bytes — either way nothing more was answered
        let _ = reader.read_to_string(&mut rest);
        assert!(rest.is_empty(), "smuggled request was answered: {rest:?}");
        // conflicting Content-Length headers: same desync family, 400
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        write!(stream,
               "POST /v1/perplexity HTTP/1.1\r\nHost: t\r\n\
                Content-Length: 12\r\nContent-Length: 2\r\n\r\n{{\"text\":\"a\"}}")
            .unwrap();
        let (status, head, body) = read_response(&mut reader);
        assert_eq!(status, 400, "{body:?}");
        assert!(body.contains("Content-Length"), "{body:?}");
        assert!(head.contains("Connection: close"), "{head:?}");
        stop.store(true, Ordering::SeqCst);
        // both refused requests were logged as served responses
        assert_eq!(handle.join().unwrap(), 2);
    });
}
