//! End-to-end acceptance tests for the model-weight pager (`awp::artifact::
//! pager`) and the `AWPPACK2` lossless second stage:
//!
//! * a [`NativeModel::from_pager`] model under a byte budget *smaller than
//!   the packed artifact* — so sites page in and out mid-forward — must
//!   produce bit-identical logits and greedy decodes to the eager
//!   [`NativeModel::from_artifact`] load at the reference tier;
//! * `AWPPACK2` must round-trip bit-identically through both the eager
//!   reader and the pager, and never be larger on disk than `AWPPACK1`
//!   for the same payload (per-site coding falls back to stored bytes
//!   when it doesn't win).

mod common;

use std::sync::Arc;

use awp::artifact::{read_artifact, write_artifact_opts, ArtifactPager,
                    ArtifactSite, ModelArtifact, PackedLinear};
use awp::compress::traits::CompressionSpec;
use awp::eval::{argmax, LayerReport};
use awp::infer::NativeModel;
use awp::model::{sites, Checkpoint};
use awp::proj::ProjScratch;

use common::{assert_bits_eq, temp_cache_dir, tiny_checkpoint};

/// Project every site of `ck` onto `spec`'s constraint set and pack the
/// result (same construction as the native-forward differential harness).
fn pack_checkpoint(ck: &Checkpoint, spec: &CompressionSpec) -> ModelArtifact {
    let mut packed_sites = Vec::new();
    for s in sites::enumerate_sites(&ck.config) {
        let mut theta = ck.matrix(&s.param).unwrap();
        spec.projection(theta.cols)
            .project_rows(&mut theta, &mut ProjScratch::new());
        let packed = PackedLinear::encode(&theta, spec);
        assert!(packed.reconstructs(&theta), "{}: lossy pack", s.param);
        packed_sites.push(ArtifactSite {
            param: s.param.clone(),
            packed,
            report: LayerReport {
                param: s.param.clone(),
                d_out: s.d_out,
                d_in: s.d_in,
                rel_loss: 0.0,
                sparsity: 0.0,
                row_uniform: false,
                iterations: 0,
                seconds: 0.0,
            },
        });
    }
    ModelArtifact {
        model: ck.config.name.clone(),
        checkpoint: ck.fingerprint(),
        calib: 0,
        method: "proj".into(),
        spec: spec.fingerprint(),
        spec_desc: spec.describe(),
        params: 0,
        compressed_with: "proj".into(),
        sites: packed_sites,
    }
}

fn tokens(ck: &Checkpoint, n: usize, seed: u64) -> Vec<i32> {
    let mut rng = awp::util::Rng::new(seed);
    (0..n).map(|_| rng.below(ck.config.vocab) as i32).collect()
}

#[test]
fn paged_model_is_bit_identical_to_eager_load_under_tight_budget() {
    let ck = tiny_checkpoint(11);
    let art = pack_checkpoint(&ck, &CompressionSpec::quant(4, 32));
    let dir = temp_cache_dir("pager-e2e");
    let path = dir.path().join("model.apack");
    write_artifact_opts(&path, &art, false).unwrap();

    let eager =
        NativeModel::from_artifact(&ck, &read_artifact(&path).unwrap())
            .unwrap();
    // a budget far below the packed footprint: every forward pass must
    // page sites in and evict them again behind the caller's back
    assert!(art.packed_bytes() > 1024);
    let pager =
        Arc::new(ArtifactPager::open(&path, Some(1024)).unwrap());
    let paged = NativeModel::from_pager(&ck, pager.clone()).unwrap();
    assert_eq!(paged.dense_site_count(), 0);
    assert_eq!(paged.packed_site_count(), eager.packed_site_count());

    let toks = tokens(&ck, 16, 5);
    let a = eager.forward(&toks, 2, 8).unwrap();
    let b = paged.forward(&toks, 2, 8).unwrap();
    assert_bits_eq(&a, &b, "paged vs eager logits");
    let c = pager.counts();
    assert!(c.misses > 0, "nothing paged in");
    assert!(c.evictions > 0, "budget never forced an eviction");
    assert!(pager.resident_bytes() < art.packed_bytes(),
            "resident set ignores the budget");

    // greedy KV-cached decode takes the same token path on both models
    let prompt = tokens(&ck, 6, 9);
    let decode = |m: &NativeModel| {
        let mut sess = m.new_session(prompt.len() + 8);
        let mut logits = m.prefill(&mut sess, &prompt).unwrap();
        let mut out = Vec::new();
        for _ in 0..8 {
            let next = argmax(&logits);
            out.push(next);
            logits = m.decode_step(&mut sess, next).unwrap();
        }
        out
    };
    assert_eq!(decode(&eager), decode(&paged), "greedy decode diverged");
}

#[test]
fn from_pager_open_reads_header_only_and_fails_cleanly_on_missing_sites() {
    let ck = tiny_checkpoint(3);
    let art = pack_checkpoint(&ck, &CompressionSpec::structured_nm(2, 4));
    let dir = temp_cache_dir("pager-hdr");
    let path = dir.path().join("model.apack");
    write_artifact_opts(&path, &art, false).unwrap();

    // truncate to the header: model construction (shape checks included)
    // must still succeed — it reads zero payload bytes — and only the
    // first real weight touch may fail
    let head_end =
        ArtifactPager::open(&path, None).unwrap().header().payload_start
            as usize;
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..head_end]).unwrap();
    let pager = Arc::new(ArtifactPager::open(&path, None).unwrap());
    let nm = NativeModel::from_pager(&ck, pager).unwrap();
    let toks = tokens(&ck, 8, 1);
    assert!(nm.forward(&toks, 1, 8).is_err(),
            "payload is gone; forward must surface the page-in error");
}

#[test]
fn pack2_round_trips_bit_identically_and_is_never_larger() {
    let ck = tiny_checkpoint(21);
    for spec in [CompressionSpec::quant(4, 32),
                 CompressionSpec::structured_nm(2, 4)] {
        let art = pack_checkpoint(&ck, &spec);
        let dir = temp_cache_dir("pack2-e2e");
        let v1 = dir.path().join("model.apack");
        let v2 = dir.path().join("model.apack2");
        write_artifact_opts(&v1, &art, false).unwrap();
        write_artifact_opts(&v2, &art, true).unwrap();
        let (b1, b2) = (std::fs::metadata(&v1).unwrap().len(),
                        std::fs::metadata(&v2).unwrap().len());
        assert!(b2 <= b1, "{}: AWPPACK2 {b2} > AWPPACK1 {b1}",
                spec.describe());

        // eager reader: every site decodes to the original bits
        let back = read_artifact(&v2).unwrap();
        assert_eq!(back.sites.len(), art.sites.len());
        for (orig, got) in art.sites.iter().zip(&back.sites) {
            assert_eq!(orig.param, got.param);
            assert_bits_eq(&orig.packed.decode(), &got.packed.decode(),
                           &orig.param);
        }

        // pager over the coded container: same bits, site by site
        let pager = ArtifactPager::open(&v2, None).unwrap();
        for (i, orig) in art.sites.iter().enumerate() {
            let p = pager.site(i).unwrap();
            assert_bits_eq(&orig.packed.decode(), &p.packed().decode(),
                           &orig.param);
        }
    }
}
