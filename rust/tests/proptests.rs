//! Property tests (seeded random sweeps — the image carries no proptest
//! crate, so the harness is a deterministic shrinking-free sweep; failures
//! print the seed for replay).
//!
//! Invariants covered:
//! * projections: exact row sparsity, INT-grid membership, idempotence,
//!   joint-mask survival, 2:4 pattern;
//! * solver: AWP never worsens its initialiser; constraint satisfaction
//!   for every mode × ratio × bits; chunk composition;
//! * substrates: Cholesky reconstruction/solve residuals, pack/unpack,
//!   JSON fuzz round-trips, checkpoint save/load;
//! * coordinator: job plans cover all sites exactly once with correct
//!   Gram routing on random architectures.

use awp::compress::awp::AwpBackend;
use awp::compress::traits::{check_constraints, CompressionSpec, LayerCompressor};
use awp::compress::{AwpCpu, CpuBackend};
use awp::coordinator::plan_jobs;
use awp::proj::{GroupedIntGrid, Intersect, RowTopK};
use awp::linalg;
use awp::model::ModelConfig;
use awp::quant::{self, QuantSpec};
use awp::sparse;
use awp::tensor::{ops, topk, Matrix};
use awp::util::{Json, Rng};

const SWEEPS: usize = 20;

fn rand_dims(rng: &mut Rng) -> (usize, usize) {
    (8 + rng.below(56), 32 * (1 + rng.below(4))) // d_in multiple of 32
}

#[test]
fn prop_topk_exact_row_sparsity() {
    for seed in 0..SWEEPS as u64 {
        let mut rng = Rng::new(seed);
        let (m, n) = rand_dims(&mut rng);
        let k = 1 + rng.below(n);
        let z = Matrix::randn(m, n, seed + 100);
        let out = topk::hard_threshold_rows(&z, k);
        for i in 0..m {
            let nnz = out.row(i).iter().filter(|&&v| v != 0.0).count();
            assert_eq!(nnz, k, "seed={seed} row={i}");
        }
        // idempotent
        assert_eq!(topk::hard_threshold_rows(&out, k), out, "seed={seed}");
    }
}

#[test]
fn prop_quant_grid_membership_and_idempotence() {
    for seed in 0..SWEEPS as u64 {
        let mut rng = Rng::new(seed);
        let (m, n) = rand_dims(&mut rng);
        let bits = [2u8, 3, 4, 8][rng.below(4)];
        let group = [8usize, 16, 32][rng.below(3)];
        let z = Matrix::randn(m, n, seed + 200);
        let spec = QuantSpec::new(bits, group);
        let q = quant::quantize_dequantize(&z, spec);
        let q2 = quant::quantize_dequantize(&q, spec);
        for (a, b) in q.data.iter().zip(&q2.data) {
            assert!((a - b).abs() < 1e-5, "seed={seed} not idempotent");
        }
        // grid membership: ≤ 2^bits distinct values per group
        if bits < 8 {
            for i in 0..m {
                for g in (0..n).step_by(group) {
                    let mut vals: Vec<f32> = q.row(i)[g..g + group].to_vec();
                    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    vals.dedup_by(|a, b| (*a - *b).abs() < 1e-6);
                    assert!(vals.len() <= (1usize << bits), "seed={seed}");
                }
            }
        }
    }
}

#[test]
fn prop_awp_constraints_all_modes() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed);
        let m = 8 + rng.below(24);
        let n = 32 * (1 + rng.below(2));
        let w = Matrix::randn(m, n, seed + 300);
        let c = Matrix::randn_gram(n, seed + 400);
        let awp = AwpCpu::default();
        let ratio = [0.25, 0.5, 0.75, 0.9][rng.below(4)];
        let bits = [2u8, 3, 4][rng.below(3)];
        for spec in [
            CompressionSpec::prune(ratio),
            CompressionSpec::quant(bits, 32),
            CompressionSpec::joint(ratio, bits, 32),
            CompressionSpec::structured_nm(2, 4),
            CompressionSpec::joint_nm(4, 8, bits, 32),
        ] {
            let out = awp.compress(&w, &c, &spec).unwrap();
            check_constraints(&out.theta, &spec)
                .unwrap_or_else(|e| panic!("seed={seed} {spec:?}: {e}"));
            assert!(out.stats.final_loss.is_finite());
        }
    }
}

#[test]
fn prop_awp_prune_never_worse_than_wanda_init() {
    let mut worse = 0;
    for seed in 0..12u64 {
        let w = Matrix::randn(24, 64, seed + 500);
        let c = Matrix::randn_gram(64, seed + 600);
        let ratio = 0.5 + 0.1 * (seed % 4) as f64;
        let out = AwpCpu::default()
            .compress(&w, &c, &CompressionSpec::prune(ratio))
            .unwrap();
        let wanda = awp::compress::wanda::wanda_loss(&w, &c, ratio);
        if out.stats.final_loss > wanda * 1.001 {
            worse += 1;
        }
    }
    assert!(worse <= 1, "AWP worse than its init on {worse}/12 problems");
}

#[test]
fn prop_chunk_composition() {
    // a*8 + b*1 decompositions agree with straight iteration
    let b = CpuBackend;
    for seed in 0..6u64 {
        let w = Matrix::randn(16, 32, seed + 700);
        let c = Matrix::randn_gram(32, seed + 800);
        let th0 = topk::hard_threshold_rows(&w, 16);
        let eta = (2.0 / c.frob_norm()) as f32;
        let proj = RowTopK::new(16);
        let (a, _, _) = b.step_chunk_from(&w, &th0, &c, eta, &proj, 13).unwrap();
        let (mut t, _, _) = b.step_chunk_from(&w, &th0, &c, eta, &proj, 8).unwrap();
        for _ in 0..5 {
            t = b.step_chunk_from(&w, &t, &c, eta, &proj, 1).unwrap().0;
        }
        for (x, y) in a.data.iter().zip(&t.data) {
            assert!((x - y).abs() < 1e-4, "seed={seed}");
        }
    }
}

#[test]
fn prop_cholesky_reconstruction_and_solve() {
    for seed in 0..SWEEPS as u64 {
        let mut rng = Rng::new(seed);
        let n = 4 + rng.below(28);
        let c = Matrix::randn_gram(n, seed + 900);
        let ch = linalg::cholesky(&c).unwrap_or_else(|| {
            panic!("seed={seed}: gram not SPD?")
        });
        let rec = ops::matmul(&ch.l, &ch.l.transpose());
        let rel: f64 = ops::sub(&rec, &c).frob_norm() / c.frob_norm().max(1e-12);
        assert!(rel < 1e-3, "seed={seed} rel={rel}");
        // random solve residual
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut b = vec![0.0f32; n];
        for i in 0..n {
            for j in 0..=i {
                b[i] += ch.l.at(i, j) * x[j];
            }
        }
        let got = linalg::solve_lower(&ch.l, &b);
        for (a, t) in got.iter().zip(&x) {
            assert!((a - t).abs() < 1e-2 * t.abs().max(1.0), "seed={seed}");
        }
    }
}

#[test]
fn prop_pack_roundtrip_random() {
    for seed in 0..SWEEPS as u64 {
        let mut rng = Rng::new(seed);
        let bits = 1 + rng.below(8) as u8;
        let n = rng.below(5000);
        let maxc = if bits == 8 { 256 } else { 1usize << bits };
        let codes: Vec<u8> = (0..n).map(|_| rng.below(maxc.max(1)) as u8).collect();
        let packed = quant::pack_bits(&codes, bits);
        assert_eq!(quant::unpack_bits(&packed, bits, n), codes, "seed={seed}");
    }
}

#[test]
fn prop_packed_linear_roundtrip_random_sites() {
    // the artifact codec's lossless law, swept over random dims × spec
    // families: whatever the projection produced, decode(encode(Θ)) must
    // reproduce Θ bit-for-bit (the representation chosen may vary)
    use awp::artifact::PackedLinear;
    use awp::proj::ProjScratch;
    for seed in 0..SWEEPS as u64 {
        let mut rng = Rng::new(seed);
        let (m, n) = rand_dims(&mut rng);
        let bits = [2u8, 3, 4][rng.below(3)];
        let group = [16usize, 32][rng.below(2)];
        let spec = match rng.below(5) {
            0 => CompressionSpec::prune(0.25 + 0.5 * (rng.below(3) as f64) / 3.0),
            1 => CompressionSpec::quant(bits, group),
            2 => CompressionSpec::joint(0.5, bits, group),
            3 => CompressionSpec::structured_nm(2, 4),
            _ => CompressionSpec::joint_nm(4, 8, bits, group),
        };
        let mut theta = Matrix::randn(m, n, seed + 900);
        spec.projection(n).project_rows(&mut theta, &mut ProjScratch::new());
        let packed = PackedLinear::encode(&theta, &spec);
        assert!(packed.reconstructs(&theta),
                "seed={seed} spec={spec:?} mode={}", packed.mode_name());
        assert!(packed.packed_bytes() < packed.dense_bytes(),
                "seed={seed} spec={spec:?} mode={} ({} !< {})",
                packed.mode_name(), packed.packed_bytes(), packed.dense_bytes());
    }
}

#[test]
fn prop_native_packed_forward_matches_dense() {
    // the native-inference differential law over random architectures:
    // whatever the projection produced and however the codec packed it,
    // the packed forward pass is bit-identical to the dense one. Shapes
    // sweep group tails (group clamped to narrow sites) and quad tails
    // (d_ff not a multiple of 4, so N:M groups and the sparse GEMM's
    // 4-quads end in a remainder).
    use awp::artifact::PackedLinear;
    use awp::infer::{NativeModel, SiteWeights};
    use awp::model::sites::enumerate_sites;
    use awp::proj::ProjScratch;

    for seed in 0..10u64 {
        let mut rng = Rng::new(seed);
        let quanty = rng.below(2) == 0;
        // RoPE needs an even head_dim (2 heads ⇒ d_model % 4 == 0); the
        // INT grid additionally needs widths the group divides
        let d_model = if quanty { 32 } else { 4 * (2 + rng.below(10)) };
        let d_ff = if quanty { 32 * (1 + rng.below(2)) } else { 9 + rng.below(70) };
        let cfg = ModelConfig {
            name: format!("n{seed}"),
            vocab: 64,
            d_model,
            n_heads: 2,
            n_layers: 1 + rng.below(2),
            d_ff,
            seq_len: 8,
            batch: 1,
            decode_len: 8,
            rope_theta: 1e4,
        };
        let spec = if quanty {
            let bits = [2u8, 3, 4][rng.below(3)];
            let group = [16usize, 32, 64][rng.below(3)]; // 64 clamps: tail
            if rng.below(2) == 0 {
                CompressionSpec::quant(bits, group)
            } else {
                CompressionSpec::joint(0.5, bits, group)
            }
        } else {
            match rng.below(3) {
                0 => CompressionSpec::prune(0.5),
                1 => CompressionSpec::structured_nm(2, 4),
                _ => CompressionSpec::structured_nm(4, 8),
            }
        };
        let ck = awp::trainer::init_checkpoint(&cfg, seed + 40);
        let mut dense_sites = Vec::new();
        let mut packed_sites = Vec::new();
        for s in enumerate_sites(&cfg) {
            let mut theta = ck.matrix(&s.param).unwrap();
            spec.projection(theta.cols)
                .project_rows(&mut theta, &mut ProjScratch::new());
            let packed = PackedLinear::encode(&theta, &spec);
            assert!(packed.reconstructs(&theta), "seed={seed} {}", s.param);
            packed_sites.push((s.param.clone(), SiteWeights::packed(packed)));
            dense_sites.push((s.param, SiteWeights::Dense(theta)));
        }
        let dense = NativeModel::with_site_weights(&ck, dense_sites).unwrap();
        let packed = NativeModel::with_site_weights(&ck, packed_sites).unwrap();
        assert_eq!(packed.dense_site_count(), 0);
        let tokens: Vec<i32> =
            (0..2 * 8).map(|_| rng.below(cfg.vocab) as i32).collect();
        let a = dense.forward(&tokens, 2, 8).unwrap();
        let b = packed.forward(&tokens, 2, 8).unwrap();
        assert_eq!(a.shape(), b.shape(), "seed={seed}");
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(),
                       "seed={seed} spec={spec:?} logit {i}: {x} vs {y}");
        }
        let (na, _) = dense.nll(&tokens, 2, 8).unwrap();
        let (nb, _) = packed.nll(&tokens, 2, 8).unwrap();
        assert_eq!(na.to_bits(), nb.to_bits(), "seed={seed} nll");
    }
}

#[test]
fn prop_json_fuzz_roundtrip() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform() < 0.5),
            2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => {
                let n = rng.below(12);
                let extra = rng.below(4);
                let mut s: String = (0..n)
                    .map(|_| char::from_u32(32 + rng.below(90) as u32).unwrap())
                    .collect();
                s.extend("\"\\\né".chars().take(extra));
                Json::Str(s)
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj((0..rng.below(5)).map(|i| {
                (format!("k{i}"), random_json(rng, depth - 1))
            }).collect()),
        }
    }
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed);
        let v = random_json(&mut rng, 3);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap_or_else(|e| panic!("seed={seed}: {e}\n{s}"));
        assert_eq!(back, v, "seed={seed}\n{s}");
    }
}

#[test]
fn prop_job_plan_on_random_architectures() {
    for seed in 0..SWEEPS as u64 {
        let mut rng = Rng::new(seed);
        let cfg = ModelConfig {
            name: format!("r{seed}"),
            vocab: 256,
            d_model: 32 * (1 + rng.below(8)),
            n_heads: 4,
            n_layers: 1 + rng.below(8),
            d_ff: 32 * (1 + rng.below(16)),
            seq_len: 64,
            batch: 2,
            decode_len: 32,
            rope_theta: 1e4,
        };
        let plan = plan_jobs(&cfg);
        assert_eq!(plan.jobs.len(), 6 * cfg.n_layers, "seed={seed}");
        let spec: std::collections::HashMap<String, Vec<usize>> =
            cfg.param_spec().into_iter().collect();
        let mut seen = std::collections::HashSet::new();
        for job in &plan.jobs {
            assert!(seen.insert(job.site.param.clone()), "dup {}", job.site.param);
            assert_eq!(spec[&job.site.param], vec![job.site.d_out, job.site.d_in]);
            // gram dimension must equal the site's d_in
            let gram_dim = match job.site.gram {
                awp::model::GramKey::MlpDownIn => cfg.d_ff,
                _ => cfg.d_model,
            };
            assert_eq!(job.site.d_in, gram_dim, "seed={seed} {}", job.site.param);
        }
    }
}

#[test]
fn prop_joint_zeros_survive_quantization() {
    let b = CpuBackend;
    for seed in 0..10u64 {
        let w = Matrix::randn(12, 64, seed + 1100);
        let c = Matrix::randn_gram(64, seed + 1200);
        let th0 = topk::hard_threshold_rows(&w, 16);
        let proj = Intersect::new(RowTopK::new(16), GroupedIntGrid::new(15.0, 32));
        let (th, _, _) = b
            .step_chunk_from(&w, &th0, &c, 0.01, &proj, 4)
            .unwrap();
        let stats = sparse::SparsityStats::of(&th);
        assert!(stats.row_max_nnz <= 16, "seed={seed}: {}", stats.row_max_nnz);
    }
}

#[test]
fn prop_2_4_projection_after_awp() {
    // future-work extension: 2:4 pattern composes with AWP output
    for seed in 0..6u64 {
        let w = Matrix::randn(16, 32, seed + 1300);
        let c = Matrix::randn_gram(32, seed + 1400);
        let out = AwpCpu::default()
            .compress(&w, &c, &CompressionSpec::prune(0.5))
            .unwrap();
        let p = sparse::project_2_4(&out.theta);
        assert!(sparse::check_2_4(&p), "seed={seed}");
        // 2:4 of a 50%-row-sparse matrix keeps at most the same mass
        assert!(p.nnz() <= out.theta.nnz());
    }
}

#[test]
fn prop_checkpoint_roundtrip_random_configs() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed);
        let cfg = ModelConfig {
            name: format!("ck{seed}"),
            vocab: 64,
            d_model: 16 * (1 + rng.below(4)),
            n_heads: 2,
            n_layers: 1 + rng.below(3),
            d_ff: 32 * (1 + rng.below(4)),
            seq_len: 16,
            batch: 1,
            decode_len: 8,
            rope_theta: 1e4,
        };
        let mut ck = awp::trainer::init_checkpoint(&cfg, seed);
        ck.meta.insert("k".into(), format!("v{seed}"));
        let dir = std::env::temp_dir().join(format!("awp-prop-ck-{seed}-{}",
                                                    std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.awp");
        ck.save(&path).unwrap();
        let back = awp::model::Checkpoint::load(&path).unwrap();
        back.validate().unwrap();
        assert_eq!(back.config, cfg);
        for ((n1, s1, d1), (n2, s2, d2)) in ck.tensors.iter().zip(&back.tensors) {
            assert_eq!((n1, s1), (n2, s2));
            assert_eq!(d1, d2, "seed={seed} tensor {n1}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
