//! Observability-layer coverage: histogram bucket semantics and snapshot
//! determinism, counter exactness under thread contention, the Prometheus
//! wire format of `GET /metrics` scraped over a real loopback socket —
//! pinned against `/v1/inspect`'s own tick accounting — and the
//! `--trace-out` Chrome trace-event export (valid JSON, nested span
//! ordering and containment).
//!
//! The metrics registry and the span sink are process-global, so every
//! test that asserts observation behaviour holds `metrics::enable_guard`
//! for its whole body (the same discipline as the unit tests in
//! `obs/metrics.rs` and `obs/trace.rs`).

mod common;

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};

use awp::coordinator::Executor;
use awp::infer::NativeModel;
use awp::model::Checkpoint;
use awp::obs::{metrics, trace};
use awp::serve::{ServeInfo, ServeLimits, ServeState, Server};
use awp::util::json::Json;
use awp::util::tempdir::TempDir;

use common::lm_cfg;

// ------------------------------------------------------------ primitives

#[test]
fn histogram_boundaries_and_snapshot_are_deterministic() {
    let _g = metrics::enable_guard();
    metrics::set_enabled(true);
    static BOUNDS: &[f64] = &[0.001, 0.01, 0.1, 1.0];
    let h = metrics::Histogram::new(BOUNDS);
    // one observation exactly on each bound (le: on-bound lands inside),
    // one strictly between each pair, one past the last bound
    for v in [0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 2.0] {
        h.observe(v);
    }
    let snap = h.snapshot();
    assert_eq!(snap.buckets, vec![1, 2, 2, 2, 1]);
    assert_eq!(snap.cumulative(), vec![1, 3, 5, 7, 8]);
    assert_eq!(snap.count, 8);
    assert_eq!(*snap.cumulative().last().unwrap(), snap.count);
    assert!((snap.sum - 3.666).abs() < 1e-3, "sum {}", snap.sum);
    // snapshots are pure reads: two in a row are identical
    assert_eq!(h.snapshot(), snap);
}

#[test]
fn counter_is_exact_under_four_thread_contention() {
    let _g = metrics::enable_guard();
    metrics::set_enabled(true);
    let c = metrics::Counter::new();
    const PER_THREAD: u64 = 50_000;
    std::thread::scope(|scope| {
        for _ in 0..4 {
            scope.spawn(|| {
                for _ in 0..PER_THREAD {
                    c.inc();
                }
            });
        }
    });
    assert_eq!(c.get(), 4 * PER_THREAD);
}

// -------------------------------------------------------------- loopback

/// One-shot HTTP/1.1 client that keeps the body raw (the `/metrics`
/// exposition is Prometheus text, not JSON). Returns
/// (status, head, body).
fn http_raw(addr: SocketAddr, method: &str, path: &str, body: &str)
    -> (u16, String, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(stream,
           "{method} {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\
            Content-Length: {}\r\n\r\n{body}",
           body.len())
        .unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    let status: u16 = raw
        .strip_prefix("HTTP/1.1 ")
        .and_then(|r| r.split(' ').next())
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad response: {raw:?}"));
    let (head, body) = raw
        .split_once("\r\n\r\n")
        .unwrap_or_else(|| panic!("headerless response: {raw:?}"));
    (status, head.to_string(), body.to_string())
}

fn lm_state(ck: &Checkpoint) -> ServeState {
    let model = NativeModel::from_checkpoint(ck).unwrap();
    let info = ServeInfo {
        model: ck.config.name.clone(),
        source: "obs-test".into(),
        method: "proj".into(),
        spec: "dense".into(),
        packed_bytes: 0,
    };
    ServeState::new(model, info, Executor::with_workers(2), ServeLimits {
        max_ctx: 64,
        max_sessions: 4,
        max_batch: 4,
        ..ServeLimits::default()
    })
}

/// The one cumulative-counter value named `sample` in a Prometheus
/// exposition body (`sample` includes any label set, e.g.
/// `awp_requests_total{route="/v1/generate",status="200"}`).
fn prom_value(text: &str, sample: &str) -> u64 {
    text.lines()
        .find_map(|l| l.strip_prefix(sample))
        .unwrap_or_else(|| panic!("no sample {sample:?} in:\n{text}"))
        .trim()
        .parse()
        .unwrap_or_else(|e| panic!("bad value for {sample:?}: {e}"))
}

#[test]
fn metrics_scrape_over_loopback_matches_inspect() {
    let _g = metrics::enable_guard();
    metrics::set_enabled(true);
    let ck = awp::trainer::init_checkpoint(&lm_cfg(), 36);
    let server = Server::new(lm_state(&ck), Executor::with_workers(2));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = AtomicBool::new(false);
    // process-global registry: other suites (separate processes) can't
    // touch it, and this guard serialises the binary's own tests
    let r = &metrics::REGISTRY;
    let ticks0 = r.decode_ticks.get();
    let tokens0 = r.generated_tokens.get();
    std::thread::scope(|scope| {
        let handle = scope.spawn(|| server.serve(listener, &stop).unwrap());
        let inspect = |tag: &str| -> u64 {
            let (status, _, body) = http_raw(addr, "GET", "/v1/inspect", "");
            assert_eq!(status, 200, "{tag}");
            Json::parse(&body).unwrap()
                .expect("decode_ticks").unwrap().as_usize().unwrap() as u64
        };
        let scrape = |tag: &str| -> String {
            let (status, head, body) = http_raw(addr, "GET", "/metrics", "");
            assert_eq!(status, 200, "{tag}");
            assert!(head.contains(metrics::PROMETHEUS_CONTENT_TYPE),
                    "{tag}: wrong content type in {head:?}");
            body
        };
        let inspect0 = inspect("before");
        let before = scrape("before");
        let gen0 = before
            .lines()
            .find_map(|l| l.strip_prefix(
                "awp_requests_total{route=\"/v1/generate\",status=\"200\"} "))
            .map_or(0, |v| v.trim().parse().unwrap());

        let (status, _, body) = http_raw(addr, "POST", "/v1/generate",
                                         r#"{"prompt":"ab","max_tokens":4}"#);
        assert_eq!(status, 200, "{body:?}");

        let after = scrape("after");
        let inspect1 = inspect("after");
        // exposition format: every family the acceptance list names
        for needle in [
            "# TYPE awp_requests_total counter",
            "# TYPE awp_request_seconds histogram",
            "# TYPE awp_decode_tick_seconds histogram",
            "# TYPE awp_batch_occupancy histogram",
            "# TYPE awp_queue_wait_seconds histogram",
            "# TYPE awp_kv_bytes gauge",
            "# TYPE awp_sessions_live gauge",
            "# TYPE awp_session_evictions_total counter",
            "# TYPE awp_gram_cache_hits_total counter",
            "# TYPE awp_artifact_cache_hits_total counter",
            "# TYPE awp_executor_job_seconds histogram",
            "# TYPE awp_kernel_calls_total counter",
            "awp_kernel_busy_seconds_total{tier=\"reference\"}",
        ] {
            assert!(after.contains(needle), "missing {needle:?} in:\n{after}");
        }
        // the generate request shows up in its labelled cell, exactly once
        let gen1 = prom_value(
            &after,
            "awp_requests_total{route=\"/v1/generate\",status=\"200\"} ");
        assert_eq!(gen1, gen0 + 1);
        // tick accounting: registry delta == the batcher's own count as
        // /v1/inspect reports it == one tick per requested token
        assert_eq!(inspect1 - inspect0, 4);
        assert_eq!(r.decode_ticks.get() - ticks0, inspect1 - inspect0);
        assert_eq!(prom_value(&after, "awp_decode_ticks_total "),
                   r.decode_ticks.get());
        // batcher-emitted tokens: steps − 1 (the first token comes off the
        // prefill logits, outside the batcher — see Batcher::decode)
        assert_eq!(r.generated_tokens.get() - tokens0, 3);
        // the decode ticks landed in the latency histogram too
        let inf = prom_value(&after,
                             "awp_decode_tick_seconds_bucket{le=\"+Inf\"} ");
        assert_eq!(inf, prom_value(&after, "awp_decode_tick_seconds_count "));
        assert!(inf >= r.decode_ticks.get() - ticks0);
        // one live session holding KV rows
        assert_eq!(prom_value(&after, "awp_sessions_live "), 1);
        assert!(prom_value(&after, "awp_kv_bytes ") > 0);
        // /v1/stats mirrors the same registry as JSON
        let (status, _, body) = http_raw(addr, "GET", "/v1/stats", "");
        assert_eq!(status, 200);
        let stats = Json::parse(&body).unwrap();
        let m = stats.expect("metrics").unwrap();
        assert_eq!(m.expect("decode_ticks").unwrap().as_usize().unwrap() as u64,
                   r.decode_ticks.get());
        assert_eq!(m.expect("sessions_live").unwrap().as_usize().unwrap(), 1);
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    });
}

// ------------------------------------------------------------- trace-out

#[test]
fn trace_export_is_valid_json_with_nested_span_ordering() {
    // the span sink shares the toggle-discipline lock with the registry
    let _g = metrics::enable_guard();
    trace::set_enabled(true);
    trace::take_records();
    {
        let _outer = trace::span("obs_it_outer", "test").arg("req", "t-1");
        {
            let _inner = trace::span("obs_it_inner", "test");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    let dir = TempDir::new("trace-out").unwrap();
    let path = dir.path().join("trace.json");
    let n = trace::write_chrome_trace(&path).unwrap();
    assert!(n >= 2, "only {n} spans buffered");
    trace::set_enabled(false);
    trace::take_records();

    // the file is one valid JSON object in Chrome trace-event shape
    let raw = std::fs::read_to_string(&path).unwrap();
    let doc = Json::parse(&raw).unwrap();
    let events = doc.expect("traceEvents").unwrap().as_arr().unwrap();
    let ours: Vec<&Json> = events
        .iter()
        .filter(|e| {
            matches!(e.get("name").and_then(|n| n.as_str().ok()),
                     Some(n) if n.starts_with("obs_it_"))
        })
        .collect();
    assert_eq!(ours.len(), 2, "in {raw}");
    let field = |e: &Json, k: &str| e.expect(k).unwrap().as_f64().unwrap();
    // spans record on drop, so the child precedes its parent in the file;
    // viewers re-nest by [ts, ts+dur) containment — assert both
    assert_eq!(ours[0].expect("name").unwrap().as_str().unwrap(),
               "obs_it_inner");
    assert_eq!(ours[1].expect("name").unwrap().as_str().unwrap(),
               "obs_it_outer");
    let (inner, outer) = (ours[0], ours[1]);
    for e in [inner, outer] {
        assert_eq!(e.expect("ph").unwrap().as_str().unwrap(), "X");
        assert!(field(e, "dur") >= 0.0);
    }
    assert!(field(outer, "ts") <= field(inner, "ts"));
    assert!(field(inner, "ts") + field(inner, "dur")
            <= field(outer, "ts") + field(outer, "dur") + 1.0);
    assert_eq!(field(inner, "tid"), field(outer, "tid"));
    assert_eq!(outer.expect("args").unwrap().expect("req").unwrap()
                   .as_str().unwrap(),
               "t-1");
}
