//! The calibration-artifact cache's external contract: bit-exact disk
//! round-trips, key invalidation on checkpoint/config change, corrupt-file
//! degradation, and — the property the whole subsystem exists for —
//! `compress_model` output is bit-identical with a cold cache (Grams
//! computed) and a warm cache (Grams loaded from disk), while a warm run
//! never invokes the calibration provider at all.

#![allow(clippy::field_reassign_with_default)]

mod common;

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use awp::compress::magnitude::MagnitudePrune;
use awp::compress::traits::CompressionSpec;
use awp::coordinator::calibrate::{synthetic_grams, Grams};
use awp::coordinator::{
    cache, compress_model, CalibSpec, Executor, GramCache, GramCacheKey,
};
use awp::config::RunConfig;

use common::{assert_grams_bit_equal, gram_key_for as key_for, temp_cache_dir,
             tiny_cfg as cfg, tiny_checkpoint};
#[test]
fn disk_round_trip_is_bit_exact() {
    let dir = temp_cache_dir("gc");
    let ck = tiny_checkpoint(1);
    let grams = synthetic_grams(&cfg(), 5);
    let key = key_for(&ck, "synthetic");
    cache::store_grams(dir.path(), &key, &grams).unwrap();
    let back = cache::load_grams(dir.path(), &key).unwrap().unwrap();
    assert_grams_bit_equal(&grams, &back);
}

#[test]
fn key_invalidates_on_checkpoint_and_calib_changes() {
    let dir = temp_cache_dir("gc");
    let ck = tiny_checkpoint(1);
    let grams = synthetic_grams(&cfg(), 5);
    let key = key_for(&ck, "synthetic");
    cache::store_grams(dir.path(), &key, &grams).unwrap();

    // a retrained checkpoint (different weights) misses
    let ck2 = tiny_checkpoint(2);
    assert_ne!(ck.fingerprint(), ck2.fingerprint());
    let key2 = key_for(&ck2, "synthetic");
    assert_ne!(key.hash(), key2.hash());
    assert!(cache::load_grams(dir.path(), &key2).unwrap().is_none());

    // a changed calibration config misses
    let mut rc = RunConfig::default();
    rc.calib_batches += 1;
    let key3 = GramCacheKey {
        model: ck.config.name.clone(),
        checkpoint: ck.fingerprint(),
        calib: CalibSpec::from_run(&rc, &ck.config, "synthetic").fingerprint(),
    };
    assert_ne!(key.hash(), key3.hash());
    assert!(cache::load_grams(dir.path(), &key3).unwrap().is_none());

    // the original key still hits
    assert!(cache::load_grams(dir.path(), &key).unwrap().is_some());
}

#[test]
fn corrupt_files_degrade_to_recompute() {
    let dir = temp_cache_dir("gc");
    let ck = tiny_checkpoint(1);
    let key = key_for(&ck, "synthetic");
    std::fs::create_dir_all(dir.path()).unwrap();
    std::fs::write(dir.path().join(key.file_name()), b"not a cache file").unwrap();
    let gc = GramCache::new(Some(dir.path().to_path_buf()));
    let computed = Arc::new(AtomicUsize::new(0));
    let c2 = computed.clone();
    let g = gc
        .get_or_compute(&key, move || {
            c2.fetch_add(1, Ordering::SeqCst);
            Ok(synthetic_grams(&cfg(), 5))
        })
        .unwrap();
    assert_eq!(computed.load(Ordering::SeqCst), 1);
    assert_eq!(g.map.len(), 8);
    // recompute healed the file: a fresh cache disk-hits without a provider
    let gc2 = GramCache::new(Some(dir.path().to_path_buf()));
    let g2 = gc2
        .get_or_compute(&key, || panic!("provider must not run on a warm cache"))
        .unwrap();
    assert_grams_bit_equal(&g, &g2);
}

#[test]
fn warm_cache_skips_the_calibration_provider_entirely() {
    // stands in for "a warm-cache run submits zero calib_capture
    // executions": the provider closure IS the calibration path, and on a
    // warm cache it must never run.
    let dir = temp_cache_dir("gc");
    let ck = tiny_checkpoint(1);
    let key = key_for(&ck, "synthetic");
    let cold = GramCache::new(Some(dir.path().to_path_buf()));
    cold.get_or_compute(&key, || Ok(synthetic_grams(&cfg(), 5))).unwrap();
    assert_eq!(cold.counts().misses, 1);

    let warm = GramCache::new(Some(dir.path().to_path_buf()));
    let g = warm
        .get_or_compute(&key, || anyhow::bail!("calib_capture executed"))
        .unwrap();
    assert!(!g.map.is_empty());
    let counts = warm.counts();
    assert_eq!((counts.disk_hits, counts.misses), (1, 0));
}

#[test]
fn compress_is_bit_identical_cold_vs_warm() {
    let dir = temp_cache_dir("gc");
    let ck = tiny_checkpoint(1);
    let key = key_for(&ck, "synthetic");
    let spec = CompressionSpec::prune(0.5);

    // cold: compute + persist
    let cold_cache = GramCache::new(Some(dir.path().to_path_buf()));
    let cold_grams = cold_cache
        .get_or_compute(&key, || Ok(synthetic_grams(&cfg(), 5)))
        .unwrap();
    let cold = compress_model(&ck, &cold_grams, &MagnitudePrune, &spec, true).unwrap();

    // warm: a fresh cache loads from disk; provider must not run
    let warm_cache = GramCache::new(Some(dir.path().to_path_buf()));
    let warm_grams = warm_cache
        .get_or_compute(&key, || anyhow::bail!("must not recompute"))
        .unwrap();
    assert_grams_bit_equal(&cold_grams, &warm_grams);
    let warm = compress_model(&ck, &warm_grams, &MagnitudePrune, &spec, true).unwrap();

    assert_eq!(cold.checkpoint.tensors.len(), warm.checkpoint.tensors.len());
    for ((n1, s1, d1), (n2, s2, d2)) in
        cold.checkpoint.tensors.iter().zip(&warm.checkpoint.tensors)
    {
        assert_eq!((n1, s1), (n2, s2));
        for (x, y) in d1.iter().zip(d2) {
            assert_eq!(x.to_bits(), y.to_bits(), "{n1}");
        }
    }
    // and the same holds on a multi-worker executor
    let warm_par = awp::coordinator::compress_model_with(
        &ck, &warm_grams, &MagnitudePrune, &spec, true, &Executor::with_workers(4))
        .unwrap();
    for ((_, _, d1), (_, _, d2)) in
        cold.checkpoint.tensors.iter().zip(&warm_par.checkpoint.tensors)
    {
        assert_eq!(d1, d2);
    }
}

#[test]
fn concurrent_callers_share_one_computation() {
    let gc = Arc::new(GramCache::memory_only());
    let ck = tiny_checkpoint(1);
    let key = key_for(&ck, "synthetic");
    let calls = Arc::new(AtomicUsize::new(0));
    let mut grams: Vec<Arc<Grams>> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for _ in 0..6 {
            let (gc, key, calls) = (gc.clone(), key.clone(), calls.clone());
            handles.push(s.spawn(move || {
                gc.get_or_compute(&key, || {
                    calls.fetch_add(1, Ordering::SeqCst);
                    Ok(synthetic_grams(&cfg(), 5))
                })
                .unwrap()
            }));
        }
        for h in handles {
            grams.push(h.join().unwrap());
        }
    });
    assert_eq!(calls.load(Ordering::SeqCst), 1);
    // all callers share the same Arc allocation
    for g in &grams {
        assert!(Arc::ptr_eq(g, &grams[0]));
    }
}

#[test]
fn warm_cache_submits_zero_calib_capture_executions_to_the_runtime() {
    use awp::coordinator::calibrate;
    use awp::data::Batch;
    use awp::runtime::{Manifest, Runtime};

    // a manifest whose 'tiny' model *has* a calib_capture entry, so a real
    // calibration attempt reaches the PJRT actor (the stub actor counts
    // the attempt, then fails — there is no XLA toolchain in tests)
    let mut manifest = Manifest::synthetic();
    manifest
        .models
        .get_mut("tiny")
        .unwrap()
        .programs
        .insert("calib_capture".into(), "missing.hlo.txt".into());
    let mc = manifest.model("tiny").unwrap().config.clone();
    let ck = awp::trainer::init_checkpoint(&mc, 3);
    let batches = vec![Batch { batch: 1, seq: 4, tokens: vec![0; 4] }];

    let runtime = Runtime::start().unwrap();
    let handle = runtime.handle();

    // control: a cold calibration does submit calib_capture to the actor
    assert!(calibrate(&handle, &manifest, "tiny", &ck, &batches).is_err());
    assert_eq!(handle.stats().unwrap().attempts_of("calib_capture"), 1);

    // warm cache: the same calibration request is served from disk and the
    // actor sees no new calib_capture submission
    let dir = temp_cache_dir("gc");
    let key = GramCacheKey {
        model: "tiny".into(),
        checkpoint: ck.fingerprint(),
        calib: CalibSpec::from_run(&RunConfig::default(), &mc, "calib_capture")
            .fingerprint(),
    };
    cache::store_grams(dir.path(), &key, &synthetic_grams(&mc, 9)).unwrap();
    let gc = GramCache::new(Some(dir.path().to_path_buf()));
    let g = gc
        .get_or_compute(&key, || calibrate(&handle, &manifest, "tiny", &ck, &batches))
        .unwrap();
    assert!(!g.map.is_empty());
    assert_eq!(handle.stats().unwrap().attempts_of("calib_capture"), 1,
               "warm run must not submit calib_capture");
    assert_eq!(gc.counts().disk_hits, 1);
}

#[test]
fn cache_file_names_are_filesystem_safe() {
    let key = GramCacheKey { model: "we/ird mo:del".into(), checkpoint: 1, calib: 2 };
    let name = key.file_name();
    assert!(!name.contains('/') && !name.contains(':'), "{name}");
    assert!(name.ends_with(".grams"));
    assert!(PathBuf::from(&name).components().count() == 1, "{name}");
}
