//! The compressed-artifact store's external contract: bit-exact pack/
//! unpack round-trips for every representation the codec emits, clean
//! errors on truncated/corrupt/mismatched files, key invalidation across
//! (checkpoint, spec, method), and — the property the subsystem exists
//! for — a warm rerun over a populated store submits **zero** compression
//! jobs while assembling a bit-identical checkpoint (modeled on the Gram
//! cache's warm-skip tests).

mod common;

use std::sync::Arc;

use anyhow::Result;
use awp::artifact::{read_artifact, store_artifact, ArtifactStore, PackedLinear};
use awp::compress::magnitude::MagnitudePrune;
use awp::compress::traits::{CompressedLayer, CompressionSpec, LayerCompressor};
use awp::coordinator::calibrate::synthetic_grams;
use awp::coordinator::{compress_model_cached, compress_model_with, Executor};
use awp::proj::ProjScratch;
use awp::tensor::Matrix;

use common::{artifact_key_for as key_for, assert_ck_bits_equal, temp_cache_dir,
             tiny_cfg, tiny_checkpoint};

/// Every spec family round-trips bit-exactly through encode/decode when
/// applied to its own projection's output — the codec's core law, swept
/// over seeds proptest-style.
#[test]
fn pack_unpack_round_trips_bit_exact_across_spec_families() {
    let specs = [
        CompressionSpec::prune(0.5),
        CompressionSpec::prune(0.9),
        CompressionSpec::quant(2, 16),
        CompressionSpec::quant(4, 32),
        CompressionSpec::joint(0.5, 4, 32),
        CompressionSpec::structured_nm(2, 4),
        CompressionSpec::structured_nm(4, 8),
        CompressionSpec::joint_nm(2, 4, 4, 32),
    ];
    for seed in 0..10u64 {
        for spec in &specs {
            let mut theta = Matrix::randn(6, 64, seed);
            spec.projection(theta.cols)
                .project_rows(&mut theta, &mut ProjScratch::new());
            let p = PackedLinear::encode(&theta, spec);
            assert!(p.reconstructs(&theta),
                    "seed={seed} spec={spec:?} mode={}", p.mode_name());
            assert!(p.packed_bytes() < p.dense_bytes(),
                    "seed={seed} spec={spec:?}: {} !< {}",
                    p.packed_bytes(), p.dense_bytes());
        }
    }
}

/// Arbitrary (unprojected) matrices still round-trip — the encoder falls
/// back to an exact representation rather than failing or approximating.
#[test]
fn pack_is_lossless_even_off_constraint() {
    for seed in 0..6u64 {
        let theta = Matrix::randn(5, 48, seed);
        for spec in [CompressionSpec::quant(4, 16), CompressionSpec::prune(0.5)] {
            let p = PackedLinear::encode(&theta, &spec);
            assert!(p.reconstructs(&theta), "seed={seed} mode={}", p.mode_name());
        }
    }
}

#[test]
fn artifact_file_round_trip_preserves_sites_and_reports() {
    let ck = tiny_checkpoint(1);
    let grams = synthetic_grams(&tiny_cfg(), 5);
    let spec = CompressionSpec::prune(0.5);
    let out = compress_model_with(&ck, &grams, &MagnitudePrune, &spec, true,
                                  &Executor::sequential())
        .unwrap();
    let dir = temp_cache_dir("apack");
    let store = ArtifactStore::new(Some(dir.path().to_path_buf()));
    let key = key_for(&ck, "magnitude", &spec);
    // build + persist through the cached pipeline, then read the file raw
    let cached = compress_model_cached(&ck, &grams, &MagnitudePrune, &spec, true,
                                       &Executor::sequential(), &store, &key)
        .unwrap();
    let path = dir.path().join(key.file_name());
    let art = read_artifact(&path).unwrap();
    assert_eq!(art.sites.len(), out.reports.len());
    for (site, rep) in art.sites.iter().zip(&out.reports) {
        assert_eq!(site.param, rep.param);
        assert_eq!(site.report.rel_loss.to_bits(), rep.rel_loss.to_bits());
        assert_eq!(site.report.iterations, rep.iterations);
        let dec = site.packed.decode();
        let orig = out.checkpoint.matrix(&site.param).unwrap();
        for (x, y) in dec.data.iter().zip(&orig.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "{}", site.param);
        }
    }
    assert!(art.packed_bytes() < art.dense_bytes());
    assert_eq!(cached.artifact.packed_bytes(), art.packed_bytes());
}

#[test]
fn warm_rerun_submits_zero_compression_jobs() {
    struct MustNotRun;
    impl LayerCompressor for MustNotRun {
        fn name(&self) -> &'static str {
            "must-not-run"
        }
        fn compress(&self, _w: &Matrix, _c: &Matrix, _s: &CompressionSpec)
            -> Result<CompressedLayer> {
            anyhow::bail!("compression job submitted on a warm artifact store")
        }
    }

    let ck = tiny_checkpoint(1);
    let grams = synthetic_grams(&tiny_cfg(), 5);
    let dir = temp_cache_dir("apack");

    for spec in [
        CompressionSpec::prune(0.5),
        CompressionSpec::structured_nm(2, 4),
    ] {
        let store = ArtifactStore::new(Some(dir.path().to_path_buf()));
        let key = key_for(&ck, "magnitude", &spec);
        let cold = compress_model_cached(&ck, &grams, &MagnitudePrune, &spec,
                                         true, &Executor::with_workers(4),
                                         &store, &key)
            .unwrap();
        assert!(!cold.warm);
        assert!(!cold.result.job_stats.is_empty());

        // fresh store handle over the same dir — a separate process rerun
        let warm_store = ArtifactStore::new(Some(dir.path().to_path_buf()));
        let warm = compress_model_cached(&ck, &grams, &MustNotRun, &spec, true,
                                         &Executor::with_workers(4),
                                         &warm_store, &key)
            .unwrap();
        assert!(warm.warm, "{spec:?}");
        assert!(warm.result.job_stats.is_empty(),
                "{spec:?}: warm rerun submitted compression jobs");
        assert_eq!(warm_store.counts().hits, 1);
        assert_ck_bits_equal(&cold.result.checkpoint, &warm.result.checkpoint);
        // reports survive the round-trip bit-for-bit too
        for (a, b) in cold.result.reports.iter().zip(&warm.result.reports) {
            assert_eq!(a.param, b.param);
            assert_eq!(a.rel_loss.to_bits(), b.rel_loss.to_bits());
        }
    }
}

#[test]
fn key_changes_invalidate_the_artifact() {
    let ck = tiny_checkpoint(1);
    let grams = synthetic_grams(&tiny_cfg(), 5);
    let spec = CompressionSpec::prune(0.5);
    let dir = temp_cache_dir("apack");
    let store = ArtifactStore::new(Some(dir.path().to_path_buf()));
    let key = key_for(&ck, "magnitude", &spec);
    compress_model_cached(&ck, &grams, &MagnitudePrune, &spec, true,
                          &Executor::sequential(), &store, &key)
        .unwrap();

    // different ratio, different method, different checkpoint: all miss
    let k2 = key_for(&ck, "magnitude", &CompressionSpec::prune(0.6));
    assert_ne!(key.hash(), k2.hash());
    assert!(store.load(&k2).is_none());
    let k3 = key_for(&ck, "wanda", &spec);
    assert!(store.load(&k3).is_none());
    let ck2 = tiny_checkpoint(2);
    let k4 = key_for(&ck2, "magnitude", &spec);
    assert!(store.load(&k4).is_none());
    // the original still hits
    assert!(store.load(&key).is_some());
}

#[test]
fn corrupt_artifact_degrades_to_recompute_and_heals() {
    let ck = tiny_checkpoint(1);
    let grams = synthetic_grams(&tiny_cfg(), 5);
    let spec = CompressionSpec::prune(0.5);
    let dir = temp_cache_dir("apack");
    let store = ArtifactStore::new(Some(dir.path().to_path_buf()));
    let key = key_for(&ck, "magnitude", &spec);
    let cold = compress_model_cached(&ck, &grams, &MagnitudePrune, &spec, true,
                                     &Executor::sequential(), &store, &key)
        .unwrap();
    // truncate the stored file: the next run logs, recompresses, heals
    let path = dir.path().join(key.file_name());
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    let healed_store = ArtifactStore::new(Some(dir.path().to_path_buf()));
    let again = compress_model_cached(&ck, &grams, &MagnitudePrune, &spec, true,
                                      &Executor::sequential(), &healed_store, &key)
        .unwrap();
    assert!(!again.warm);
    assert_ck_bits_equal(&cold.result.checkpoint, &again.result.checkpoint);
    // healed: a third run is warm
    let warm_store = ArtifactStore::new(Some(dir.path().to_path_buf()));
    let warm = compress_model_cached(&ck, &grams, &MagnitudePrune, &spec, true,
                                     &Executor::sequential(), &warm_store, &key)
        .unwrap();
    assert!(warm.warm);
}

#[test]
fn truncated_and_garbage_files_error_cleanly() {
    let dir = temp_cache_dir("apack");
    let path = dir.path().join("x.apack");
    std::fs::write(&path, b"not an artifact").unwrap();
    assert!(read_artifact(&path).is_err());
    std::fs::write(&path, b"AWPPACK1").unwrap();
    assert!(read_artifact(&path).is_err());
}

/// A sweep rerun through the experiment harness is incremental: the
/// second `eval_cell` for the same (model, method, spec) hits the store,
/// recompresses nothing, and reproduces the same quality number.
#[test]
fn experiment_cells_are_incremental_over_the_store() {
    use awp::config::RunConfig;
    use awp::coordinator::{ExperimentCtx, Method};
    use awp::runtime::{Manifest, Runtime};

    let runtime = Runtime::start().unwrap();
    let manifest = Arc::new(Manifest::synthetic());
    let mut ctx = ExperimentCtx::new(runtime.handle(), manifest, RunConfig::default());
    ctx.set_synthetic(true);
    let dir = temp_cache_dir("apack");
    ctx.set_artifact_store(Arc::new(ArtifactStore::new(
        Some(dir.path().to_path_buf()),
    )));

    let spec = CompressionSpec::prune(0.5);
    let a = ctx.eval_cell("tiny", Method::Magnitude, &spec).unwrap();
    let c = ctx.artifact_store().counts();
    assert_eq!((c.hits, c.misses, c.stores), (0, 1, 1));

    let b = ctx.eval_cell("tiny", Method::Magnitude, &spec).unwrap();
    let c = ctx.artifact_store().counts();
    assert_eq!((c.hits, c.misses), (1, 1), "second cell must warm-hit");
    assert_eq!(a.to_bits(), b.to_bits(), "warm cell changed the quality number");

    // a different spec is a different identity: computes, not hits
    ctx.eval_cell("tiny", Method::Magnitude, &CompressionSpec::prune(0.6))
        .unwrap();
    assert_eq!(ctx.artifact_store().counts().misses, 2);
}

#[test]
fn store_and_load_validate_identity() {
    let ck = tiny_checkpoint(1);
    let grams = synthetic_grams(&tiny_cfg(), 5);
    let spec = CompressionSpec::prune(0.5);
    let dir = temp_cache_dir("apack");
    let store = ArtifactStore::new(Some(dir.path().to_path_buf()));
    let key = key_for(&ck, "magnitude", &spec);
    let cached = compress_model_cached(&ck, &grams, &MagnitudePrune, &spec, true,
                                       &Executor::sequential(), &store, &key)
        .unwrap();
    // renaming the file under a different key's name must be rejected
    let other = key_for(&ck, "wanda", &spec);
    std::fs::rename(dir.path().join(key.file_name()),
                    dir.path().join(other.file_name()))
        .unwrap();
    assert!(awp::artifact::load_artifact(dir.path(), &other).is_err());
    // and store_artifact refuses a key/artifact mismatch outright
    assert!(store_artifact(dir.path(), &other, &cached.artifact).is_err());
}
