//! Cross-model sweep scheduling contracts (`coordinator::sweep`): with two
//! synthetic models × two cells each, the parallel schedule must produce
//! tables identical to the sequential run in plan order, prepare each
//! model exactly once, and attribute failures to the lowest-index failing
//! cell (or the failing model's preparation job).

mod common;

use std::collections::HashMap;
use std::sync::Mutex;

use awp::coordinator::{run_tables, sweep_cells, CellRef, Executor};
use awp::report::Table;

use common::prune_table as table;

/// Deterministic synthetic "perplexity" for a cell.
fn fake_ppl(c: &CellRef) -> f64 {
    let model_part = c.model.len() as f64;
    let ratio = match c.spec.mode {
        awp::compress::traits::CompressionMode::Prune { ratio } => ratio,
        _ => 0.0,
    };
    10.0 * model_part + ratio + c.table as f64
}

fn render(tables: &[Table]) -> String {
    tables.iter().map(|t| t.to_console()).collect::<Vec<_>>().join("\n")
}

#[test]
fn two_models_by_two_cells_is_plan_order_deterministic() {
    let tables = [table("t1", "alpha"), table("t2", "beta")];
    assert_eq!(sweep_cells(&tables).len(), 4);

    let run = |exec: Executor| {
        run_tables(
            &exec,
            &tables,
            |_m| Ok(()),
            |c| {
                // jitter completion order so parallel ≠ submission order
                std::thread::sleep(std::time::Duration::from_micros(
                    ((c.table * 7 + 3) % 5) as u64 * 150,
                ));
                Ok(fake_ppl(c))
            },
            |c| (c.table as u64 + 1) * 100,
            |t| t.title_prefix.clone(),
        )
        .unwrap()
    };

    let seq = run(Executor::sequential());
    for workers in [2usize, 4] {
        let par = run(Executor::with_workers(workers));
        assert_eq!(render(&seq), render(&par), "workers={workers}");
    }
    // values land in the right cells: row-major methods × specs
    assert_eq!(seq[0].rows[0].1[0], Some(fake_ppl(&sweep_cells(&tables)[0])));
    assert_eq!(seq[1].rows[0].1[1], Some(fake_ppl(&sweep_cells(&tables)[3])));
}

#[test]
fn each_model_prepares_once_even_when_shared_by_tables() {
    let tables = [table("t1", "alpha"), table("t2", "beta"), table("t3", "alpha")];
    let preps: Mutex<HashMap<String, usize>> = Mutex::new(HashMap::new());
    run_tables(
        &Executor::with_workers(4),
        &tables,
        |m| {
            *preps.lock().unwrap().entry(m.to_string()).or_insert(0) += 1;
            Ok(())
        },
        |c| Ok(fake_ppl(c)),
        |_c| 1,
        |t| t.title_prefix.clone(),
    )
    .unwrap();
    let preps = preps.into_inner().unwrap();
    assert_eq!(preps.len(), 2);
    assert_eq!(preps["alpha"], 1);
    assert_eq!(preps["beta"], 1);
}

#[test]
fn failing_model_attributes_the_lowest_index_failing_cell() {
    let tables = [table("t1", "alpha"), table("t2", "beta")];
    // exactly one cell fails (the second model's first cell, flat index 2):
    // with a single failure the attribution is deterministic at any worker
    // count — the error must name that cell's index and label
    let ratio_of = |c: &CellRef| match c.spec.mode {
        awp::compress::traits::CompressionMode::Prune { ratio } => ratio,
        _ => 0.0,
    };
    for workers in [1usize, 4] {
        let err = run_tables(
            &Executor::with_workers(workers),
            &tables,
            |_m| Ok(()),
            |c| {
                if c.model == "beta" && ratio_of(c) == 0.5 {
                    anyhow::bail!("model beta exploded");
                }
                Ok(fake_ppl(c))
            },
            |_c| 1,
            |t| t.title_prefix.clone(),
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        // cells are [t1 cell0, t1 cell1, t2 cell0, t2 cell1]
        assert!(msg.contains("job 2"), "workers={workers}: {msg}");
        assert!(msg.contains("t2[beta] magnitude prune50"),
                "workers={workers}: {msg}");
        assert!(msg.contains("model beta exploded"), "workers={workers}: {msg}");
    }
    // with *several* failing cells, the sequential schedule (the reference
    // the parallel one must match when unraced) still surfaces the lowest
    let err = run_tables(
        &Executor::sequential(),
        &tables,
        |_m| Ok(()),
        |c| {
            if c.model == "beta" {
                anyhow::bail!("model beta exploded");
            }
            Ok(fake_ppl(c))
        },
        |_c| 1,
        |t| t.title_prefix.clone(),
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("job 2"), "{msg}");
}

#[test]
fn failing_preparation_names_the_model_before_any_cell_runs() {
    let tables = [table("t1", "alpha"), table("t2", "beta")];
    let cells_run = Mutex::new(0usize);
    let err = run_tables(
        &Executor::sequential(),
        &tables,
        |m| {
            if m == "beta" {
                anyhow::bail!("training diverged");
            }
            Ok(())
        },
        |_c| {
            *cells_run.lock().unwrap() += 1;
            Ok(0.0)
        },
        |_c| 1,
        |t| t.title_prefix.clone(),
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("prepare beta"), "{msg}");
    assert!(msg.contains("training diverged"), "{msg}");
    assert_eq!(*cells_run.lock().unwrap(), 0, "cells must not start");
}

#[test]
fn cost_weights_reach_the_executor_stats() {
    // run the cell phase directly through the weighted executor to pin
    // that sweep costs land in JobStats (the ETA line's input)
    let tables = [table("t1", "alpha")];
    let cells = sweep_cells(&tables);
    let rep = Executor::with_workers(2)
        .run_weighted(
            cells.len(),
            |i| (i as u64 + 1) * 10,
            |i| cells[i].label(&tables),
            |i| Ok(fake_ppl(&cells[i])),
        )
        .unwrap();
    for (i, s) in rep.stats.iter().enumerate() {
        assert_eq!(s.cost, (i as u64 + 1) * 10);
        assert_eq!(s.label, cells[i].label(&tables));
    }
}
