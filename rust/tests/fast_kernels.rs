//! Differential validation of the fast kernel tier
//! ([`awp::tensor::KernelTier::Fast`]): every compression family × awkward
//! shape must match the reference tier within the documented tolerance
//! (`|x − y| ≤ 1e-4 · (1 + |x| + |y|)` per entry — KERNELS.md), the
//! reference tier must stay bitwise equal to the dense GEMM over the
//! decoded weights, and the fast tier must be thread-count invariant.
//!
//! The fast kernels change accumulation order (8-lane FMA panels, per-group
//! rescale of integer accumulators), so bit equality is the *wrong* oracle
//! here — tolerance is the contract, and the tolerance is tight enough that
//! any indexing, zero-point or tail-handling bug (O(1) errors) still fails.

mod common;

use awp::artifact::PackedLinear;
use awp::compress::traits::CompressionSpec;
use awp::infer::{NativeModel, SiteWeights};
use awp::model::sites::enumerate_sites;
use awp::proj::ProjScratch;
use awp::tensor::{ops, KernelTier, Matrix};
use awp::trainer::init_checkpoint;
use awp::util::parallel::with_thread_budget;
use awp::util::Rng;

use common::{assert_bits_eq, lm_cfg};

/// The fast-tier tolerance from KERNELS.md.
fn assert_close(fast: &Matrix, reference: &Matrix, what: &str) {
    assert_eq!(fast.shape(), reference.shape(), "{what}");
    for (i, (a, b)) in fast.data.iter().zip(&reference.data).enumerate() {
        let tol = 1e-4 * (1.0 + a.abs() + b.abs());
        assert!((a - b).abs() <= tol, "{what} entry {i}: {a} vs {b}");
    }
}

/// Proptest-style sweep: every spec family over widths that stress the
/// kernels' edge cases — group-clamp widths (`k < group`), ragged N:M
/// tails (`k % m != 0`), survivor-quad tails, and `n` that is not a
/// multiple of the 8-float SIMD lane count (including `n = 1`).
#[test]
fn fast_gemm_matches_reference_over_random_shapes_and_families() {
    let cases: &[(CompressionSpec, &[usize])] = &[
        (CompressionSpec::prune(0.4), &[5, 17, 30, 64, 100]),
        (CompressionSpec::quant(4, 32), &[16, 24, 32, 64, 96]),
        (CompressionSpec::quant(2, 16), &[8, 16, 48, 64]),
        (CompressionSpec::joint(0.5, 4, 32), &[16, 32, 64, 96]),
        (CompressionSpec::structured_nm(2, 4), &[8, 30, 64, 100]),
        (CompressionSpec::structured_nm(4, 8), &[16, 30, 64]),
        (CompressionSpec::joint_nm(2, 4, 4, 32), &[32, 64, 96]),
    ];
    let ns = [1usize, 3, 7, 8, 17, 33];
    let mut rng = Rng::new(0xFA57);
    for (case, (spec, ks)) in cases.iter().enumerate() {
        for &k in ks.iter() {
            for draw in 0..2u64 {
                let m = 1 + rng.below(12);
                let n = ns[rng.below(ns.len())];
                let seed = 1000 + case as u64 * 100 + k as u64 + draw;
                let mut theta = Matrix::randn(m, k, seed);
                spec.projection(theta.cols)
                    .project_rows(&mut theta, &mut ProjScratch::new());
                let packed = PackedLinear::encode(&theta, spec);
                let prepared = packed.clone().prepare();
                let b = Matrix::randn(k, n, seed + 1);
                let what = format!("spec={:?} {m}x{k}x{n} mode={}",
                                   spec.mode, prepared.mode_name());
                let fast = prepared.matmul_tier(&b, KernelTier::Fast);
                let reference = prepared.matmul_tier(&b, KernelTier::Reference);
                assert_close(&fast, &reference, &what);
                // the reference tier is the bitwise oracle: identical to
                // the dense GEMM over the decoded weights
                assert_bits_eq(&reference, &ops::matmul(&packed.decode(), &b),
                               &what);
            }
        }
    }
}

/// End-to-end serving: an all-packed int4 model on the fast tier produces
/// logits and NLL within tolerance of the reference tier, and the fast
/// tier's logits are bitwise identical across thread budgets.
#[test]
fn fast_model_matches_reference_and_is_thread_invariant() {
    let cfg = lm_cfg();
    let ck = init_checkpoint(&cfg, 9);
    let spec = CompressionSpec::quant(4, 32);
    let mut ref_sites = Vec::new();
    let mut fast_sites = Vec::new();
    for s in enumerate_sites(&cfg) {
        let mut theta = ck.matrix(&s.param).unwrap();
        spec.projection(theta.cols)
            .project_rows(&mut theta, &mut ProjScratch::new());
        let packed = PackedLinear::encode(&theta, &spec);
        ref_sites.push((s.param.clone(), SiteWeights::packed(packed.clone())));
        fast_sites.push((s.param, SiteWeights::packed(packed)));
    }
    let reference = NativeModel::with_site_weights(&ck, ref_sites).unwrap();
    let mut fast = NativeModel::with_site_weights(&ck, fast_sites).unwrap();
    fast.set_tier(KernelTier::Fast);
    let (batch, seq) = (cfg.batch, cfg.seq_len);
    let tokens: Vec<i32> = (0..batch * seq)
        .map(|i| (i * 37 % cfg.vocab) as i32)
        .collect();
    let a = reference.forward(&tokens, batch, seq).unwrap();
    let b = fast.forward(&tokens, batch, seq).unwrap();
    assert_close(&b, &a, "fast vs reference logits");
    let (nll_ref, c_ref) = reference.nll(&tokens, batch, seq).unwrap();
    let (nll_fast, c_fast) = fast.nll(&tokens, batch, seq).unwrap();
    assert_eq!(c_ref, c_fast);
    assert!((nll_ref - nll_fast).abs() / nll_ref.abs() < 1e-3,
            "nll {nll_ref} vs {nll_fast}");
    // fast-tier parallelism splits only independent output rows, so the
    // logits must not move with the thread budget — bitwise, like the
    // reference tier's guarantee in rust/tests/awp_threads_env.rs
    let f1 = with_thread_budget(1, || fast.forward(&tokens, batch, seq).unwrap());
    let f4 = with_thread_budget(4, || fast.forward(&tokens, batch, seq).unwrap());
    assert_bits_eq(&f1, &f4, "fast tier across thread budgets");
}

/// `generate --native --fast` path: greedy decode on the fast tier runs
/// end-to-end. (Token-level equality with the reference tier is *not*
/// asserted — a near-tie can legitimately flip under tolerance-level
/// logit differences.)
#[test]
fn fast_tier_generation_runs() {
    let cfg = lm_cfg();
    let ck = init_checkpoint(&cfg, 21);
    let mut nm = NativeModel::from_checkpoint(&ck).unwrap();
    nm.set_tier(KernelTier::Fast);
    let text = awp::eval::native_generate(&nm, "ab", 8).unwrap();
    assert!(text.len() >= 2, "generation produced {text:?}");
}

/// The `AWP_KERNEL_TIER` env knob: explicit values parse, garbage falls
/// back to the reference tier. Runs in this dedicated test binary because
/// it mutates process env.
#[test]
fn kernel_tier_env_knob() {
    assert_eq!(KernelTier::parse("fast"), Some(KernelTier::Fast));
    assert_eq!(KernelTier::parse("REFERENCE"), Some(KernelTier::Reference));
    assert_eq!(KernelTier::parse("turbo"), None);
    std::env::set_var("AWP_KERNEL_TIER", "fast");
    assert_eq!(KernelTier::from_env(), KernelTier::Fast);
    std::env::set_var("AWP_KERNEL_TIER", "nonsense");
    assert_eq!(KernelTier::from_env(), KernelTier::Reference);
    std::env::remove_var("AWP_KERNEL_TIER");
    assert_eq!(KernelTier::from_env(), KernelTier::Reference);
}
