//! Integration over the real AOT artifacts + PJRT runtime.
//!
//! These tests need `artifacts/` (run `make artifacts`); each skips
//! gracefully when it is absent so `cargo test` stays green pre-build.

use std::sync::Arc;

use awp::compress::awp::AwpBackend;
use awp::compress::CpuBackend;
use awp::coordinator::calibrate;
use awp::proj::{GroupedIntGrid, Intersect, RowTopK};
use awp::data::{Batcher, CorpusConfig, Split, SyntheticCorpus};
use awp::eval::{generate, perplexity};
use awp::model::GramKey;
use awp::runtime::{HloBackend, Manifest, Runtime};
use awp::tensor::Matrix;
use awp::trainer::{self, TrainConfig};

fn setup() -> Option<(Arc<Manifest>, Runtime)> {
    let manifest = Manifest::load("artifacts").ok()?;
    let runtime = Runtime::start().ok()?;
    Some((Arc::new(manifest), runtime))
}

fn small_batcher() -> Batcher {
    let corpus = SyntheticCorpus::generate(CorpusConfig {
        total_bytes: 512 << 10,
        ..Default::default()
    });
    Batcher::new(&corpus, 4, 128)
}

#[test]
fn hlo_and_cpu_awp_backends_agree() {
    let Some((manifest, runtime)) = setup() else { return };
    let hlo = HloBackend::new(runtime.handle(), manifest);
    let cpu = CpuBackend;
    let w = Matrix::randn(256, 256, 0);
    let th = Matrix::zeros(256, 256);
    let c = Matrix::randn_gram(256, 1);
    let eta = (2.0 / c.frob_norm()) as f32;

    // prune: 8 iterations (one chunk program call)
    let prune = RowTopK::new(128);
    let (ta, ga, la) = hlo.step_chunk_from(&w, &th, &c, eta, &prune, 8).unwrap();
    let (tb, gb, lb) = cpu.step_chunk_from(&w, &th, &c, eta, &prune, 8).unwrap();
    assert!((ga - gb).abs() < 1e-4 && (la - lb).abs() < 1e-4);
    let max = ta.data.iter().zip(&tb.data).map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max < 1e-3, "prune theta diverged: {max}");

    // quant single step
    let grid = GroupedIntGrid::new(15.0, 32);
    let (qa, _, _) = hlo.step_chunk_from(&w, &w, &c, eta, &grid, 1).unwrap();
    let (qb, _, _) = cpu.step_chunk_from(&w, &w, &c, eta, &grid, 1).unwrap();
    let max = qa.data.iter().zip(&qb.data).map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max < 1e-4, "quant theta diverged: {max}");

    // joint: 3 iterations via 1-step programs
    let joint = Intersect::new(RowTopK::new(64), GroupedIntGrid::new(15.0, 32));
    let (ja, _, _) = hlo.step_chunk_from(&w, &th, &c, eta, &joint, 3).unwrap();
    let (jb, _, _) = cpu.step_chunk_from(&w, &th, &c, eta, &joint, 3).unwrap();
    let max = ja.data.iter().zip(&jb.data).map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max < 2e-3, "joint theta diverged: {max}");
}

#[test]
fn hlo_iteration_decomposition_composes() {
    // 11 iterations = chunk(8) + 3 single calls; must equal CPU's 11.
    let Some((manifest, runtime)) = setup() else { return };
    let hlo = HloBackend::new(runtime.handle(), manifest);
    let cpu = CpuBackend;
    let w = Matrix::randn(128, 128, 5);
    let th = Matrix::zeros(128, 128);
    let c = Matrix::randn_gram(128, 6);
    let eta = (2.0 / c.frob_norm()) as f32;
    let proj = RowTopK::new(64);
    let (ta, _, _) = hlo.step_chunk_from(&w, &th, &c, eta, &proj, 11).unwrap();
    let (tb, _, _) = cpu.step_chunk_from(&w, &th, &c, eta, &proj, 11).unwrap();
    let max = ta.data.iter().zip(&tb.data).map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max < 1e-3, "{max}");
}

#[test]
fn training_reduces_loss_and_eval_matches() {
    let Some((manifest, runtime)) = setup() else { return };
    let batcher = small_batcher();
    let tc = TrainConfig { steps: 40, warmup: 5, log_every: 1000, seed: 3,
                           lr_max: 3e-3 };
    let (ck, curve) =
        trainer::train(&runtime.handle(), &manifest, "small", &batcher, &tc).unwrap();
    let first = curve.first().unwrap().1;
    let last = curve.last().unwrap().1;
    assert!(first > 5.0 && first < 6.2, "init loss ≈ ln(256), got {first}");
    assert!(last < first - 1.0, "no learning: {first} → {last}");
    // eval perplexity consistent with train loss ballpark
    let rep = perplexity(&runtime.handle(), &manifest, "small", &ck, &batcher,
                         Split::Val, 8).unwrap();
    assert!(rep.ppl < 60.0, "ppl {}", rep.ppl);
    assert!(rep.ppl > 1.5);
    // deterministic evaluation
    let rep2 = perplexity(&runtime.handle(), &manifest, "small", &ck, &batcher,
                          Split::Val, 8).unwrap();
    assert_eq!(rep.ppl, rep2.ppl);
}

#[test]
fn untrained_model_ppl_is_near_vocab() {
    let Some((manifest, runtime)) = setup() else { return };
    let batcher = small_batcher();
    let ck = trainer::init_checkpoint(&manifest.model("small").unwrap().config, 0);
    let rep = perplexity(&runtime.handle(), &manifest, "small", &ck, &batcher,
                         Split::Val, 4).unwrap();
    assert!(rep.ppl > 150.0 && rep.ppl < 400.0, "ppl {}", rep.ppl);
}

#[test]
fn calibration_grams_are_psd_and_scaled() {
    let Some((manifest, runtime)) = setup() else { return };
    let batcher = small_batcher();
    let ck = trainer::init_checkpoint(&manifest.model("small").unwrap().config, 1);
    let batches = batcher.calibration_set(3, 99);
    let grams = calibrate(&runtime.handle(), &manifest, "small", &ck, &batches)
        .unwrap();
    assert_eq!(grams.tokens, 3 * 4 * 128);
    let cfg = &manifest.model("small").unwrap().config;
    assert_eq!(grams.map.len(), 4 * cfg.n_layers);
    for ((key, layer), c) in &grams.map {
        let d = match key {
            GramKey::MlpDownIn => cfg.d_ff,
            _ => cfg.d_model,
        };
        assert_eq!(c.shape(), (d, d), "{key:?} {layer}");
        // symmetric, positive diagonal
        for i in 0..d.min(32) {
            assert!(c.at(i, i) >= -1e-4, "{key:?}[{layer}] diag {}", c.at(i, i));
        }
        // determinism: same calibration set, same gram
    }
    let grams2 = calibrate(&runtime.handle(), &manifest, "small", &ck, &batches)
        .unwrap();
    let a = grams.get(GramKey::AttnIn, 0).unwrap();
    let b = grams2.get(GramKey::AttnIn, 0).unwrap();
    assert_eq!(a.data, b.data);
}

#[test]
fn generation_is_deterministic_and_prompt_preserving() {
    let Some((manifest, runtime)) = setup() else { return };
    let ck = trainer::init_checkpoint(&manifest.model("tiny").unwrap().config, 7);
    let t1 = generate(&runtime.handle(), &manifest, "tiny", &ck, "Hello", 10).unwrap();
    let t2 = generate(&runtime.handle(), &manifest, "tiny", &ck, "Hello", 10).unwrap();
    assert_eq!(t1, t2);
    assert!(t1.starts_with("Hello"));
    // 10 generated byte-tokens; an untrained model may emit invalid UTF-8
    // which the lossy decode can merge into replacement chars, so only
    // bound the char count.
    let extra = t1.chars().count() - "Hello".chars().count();
    assert!(extra >= 4 && extra <= 10, "extra chars {extra}");
}

#[test]
fn runtime_stats_track_executions() {
    let Some((manifest, runtime)) = setup() else { return };
    let handle = runtime.handle();
    let before = handle.stats().unwrap().executions;
    let hlo = HloBackend::new(handle.clone(), manifest);
    let w = Matrix::randn(128, 128, 9);
    let c = Matrix::randn_gram(128, 10);
    hlo.step_chunk_from(&w, &Matrix::zeros(128, 128), &c, 0.01, &RowTopK::new(64), 8)
        .unwrap();
    let after = handle.stats().unwrap();
    assert_eq!(after.executions, before + 1);
    assert!(after.exec_seconds > 0.0);
}

#[test]
fn missing_program_is_a_clean_error() {
    let Some((manifest, runtime)) = setup() else { return };
    let hlo = HloBackend::new(runtime.handle(), manifest);
    // shape class that was never lowered
    let w = Matrix::randn(96, 96, 11);
    let c = Matrix::randn_gram(96, 12);
    let err = hlo.step_chunk_from(&w, &Matrix::zeros(96, 96), &c, 0.01,
                                  &RowTopK::new(48), 8);
    assert!(err.is_err());
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("make artifacts"), "{msg}");
}
