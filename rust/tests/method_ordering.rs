//! Cross-method ordering at the *pipeline* level — the qualitative shape of
//! the paper's tables, on a synthetic checkpoint + synthetic Grams (no
//! artifacts needed, so this always runs).
//!
//! The quantitative reproduction (real trained model, real calibration,
//! perplexity) is `repro experiment …`; this suite pins the orderings that
//! must hold for those tables to come out right.

mod common;

use std::collections::HashMap;

use awp::compress::awp::AwpHyper;
use awp::compress::traits::CompressionSpec;
use awp::coordinator::calibrate::Grams;
use awp::coordinator::{compress_model, make_compressor, Method};
use awp::eval::reconstruction::summarize;
use awp::model::GramKey;
use awp::tensor::Matrix;

use common::tiny_cfg as cfg;

fn setup() -> (awp::model::Checkpoint, Grams) {
    let cfg = cfg();
    let ck = awp::trainer::init_checkpoint(&cfg, 42);
    let mut map = HashMap::new();
    for l in 0..cfg.n_layers {
        for key in [GramKey::AttnIn, GramKey::AttnOutIn, GramKey::MlpIn] {
            map.insert((key, l),
                       Matrix::randn_gram(cfg.d_model, 7 * l as u64 + key.index() as u64));
        }
        map.insert((GramKey::MlpDownIn, l), Matrix::randn_gram(cfg.d_ff, 31 + l as u64));
    }
    (ck, Grams { map, tokens: 4096 })
}

fn mean_loss(method: Method, spec: &CompressionSpec) -> f64 {
    let (ck, grams) = setup();
    let compressor = make_compressor(method, AwpHyper::default(), None).unwrap();
    let out = compress_model(&ck, &grams, compressor.as_ref(), spec, true).unwrap();
    assert_eq!(out.reports.len(), 12);
    summarize(&out.reports).0
}

#[test]
fn table1_ordering_activation_aware_beats_magnitude() {
    let spec = CompressionSpec::prune(0.6);
    let mag = mean_loss(Method::Magnitude, &spec);
    let wanda = mean_loss(Method::Wanda, &spec);
    let sgpt = mean_loss(Method::SparseGpt, &spec);
    let awp = mean_loss(Method::AwpCpu, &spec);
    assert!(wanda < mag, "wanda {wanda} vs magnitude {mag}");
    assert!(sgpt < mag, "sparsegpt {sgpt} vs magnitude {mag}");
    assert!(awp <= wanda, "awp {awp} vs wanda {wanda}");
}

#[test]
fn table1_high_ratio_gap_widens() {
    // the AWP-vs-Wanda gap must grow with the pruning ratio (70%+ is where
    // the paper's Table 1 shows the blow-up)
    let gap = |ratio: f64| {
        let spec = CompressionSpec::prune(ratio);
        let wanda = mean_loss(Method::Wanda, &spec);
        let awp = mean_loss(Method::AwpCpu, &spec);
        (wanda - awp) / wanda.max(1e-12)
    };
    // on a random-init checkpoint with synthetic Grams the *relative* gap
    // need not widen monotonically (the trained-model experiments show the
    // paper's blow-up); require AWP to clearly win at both ratios.
    let g50 = gap(0.5);
    let g80 = gap(0.8);
    assert!(g50 > 0.01, "awp should win at 50%: {g50:.3}");
    assert!(g80 > 0.01, "awp should clearly win at 80%: {g80:.3}");
}

#[test]
fn table3_ordering_quant() {
    let spec = CompressionSpec::quant(3, 32);
    let rtn = mean_loss(Method::Rtn, &spec);
    let awq = mean_loss(Method::Awq, &spec);
    let gptq = mean_loss(Method::Gptq, &spec);
    let awp = mean_loss(Method::AwpCpu, &spec);
    assert!(awq <= rtn * 1.0001, "awq {awq} vs rtn {rtn}");
    assert!(gptq < rtn, "gptq {gptq} vs rtn {rtn}");
    assert!(awp <= rtn, "awp {awp} vs rtn {rtn}");
}

#[test]
fn table4_ordering_joint() {
    // at 50% on random-init weights the AWP-vs-sequential margin is thin
    // (the paper's Table 4 50% column is 9.46 vs 9.32 — ~1.5%); the clear
    // separation is at 75%, which we require strictly.
    let spec50 = CompressionSpec::joint(0.5, 4, 32);
    let qp = mean_loss(Method::AwqThenWanda, &spec50);
    let pq = mean_loss(Method::WandaThenAwq, &spec50);
    let awp = mean_loss(Method::AwpCpu, &spec50);
    assert!(pq <= qp * 1.05, "prune-first {pq} should ≲ quant-first {qp}");
    assert!(awp <= pq * 1.05, "awp joint {awp} far off wanda+awq {pq}");

    let spec75 = CompressionSpec::joint(0.75, 4, 32);
    let pq75 = mean_loss(Method::WandaThenAwq, &spec75);
    let awp75 = mean_loss(Method::AwpCpu, &spec75);
    assert!(awp75 < pq75, "awp joint 75% {awp75} vs wanda+awq {pq75}");
}

#[test]
fn section43_int4_75_beats_int2() {
    // the paper's headline §4.3 observation at matched ~2 bits/weight
    let int2 = mean_loss(Method::AwpCpu, &CompressionSpec::quant(2, 32));
    let joint = mean_loss(Method::AwpCpu, &CompressionSpec::joint(0.75, 4, 32));
    assert!(joint < int2, "INT4+75% ({joint}) must beat INT2 ({int2})");
}

#[test]
fn structured_2_4_mode_across_methods() {
    // paper §5 future work: 2:4 satisfies the pattern for every method, is
    // exactly 50% sparse, and activation-awareness keeps paying off
    // (wanda/awp ≤ magnitude under the same structural constraint); the
    // structural restriction costs vs unstructured 50%.
    let spec24 = CompressionSpec::structured24();
    let (ck, grams) = setup();
    for method in [Method::Magnitude, Method::Wanda, Method::AwpCpu] {
        let compressor = make_compressor(method, AwpHyper::default(), None).unwrap();
        let out = compress_model(&ck, &grams, compressor.as_ref(), &spec24, true)
            .unwrap();
        for r in &out.reports {
            assert!((r.sparsity - 0.5).abs() < 1e-6, "{method:?} {}", r.param);
        }
        for site in awp::model::sites::enumerate_sites(&ck.config) {
            let m = out.checkpoint.matrix(&site.param).unwrap();
            assert!(awp::sparse::check_2_4(&m), "{method:?} {}", site.param);
        }
    }
    let mag = mean_loss(Method::Magnitude, &spec24);
    let wanda = mean_loss(Method::Wanda, &spec24);
    let awp_l = mean_loss(Method::AwpCpu, &spec24);
    assert!(wanda < mag, "wanda24 {wanda} vs magnitude24 {mag}");
    assert!(awp_l <= wanda * 1.0001, "awp24 {awp_l} vs wanda24 {wanda}");
    // structural constraint costs vs unstructured 50%
    let unstructured = mean_loss(Method::AwpCpu, &CompressionSpec::prune(0.5));
    assert!(awp_l >= unstructured, "2:4 {awp_l} vs unstructured {unstructured}");
}

#[test]
fn losses_scale_with_severity() {
    // sanity: more pruning / fewer bits ⇒ more loss, for every method
    for method in [Method::Wanda, Method::AwpCpu] {
        let l5 = mean_loss(method, &CompressionSpec::prune(0.5));
        let l9 = mean_loss(method, &CompressionSpec::prune(0.9));
        assert!(l9 > l5, "{method:?}");
    }
    for method in [Method::Rtn, Method::AwpCpu] {
        let l4 = mean_loss(method, &CompressionSpec::quant(4, 32));
        let l2 = mean_loss(method, &CompressionSpec::quant(2, 32));
        assert!(l2 > l4, "{method:?}");
    }
}
