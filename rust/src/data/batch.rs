//! Train/val/calibration splits and sequence batching.

use super::{ByteTokenizer, SyntheticCorpus};
use crate::util::Rng;

/// Which slice of the corpus a batch is drawn from. Mirrors the paper's
/// protocol: calibration comes from the *training* distribution (C4/Pile),
/// perplexity is measured on a held-out split (WikiText-2 validation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Calib,
}

/// A `(batch, seq)` block of token ids, row-major, ready to marshal into an
/// `xla::Literal` of s32.
#[derive(Clone, Debug)]
pub struct Batch {
    pub batch: usize,
    pub seq: usize,
    pub tokens: Vec<i32>,
}

/// Deterministic batcher over the tokenized corpus.
///
/// Layout: `[ train | val | calib ]` contiguous regions (val/calib 10% each
/// by default). Train batches sample random windows; val batches iterate
/// sequential non-overlapping windows (stable perplexity); calib batches
/// sample random windows from the calib region with a *fixed* seed, like
/// the paper's fixed 128-sequence calibration sample.
pub struct Batcher {
    tokens: Vec<i32>,
    train_end: usize,
    val_end: usize,
    pub batch: usize,
    pub seq: usize,
}

impl Batcher {
    pub fn new(corpus: &SyntheticCorpus, batch: usize, seq: usize) -> Self {
        let tokens = ByteTokenizer.encode(&corpus.bytes);
        let n = tokens.len();
        assert!(n > 20 * seq, "corpus too small for seq={seq}");
        let train_end = n * 8 / 10;
        let val_end = n * 9 / 10;
        Batcher { tokens, train_end, val_end, batch, seq }
    }

    fn region(&self, split: Split) -> (usize, usize) {
        match split {
            Split::Train => (0, self.train_end),
            Split::Val => (self.train_end, self.val_end),
            Split::Calib => (self.val_end, self.tokens.len()),
        }
    }

    /// Random-window batch (train/calib style) from `split`, deterministic
    /// given `rng` state.
    pub fn sample(&self, split: Split, rng: &mut Rng) -> Batch {
        let (lo, hi) = self.region(split);
        let span = hi - lo - self.seq;
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let start = lo + rng.below(span);
            tokens.extend_from_slice(&self.tokens[start..start + self.seq]);
        }
        Batch { batch: self.batch, seq: self.seq, tokens }
    }

    /// Number of non-overlapping eval windows available in `split`.
    pub fn eval_batches(&self, split: Split) -> usize {
        let (lo, hi) = self.region(split);
        (hi - lo) / (self.seq * self.batch)
    }

    /// The `idx`-th sequential non-overlapping batch of `split`.
    pub fn eval_batch(&self, split: Split, idx: usize) -> Batch {
        let (lo, _hi) = self.region(split);
        let stride = self.seq * self.batch;
        let start = lo + idx * stride;
        let mut tokens = Vec::with_capacity(stride);
        for b in 0..self.batch {
            let s = start + b * self.seq;
            tokens.extend_from_slice(&self.tokens[s..s + self.seq]);
        }
        Batch { batch: self.batch, seq: self.seq, tokens }
    }

    /// Fixed calibration set: `n` random-window batches with a dedicated
    /// seed, independent of training RNG state.
    pub fn calibration_set(&self, n: usize, seed: u64) -> Vec<Batch> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| self.sample(Split::Calib, &mut rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CorpusConfig;

    fn batcher() -> Batcher {
        let corpus = SyntheticCorpus::generate(CorpusConfig {
            total_bytes: 128 << 10,
            ..Default::default()
        });
        Batcher::new(&corpus, 4, 64)
    }

    #[test]
    fn batch_shape() {
        let b = batcher();
        let mut rng = Rng::new(0);
        let batch = b.sample(Split::Train, &mut rng);
        assert_eq!(batch.tokens.len(), 4 * 64);
        assert!(batch.tokens.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn splits_disjoint() {
        let b = batcher();
        let (t0, t1) = b.region(Split::Train);
        let (v0, v1) = b.region(Split::Val);
        let (c0, c1) = b.region(Split::Calib);
        assert!(t0 < t1 && t1 == v0 && v0 < v1 && v1 == c0 && c0 < c1);
        assert_eq!(c1, b.tokens.len());
    }

    #[test]
    fn eval_batches_sequential_and_disjoint() {
        let b = batcher();
        let n = b.eval_batches(Split::Val);
        assert!(n >= 2);
        let b0 = b.eval_batch(Split::Val, 0);
        let b1 = b.eval_batch(Split::Val, 1);
        assert_ne!(b0.tokens, b1.tokens);
        // deterministic
        assert_eq!(b0.tokens, b.eval_batch(Split::Val, 0).tokens);
    }

    #[test]
    fn calibration_set_fixed() {
        let b = batcher();
        let c1 = b.calibration_set(3, 7);
        let c2 = b.calibration_set(3, 7);
        for (a, bb) in c1.iter().zip(&c2) {
            assert_eq!(a.tokens, bb.tokens);
        }
        let c3 = b.calibration_set(3, 8);
        assert_ne!(c1[0].tokens, c3[0].tokens);
    }

    #[test]
    fn train_sampling_varies() {
        let b = batcher();
        let mut rng = Rng::new(1);
        let s1 = b.sample(Split::Train, &mut rng);
        let s2 = b.sample(Split::Train, &mut rng);
        assert_ne!(s1.tokens, s2.tokens);
    }
}
