//! Zipf–Markov synthetic corpus.
//!
//! A two-level generative process over a closed word vocabulary:
//!
//! * **unigram**: word frequencies follow a Zipf law (exponent ~1.05), like
//!   natural text;
//! * **bigram**: each word draws its successor from a sparse per-word
//!   transition table (Markov order 1), giving the corpus *predictable
//!   structure* — a trained LM reaches substantially-below-uniform
//!   perplexity, so compression-induced degradation is measurable;
//! * **surface form**: words are synthesised letter strings; sentences get
//!   spaces, punctuation and capitalisation so the byte-level LM also has
//!   low-level structure to learn.
//!
//! Deterministic from the seed: the corpus, splits and calibration sample
//! are exactly reproducible, mirroring the paper's fixed 128-sequence C4
//! calibration setup.

use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub seed: u64,
    /// number of distinct words
    pub vocab_words: usize,
    /// Zipf exponent for unigram frequencies
    pub zipf_s: f64,
    /// successors per word in the bigram table
    pub branching: usize,
    /// probability of following the bigram table vs resampling unigram
    pub markov_strength: f64,
    /// total bytes to generate
    pub total_bytes: usize,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            seed: 1234,
            vocab_words: 2000,
            zipf_s: 1.05,
            branching: 6,
            markov_strength: 0.85,
            total_bytes: 4 << 20, // 4 MiB
        }
    }
}

/// The generated corpus: one long byte stream plus the word list (kept for
/// inspection/debugging of generation demos).
pub struct SyntheticCorpus {
    pub bytes: Vec<u8>,
    pub words: Vec<String>,
    pub config: CorpusConfig,
}

fn make_word(rng: &mut Rng, len: usize) -> String {
    const CONS: &[u8] = b"bcdfghjklmnprstvwz";
    const VOWS: &[u8] = b"aeiou";
    let mut s = String::new();
    for i in 0..len {
        let set = if i % 2 == 0 { CONS } else { VOWS };
        s.push(set[rng.below(set.len())] as char);
    }
    s
}

impl SyntheticCorpus {
    pub fn generate(config: CorpusConfig) -> Self {
        let mut rng = Rng::new(config.seed);
        // word surface forms (unique by construction attempt, duplicates OK)
        let words: Vec<String> = (0..config.vocab_words)
            .map(|_| {
                let len = 3 + rng.below(6);
                make_word(&mut rng, len)
            })
            .collect();
        // Zipf unigram weights
        let uni: Vec<f64> = (0..config.vocab_words)
            .map(|i| 1.0 / ((i + 1) as f64).powf(config.zipf_s))
            .collect();
        // sparse bigram successor lists (weights decay geometrically)
        let succ: Vec<Vec<usize>> = (0..config.vocab_words)
            .map(|_| {
                (0..config.branching)
                    .map(|_| rng.categorical(&uni))
                    .collect()
            })
            .collect();
        let succ_w: Vec<f64> =
            (0..config.branching).map(|i| 0.5f64.powi(i as i32)).collect();

        let mut bytes = Vec::with_capacity(config.total_bytes + 64);
        let mut cur = rng.categorical(&uni);
        let mut sentence_len = 0usize;
        let mut cap_next = true;
        while bytes.len() < config.total_bytes {
            let w = &words[cur];
            if cap_next {
                let mut chars = w.chars();
                if let Some(c0) = chars.next() {
                    bytes.extend(c0.to_uppercase().to_string().as_bytes());
                    bytes.extend(chars.as_str().as_bytes());
                }
                cap_next = false;
            } else {
                bytes.extend(w.as_bytes());
            }
            sentence_len += 1;
            // sentence boundary ~ geometric, mean ~12 words
            if rng.uniform() < 1.0 / 12.0 && sentence_len >= 3 {
                bytes.push(b'.');
                bytes.push(b' ');
                sentence_len = 0;
                cap_next = true;
                cur = rng.categorical(&uni);
                continue;
            }
            bytes.push(b' ');
            cur = if rng.uniform() < config.markov_strength {
                succ[cur][rng.categorical(&succ_w)]
            } else {
                rng.categorical(&uni)
            };
        }
        bytes.truncate(config.total_bytes);
        SyntheticCorpus { bytes, words, config }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CorpusConfig {
        CorpusConfig { total_bytes: 64 << 10, ..Default::default() }
    }

    #[test]
    fn deterministic() {
        let a = SyntheticCorpus::generate(small());
        let b = SyntheticCorpus::generate(small());
        assert_eq!(a.bytes, b.bytes);
    }

    #[test]
    fn seed_changes_output() {
        let a = SyntheticCorpus::generate(small());
        let b = SyntheticCorpus::generate(CorpusConfig { seed: 99, ..small() });
        assert_ne!(a.bytes, b.bytes);
    }

    #[test]
    fn exact_size_and_ascii() {
        let c = SyntheticCorpus::generate(small());
        assert_eq!(c.bytes.len(), 64 << 10);
        assert!(c.bytes.iter().all(|&b| b.is_ascii()));
    }

    #[test]
    fn has_sentence_structure() {
        let c = SyntheticCorpus::generate(small());
        let text = String::from_utf8(c.bytes.clone()).unwrap();
        assert!(text.contains(". "));
        assert!(text.bytes().filter(|&b| b == b' ').count() > 1000);
    }

    #[test]
    fn zipf_head_dominates() {
        // the most frequent word should appear far more often than a
        // mid-rank word — the heavy-tail property the Gram anisotropy
        // ultimately derives from.
        let c = SyntheticCorpus::generate(small());
        let text = String::from_utf8(c.bytes).unwrap();
        let count = |w: &str| text.matches(&format!(" {w} ")).count();
        let head = count(&c.words[0]);
        let mid = count(&c.words[500]);
        assert!(head > 5 * (mid + 1), "head={head} mid={mid}");
    }

    #[test]
    fn bigram_structure_present() {
        // P(next | cur) concentrated: the most common successor pair of the
        // top word should beat the unigram rate of that successor.
        let c = SyntheticCorpus::generate(CorpusConfig {
            total_bytes: 256 << 10,
            ..Default::default()
        });
        let text = String::from_utf8(c.bytes).unwrap();
        let tokens: Vec<&str> = text
            .split([' ', '.'])
            .filter(|s| !s.is_empty())
            .collect();
        let top = c.words[0].as_str();
        let mut after = std::collections::HashMap::new();
        let mut top_n = 0usize;
        for w in tokens.windows(2) {
            if w[0].to_lowercase() == top {
                *after.entry(w[1].to_string()).or_insert(0usize) += 1;
                top_n += 1;
            }
        }
        let best = after.values().max().copied().unwrap_or(0);
        assert!(top_n > 20);
        // markov_strength=.85, branching=6 with geometric weights ⇒ the top
        // successor takes >~25% of transitions; unigram zipf head is ~13%.
        assert!(best as f64 / top_n as f64 > 0.15);
    }
}
