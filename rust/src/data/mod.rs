//! Data substrate: synthetic corpus, byte tokenizer, splits and batching.
//!
//! Stands in for the paper's C4 (pruning calibration), Pile (quantization
//! calibration) and WikiText-2 (perplexity eval) — see DESIGN.md §2 for why
//! a Zipf–Markov synthetic corpus preserves the properties the experiments
//! depend on (non-isotropic, cross-correlated activation Grams).

pub mod batch;
pub mod corpus;
pub mod tokenizer;

pub use batch::{Batch, Batcher, Split};
pub use corpus::{CorpusConfig, SyntheticCorpus};
pub use tokenizer::ByteTokenizer;
