//! Byte-level tokenizer (vocab = 256).
//!
//! Byte-level tokenization keeps the model's vocabulary tiny (the paper's
//! Llama tokenizers would dwarf our models) while remaining a *real*
//! tokenizer: decode(encode(x)) == x for arbitrary bytes, and perplexity-
//! per-byte is a standard, well-defined metric.

/// Identity byte tokenizer.
#[derive(Clone, Copy, Debug, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB: usize = 256;

    pub fn encode(&self, text: &[u8]) -> Vec<i32> {
        text.iter().map(|&b| b as i32).collect()
    }

    pub fn decode(&self, tokens: &[i32]) -> Vec<u8> {
        tokens
            .iter()
            .map(|&t| u8::try_from(t.clamp(0, 255)).unwrap())
            .collect()
    }

    pub fn decode_lossy_string(&self, tokens: &[i32]) -> String {
        String::from_utf8_lossy(&self.decode(tokens)).into_owned()
    }

    /// The id decode windows are left-padded with. A byte-level vocabulary
    /// has no reserved pad token, so the tokenizer nominates the corpus'
    /// neutral filler byte (space); consumers must take it from here
    /// rather than hard-coding a byte (`eval::generate::decode_window`).
    pub fn pad_id(&self) -> i32 {
        b' ' as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = ByteTokenizer;
        let text = b"Hello, AWP! \x00\xff".to_vec();
        assert_eq!(t.decode(&t.encode(&text)), text);
    }

    #[test]
    fn vocab_range() {
        let t = ByteTokenizer;
        let all: Vec<u8> = (0..=255).collect();
        let toks = t.encode(&all);
        assert!(toks.iter().all(|&x| (0..256).contains(&x)));
    }

    #[test]
    fn clamps_out_of_range() {
        let t = ByteTokenizer;
        assert_eq!(t.decode(&[-5, 300]), vec![0u8, 255]);
    }

    #[test]
    fn pad_id_is_a_real_vocab_token() {
        let t = ByteTokenizer;
        assert!((0..ByteTokenizer::VOCAB as i32).contains(&t.pad_id()));
        // padding round-trips through decode like any other token
        assert_eq!(t.decode(&[t.pad_id()]), vec![b' ']);
    }
}
