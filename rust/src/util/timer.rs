//! Wall-clock section timing for the coordinator's progress reporting.

use std::time::Instant;

/// A labelled stopwatch; used by the pipeline to report per-phase timings
/// (calibration, per-layer compression, evaluation) in experiment logs.
pub struct Timer {
    label: String,
    start: Instant,
}

impl Timer {
    pub fn start(label: impl Into<String>) -> Self {
        Timer { label: label.into(), start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn report(&self) -> String {
        format!("[{}] {:.2}s", self.label, self.elapsed_s())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start("x");
        let a = t.elapsed_s();
        let b = t.elapsed_s();
        assert!(b >= a);
        assert!(t.report().starts_with("[x]"));
    }
}
