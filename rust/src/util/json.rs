//! Minimal JSON substrate (parser + writer).
//!
//! The build is fully offline (no serde on the image), so the repo carries
//! its own JSON implementation: a recursive-descent parser and a compact
//! writer. It covers everything the system exchanges as JSON — the AOT
//! `artifacts/manifest.json`, experiment configs, checkpoint headers and
//! result reports. Numbers are f64 (ample for our shapes/counts); object
//! key order is preserved on write for stable diffs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---------------------------------------------------------------- access
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn expect(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Ok(o),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// Convenience: object as a map view.
    pub fn to_map(&self) -> Result<BTreeMap<&str, &Json>> {
        Ok(self.as_obj()?.iter().map(|(k, v)| (k.as_str(), v)).collect())
    }

    // ----------------------------------------------------------- construction
    pub fn obj(kvs: Vec<(&str, Json)>) -> Json {
        Json::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    // ------------------------------------------------------------------ write
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ------------------------------------------------------------------ parse
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing characters at byte {pos}");
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => {
            expect_lit(b, pos, "true")?;
            Ok(Json::Bool(true))
        }
        b'f' => {
            expect_lit(b, pos, "false")?;
            Ok(Json::Bool(false))
        }
        b'n' => {
            expect_lit(b, pos, "null")?;
            Ok(Json::Null)
        }
        _ => parse_num(b, pos),
    }
}

fn expect_lit(b: &[u8], pos: &mut usize, lit: &str) -> Result<()> {
    if b.len() - *pos >= lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes() {
        *pos += lit.len();
        Ok(())
    } else {
        bail!("expected '{lit}' at byte {pos}");
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // {
    let mut kvs = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(kvs));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b':' {
            bail!("expected ':' at byte {pos}");
        }
        *pos += 1;
        let val = parse_value(b, pos)?;
        kvs.push((key, val));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(kvs));
            }
            _ => bail!("expected ',' or '}}' at byte {pos}"),
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // [
    let mut items = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => bail!("expected ',' or ']' at byte {pos}"),
        }
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    if b.get(*pos) != Some(&b'"') {
        bail!("expected string at byte {pos}");
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        if *pos + 4 >= b.len() {
                            bail!("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let cp = u32::from_str_radix(hex, 16)?;
                        // no surrogate-pair support needed for our payloads
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => bail!("bad escape at byte {pos}"),
                }
                *pos += 1;
            }
            _ => {
                // copy one UTF-8 scalar
                let s = std::str::from_utf8(&b[*pos..])?;
                let c = s.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
    bail!("unterminated string");
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-3.5", "1e-3", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let back = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "{src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"c"}],"d":{"e":null}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
        assert_eq!(v.get("d").unwrap().get("e"), Some(&Json::Null));
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::obj(vec![("z", Json::Num(1.0)), ("a", Json::Num(2.0))]);
        assert_eq!(v.to_string(), r#"{"z":1,"a":2}"#);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("12x").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
            "awp": {"chunk": 8, "group": 32,
                    "programs": {"awp_prune_256x256": "awp_prune_256x256.hlo.txt"}},
            "format": "hlo-text",
            "models": {"small": {"config": {"d_model": 256},
                                 "params": [{"name": "embed", "shape": [256, 256]}],
                                 "programs": {"train_step": "train_step_small.hlo.txt"}}},
            "version": 1
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.expect("awp").unwrap().expect("chunk").unwrap().as_usize().unwrap(), 8);
        let shape = v.expect("models").unwrap().expect("small").unwrap()
            .expect("params").unwrap().as_arr().unwrap()[0]
            .expect("shape").unwrap();
        assert_eq!(shape.as_arr().unwrap()[0].as_usize().unwrap(), 256);
    }

    #[test]
    fn integers_written_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
