//! Self-cleaning temporary directories for tests (no tempfile crate on the
//! offline image).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A uniquely-named temp directory removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(tag: &str) -> std::io::Result<TempDir> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "awp-{tag}-{}-{}-{n}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans() {
        let p;
        {
            let d = TempDir::new("t").unwrap();
            p = d.path().to_path_buf();
            std::fs::write(d.path().join("x"), b"hi").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn unique_names() {
        let a = TempDir::new("u").unwrap();
        let b = TempDir::new("u").unwrap();
        assert_ne!(a.path(), b.path());
    }
}
