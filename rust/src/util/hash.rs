//! Content hashing for cache keys: FNV-1a 64-bit.
//!
//! The build is fully offline (no hashing crates on the image), so the
//! calibration-artifact cache carries its own hash. FNV-1a is not
//! cryptographic — the cache only needs *change detection* (checkpoint
//! fingerprints, calibration-config fingerprints), and every cache file
//! re-validates its identity fields on load, so a collision degrades to a
//! recompute, never to wrong data.

/// Incremental FNV-1a 64-bit hasher.
#[derive(Clone, Debug)]
pub struct Fnv64(u64);

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64(FNV_OFFSET)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        self.0 = h;
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }

    /// Hash the bit pattern (covers NaN/-0.0 distinctions deterministically).
    pub fn write_f64(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    /// Length-prefixed so `("ab","c")` and `("a","bc")` hash differently.
    pub fn write_str(&mut self, s: &str) {
        self.write_usize(s.len());
        self.write(s.as_bytes());
    }

    /// Hash a whole `f32` buffer (little-endian bit patterns).
    pub fn write_f32_slice(&mut self, data: &[f32]) {
        self.write_usize(data.len());
        for v in data {
            self.write(&v.to_le_bytes());
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64::new()
    }
}

/// One-shot convenience.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.write(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // standard FNV-1a test vectors
        assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn deterministic_and_order_sensitive() {
        let mut a = Fnv64::new();
        a.write_str("ab");
        a.write_str("c");
        let mut b = Fnv64::new();
        b.write_str("a");
        b.write_str("bc");
        assert_ne!(a.finish(), b.finish());
        let mut c = Fnv64::new();
        c.write_str("ab");
        c.write_str("c");
        assert_eq!(a.finish(), c.finish());
    }

    #[test]
    fn f32_slices_hash_bit_patterns() {
        let mut a = Fnv64::new();
        a.write_f32_slice(&[1.0, -0.0]);
        let mut b = Fnv64::new();
        b.write_f32_slice(&[1.0, 0.0]);
        assert_ne!(a.finish(), b.finish(), "-0.0 and 0.0 must differ");
    }
}
