//! Micro-benchmark harness (the image carries no criterion): warmup +
//! repeated timing, reporting min/median/mean. Used by `benches/*.rs`
//! (`cargo bench`) and the perf pass in EXPERIMENTS.md §Perf.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min_s: f64,
    pub median_s: f64,
    pub mean_s: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!("{:44} {:>5}x  min {:>10}  median {:>10}  mean {:>10}",
                self.name, self.iters, fmt_s(self.min_s), fmt_s(self.median_s),
                fmt_s(self.mean_s))
    }

    /// throughput helper: GFLOP/s at `flops` per iteration (median)
    pub fn gflops(&self, flops: f64) -> f64 {
        flops / self.median_s / 1e9
    }
}

pub fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Time `f` with auto-scaled iteration count targeting ~`budget_s` seconds
/// of measurement (min 3 iterations), after one warmup call.
pub fn bench(name: &str, budget_s: f64, mut f: impl FnMut()) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / once).ceil() as usize).clamp(3, 10_000);
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let result = BenchResult {
        name: name.to_string(),
        iters,
        min_s: times[0],
        median_s: times[times.len() / 2],
        mean_s: times.iter().sum::<f64>() / times.len() as f64,
    };
    println!("{}", result.line());
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", 0.01, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.min_s <= r.median_s && r.median_s <= r.mean_s * 2.0);
        assert!(r.iters >= 3);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_s(5e-9).ends_with("ns"));
        assert!(fmt_s(5e-6).ends_with("µs"));
        assert!(fmt_s(5e-3).ends_with("ms"));
        assert!(fmt_s(5.0).ends_with("s"));
    }
}
