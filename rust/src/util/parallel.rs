//! Thread-parallel substrate (no external runtime on the image): scoped
//! parallel-for and a work-stealing-ish chunked map built on `std::thread`.
//!
//! Used by the tensor GEMM row-panels and the coordinator's layer-job
//! worker pool (`coordinator::executor`). Thread count defaults to the
//! machine's parallelism and can be pinned via `AWP_THREADS` (useful for
//! the perf-pass scaling study).
//!
//! ## Thread budgets (outer × inner ≤ `AWP_THREADS`)
//!
//! Two levels of parallelism coexist: the executor's *outer* layer-job
//! workers and the *inner* GEMM row-panel threads each job spawns through
//! [`par_map`]/[`par_chunks_mut`]. To keep the product bounded by the
//! machine budget instead of oversubscribing cores, a worker thread runs
//! its job inside [`with_thread_budget`]`(inner, ..)`; every parallel
//! primitive consults the calling thread's budget (via [`num_threads`])
//! before falling back to `AWP_THREADS` / available parallelism. Budgets
//! nest: an executor created inside a budgeted scope sizes itself from the
//! scope's budget, so job-level parallelism composes with the GEMM
//! parallelism in `tensor::ops` automatically.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

thread_local! {
    /// Per-thread cap on how many threads parallel primitives may use.
    /// `None` ⇒ fall back to `AWP_THREADS` / available parallelism.
    static THREAD_BUDGET: Cell<Option<usize>> = const { Cell::new(None) };
}

/// The calling thread's inner-parallelism budget, if one is in force.
pub fn current_thread_budget() -> Option<usize> {
    THREAD_BUDGET.with(|b| b.get())
}

/// Run `f` with this thread's parallelism budget capped at `n` (≥ 1).
/// Restores the previous budget afterwards (also on panic), so budgeted
/// scopes nest.
pub fn with_thread_budget<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<usize>);
    impl Drop for Restore {
        fn drop(&mut self) {
            THREAD_BUDGET.with(|b| b.set(self.0));
        }
    }
    let prev = THREAD_BUDGET.with(|b| b.replace(Some(n.max(1))));
    let _restore = Restore(prev);
    f()
}

/// Number of worker threads to use: the calling thread's budget if one is
/// set, else `AWP_THREADS`, else the machine's available parallelism.
pub fn num_threads() -> usize {
    if let Some(n) = current_thread_budget() {
        return n.max(1);
    }
    if let Ok(v) = std::env::var("AWP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Parallel map over `0..n` with dynamic (atomic-counter) scheduling.
/// `f(i)` must be independent per index. Results come back in index order.
///
/// Scheduling granularity is a contiguous *chunk* of indices; each worker
/// writes a finished chunk back with one lock acquisition (no per-element
/// locking — the results are reassembled in chunk order at the end).
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    // ~4 chunks per worker keeps the tail balanced without lock churn
    let chunk = n.div_ceil(threads * 4).max(1);
    let n_chunks = n.div_ceil(chunk);
    let counter = AtomicUsize::new(0);
    let done: Mutex<Vec<(usize, Vec<T>)>> = Mutex::new(Vec::with_capacity(n_chunks));
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let ci = counter.fetch_add(1, Ordering::Relaxed);
                if ci >= n_chunks {
                    break;
                }
                let lo = ci * chunk;
                let hi = (lo + chunk).min(n);
                let vals: Vec<T> = (lo..hi).map(&f).collect();
                done.lock().unwrap().push((ci, vals));
            });
        }
    });
    let mut parts = done.into_inner().unwrap();
    debug_assert_eq!(parts.len(), n_chunks, "worker died before finishing");
    parts.sort_unstable_by_key(|(ci, _)| *ci);
    let mut out = Vec::with_capacity(n);
    for (_, mut vals) in parts {
        out.append(&mut vals);
    }
    out
}

/// Parallel for-each over mutable, disjoint chunks of a slice (static
/// round-robin assignment). The workhorse of the blocked GEMM: each chunk
/// is one output row.
pub fn par_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    chunk: usize,
    f: F,
) {
    assert!(chunk > 0);
    let n_chunks = data.len().div_ceil(chunk);
    let threads = num_threads().min(n_chunks.max(1));
    if threads <= 1 || n_chunks <= 1 {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    // hand out raw chunk pointers through a Vec of &mut
    let chunks: Vec<&mut [T]> = data.chunks_mut(chunk).collect();
    let chunks = Mutex::new(chunks.into_iter().map(Some).collect::<Vec<_>>());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n_chunks {
                    break;
                }
                let c = chunks.lock().unwrap()[i].take().unwrap();
                f(i, c);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_ordered() {
        let out = par_map(100, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        assert!(par_map(0, |i| i).is_empty());
        assert_eq!(par_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn par_map_non_divisible_lengths() {
        // exercise chunk-boundary reassembly across awkward sizes
        for n in [2usize, 3, 7, 31, 97, 101, 1000] {
            let out = par_map(n, |i| 3 * i + 1);
            assert_eq!(out.len(), n);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, 3 * i + 1, "n={n}");
            }
        }
    }

    #[test]
    fn par_chunks_mut_covers_all() {
        let mut data = vec![0u32; 97]; // non-divisible length
        par_chunks_mut(&mut data, 10, |i, c| {
            for v in c.iter_mut() {
                *v = i as u32 + 1;
            }
        });
        assert!(data.iter().all(|&v| v > 0));
        assert_eq!(data[0], 1);
        assert_eq!(data[96], 10);
    }

    #[test]
    fn num_threads_env_override() {
        // can't set env safely in parallel tests; just check default sanity
        assert!(num_threads() >= 1);
    }

    #[test]
    fn budget_caps_num_threads_and_restores() {
        assert_eq!(current_thread_budget(), None);
        let inside = with_thread_budget(2, || {
            assert_eq!(current_thread_budget(), Some(2));
            // nesting: inner budget wins, outer restored after
            with_thread_budget(1, || assert_eq!(num_threads(), 1));
            assert_eq!(current_thread_budget(), Some(2));
            num_threads()
        });
        assert_eq!(inside, 2);
        assert_eq!(current_thread_budget(), None);
    }

    #[test]
    fn budget_is_per_thread() {
        with_thread_budget(1, || {
            // a freshly spawned thread does not inherit the budget
            let child = std::thread::spawn(current_thread_budget);
            assert_eq!(child.join().unwrap(), None);
            assert_eq!(current_thread_budget(), Some(1));
        });
    }

    #[test]
    fn budget_zero_clamps_to_one() {
        with_thread_budget(0, || {
            assert_eq!(num_threads(), 1);
        });
    }

    #[test]
    fn par_map_respects_budget_of_one() {
        // budget 1 ⇒ sequential fast path; results identical either way
        let seq = with_thread_budget(1, || par_map(50, |i| i * 2));
        let par = par_map(50, |i| i * 2);
        assert_eq!(seq, par);
    }
}
