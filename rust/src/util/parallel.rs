//! Thread-parallel substrate (no external runtime on the image): scoped
//! parallel-for and a work-stealing-ish chunked map built on `std::thread`.
//!
//! Used by the tensor GEMM row-panels and the coordinator's layer-job
//! worker pool. Thread count defaults to the machine's parallelism and can
//! be pinned via `AWP_THREADS` (useful for the perf-pass scaling study).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("AWP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Parallel map over `0..n` with dynamic (atomic-counter) scheduling.
/// `f(i)` must be independent per index. Results come back in index order.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let counter = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                *slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker died before filling slot"))
        .collect()
}

/// Parallel for-each over mutable, disjoint chunks of a slice (static
/// round-robin assignment). The workhorse of the blocked GEMM: each chunk
/// is one output row.
pub fn par_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    chunk: usize,
    f: F,
) {
    assert!(chunk > 0);
    let n_chunks = data.len().div_ceil(chunk);
    let threads = num_threads().min(n_chunks.max(1));
    if threads <= 1 || n_chunks <= 1 {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    // hand out raw chunk pointers through a Vec of &mut
    let chunks: Vec<&mut [T]> = data.chunks_mut(chunk).collect();
    let chunks = Mutex::new(chunks.into_iter().map(Some).collect::<Vec<_>>());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n_chunks {
                    break;
                }
                let c = chunks.lock().unwrap()[i].take().unwrap();
                f(i, c);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_ordered() {
        let out = par_map(100, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        assert!(par_map(0, |i| i).is_empty());
        assert_eq!(par_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn par_chunks_mut_covers_all() {
        let mut data = vec![0u32; 97]; // non-divisible length
        par_chunks_mut(&mut data, 10, |i, c| {
            for v in c.iter_mut() {
                *v = i as u32 + 1;
            }
        });
        assert!(data.iter().all(|&v| v > 0));
        assert_eq!(data[0], 1);
        assert_eq!(data[96], 10);
    }

    #[test]
    fn num_threads_env_override() {
        // can't set env safely in parallel tests; just check default sanity
        assert!(num_threads() >= 1);
    }
}
