//! Small shared utilities: deterministic RNG, timing, logging helpers.

pub mod bench;
pub mod hash;
pub mod json;
pub mod parallel;
pub mod rng;
pub mod tempdir;
pub mod timer;

pub use hash::Fnv64;
pub use json::Json;
pub use rng::Rng;
pub use timer::Timer;
