//! Deterministic, seedable RNG (splitmix64 core + Box–Muller normals).
//!
//! Every stochastic piece of the repo (corpus generation, weight init for
//! tests, calibration sampling) routes through this so experiments are
//! exactly reproducible from a seed recorded in the config — the same role
//! the paper's fixed calibration sample plays.

/// splitmix64: tiny, high-quality, and trivially seedable.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    /// cached second Box–Muller normal
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = self.uniform();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.uniform();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fork a child RNG (stable across call sites).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(3);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
