//! End-to-end compression pipeline: plan → compress every site → assemble.

use anyhow::{Context, Result};

use super::calibrate::Grams;
use super::jobs::plan_jobs;
use crate::compress::traits::{check_constraints, CompressionSpec, LayerCompressor};
use crate::eval::reconstruction::{layer_report, LayerReport};
use crate::model::Checkpoint;
use crate::util::Timer;

/// Output of a pipeline run.
pub struct PipelineResult {
    pub checkpoint: Checkpoint,
    pub reports: Vec<LayerReport>,
    pub seconds: f64,
}

/// Compress every block-linear site of `ck` with `compressor` under `spec`,
/// returning the assembled checkpoint (embeddings/norms untouched — the
/// paper compresses transformer-block weights only).
///
/// `verify` re-checks the constraint set on every produced Θ before it is
/// installed (cheap; catches method/spec mismatches at the source).
pub fn compress_model(ck: &Checkpoint, grams: &Grams,
                      compressor: &dyn LayerCompressor, spec: &CompressionSpec,
                      verify: bool) -> Result<PipelineResult> {
    let timer = Timer::start("pipeline");
    let plan = plan_jobs(&ck.config);
    let mut out = Checkpoint {
        config: ck.config.clone(),
        tensors: ck.tensors.clone(),
        meta: ck.meta.clone(),
    };
    let mut reports = Vec::with_capacity(plan.jobs.len());
    for job in &plan.jobs {
        let site = &job.site;
        let w = ck
            .matrix(&site.param)
            .with_context(|| format!("loading {}", site.param))?;
        let c = grams
            .get(site.gram, site.layer)
            .with_context(|| format!("missing Gram for {}", site.param))?;
        let result = compressor
            .compress(&w, c, spec)
            .with_context(|| format!("compressing {}", site.param))?;
        if verify {
            // the INT-grid refit check only applies to methods whose grid is
            // the min/max fit of their own output (see LayerCompressor docs);
            // for the others, still verify the sparsity half of the spec.
            use crate::compress::traits::CompressionMode;
            let check_spec = if compressor.grid_refit_checkable() {
                Some(*spec)
            } else {
                match spec.mode {
                    CompressionMode::Prune { .. } | CompressionMode::Structured24 => {
                        Some(*spec)
                    }
                    CompressionMode::Joint { ratio, .. } => {
                        Some(CompressionSpec::prune(ratio))
                    }
                    CompressionMode::Quant { .. } => None,
                }
            };
            if let Some(cs) = check_spec {
                check_constraints(&result.theta, &cs)
                    .with_context(|| format!("constraint violation at {}", site.param))?;
            }
        }
        reports.push(layer_report(site, &result.theta, &result.stats));
        out.set(&site.param, result.theta.data)
            .with_context(|| format!("installing {}", site.param))?;
    }
    out.meta.insert("compressed_with".into(), compressor.name().to_string());
    Ok(PipelineResult { checkpoint: out, reports, seconds: timer.elapsed_s() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::magnitude::MagnitudePrune;
    use crate::model::{sites, GramKey, ModelConfig};
    use crate::tensor::Matrix;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(), vocab: 32, d_model: 16, n_heads: 2, n_layers: 2,
            d_ff: 32, seq_len: 8, batch: 1, decode_len: 8, rope_theta: 1e4,
        }
    }

    fn synthetic_grams(cfg: &ModelConfig) -> Grams {
        let mut map = std::collections::HashMap::new();
        for l in 0..cfg.n_layers {
            for key in [GramKey::AttnIn, GramKey::AttnOutIn, GramKey::MlpIn] {
                map.insert((key, l), Matrix::randn_gram(cfg.d_model, l as u64 * 10 + key.index() as u64));
            }
            map.insert((GramKey::MlpDownIn, l), Matrix::randn_gram(cfg.d_ff, 99 + l as u64));
        }
        Grams { map, tokens: 1000 }
    }

    #[test]
    fn compresses_all_sites_and_only_sites() {
        let cfg = tiny_cfg();
        let ck = crate::trainer::init_checkpoint(&cfg, 0);
        let grams = synthetic_grams(&cfg);
        let spec = CompressionSpec::prune(0.5);
        let out = compress_model(&ck, &grams, &MagnitudePrune, &spec, true).unwrap();
        assert_eq!(out.reports.len(), sites::enumerate_sites(&cfg).len());
        // every block weight 50% sparse
        for s in sites::enumerate_sites(&cfg) {
            let m = out.checkpoint.matrix(&s.param).unwrap();
            assert!((m.sparsity() - 0.5).abs() < 0.05, "{}", s.param);
        }
        // embeddings untouched
        assert_eq!(out.checkpoint.get("embed").unwrap().1, ck.get("embed").unwrap().1);
        assert_eq!(out.checkpoint.meta["compressed_with"], "magnitude");
    }

    #[test]
    fn missing_gram_is_an_error() {
        let cfg = tiny_cfg();
        let ck = crate::trainer::init_checkpoint(&cfg, 0);
        let mut grams = synthetic_grams(&cfg);
        grams.map.remove(&(GramKey::MlpDownIn, 1));
        let spec = CompressionSpec::prune(0.5);
        let err = compress_model(&ck, &grams, &MagnitudePrune, &spec, false);
        assert!(err.is_err());
    }
}
