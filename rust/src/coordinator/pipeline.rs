//! End-to-end compression pipeline: plan → compress every site on the
//! layer-job executor → assemble.
//!
//! Every `(W, C)` site is an independent PGD problem, so the jobs run on
//! [`Executor`]'s worker pool; assembly happens afterwards in plan order,
//! which keeps the reports and the produced checkpoint identical to a
//! sequential run regardless of worker count or completion order.

use anyhow::{Context, Result};

use super::calibrate::Grams;
use super::executor::{Executor, JobStats};
use super::jobs::plan_jobs;
use crate::artifact::{
    ArtifactKey, ArtifactSite, ArtifactStore, ModelArtifact, PackedLinear,
};
use crate::compress::traits::{
    check_constraints, verification_spec, CompressionSpec, LayerCompressor,
};
use crate::eval::reconstruction::{layer_report, LayerReport};
use crate::model::Checkpoint;
use crate::util::Timer;

/// Output of a pipeline run.
pub struct PipelineResult {
    pub checkpoint: Checkpoint,
    pub reports: Vec<LayerReport>,
    /// per-job executor telemetry (wall-clock, worker id), in plan order
    pub job_stats: Vec<JobStats>,
    pub seconds: f64,
}

/// Compress every block-linear site of `ck` with `compressor` under `spec`
/// on the ambient executor (`AWP_THREADS`-sized pool). See
/// [`compress_model_with`] for the fully-specified variant.
pub fn compress_model(ck: &Checkpoint, grams: &Grams,
                      compressor: &dyn LayerCompressor, spec: &CompressionSpec,
                      verify: bool) -> Result<PipelineResult> {
    compress_model_with(ck, grams, compressor, spec, verify, &Executor::new(None))
}

/// Compress every block-linear site of `ck` with `compressor` under `spec`,
/// returning the assembled checkpoint (embeddings/norms untouched — the
/// paper compresses transformer-block weights only).
///
/// `verify` re-checks the constraint set on every produced Θ before it is
/// installed (cheap; catches method/spec mismatches at the source). The
/// check runs inside each layer job, so it parallelises with the
/// compression itself.
///
/// Jobs are submitted to `exec` in the plan's LPT order; a failing site
/// aborts the run with that site's param name in the error chain.
pub fn compress_model_with(ck: &Checkpoint, grams: &Grams,
                           compressor: &dyn LayerCompressor,
                           spec: &CompressionSpec, verify: bool,
                           exec: &Executor) -> Result<PipelineResult> {
    let timer = Timer::start("pipeline");
    let plan = plan_jobs(&ck.config);
    let jobs = &plan.jobs;
    let check_spec = if verify { verification_spec(compressor, spec) } else { None };
    let run = exec.run_weighted(
        jobs.len(),
        |i| jobs[i].cost(),
        |i| jobs[i].site.param.clone(),
        |i| {
            let site = &jobs[i].site;
            let w = ck
                .matrix(&site.param)
                .with_context(|| format!("loading {}", site.param))?;
            let c = grams
                .get(site.gram, site.layer)
                .with_context(|| format!("missing Gram for {}", site.param))?;
            let result = compressor
                .compress(&w, c, spec)
                .with_context(|| format!("compressing {}", site.param))?;
            if let Some(cs) = check_spec {
                check_constraints(&result.theta, &cs)
                    .with_context(|| format!("constraint violation at {}", site.param))?;
            }
            let report = layer_report(site, &result.theta, &result.stats);
            Ok((report, result.theta.data))
        },
    )?;

    // deterministic assembly: install results in plan order, regardless of
    // the order workers finished them
    let mut out = Checkpoint {
        config: ck.config.clone(),
        tensors: ck.tensors.clone(),
        meta: ck.meta.clone(),
    };
    let mut reports = Vec::with_capacity(jobs.len());
    for (job, (report, theta)) in jobs.iter().zip(run.results) {
        out.set(&job.site.param, theta)
            .with_context(|| format!("installing {}", job.site.param))?;
        reports.push(report);
    }
    out.meta.insert("compressed_with".into(), compressor.name().to_string());
    Ok(PipelineResult {
        checkpoint: out,
        reports,
        job_stats: run.stats,
        seconds: timer.elapsed_s(),
    })
}

/// [`compress_model_with`] plus its compressed artifact and provenance.
pub struct CachedPipelineResult {
    pub result: PipelineResult,
    /// the stored (warm) or freshly built (cold) artifact — the
    /// `--pack-out` payload and the footprint table's source
    pub artifact: ModelArtifact,
    /// `true` when served from the store: zero compression jobs were
    /// submitted (`result.job_stats` is empty)
    pub warm: bool,
}

/// Artifact-aware compression: consult `store` for `key` first; on a hit,
/// decode the stored sites (bit-identical to the pipeline's output by the
/// codec contract) and assemble the checkpoint with **zero** compression
/// jobs; on a miss, run [`compress_model_with`], pack every site, and
/// persist the artifact for the next run. This is the ROADMAP
/// "incremental sweeps" item: repeated `experiment`/sweep runs over a
/// populated store recompress nothing.
///
/// A stale hit — an artifact whose site list no longer matches the model's
/// job plan — is logged and degraded to a cold run (same corrupt-file
/// discipline as the Gram cache).
pub fn compress_model_cached(ck: &Checkpoint, grams: &Grams,
                             compressor: &dyn LayerCompressor,
                             spec: &CompressionSpec, verify: bool,
                             exec: &Executor, store: &ArtifactStore,
                             key: &ArtifactKey) -> Result<CachedPipelineResult> {
    if let Some(art) = store.load(key) {
        match assemble_from_artifact(ck, &art, compressor, spec, verify) {
            Ok(result) => {
                return Ok(CachedPipelineResult { result, artifact: art, warm: true })
            }
            Err(e) => {
                eprintln!("[artifact] stored artifact for '{}' unusable \
                           ({e:#}) — recompressing", key.gram.model);
            }
        }
    }
    let result = compress_model_with(ck, grams, compressor, spec, verify, exec)?;
    let plan = plan_jobs(&ck.config);
    let mut sites = Vec::with_capacity(plan.jobs.len());
    for (job, report) in plan.jobs.iter().zip(&result.reports) {
        let theta = result.checkpoint.matrix(&job.site.param)?;
        sites.push(ArtifactSite {
            param: job.site.param.clone(),
            packed: PackedLinear::encode(&theta, spec),
            report: report.clone(),
        });
    }
    let artifact = ModelArtifact {
        model: key.gram.model.clone(),
        checkpoint: key.gram.checkpoint,
        calib: key.gram.calib,
        method: key.method.clone(),
        spec: key.spec,
        spec_desc: key.spec_desc.clone(),
        params: key.params,
        compressed_with: compressor.name().to_string(),
        sites,
    };
    store.save(key, &artifact);
    Ok(CachedPipelineResult { result, artifact, warm: false })
}

/// Warm-path assembly: decode every stored site into a copy of `ck`.
/// Site coverage and shapes are checked against the current job plan, and
/// `verify` re-runs the constraint check on the decoded Θ — the same gate
/// the cold path applies.
fn assemble_from_artifact(ck: &Checkpoint, art: &ModelArtifact,
                          compressor: &dyn LayerCompressor,
                          spec: &CompressionSpec, verify: bool)
    -> Result<PipelineResult> {
    let timer = Timer::start("artifact-assembly");
    let plan = plan_jobs(&ck.config);
    if art.sites.len() != plan.jobs.len() {
        anyhow::bail!("artifact has {} sites, plan expects {}", art.sites.len(),
                      plan.jobs.len());
    }
    let check_spec = if verify { verification_spec(compressor, spec) } else { None };
    let mut reports = Vec::with_capacity(art.sites.len());
    let mut tensors = Vec::with_capacity(art.sites.len());
    for (job, site) in plan.jobs.iter().zip(&art.sites) {
        if site.param != job.site.param
            || site.packed.rows() != job.site.d_out
            || site.packed.cols() != job.site.d_in
        {
            anyhow::bail!("artifact site {} ({}x{}) does not match plan site \
                           {} ({}x{})", site.param, site.packed.rows(),
                          site.packed.cols(), job.site.param, job.site.d_out,
                          job.site.d_in);
        }
        let theta = site.packed.decode();
        if let Some(cs) = check_spec {
            check_constraints(&theta, &cs)
                .with_context(|| format!("constraint violation decoding {}",
                                         site.param))?;
        }
        reports.push(site.report.clone());
        tensors.push((site.param.clone(), theta.data));
    }
    let mut out = ck.with_tensors(tensors)?;
    out.meta.insert("compressed_with".into(), art.compressed_with.clone());
    Ok(PipelineResult {
        checkpoint: out,
        reports,
        job_stats: Vec::new(),
        seconds: timer.elapsed_s(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::magnitude::MagnitudePrune;
    use crate::model::{sites, GramKey, ModelConfig};
    use crate::tensor::Matrix;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(), vocab: 32, d_model: 16, n_heads: 2, n_layers: 2,
            d_ff: 32, seq_len: 8, batch: 1, decode_len: 8, rope_theta: 1e4,
        }
    }

    fn synthetic_grams(cfg: &ModelConfig) -> Grams {
        let mut map = std::collections::HashMap::new();
        for l in 0..cfg.n_layers {
            for key in [GramKey::AttnIn, GramKey::AttnOutIn, GramKey::MlpIn] {
                map.insert((key, l), Matrix::randn_gram(cfg.d_model, l as u64 * 10 + key.index() as u64));
            }
            map.insert((GramKey::MlpDownIn, l), Matrix::randn_gram(cfg.d_ff, 99 + l as u64));
        }
        Grams { map, tokens: 1000 }
    }

    #[test]
    fn compresses_all_sites_and_only_sites() {
        let cfg = tiny_cfg();
        let ck = crate::trainer::init_checkpoint(&cfg, 0);
        let grams = synthetic_grams(&cfg);
        let spec = CompressionSpec::prune(0.5);
        let out = compress_model(&ck, &grams, &MagnitudePrune, &spec, true).unwrap();
        assert_eq!(out.reports.len(), sites::enumerate_sites(&cfg).len());
        assert_eq!(out.job_stats.len(), out.reports.len());
        // every block weight 50% sparse
        for s in sites::enumerate_sites(&cfg) {
            let m = out.checkpoint.matrix(&s.param).unwrap();
            assert!((m.sparsity() - 0.5).abs() < 0.05, "{}", s.param);
        }
        // embeddings untouched
        assert_eq!(out.checkpoint.get("embed").unwrap().1, ck.get("embed").unwrap().1);
        assert_eq!(out.checkpoint.meta["compressed_with"], "magnitude");
    }

    #[test]
    fn missing_gram_is_an_error() {
        let cfg = tiny_cfg();
        let ck = crate::trainer::init_checkpoint(&cfg, 0);
        let mut grams = synthetic_grams(&cfg);
        grams.map.remove(&(GramKey::MlpDownIn, 1));
        let spec = CompressionSpec::prune(0.5);
        let err = compress_model(&ck, &grams, &MagnitudePrune, &spec, false);
        assert!(err.is_err());
        // the failing site's name survives executor aggregation
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("w_down"), "{msg}");
    }

    #[test]
    fn cached_pipeline_is_incremental() {
        use crate::util::tempdir::TempDir;

        /// Stands in for "the expensive compression must not run warm".
        struct MustNotRun;
        impl LayerCompressor for MustNotRun {
            fn name(&self) -> &'static str {
                "must-not-run"
            }
            fn compress(&self, _w: &Matrix, _c: &Matrix, _s: &CompressionSpec)
                -> Result<crate::compress::traits::CompressedLayer> {
                anyhow::bail!("compression job submitted on a warm artifact store")
            }
        }

        let cfg = tiny_cfg();
        let ck = crate::trainer::init_checkpoint(&cfg, 0);
        let grams = synthetic_grams(&cfg);
        let spec = CompressionSpec::prune(0.5);
        let dir = TempDir::new("apack").unwrap();
        let store = ArtifactStore::new(Some(dir.path().to_path_buf()));
        let key = ArtifactKey::new(
            crate::coordinator::cache::GramCacheKey {
                model: "t".into(), checkpoint: ck.fingerprint(), calib: 9,
            },
            "magnitude",
            &spec,
        );
        let cold = compress_model_cached(&ck, &grams, &MagnitudePrune, &spec, true,
                                         &Executor::sequential(), &store, &key)
            .unwrap();
        assert!(!cold.warm);
        assert_eq!(cold.result.job_stats.len(),
                   sites::enumerate_sites(&cfg).len());

        let warm = compress_model_cached(&ck, &grams, &MustNotRun, &spec, true,
                                         &Executor::sequential(), &store, &key)
            .unwrap();
        assert!(warm.warm);
        assert!(warm.result.job_stats.is_empty(), "warm rerun submitted jobs");
        // bit-identical assembly cold vs warm
        for ((n1, _, d1), (_, _, d2)) in cold
            .result
            .checkpoint
            .tensors
            .iter()
            .zip(&warm.result.checkpoint.tensors)
        {
            for (x, y) in d1.iter().zip(d2) {
                assert_eq!(x.to_bits(), y.to_bits(), "{n1}");
            }
        }
        assert_eq!(warm.result.checkpoint.meta["compressed_with"], "magnitude");
        assert_eq!(store.counts().hits, 1);
    }

    #[test]
    fn reports_follow_plan_order_at_any_worker_count() {
        let cfg = tiny_cfg();
        let ck = crate::trainer::init_checkpoint(&cfg, 0);
        let grams = synthetic_grams(&cfg);
        let spec = CompressionSpec::prune(0.5);
        let plan = plan_jobs(&cfg);
        for workers in [1usize, 4] {
            let out = compress_model_with(&ck, &grams, &MagnitudePrune, &spec,
                                          false, &Executor::with_workers(workers))
                .unwrap();
            for (job, rep) in plan.jobs.iter().zip(&out.reports) {
                assert_eq!(job.site.param, rep.param, "workers={workers}");
            }
            for (i, s) in out.job_stats.iter().enumerate() {
                assert_eq!(s.index, i);
                assert_eq!(s.label, plan.jobs[i].site.param);
                assert_eq!(s.cost, plan.jobs[i].cost(), "workers={workers}");
            }
        }
    }
}
