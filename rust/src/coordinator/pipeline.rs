//! End-to-end compression pipeline: plan → compress every site on the
//! layer-job executor → assemble.
//!
//! Every `(W, C)` site is an independent PGD problem, so the jobs run on
//! [`Executor`]'s worker pool; assembly happens afterwards in plan order,
//! which keeps the reports and the produced checkpoint identical to a
//! sequential run regardless of worker count or completion order.

use anyhow::{Context, Result};

use super::calibrate::Grams;
use super::executor::{Executor, JobStats};
use super::jobs::plan_jobs;
use crate::compress::traits::{
    check_constraints, verification_spec, CompressionSpec, LayerCompressor,
};
use crate::eval::reconstruction::{layer_report, LayerReport};
use crate::model::Checkpoint;
use crate::util::Timer;

/// Output of a pipeline run.
pub struct PipelineResult {
    pub checkpoint: Checkpoint,
    pub reports: Vec<LayerReport>,
    /// per-job executor telemetry (wall-clock, worker id), in plan order
    pub job_stats: Vec<JobStats>,
    pub seconds: f64,
}

/// Compress every block-linear site of `ck` with `compressor` under `spec`
/// on the ambient executor (`AWP_THREADS`-sized pool). See
/// [`compress_model_with`] for the fully-specified variant.
pub fn compress_model(ck: &Checkpoint, grams: &Grams,
                      compressor: &dyn LayerCompressor, spec: &CompressionSpec,
                      verify: bool) -> Result<PipelineResult> {
    compress_model_with(ck, grams, compressor, spec, verify, &Executor::new(None))
}

/// Compress every block-linear site of `ck` with `compressor` under `spec`,
/// returning the assembled checkpoint (embeddings/norms untouched — the
/// paper compresses transformer-block weights only).
///
/// `verify` re-checks the constraint set on every produced Θ before it is
/// installed (cheap; catches method/spec mismatches at the source). The
/// check runs inside each layer job, so it parallelises with the
/// compression itself.
///
/// Jobs are submitted to `exec` in the plan's LPT order; a failing site
/// aborts the run with that site's param name in the error chain.
pub fn compress_model_with(ck: &Checkpoint, grams: &Grams,
                           compressor: &dyn LayerCompressor,
                           spec: &CompressionSpec, verify: bool,
                           exec: &Executor) -> Result<PipelineResult> {
    let timer = Timer::start("pipeline");
    let plan = plan_jobs(&ck.config);
    let jobs = &plan.jobs;
    let check_spec = if verify { verification_spec(compressor, spec) } else { None };
    let run = exec.run_weighted(
        jobs.len(),
        |i| jobs[i].cost(),
        |i| jobs[i].site.param.clone(),
        |i| {
            let site = &jobs[i].site;
            let w = ck
                .matrix(&site.param)
                .with_context(|| format!("loading {}", site.param))?;
            let c = grams
                .get(site.gram, site.layer)
                .with_context(|| format!("missing Gram for {}", site.param))?;
            let result = compressor
                .compress(&w, c, spec)
                .with_context(|| format!("compressing {}", site.param))?;
            if let Some(cs) = check_spec {
                check_constraints(&result.theta, &cs)
                    .with_context(|| format!("constraint violation at {}", site.param))?;
            }
            let report = layer_report(site, &result.theta, &result.stats);
            Ok((report, result.theta.data))
        },
    )?;

    // deterministic assembly: install results in plan order, regardless of
    // the order workers finished them
    let mut out = Checkpoint {
        config: ck.config.clone(),
        tensors: ck.tensors.clone(),
        meta: ck.meta.clone(),
    };
    let mut reports = Vec::with_capacity(jobs.len());
    for (job, (report, theta)) in jobs.iter().zip(run.results) {
        out.set(&job.site.param, theta)
            .with_context(|| format!("installing {}", job.site.param))?;
        reports.push(report);
    }
    out.meta.insert("compressed_with".into(), compressor.name().to_string());
    Ok(PipelineResult {
        checkpoint: out,
        reports,
        job_stats: run.stats,
        seconds: timer.elapsed_s(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::magnitude::MagnitudePrune;
    use crate::model::{sites, GramKey, ModelConfig};
    use crate::tensor::Matrix;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(), vocab: 32, d_model: 16, n_heads: 2, n_layers: 2,
            d_ff: 32, seq_len: 8, batch: 1, decode_len: 8, rope_theta: 1e4,
        }
    }

    fn synthetic_grams(cfg: &ModelConfig) -> Grams {
        let mut map = std::collections::HashMap::new();
        for l in 0..cfg.n_layers {
            for key in [GramKey::AttnIn, GramKey::AttnOutIn, GramKey::MlpIn] {
                map.insert((key, l), Matrix::randn_gram(cfg.d_model, l as u64 * 10 + key.index() as u64));
            }
            map.insert((GramKey::MlpDownIn, l), Matrix::randn_gram(cfg.d_ff, 99 + l as u64));
        }
        Grams { map, tokens: 1000 }
    }

    #[test]
    fn compresses_all_sites_and_only_sites() {
        let cfg = tiny_cfg();
        let ck = crate::trainer::init_checkpoint(&cfg, 0);
        let grams = synthetic_grams(&cfg);
        let spec = CompressionSpec::prune(0.5);
        let out = compress_model(&ck, &grams, &MagnitudePrune, &spec, true).unwrap();
        assert_eq!(out.reports.len(), sites::enumerate_sites(&cfg).len());
        assert_eq!(out.job_stats.len(), out.reports.len());
        // every block weight 50% sparse
        for s in sites::enumerate_sites(&cfg) {
            let m = out.checkpoint.matrix(&s.param).unwrap();
            assert!((m.sparsity() - 0.5).abs() < 0.05, "{}", s.param);
        }
        // embeddings untouched
        assert_eq!(out.checkpoint.get("embed").unwrap().1, ck.get("embed").unwrap().1);
        assert_eq!(out.checkpoint.meta["compressed_with"], "magnitude");
    }

    #[test]
    fn missing_gram_is_an_error() {
        let cfg = tiny_cfg();
        let ck = crate::trainer::init_checkpoint(&cfg, 0);
        let mut grams = synthetic_grams(&cfg);
        grams.map.remove(&(GramKey::MlpDownIn, 1));
        let spec = CompressionSpec::prune(0.5);
        let err = compress_model(&ck, &grams, &MagnitudePrune, &spec, false);
        assert!(err.is_err());
        // the failing site's name survives executor aggregation
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("w_down"), "{msg}");
    }

    #[test]
    fn reports_follow_plan_order_at_any_worker_count() {
        let cfg = tiny_cfg();
        let ck = crate::trainer::init_checkpoint(&cfg, 0);
        let grams = synthetic_grams(&cfg);
        let spec = CompressionSpec::prune(0.5);
        let plan = plan_jobs(&cfg);
        for workers in [1usize, 4] {
            let out = compress_model_with(&ck, &grams, &MagnitudePrune, &spec,
                                          false, &Executor::with_workers(workers))
                .unwrap();
            for (job, rep) in plan.jobs.iter().zip(&out.reports) {
                assert_eq!(job.site.param, rep.param, "workers={workers}");
            }
            for (i, s) in out.job_stats.iter().enumerate() {
                assert_eq!(s.index, i);
                assert_eq!(s.label, plan.jobs[i].site.param);
                assert_eq!(s.cost, plan.jobs[i].cost(), "workers={workers}");
            }
        }
    }
}
