//! Calibration: estimate each site's input-activation Gram `C = X Xᵀ / n`.
//!
//! Mirrors the paper's §4.1 protocol (a small fixed calibration sample from
//! the training distribution): the AOT `calib_capture` program returns the
//! per-site Gram *sums* for one batch; the coordinator accumulates across
//! batches in f64 and normalises by the total token count.

use std::collections::HashMap;

use anyhow::{ensure, Result};

use crate::data::Batch;
use crate::eval::perplexity::checkpoint_args;
use crate::model::{Checkpoint, GramKey};
use crate::runtime::{HostTensor, Manifest, RuntimeHandle};

/// Per-site calibration Grams: `(gram kind, layer) → C`.
pub struct Grams {
    pub map: HashMap<(GramKey, usize), crate::tensor::Matrix>,
    pub tokens: usize,
}

impl Grams {
    pub fn get(&self, key: GramKey, layer: usize) -> Option<&crate::tensor::Matrix> {
        self.map.get(&(key, layer))
    }
}

const GRAM_ORDER: [GramKey; 4] = GramKey::ALL;

/// Deterministic runtime-free Grams for every site of `cfg` — the
/// calibration provider behind `repro … --synthetic` (CI runners without
/// AOT artifacts) and the cache/pipeline tests. Seeded per `(model name,
/// gram kind, layer)` so distinct models/sites get distinct-but-stable
/// activation statistics with the usual log-normal outlier structure.
pub fn synthetic_grams(cfg: &crate::model::ModelConfig, seed: u64) -> Grams {
    let mut map = HashMap::new();
    let name_salt = crate::util::hash::fnv64(cfg.name.as_bytes());
    for layer in 0..cfg.n_layers {
        for key in GramKey::ALL {
            let dim = match key {
                GramKey::MlpDownIn => cfg.d_ff,
                _ => cfg.d_model,
            };
            let s = seed ^ name_salt ^ (((layer as u64) << 8) | key.index() as u64);
            map.insert((key, layer), crate::tensor::Matrix::randn_gram(dim, s));
        }
    }
    Grams { map, tokens: cfg.batch * cfg.seq_len }
}

/// Run `calib_capture` over `batches` and accumulate the normalised Grams.
pub fn calibrate(handle: &RuntimeHandle, manifest: &Manifest, model: &str,
                 ck: &Checkpoint, batches: &[Batch]) -> Result<Grams> {
    ensure!(!batches.is_empty(), "need at least one calibration batch");
    let entry = manifest.model(model)?;
    let path = manifest.model_program_path(model, "calib_capture")?;
    let params = checkpoint_args(ck)?;
    let n_layers = entry.config.n_layers;

    // f64 accumulators keyed like the output stacks
    let mut acc: HashMap<(GramKey, usize), Vec<f64>> = HashMap::new();
    let mut dims: HashMap<GramKey, usize> = HashMap::new();
    let mut total_tokens = 0.0f64;

    for batch in batches {
        let mut args = params.clone();
        args.push(HostTensor::vec_i32(batch.tokens.clone(),
                                      vec![batch.batch, batch.seq]));
        let out = handle.execute("calib_capture", path.clone(), args)?;
        ensure!(out.len() == 5, "calib_capture returned {} outputs", out.len());
        total_tokens += out[4].scalar()?;
        for (gi, key) in GRAM_ORDER.iter().enumerate() {
            let stack = out[gi].to_matrix_stack()?;
            ensure!(stack.len() == n_layers);
            dims.insert(*key, stack[0].rows);
            for (layer, m) in stack.into_iter().enumerate() {
                let slot = acc
                    .entry((*key, layer))
                    .or_insert_with(|| vec![0.0; m.data.len()]);
                for (a, &v) in slot.iter_mut().zip(&m.data) {
                    *a += v as f64;
                }
            }
        }
    }

    let mut map = HashMap::new();
    for ((key, layer), sum) in acc {
        let d = dims[&key];
        let data: Vec<f32> = sum.iter().map(|&v| (v / total_tokens) as f32).collect();
        map.insert((key, layer), crate::tensor::Matrix::from_vec(d, d, data));
    }
    Ok(Grams { map, tokens: total_tokens as usize })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_grams_cover_every_site_deterministically() {
        let cfg = crate::model::ModelConfig {
            name: "t".into(), vocab: 32, d_model: 16, n_heads: 2, n_layers: 2,
            d_ff: 32, seq_len: 8, batch: 1, decode_len: 8, rope_theta: 1e4,
        };
        let a = synthetic_grams(&cfg, 7);
        assert_eq!(a.map.len(), 4 * cfg.n_layers);
        for site in crate::model::sites::enumerate_sites(&cfg) {
            let c = a.get(site.gram, site.layer).unwrap();
            assert_eq!(c.rows, site.d_in, "{}", site.param);
        }
        // bit-stable across calls; sensitive to seed and model name
        let b = synthetic_grams(&cfg, 7);
        assert_eq!(a.get(GramKey::AttnIn, 0).unwrap().data,
                   b.get(GramKey::AttnIn, 0).unwrap().data);
        let c = synthetic_grams(&cfg, 8);
        assert_ne!(a.get(GramKey::AttnIn, 0).unwrap().data,
                   c.get(GramKey::AttnIn, 0).unwrap().data);
        let mut cfg2 = cfg.clone();
        cfg2.name = "u".into();
        let d = synthetic_grams(&cfg2, 7);
        assert_ne!(a.get(GramKey::AttnIn, 0).unwrap().data,
                   d.get(GramKey::AttnIn, 0).unwrap().data);
    }

    #[test]
    fn gram_order_matches_capture_output_convention() {
        // python/compile/model.py::make_calib_capture returns
        // (attn_in, attn_out_in, mlp_in, mlp_down_in, count)
        assert_eq!(GRAM_ORDER[0].index(), 0);
        assert_eq!(GRAM_ORDER[3].index(), 3);
    }
}
