//! The layer-job execution engine — the worker pool the scheduler in
//! [`super::jobs`] was designed for.
//!
//! `plan_jobs` emits jobs in LPT order (longest first); this module runs
//! them on a dynamic pool: an atomic cursor over the job list hands the
//! next job to whichever worker frees up first, so the LPT order turns
//! into the classic makespan heuristic. Three guarantees the pipeline and
//! the experiment harness rely on:
//!
//! * **Determinism** — results are reassembled in submission (plan) order,
//!   so reports and checkpoint assembly are identical to a sequential run
//!   regardless of completion order or worker count.
//! * **Fail-fast with attribution** — the first failure flips an abort
//!   flag (no new jobs start; in-flight jobs finish), and the error
//!   surfaced is the *lowest-index* failure, wrapped with that job's
//!   label, so "which site failed" survives the parallel run.
//! * **Bounded threads** — outer workers × inner GEMM threads ≤ the
//!   machine budget (`AWP_THREADS` or available parallelism): each worker
//!   runs its job inside [`with_thread_budget`], shrinking the row-panel
//!   parallelism of `tensor::ops` as the worker count grows instead of
//!   oversubscribing cores. Budgets nest, so an executor built *inside* a
//!   budgeted worker (e.g. a per-cell `compress_model` under a table
//!   sweep) sizes itself from the enclosing budget automatically.
//!
//! See `EXECUTOR_DESIGN.md` for the design note.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::obs::{metrics, trace};
use crate::util::parallel::{num_threads, with_thread_budget};
use crate::util::Timer;

/// Per-job wall-clock telemetry, reported in submission order.
#[derive(Clone, Debug)]
pub struct JobStats {
    /// submission index (== position in the `JobPlan` / cell list)
    pub index: usize,
    /// human-readable job label (site param name, table-cell name, …)
    pub label: String,
    /// wall-clock seconds for this job alone
    pub seconds: f64,
    /// which pool worker ran it (0 for the sequential fast path)
    pub worker: usize,
    /// the job's FLOP-ish cost weight ([`super::jobs::Job::cost`]; 1 for
    /// unweighted runs) — feeds the cost-weighted progress line and
    /// `report::timing_table_weighted`
    pub cost: u64,
}

/// Everything a pool run produces: per-job results in submission order,
/// per-job telemetry, and the wall-clock of the whole run.
pub struct ExecReport<T> {
    pub results: Vec<T>,
    pub stats: Vec<JobStats>,
    pub seconds: f64,
}

/// A sized worker pool: `workers` outer job slots, each allowed
/// `inner_threads` threads of nested parallelism.
#[derive(Clone, Copy, Debug)]
pub struct Executor {
    workers: usize,
    inner_threads: usize,
    /// emit a cost-weighted progress/ETA line as jobs complete (CLI runs;
    /// off by default so library/test use stays quiet)
    progress: bool,
}

impl Executor {
    /// Build from an explicit `--jobs` request (`Some(n)`) or the ambient
    /// thread budget (`None` ⇒ one worker per budget thread). Workers are
    /// clamped to the budget — `--jobs 8` under `AWP_THREADS=2` gets 2
    /// workers, keeping outer × inner ≤ the budget instead of
    /// oversubscribing. The inner budget is what's left after the split:
    /// `total / workers`, at least 1.
    pub fn new(jobs: Option<usize>) -> Self {
        let total = num_threads().max(1);
        let workers = jobs.unwrap_or(total).clamp(1, total);
        Executor { workers, inner_threads: (total / workers).max(1), progress: false }
    }

    /// `n` outer workers (clamped to the ambient budget, which also funds
    /// the inner split) — the `--jobs N` entry point.
    pub fn with_workers(n: usize) -> Self {
        Executor::new(Some(n))
    }

    /// One worker, full inner budget: byte-for-byte the sequential path.
    pub fn sequential() -> Self {
        Executor::new(Some(1))
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn inner_threads(&self) -> usize {
        self.inner_threads
    }

    /// Same pool, with the cost-weighted progress/ETA line switched on/off
    /// (consumed by `run_weighted`; the CLI enables it, tests leave it off).
    pub fn with_progress(mut self, on: bool) -> Self {
        self.progress = on;
        self
    }

    pub fn progress(&self) -> bool {
        self.progress
    }

    /// Run `job(0..n)` on the pool. `label(i)` names job `i` for telemetry
    /// and error attribution. Results come back in index order; the first
    /// error (lowest index among failures) aborts the run.
    ///
    /// When `n` is smaller than the pool, the idle workers' share of the
    /// thread budget is re-granted to the jobs that do run (a 1-cell run
    /// on an 8-thread default executor gets all 8 threads for its GEMMs,
    /// not `8 / 8 = 1`).
    pub fn run<T, F, L>(&self, n: usize, label: L, job: F) -> Result<ExecReport<T>>
    where
        T: Send,
        F: Fn(usize) -> Result<T> + Sync,
        L: Fn(usize) -> String + Sync,
    {
        self.run_weighted(n, |_| 1, label, job)
    }

    /// [`Executor::run`] with a per-job cost weight (`Job::cost`-style
    /// FLOP estimates). Costs drive the progress/ETA line — "fraction of
    /// total *cost* completed" tracks wall-clock far better than job
    /// counts when job sizes vary (one `w_down` site outweighs a whole
    /// attention block) — and are recorded in each job's [`JobStats`].
    pub fn run_weighted<T, F, L, C>(&self, n: usize, cost: C, label: L, job: F)
        -> Result<ExecReport<T>>
    where
        T: Send,
        F: Fn(usize) -> Result<T> + Sync,
        L: Fn(usize) -> String + Sync,
        C: Fn(usize) -> u64 + Sync,
    {
        let timer = Timer::start("executor");
        let total_cost: u64 = (0..n).map(|i| cost(i).max(1)).sum();
        let workers = self.workers.min(n.max(1));
        // re-split this executor's total budget over the workers actually used
        let inner = ((self.workers * self.inner_threads) / workers).max(1);
        if workers <= 1 {
            return self.run_sequential(n, inner, total_cost, &cost, &label, &job,
                                       timer);
        }

        let cursor = AtomicUsize::new(0);
        let abort = AtomicBool::new(false);
        let done_cost = AtomicU64::new(0);
        let done_jobs = AtomicUsize::new(0);
        let done: Mutex<Vec<(usize, T, JobStats)>> = Mutex::new(Vec::with_capacity(n));
        let failures: Mutex<Vec<(usize, anyhow::Error)>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for wid in 0..workers {
                let (cursor, abort) = (&cursor, &abort);
                let (done, failures) = (&done, &failures);
                let (done_cost, done_jobs) = (&done_cost, &done_jobs);
                let (job, label, cost) = (&job, &label, &cost);
                let timer = &timer;
                scope.spawn(move || {
                    with_thread_budget(inner, || loop {
                        if abort.load(Ordering::Relaxed) {
                            break;
                        }
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let t = Timer::start("job");
                        let result = {
                            let mut span = trace::span("executor_job", "coord");
                            if trace::enabled() {
                                span.set_arg("label", label(i));
                            }
                            job(i)
                        };
                        metrics::REGISTRY.executor_jobs.inc();
                        metrics::REGISTRY
                            .executor_job_seconds
                            .observe(t.elapsed_s());
                        match result {
                            Ok(v) => {
                                let c = cost(i).max(1);
                                let stats = JobStats {
                                    index: i,
                                    label: label(i),
                                    seconds: t.elapsed_s(),
                                    worker: wid,
                                    cost: c,
                                };
                                done.lock().unwrap().push((i, v, stats));
                                let dc = done_cost.fetch_add(c, Ordering::Relaxed) + c;
                                let dj = done_jobs.fetch_add(1, Ordering::Relaxed) + 1;
                                if self.progress {
                                    eprintln!("{}", crate::report::progress_line(
                                        dj, n, dc, total_cost, timer.elapsed_s()));
                                }
                            }
                            Err(e) => {
                                abort.store(true, Ordering::Relaxed);
                                failures.lock().unwrap().push((i, e));
                            }
                        }
                    });
                });
            }
        });

        let completed = done.into_inner().unwrap();
        let mut failures = failures.into_inner().unwrap();
        if !failures.is_empty() {
            // deterministic attribution: surface the lowest-index failure
            failures.sort_by_key(|(i, _)| *i);
            let n_failed = failures.len();
            let (i, err) = failures.remove(0);
            return Err(err.context(format!(
                "job {i} ({}) failed; aborted with {} of {n} jobs done \
                 ({n_failed} failed)",
                label(i),
                completed.len(),
            )));
        }
        debug_assert_eq!(completed.len(), n, "pool lost a job result");
        let mut completed = completed;
        completed.sort_unstable_by_key(|(i, _, _)| *i);
        let mut results = Vec::with_capacity(n);
        let mut stats = Vec::with_capacity(n);
        for (_, v, s) in completed {
            results.push(v);
            stats.push(s);
        }
        Ok(ExecReport { results, stats, seconds: timer.elapsed_s() })
    }

    /// Single-worker path: same loop, same budget discipline, no threads —
    /// this is the bit-identical reference the parallel path is tested
    /// against (and what `--jobs 1` / `AWP_THREADS=1` select).
    fn run_sequential<T, F, L, C>(&self, n: usize, inner: usize, total_cost: u64,
                                  cost: &C, label: &L, job: &F, timer: Timer)
        -> Result<ExecReport<T>>
    where
        F: Fn(usize) -> Result<T>,
        L: Fn(usize) -> String,
        C: Fn(usize) -> u64,
    {
        let mut results = Vec::with_capacity(n);
        let mut stats = Vec::with_capacity(n);
        let mut done_cost = 0u64;
        for i in 0..n {
            let t = Timer::start("job");
            let result = {
                let mut span = trace::span("executor_job", "coord");
                if trace::enabled() {
                    span.set_arg("label", label(i));
                }
                with_thread_budget(inner, || job(i))
            };
            metrics::REGISTRY.executor_jobs.inc();
            metrics::REGISTRY.executor_job_seconds.observe(t.elapsed_s());
            match result {
                Ok(v) => {
                    let c = cost(i).max(1);
                    results.push(v);
                    stats.push(JobStats {
                        index: i,
                        label: label(i),
                        seconds: t.elapsed_s(),
                        worker: 0,
                        cost: c,
                    });
                    done_cost += c;
                    if self.progress {
                        eprintln!("{}", crate::report::progress_line(
                            i + 1, n, done_cost, total_cost, timer.elapsed_s()));
                    }
                }
                Err(e) => {
                    return Err(e.context(format!(
                        "job {i} ({}) failed; aborted with {i} of {n} jobs \
                         done (1 failed)",
                        label(i),
                    )));
                }
            }
        }
        Ok(ExecReport { results, stats, seconds: timer.elapsed_s() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::bail;

    fn label(i: usize) -> String {
        format!("job-{i}")
    }

    #[test]
    fn results_come_back_in_index_order() {
        let exec = Executor::with_workers(4);
        // jittered job durations so completion order ≠ submission order
        let rep = exec
            .run(33, label, |i| {
                std::thread::sleep(std::time::Duration::from_micros(
                    ((i * 7919) % 5) as u64 * 200,
                ));
                Ok(i * i)
            })
            .unwrap();
        assert_eq!(rep.results.len(), 33);
        for (i, v) in rep.results.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
        for (i, s) in rep.stats.iter().enumerate() {
            assert_eq!(s.index, i);
            assert_eq!(s.label, format!("job-{i}"));
            assert!(s.seconds >= 0.0);
        }
    }

    #[test]
    fn sequential_and_parallel_agree() {
        let f = |i: usize| -> Result<usize> { Ok(i + 100) };
        let a = Executor::sequential().run(20, label, f).unwrap();
        let b = Executor::with_workers(4).run(20, label, f).unwrap();
        assert_eq!(a.results, b.results);
    }

    #[test]
    fn failure_aborts_and_names_the_job() {
        let exec = Executor::with_workers(4);
        let err = exec
            .run(40, label, |i| {
                if i == 11 {
                    bail!("synthetic failure");
                }
                Ok(i)
            })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("job-11"), "{msg}");
        assert!(msg.contains("synthetic failure"), "{msg}");
    }

    #[test]
    fn sequential_failure_names_the_job_too() {
        let err = Executor::sequential()
            .run(5, label, |i| {
                if i == 3 {
                    bail!("boom");
                }
                Ok(i)
            })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("job-3"), "{msg}");
    }

    #[test]
    fn weighted_run_records_costs_in_index_order() {
        for workers in [1usize, 4] {
            let rep = Executor::with_workers(workers)
                .run_weighted(9, |i| (i as u64 + 1) * 100, label, |i| Ok(i))
                .unwrap();
            assert_eq!(rep.results, (0..9).collect::<Vec<_>>());
            for (i, s) in rep.stats.iter().enumerate() {
                assert_eq!(s.cost, (i as u64 + 1) * 100, "workers={workers}");
            }
        }
        // zero costs are clamped so the ETA denominator never vanishes
        let rep = Executor::sequential()
            .run_weighted(3, |_| 0, label, |i| Ok(i))
            .unwrap();
        assert!(rep.stats.iter().all(|s| s.cost == 1));
    }

    #[test]
    fn unweighted_run_has_unit_costs() {
        let rep = Executor::with_workers(2).run(4, label, |i| Ok(i)).unwrap();
        assert!(rep.stats.iter().all(|s| s.cost == 1));
    }

    #[test]
    fn empty_run_is_fine() {
        let rep = Executor::with_workers(4)
            .run(0, label, |_| Ok(0usize))
            .unwrap();
        assert!(rep.results.is_empty());
        assert!(rep.stats.is_empty());
    }

    #[test]
    fn budget_split_bounds_product() {
        use crate::util::parallel::with_thread_budget;
        with_thread_budget(8, || {
            for jobs in 1..=8usize {
                let e = Executor::with_workers(jobs);
                assert_eq!(e.workers(), jobs);
                assert!(e.workers() * e.inner_threads() <= 8,
                        "jobs={jobs} inner={}", e.inner_threads());
                assert!(e.inner_threads() >= 1);
            }
            // default: one worker per budget thread, inner collapses to 1
            let e = Executor::new(None);
            assert_eq!(e.workers(), 8);
            assert_eq!(e.inner_threads(), 1);
            // --jobs 1 keeps the whole budget for the inner GEMMs
            let e = Executor::sequential();
            assert_eq!(e.inner_threads(), 8);
            // over-asking is clamped to the budget, never oversubscribed
            let e = Executor::with_workers(16);
            assert_eq!(e.workers(), 8);
            assert_eq!(e.inner_threads(), 1);
        });
    }

    #[test]
    fn small_runs_reclaim_the_idle_workers_budget() {
        use crate::util::parallel::{current_thread_budget, with_thread_budget};
        with_thread_budget(8, || {
            let exec = Executor::new(None); // 8 workers × 1 inner
            // a single job gets the whole budget back, not 8/8 = 1
            let rep = exec
                .run(1, label, |_| Ok(current_thread_budget()))
                .unwrap();
            assert_eq!(rep.results, vec![Some(8)]);
            // two jobs split it evenly
            let rep = exec
                .run(2, label, |_| Ok(current_thread_budget()))
                .unwrap();
            assert_eq!(rep.results, vec![Some(4), Some(4)]);
        });
    }

    #[test]
    fn workers_see_the_inner_budget() {
        use crate::util::parallel::current_thread_budget;
        with_thread_budget_outer(|| {
            let exec = Executor::with_workers(2);
            let rep = exec
                .run(4, label, |_| Ok(current_thread_budget()))
                .unwrap();
            for b in rep.results {
                assert_eq!(b, Some(exec.inner_threads()));
            }
        });
    }

    fn with_thread_budget_outer(f: impl FnOnce()) {
        crate::util::parallel::with_thread_budget(4, f)
    }
}
