//! Site-job planning — the pure scheduling core of the pipeline, kept free
//! of I/O so its invariants are directly property-testable (rust/tests/):
//! every compressible site appears exactly once, its Gram key matches its
//! input distribution, jobs are deterministically ordered, and the whole
//! plan covers exactly the model's block-linear parameters.

use crate::model::{sites, LayerSite, ModelConfig};

/// One schedulable unit: compress `site` using the Gram at `gram_index`.
#[derive(Clone, Debug, PartialEq)]
pub struct Job {
    pub id: usize,
    pub site: LayerSite,
}

impl Job {
    /// FLOP-ish cost model shared by the LPT sort, progress estimation and
    /// the executor's telemetry: one PGD iteration is a `(d_out, d_in) ·
    /// (d_in, d_in)` GEMM, so cost ≈ `d_out·d_in²`.
    pub fn cost(&self) -> u64 {
        (self.site.d_out as u64) * (self.site.d_in as u64) * (self.site.d_in as u64)
    }
}

/// A full compression plan for a model.
#[derive(Clone, Debug)]
pub struct JobPlan {
    pub jobs: Vec<Job>,
}

/// Deterministic plan: sites in block order, q/k/v/o before MLP — large
/// `d_in` (MLP-down) sites scheduled *first* within each layer so the
/// longest jobs start earliest on the worker pool (classic LPT heuristic).
pub fn plan_jobs(cfg: &ModelConfig) -> JobPlan {
    let mut all = sites::enumerate_sites(cfg);
    all.sort_by_key(|s| {
        // (layer, -cost) ordering: cost ≈ d_out·d_in²
        let cost = (s.d_out as u64) * (s.d_in as u64) * (s.d_in as u64);
        (s.layer, std::cmp::Reverse(cost), s.param.clone())
    });
    JobPlan {
        jobs: all
            .into_iter()
            .enumerate()
            .map(|(id, site)| Job { id, site })
            .collect(),
    }
}

impl JobPlan {
    /// Total FLOP-ish cost (for progress estimation): Σ [`Job::cost`].
    pub fn total_cost(&self) -> u64 {
        self.jobs.iter().map(Job::cost).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(), vocab: 256, d_model: 128, n_heads: 4, n_layers: 3,
            d_ff: 512, seq_len: 64, batch: 2, decode_len: 32, rope_theta: 1e4,
        }
    }

    #[test]
    fn covers_every_site_once() {
        let plan = plan_jobs(&cfg());
        assert_eq!(plan.jobs.len(), 18);
        let mut params: Vec<&str> =
            plan.jobs.iter().map(|j| j.site.param.as_str()).collect();
        params.sort();
        params.dedup();
        assert_eq!(params.len(), 18, "duplicate site in plan");
    }

    #[test]
    fn deterministic() {
        let a = plan_jobs(&cfg());
        let b = plan_jobs(&cfg());
        assert_eq!(a.jobs, b.jobs);
    }

    #[test]
    fn lpt_within_layer() {
        let plan = plan_jobs(&cfg());
        // first job of each layer must be the most expensive site (mlp_down:
        // d_in=512 ⇒ cost 128·512² > w_up 512·128² > attn 128·128²)
        for l in 0..3 {
            let first = plan.jobs.iter().find(|j| j.site.layer == l).unwrap();
            assert!(first.site.param.ends_with("w_down"), "{}", first.site.param);
        }
    }

    #[test]
    fn cost_is_non_increasing_within_layer() {
        // the executor's atomic cursor walks the plan in order, so LPT only
        // works if Job::cost agrees with the sort key used by plan_jobs
        let plan = plan_jobs(&cfg());
        for pair in plan.jobs.windows(2) {
            if pair[0].site.layer == pair[1].site.layer {
                assert!(pair[0].cost() >= pair[1].cost(),
                        "{} before {}", pair[0].site.param, pair[1].site.param);
            }
        }
    }

    #[test]
    fn ids_are_sequential() {
        let plan = plan_jobs(&cfg());
        for (i, j) in plan.jobs.iter().enumerate() {
            assert_eq!(j.id, i);
        }
        assert!(plan.total_cost() > 0);
    }
}
