//! The Layer-3 coordinator — the system piece of this reproduction.
//!
//! Orchestrates the paper's full layer-wise post-training compression flow:
//!
//! ```text
//!  checkpoint ──► calibrate ──► schedule layer jobs ──► assemble ──► eval
//!                 (Gram C per   (one job per linear     (compressed
//!                  input site)   site; method = AWP      checkpoint +
//!                                or any baseline)        per-layer report)
//! ```
//!
//! * `calibrate` — drives the AOT `calib_capture` program over the fixed
//!   calibration sample and accumulates `C = XXᵀ/n` per site.
//! * `jobs` — the site-job scheduler (pure logic, property-tested: every
//!   site exactly once, Gram routing correct, deterministic order).
//! * `executor` — the layer-job worker pool the scheduler feeds: dynamic
//!   (atomic-cursor) dispatch over the LPT order, per-job telemetry,
//!   fail-fast error attribution, deterministic output order, and the
//!   outer-workers × inner-GEMM-threads budget split.
//! * `methods` — name → compressor registry covering the paper's full
//!   method matrix.
//! * `pipeline` — end-to-end orchestration + assembly into a new checkpoint.
//! * `experiments` — regenerates every table/figure of the paper's §4
//!   (table sweeps submit their cells through the executor).

pub mod calibrate;
pub mod executor;
pub mod experiments;
pub mod jobs;
pub mod methods;
pub mod pipeline;

pub use experiments::ExperimentCtx;

pub use calibrate::{calibrate, Grams};
pub use executor::{ExecReport, Executor, JobStats};
pub use jobs::{plan_jobs, Job, JobPlan};
pub use methods::{make_compressor, Method};
pub use pipeline::{compress_model, compress_model_with, PipelineResult};
