//! The Layer-3 coordinator — the system piece of this reproduction.
//!
//! Orchestrates the paper's full layer-wise post-training compression flow:
//!
//! ```text
//!  checkpoint ──► calibrate ──► schedule layer jobs ──► assemble ──► eval
//!                 (Gram C per   (one job per linear     (compressed
//!                  input site)   site; method = AWP      checkpoint +
//!                                or any baseline)        per-layer report)
//! ```
//!
//! * `calibrate` — drives the AOT `calib_capture` program over the fixed
//!   calibration sample and accumulates `C = XXᵀ/n` per site.
//! * `cache` — the calibration-artifact cache: persists Grams to disk
//!   keyed by (model, checkpoint fingerprint, calibration config), with an
//!   `Arc`-shared in-memory layer so concurrent jobs never recompute or
//!   re-load a Gram twice.
//! * `jobs` — the site-job scheduler (pure logic, property-tested: every
//!   site exactly once, Gram routing correct, deterministic order).
//! * `executor` — the layer-job worker pool the scheduler feeds: dynamic
//!   (atomic-cursor) dispatch over the LPT order, per-job telemetry with
//!   cost weights (progress/ETA), fail-fast error attribution,
//!   deterministic output order, and the outer-workers ×
//!   inner-GEMM-threads budget split.
//! * `methods` — name → compressor registry covering the paper's full
//!   method matrix.
//! * `pipeline` — end-to-end orchestration + assembly into a new
//!   checkpoint; `compress_model_cached` consults the compressed-artifact
//!   store (`crate::artifact`) first, so warm reruns assemble from packed
//!   sites and submit zero compression jobs.
//! * `sweep` — cross-model sweep scheduling: per-model preparation jobs
//!   plus every table's cells on one executor pool, plan-order
//!   deterministic assembly.
//! * `experiments` — regenerates every table/figure of the paper's §4
//!   (all sweeps schedule through `sweep` on the shared executor).

pub mod cache;
pub mod calibrate;
pub mod executor;
pub mod experiments;
pub mod jobs;
pub mod methods;
pub mod pipeline;
pub mod sweep;

pub use experiments::ExperimentCtx;

pub use cache::{CacheCounts, CalibSpec, GramCache, GramCacheKey, KeyedOnce};
pub use calibrate::{calibrate, synthetic_grams, Grams};
pub use executor::{ExecReport, Executor, JobStats};
pub use jobs::{plan_jobs, Job, JobPlan};
pub use methods::{make_compressor, Method};
pub use pipeline::{
    compress_model, compress_model_cached, compress_model_with,
    CachedPipelineResult, PipelineResult,
};
pub use sweep::{run_tables, sweep_cells, sweep_models, CellRef, TableSpec};
