//! Calibration-artifact cache — persist and share activation Grams.
//!
//! The calibration protocol is deterministic (fixed corpus, fixed seed,
//! fixed batch config), so a model's Grams are a pure function of
//! `(checkpoint, calibration config)`. Recomputing them on every run
//! re-executes `calib_capture` over the whole calibration set through the
//! PJRT actor — the single most serialising step of a sweep. This module
//! removes that waste with two layers:
//!
//! * **memory** — an `Arc`-shared, per-key once-cell map: concurrent
//!   experiment cells (and cross-model sweep jobs) asking for the same
//!   model's Grams block only on that key's slot, never on each other, and
//!   the Grams are computed exactly once per process;
//! * **disk** — an `AWPGRAM1` container under `--cache-dir`, keyed by a
//!   content hash of (model id, checkpoint fingerprint, calibration
//!   config); a warm run loads Grams without a single `calib_capture`
//!   execution. Corrupt or stale files are discarded and recomputed.
//!
//! ### Key schema
//!
//! ```text
//! key = fnv64(model, checkpoint.fingerprint(), CalibSpec.fingerprint())
//!   CalibSpec = corpus {bytes, seed, vocab_words, zipf_s, branching,
//!               markov_strength} + calib {batches, seed} + model {batch,
//!               seq} + provider ("calib_capture" | "synthetic")
//! file  = <model>-<key:016x>.grams
//!   magic "AWPGRAM1" | u64 header_len | header JSON | f32 LE gram data
//!   header: {version, model, checkpoint, calib, tokens,
//!            entries: [{gram, layer, dim, offset}, ...]}
//! ```
//!
//! Within a file, entries are indexed by `(GramKey, layer)` — the same
//! granularity `Grams::get` serves the pipeline at. Loads re-validate the
//! identity fields against the requested key, so an FNV collision (or a
//! hand-copied file) degrades to a recompute, never to wrong Grams.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::calibrate::Grams;
use crate::config::RunConfig;
use crate::model::{GramKey, ModelConfig};
use crate::tensor::Matrix;
use crate::util::{Fnv64, Json};

const MAGIC: &[u8; 8] = b"AWPGRAM1";
const VERSION: usize = 1;

// ---------------------------------------------------------------------------
// key schema

/// Everything the calibration pass depends on besides the checkpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct CalibSpec {
    pub corpus_bytes: usize,
    pub corpus_seed: u64,
    pub vocab_words: usize,
    pub zipf_s: f64,
    pub branching: usize,
    pub markov_strength: f64,
    pub calib_batches: usize,
    pub calib_seed: u64,
    pub batch: usize,
    pub seq: usize,
    /// which provider produced the Grams (`calib_capture` vs `synthetic`)
    /// — keeps runtime-free synthetic Grams from ever colliding with real
    /// calibration artifacts in a shared cache dir
    pub provider: String,
}

impl CalibSpec {
    /// The calibration configuration of a run, for `model`'s batch shape.
    pub fn from_run(cfg: &RunConfig, mc: &ModelConfig, provider: &str) -> CalibSpec {
        CalibSpec {
            corpus_bytes: cfg.corpus.total_bytes,
            corpus_seed: cfg.corpus.seed,
            vocab_words: cfg.corpus.vocab_words,
            zipf_s: cfg.corpus.zipf_s,
            branching: cfg.corpus.branching,
            markov_strength: cfg.corpus.markov_strength,
            calib_batches: cfg.calib_batches,
            calib_seed: cfg.calib_seed(),
            batch: mc.batch,
            seq: mc.seq_len,
            provider: provider.to_string(),
        }
    }

    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_usize(self.corpus_bytes);
        h.write_u64(self.corpus_seed);
        h.write_usize(self.vocab_words);
        h.write_f64(self.zipf_s);
        h.write_usize(self.branching);
        h.write_f64(self.markov_strength);
        h.write_usize(self.calib_batches);
        h.write_u64(self.calib_seed);
        h.write_usize(self.batch);
        h.write_usize(self.seq);
        h.write_str(&self.provider);
        h.finish()
    }
}

/// Full identity of one model's calibration Grams.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct GramCacheKey {
    pub model: String,
    /// [`crate::model::Checkpoint::fingerprint`]
    pub checkpoint: u64,
    /// [`CalibSpec::fingerprint`]
    pub calib: u64,
}

impl GramCacheKey {
    pub fn hash(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_str(&self.model);
        h.write_u64(self.checkpoint);
        h.write_u64(self.calib);
        h.finish()
    }

    /// Cache file name: `<model>-<hash:016x>.grams`.
    pub fn file_name(&self) -> String {
        let safe: String = self
            .model
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .collect();
        format!("{safe}-{:016x}.grams", self.hash())
    }
}

// ---------------------------------------------------------------------------
// disk codec

/// Serialise `grams` under `key` into `dir` (created if absent). Writes to
/// a unique temp file first and renames, so concurrent processes warming
/// the same cache never observe a half-written artifact.
pub fn store_grams(dir: &Path, key: &GramCacheKey, grams: &Grams) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating cache dir {dir:?}"))?;
    let path = dir.join(key.file_name());

    // deterministic entry order: (gram index, layer)
    let mut keys: Vec<(GramKey, usize)> = grams.map.keys().copied().collect();
    keys.sort_by_key(|(g, l)| (g.index(), *l));

    let mut entries = Vec::with_capacity(keys.len());
    let mut offset = 0usize;
    for (g, l) in &keys {
        let m = &grams.map[&(*g, *l)];
        if m.rows != m.cols {
            bail!("gram {:?}[{l}] is not square: {}x{}", g, m.rows, m.cols);
        }
        entries.push(Json::obj(vec![
            ("gram", Json::Num(g.index() as f64)),
            ("layer", Json::Num(*l as f64)),
            ("dim", Json::Num(m.rows as f64)),
            ("offset", Json::Num(offset as f64)),
        ]));
        offset += m.data.len();
    }
    let header = Json::obj(vec![
        ("version", Json::Num(VERSION as f64)),
        ("model", Json::Str(key.model.clone())),
        ("checkpoint", Json::Str(format!("{:016x}", key.checkpoint))),
        ("calib", Json::Str(format!("{:016x}", key.calib))),
        ("tokens", Json::Num(grams.tokens as f64)),
        ("entries", Json::Arr(entries)),
    ]);
    let hjson = header.to_string().into_bytes();

    let tmp = dir.join(format!("{}.tmp.{}", key.file_name(), std::process::id()));
    {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&(hjson.len() as u64).to_le_bytes())?;
        f.write_all(&hjson)?;
        for (g, l) in &keys {
            let data = &grams.map[&(*g, *l)].data;
            let mut buf = Vec::with_capacity(data.len() * 4);
            for v in data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            f.write_all(&buf)?;
        }
    }
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("installing cache file {path:?}"))?;
    Ok(path)
}

/// Load the Grams for `key` from `dir`. `Ok(None)` when no file exists;
/// `Err` when the file exists but is corrupt, truncated, or belongs to a
/// different identity (hash collision / stale copy) — callers treat both
/// as a miss, but the `Err` is logged so disk rot is visible.
pub fn load_grams(dir: &Path, key: &GramCacheKey) -> Result<Option<Grams>> {
    let path = dir.join(key.file_name());
    let mut f = match std::fs::File::open(&path) {
        Ok(f) => std::io::BufReader::new(f),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e).with_context(|| format!("open {path:?}")),
    };
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic).context("reading magic")?;
    if &magic != MAGIC {
        bail!("{path:?}: not an AWP gram cache file (bad magic)");
    }
    let mut lenb = [0u8; 8];
    f.read_exact(&mut lenb).context("reading header length")?;
    let hlen = u64::from_le_bytes(lenb) as usize;
    if hlen > 64 << 20 {
        bail!("{path:?}: implausible header length {hlen}");
    }
    let mut hjson = vec![0u8; hlen];
    f.read_exact(&mut hjson).context("reading header")?;
    let header = Json::parse(std::str::from_utf8(&hjson)?)?;
    if header.expect("version")?.as_usize()? != VERSION {
        bail!("{path:?}: unsupported cache version");
    }
    // identity check: never serve Grams across models/checkpoints/configs
    let model = header.expect("model")?.as_str()?;
    let ck = header.expect("checkpoint")?.as_str()?;
    let calib = header.expect("calib")?.as_str()?;
    if model != key.model
        || ck != format!("{:016x}", key.checkpoint)
        || calib != format!("{:016x}", key.calib)
    {
        bail!("{path:?}: cache identity mismatch (stale file or hash collision)");
    }
    let tokens = header.expect("tokens")?.as_usize()?;
    let mut rest = Vec::new();
    f.read_to_end(&mut rest)?;
    let mut map = HashMap::new();
    for e in header.expect("entries")?.as_arr()? {
        let gi = e.expect("gram")?.as_usize()?;
        let gram = GramKey::from_index(gi)
            .with_context(|| format!("{path:?}: bad gram index {gi}"))?;
        let layer = e.expect("layer")?.as_usize()?;
        let dim = e.expect("dim")?.as_usize()?;
        let offset = e.expect("offset")?.as_usize()?;
        // header fields are untrusted: checked arithmetic so a corrupt file
        // degrades to the Err-and-recompute path, never a panic or a
        // wrapped-past-the-bounds-check read
        if dim == 0 || dim > 1 << 20 {
            bail!("{path:?}: implausible gram dim {dim}");
        }
        let len = dim
            .checked_mul(dim)
            .with_context(|| format!("{path:?}: dim overflow"))?;
        let (start, end) = offset
            .checked_mul(4)
            .and_then(|s| len.checked_mul(4).and_then(|l| s.checked_add(l))
                             .map(|e| (s, e)))
            .with_context(|| format!("{path:?}: offset overflow"))?;
        if end > rest.len() {
            bail!("{path:?}: truncated ({:?}[{layer}] needs {end} bytes)", gram);
        }
        let data: Vec<f32> = rest[start..end]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        if map.insert((gram, layer), Matrix::from_vec(dim, dim, data)).is_some() {
            bail!("{path:?}: duplicate entry {:?}[{layer}]", gram);
        }
    }
    Ok(Some(Grams { map, tokens }))
}

// ---------------------------------------------------------------------------
// keyed once-cells (the Arc-shared memory layer)

/// A concurrent per-key once-map: `get_or_try_init` runs the initialiser
/// exactly once per key; callers racing on the *same* key block on that
/// key's slot only, callers on different keys proceed independently. A
/// failed initialisation leaves the slot empty, so the next caller retries.
pub struct KeyedOnce<K, V> {
    slots: Mutex<HashMap<K, Arc<Mutex<Option<V>>>>>,
}

impl<K: Eq + std::hash::Hash + Clone, V: Clone> KeyedOnce<K, V> {
    pub fn new() -> Self {
        KeyedOnce { slots: Mutex::new(HashMap::new()) }
    }

    pub fn get_or_try_init(&self, key: &K, init: impl FnOnce() -> Result<V>)
        -> Result<V> {
        let slot = {
            let mut slots = self.slots.lock().unwrap();
            slots
                .entry(key.clone())
                .or_insert_with(|| Arc::new(Mutex::new(None)))
                .clone()
        };
        let mut guard = slot.lock().unwrap();
        if let Some(v) = guard.as_ref() {
            return Ok(v.clone());
        }
        let v = init()?;
        *guard = Some(v.clone());
        Ok(v)
    }

    /// The cached value, if already initialised (never runs an initialiser).
    pub fn get(&self, key: &K) -> Option<V> {
        let slot = self.slots.lock().unwrap().get(key).cloned()?;
        let guard = slot.lock().unwrap();
        guard.clone()
    }
}

impl<K: Eq + std::hash::Hash + Clone, V: Clone> Default for KeyedOnce<K, V> {
    fn default() -> Self {
        KeyedOnce::new()
    }
}

// ---------------------------------------------------------------------------
// the cache proper

/// Hit/miss counters (snapshot of [`GramCache::counts`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheCounts {
    pub mem_hits: u64,
    pub disk_hits: u64,
    pub misses: u64,
}

/// Two-layer calibration-Gram cache: Arc-shared memory in front of an
/// optional on-disk store. Safe to share across threads (the experiment
/// executor's workers all hold the same `Arc<GramCache>`). The memory
/// layer is a [`KeyedOnce`] keyed by the *full* [`GramCacheKey`] (not its
/// 64-bit hash), so an FNV collision can never serve one model's Grams
/// for another — on disk the identity check inside [`load_grams`]
/// provides the same guarantee.
pub struct GramCache {
    dir: Option<PathBuf>,
    slots: KeyedOnce<GramCacheKey, Arc<Grams>>,
    mem_hits: AtomicU64,
    disk_hits: AtomicU64,
    misses: AtomicU64,
}

impl GramCache {
    /// `dir = Some(..)` enables the disk layer (`--cache-dir`); `None`
    /// keeps the in-process memory layer only (`--no-cache`).
    pub fn new(dir: Option<PathBuf>) -> GramCache {
        GramCache {
            dir,
            slots: KeyedOnce::new(),
            mem_hits: AtomicU64::new(0),
            disk_hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Memory-only cache (no persistence).
    pub fn memory_only() -> GramCache {
        GramCache::new(None)
    }

    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    pub fn counts(&self) -> CacheCounts {
        CacheCounts {
            mem_hits: self.mem_hits.load(Ordering::Relaxed),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Fetch the Grams for `key`, computing them with `compute` on a full
    /// miss. Resolution order: memory → disk → compute (+ write-back).
    /// Concurrent callers with the same key compute once (the
    /// [`KeyedOnce`] slot serializes them); a failing `compute` is
    /// propagated and retried by the next caller.
    pub fn get_or_compute(
        &self,
        key: &GramCacheKey,
        compute: impl FnOnce() -> Result<Grams>,
    ) -> Result<Arc<Grams>> {
        let hash = key.hash();
        let mut initialised = false;
        let g = self.slots.get_or_try_init(key, || {
            initialised = true;
            if let Some(dir) = &self.dir {
                match load_grams(dir, key) {
                    Ok(Some(g)) => {
                        self.disk_hits.fetch_add(1, Ordering::Relaxed);
                        crate::obs::metrics::REGISTRY.gram_disk_hits.inc();
                        eprintln!("[cache] gram cache hit (disk) for '{}' \
                                   [{hash:016x}] — skipping calibration", key.model);
                        return Ok(Arc::new(g));
                    }
                    Ok(None) => {}
                    Err(e) => {
                        eprintln!("[cache] discarding unreadable cache file for \
                                   '{}' [{hash:016x}]: {e:#}", key.model);
                    }
                }
            }
            self.misses.fetch_add(1, Ordering::Relaxed);
            crate::obs::metrics::REGISTRY.gram_misses.inc();
            eprintln!("[cache] gram cache miss for '{}' [{hash:016x}] — calibrating",
                      key.model);
            let g = Arc::new(compute()?);
            if let Some(dir) = &self.dir {
                match store_grams(dir, key, &g) {
                    Ok(path) => eprintln!("[cache] stored Grams for '{}' at {path:?}",
                                          key.model),
                    Err(e) => eprintln!("[cache] failed to persist Grams for \
                                         '{}': {e:#}", key.model),
                }
            }
            Ok(g)
        })?;
        if !initialised {
            self.mem_hits.fetch_add(1, Ordering::Relaxed);
            crate::obs::metrics::REGISTRY.gram_mem_hits.inc();
            eprintln!("[cache] gram cache hit (memory) for '{}' [{hash:016x}]",
                      key.model);
        }
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::calibrate::synthetic_grams;
    use crate::util::tempdir::TempDir;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(), vocab: 32, d_model: 16, n_heads: 2, n_layers: 2,
            d_ff: 32, seq_len: 8, batch: 1, decode_len: 8, rope_theta: 1e4,
        }
    }

    fn key(ck: u64, calib: u64) -> GramCacheKey {
        GramCacheKey { model: "t".into(), checkpoint: ck, calib }
    }

    #[test]
    fn disk_roundtrip_is_bit_exact() {
        let dir = TempDir::new("gramcache").unwrap();
        let grams = synthetic_grams(&cfg(), 3);
        let k = key(1, 2);
        store_grams(dir.path(), &k, &grams).unwrap();
        let back = load_grams(dir.path(), &k).unwrap().unwrap();
        assert_eq!(back.tokens, grams.tokens);
        assert_eq!(back.map.len(), grams.map.len());
        for (gk, m) in &grams.map {
            let b = &back.map[gk];
            assert_eq!(m.shape(), b.shape());
            for (x, y) in m.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn absent_file_is_a_clean_miss() {
        let dir = TempDir::new("gramcache").unwrap();
        assert!(load_grams(dir.path(), &key(1, 2)).unwrap().is_none());
    }

    #[test]
    fn corrupt_and_mismatched_files_error() {
        let dir = TempDir::new("gramcache").unwrap();
        let k = key(1, 2);
        // garbage
        std::fs::write(dir.path().join(k.file_name()), b"garbage").unwrap();
        assert!(load_grams(dir.path(), &k).is_err());
        // truncated: store then chop the data region
        let grams = synthetic_grams(&cfg(), 3);
        let path = store_grams(dir.path(), &k, &grams).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 64]).unwrap();
        assert!(load_grams(dir.path(), &k).is_err());
        // identity mismatch: valid file renamed under a different key's name
        let k2 = key(9, 2);
        store_grams(dir.path(), &k, &grams).unwrap();
        std::fs::rename(dir.path().join(k.file_name()),
                        dir.path().join(k2.file_name()))
            .unwrap();
        assert!(load_grams(dir.path(), &k2).is_err());
    }

    #[test]
    fn key_hash_tracks_every_component() {
        let base = key(1, 2).hash();
        assert_eq!(base, key(1, 2).hash());
        assert_ne!(base, key(3, 2).hash());
        assert_ne!(base, key(1, 3).hash());
        let other = GramCacheKey { model: "u".into(), checkpoint: 1, calib: 2 };
        assert_ne!(base, other.hash());
    }

    #[test]
    fn calib_spec_fingerprint_tracks_config() {
        let rc = RunConfig::default();
        let mc = cfg();
        let base = CalibSpec::from_run(&rc, &mc, "calib_capture").fingerprint();
        assert_eq!(base, CalibSpec::from_run(&rc, &mc, "calib_capture").fingerprint());
        let mut rc2 = RunConfig::default();
        rc2.calib_batches += 1;
        assert_ne!(base, CalibSpec::from_run(&rc2, &mc, "calib_capture").fingerprint());
        let mut rc3 = RunConfig::default();
        rc3.corpus.seed ^= 1;
        assert_ne!(base, CalibSpec::from_run(&rc3, &mc, "calib_capture").fingerprint());
        let mut rc4 = RunConfig::default();
        rc4.seed ^= 1; // calibration sampling seed
        assert_ne!(base, CalibSpec::from_run(&rc4, &mc, "calib_capture").fingerprint());
        assert_ne!(base, CalibSpec::from_run(&rc, &mc, "synthetic").fingerprint());
    }

    #[test]
    fn memory_layer_computes_once_under_contention() {
        use std::sync::atomic::AtomicUsize;
        let cache = Arc::new(GramCache::memory_only());
        let calls = Arc::new(AtomicUsize::new(0));
        let k = key(7, 8);
        std::thread::scope(|s| {
            for _ in 0..8 {
                let (cache, calls, k) = (cache.clone(), calls.clone(), k.clone());
                s.spawn(move || {
                    cache
                        .get_or_compute(&k, || {
                            calls.fetch_add(1, Ordering::SeqCst);
                            Ok(synthetic_grams(&cfg(), 3))
                        })
                        .unwrap();
                });
            }
        });
        assert_eq!(calls.load(Ordering::SeqCst), 1);
        let c = cache.counts();
        assert_eq!(c.misses, 1);
        assert_eq!(c.mem_hits, 7);
    }

    #[test]
    fn failed_compute_is_retried() {
        let cache = GramCache::memory_only();
        let k = key(7, 8);
        assert!(cache.get_or_compute(&k, || anyhow::bail!("boom")).is_err());
        let g = cache.get_or_compute(&k, || Ok(synthetic_grams(&cfg(), 3))).unwrap();
        assert!(!g.map.is_empty());
    }

    #[test]
    fn warm_disk_cache_never_invokes_the_provider() {
        let dir = TempDir::new("gramcache").unwrap();
        let k = key(4, 5);
        let cold = GramCache::new(Some(dir.path().to_path_buf()));
        cold.get_or_compute(&k, || Ok(synthetic_grams(&cfg(), 3))).unwrap();
        // a fresh process (fresh memory layer) with the same dir: the
        // provider must not run — this is the "warm run skips calib_capture"
        // guarantee, with a bailing provider standing in for the runtime
        let warm = GramCache::new(Some(dir.path().to_path_buf()));
        let g = warm
            .get_or_compute(&k, || anyhow::bail!("calib_capture must not run"))
            .unwrap();
        assert_eq!(g.map.len(), 8);
        assert_eq!(warm.counts(), CacheCounts { mem_hits: 0, disk_hits: 1, misses: 0 });
    }

    #[test]
    fn corrupt_file_degrades_to_recompute_and_heals() {
        let dir = TempDir::new("gramcache").unwrap();
        let k = key(4, 5);
        std::fs::create_dir_all(dir.path()).unwrap();
        std::fs::write(dir.path().join(k.file_name()), b"AWPGRAM1junk").unwrap();
        let cache = GramCache::new(Some(dir.path().to_path_buf()));
        let g = cache.get_or_compute(&k, || Ok(synthetic_grams(&cfg(), 3))).unwrap();
        assert_eq!(cache.counts().misses, 1);
        // the rewrite healed the file: a fresh cache now disk-hits
        let healed = GramCache::new(Some(dir.path().to_path_buf()));
        let g2 = healed
            .get_or_compute(&k, || anyhow::bail!("should be healed"))
            .unwrap();
        assert_eq!(g.tokens, g2.tokens);
    }

    #[test]
    fn keyed_once_initialises_once_per_key() {
        let once: KeyedOnce<String, usize> = KeyedOnce::new();
        let a = once.get_or_try_init(&"a".to_string(), || Ok(1)).unwrap();
        let b = once.get_or_try_init(&"a".to_string(), || Ok(2)).unwrap();
        assert_eq!((a, b), (1, 1));
        assert_eq!(once.get(&"a".to_string()), Some(1));
        assert_eq!(once.get(&"b".to_string()), None);
        assert!(once.get_or_try_init(&"c".to_string(), || anyhow::bail!("x")).is_err());
        assert_eq!(once.get_or_try_init(&"c".to_string(), || Ok(3)).unwrap(), 3);
    }
}
