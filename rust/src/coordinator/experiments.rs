//! Experiment harness — regenerates every table and figure of the paper's
//! evaluation section on this repo's substrate (see DESIGN.md §5 for the
//! experiment index and the substitution notes).
//!
//! Each `table*` function produces the same rows/columns the paper reports;
//! `fig1` emits the per-iteration activation-loss series. Results are
//! written to `reports/` as console text, markdown and CSV.
//!
//! Table sweeps submit their cells through the shared layer-job
//! [`Executor`] (`--jobs N`): each cell is one pool job (compress + eval),
//! the nested per-cell pipeline runs sequentially inside the cell's thread
//! budget, and the memoized checkpoint/Gram/batcher state is shared across
//! cells via `Arc` rather than recomputed. Cell results come back in
//! submission order, so the rendered tables are identical to a sequential
//! run at any worker count.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::calibrate::{calibrate, Grams};
use super::executor::Executor;
use super::methods::{make_compressor, Method};
use super::pipeline::compress_model_with;
use crate::compress::awp::AwpHyper;
use crate::compress::traits::CompressionSpec;
use crate::config::RunConfig;
use crate::data::{Batcher, Split, SyntheticCorpus};
use crate::eval::perplexity::perplexity;
use crate::model::Checkpoint;
use crate::report::{series_csv, Table};
use crate::runtime::{Manifest, RuntimeHandle};
use crate::trainer;
use crate::util::Timer;

/// Shared state across experiments: runtime, manifest, corpus, trained
/// checkpoints and calibration Grams (each produced once and reused), plus
/// the executor table sweeps and pipeline runs are submitted through.
pub struct ExperimentCtx {
    pub handle: RuntimeHandle,
    pub manifest: Arc<Manifest>,
    pub cfg: RunConfig,
    executor: Executor,
    corpus: Option<Arc<SyntheticCorpus>>,
    batchers: HashMap<(usize, usize), Arc<Batcher>>,
    checkpoints: HashMap<String, Arc<Checkpoint>>,
    grams: HashMap<String, Arc<Grams>>,
    dense_ppl: HashMap<String, f64>,
}

impl ExperimentCtx {
    pub fn new(handle: RuntimeHandle, manifest: Arc<Manifest>, cfg: RunConfig) -> Self {
        ExperimentCtx {
            handle,
            manifest,
            cfg,
            executor: Executor::new(None),
            corpus: None,
            batchers: HashMap::new(),
            checkpoints: HashMap::new(),
            grams: HashMap::new(),
            dense_ppl: HashMap::new(),
        }
    }

    /// Size the worker pool (the `--jobs N` flag; `None` ⇒ ambient budget).
    pub fn set_jobs(&mut self, jobs: Option<usize>) {
        self.executor = Executor::new(jobs);
    }

    /// The executor cell sweeps and pipeline runs go through.
    pub fn executor(&self) -> Executor {
        self.executor
    }

    fn corpus(&mut self) -> Arc<SyntheticCorpus> {
        if self.corpus.is_none() {
            let t = Timer::start("corpus");
            self.corpus =
                Some(Arc::new(SyntheticCorpus::generate(self.cfg.corpus.clone())));
            eprintln!("[ctx] corpus generated {}", t.report());
        }
        self.corpus.as_ref().unwrap().clone()
    }

    pub fn batcher(&mut self, model: &str) -> Result<Arc<Batcher>> {
        let mc = self.manifest.model(model)?.config.clone();
        let key = (mc.batch, mc.seq_len);
        if !self.batchers.contains_key(&key) {
            let corpus = self.corpus();
            self.batchers
                .insert(key, Arc::new(Batcher::new(&corpus, mc.batch, mc.seq_len)));
        }
        Ok(self.batchers[&key].clone())
    }

    /// Load the trained checkpoint for `model`, training (and saving) it if
    /// absent — training is part of the system, not an external input.
    pub fn checkpoint(&mut self, model: &str) -> Result<Arc<Checkpoint>> {
        if let Some(ck) = self.checkpoints.get(model) {
            return Ok(ck.clone());
        }
        let path = self.cfg.paths.checkpoint_file(model);
        let ck = if path.exists() {
            eprintln!("[ctx] loading checkpoint {path:?}");
            let ck = Checkpoint::load(&path)?;
            ck.validate()?;
            ck
        } else {
            eprintln!("[ctx] no checkpoint for '{model}' — training now");
            self.cfg.paths.ensure_dirs()?;
            let batcher = self.batcher(model)?;
            let tc = self.cfg.train_config(model);
            let (ck, _curve) =
                trainer::train(&self.handle, &self.manifest, model, &batcher, &tc)?;
            ck.save(&path).with_context(|| format!("saving {path:?}"))?;
            ck
        };
        let ck = Arc::new(ck);
        self.checkpoints.insert(model.to_string(), ck.clone());
        Ok(ck)
    }

    pub fn grams(&mut self, model: &str) -> Result<Arc<Grams>> {
        if let Some(g) = self.grams.get(model) {
            return Ok(g.clone());
        }
        let ck = self.checkpoint(model)?;
        let batcher = self.batcher(model)?;
        let batches = batcher.calibration_set(self.cfg.calib_batches,
                                              self.cfg.seed ^ 0xCA11B);
        let t = Timer::start("calibrate");
        let grams = calibrate(&self.handle, &self.manifest, model, &ck, &batches)?;
        eprintln!("[ctx] calibrated '{model}' over {} tokens {}",
                  grams.tokens, t.report());
        let g = Arc::new(grams);
        self.grams.insert(model.to_string(), g.clone());
        Ok(g)
    }

    pub fn ppl(&mut self, model: &str, ck: &Checkpoint) -> Result<f64> {
        let batcher = self.batcher(model)?;
        let rep = perplexity(&self.handle, &self.manifest, model, ck, &batcher,
                             Split::Val, self.cfg.eval_batches)?;
        Ok(rep.ppl)
    }

    pub fn dense_ppl(&mut self, model: &str) -> Result<f64> {
        if let Some(&p) = self.dense_ppl.get(model) {
            return Ok(p);
        }
        let ck = self.checkpoint(model)?;
        let p = self.ppl(model, &ck)?;
        eprintln!("[ctx] dense ppl({model}) = {p:.3}");
        self.dense_ppl.insert(model.to_string(), p);
        Ok(p)
    }

    /// One table cell: compress `model` with `method` under `spec`, return
    /// held-out perplexity.
    pub fn cell(&mut self, model: &str, method: Method, spec: &CompressionSpec)
        -> Result<f64> {
        Ok(self.cells(model, &[(method, *spec)])?[0])
    }

    /// A batch of table cells, run through the shared executor: one pool
    /// job per `(method, spec)` cell. The trained checkpoint, Grams and
    /// batcher are produced (or fetched from cache) once up front and
    /// shared across cells via `Arc`; each cell builds its compressor,
    /// runs the per-cell pipeline *sequentially* inside its thread budget,
    /// and evaluates held-out perplexity. Results are in `specs` order.
    pub fn cells(&mut self, model: &str, specs: &[(Method, CompressionSpec)])
        -> Result<Vec<f64>> {
        // memoized shared state, resolved before the parallel section
        let ck = self.checkpoint(model)?;
        let grams = self.grams(model)?;
        let batcher = self.batcher(model)?;
        let handle = self.handle.clone();
        let manifest = self.manifest.clone();
        let eval_batches = self.cfg.eval_batches;
        let hyper = AwpHyper { group: self.manifest.awp_group,
                               chunk: self.manifest.awp_chunk,
                               ..AwpHyper::default() };
        let run = self.executor.run(
            specs.len(),
            |i| format!("{} {:?}", specs[i].0.label(), specs[i].1.mode),
            |i| {
                let (method, spec) = specs[i];
                let compressor =
                    make_compressor(method, hyper, Some((&handle, &manifest)))?;
                let t = Timer::start("cell");
                // cell-level parallelism owns the budget split; the nested
                // pipeline runs its layer jobs sequentially within it
                let out = compress_model_with(&ck, &grams, compressor.as_ref(),
                                              &spec, false, &Executor::sequential())?;
                let rep = perplexity(&handle, &manifest, model, &out.checkpoint,
                                     &batcher, Split::Val, eval_batches)?;
                eprintln!("[cell] {model} {} {:?} → ppl {:.3} ({:.1}s)",
                          method.label(), spec.mode, rep.ppl, t.elapsed_s());
                Ok(rep.ppl)
            },
        )?;
        Ok(run.results)
    }

    pub fn write_report(&self, name: &str, table: &Table) -> Result<()> {
        self.cfg.paths.ensure_dirs()?;
        let dir = &self.cfg.paths.reports;
        std::fs::write(dir.join(format!("{name}.txt")), table.to_console())?;
        std::fs::write(dir.join(format!("{name}.md")), table.to_markdown())?;
        std::fs::write(dir.join(format!("{name}.csv")), table.to_csv())?;
        println!("{}", table.to_console());
        Ok(())
    }
}

pub const PRUNE_RATIOS: [f64; 5] = [0.5, 0.6, 0.7, 0.8, 0.9];
pub const JOINT_RATIOS: [f64; 3] = [0.25, 0.5, 0.75];

/// Run a `methods × specs` sweep through [`ExperimentCtx::cells`] as one
/// flat row-major cell list and append one table row per method — the
/// shared body of every table/ablation generator.
fn sweep_into(ctx: &mut ExperimentCtx, t: &mut Table, model: &str,
              methods: &[Method], specs: &[CompressionSpec]) -> Result<()> {
    let mut cells = Vec::with_capacity(methods.len() * specs.len());
    for &method in methods {
        for &spec in specs {
            cells.push((method, spec));
        }
    }
    let ppls = ctx.cells(model, &cells)?;
    for (method, row) in methods.iter().zip(ppls.chunks(specs.len())) {
        t.push_row(method.label().to_uppercase(),
                   row.iter().map(|&p| Some(p)).collect());
    }
    Ok(())
}

/// Tables 1 & 2: pruning perplexity across ratios and methods.
fn prune_table(ctx: &mut ExperimentCtx, name: &str, model: &str,
               awp_method: Method) -> Result<Table> {
    let dense = ctx.dense_ppl(model)?;
    let cols: Vec<String> = PRUNE_RATIOS.iter().map(|r| format!("{:.0}%", r * 100.0)).collect();
    let mut t = Table::new(
        format!("{name}: ppl of pruned '{model}' (dense = {dense:.2})"),
        "method", cols);
    let methods = [Method::Magnitude, Method::SparseGpt, Method::Wanda, awp_method];
    let specs: Vec<CompressionSpec> =
        PRUNE_RATIOS.iter().map(|&r| CompressionSpec::prune(r)).collect();
    sweep_into(ctx, &mut t, model, &methods, &specs)?;
    Ok(t)
}

pub fn table1(ctx: &mut ExperimentCtx, awp: Method) -> Result<Table> {
    let t = prune_table(ctx, "Table 1", "small", awp)?;
    ctx.write_report("table1", &t)?;
    Ok(t)
}

pub fn table2(ctx: &mut ExperimentCtx, awp: Method) -> Result<Table> {
    let t = prune_table(ctx, "Table 2", "medium", awp)?;
    ctx.write_report("table2", &t)?;
    Ok(t)
}

/// Table 3: INT4/INT3/INT2 weight-only grouped quantization.
pub fn table3(ctx: &mut ExperimentCtx, awp: Method) -> Result<Table> {
    let model = "small";
    let dense = ctx.dense_ppl(model)?;
    let group = ctx.manifest.awp_group;
    let mut t = Table::new(
        format!("Table 3: ppl of quantized '{model}' (group={group}, dense = {dense:.2})"),
        "method",
        vec!["INT4".into(), "INT3".into(), "INT2".into()]);
    let methods = [Method::Rtn, Method::Gptq, Method::Awq, awp];
    let specs: Vec<CompressionSpec> =
        [4u8, 3, 2].iter().map(|&b| CompressionSpec::quant(b, group)).collect();
    sweep_into(ctx, &mut t, model, &methods, &specs)?;
    ctx.write_report("table3", &t)?;
    Ok(t)
}

/// Tables 4 & 5: joint pruning + INT4 quantization.
fn joint_table(ctx: &mut ExperimentCtx, name: &str, model: &str,
               awp_method: Method) -> Result<Table> {
    let dense = ctx.dense_ppl(model)?;
    let group = ctx.manifest.awp_group;
    let cols: Vec<String> = JOINT_RATIOS.iter().map(|r| format!("{:.0}%", r * 100.0)).collect();
    let mut t = Table::new(
        format!("{name}: ppl of pruned + INT4 '{model}' (dense = {dense:.2})"),
        "method", cols);
    let methods = [Method::AwqThenWanda, Method::WandaThenAwq, awp_method];
    let specs: Vec<CompressionSpec> =
        JOINT_RATIOS.iter().map(|&r| CompressionSpec::joint(r, 4, group)).collect();
    sweep_into(ctx, &mut t, model, &methods, &specs)?;
    Ok(t)
}

pub fn table4(ctx: &mut ExperimentCtx, awp: Method) -> Result<Table> {
    let t = joint_table(ctx, "Table 4", "small", awp)?;
    ctx.write_report("table4", &t)?;
    Ok(t)
}

pub fn table5(ctx: &mut ExperimentCtx, awp: Method) -> Result<Table> {
    let t = joint_table(ctx, "Table 5", "tiny", awp)?;
    ctx.write_report("table5", &t)?;
    Ok(t)
}

/// Ablation (paper §5 future work): unstructured 50% vs 2:4 semi-structured
/// sparsity, per method. 2:4 constrains *where* zeros live, so it should
/// cost some perplexity vs unstructured 50% at equal density — the
/// acceleration-vs-quality trade-off the paper's future-work section is
/// about.
pub fn ablation24(ctx: &mut ExperimentCtx) -> Result<Table> {
    let model = "small";
    let dense = ctx.dense_ppl(model)?;
    let mut t = Table::new(
        format!("Ablation: unstructured 50% vs 2:4 on '{model}' (dense = {dense:.2})"),
        "method",
        vec!["unstructured 50%".into(), "2:4".into()]);
    let methods = [Method::Magnitude, Method::Wanda, Method::AwpCpu];
    let specs = [CompressionSpec::prune(0.5), CompressionSpec::structured24()];
    sweep_into(ctx, &mut t, model, &methods, &specs)?;
    ctx.write_report("ablation24", &t)?;
    Ok(t)
}

/// Figure 1: normalized activation-aware loss vs AWP iteration for one
/// layer — run on the production HLO backend (chunk-1 program).
pub fn fig1(ctx: &mut ExperimentCtx, layer_param: &str, ratio: f64)
    -> Result<Vec<(f64, f64)>> {
    let model = "small";
    let ck = ctx.checkpoint(model)?;
    let grams = ctx.grams(model)?;
    let site = super::jobs::plan_jobs(&ck.config)
        .jobs
        .into_iter()
        .map(|j| j.site)
        .find(|s| s.param == layer_param)
        .with_context(|| format!("no site {layer_param}"))?;
    let w = ck.matrix(&site.param)?;
    let c = grams.get(site.gram, site.layer).context("gram missing")?;
    let hyper = AwpHyper {
        track_series: true,
        group: ctx.manifest.awp_group,
        chunk: ctx.manifest.awp_chunk,
        ..AwpHyper::default()
    };
    let compressor = make_compressor(Method::AwpHlo, hyper,
                                     Some((&ctx.handle, &ctx.manifest)))?;
    let spec = CompressionSpec::prune(ratio);
    let out = compressor.compress(&w, c, &spec)?;
    let points: Vec<(f64, f64)> = out
        .stats
        .loss_series
        .iter()
        .enumerate()
        .map(|(i, &l)| (i as f64, l))
        .collect();
    ctx.cfg.paths.ensure_dirs()?;
    std::fs::write(ctx.cfg.paths.reports.join("fig1.csv"),
                   series_csv(("iteration", "rel_loss"), &points))?;
    println!("# Figure 1: ||W·C½ − Θ(t)·C½||_F / ||W||_F on {layer_param} @ {:.0}%",
             ratio * 100.0);
    for (x, y) in points.iter().take(12) {
        println!("  iter {x:3.0}  rel_loss {y:.5}");
    }
    if points.len() > 12 {
        let (x, y) = points.last().unwrap();
        println!("  ...\n  iter {x:3.0}  rel_loss {y:.5}");
    }
    Ok(points)
}
