//! Experiment harness — regenerates every table and figure of the paper's
//! evaluation section on this repo's substrate (see DESIGN.md §5 for the
//! experiment index and the substitution notes).
//!
//! Each `table*` function produces the same rows/columns the paper reports;
//! `fig1` emits the per-iteration activation-loss series. Results are
//! written to `reports/` as console text, markdown and CSV.
//!
//! Sweeps are scheduled **cross-model** through the shared layer-job
//! [`Executor`] (`--jobs N`) via [`super::sweep`]: `experiment all` hands
//! all five tables to one pool — per-model preparation (train/load
//! checkpoint, calibration Grams through the [`super::cache`] subsystem,
//! dense perplexity) runs as one executor job per model, then every
//! `(table, method, spec)` cell of every table runs as one cost-weighted
//! pool job. Cell results come back in submission order, so the rendered
//! tables are identical to a sequential run at any worker count.
//!
//! All memoized state (corpus, batchers, checkpoints, Grams, dense ppl)
//! lives behind `Arc`-shared keyed once-cells, so the harness is `&self`
//! throughout and concurrent jobs share rather than recompute.

use std::sync::{Arc, OnceLock};

use anyhow::{Context, Result};

use super::cache::{CalibSpec, GramCache, GramCacheKey, KeyedOnce};
use super::calibrate::{calibrate, synthetic_grams, Grams};
use super::executor::Executor;
use super::jobs::plan_jobs;
use super::methods::{make_compressor, Method};
use super::pipeline::{compress_model_cached, compress_model_with};
use super::sweep::{self, TableSpec};
use crate::artifact::{ArtifactKey, ArtifactStore};
use crate::compress::awp::AwpHyper;
use crate::compress::traits::CompressionSpec;
use crate::config::RunConfig;
use crate::data::{Batcher, Split, SyntheticCorpus};
use crate::eval::perplexity::{native_perplexity, perplexity, PerplexityReport};
use crate::infer::NativeModel;
use crate::model::Checkpoint;
use crate::report::{series_csv, Table};
use crate::runtime::{Manifest, RuntimeHandle};
use crate::trainer;
use crate::util::Timer;

/// Shared state across experiments: runtime, manifest, corpus, trained
/// checkpoints, calibration Grams (behind the two-layer gram cache) and
/// dense-perplexity baselines — each produced once and shared via `Arc`
/// across every concurrent sweep job — plus the executor all sweeps and
/// pipeline runs are submitted through.
pub struct ExperimentCtx {
    pub handle: RuntimeHandle,
    pub manifest: Arc<Manifest>,
    pub cfg: RunConfig,
    executor: Executor,
    /// runtime-free mode: untrained checkpoints + synthetic Grams, no
    /// perplexity eval (CI runners without AOT artifacts)
    synthetic: bool,
    cache: Arc<GramCache>,
    /// compressed-artifact store (`--artifact-dir`); disabled by default
    /// for library/test use, enabled by the CLI
    artifacts: Arc<ArtifactStore>,
    corpus: OnceLock<Arc<SyntheticCorpus>>,
    batchers: KeyedOnce<(usize, usize), Arc<Batcher>>,
    checkpoints: KeyedOnce<String, Arc<Checkpoint>>,
    fingerprints: KeyedOnce<String, u64>,
    dense_ppl: KeyedOnce<String, f64>,
}

impl ExperimentCtx {
    pub fn new(handle: RuntimeHandle, manifest: Arc<Manifest>, cfg: RunConfig) -> Self {
        ExperimentCtx {
            handle,
            manifest,
            cfg,
            executor: Executor::new(None),
            synthetic: false,
            cache: Arc::new(GramCache::memory_only()),
            artifacts: Arc::new(ArtifactStore::disabled()),
            corpus: OnceLock::new(),
            batchers: KeyedOnce::new(),
            checkpoints: KeyedOnce::new(),
            fingerprints: KeyedOnce::new(),
            dense_ppl: KeyedOnce::new(),
        }
    }

    /// Size the worker pool (the `--jobs N` flag; `None` ⇒ ambient budget).
    pub fn set_jobs(&mut self, jobs: Option<usize>) {
        self.executor = Executor::new(jobs).with_progress(self.executor.progress());
    }

    /// Toggle the executor's cost-weighted progress/ETA line (CLI runs).
    pub fn set_progress(&mut self, on: bool) {
        self.executor = self.executor.with_progress(on);
    }

    /// Install the calibration-artifact cache (`--cache-dir`/`--no-cache`).
    pub fn set_cache(&mut self, cache: Arc<GramCache>) {
        self.cache = cache;
    }

    pub fn cache(&self) -> &GramCache {
        &self.cache
    }

    /// Install the compressed-artifact store (`--artifact-dir` /
    /// `--no-artifacts`). With a store installed, every cell and CLI
    /// compression goes through
    /// [`compress_model_cached`](super::pipeline::compress_model_cached):
    /// warm reruns assemble from packed sites and submit zero compression
    /// jobs.
    pub fn set_artifact_store(&mut self, store: Arc<ArtifactStore>) {
        self.artifacts = store;
    }

    pub fn artifact_store(&self) -> &ArtifactStore {
        &self.artifacts
    }

    /// The artifact identity of `(model, method, spec)` under the current
    /// run configuration — Gram cache key × spec fingerprint × method ×
    /// hyperparameter fingerprint (step sizes, iteration budgets, AOT
    /// chunk/group all change Θ, so they are part of the identity).
    pub fn artifact_key(&self, model: &str, method: Method,
                        spec: &CompressionSpec) -> Result<ArtifactKey> {
        Ok(ArtifactKey::new(self.gram_key(model)?, method.label(), spec)
            .with_params(self.hyper().fingerprint()))
    }

    /// Runtime-free synthetic mode: untrained checkpoints and synthetic
    /// Grams (the calibration cache still runs the full key/disk path).
    pub fn set_synthetic(&mut self, on: bool) {
        self.synthetic = on;
    }

    pub fn synthetic(&self) -> bool {
        self.synthetic
    }

    /// The executor cell sweeps and pipeline runs go through.
    pub fn executor(&self) -> Executor {
        self.executor
    }

    fn corpus(&self) -> Arc<SyntheticCorpus> {
        self.corpus
            .get_or_init(|| {
                let t = Timer::start("corpus");
                let c = Arc::new(SyntheticCorpus::generate(self.cfg.corpus.clone()));
                eprintln!("[ctx] corpus generated {}", t.report());
                c
            })
            .clone()
    }

    pub fn batcher(&self, model: &str) -> Result<Arc<Batcher>> {
        let mc = self.manifest.model(model)?.config.clone();
        let key = (mc.batch, mc.seq_len);
        self.batchers.get_or_try_init(&key, || {
            let corpus = self.corpus();
            Ok(Arc::new(Batcher::new(&corpus, mc.batch, mc.seq_len)))
        })
    }

    /// Load the trained checkpoint for `model`, training (and saving) it if
    /// absent — training is part of the system, not an external input. In
    /// synthetic mode the checkpoint is the deterministic init (no
    /// training, no runtime).
    pub fn checkpoint(&self, model: &str) -> Result<Arc<Checkpoint>> {
        self.checkpoints.get_or_try_init(&model.to_string(), || {
            let mc = self.manifest.model(model)?.config.clone();
            if self.synthetic {
                eprintln!("[ctx] synthetic checkpoint for '{model}' (untrained)");
                return Ok(Arc::new(trainer::init_checkpoint(&mc, self.cfg.seed)));
            }
            let path = self.cfg.paths.checkpoint_file(model);
            let ck = if path.exists() {
                eprintln!("[ctx] loading checkpoint {path:?}");
                let ck = Checkpoint::load(&path)?;
                ck.validate()?;
                ck
            } else {
                eprintln!("[ctx] no checkpoint for '{model}' — training now");
                self.cfg.paths.ensure_dirs()?;
                let batcher = self.batcher(model)?;
                let tc = self.cfg.train_config(model);
                let (ck, _curve) = trainer::train(&self.handle, &self.manifest,
                                                  model, &batcher, &tc)?;
                ck.save(&path).with_context(|| format!("saving {path:?}"))?;
                ck
            };
            Ok(Arc::new(ck))
        })
    }

    /// Checkpoint content fingerprint, hashed once per model per process.
    fn fingerprint(&self, model: &str) -> Result<u64> {
        self.fingerprints.get_or_try_init(&model.to_string(), || {
            Ok(self.checkpoint(model)?.fingerprint())
        })
    }

    /// The gram-cache key identifying `model`'s calibration artifacts
    /// under the current run configuration.
    pub fn gram_key(&self, model: &str) -> Result<GramCacheKey> {
        let mc = &self.manifest.model(model)?.config;
        let provider = if self.synthetic { "synthetic" } else { "calib_capture" };
        Ok(GramCacheKey {
            model: model.to_string(),
            checkpoint: self.fingerprint(model)?,
            calib: CalibSpec::from_run(&self.cfg, mc, provider).fingerprint(),
        })
    }

    /// Calibration Grams for `model`, through the two-layer cache:
    /// memory → disk (`--cache-dir`) → run `calib_capture` over the fixed
    /// calibration set (or synthesize, in synthetic mode). The cache's
    /// memory layer IS the per-process memo — the ctx adds no second one,
    /// so its hit counters reflect real sharing across cells.
    pub fn grams(&self, model: &str) -> Result<Arc<Grams>> {
        let key = self.gram_key(model)?;
        let ck = self.checkpoint(model)?;
        self.cache.get_or_compute(&key, || {
            if self.synthetic {
                return Ok(synthetic_grams(&ck.config, self.cfg.seed));
            }
            let batcher = self.batcher(model)?;
            let batches = batcher.calibration_set(self.cfg.calib_batches,
                                                  self.cfg.calib_seed());
            let t = Timer::start("calibrate");
            let grams = calibrate(&self.handle, &self.manifest, model, &ck,
                                  &batches)?;
            eprintln!("[ctx] calibrated '{model}' over {} tokens {}",
                      grams.tokens, t.report());
            Ok(grams)
        })
    }

    pub fn ppl(&self, model: &str, ck: &Checkpoint) -> Result<f64> {
        let batcher = self.batcher(model)?;
        let rep = perplexity(&self.handle, &self.manifest, model, ck, &batcher,
                             Split::Val, self.cfg.eval_batches)?;
        Ok(rep.ppl)
    }

    /// Held-out perplexity through the native CPU forward pass — the
    /// runtime-free eval backend (`repro eval --native`). Works in
    /// synthetic mode, where the AOT `eval_loss` program is unavailable,
    /// and on packed models ([`NativeModel::from_artifact`]), where it is
    /// the first eval path that never assembles a dense f32 checkpoint.
    pub fn native_ppl(&self, model: &str, nm: &NativeModel)
        -> Result<PerplexityReport> {
        let batcher = self.batcher(model)?;
        native_perplexity(nm, &batcher, Split::Val, self.cfg.eval_batches)
    }

    pub fn dense_ppl(&self, model: &str) -> Result<f64> {
        self.dense_ppl.get_or_try_init(&model.to_string(), || {
            let ck = self.checkpoint(model)?;
            let p = self.ppl(model, &ck)?;
            eprintln!("[ctx] dense ppl({model}) = {p:.3}");
            Ok(p)
        })
    }

    /// One cross-model-sweep preparation job: everything a model's cells
    /// need, produced once and shared (checkpoint, Grams, dense baseline).
    pub fn prepare_model(&self, model: &str) -> Result<()> {
        self.checkpoint(model)?;
        self.grams(model)?;
        if !self.synthetic {
            self.dense_ppl(model)?;
        }
        Ok(())
    }

    fn hyper(&self) -> AwpHyper {
        AwpHyper { group: self.manifest.awp_group,
                   chunk: self.manifest.awp_chunk,
                   ..AwpHyper::default() }
    }

    /// One table cell: compress `model` with `method` under `spec`, return
    /// held-out perplexity (or, in synthetic mode, the mean per-layer
    /// reconstruction loss — perplexity needs the runtime). The nested
    /// pipeline runs sequentially inside the calling sweep job's budget.
    pub fn eval_cell(&self, model: &str, method: Method, spec: &CompressionSpec)
        -> Result<f64> {
        let ck = self.checkpoint(model)?;
        let grams = self.grams(model)?;
        let compressor = make_compressor(method, self.hyper(),
                                         Some((&self.handle, &self.manifest)))?;
        let t = Timer::start("cell");
        // with an artifact store installed, the cell is incremental: a
        // warm rerun assembles this (model, method, spec)'s sites from the
        // packed artifact and submits zero compression jobs
        let out = if self.artifacts.enabled() {
            let key = self.artifact_key(model, method, spec)?;
            compress_model_cached(&ck, &grams, compressor.as_ref(), spec, false,
                                  &Executor::sequential(), &self.artifacts, &key)?
                .result
        } else {
            compress_model_with(&ck, &grams, compressor.as_ref(), spec, false,
                                &Executor::sequential())?
        };
        if self.synthetic {
            let mean_loss = out.reports.iter().map(|r| r.rel_loss).sum::<f64>()
                / out.reports.len().max(1) as f64;
            eprintln!("[cell] {model} {} {} → rel_loss {mean_loss:.4} ({:.1}s) \
                       [synthetic]", method.label(), sweep::spec_tag(spec),
                      t.elapsed_s());
            return Ok(mean_loss);
        }
        let batcher = self.batcher(model)?;
        let rep = perplexity(&self.handle, &self.manifest, model, &out.checkpoint,
                             &batcher, Split::Val, self.cfg.eval_batches)?;
        eprintln!("[cell] {model} {} {} → ppl {:.3} ({:.1}s)", method.label(),
                  sweep::spec_tag(spec), rep.ppl, t.elapsed_s());
        Ok(rep.ppl)
    }

    /// FLOP-ish cost of one of `model`'s cells: the model's full layer-job
    /// plan cost (every cell compresses every site once).
    pub fn cell_cost(&self, model: &str) -> u64 {
        self.manifest
            .model(model)
            .map(|e| plan_jobs(&e.config).total_cost())
            .unwrap_or(1)
    }

    fn table_title(&self, t: &TableSpec) -> String {
        match self.dense_ppl.get(&t.model) {
            Some(d) => format!("{} '{}' ({}dense = {d:.2})", t.title_prefix,
                               t.model, t.title_extra),
            None => format!("{} '{}' ({}dense = n/a)", t.title_prefix, t.model,
                            t.title_extra),
        }
    }

    /// Schedule `tables` as one cross-model sweep on the shared executor
    /// (see [`sweep::run_tables`]) and write each report.
    pub fn run_tables(&self, tables: &[TableSpec]) -> Result<Vec<Table>> {
        let out = sweep::run_tables(
            &self.executor,
            tables,
            |m| self.prepare_model(m),
            |c| self.eval_cell(&c.model, c.method, &c.spec),
            |c| self.cell_cost(&c.model),
            |t| self.table_title(t),
        )?;
        for (spec, table) in tables.iter().zip(&out) {
            self.write_report(&spec.name, table)?;
        }
        Ok(out)
    }

    pub fn write_report(&self, name: &str, table: &Table) -> Result<()> {
        self.cfg.paths.ensure_dirs()?;
        let dir = &self.cfg.paths.reports;
        std::fs::write(dir.join(format!("{name}.txt")), table.to_console())?;
        std::fs::write(dir.join(format!("{name}.md")), table.to_markdown())?;
        std::fs::write(dir.join(format!("{name}.csv")), table.to_csv())?;
        println!("{}", table.to_console());
        Ok(())
    }
}

pub const PRUNE_RATIOS: [f64; 5] = [0.5, 0.6, 0.7, 0.8, 0.9];
pub const JOINT_RATIOS: [f64; 3] = [0.25, 0.5, 0.75];

/// Tables 1 & 2: pruning perplexity across ratios and methods.
fn prune_spec(name: &str, num: &str, model: &str, awp: Method) -> TableSpec {
    TableSpec {
        name: name.into(),
        model: model.into(),
        col_header: "method".into(),
        columns: PRUNE_RATIOS.iter().map(|r| format!("{:.0}%", r * 100.0)).collect(),
        methods: vec![Method::Magnitude, Method::SparseGpt, Method::Wanda, awp],
        specs: PRUNE_RATIOS.iter().map(|&r| CompressionSpec::prune(r)).collect(),
        title_prefix: format!("{num}: ppl of pruned"),
        title_extra: String::new(),
    }
}

/// Table 3: INT4/INT3/INT2 weight-only grouped quantization.
fn quant_spec(model: &str, awp: Method, group: usize) -> TableSpec {
    TableSpec {
        name: "table3".into(),
        model: model.into(),
        col_header: "method".into(),
        columns: vec!["INT4".into(), "INT3".into(), "INT2".into()],
        methods: vec![Method::Rtn, Method::Gptq, Method::Awq, awp],
        specs: [4u8, 3, 2].iter().map(|&b| CompressionSpec::quant(b, group)).collect(),
        title_prefix: "Table 3: ppl of quantized".into(),
        title_extra: format!("group={group}, "),
    }
}

/// Tables 4 & 5: joint pruning + INT4 quantization.
fn joint_spec(name: &str, num: &str, model: &str, awp: Method, group: usize)
    -> TableSpec {
    TableSpec {
        name: name.into(),
        model: model.into(),
        col_header: "method".into(),
        columns: JOINT_RATIOS.iter().map(|r| format!("{:.0}%", r * 100.0)).collect(),
        methods: vec![Method::AwqThenWanda, Method::WandaThenAwq, awp],
        specs: JOINT_RATIOS
            .iter()
            .map(|&r| CompressionSpec::joint(r, 4, group))
            .collect(),
        title_prefix: format!("{num}: ppl of pruned + INT4"),
        title_extra: String::new(),
    }
}

/// Ablation (paper §5 future work): unstructured 50% vs 2:4 semi-structured
/// sparsity, per method. 2:4 constrains *where* zeros live, so it should
/// cost some perplexity vs unstructured 50% at equal density — the
/// acceleration-vs-quality trade-off the paper's future-work section is
/// about.
fn ablation_spec(model: &str) -> TableSpec {
    TableSpec {
        name: "ablation24".into(),
        model: model.into(),
        col_header: "method".into(),
        columns: vec!["unstructured 50%".into(), "2:4".into()],
        methods: vec![Method::Magnitude, Method::Wanda, Method::AwpCpu],
        specs: vec![CompressionSpec::prune(0.5), CompressionSpec::structured24()],
        title_prefix: "Ablation: unstructured 50% vs 2:4 on".into(),
        title_extra: String::new(),
    }
}

fn one_table(ctx: &ExperimentCtx, spec: TableSpec) -> Result<Table> {
    Ok(ctx.run_tables(std::slice::from_ref(&spec))?.remove(0))
}

pub fn table1(ctx: &ExperimentCtx, awp: Method) -> Result<Table> {
    one_table(ctx, prune_spec("table1", "Table 1", "small", awp))
}

pub fn table2(ctx: &ExperimentCtx, awp: Method) -> Result<Table> {
    one_table(ctx, prune_spec("table2", "Table 2", "medium", awp))
}

pub fn table3(ctx: &ExperimentCtx, awp: Method) -> Result<Table> {
    one_table(ctx, quant_spec("small", awp, ctx.manifest.awp_group))
}

pub fn table4(ctx: &ExperimentCtx, awp: Method) -> Result<Table> {
    one_table(ctx, joint_spec("table4", "Table 4", "small", awp,
                              ctx.manifest.awp_group))
}

pub fn table5(ctx: &ExperimentCtx, awp: Method) -> Result<Table> {
    one_table(ctx, joint_spec("table5", "Table 5", "tiny", awp,
                              ctx.manifest.awp_group))
}

pub fn ablation24(ctx: &ExperimentCtx) -> Result<Table> {
    one_table(ctx, ablation_spec("small"))
}

/// The full sweep: every table of the paper as **one** cross-model
/// schedule on the shared executor (models prepare in parallel, all
/// tables' cells interleave on the pool), then Figure 1.
pub fn run_all(ctx: &ExperimentCtx, awp: Method) -> Result<Vec<Table>> {
    let group = ctx.manifest.awp_group;
    let tables = vec![
        prune_spec("table1", "Table 1", "small", awp),
        prune_spec("table2", "Table 2", "medium", awp),
        quant_spec("small", awp, group),
        joint_spec("table4", "Table 4", "small", awp, group),
        joint_spec("table5", "Table 5", "tiny", awp, group),
    ];
    let out = ctx.run_tables(&tables)?;
    if ctx.synthetic() {
        eprintln!("[experiment] skipping fig1 in synthetic mode (needs the HLO \
                   runtime)");
    } else {
        fig1(ctx, "blocks.1.wq", 0.5)?;
    }
    Ok(out)
}

/// Figure 1: normalized activation-aware loss vs AWP iteration for one
/// layer — run on the production HLO backend (chunk-1 program).
pub fn fig1(ctx: &ExperimentCtx, layer_param: &str, ratio: f64)
    -> Result<Vec<(f64, f64)>> {
    let model = "small";
    let ck = ctx.checkpoint(model)?;
    let grams = ctx.grams(model)?;
    let site = super::jobs::plan_jobs(&ck.config)
        .jobs
        .into_iter()
        .map(|j| j.site)
        .find(|s| s.param == layer_param)
        .with_context(|| format!("no site {layer_param}"))?;
    let w = ck.matrix(&site.param)?;
    let c = grams.get(site.gram, site.layer).context("gram missing")?;
    let hyper = AwpHyper {
        track_series: true,
        group: ctx.manifest.awp_group,
        chunk: ctx.manifest.awp_chunk,
        ..AwpHyper::default()
    };
    let compressor = make_compressor(Method::AwpHlo, hyper,
                                     Some((&ctx.handle, &ctx.manifest)))?;
    let spec = CompressionSpec::prune(ratio);
    let out = compressor.compress(&w, c, &spec)?;
    let points: Vec<(f64, f64)> = out
        .stats
        .loss_series
        .iter()
        .enumerate()
        .map(|(i, &l)| (i as f64, l))
        .collect();
    ctx.cfg.paths.ensure_dirs()?;
    std::fs::write(ctx.cfg.paths.reports.join("fig1.csv"),
                   series_csv(("iteration", "rel_loss"), &points))?;
    println!("# Figure 1: ||W·C½ − Θ(t)·C½||_F / ||W||_F on {layer_param} @ {:.0}%",
             ratio * 100.0);
    for (x, y) in points.iter().take(12) {
        println!("  iter {x:3.0}  rel_loss {y:.5}");
    }
    if points.len() > 12 {
        let (x, y) = points.last().unwrap();
        println!("  ...\n  iter {x:3.0}  rel_loss {y:.5}");
    }
    Ok(points)
}
