//! Method registry: the paper's full method matrix by name.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::compress::awp::AwpHyper;
use crate::compress::{
    awq::AwqQuant, gptq::Gptq, magnitude::MagnitudePrune, rtn::RtnQuant,
    sequential::SequentialCombo, sparsegpt::SparseGpt, wanda::WandaPrune, AwpDriver,
    CpuBackend, LayerCompressor,
};
use crate::runtime::{HloBackend, Manifest, RuntimeHandle};

/// Every compression method the experiments reference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Magnitude,
    Wanda,
    SparseGpt,
    Rtn,
    Awq,
    Gptq,
    AwqThenWanda,
    WandaThenAwq,
    /// AWP on the pure-Rust backend
    AwpCpu,
    /// AWP on the AOT/PJRT backend (the production path)
    AwpHlo,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "magnitude" | "mag" => Method::Magnitude,
            "wanda" => Method::Wanda,
            "sparsegpt" => Method::SparseGpt,
            "rtn" => Method::Rtn,
            "awq" => Method::Awq,
            "gptq" => Method::Gptq,
            "awq+wanda" => Method::AwqThenWanda,
            "wanda+awq" => Method::WandaThenAwq,
            "awp" | "awp-hlo" => Method::AwpHlo,
            "awp-cpu" => Method::AwpCpu,
            other => bail!("unknown method '{other}'"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Method::Magnitude => "magnitude",
            Method::Wanda => "wanda",
            Method::SparseGpt => "sparsegpt",
            Method::Rtn => "rtn",
            Method::Awq => "awq",
            Method::Gptq => "gptq",
            Method::AwqThenWanda => "awq+wanda",
            Method::WandaThenAwq => "wanda+awq",
            Method::AwpCpu => "awp-cpu",
            Method::AwpHlo => "awp",
        }
    }
}

/// Build a compressor. `runtime` is required only for [`Method::AwpHlo`].
///
/// Returns an `Arc` (compressors are stateless and `Send + Sync`) so one
/// instance can be shared across the executor's worker pool and across
/// table cells without rebuilding per job.
pub fn make_compressor(
    method: Method,
    hyper: AwpHyper,
    runtime: Option<(&RuntimeHandle, &Arc<Manifest>)>,
) -> Result<Arc<dyn LayerCompressor>> {
    Ok(match method {
        Method::Magnitude => Arc::new(MagnitudePrune),
        Method::Wanda => Arc::new(WandaPrune),
        Method::SparseGpt => Arc::new(SparseGpt::default()),
        Method::Rtn => Arc::new(RtnQuant),
        Method::Awq => Arc::new(AwqQuant::default()),
        Method::Gptq => Arc::new(Gptq::default()),
        Method::AwqThenWanda => Arc::new(SequentialCombo::awq_then_wanda()),
        Method::WandaThenAwq => Arc::new(SequentialCombo::wanda_then_awq()),
        Method::AwpCpu => Arc::new(AwpDriver::with_hyper(CpuBackend, hyper)),
        Method::AwpHlo => {
            let Some((handle, manifest)) = runtime else {
                bail!("awp (HLO backend) needs the PJRT runtime; use awp-cpu otherwise");
            };
            Arc::new(AwpDriver::with_hyper(
                HloBackend::new(handle.clone(), manifest.clone()),
                hyper,
            ))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in [Method::Magnitude, Method::Wanda, Method::SparseGpt, Method::Rtn,
                  Method::Awq, Method::Gptq, Method::AwqThenWanda,
                  Method::WandaThenAwq, Method::AwpCpu] {
            assert_eq!(Method::parse(m.label()).unwrap(), m);
        }
        assert_eq!(Method::parse("awp").unwrap(), Method::AwpHlo);
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn cpu_methods_construct_without_runtime() {
        for m in [Method::Magnitude, Method::Wanda, Method::SparseGpt, Method::Rtn,
                  Method::Awq, Method::Gptq, Method::AwqThenWanda,
                  Method::WandaThenAwq, Method::AwpCpu] {
            assert!(make_compressor(m, AwpHyper::default(), None).is_ok());
        }
        assert!(make_compressor(Method::AwpHlo, AwpHyper::default(), None).is_err());
    }
}
