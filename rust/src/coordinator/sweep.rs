//! Cross-model sweep scheduling — one level above the per-table cell pool.
//!
//! PR 1 parallelised the cells *within* one experiment table; this module
//! lifts that to the whole sweep: `experiment all` hands every table of
//! the run to [`run_tables`], which schedules **all models' cells through
//! one executor**. Two phases, both on the shared pool:
//!
//! 1. **prepare** — one job per distinct model (first-appearance order):
//!    train/load the checkpoint, fetch or compute the calibration Grams
//!    (through the [`super::cache`] subsystem), measure dense perplexity.
//!    A failing model aborts with `prepare <model>` attribution, lowest
//!    index first — the executor's usual fail-fast contract.
//! 2. **cells** — every `(table, method, spec)` cell of every table as one
//!    flat row-major job list, cost-weighted by the caller's FLOP model so
//!    the progress/ETA line tracks real work. Results come back in
//!    submission order, so the assembled tables are identical to a
//!    sequential run at any worker count, and a failing cell surfaces the
//!    lowest-index failure wrapped with its `table[model] method mode`
//!    label.
//!
//! The scheduling core is pure (closures in, `Table`s out) so the
//! determinism and attribution contracts are testable without the runtime
//! (`rust/tests/cross_model_sweep.rs`).
//!
//! With a compressed-artifact store installed (`--artifact-dir`,
//! `crate::artifact`), the cell phase is **incremental**: each cell's
//! `eval_cell` consults the store under its (Gram key, spec, method)
//! identity, so a warm rerun of a populated sweep assembles every cell
//! from packed sites and submits zero compression jobs — only the
//! evaluation (perplexity / reconstruction) reruns.

use anyhow::Result;

use super::executor::Executor;
use super::methods::Method;
use crate::compress::traits::{CompressionMode, CompressionSpec};
use crate::report::Table;

/// Compact spec tag for job labels: `prune50`, `int4`, `joint50+int4`, `2:4`.
pub fn spec_tag(spec: &CompressionSpec) -> String {
    match spec.mode {
        CompressionMode::Prune { ratio } => format!("prune{:.0}", ratio * 100.0),
        CompressionMode::Quant { spec } => format!("int{}", spec.bits),
        CompressionMode::Joint { ratio, spec } => {
            format!("joint{:.0}+int{}", ratio * 100.0, spec.bits)
        }
        CompressionMode::StructuredNm { n, m } => format!("{n}:{m}"),
        CompressionMode::JointNm { n, m, spec } => {
            format!("{n}:{m}+int{}", spec.bits)
        }
    }
}

/// One experiment table: `methods × specs` cells on one model.
#[derive(Clone, Debug)]
pub struct TableSpec {
    /// report key, e.g. `table1` (also the report file stem)
    pub name: String,
    pub model: String,
    pub col_header: String,
    /// one column label per spec
    pub columns: Vec<String>,
    pub methods: Vec<Method>,
    pub specs: Vec<CompressionSpec>,
    /// title pieces consumed by the caller's `title` closure (the
    /// experiment harness renders `"{prefix} '{model}' ({extra}dense = …)"`
    /// after the preparation phase has measured the dense baseline)
    pub title_prefix: String,
    pub title_extra: String,
}

impl TableSpec {
    pub fn n_cells(&self) -> usize {
        self.methods.len() * self.specs.len()
    }
}

/// One scheduled cell of a sweep (row-major within its table).
#[derive(Clone, Debug)]
pub struct CellRef {
    /// index into the `tables` slice passed to [`run_tables`]
    pub table: usize,
    pub model: String,
    pub method: Method,
    pub spec: CompressionSpec,
}

impl CellRef {
    /// Executor job label: `table1[small] wanda prune50`.
    pub fn label(&self, tables: &[TableSpec]) -> String {
        format!("{}[{}] {} {}", tables[self.table].name, self.model,
                self.method.label(), spec_tag(&self.spec))
    }
}

/// The distinct models of a sweep, in first-appearance (plan) order.
pub fn sweep_models(tables: &[TableSpec]) -> Vec<String> {
    let mut models: Vec<String> = Vec::new();
    for t in tables {
        if !models.iter().any(|m| *m == t.model) {
            models.push(t.model.clone());
        }
    }
    models
}

/// Flatten a sweep into its plan-ordered cell list (tables in order, cells
/// row-major within each table).
pub fn sweep_cells(tables: &[TableSpec]) -> Vec<CellRef> {
    let mut cells = Vec::with_capacity(tables.iter().map(TableSpec::n_cells).sum());
    for (ti, t) in tables.iter().enumerate() {
        for &method in &t.methods {
            for &spec in &t.specs {
                cells.push(CellRef { table: ti, model: t.model.clone(), method, spec });
            }
        }
    }
    cells
}

/// Run a whole multi-table, multi-model sweep on `exec`: prepare each
/// distinct model once, evaluate every cell, assemble one [`Table`] per
/// spec in input order. `title(t)` is rendered *after* preparation, so it
/// may read per-model state (dense perplexity) produced by `prep`.
///
/// Failure semantics follow the executor's fail-fast contract: one bad
/// cell aborts the whole schedule and no tables are assembled (completed
/// cells are discarded with it). That trade is deliberate — a rerun after
/// a failure is cheap, because checkpoints come from disk and Grams from
/// the calibration cache, so only the cells themselves recompute.
pub fn run_tables<P, E, C, T>(exec: &Executor, tables: &[TableSpec], prep: P,
                              eval: E, cost: C, title: T) -> Result<Vec<Table>>
where
    P: Fn(&str) -> Result<()> + Sync,
    E: Fn(&CellRef) -> Result<f64> + Sync,
    C: Fn(&CellRef) -> u64 + Sync,
    T: Fn(&TableSpec) -> String,
{
    // phase 1: per-model preparation jobs (checkpoint, Grams, dense ppl)
    let models = sweep_models(tables);
    exec.run(models.len(), |i| format!("prepare {}", models[i]),
             |i| prep(&models[i]))?;

    // phase 2: every cell of every table through one weighted pool run
    let cells = sweep_cells(tables);
    let run = exec.run_weighted(
        cells.len(),
        |i| cost(&cells[i]),
        |i| cells[i].label(tables),
        |i| eval(&cells[i]),
    )?;

    // phase 3: deterministic assembly in plan order
    let mut out = Vec::with_capacity(tables.len());
    let mut next = 0usize;
    for t in tables {
        let mut table = Table::new(title(t), t.col_header.clone(), t.columns.clone());
        for method in &t.methods {
            let row = &run.results[next..next + t.specs.len()];
            table.push_row(method.label().to_uppercase(),
                           row.iter().map(|&p| Some(p)).collect());
            next += t.specs.len();
        }
        out.push(table);
    }
    debug_assert_eq!(next, run.results.len());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    fn spec(name: &str, model: &str, methods: Vec<Method>) -> TableSpec {
        TableSpec {
            name: name.into(),
            model: model.into(),
            col_header: "method".into(),
            columns: vec!["50%".into()],
            methods,
            specs: vec![CompressionSpec::prune(0.5)],
            title_prefix: String::new(),
            title_extra: String::new(),
        }
    }

    #[test]
    fn models_and_cells_are_plan_ordered() {
        let tables = [
            spec("t1", "a", vec![Method::Magnitude, Method::Wanda]),
            spec("t2", "b", vec![Method::Magnitude]),
            spec("t3", "a", vec![Method::Wanda]),
        ];
        assert_eq!(sweep_models(&tables), vec!["a".to_string(), "b".to_string()]);
        let cells = sweep_cells(&tables);
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].label(&tables), "t1[a] magnitude prune50");
        assert_eq!(cells[2].label(&tables), "t2[b] magnitude prune50");
        assert_eq!(cells[3].table, 2);
    }

    #[test]
    fn each_model_is_prepared_exactly_once() {
        let tables = [
            spec("t1", "a", vec![Method::Magnitude]),
            spec("t2", "a", vec![Method::Wanda]),
            spec("t3", "b", vec![Method::Magnitude]),
        ];
        let prepped: Mutex<Vec<String>> = Mutex::new(Vec::new());
        run_tables(
            &Executor::with_workers(4),
            &tables,
            |m| {
                prepped.lock().unwrap().push(m.to_string());
                Ok(())
            },
            |_| Ok(1.0),
            |_| 1,
            |t| t.name.clone(),
        )
        .unwrap();
        let mut p = prepped.into_inner().unwrap();
        p.sort();
        assert_eq!(p, vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn failing_prep_names_the_model() {
        let tables = [spec("t1", "a", vec![Method::Magnitude]),
                      spec("t2", "b", vec![Method::Magnitude])];
        let err = run_tables(
            &Executor::sequential(),
            &tables,
            |m| {
                if m == "b" {
                    anyhow::bail!("no checkpoint");
                }
                Ok(())
            },
            |_| Ok(1.0),
            |_| 1,
            |t| t.name.clone(),
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("prepare b"), "{msg}");
        assert!(msg.contains("no checkpoint"), "{msg}");
    }
}
