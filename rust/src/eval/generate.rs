//! Greedy generation — through the AOT `decode_step` executable, or
//! through the native CPU forward pass (`--native`, no runtime) — the
//! user-facing proof that a compressed checkpoint still *is* a language
//! model (used by `examples/generate_demo.rs`).

use anyhow::{ensure, Result};

use crate::data::ByteTokenizer;
use crate::infer::NativeModel;
use crate::model::Checkpoint;
use crate::runtime::{HostTensor, Manifest, RuntimeHandle};

use super::perplexity::checkpoint_args;

/// Right-aligned decode window: the last `window` tokens of `tokens`,
/// left-padded with `pad` (the tokenizer's [`ByteTokenizer::pad_id`], not
/// a hard-coded byte) when the prompt is shorter than the window.
///
/// Only the AOT `decode_step` executable still consumes this — its program
/// is compiled for a fixed `(1, decode_len)` geometry. The native path
/// ([`native_generate`], `repro serve`) decodes through a growing
/// [`crate::infer::DecodeSession`] instead, where positions are stable and
/// the K/V cache makes each step O(ctx).
pub fn decode_window(tokens: &[i32], window: usize, pad: i32) -> Vec<i32> {
    let mut ctx = vec![pad; window];
    let take = tokens.len().min(window);
    ctx[window - take..].copy_from_slice(&tokens[tokens.len() - take..]);
    ctx
}

/// Greedy pick over a logit vector (ties break to the lowest id, like
/// `jnp.argmax`). Panics on an empty slice.
pub fn argmax(logits: &[f32]) -> i32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as i32)
        .unwrap()
}

/// Greedily extend `prompt` by `n_tokens` bytes with a sliding
/// `decode_len` window.
pub fn generate(handle: &RuntimeHandle, manifest: &Manifest, model: &str,
                ck: &Checkpoint, prompt: &str, n_tokens: usize) -> Result<String> {
    let entry = manifest.model(model)?;
    let window = entry.config.decode_len;
    let path = manifest.model_program_path(model, "decode_step")?;
    let params = checkpoint_args(ck)?;
    let tok = ByteTokenizer;
    let mut tokens: Vec<i32> = tok.encode(prompt.as_bytes());
    ensure!(!tokens.is_empty(), "prompt must be non-empty");
    for _ in 0..n_tokens {
        let ctx = decode_window(&tokens, window, tok.pad_id());
        let mut args = params.clone();
        args.push(HostTensor::vec_i32(ctx, vec![1, window]));
        let out = handle.execute("decode_step", path.clone(), args)?;
        tokens.push(argmax(out[0].as_f32()?));
    }
    Ok(tok.decode_lossy_string(&tokens))
}

/// Greedy generation through the native forward pass — no runtime, and
/// the model may hold packed sites ([`NativeModel::from_artifact`]): the
/// decode path that serves a compressed artifact without assembling it.
/// One KV-cached [`crate::infer::DecodeSession`] carries the whole run:
/// the prompt is prefilled in one batched pass, then each new token is an
/// O(ctx) `decode_step` over a growing left-aligned context (no sliding
/// window, no pad tokens — positions are stable, which is what lets the
/// cache be exact). Deterministic at any thread budget
/// (`rust/tests/native_forward.rs`, `rust/tests/serve_decode.rs`).
pub fn native_generate(model: &NativeModel, prompt: &str, n_tokens: usize)
    -> Result<String> {
    let tok = ByteTokenizer;
    let mut tokens: Vec<i32> = tok.encode(prompt.as_bytes());
    ensure!(!tokens.is_empty(), "prompt must be non-empty");
    let mut session = model.new_session(tokens.len() + n_tokens.max(1) - 1);
    let mut logits = model.prefill(&mut session, &tokens)?;
    for i in 0..n_tokens {
        let next = argmax(&logits);
        tokens.push(next);
        if i + 1 < n_tokens {
            // the final token's own logits are never consumed
            logits = model.decode_step(&mut session, next)?;
        }
    }
    Ok(tok.decode_lossy_string(&tokens))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_prompts_left_pad_with_the_tokenizer_pad() {
        let tok = ByteTokenizer;
        let prompt = tok.encode(b"ab");
        let ctx = decode_window(&prompt, 8, tok.pad_id());
        assert_eq!(ctx.len(), 8);
        assert_eq!(&ctx[..6], &vec![tok.pad_id(); 6][..]);
        assert_eq!(&ctx[6..], &[b'a' as i32, b'b' as i32]);
    }

    #[test]
    fn long_prompts_keep_the_window_tail() {
        let tokens: Vec<i32> = (0..20).collect();
        let ctx = decode_window(&tokens, 8, ByteTokenizer.pad_id());
        assert_eq!(ctx, (12..20).collect::<Vec<i32>>());
    }

    #[test]
    fn exact_window_needs_no_pad() {
        let tokens: Vec<i32> = (0..8).collect();
        let ctx = decode_window(&tokens, 8, ByteTokenizer.pad_id());
        assert_eq!(ctx, tokens);
    }

    #[test]
    fn argmax_breaks_ties_low() {
        assert_eq!(argmax(&[0.5, 1.0, 1.0, 0.1]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn native_generate_extends_a_short_prompt() {
        // prompt (1 byte) far shorter than decode_len: the window is
        // pad-filled and generation still proceeds deterministically
        use crate::model::ModelConfig;
        let cfg = ModelConfig {
            name: "t".into(), vocab: 256, d_model: 8, n_heads: 2, n_layers: 1,
            d_ff: 16, seq_len: 8, batch: 1, decode_len: 8, rope_theta: 1e4,
        };
        let ck = crate::trainer::init_checkpoint(&cfg, 2);
        let model = NativeModel::from_checkpoint(&ck).unwrap();
        let a = native_generate(&model, "a", 4).unwrap();
        // 1 prompt byte + 4 generated bytes (lossy utf-8 may re-group
        // high bytes, so compare through the tokenizer's decode)
        assert!(a.starts_with('a'));
        assert!(!a[1..].is_empty());
        // deterministic
        assert_eq!(a, native_generate(&model, "a", 4).unwrap());
        // empty prompt rejected
        assert!(native_generate(&model, "", 1).is_err());
    }
}
