//! Greedy generation through the AOT `decode_step` executable — the
//! user-facing proof that a compressed checkpoint still *is* a language
//! model (used by `examples/generate_demo.rs`).

use anyhow::{ensure, Result};

use crate::data::ByteTokenizer;
use crate::model::Checkpoint;
use crate::runtime::{HostTensor, Manifest, RuntimeHandle};

use super::perplexity::checkpoint_args;

/// Greedily extend `prompt` by `n_tokens` bytes with a sliding
/// `decode_len` window.
pub fn generate(handle: &RuntimeHandle, manifest: &Manifest, model: &str,
                ck: &Checkpoint, prompt: &str, n_tokens: usize) -> Result<String> {
    let entry = manifest.model(model)?;
    let window = entry.config.decode_len;
    let path = manifest.model_program_path(model, "decode_step")?;
    let params = checkpoint_args(ck)?;
    let tok = ByteTokenizer;
    let mut tokens: Vec<i32> = tok.encode(prompt.as_bytes());
    ensure!(!tokens.is_empty(), "prompt must be non-empty");
    for _ in 0..n_tokens {
        // right-align the last `window` tokens (pad left with spaces)
        let mut ctx = vec![b' ' as i32; window];
        let take = tokens.len().min(window);
        ctx[window - take..].copy_from_slice(&tokens[tokens.len() - take..]);
        let mut args = params.clone();
        args.push(HostTensor::vec_i32(ctx, vec![1, window]));
        let out = handle.execute("decode_step", path.clone(), args)?;
        let logits = out[0].as_f32()?;
        let next = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap();
        tokens.push(next);
    }
    Ok(tok.decode_lossy_string(&tokens))
}
