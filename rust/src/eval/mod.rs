//! Evaluation harness: held-out perplexity (the paper's metric), per-layer
//! reconstruction reporting, and greedy generation.

pub mod generate;
pub mod perplexity;
pub mod reconstruction;

pub use generate::generate;
pub use perplexity::{perplexity, PerplexityReport};
pub use reconstruction::{layer_report, recompute_report, LayerReport};
