//! Evaluation harness: held-out perplexity (the paper's metric), per-layer
//! reconstruction reporting, and greedy generation — each over two
//! backends: the AOT runtime programs, or the native CPU forward pass
//! (`crate::infer`, `--native`), which also executes packed artifacts
//! directly.

pub mod generate;
pub mod perplexity;
pub mod reconstruction;

pub use generate::{argmax, decode_window, generate, native_generate};
pub use perplexity::{native_perplexity, perplexity, PerplexityReport};
pub use reconstruction::{layer_report, recompute_report, LayerReport};
