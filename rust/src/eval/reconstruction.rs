//! Per-layer reconstruction reporting: the activation-aware loss, sparsity
//! and solver statistics for every compressed site — the audit trail behind
//! each table cell (and the source of Figure 1's series).

use crate::compress::CompressStats;
use crate::model::LayerSite;
use crate::sparse::SparsityStats;
use crate::tensor::Matrix;

/// One site's compression outcome.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub param: String,
    pub d_out: usize,
    pub d_in: usize,
    pub rel_loss: f64,
    pub sparsity: f64,
    pub row_uniform: bool,
    pub iterations: usize,
    pub seconds: f64,
}

pub fn layer_report(site: &LayerSite, theta: &Matrix, stats: &CompressStats)
    -> LayerReport {
    let sp = SparsityStats::of(theta);
    LayerReport {
        param: site.param.clone(),
        d_out: site.d_out,
        d_in: site.d_in,
        rel_loss: stats.rel_loss,
        sparsity: sp.ratio(),
        row_uniform: sp.is_row_uniform(),
        iterations: stats.iterations,
        seconds: stats.seconds,
    }
}

/// Aggregate a set of layer reports into (mean rel-loss, total seconds).
pub fn summarize(reports: &[LayerReport]) -> (f64, f64) {
    if reports.is_empty() {
        return (0.0, 0.0);
    }
    let mean = reports.iter().map(|r| r.rel_loss).sum::<f64>() / reports.len() as f64;
    let secs = reports.iter().map(|r| r.seconds).sum();
    (mean, secs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GramKey, SiteKind};

    #[test]
    fn report_captures_sparsity() {
        let site = LayerSite {
            param: "blocks.0.wq".into(), layer: 0, kind: SiteKind::AttnQ,
            d_out: 8, d_in: 8, gram: GramKey::AttnIn,
        };
        let theta = crate::tensor::topk::hard_threshold_rows(&Matrix::randn(8, 8, 0), 4);
        let stats = CompressStats { rel_loss: 0.25, iterations: 10, seconds: 0.5,
                                    ..Default::default() };
        let r = layer_report(&site, &theta, &stats);
        assert!((r.sparsity - 0.5).abs() < 1e-9);
        assert!(r.row_uniform);
        let (mean, secs) = summarize(&[r.clone(), r]);
        assert!((mean - 0.25).abs() < 1e-12);
        assert!((secs - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_summary() {
        assert_eq!(summarize(&[]), (0.0, 0.0));
    }
}
