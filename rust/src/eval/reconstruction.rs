//! Per-layer reconstruction reporting: the activation-aware loss, sparsity
//! and solver statistics for every compressed site — the audit trail behind
//! each table cell (and the source of Figure 1's series).

use crate::compress::CompressStats;
use crate::model::LayerSite;
use crate::sparse::SparsityStats;
use crate::tensor::Matrix;

/// One site's compression outcome.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub param: String,
    pub d_out: usize,
    pub d_in: usize,
    pub rel_loss: f64,
    pub sparsity: f64,
    pub row_uniform: bool,
    pub iterations: usize,
    pub seconds: f64,
}

pub fn layer_report(site: &LayerSite, theta: &Matrix, stats: &CompressStats)
    -> LayerReport {
    let sp = SparsityStats::of(theta);
    LayerReport {
        param: site.param.clone(),
        d_out: site.d_out,
        d_in: site.d_in,
        rel_loss: stats.rel_loss,
        sparsity: sp.ratio(),
        row_uniform: sp.is_row_uniform(),
        iterations: stats.iterations,
        seconds: stats.seconds,
    }
}

/// Recompute a site's quality report from a reconstructed Θ — the
/// `repro eval --from-artifact` path. Uses the same
/// [`ops::rel_activation_loss`](crate::tensor::ops::rel_activation_loss)
/// expression every compressor records via `CompressedLayer::from_theta`,
/// so a decoded Θ that is bit-identical to the in-memory compressed Θ
/// reproduces the compressor's rel-loss bit-for-bit. `iterations` and
/// `seconds` come from the artifact (they are historical facts of the
/// compression run, not recomputable from Θ).
pub fn recompute_report(param: &str, w: &Matrix, theta: &Matrix, c: &Matrix,
                        iterations: usize, seconds: f64) -> LayerReport {
    let sp = SparsityStats::of(theta);
    LayerReport {
        param: param.to_string(),
        d_out: theta.rows,
        d_in: theta.cols,
        rel_loss: crate::tensor::ops::rel_activation_loss(w, theta, c),
        sparsity: sp.ratio(),
        row_uniform: sp.is_row_uniform(),
        iterations,
        seconds,
    }
}

/// Aggregate a set of layer reports into (mean rel-loss, total seconds).
pub fn summarize(reports: &[LayerReport]) -> (f64, f64) {
    if reports.is_empty() {
        return (0.0, 0.0);
    }
    let mean = reports.iter().map(|r| r.rel_loss).sum::<f64>() / reports.len() as f64;
    let secs = reports.iter().map(|r| r.seconds).sum();
    (mean, secs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GramKey, SiteKind};

    #[test]
    fn report_captures_sparsity() {
        let site = LayerSite {
            param: "blocks.0.wq".into(), layer: 0, kind: SiteKind::AttnQ,
            d_out: 8, d_in: 8, gram: GramKey::AttnIn,
        };
        let theta = crate::tensor::topk::hard_threshold_rows(&Matrix::randn(8, 8, 0), 4);
        let stats = CompressStats { rel_loss: 0.25, iterations: 10, seconds: 0.5,
                                    ..Default::default() };
        let r = layer_report(&site, &theta, &stats);
        assert!((r.sparsity - 0.5).abs() < 1e-9);
        assert!(r.row_uniform);
        let (mean, secs) = summarize(&[r.clone(), r]);
        assert!((mean - 0.25).abs() < 1e-12);
        assert!((secs - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_summary() {
        assert_eq!(summarize(&[]), (0.0, 0.0));
    }

    #[test]
    fn recomputed_report_matches_compressor_stats_bitwise() {
        // the eval --from-artifact invariant: recomputing quality from a
        // bit-identical Θ reproduces the pipeline's recorded rel_loss
        use crate::compress::traits::CompressedLayer;
        let w = Matrix::randn(8, 16, 4);
        let c = Matrix::randn_gram(16, 5);
        let theta = crate::tensor::topk::hard_threshold_rows(&w, 8);
        let out = CompressedLayer::from_theta(&w, &c, theta.clone(), 3, 0.1);
        let rep = recompute_report("p", &w, &theta, &c, 3, 0.1);
        assert_eq!(rep.rel_loss.to_bits(), out.stats.rel_loss.to_bits());
        assert_eq!(rep.iterations, 3);
        assert!((rep.sparsity - 0.5).abs() < 1e-9);
    }
}
