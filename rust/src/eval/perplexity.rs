//! Held-out perplexity via the AOT `eval_loss` executable.
//!
//! Mirrors the paper's protocol: the compressed model's quality is the
//! exponentiated mean next-token NLL over a held-out split (their
//! WikiText-2 validation; our corpus' val region), evaluated with
//! non-overlapping windows for determinism.

use anyhow::{ensure, Result};

use crate::data::{Batcher, Split};
use crate::infer::NativeModel;
use crate::model::Checkpoint;
use crate::runtime::{HostTensor, Manifest, RuntimeHandle};

#[derive(Clone, Debug)]
pub struct PerplexityReport {
    pub ppl: f64,
    pub nll_per_token: f64,
    pub tokens: usize,
    pub batches: usize,
}

/// Checkpoint → flat HLO argument list (positional, validated).
pub fn checkpoint_args(ck: &Checkpoint) -> Result<Vec<HostTensor>> {
    ck.validate()?;
    Ok(ck
        .tensors
        .iter()
        .map(|(_, s, d)| HostTensor::vec_f32(d.clone(), s.clone()))
        .collect())
}

/// Perplexity of `ck` on `split`, using at most `max_batches` windows.
pub fn perplexity(handle: &RuntimeHandle, manifest: &Manifest, model: &str,
                  ck: &Checkpoint, batcher: &Batcher, split: Split,
                  max_batches: usize) -> Result<PerplexityReport> {
    let entry = manifest.model(model)?;
    ensure!(batcher.batch == entry.config.batch && batcher.seq == entry.config.seq_len,
            "batcher geometry mismatch");
    let path = manifest.model_program_path(model, "eval_loss")?;
    let params = checkpoint_args(ck)?;
    let n_batches = batcher.eval_batches(split).min(max_batches).max(1);
    let mut total_nll = 0.0f64;
    let mut total_tokens = 0.0f64;
    for i in 0..n_batches {
        let batch = batcher.eval_batch(split, i);
        let mut args = params.clone();
        args.push(HostTensor::vec_i32(batch.tokens, vec![batch.batch, batch.seq]));
        let out = handle.execute("eval_loss", path.clone(), args)?;
        ensure!(out.len() == 2, "eval_loss returned {} outputs", out.len());
        total_nll += out[0].scalar()?;
        total_tokens += out[1].scalar()?;
    }
    let nll = total_nll / total_tokens.max(1.0);
    Ok(PerplexityReport {
        ppl: nll.exp(),
        nll_per_token: nll,
        tokens: total_tokens as usize,
        batches: n_batches,
    })
}

/// Perplexity of `model` on `split` through the native CPU forward pass —
/// the runtime-free eval backend (`repro eval --native`). Same protocol as
/// [`perplexity`]: sequential non-overlapping windows, summed NLL over at
/// most `max_batches` of them. Works on dense and packed
/// [`NativeModel`]s alike, and the two produce bit-identical reports
/// (`rust/tests/native_forward.rs`).
pub fn native_perplexity(model: &NativeModel, batcher: &Batcher, split: Split,
                         max_batches: usize) -> Result<PerplexityReport> {
    let cfg = model.config();
    ensure!(batcher.batch == cfg.batch && batcher.seq == cfg.seq_len,
            "batcher geometry mismatch");
    let n_batches = batcher.eval_batches(split).min(max_batches).max(1);
    let mut total_nll = 0.0f64;
    let mut total_tokens = 0usize;
    for i in 0..n_batches {
        let batch = batcher.eval_batch(split, i);
        let (nll, count) = model.nll(&batch.tokens, batch.batch, batch.seq)?;
        total_nll += nll;
        total_tokens += count;
    }
    let nll = total_nll / (total_tokens.max(1)) as f64;
    Ok(PerplexityReport {
        ppl: nll.exp(),
        nll_per_token: nll,
        tokens: total_tokens,
        batches: n_batches,
    })
}

#[cfg(test)]
mod tests {
    // exercised end-to-end in rust/tests/integration_runtime.rs (needs
    // artifacts); the native backend's differential coverage lives in
    // rust/tests/native_forward.rs. Unit coverage here: argument assembly
    // and the native window protocol.
    use super::*;
    use crate::model::ModelConfig;

    #[test]
    fn checkpoint_args_positional() {
        let cfg = ModelConfig {
            name: "t".into(), vocab: 16, d_model: 8, n_heads: 2, n_layers: 1,
            d_ff: 16, seq_len: 8, batch: 1, decode_len: 8, rope_theta: 1e4,
        };
        let ck = crate::trainer::init_checkpoint(&cfg, 0);
        let args = checkpoint_args(&ck).unwrap();
        assert_eq!(args.len(), ck.tensors.len());
        assert_eq!(args[0].shape(), &[16, 8]); // embed first
    }

    #[test]
    fn native_perplexity_walks_sequential_windows() {
        use crate::data::{CorpusConfig, SyntheticCorpus};
        let cfg = ModelConfig {
            name: "t".into(), vocab: 256, d_model: 8, n_heads: 2, n_layers: 1,
            d_ff: 16, seq_len: 16, batch: 2, decode_len: 8, rope_theta: 1e4,
        };
        let ck = crate::trainer::init_checkpoint(&cfg, 1);
        let model = NativeModel::from_checkpoint(&ck).unwrap();
        let corpus = SyntheticCorpus::generate(CorpusConfig {
            total_bytes: 32 << 10,
            ..Default::default()
        });
        let batcher = Batcher::new(&corpus, 2, 16);
        let rep = native_perplexity(&model, &batcher, Split::Val, 3).unwrap();
        assert_eq!(rep.batches, 3);
        assert_eq!(rep.tokens, 3 * 2 * 15); // batch × (seq − 1) per window
        assert!(rep.ppl.is_finite() && rep.ppl > 1.0);
        // deterministic: a rerun reproduces the same bits
        let again = native_perplexity(&model, &batcher, Split::Val, 3).unwrap();
        assert_eq!(rep.ppl.to_bits(), again.ppl.to_bits());
        // geometry mismatch is an error
        let bad = Batcher::new(&corpus, 1, 16);
        assert!(native_perplexity(&model, &bad, Split::Val, 1).is_err());
    }
}
