//! Held-out perplexity via the AOT `eval_loss` executable.
//!
//! Mirrors the paper's protocol: the compressed model's quality is the
//! exponentiated mean next-token NLL over a held-out split (their
//! WikiText-2 validation; our corpus' val region), evaluated with
//! non-overlapping windows for determinism.

use anyhow::{ensure, Result};

use crate::data::{Batcher, Split};
use crate::model::Checkpoint;
use crate::runtime::{HostTensor, Manifest, RuntimeHandle};

#[derive(Clone, Debug)]
pub struct PerplexityReport {
    pub ppl: f64,
    pub nll_per_token: f64,
    pub tokens: usize,
    pub batches: usize,
}

/// Checkpoint → flat HLO argument list (positional, validated).
pub fn checkpoint_args(ck: &Checkpoint) -> Result<Vec<HostTensor>> {
    ck.validate()?;
    Ok(ck
        .tensors
        .iter()
        .map(|(_, s, d)| HostTensor::vec_f32(d.clone(), s.clone()))
        .collect())
}

/// Perplexity of `ck` on `split`, using at most `max_batches` windows.
pub fn perplexity(handle: &RuntimeHandle, manifest: &Manifest, model: &str,
                  ck: &Checkpoint, batcher: &Batcher, split: Split,
                  max_batches: usize) -> Result<PerplexityReport> {
    let entry = manifest.model(model)?;
    ensure!(batcher.batch == entry.config.batch && batcher.seq == entry.config.seq_len,
            "batcher geometry mismatch");
    let path = manifest.model_program_path(model, "eval_loss")?;
    let params = checkpoint_args(ck)?;
    let n_batches = batcher.eval_batches(split).min(max_batches).max(1);
    let mut total_nll = 0.0f64;
    let mut total_tokens = 0.0f64;
    for i in 0..n_batches {
        let batch = batcher.eval_batch(split, i);
        let mut args = params.clone();
        args.push(HostTensor::vec_i32(batch.tokens, vec![batch.batch, batch.seq]));
        let out = handle.execute("eval_loss", path.clone(), args)?;
        ensure!(out.len() == 2, "eval_loss returned {} outputs", out.len());
        total_nll += out[0].scalar()?;
        total_tokens += out[1].scalar()?;
    }
    let nll = total_nll / total_tokens.max(1.0);
    Ok(PerplexityReport {
        ppl: nll.exp(),
        nll_per_token: nll,
        tokens: total_tokens as usize,
        batches: n_batches,
    })
}

#[cfg(test)]
mod tests {
    // exercised end-to-end in rust/tests/integration_runtime.rs (needs
    // artifacts); unit coverage here is limited to argument assembly.
    use super::*;
    use crate::model::ModelConfig;

    #[test]
    fn checkpoint_args_positional() {
        let cfg = ModelConfig {
            name: "t".into(), vocab: 16, d_model: 8, n_heads: 2, n_layers: 1,
            d_ff: 16, seq_len: 8, batch: 1, decode_len: 8, rope_theta: 1e4,
        };
        let ck = crate::trainer::init_checkpoint(&cfg, 0);
        let args = checkpoint_args(&ck).unwrap();
        assert_eq!(args.len(), ck.tensors.len());
        assert_eq!(args[0].shape(), &[16, 8]); // embed first
    }
}
