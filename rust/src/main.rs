//! `repro` — the AWP reproduction CLI (Layer-3 entrypoint).
//!
//! ```text
//! repro train   --model small [--steps N]
//! repro eval    --model small [--checkpoint path] [--native [--fast]]
//!               # --native: perplexity through the native CPU forward pass
//!               # (rust/src/infer) — no AOT runtime needed; with
//!               # --from-artifact the block-linear sites execute straight
//!               # off the packed bytes (zero decode-to-dense assemblies);
//!               # --fast serves on the compressed-domain + SIMD kernel
//!               # tier (also: AWP_KERNEL_TIER=fast) — see KERNELS.md
//! repro compress --model small --method awp --mode prune --ratio 0.5 [--bits 4]
//!               # --mode also takes nm:N:M (semi-structured sparsity, e.g.
//!               # nm:2:4, nm:4:8) and jointnm:N:M (N:M ∩ INT grid from
//!               # --bits/--group); N:M runs on the CPU backend (awp-cpu)
//! repro generate --model small --prompt "..." [--tokens N] [--native [--fast]]
//! repro experiment table1|table2|table3|table4|table5|fig1|all [--awp-backend cpu|hlo]
//! repro e2e     # end-to-end driver: train → eval → compress → eval
//! repro info    # artifacts / manifest summary
//! repro inspect <file.apack>   # per-site footprint of a packed artifact
//! repro bench-json [--quick] [--out BENCH_10.json]
//!               # kernel-tier perf snapshot: GEMM GFLOP/s per compression
//!               # family (dense vs reference vs fast), native tokens/sec,
//!               # KV-cached vs uncached decode tokens/sec, batched vs
//!               # serial multi-session decode (continuous batching), and
//!               # the metrics-registry overhead gate (obs_overhead)
//! repro serve   --from-artifact <file.apack> [--addr host:port]
//!               [--max-ctx N] [--max-sessions N] [--max-batch N]
//!               [--max-kv-mb N] [--weight-budget-mb N]
//!               [--fast|--reference] [--log-json]
//!               # long-lived HTTP server over the native packed engine.
//!               # Weights are *paged*: serve opens the artifact by reading
//!               # only its header and materialises each site on first
//!               # touch; --weight-budget-mb bounds resident packed weights
//!               # with LRU eviction (0/absent = unlimited), so artifacts
//!               # larger than RAM serve fine — see ARTIFACTS.md.
//!               # /v1/generate (per-session KV-cached decode, continuous
//!               # batching across concurrent requests, ?stream=true for
//!               # chunked token streaming), /v1/perplexity, /v1/inspect,
//!               # /metrics (Prometheus text), /v1/stats (the same registry
//!               # as JSON), /healthz. Keep-alive connections, fast tier by
//!               # default; graceful SIGINT drain; --log-json switches the
//!               # per-request stderr line to JSONL — see SERVING.md and
//!               # OBSERVABILITY.md
//! ```
//!
//! Global flags: `--config <file.json>` (see rust/src/config), `--artifacts
//! <dir>`, `--jobs N` (size of the layer-job/table-cell worker pool;
//! default = thread budget, i.e. `AWP_THREADS` or the machine parallelism —
//! the executor splits the budget so outer workers × inner GEMM threads
//! stay ≤ it), `--cache-dir <dir>` / `--no-cache` (where the calibration
//! Grams persist; default `cache/grams`), `--artifact-dir <dir>` /
//! `--no-artifacts` (the compressed-artifact store, default
//! `cache/artifacts`: compressed sites persist bit-packed, keyed by (Gram
//! key, spec, method), so warm `compress`/`experiment` reruns submit zero
//! compression jobs), and `--synthetic` (runtime-free mode for
//! `compress`/`eval --from-artifact`: untrained checkpoint + synthetic
//! Grams, CPU methods only — exercises the cache subsystems on machines
//! without AOT artifacts). `--trace-out <file>` (any subcommand; most
//! useful on `serve` and `compress`) enables the span sink and writes a
//! Chrome trace-event JSON on exit — load it in `chrome://tracing` /
//! Perfetto (OBSERVABILITY.md). `repro compress` also takes `--timings` (per-
//! layer executor telemetry) and `--pack-out <file>` (emit the bit-packed
//! `AWPPACK1` artifact and print its footprint table; add `--pack2` for the
//! `AWPPACK2` container, whose per-site payloads are entropy-coded when that
//! wins — lossless, read transparently); `repro eval --from-artifact <file>`
//! reproduces quality numbers from the packed file alone (`--native
//! --weight-budget-mb N` routes it through the weight pager instead of the
//! eager load). The CLI is hand-rolled (the image has no argument-parsing
//! crate); see `Args` below.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use awp::artifact::{read_artifact, write_artifact_opts, ArtifactPager,
                    ArtifactStore};
use awp::compress::awp::AwpHyper;
use awp::compress::traits::CompressionSpec;
use awp::config::RunConfig;
use awp::coordinator::experiments::{self, ExperimentCtx};
use awp::coordinator::{
    compress_model_cached, compress_model_with, make_compressor, plan_jobs,
    GramCache, Method,
};
use awp::data::Split;
use awp::eval::{generate, native_generate, perplexity, recompute_report};
use awp::infer::NativeModel;
use awp::model::Checkpoint;
use awp::runtime::{Manifest, Runtime};
use awp::tensor::{simd, KernelTier};
use awp::trainer;

/// Minimal flag parser: positional subcommand + `--key value` pairs.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse() -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut it = std::env::args().skip(1).peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                flags.insert(key.to_string(), val);
            } else {
                positional.push(a);
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
            None => Ok(default),
        }
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
            None => Ok(default),
        }
    }
}

/// Kernel tier for `--native` serving: explicit `--fast` wins, otherwise
/// the `AWP_KERNEL_TIER` env knob (default: reference). Logged to stderr so
/// smoke scripts can assert which tier actually ran.
fn kernel_tier(args: &Args) -> KernelTier {
    let tier = if args.get("fast").is_some() {
        KernelTier::Fast
    } else {
        KernelTier::from_env()
    };
    eprintln!("[native] kernel tier: {} (simd: {})", tier.describe(),
              simd::backend_name());
    tier
}

/// Kernel tier for `repro serve`: the default is **Fast** — the fast tier
/// exists for the serving hot path — overridden by an explicit
/// `--reference`/`--fast` flag or the `AWP_KERNEL_TIER` env knob.
fn serve_tier(args: &Args) -> KernelTier {
    let tier = if args.get("fast").is_some() {
        KernelTier::Fast
    } else if args.get("reference").is_some() {
        KernelTier::Reference
    } else if std::env::var("AWP_KERNEL_TIER").is_ok() {
        KernelTier::from_env()
    } else {
        KernelTier::Fast
    };
    eprintln!("[serve] kernel tier: {} (simd: {})", tier.describe(),
              simd::backend_name());
    tier
}

fn run_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = RunConfig::default();
    if let Some(path) = args.get("config") {
        cfg.load_overrides(path)?;
    }
    if let Some(dir) = args.get("artifacts") {
        cfg.paths.artifacts = dir.into();
    }
    Ok(cfg)
}

/// `"N:M"` → `(n, m)` with the projection subsystem's validity rule.
fn parse_nm(s: &str) -> Result<(usize, usize)> {
    let (n, m) = s
        .split_once(':')
        .with_context(|| format!("'{s}' is not of the form N:M"))?;
    let n: usize = n.parse().with_context(|| format!("N in '{s}'"))?;
    let m: usize = m.parse().with_context(|| format!("M in '{s}'"))?;
    if !awp::proj::NmStructured::valid(n, m) {
        bail!("N:M needs 1 <= N <= M and M >= 2, got {n}:{m}");
    }
    Ok((n, m))
}

fn spec_from_args(args: &Args) -> Result<CompressionSpec> {
    let mode = args.get_or("mode", "prune");
    let ratio = args.get_f64("ratio", 0.5)?;
    let bits = args.get_usize("bits", 4)? as u8;
    let group = args.get_usize("group", 32)?;
    Ok(match mode.as_str() {
        "prune" => CompressionSpec::prune(ratio),
        "quant" => CompressionSpec::quant(bits, group),
        "joint" => CompressionSpec::joint(ratio, bits, group),
        // N:M semi-structured sparsity, e.g. nm:2:4, nm:4:8; jointnm:N:M
        // intersects the pattern with the INT grid from --bits/--group
        s if s.starts_with("nm:") => {
            let (n, m) = parse_nm(&s["nm:".len()..])?;
            CompressionSpec::structured_nm(n, m)
        }
        s if s.starts_with("jointnm:") => {
            let (n, m) = parse_nm(&s["jointnm:".len()..])?;
            CompressionSpec::joint_nm(n, m, bits, group)
        }
        other => bail!("unknown --mode '{other}' \
                        (prune|quant|joint|nm:N:M|jointnm:N:M)"),
    })
}

fn main() -> Result<()> {
    let args = Args::parse();
    // --trace-out: enable the span sink before any work runs, and write
    // the Chrome trace on the way out — even when `run` early-returns or
    // fails, so a crashed compress still leaves its trace behind
    let trace_out = args.get("trace-out").map(PathBuf::from);
    if trace_out.is_some() {
        awp::obs::trace::set_enabled(true);
    }
    let result = run(&args);
    if let Some(path) = &trace_out {
        match awp::obs::trace::write_chrome_trace(path) {
            Ok(n) => eprintln!("[trace] {n} spans written to {}",
                               path.display()),
            Err(e) => eprintln!("[trace] failed to write {}: {e:#}",
                                path.display()),
        }
    }
    result
}

fn run(args: &Args) -> Result<()> {
    let Some(cmd) = args.positional.first().cloned() else {
        eprintln!("usage: repro <train|eval|compress|generate|experiment|e2e|\
                   info|inspect|bench-json|serve> [flags]");
        std::process::exit(2);
    };
    let cfg = run_config(args)?;
    // `inspect` reads a packed artifact alone — no manifest or runtime
    if cmd == "inspect" {
        let path = args
            .positional
            .get(1)
            .context("usage: repro inspect <file.apack>")?;
        let art = read_artifact(Path::new(path))?;
        println!("artifact {path}: model '{}' · method {} · spec {}",
                 art.model, art.method, art.spec_desc);
        println!("identity: checkpoint {:016x} · calib {:016x} · packed with \
                  '{}'", art.checkpoint, art.calib, art.compressed_with);
        print!("{}", art.footprint_table().to_console());
        println!("total: packed {} bytes, dense {} bytes, ratio {:.2}x",
                 art.packed_bytes(), art.dense_bytes(),
                 art.dense_bytes() as f64 / art.packed_bytes().max(1) as f64);
        return Ok(());
    }
    // `bench-json` is pure CPU kernel timing — no manifest or runtime either
    if cmd == "bench-json" {
        let quick = args.get("quick").is_some();
        let out = args.get_or("out", "BENCH_10.json");
        eprintln!("[bench] kernel tiers on {} threads, simd: {}{}",
                  awp::util::parallel::num_threads(), simd::backend_name(),
                  if quick { " (quick)" } else { "" });
        awp::report::perf::write_bench_json(Path::new(&out), quick)?;
        println!("bench-json written to {out}");
        return Ok(());
    }
    let synthetic = args.get("synthetic").is_some();
    let manifest = if synthetic {
        Arc::new(Manifest::synthetic())
    } else {
        Arc::new(Manifest::load(&cfg.paths.artifacts)?)
    };
    let runtime = Runtime::start()?;
    let mut ctx = ExperimentCtx::new(runtime.handle(), manifest.clone(), cfg.clone());
    let jobs = match args.get("jobs") {
        Some(v) => Some(v.parse::<usize>().with_context(|| format!("--jobs {v}"))?),
        None => None,
    };
    ctx.set_jobs(jobs);
    ctx.set_progress(true);
    ctx.set_synthetic(synthetic);
    // calibration-artifact cache: disk layer on by default (cache/grams),
    // redirected by --cache-dir, disabled by --no-cache
    let cache_dir = if args.get("no-cache").is_some() {
        None
    } else {
        Some(args.get("cache-dir").map(PathBuf::from)
                 .unwrap_or_else(|| cfg.paths.gram_cache.clone()))
    };
    ctx.set_cache(Arc::new(GramCache::new(cache_dir)));
    // compressed-artifact store: disk layer on by default (cache/artifacts),
    // redirected by --artifact-dir, disabled by --no-artifacts
    let artifact_dir = if args.get("no-artifacts").is_some() {
        None
    } else {
        Some(args.get("artifact-dir").map(PathBuf::from)
                 .unwrap_or_else(|| cfg.paths.artifact_cache.clone()))
    };
    ctx.set_artifact_store(Arc::new(ArtifactStore::new(artifact_dir)));

    match cmd.as_str() {
        "info" => {
            println!("artifacts: {:?}", cfg.paths.artifacts);
            println!("awp chunk={} group={}", manifest.awp_chunk, manifest.awp_group);
            let mut names: Vec<_> = manifest.models.keys().collect();
            names.sort();
            for name in names {
                let e = manifest.model(name)?;
                println!("model {name:8} d={} ff={} L={} params={}",
                         e.config.d_model, e.config.d_ff, e.config.n_layers,
                         e.config.param_count());
            }
            println!("awp programs: {}", manifest.awp_programs.len());
        }
        "train" => {
            let model = args.get_or("model", "small");
            let mut tc = cfg.train_config(&model);
            if let Some(s) = args.get("steps") {
                tc.steps = s.parse()?;
                tc.warmup = (tc.steps / 10).max(1);
            }
            cfg.paths.ensure_dirs()?;
            let batcher = ctx.batcher(&model)?;
            let (ck, curve) =
                trainer::train(&runtime.handle(), &manifest, &model, &batcher, &tc)?;
            let path = cfg.paths.checkpoint_file(&model);
            ck.save(&path)?;
            println!("saved {path:?} (final loss {:.4})",
                     curve.last().map(|(_, l)| *l).unwrap_or(f64::NAN));
        }
        "eval" => {
            let native = args.get("native").is_some();
            if let Some(apath) = args.get("from-artifact") {
                if native && args.get("weight-budget-mb").is_some() {
                    // paged route: open by header only, materialise sites
                    // on first touch, LRU-evict under the byte budget —
                    // same bits as the eager load at the reference tier
                    let budget_mb = args.get_usize("weight-budget-mb", 0)?;
                    let pager = Arc::new(ArtifactPager::open(
                        Path::new(apath),
                        match budget_mb {
                            0 => None,
                            mb => Some(mb << 20),
                        },
                    )?);
                    let model = pager.header().model.clone();
                    let ck = ctx.checkpoint(&model)?;
                    let gk = ctx.gram_key(&model)?;
                    let h = pager.header();
                    if h.checkpoint != gk.checkpoint || h.calib != gk.calib {
                        bail!("artifact {apath} identity mismatch: packed \
                               against checkpoint {:016x}/calib {:016x}, \
                               current run is {:016x}/{:016x}",
                              h.checkpoint, h.calib, gk.checkpoint, gk.calib);
                    }
                    let mut nm = NativeModel::from_pager(&ck, pager.clone())?;
                    nm.set_tier(kernel_tier(args));
                    eprintln!("[native] {} sites packed, {} decode-to-dense \
                               assemblies", nm.packed_site_count(),
                              nm.dense_site_count());
                    let rep = ctx.native_ppl(&model, &nm)?;
                    println!("ppl = {:.4}  (nll/token {:.4}, {} tokens, \
                              {} windows) [native, paged artifact]",
                             rep.ppl, rep.nll_per_token, rep.tokens,
                             rep.batches);
                    let pc = pager.counts();
                    eprintln!("[pager] {} hits, {} misses, {} evictions, \
                               {} bytes resident", pc.hits, pc.misses,
                              pc.evictions, pager.resident_bytes());
                    return Ok(());
                }
                // quality numbers from the packed file alone: decode the
                // artifact's sites (bit-identical to the pipeline output)
                // over the base checkpoint and evaluate that assembly
                let art = read_artifact(Path::new(apath))?;
                let model = art.model.clone();
                let ck = ctx.checkpoint(&model)?;
                let gk = ctx.gram_key(&model)?;
                if art.checkpoint != gk.checkpoint || art.calib != gk.calib {
                    bail!("artifact {apath} identity mismatch: packed against \
                           checkpoint {:016x}/calib {:016x}, current run is \
                           {:016x}/{:016x}", art.checkpoint, art.calib,
                          gk.checkpoint, gk.calib);
                }
                if native {
                    // packed serving: block-linear sites execute straight
                    // off the packed bytes through the native forward pass
                    // — no AOT runtime, no decode-to-dense assembly
                    let mut nm = NativeModel::from_artifact(&ck, &art)?;
                    nm.set_tier(kernel_tier(args));
                    eprintln!("[native] {} sites packed, {} decode-to-dense \
                               assemblies", nm.packed_site_count(),
                              nm.dense_site_count());
                    let rep = ctx.native_ppl(&model, &nm)?;
                    println!("ppl = {:.4}  (nll/token {:.4}, {} tokens, \
                              {} windows) [native, from artifact]",
                             rep.ppl, rep.nll_per_token, rep.tokens,
                             rep.batches);
                    return Ok(());
                }
                if ctx.synthetic() {
                    // no runtime ⇒ no perplexity; recompute the per-site
                    // reconstruction quality from the decoded weights —
                    // bit-identical to the dense compress run's numbers
                    let grams = ctx.grams(&model)?;
                    let plan = plan_jobs(&ck.config);
                    let mut sum = 0.0f64;
                    for job in &plan.jobs {
                        let s = art
                            .sites
                            .iter()
                            .find(|s| s.param == job.site.param)
                            .with_context(|| format!("artifact misses site {}",
                                                     job.site.param))?;
                        let w = ck.matrix(&job.site.param)?;
                        let c = grams
                            .get(job.site.gram, job.site.layer)
                            .context("missing Gram")?;
                        let rep = recompute_report(&s.param, &w,
                                                   &s.packed.decode(), c,
                                                   s.report.iterations,
                                                   s.report.seconds);
                        sum += rep.rel_loss;
                    }
                    let mean = sum / plan.jobs.len().max(1) as f64;
                    println!("{} {}: mean rel_loss {mean:.4}  ({} sites) \
                              [synthetic, from artifact]",
                             art.method, art.spec_desc, art.sites.len());
                } else {
                    // plan-coverage gate (mirrors the synthetic branch and
                    // the warm-pipeline assembly): every compressible site
                    // must come from the artifact, or the "ppl [from
                    // artifact]" number would silently mix dense weights in
                    let plan = plan_jobs(&ck.config);
                    let mut tensors = Vec::with_capacity(plan.jobs.len());
                    for job in &plan.jobs {
                        let s = art
                            .sites
                            .iter()
                            .find(|s| s.param == job.site.param)
                            .with_context(|| format!("artifact misses site {}",
                                                     job.site.param))?;
                        tensors.push((s.param.clone(), s.packed.decode().data));
                    }
                    let compressed = ck.with_tensors(tensors)?;
                    let batcher = ctx.batcher(&model)?;
                    let rep = perplexity(&runtime.handle(), &manifest, &model,
                                         &compressed, &batcher, Split::Val,
                                         cfg.eval_batches)?;
                    println!("ppl = {:.4}  (nll/token {:.4}, {} tokens, \
                              {} windows) [from artifact]",
                             rep.ppl, rep.nll_per_token, rep.tokens, rep.batches);
                }
                return Ok(());
            }
            let model = args.get_or("model", "small");
            let ck = match args.get("checkpoint") {
                Some(p) => Arc::new(Checkpoint::load(p)?),
                None => ctx.checkpoint(&model)?,
            };
            if native {
                let mut nm = NativeModel::from_checkpoint(&ck)?;
                nm.set_tier(kernel_tier(args));
                eprintln!("[native] {} sites dense f32",
                          nm.dense_site_count());
                let rep = ctx.native_ppl(&model, &nm)?;
                println!("ppl = {:.4}  (nll/token {:.4}, {} tokens, \
                          {} windows) [native]",
                         rep.ppl, rep.nll_per_token, rep.tokens, rep.batches);
                return Ok(());
            }
            let batcher = ctx.batcher(&model)?;
            let rep = perplexity(&runtime.handle(), &manifest, &model, &ck,
                                 &batcher, Split::Val, cfg.eval_batches)?;
            println!("ppl = {:.4}  (nll/token {:.4}, {} tokens, {} windows)",
                     rep.ppl, rep.nll_per_token, rep.tokens, rep.batches);
        }
        "compress" => {
            let model = args.get_or("model", "small");
            let method = Method::parse(&args.get_or("method", "awp"))?;
            let spec = spec_from_args(args)?;
            let ck = ctx.checkpoint(&model)?;
            let grams = ctx.grams(&model)?;
            let hyper = AwpHyper { group: manifest.awp_group,
                                   chunk: manifest.awp_chunk,
                                   ..AwpHyper::default() };
            let compressor = make_compressor(method, hyper,
                                             Some((&runtime.handle(), &manifest)))?;
            let exec = ctx.executor();
            // pack an artifact only when a consumer exists — the store (on
            // by default) or an explicit --pack-out; with both disabled,
            // skip the per-site encode work entirely
            let pack_out = args.get("pack-out").map(str::to_string);
            let (out, artifact) = if ctx.artifact_store().enabled()
                || pack_out.is_some()
            {
                let akey = ctx.artifact_key(&model, method, &spec)?;
                let cached = compress_model_cached(&ck, &grams,
                                                   compressor.as_ref(), &spec,
                                                   true, &exec,
                                                   ctx.artifact_store(), &akey)?;
                if cached.warm {
                    eprintln!("[artifact] warm run: {} sites assembled from \
                               the artifact store, 0 compression jobs \
                               submitted", cached.artifact.sites.len());
                }
                (cached.result, Some(cached.artifact))
            } else {
                (compress_model_with(&ck, &grams, compressor.as_ref(), &spec,
                                     true, &exec)?,
                 None)
            };
            if ctx.synthetic() {
                // no runtime ⇒ no perplexity; report reconstruction stats
                let mean_loss = out.reports.iter().map(|r| r.rel_loss).sum::<f64>()
                    / out.reports.len().max(1) as f64;
                println!("{} {:?}: mean rel_loss {mean_loss:.4}  ({:.1}s, \
                          {} layers, {} workers × {} threads) [synthetic]",
                         method.label(), spec.mode, out.seconds, out.reports.len(),
                         exec.workers(), exec.inner_threads());
            } else {
                let dense = ctx.dense_ppl(&model)?;
                let ppl = ctx.ppl(&model, &out.checkpoint)?;
                println!("{} {:?}: ppl {dense:.3} → {ppl:.3}  ({:.1}s, {} layers, \
                          {} workers × {} threads)",
                         method.label(), spec.mode, out.seconds, out.reports.len(),
                         exec.workers(), exec.inner_threads());
            }
            let c = ctx.cache().counts();
            eprintln!("[cache] session counts: {} memory hits, {} disk hits, \
                       {} misses", c.mem_hits, c.disk_hits, c.misses);
            let ac = ctx.artifact_store().counts();
            eprintln!("[artifact] session counts: {} hits, {} misses, \
                       {} stores", ac.hits, ac.misses, ac.stores);
            if let Some(path) = &pack_out {
                let art = artifact.as_ref().expect("--pack-out implies packing");
                // --pack2: AWPPACK2 container — per-site entropy coding
                // where it wins, bit-identical on read, never larger
                let pack2 = args.get("pack2").is_some();
                write_artifact_opts(Path::new(path), art, pack2)?;
                print!("{}", art.footprint_table().to_console());
                let disk = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
                println!("packed artifact written to {path} ({}): {} dense \
                          bytes → {} packed, {} on disk ({:.2}x)",
                         if pack2 { "AWPPACK2" } else { "AWPPACK1" },
                         art.dense_bytes(), art.packed_bytes(), disk,
                         art.dense_bytes() as f64
                             / art.packed_bytes().max(1) as f64);
            }
            if args.get("timings").is_some() {
                let rows: Vec<(String, f64, u64)> = out
                    .job_stats
                    .iter()
                    .map(|s| (s.label.clone(), s.seconds, s.cost))
                    .collect();
                println!("{}", awp::report::timing_table_weighted(
                                   "layer-job timings", &rows).to_console());
            }
            if let Some(path) = args.get("save") {
                out.checkpoint.save(path)?;
                println!("saved compressed checkpoint to {path}");
            }
        }
        "generate" => {
            let model = args.get_or("model", "small");
            let prompt = args.get_or("prompt", "The ");
            let n = args.get_usize("tokens", 120)?;
            let ck = match args.get("checkpoint") {
                Some(p) => Arc::new(Checkpoint::load(p)?),
                None => ctx.checkpoint(&model)?,
            };
            let text = if args.get("native").is_some() {
                let mut nm = NativeModel::from_checkpoint(&ck)?;
                nm.set_tier(kernel_tier(args));
                native_generate(&nm, &prompt, n)?
            } else {
                generate(&runtime.handle(), &manifest, &model, &ck, &prompt, n)?
            };
            println!("{text}");
        }
        "experiment" => {
            let which = args
                .positional
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("all")
                .to_string();
            let awp = match args.get_or("awp-backend", "cpu").as_str() {
                // both backends are numerically interchangeable (verified in
                // rust/tests/); cpu is the fast path on this testbed, hlo
                // exercises the production AOT artifacts.
                "cpu" => Method::AwpCpu,
                "hlo" => Method::AwpHlo,
                other => bail!("--awp-backend {other}? (cpu|hlo)"),
            };
            match which.as_str() {
                "table1" => { experiments::table1(&ctx, awp)?; }
                "table2" => { experiments::table2(&ctx, awp)?; }
                "table3" => { experiments::table3(&ctx, awp)?; }
                "table4" => { experiments::table4(&ctx, awp)?; }
                "table5" => { experiments::table5(&ctx, awp)?; }
                "fig1" => {
                    let layer = args.get_or("layer", "blocks.1.wq");
                    let ratio = args.get_f64("ratio", 0.5)?;
                    experiments::fig1(&ctx, &layer, ratio)?;
                }
                "ablation24" => { experiments::ablation24(&ctx)?; }
                // one cross-model schedule: every table's cells through the
                // shared executor, per-model prep jobs in parallel
                "all" => { experiments::run_all(&ctx, awp)?; }
                other => bail!("unknown experiment '{other}'"),
            }
            let c = ctx.cache().counts();
            eprintln!("[cache] session counts: {} memory hits, {} disk hits, \
                       {} misses", c.mem_hits, c.disk_hits, c.misses);
            let ac = ctx.artifact_store().counts();
            eprintln!("[artifact] session counts: {} hits, {} misses, \
                       {} stores", ac.hits, ac.misses, ac.stores);
        }
        "e2e" => {
            // end-to-end driver: train → dense ppl → AWP 50% + INT4 joint →
            // compressed ppl → short generation (DESIGN.md §6).
            let model = args.get_or("model", "small");
            let ck = ctx.checkpoint(&model)?;
            let dense = ctx.dense_ppl(&model)?;
            println!("[e2e] dense ppl = {dense:.3}");
            let grams = ctx.grams(&model)?;
            let hyper = AwpHyper { group: manifest.awp_group,
                                   chunk: manifest.awp_chunk,
                                   ..AwpHyper::default() };
            let spec = CompressionSpec::joint(0.5, 4, manifest.awp_group);
            let compressor = make_compressor(Method::AwpHlo, hyper,
                                             Some((&runtime.handle(), &manifest)))?;
            let out = if ctx.artifact_store().enabled() {
                let akey = ctx.artifact_key(&model, Method::AwpHlo, &spec)?;
                compress_model_cached(&ck, &grams, compressor.as_ref(), &spec,
                                      true, &ctx.executor(),
                                      ctx.artifact_store(), &akey)?
                    .result
            } else {
                compress_model_with(&ck, &grams, compressor.as_ref(), &spec,
                                    true, &ctx.executor())?
            };
            let ppl = ctx.ppl(&model, &out.checkpoint)?;
            println!("[e2e] AWP joint 50% + INT4 (HLO backend): ppl = {ppl:.3} \
                      ({:.1}s over {} layers)", out.seconds, out.reports.len());
            let sample = generate(&runtime.handle(), &manifest, &model,
                                  &out.checkpoint, "The ", 80)?;
            println!("[e2e] sample from compressed model: {sample:?}");
            let stats = runtime.handle().stats()?;
            println!("[e2e] runtime: {} executions, {} compilations, \
                      exec {:.1}s, compile {:.1}s",
                     stats.executions, stats.compilations,
                     stats.exec_seconds, stats.compile_seconds);
        }
        "serve" => {
            // long-lived serving over the weight pager: open the artifact
            // by reading only its header, verify identity against the
            // current checkpoint/calibration exactly like `eval
            // --from-artifact`, and page sites in on first touch —
            // --weight-budget-mb bounds resident packed weights with LRU
            // eviction so artifacts larger than RAM still serve. The CLI
            // logs the zero decode-to-dense count the CI smoke pins
            let apath = args
                .get("from-artifact")
                .context("repro serve requires --from-artifact <file.apack>")?;
            // resident packed-weight budget in MiB; 0 / absent = unlimited
            let budget_mb = args.get_usize("weight-budget-mb", 0)?;
            let pager = Arc::new(ArtifactPager::open(
                Path::new(apath),
                match budget_mb {
                    0 => None,
                    mb => Some(mb << 20),
                },
            )?);
            let model = pager.header().model.clone();
            let ck = ctx.checkpoint(&model)?;
            let gk = ctx.gram_key(&model)?;
            let (method, spec_desc, packed_bytes) = {
                let h = pager.header();
                if h.checkpoint != gk.checkpoint || h.calib != gk.calib {
                    bail!("artifact {apath} identity mismatch: packed against \
                           checkpoint {:016x}/calib {:016x}, current run is \
                           {:016x}/{:016x}", h.checkpoint, h.calib,
                          gk.checkpoint, gk.calib);
                }
                (h.method.clone(), h.spec_desc.clone(), h.packed_bytes())
            };
            let mut nm = NativeModel::from_pager(&ck, pager.clone())?;
            nm.set_tier(serve_tier(args));
            eprintln!("[serve] {} sites packed, {} decode-to-dense \
                       assemblies", nm.packed_site_count(),
                      nm.dense_site_count());
            eprintln!("[serve] weight pager: {} sites, {} packed bytes, \
                       budget {}", pager.site_count(), packed_bytes,
                      if budget_mb == 0 { "unlimited".to_string() }
                      else { format!("{budget_mb} MiB") });
            let limits = awp::serve::ServeLimits {
                max_ctx: args
                    .get_usize("max-ctx", (ck.config.seq_len * 8).max(512))?,
                max_sessions: args.get_usize("max-sessions", 64)?,
                max_batch: args.get_usize("max-batch", 8)?,
                // resident KV budget in MiB; 0 / absent = unlimited
                max_kv_bytes: match args.get_usize("max-kv-mb", 0)? {
                    0 => usize::MAX,
                    mb => mb * (1 << 20),
                },
            };
            eprintln!("[serve] limits: max_ctx={} max_sessions={} \
                       max_batch={} max_kv_mb={}",
                      limits.max_ctx, limits.max_sessions, limits.max_batch,
                      if limits.max_kv_bytes == usize::MAX { 0 }
                      else { limits.max_kv_bytes >> 20 });
            let info = awp::serve::ServeInfo {
                model: model.clone(),
                source: apath.to_string(),
                method,
                spec: spec_desc,
                packed_bytes,
            };
            let exec = ctx.executor();
            let state = awp::serve::ServeState::new(nm, info, exec, limits)
                .with_log_json(args.get("log-json").is_some());
            let addr = args.get_or("addr", "127.0.0.1:8080");
            let listener = std::net::TcpListener::bind(&addr)
                .with_context(|| format!("cannot bind {addr}"))?;
            awp::serve::install_signal_handlers();
            let server = awp::serve::Server::new(state, exec);
            server.serve(listener, awp::serve::shutdown_flag())?;
        }
        other => bail!("unknown command '{other}'"),
    }
    Ok(())
}
