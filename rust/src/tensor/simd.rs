//! Fast-tier SIMD substrate: the runtime-dispatched row-panel primitives
//! behind [`KernelTier::Fast`], plus the tier selector itself.
//!
//! The repo's reference kernels ([`super::ops::matmul_row_panel`], the
//! packed GEMMs in `artifact::packed`) are deliberately bit-identical to
//! each other — same blocking, same accumulation order — which makes them
//! the *oracle* but pins them to scalar adds in a fixed order. The fast
//! tier trades that bitwise pin for speed: explicit AVX2+FMA panels when
//! the CPU has them (detected once at runtime), a portable unrolled scalar
//! fallback otherwise. FMA fuses the multiply-add rounding step and the
//! panels accumulate in a different association order, so fast-tier output
//! is validated against the reference tier by *tolerance*, never by bits
//! (`rust/tests/fast_kernels.rs`; bounds documented in KERNELS.md).
//!
//! `std::simd` is nightly-only and the CI toolchain is stable, so the SIMD
//! path uses `core::arch::x86_64` intrinsics behind
//! `is_x86_feature_detected!` (the "explicit AVX2 path" ROADMAP names);
//! non-x86 targets compile the scalar fallback only.
//!
//! Everything here is a *panel* primitive operating on raw slices — the
//! tier-dispatching GEMMs live in [`super::ops`] (dense) and
//! `artifact::packed` (compressed domain), which parallelise over output
//! rows and call into these per row. Each output row is computed
//! sequentially by exactly one worker, so the fast tier is thread-count
//! invariant bit-for-bit, just like the reference tier.

/// Which GEMM implementation the serving path runs.
///
/// * [`KernelTier::Reference`] — the bit-identical oracle kernels
///   (streaming dequant / survivor-only sparse / dense row panel, all
///   sharing one accumulation order). Default everywhere.
/// * [`KernelTier::Fast`] — compressed-domain + SIMD kernels: integer-
///   accumulate GEMM for `GroupedInt`, palette-LUT GEMM for `Palette`,
///   cache-blocked survivor-only GEMM for `SparseMask`, SIMD row panels
///   for dense. Within documented tolerance of the reference tier, not
///   bitwise. CLI: `--fast` on `repro eval/generate --native`; env:
///   `AWP_KERNEL_TIER=fast`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelTier {
    #[default]
    Reference,
    Fast,
}

impl KernelTier {
    /// Parse a tier name (`"fast"`, `"reference"`/`"ref"`), case-insensitive.
    pub fn parse(s: &str) -> Option<KernelTier> {
        match s.to_ascii_lowercase().as_str() {
            "fast" => Some(KernelTier::Fast),
            "reference" | "ref" => Some(KernelTier::Reference),
            _ => None,
        }
    }

    /// Tier from the `AWP_KERNEL_TIER` env knob; unset ⇒ `Reference`,
    /// unrecognised ⇒ `Reference` with a warning on stderr.
    pub fn from_env() -> KernelTier {
        match std::env::var("AWP_KERNEL_TIER") {
            Ok(v) => KernelTier::parse(&v).unwrap_or_else(|| {
                eprintln!("[kernels] unknown AWP_KERNEL_TIER '{v}' \
                           (fast|reference), using reference");
                KernelTier::Reference
            }),
            Err(_) => KernelTier::Reference,
        }
    }

    pub fn describe(self) -> &'static str {
        match self {
            KernelTier::Reference => "reference",
            KernelTier::Fast => "fast",
        }
    }

    pub fn is_fast(self) -> bool {
        self == KernelTier::Fast
    }
}

#[cfg(target_arch = "x86_64")]
fn use_avx2() -> bool {
    use std::sync::OnceLock;
    static AVX2: OnceLock<bool> = OnceLock::new();
    *AVX2.get_or_init(|| {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    })
}

/// Name of the SIMD backend the fast tier selected at runtime —
/// `"avx2+fma"` or `"portable-scalar"` (logged by the CLI and recorded in
/// `BENCH_*.json` so perf numbers are comparable across machines).
pub fn backend_name() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        return "avx2+fma";
    }
    "portable-scalar"
}

/// Fast row panel `orow += arow · B`, where `bdata` holds `arow.len()`
/// rows of width `n` contiguously (a sub-range of a row-major matrix is
/// fine — the per-group quantized kernel passes one group's B rows).
/// `orow` must arrive zeroed or holding a partial accumulation.
pub fn row_panel_fast(arow: &[f32], bdata: &[f32], n: usize, orow: &mut [f32]) {
    assert!(bdata.len() >= arow.len() * n, "B panel too short");
    assert_eq!(orow.len(), n);
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // Safety: AVX2+FMA presence checked once via use_avx2()
        unsafe { x86::row_panel(arow, bdata, n, orow) };
        return;
    }
    row_panel_scalar(arow, bdata, n, orow);
}

fn row_panel_scalar(arow: &[f32], bdata: &[f32], n: usize, orow: &mut [f32]) {
    let k = arow.len();
    let mut kk = 0usize;
    while kk + 4 <= k {
        let (a0, a1, a2, a3) = (arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]);
        if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
            let b0 = &bdata[kk * n..kk * n + n];
            let b1 = &bdata[(kk + 1) * n..(kk + 1) * n + n];
            let b2 = &bdata[(kk + 2) * n..(kk + 2) * n + n];
            let b3 = &bdata[(kk + 3) * n..(kk + 3) * n + n];
            for j in 0..n {
                orow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
        }
        kk += 4;
    }
    while kk < k {
        let av = arow[kk];
        if av != 0.0 {
            axpy_scalar(av, &bdata[kk * n..kk * n + n], orow);
        }
        kk += 1;
    }
}

/// Fast `y += a · x` over equal-length slices.
pub fn axpy_fast(a: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // Safety: AVX2+FMA presence checked once via use_avx2()
        unsafe { x86::axpy(a, x, y) };
        return;
    }
    axpy_scalar(a, x, y);
}

fn axpy_scalar(a: f32, x: &[f32], y: &mut [f32]) {
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv += a * xv;
    }
}

/// Fast 4-row panel over *non-contiguous* B rows:
/// `orow += a[0]·r0 + a[1]·r1 + a[2]·r2 + a[3]·r3` — the survivor-quad
/// primitive of the cache-blocked sparse GEMM (each `r` is one surviving
/// coefficient's B-row slice within the current column block).
pub fn panel4_fast(a: [f32; 4], r0: &[f32], r1: &[f32], r2: &[f32],
                   r3: &[f32], orow: &mut [f32]) {
    let n = orow.len();
    assert!(r0.len() == n && r1.len() == n && r2.len() == n && r3.len() == n);
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // Safety: AVX2+FMA presence checked once via use_avx2()
        unsafe { x86::panel4(a, r0, r1, r2, r3, orow) };
        return;
    }
    for j in 0..n {
        orow[j] += a[0] * r0[j] + a[1] * r1[j] + a[2] * r2[j] + a[3] * r3[j];
    }
}

/// Fast grouped-int rescale `orow += s·gacc − szp·sums` — the once-per-
/// group epilogue of the integer-accumulate GEMM (`gacc` is the raw code
/// accumulation, `sums` the per-group activation column sums, `szp =
/// scale·zero_point`).
pub fn rescale_add_fast(orow: &mut [f32], gacc: &[f32], sums: &[f32],
                        s: f32, szp: f32) {
    let n = orow.len();
    assert!(gacc.len() == n && sums.len() == n);
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // Safety: AVX2+FMA presence checked once via use_avx2()
        unsafe { x86::rescale_add(orow, gacc, sums, s, szp) };
        return;
    }
    for j in 0..n {
        orow[j] += s * gacc[j] - szp * sums[j];
    }
}

/// Fused two-group rescale `orow += sa·ga − szpa·suma; orow += sb·gb −
/// szpb·sumb` in a single pass over the output row — the batched epilogue
/// of the integer-accumulate GEMM. The epilogue's output-row traffic only
/// matters when the row is wide, i.e. when many activation columns (a
/// decode batch of sessions) ride through one launch; folding two groups
/// into one load/store pass halves it there. The arithmetic is applied in
/// the same per-element order as two [`rescale_add_fast`] calls, so the
/// result is bit-identical to the unfused epilogue on either backend.
#[allow(clippy::too_many_arguments)]
pub fn rescale_add2_fast(orow: &mut [f32], ga: &[f32], suma: &[f32], sa: f32,
                         szpa: f32, gb: &[f32], sumb: &[f32], sb: f32,
                         szpb: f32) {
    let n = orow.len();
    assert!(ga.len() == n && suma.len() == n);
    assert!(gb.len() == n && sumb.len() == n);
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // Safety: AVX2+FMA presence checked once via use_avx2()
        unsafe { x86::rescale_add2(orow, ga, suma, sa, szpa, gb, sumb, sb, szpb) };
        return;
    }
    for j in 0..n {
        orow[j] += sa * ga[j] - szpa * suma[j];
        orow[j] += sb * gb[j] - szpb * sumb[j];
    }
}

/// Fast element-wise `y += x`.
pub fn add_assign_fast(y: &mut [f32], x: &[f32]) {
    axpy_fast(1.0, x, y);
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! AVX2+FMA bodies. Every function is `unsafe` with the contract that
    //! the caller verified `avx2` and `fma` are available (the public
    //! wrappers gate on `use_avx2()`); slices are plain `&[f32]`, all
    //! loads/stores unaligned.
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub unsafe fn row_panel(arow: &[f32], bdata: &[f32], n: usize,
                            orow: &mut [f32]) {
        let k = arow.len();
        let bp = bdata.as_ptr();
        let op = orow.as_mut_ptr();
        let mut kk = 0usize;
        // 4 B-rows per pass over the output row, 8 lanes per FMA
        while kk + 4 <= k {
            let a0 = _mm256_set1_ps(arow[kk]);
            let a1 = _mm256_set1_ps(arow[kk + 1]);
            let a2 = _mm256_set1_ps(arow[kk + 2]);
            let a3 = _mm256_set1_ps(arow[kk + 3]);
            let b0 = bp.add(kk * n);
            let b1 = bp.add((kk + 1) * n);
            let b2 = bp.add((kk + 2) * n);
            let b3 = bp.add((kk + 3) * n);
            let mut j = 0usize;
            while j + 8 <= n {
                let mut acc = _mm256_loadu_ps(op.add(j));
                acc = _mm256_fmadd_ps(a0, _mm256_loadu_ps(b0.add(j)), acc);
                acc = _mm256_fmadd_ps(a1, _mm256_loadu_ps(b1.add(j)), acc);
                acc = _mm256_fmadd_ps(a2, _mm256_loadu_ps(b2.add(j)), acc);
                acc = _mm256_fmadd_ps(a3, _mm256_loadu_ps(b3.add(j)), acc);
                _mm256_storeu_ps(op.add(j), acc);
                j += 8;
            }
            while j < n {
                *op.add(j) += arow[kk] * *b0.add(j)
                    + arow[kk + 1] * *b1.add(j)
                    + arow[kk + 2] * *b2.add(j)
                    + arow[kk + 3] * *b3.add(j);
                j += 1;
            }
            kk += 4;
        }
        while kk < k {
            let av = arow[kk];
            if av != 0.0 {
                axpy(av, std::slice::from_raw_parts(bp.add(kk * n), n), orow);
            }
            kk += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub unsafe fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        let n = y.len();
        let av = _mm256_set1_ps(a);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut j = 0usize;
        while j + 8 <= n {
            let acc = _mm256_fmadd_ps(av, _mm256_loadu_ps(xp.add(j)),
                                      _mm256_loadu_ps(yp.add(j)));
            _mm256_storeu_ps(yp.add(j), acc);
            j += 8;
        }
        while j < n {
            *yp.add(j) += a * *xp.add(j);
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub unsafe fn panel4(a: [f32; 4], r0: &[f32], r1: &[f32], r2: &[f32],
                         r3: &[f32], orow: &mut [f32]) {
        let n = orow.len();
        let a0 = _mm256_set1_ps(a[0]);
        let a1 = _mm256_set1_ps(a[1]);
        let a2 = _mm256_set1_ps(a[2]);
        let a3 = _mm256_set1_ps(a[3]);
        let op = orow.as_mut_ptr();
        let mut j = 0usize;
        while j + 8 <= n {
            let mut acc = _mm256_loadu_ps(op.add(j));
            acc = _mm256_fmadd_ps(a0, _mm256_loadu_ps(r0.as_ptr().add(j)), acc);
            acc = _mm256_fmadd_ps(a1, _mm256_loadu_ps(r1.as_ptr().add(j)), acc);
            acc = _mm256_fmadd_ps(a2, _mm256_loadu_ps(r2.as_ptr().add(j)), acc);
            acc = _mm256_fmadd_ps(a3, _mm256_loadu_ps(r3.as_ptr().add(j)), acc);
            _mm256_storeu_ps(op.add(j), acc);
            j += 8;
        }
        while j < n {
            *op.add(j) += a[0] * r0[j] + a[1] * r1[j] + a[2] * r2[j]
                + a[3] * r3[j];
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn rescale_add2(orow: &mut [f32], ga: &[f32], suma: &[f32],
                               sa: f32, szpa: f32, gb: &[f32], sumb: &[f32],
                               sb: f32, szpb: f32) {
        let n = orow.len();
        let sav = _mm256_set1_ps(sa);
        let zav = _mm256_set1_ps(szpa);
        let sbv = _mm256_set1_ps(sb);
        let zbv = _mm256_set1_ps(szpb);
        let op = orow.as_mut_ptr();
        let mut j = 0usize;
        // same FMA sequence as two rescale_add passes, minus the
        // intermediate store/load — bit-identical, half the orow traffic
        while j + 8 <= n {
            let mut acc = _mm256_loadu_ps(op.add(j));
            acc = _mm256_fmadd_ps(sav, _mm256_loadu_ps(ga.as_ptr().add(j)), acc);
            acc = _mm256_fnmadd_ps(zav, _mm256_loadu_ps(suma.as_ptr().add(j)), acc);
            acc = _mm256_fmadd_ps(sbv, _mm256_loadu_ps(gb.as_ptr().add(j)), acc);
            acc = _mm256_fnmadd_ps(zbv, _mm256_loadu_ps(sumb.as_ptr().add(j)), acc);
            _mm256_storeu_ps(op.add(j), acc);
            j += 8;
        }
        while j < n {
            *op.add(j) += sa * ga[j] - szpa * suma[j];
            *op.add(j) += sb * gb[j] - szpb * sumb[j];
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub unsafe fn rescale_add(orow: &mut [f32], gacc: &[f32], sums: &[f32],
                              s: f32, szp: f32) {
        let n = orow.len();
        let sv = _mm256_set1_ps(s);
        let zv = _mm256_set1_ps(szp);
        let op = orow.as_mut_ptr();
        let mut j = 0usize;
        while j + 8 <= n {
            let mut acc = _mm256_loadu_ps(op.add(j));
            acc = _mm256_fmadd_ps(sv, _mm256_loadu_ps(gacc.as_ptr().add(j)), acc);
            acc = _mm256_fnmadd_ps(zv, _mm256_loadu_ps(sums.as_ptr().add(j)), acc);
            _mm256_storeu_ps(op.add(j), acc);
            j += 8;
        }
        while j < n {
            *op.add(j) += s * gacc[j] - szp * sums[j];
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;

    fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                    "{what} entry {i}: {x} vs {y}");
        }
    }

    #[test]
    fn tier_parse_and_describe() {
        assert_eq!(KernelTier::parse("fast"), Some(KernelTier::Fast));
        assert_eq!(KernelTier::parse("FAST"), Some(KernelTier::Fast));
        assert_eq!(KernelTier::parse("reference"), Some(KernelTier::Reference));
        assert_eq!(KernelTier::parse("ref"), Some(KernelTier::Reference));
        assert_eq!(KernelTier::parse("warp"), None);
        assert_eq!(KernelTier::default(), KernelTier::Reference);
        assert_eq!(KernelTier::Fast.describe(), "fast");
        assert!(KernelTier::Fast.is_fast() && !KernelTier::Reference.is_fast());
    }

    #[test]
    fn backend_name_is_known() {
        let name = backend_name();
        assert!(name == "avx2+fma" || name == "portable-scalar", "{name}");
    }

    #[test]
    fn row_panel_fast_matches_reference_panel() {
        // odd k (quad tail) and odd n (lane tail) both exercised
        for (k, n) in [(7usize, 5usize), (16, 8), (33, 17), (64, 24), (1, 1)] {
            let a = Matrix::randn(1, k, k as u64);
            let b = Matrix::randn(k, n, n as u64);
            let mut want = vec![0.0f32; n];
            crate::tensor::ops::matmul_row_panel(&a.data, &b, &mut want);
            let mut got = vec![0.0f32; n];
            row_panel_fast(&a.data, &b.data, n, &mut got);
            assert_close(&got, &want, 1e-5, &format!("panel {k}x{n}"));
        }
    }

    #[test]
    fn row_panel_fast_accumulates_into_partial() {
        let a = Matrix::randn(1, 12, 3);
        let b = Matrix::randn(12, 9, 4);
        let mut out = vec![2.0f32; 9];
        let mut want = vec![2.0f32; 9];
        row_panel_fast(&a.data, &b.data, 9, &mut out);
        crate::tensor::ops::matmul_row_panel(&a.data, &b, &mut want);
        assert_close(&out, &want, 1e-5, "partial accumulation");
    }

    #[test]
    fn axpy_and_panel4_match_scalar_math() {
        let x = Matrix::randn(4, 21, 9);
        let mut y = vec![0.5f32; 21];
        axpy_fast(0.75, x.row(0), &mut y);
        for (j, v) in y.iter().enumerate() {
            let want = 0.5 + 0.75 * x.row(0)[j];
            assert!((v - want).abs() <= 1e-6 * (1.0 + want.abs()), "axpy {j}");
        }
        let a = [0.3f32, -1.1, 2.4, 0.05];
        let mut o = vec![0.0f32; 21];
        panel4_fast(a, x.row(0), x.row(1), x.row(2), x.row(3), &mut o);
        for j in 0..21 {
            let want = a[0] * x.row(0)[j] + a[1] * x.row(1)[j]
                + a[2] * x.row(2)[j] + a[3] * x.row(3)[j];
            assert!((o[j] - want).abs() <= 1e-5 * (1.0 + want.abs()),
                    "panel4 {j}");
        }
    }

    #[test]
    fn fused_rescale_add2_is_bit_identical_to_two_passes() {
        // wide (vector lanes) and narrow (scalar tail only) rows, the
        // narrow case being the unbatched decode width
        for n in [1usize, 7, 8, 19, 64] {
            let ga = Matrix::randn(1, n, 31);
            let gb = Matrix::randn(1, n, 32);
            let suma = Matrix::randn(1, n, 33);
            let sumb = Matrix::randn(1, n, 34);
            let (sa, szpa) = (0.25f32, 0.25 * 3.0);
            let (sb, szpb) = (0.0625f32, 0.0625 * -5.0);
            let mut fused = vec![0.75f32; n];
            rescale_add2_fast(&mut fused, &ga.data, &suma.data, sa, szpa,
                              &gb.data, &sumb.data, sb, szpb);
            let mut unfused = vec![0.75f32; n];
            rescale_add_fast(&mut unfused, &ga.data, &suma.data, sa, szpa);
            rescale_add_fast(&mut unfused, &gb.data, &sumb.data, sb, szpb);
            for (j, (a, b)) in fused.iter().zip(&unfused).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "n={n} entry {j}");
            }
        }
    }

    #[test]
    fn rescale_add_matches_identity() {
        let gacc = Matrix::randn(1, 19, 5);
        let sums = Matrix::randn(1, 19, 6);
        let (s, szp) = (0.125f32, 0.125 * 7.0);
        let mut o = vec![1.0f32; 19];
        rescale_add_fast(&mut o, &gacc.data, &sums.data, s, szp);
        for j in 0..19 {
            let want = 1.0 + s * gacc.data[j] - szp * sums.data[j];
            assert!((o[j] - want).abs() <= 1e-5 * (1.0 + want.abs()),
                    "rescale {j}");
        }
    }
}
