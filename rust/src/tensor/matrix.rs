//! Row-major dense f32 matrix.

use crate::util::Rng;

/// Dense row-major matrix of `f32`.
///
/// The core container of the compression pipeline: weights `W`, iterates
/// `Θ`, and activation Grams `C` are all `Matrix`. Kept deliberately plain
/// (a `Vec<f32>` + dims) so slices map 1:1 onto XLA literals and the
/// checkpoint format.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Matrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Standard-normal entries (deterministic from seed).
    pub fn randn(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let data = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        Matrix { rows, cols, data }
    }

    /// A synthetic-but-realistic activation Gram: `C = X Xᵀ / n` where the
    /// rows of `X` have log-normal per-dimension scales (activation
    /// "outliers" — the phenomenon AWQ/Wanda exploit and that separates
    /// activation-aware methods from magnitude pruning in our tests).
    pub fn randn_gram(dim: usize, seed: u64) -> Self {
        let n = 4 * dim;
        let mut rng = Rng::new(seed);
        let scales: Vec<f32> =
            (0..dim).map(|_| (0.75 * rng.normal()).exp() as f32).collect();
        let mut x = Matrix::zeros(dim, n);
        for i in 0..dim {
            for j in 0..n {
                x.data[i * n + j] = scales[i] * rng.normal() as f32;
            }
        }
        let mut c = Matrix::zeros(dim, dim);
        for i in 0..dim {
            for j in i..dim {
                let mut s = 0.0f64;
                for t in 0..n {
                    s += (x.data[i * n + t] * x.data[j * n + t]) as f64;
                }
                let v = (s / n as f64) as f32;
                c.data[i * dim + j] = v;
                c.data[j * dim + i] = v;
            }
        }
        c
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        self.transpose_into(&mut out);
        out
    }

    /// [`Matrix::transpose`] into a caller-owned buffer: `out` is resized
    /// (reusing its allocation) and every entry overwritten. The
    /// allocation-free form the per-thread apply workspace in
    /// `infer::linear` runs on.
    pub fn transpose_into(&self, out: &mut Matrix) {
        out.reset_zeroed(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
    }

    /// Reshape to `(rows, cols)` with all entries zeroed, reusing the
    /// existing allocation when capacity suffices — equivalent to
    /// `*self = Matrix::zeros(rows, cols)` without the allocation.
    pub fn reset_zeroed(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    pub fn diag(&self) -> Vec<f32> {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.at(i, i)).collect()
    }

    /// Count of exactly-zero entries.
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0.0).count()
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / self.data.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(m.shape(), (2, 3));
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_checks_len() {
        Matrix::from_vec(2, 2, vec![1.0; 3]);
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::randn(5, 7, 0);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn transpose_into_reuses_dirty_buffer() {
        let m = Matrix::randn(4, 6, 1);
        let mut out = Matrix::from_fn(9, 2, |_, _| f32::NAN);
        m.transpose_into(&mut out);
        assert_eq!(out, m.transpose());
        // and reset_zeroed really zeroes
        out.reset_zeroed(3, 3);
        assert_eq!(out, Matrix::zeros(3, 3));
    }

    #[test]
    fn eye_diag() {
        let e = Matrix::eye(4);
        assert_eq!(e.diag(), vec![1.0; 4]);
        assert_eq!(e.nnz(), 4);
        assert!((e.sparsity() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn gram_is_symmetric_and_diag_positive() {
        let c = Matrix::randn_gram(16, 3);
        for i in 0..16 {
            assert!(c.at(i, i) > 0.0);
            for j in 0..16 {
                assert!((c.at(i, j) - c.at(j, i)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn gram_has_anisotropic_spectrum() {
        // the log-normal scales must create a wide diagonal spread — this is
        // the property that makes activation-aware methods win in our tests.
        let c = Matrix::randn_gram(32, 7);
        let d = c.diag();
        let max = d.iter().cloned().fold(f32::MIN, f32::max);
        let min = d.iter().cloned().fold(f32::MAX, f32::min);
        assert!(max / min > 4.0, "spread {max}/{min}");
    }

    #[test]
    fn randn_deterministic() {
        assert_eq!(Matrix::randn(3, 3, 9), Matrix::randn(3, 3, 9));
        assert_ne!(Matrix::randn(3, 3, 9), Matrix::randn(3, 3, 10));
    }
}
