//! Dense f32 tensor substrate.
//!
//! Every baseline compressor (Wanda, SparseGPT, GPTQ, …) and the pure-CPU
//! AWP reference operate on these matrices; the PJRT path marshals them
//! to/from `xla::Literal`s. Row-major, contiguous, no broadcasting magic —
//! exactly what layer-wise compression needs: `(d_out, d_in)` weights and
//! `(d_in, d_in)` Grams.

pub mod matrix;
pub mod ops;
pub mod simd;
pub mod topk;

pub use matrix::Matrix;
pub use simd::KernelTier;
pub use topk::{row_topk_mask, row_topk_threshold};
