//! Row-wise top-k selection — the hard-thresholding projection `H_k` of the
//! paper's `C_row` constraint set (eq. 5), plus score-based variants used by
//! Wanda and magnitude pruning.

use super::Matrix;

/// Threshold value of the k-th largest |entry| in `row` (k >= 1).
/// O(n) average via quickselect on a scratch buffer.
pub fn row_topk_threshold(row: &[f32], k: usize) -> f32 {
    assert!(k >= 1 && k <= row.len());
    let mut mags: Vec<f32> = row.iter().map(|v| v.abs()).collect();
    let idx = k - 1;
    // select_nth_unstable_by sorts descending around the pivot
    mags.select_nth_unstable_by(idx, |a, b| b.partial_cmp(a).unwrap());
    mags[idx]
}

/// Boolean keep-mask of the k largest-|.| entries per row of `scores`.
///
/// Exact-k even under ties: ties at the threshold are broken by column
/// order, so every row keeps exactly `min(k, cols)` entries — the semi-
/// structured uniform-per-row sparsity the paper adopts from Wanda.
pub fn row_topk_mask(scores: &Matrix, k: usize) -> Vec<bool> {
    let (m, n) = scores.shape();
    let k = k.min(n);
    let mut mask = vec![false; m * n];
    if k == 0 {
        return mask;
    }
    for i in 0..m {
        let row = scores.row(i);
        let thr = row_topk_threshold(row, k);
        let mrow = &mut mask[i * n..(i + 1) * n];
        let mut kept = 0usize;
        // first pass: strictly above threshold
        for j in 0..n {
            if row[j].abs() > thr {
                mrow[j] = true;
                kept += 1;
            }
        }
        // second pass: fill remaining slots with at-threshold entries
        for j in 0..n {
            if kept == k {
                break;
            }
            if !mrow[j] && row[j].abs() == thr {
                mrow[j] = true;
                kept += 1;
            }
        }
        debug_assert_eq!(kept, k);
    }
    mask
}

/// Apply a keep-mask in place: zero everything not kept.
pub fn apply_mask(w: &mut Matrix, mask: &[bool]) {
    assert_eq!(mask.len(), w.data.len());
    for (v, &keep) in w.data.iter_mut().zip(mask) {
        if !keep {
            *v = 0.0;
        }
    }
}

/// Hard-threshold `z` to the k largest-|.| entries per row (projection onto
/// `C_row`), returning a new matrix.
pub fn hard_threshold_rows(z: &Matrix, k: usize) -> Matrix {
    let mask = row_topk_mask(z, k);
    let mut out = z.clone();
    apply_mask(&mut out, &mask);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_is_kth_largest() {
        let row = [3.0, -1.0, 4.0, -1.5, 0.5];
        assert_eq!(row_topk_threshold(&row, 1), 4.0);
        assert_eq!(row_topk_threshold(&row, 2), 3.0);
        assert_eq!(row_topk_threshold(&row, 3), 1.5);
        assert_eq!(row_topk_threshold(&row, 5), 0.5);
    }

    #[test]
    fn mask_exact_k_with_ties() {
        let m = Matrix::from_vec(1, 4, vec![1.0, 1.0, 1.0, 1.0]);
        let mask = row_topk_mask(&m, 2);
        assert_eq!(mask.iter().filter(|&&b| b).count(), 2);
    }

    #[test]
    fn mask_keeps_largest() {
        let m = Matrix::from_vec(2, 4, vec![0.1, -5.0, 2.0, 0.3, 7.0, 0.0, -0.2, 1.0]);
        let mask = row_topk_mask(&m, 2);
        assert_eq!(&mask[..4], &[false, true, true, false]);
        assert_eq!(&mask[4..], &[true, false, false, true]);
    }

    #[test]
    fn hard_threshold_rowwise_sparsity() {
        let z = Matrix::randn(10, 32, 0);
        let out = hard_threshold_rows(&z, 8);
        for i in 0..10 {
            let nnz = out.row(i).iter().filter(|&&v| v != 0.0).count();
            assert_eq!(nnz, 8);
        }
        // kept entries are unchanged
        for (a, b) in z.data.iter().zip(&out.data) {
            assert!(*b == 0.0 || a == b);
        }
    }

    #[test]
    fn k_zero_and_k_full() {
        let z = Matrix::randn(3, 5, 1);
        assert_eq!(hard_threshold_rows(&z, 0).nnz(), 0);
        assert_eq!(hard_threshold_rows(&z, 5), z);
    }
}
