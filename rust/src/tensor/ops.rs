//! Matrix operations: blocked + rayon-parallel GEMM and the handful of
//! fused kernels the compression hot paths need on the CPU side.
//!
//! The pure-Rust AWP reference (`compress::awp_cpu`) and all baselines are
//! built on these; `matmul` is cache-blocked and parallelised over row
//! panels because `(W−Θ)·C` at `(1536, 384)·(384, 384)`-ish sizes dominates
//! their profile (see EXPERIMENTS.md §Perf).

use super::simd::{self, KernelTier};
use super::Matrix;
use crate::util::parallel::{par_chunks_mut, par_map};

/// Blocked, thread-parallel `A·B` (row panels scheduled dynamically).
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_tier(a, b, KernelTier::Reference)
}

/// [`matmul`] on the fast tier: same row-parallel schedule, SIMD panels
/// ([`simd::row_panel_fast`]) instead of the reference kernel. Within
/// tolerance of [`matmul`], not bitwise (see KERNELS.md).
pub fn matmul_fast(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_tier(a, b, KernelTier::Fast)
}

/// `A·B` on the selected [`KernelTier`].
pub fn matmul_tier(a: &Matrix, b: &Matrix, tier: KernelTier) -> Matrix {
    let mut out = Matrix::zeros(a.rows, b.cols);
    matmul_tier_into(a, b, tier, &mut out);
    out
}

/// [`matmul_tier`] writing into a caller-owned buffer (resized and zeroed
/// via [`Matrix::reset_zeroed`], so any dirty buffer works) — the
/// allocation-free form the per-thread apply workspace runs on. On
/// `Reference` this is the exact dense kernel over a zeroed buffer, so the
/// result is bit-identical to [`matmul`].
pub fn matmul_tier_into(a: &Matrix, b: &Matrix, tier: KernelTier,
                        out: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "matmul {}x{} · {}x{}", a.rows, a.cols,
               b.rows, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    out.reset_zeroed(m, n);
    par_chunks_mut(&mut out.data, n, |i, orow| {
        let arow = &a.data[i * k..(i + 1) * k];
        match tier {
            KernelTier::Reference => matmul_row_panel(arow, b, orow),
            KernelTier::Fast => simd::row_panel_fast(arow, &b.data, n, orow),
        }
    });
}

/// One output-row panel of [`matmul`]: `orow += arow · B`, with the KB
/// blocking, 4-way k-unroll and zero-quad skip of the dense kernel.
/// `orow` must arrive zeroed (or holding a partial accumulation).
///
/// This is the single shared inner kernel: the packed execution path
/// (`crate::artifact::PackedLinear`) streams decoded coefficient rows
/// through the same function, which is what makes the packed GEMM
/// bit-identical to `matmul` on the decoded matrix — same blocking, same
/// unroll, same accumulation order.
pub fn matmul_row_panel(arow: &[f32], b: &Matrix, orow: &mut [f32]) {
    let k = arow.len();
    let n = b.cols;
    debug_assert_eq!(k, b.rows);
    debug_assert_eq!(n, orow.len());
    const KB: usize = 64; // k-panel: keeps a B panel hot in L1/L2
    for k0 in (0..k).step_by(KB) {
        let k1 = (k0 + KB).min(k);
        let mut kk = k0;
        // 4-way k-unroll: one pass over the output row consumes four B
        // rows, quartering the orow read/write traffic (perf pass §L3;
        // see EXPERIMENTS.md §Perf for before/after).
        while kk + 4 <= k1 {
            let a0 = arow[kk];
            let a1 = arow[kk + 1];
            let a2 = arow[kk + 2];
            let a3 = arow[kk + 3];
            if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                let b0 = &b.data[kk * n..kk * n + n];
                let b1 = &b.data[(kk + 1) * n..(kk + 1) * n + n];
                let b2 = &b.data[(kk + 2) * n..(kk + 2) * n + n];
                let b3 = &b.data[(kk + 3) * n..(kk + 3) * n + n];
                for j in 0..n {
                    orow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                }
            }
            kk += 4;
        }
        while kk < k1 {
            let av = arow[kk];
            if av != 0.0 {
                let brow = &b.data[kk * n..kk * n + n];
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
            kk += 1;
        }
    }
}

/// `out = theta + eta * (w - theta) * c` — the CPU mirror of the L1 Pallas
/// kernel (`python/compile/kernels/pgd_step.py`), fused the same way: the
/// residual is formed per row panel and never materialised. Allocates the
/// output; the PGD hot loop uses [`pgd_step_into`] with a preallocated
/// buffer instead (`proj::PgdWorkspace`).
pub fn pgd_step(w: &Matrix, theta: &Matrix, c: &Matrix, eta: f32) -> Matrix {
    let mut out = Matrix::zeros(w.rows, w.cols);
    pgd_step_into(w, theta, c, eta, &mut out);
    out
}

/// [`pgd_step`] writing into a caller-owned buffer (every output entry is
/// overwritten, so `out` need not be zeroed) — the allocation-free form the
/// workspace-driven PGD inner loop runs on.
pub fn pgd_step_into(w: &Matrix, theta: &Matrix, c: &Matrix, eta: f32,
                     out: &mut Matrix) {
    assert_eq!(w.shape(), theta.shape());
    assert_eq!(c.rows, c.cols);
    assert_eq!(w.cols, c.rows);
    assert_eq!(out.shape(), w.shape());
    let (_m, k) = w.shape();
    let n = k;
    par_chunks_mut(&mut out.data, n, |i, orow| {
        let wrow = &w.data[i * k..(i + 1) * k];
        let trow = &theta.data[i * k..(i + 1) * k];
        orow.copy_from_slice(trow);
        let mut kk = 0;
        // same 4-way unroll as matmul (see EXPERIMENTS.md §Perf)
        while kk + 4 <= k {
            let r0 = eta * (wrow[kk] - trow[kk]);
            let r1 = eta * (wrow[kk + 1] - trow[kk + 1]);
            let r2 = eta * (wrow[kk + 2] - trow[kk + 2]);
            let r3 = eta * (wrow[kk + 3] - trow[kk + 3]);
            if r0 != 0.0 || r1 != 0.0 || r2 != 0.0 || r3 != 0.0 {
                let c0 = &c.data[kk * n..kk * n + n];
                let c1 = &c.data[(kk + 1) * n..(kk + 1) * n + n];
                let c2 = &c.data[(kk + 2) * n..(kk + 2) * n + n];
                let c3 = &c.data[(kk + 3) * n..(kk + 3) * n + n];
                for j in 0..n {
                    orow[j] += r0 * c0[j] + r1 * c1[j] + r2 * c2[j] + r3 * c3[j];
                }
            }
            kk += 4;
        }
        while kk < k {
            let r = eta * (wrow[kk] - trow[kk]);
            if r != 0.0 {
                let crow = &c.data[kk * n..kk * n + n];
                for j in 0..n {
                    orow[j] += r * crow[j];
                }
            }
            kk += 1;
        }
    });
}

/// Activation-aware loss `‖(W−Θ)C½‖_F² = Σ R∘(R·C)` (paper Appendix B) —
/// no matrix square root needed.
pub fn activation_loss(w: &Matrix, theta: &Matrix, c: &Matrix) -> f64 {
    assert_eq!(w.shape(), theta.shape());
    let (m, k) = w.shape();
    par_map(m, |i| {
            let wrow = &w.data[i * k..(i + 1) * k];
            let trow = &theta.data[i * k..(i + 1) * k];
            // row_g = r · C ; contribution = r ∘ row_g
            let mut acc = 0.0f64;
            let mut g = vec![0.0f32; k];
            for kk in 0..k {
                let r = wrow[kk] - trow[kk];
                if r == 0.0 {
                    continue;
                }
                let crow = &c.data[kk * k..kk * k + k];
                for j in 0..k {
                    g[j] += r * crow[j];
                }
            }
            for kk in 0..k {
                acc += ((wrow[kk] - trow[kk]) * g[kk]) as f64;
            }
            acc
    })
    .into_iter()
    .sum::<f64>()
    .max(0.0)
}

/// `‖(W−Θ)C½‖_F / ‖W‖_F` from an already-computed `activation_loss` —
/// the single normalisation [`crate::compress::CompressedLayer::from_theta`]
/// and [`rel_activation_loss`] share, so the recorded and the recomputed
/// rel-loss can never drift apart.
pub fn rel_loss_from(final_loss: f64, w: &Matrix) -> f64 {
    final_loss.sqrt() / w.frob_norm().max(1e-30)
}

/// The Figure-1 metric `‖(W−Θ)C½‖_F / ‖W‖_F` — the exact expression
/// [`crate::compress::CompressedLayer::from_theta`] records as `rel_loss`.
/// The artifact eval path (`repro eval --from-artifact`) recomputes layer
/// quality through this same function, so a decoded Θ that is bit-identical
/// to the in-memory compressed Θ yields a bit-identical rel-loss.
pub fn rel_activation_loss(w: &Matrix, theta: &Matrix, c: &Matrix) -> f64 {
    rel_loss_from(activation_loss(w, theta, c), w)
}

/// Frobenius norm of the gradient `(W−Θ)C` (the paper's stopping criterion
/// numerator), computed without materialising the full product when Θ is
/// sparse.
pub fn grad_frob_norm(w: &Matrix, theta: &Matrix, c: &Matrix) -> f64 {
    let (m, k) = w.shape();
    par_map(m, |i| {
            let wrow = &w.data[i * k..(i + 1) * k];
            let trow = &theta.data[i * k..(i + 1) * k];
            let mut g = vec![0.0f32; k];
            for kk in 0..k {
                let r = wrow[kk] - trow[kk];
                if r == 0.0 {
                    continue;
                }
                let crow = &c.data[kk * k..kk * k + k];
                for j in 0..k {
                    g[j] += r * crow[j];
                }
            }
            g.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
    })
    .into_iter()
    .sum::<f64>()
    .sqrt()
}

pub fn add(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.shape(), b.shape());
    Matrix {
        rows: a.rows,
        cols: a.cols,
        data: a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect(),
    }
}

pub fn sub(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.shape(), b.shape());
    Matrix {
        rows: a.rows,
        cols: a.cols,
        data: a.data.iter().zip(&b.data).map(|(x, y)| x - y).collect(),
    }
}

pub fn scale(a: &Matrix, s: f32) -> Matrix {
    Matrix { rows: a.rows, cols: a.cols, data: a.data.iter().map(|&x| x * s).collect() }
}

/// Column-wise scaling: `out[:, j] = a[:, j] * s[j]` (AWQ / Wanda scaling).
pub fn scale_cols(a: &Matrix, s: &[f32]) -> Matrix {
    assert_eq!(a.cols, s.len());
    let mut out = a.clone();
    for i in 0..a.rows {
        let row = out.row_mut(i);
        for j in 0..a.cols {
            row[j] *= s[j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *out.at_mut(i, j) = s;
            }
        }
        out
    }

    fn assert_close(a: &Matrix, b: &Matrix, tol: f32) {
        assert_eq!(a.shape(), b.shape());
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol, "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let a = Matrix::randn(17, 33, 0);
        let b = Matrix::randn(33, 9, 1);
        assert_close(&matmul(&a, &b), &naive_matmul(&a, &b), 1e-4);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::randn(8, 8, 2);
        assert_close(&matmul(&a, &Matrix::eye(8)), &a, 1e-6);
    }

    #[test]
    fn matmul_fast_matches_reference_within_tol() {
        // odd k/n exercise the quad and SIMD-lane tails
        for (m, k, n) in [(5usize, 33usize, 17usize), (8, 64, 24), (3, 7, 1)] {
            let a = Matrix::randn(m, k, (m + k) as u64);
            let b = Matrix::randn(k, n, (k + n) as u64);
            let fast = matmul_fast(&a, &b);
            let reference = matmul(&a, &b);
            assert_close(&fast, &reference, 1e-3);
        }
    }

    #[test]
    fn matmul_tier_into_reference_is_bitwise_matmul() {
        let a = Matrix::randn(6, 32, 40);
        let b = Matrix::randn(32, 11, 41);
        let want = matmul(&a, &b);
        let mut out = Matrix::from_fn(2, 2, |_, _| f32::NAN); // dirty + wrong shape
        matmul_tier_into(&a, &b, KernelTier::Reference, &mut out);
        assert_eq!(out.shape(), want.shape());
        for (x, y) in out.data.iter().zip(&want.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn pgd_step_matches_composition() {
        let w = Matrix::randn(12, 16, 3);
        let t = Matrix::randn(12, 16, 4);
        let c = Matrix::randn_gram(16, 5);
        let eta = 0.07;
        let got = pgd_step(&w, &t, &c, eta);
        let want = add(&t, &scale(&matmul(&sub(&w, &t), &c), eta));
        assert_close(&got, &want, 1e-4);
    }

    #[test]
    fn pgd_step_into_overwrites_dirty_buffer() {
        let w = Matrix::randn(9, 12, 20);
        let t = Matrix::randn(9, 12, 21);
        let c = Matrix::randn_gram(12, 22);
        let want = pgd_step(&w, &t, &c, 0.3);
        let mut out = Matrix::from_fn(9, 12, |_, _| f32::NAN);
        pgd_step_into(&w, &t, &c, 0.3, &mut out);
        assert_eq!(out.data, want.data);
    }

    #[test]
    fn pgd_step_fixed_point_at_w() {
        let w = Matrix::randn(6, 6, 6);
        let c = Matrix::randn_gram(6, 7);
        assert_close(&pgd_step(&w, &w, &c, 0.5), &w, 1e-6);
    }

    #[test]
    fn activation_loss_matches_definition() {
        // ‖R·C½‖² == tr(R C Rᵀ); check against explicit R·C·Rᵀ trace.
        let w = Matrix::randn(5, 8, 8);
        let t = Matrix::randn(5, 8, 9);
        let c = Matrix::randn_gram(8, 10);
        let r = sub(&w, &t);
        let rc = matmul(&r, &c);
        let mut want = 0.0f64;
        for i in 0..5 {
            for j in 0..8 {
                want += (r.at(i, j) * rc.at(i, j)) as f64;
            }
        }
        let got = activation_loss(&w, &t, &c);
        assert!((got - want).abs() < 1e-3 * want.abs().max(1.0));
    }

    #[test]
    fn activation_loss_zero_iff_equal() {
        let w = Matrix::randn(4, 4, 11);
        let c = Matrix::randn_gram(4, 12);
        assert_eq!(activation_loss(&w, &w, &c), 0.0);
        let t = Matrix::zeros(4, 4);
        assert!(activation_loss(&w, &t, &c) > 0.0);
    }

    #[test]
    fn grad_norm_matches_matmul() {
        let w = Matrix::randn(7, 10, 13);
        let t = Matrix::randn(7, 10, 14);
        let c = Matrix::randn_gram(10, 15);
        let g = matmul(&sub(&w, &t), &c);
        let want = g.frob_norm();
        let got = grad_frob_norm(&w, &t, &c);
        assert!((got - want).abs() < 1e-4 * want);
    }

    #[test]
    fn scale_cols_basic() {
        let a = Matrix::from_fn(2, 3, |_, _| 1.0);
        let s = vec![1.0, 2.0, 3.0];
        let out = scale_cols(&a, &s);
        assert_eq!(out.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(out.row(1), &[1.0, 2.0, 3.0]);
    }
}
