//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them from the Rust request path. Python never runs here.
//!
//! Structure:
//!
//! * `manifest` — parses `artifacts/manifest.json` (program index, model
//!   configs, AWP chunk geometry) and cross-validates it against the Rust
//!   `ModelConfig` mirror.
//! * `tensor_host` — the `HostTensor` marshalling type that crosses the
//!   actor boundary (xla handles are not `Send`).
//! * `client` — the PJRT *actor*: a dedicated thread owning the
//!   `PjRtClient` and a lazily-populated executable cache; callers talk to
//!   it through a cloneable channel handle. XLA's CPU backend parallelises
//!   each execution internally, so serialising submissions costs little and
//!   buys determinism.
//! * `hlo_backend` — [`crate::compress::AwpBackend`] implemented over the
//!   actor: the production AWP path running the L1/L2-lowered chunk
//!   programs.

pub mod client;
pub mod hlo_backend;
pub mod manifest;
pub mod tensor_host;

pub use client::{Runtime, RuntimeHandle};
pub use hlo_backend::HloBackend;
pub use manifest::{Manifest, ModelEntry};
pub use tensor_host::HostTensor;
