//! Host-side tensor payloads crossing the runtime-actor channel.

use anyhow::{bail, Result};

use crate::tensor::Matrix;

/// A tensor that can cross threads (xla handles cannot).
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn from_matrix(m: &Matrix) -> Self {
        HostTensor::F32 { shape: vec![m.rows, m.cols], data: m.data.clone() }
    }

    pub fn vec_f32(data: Vec<f32>, shape: Vec<usize>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::F32 { shape, data }
    }

    pub fn vec_i32(data: Vec<i32>, shape: Vec<usize>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::I32 { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn scalar(&self) -> Result<f64> {
        match self {
            HostTensor::F32 { data, .. } if data.len() == 1 => Ok(data[0] as f64),
            HostTensor::I32 { data, .. } if data.len() == 1 => Ok(data[0] as f64),
            _ => bail!("not a scalar: shape {:?}", self.shape()),
        }
    }

    pub fn to_matrix(&self) -> Result<Matrix> {
        match self {
            HostTensor::F32 { shape, data } if shape.len() == 2 => {
                Ok(Matrix::from_vec(shape[0], shape[1], data.clone()))
            }
            _ => bail!("not a 2-D f32 tensor: {:?}", self.shape()),
        }
    }

    /// Slice a `(L, d, d)` stack into per-layer matrices.
    pub fn to_matrix_stack(&self) -> Result<Vec<Matrix>> {
        match self {
            HostTensor::F32 { shape, data } if shape.len() == 3 => {
                let (l, r, c) = (shape[0], shape[1], shape[2]);
                Ok((0..l)
                    .map(|i| {
                        Matrix::from_vec(r, c, data[i * r * c..(i + 1) * r * c].to_vec())
                    })
                    .collect())
            }
            _ => bail!("not a 3-D f32 tensor: {:?}", self.shape()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_roundtrip() {
        let m = Matrix::randn(3, 4, 0);
        let t = HostTensor::from_matrix(&m);
        assert_eq!(t.to_matrix().unwrap(), m);
        assert_eq!(t.shape(), &[3, 4]);
    }

    #[test]
    fn scalars() {
        assert_eq!(HostTensor::scalar_f32(2.5).scalar().unwrap(), 2.5);
        assert_eq!(HostTensor::scalar_i32(-3).scalar().unwrap(), -3.0);
        assert!(HostTensor::vec_f32(vec![1.0, 2.0], vec![2]).scalar().is_err());
    }

    #[test]
    fn stack_slicing() {
        let data: Vec<f32> = (0..2 * 3 * 3).map(|i| i as f32).collect();
        let t = HostTensor::vec_f32(data, vec![2, 3, 3]);
        let ms = t.to_matrix_stack().unwrap();
        assert_eq!(ms.len(), 2);
        assert_eq!(ms[1].at(0, 0), 9.0);
    }

    #[test]
    #[should_panic]
    fn vec_shape_mismatch_panics() {
        HostTensor::vec_f32(vec![1.0; 5], vec![2, 2]);
    }
}
