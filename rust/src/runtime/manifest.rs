//! `artifacts/manifest.json` — the contract between the python build path
//! and the Rust runtime.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::model::ModelConfig;
use crate::util::Json;

/// One model's AOT entry.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub config: ModelConfig,
    /// parameter order as lowered (must equal `config.param_spec()`)
    pub params: Vec<(String, Vec<usize>)>,
    /// program name (train_step, eval_loss, calib_capture, decode_step)
    /// → artifact file name
    pub programs: HashMap<String, String>,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: HashMap<String, ModelEntry>,
    /// AWP chunk length baked into the chunked programs
    pub awp_chunk: usize,
    /// quantization group size baked into the quant/joint programs
    pub awp_group: usize,
    /// awp program name (e.g. `awp_prune_256x256`) → artifact file name
    pub awp_programs: HashMap<String, String>,
}

impl Manifest {
    /// A manifest with no AOT artifacts behind it: small model configs and
    /// empty program tables. Backs `repro … --synthetic` (CI runners
    /// without `make artifacts`): checkpoint init, the CPU-backend
    /// compressors and the calibration cache all work; anything that would
    /// execute an HLO program fails with the stub actor's clear error.
    /// Dims are multiples of the quant group (32) so every spec mode
    /// re-projects cleanly.
    pub fn synthetic() -> Manifest {
        let mk = |name: &str, d_model: usize, n_heads: usize, n_layers: usize,
                  d_ff: usize| {
            let config = ModelConfig {
                name: name.to_string(),
                vocab: 256,
                d_model,
                n_heads,
                n_layers,
                d_ff,
                seq_len: 32,
                batch: 2,
                decode_len: 16,
                rope_theta: 1e4,
            };
            let params = config.param_spec();
            (name.to_string(), ModelEntry { config, params,
                                            programs: HashMap::new() })
        };
        let models: HashMap<String, ModelEntry> = [
            mk("tiny", 64, 2, 2, 128),
            mk("small", 128, 4, 2, 256),
            mk("medium", 192, 4, 3, 384),
        ]
        .into();
        Manifest {
            dir: PathBuf::new(),
            models,
            awp_chunk: 8,
            awp_group: 32,
            awp_programs: HashMap::new(),
        }
    }

    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text)?;
        if v.expect("format")?.as_str()? != "hlo-text" {
            bail!("unsupported artifact format");
        }
        let mut models = HashMap::new();
        for (name, entry) in v.expect("models")?.as_obj()? {
            let config = ModelConfig::from_json(entry.expect("config")?)?;
            let mut params = Vec::new();
            for p in entry.expect("params")?.as_arr()? {
                let pname = p.expect("name")?.as_str()?.to_string();
                let shape: Vec<usize> = p
                    .expect("shape")?
                    .as_arr()?
                    .iter()
                    .map(|s| s.as_usize())
                    .collect::<Result<_>>()?;
                params.push((pname, shape));
            }
            // the AOT param order must equal the Rust mirror's param_spec —
            // checkpoints are streamed positionally into HLO argument lists.
            if params != config.param_spec() {
                bail!("manifest param order for '{name}' diverges from ModelConfig::param_spec — python/rust model mirrors out of sync");
            }
            let mut programs = HashMap::new();
            for (k, f) in entry.expect("programs")?.as_obj()? {
                programs.insert(k.clone(), f.as_str()?.to_string());
            }
            models.insert(name.clone(), ModelEntry { config, params, programs });
        }
        let awp = v.expect("awp")?;
        let mut awp_programs = HashMap::new();
        for (k, f) in awp.expect("programs")?.as_obj()? {
            awp_programs.insert(k.clone(), f.as_str()?.to_string());
        }
        Ok(Manifest {
            dir,
            models,
            awp_chunk: awp.expect("chunk")?.as_usize()?,
            awp_group: awp.expect("group")?.as_usize()?,
            awp_programs,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .with_context(|| format!("model '{name}' not in manifest"))
    }

    /// Absolute path of a model program's HLO file.
    pub fn model_program_path(&self, model: &str, program: &str) -> Result<PathBuf> {
        let entry = self.model(model)?;
        let f = entry
            .programs
            .get(program)
            .with_context(|| format!("program '{program}' not lowered for '{model}'"))?;
        Ok(self.dir.join(f))
    }

    /// Name + path of an AWP chunk program for a weight shape.
    /// `mode` ∈ {prune, prune1, quant, quant1, joint, joint1}.
    pub fn awp_program(&self, mode: &str, d_out: usize, d_in: usize)
        -> Result<(String, PathBuf)> {
        let name = format!("awp_{mode}_{d_out}x{d_in}");
        let f = self
            .awp_programs
            .get(&name)
            .with_context(|| format!("no AOT program '{name}' — re-run `make artifacts`"))?;
        Ok((name, self.dir.join(f)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration-style test against the real artifacts when present;
    /// silently skipped otherwise (CI without `make artifacts`).
    fn real_manifest() -> Option<Manifest> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(dir).ok()
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let Some(m) = real_manifest() else { return };
        assert!(m.models.contains_key("small"));
        assert_eq!(m.awp_group, 32);
        let entry = m.model("small").unwrap();
        assert_eq!(entry.config.d_model, 256);
        for p in ["train_step", "eval_loss", "calib_capture", "decode_step"] {
            let path = m.model_program_path("small", p).unwrap();
            assert!(path.exists(), "{path:?}");
        }
        for mode in ["prune", "prune1", "quant", "quant1", "joint", "joint1"] {
            let (_, path) = m.awp_program(mode, 256, 256).unwrap();
            assert!(path.exists());
        }
        assert!(m.awp_program("prune", 999, 999).is_err());
    }

    #[test]
    fn synthetic_manifest_is_self_consistent() {
        let m = Manifest::synthetic();
        for name in ["tiny", "small", "medium"] {
            let e = m.model(name).unwrap();
            assert_eq!(e.params, e.config.param_spec());
            assert_eq!(e.config.d_model % m.awp_group, 0, "{name}");
            assert_eq!(e.config.d_ff % m.awp_group, 0, "{name}");
            // no AOT programs: the runtime-facing lookups fail cleanly
            assert!(m.model_program_path(name, "calib_capture").is_err());
        }
        assert_eq!(m.awp_group, 32);
    }

    #[test]
    fn rejects_bad_format() {
        let dir = crate::util::tempdir::TempDir::new("man").unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"format": "protobuf", "models": {}, "awp": {"chunk":8,"group":32,"programs":{}}}"#,
        )
        .unwrap();
        assert!(Manifest::load(dir.path()).is_err());
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let dir = crate::util::tempdir::TempDir::new("man2").unwrap();
        let err = Manifest::load(dir.path()).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
