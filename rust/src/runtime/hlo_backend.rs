//! [`AwpBackend`] over the PJRT actor — the production AWP compute path.
//!
//! Each call binds to the AOT chunk program for the layer's `(d_out, d_in)`
//! shape class; an `iters` request is realised as `⌊iters/chunk⌋` calls of
//! the chunk-`n` program plus single-step calls for the remainder, which
//! composes exactly (verified against the CPU backend in rust/tests/).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use super::manifest::Manifest;
use super::tensor_host::HostTensor;
use super::RuntimeHandle;
use crate::compress::awp::AwpBackend;
use crate::proj::{PgdWorkspace, ProjKind, Projection};
use crate::tensor::Matrix;

/// AWP chunk programs executed via PJRT.
pub struct HloBackend {
    pub handle: RuntimeHandle,
    pub manifest: Arc<Manifest>,
    /// `(mode, d_out, d_in)` → resolved program name + path. A chunked PGD
    /// run re-enters [`HloBackend::call`] every `chunk` iterations for
    /// every site; memoizing the manifest resolution keeps those thousands
    /// of calls out of the name-formatting/lookup path (the actor already
    /// caches the compiled executable behind the name).
    programs: Mutex<HashMap<(String, usize, usize), (String, PathBuf)>>,
}

impl HloBackend {
    pub fn new(handle: RuntimeHandle, manifest: Arc<Manifest>) -> Self {
        HloBackend { handle, manifest, programs: Mutex::new(HashMap::new()) }
    }

    /// Resolve (and memoize) the chunk program for `(mode_name, shape)`.
    fn program(&self, mode_name: &str, d_out: usize, d_in: usize)
        -> Result<(String, PathBuf)> {
        let key = (mode_name.to_string(), d_out, d_in);
        if let Some(hit) = self.programs.lock().unwrap().get(&key) {
            return Ok(hit.clone());
        }
        let resolved = self.manifest.awp_program(mode_name, d_out, d_in)?;
        self.programs.lock().unwrap().insert(key, resolved.clone());
        Ok(resolved)
    }

    /// Run one lowered chunk program. `mode` ∈ {prune, quant, joint};
    /// `single` selects the chunk-1 variant.
    fn call(&self, mode: &str, single: bool, w: &Matrix, theta: &Matrix,
            c: &Matrix, mut args: Vec<HostTensor>) -> Result<(Matrix, f64, f64)> {
        let mode_name = if single { format!("{mode}1") } else { mode.to_string() };
        let (name, path) = self.program(&mode_name, w.rows, w.cols)?;
        let mut full = vec![
            HostTensor::from_matrix(w),
            HostTensor::from_matrix(theta),
            HostTensor::from_matrix(c),
        ];
        full.append(&mut args);
        let out = self.handle.execute(&name, path, full)?;
        ensure!(out.len() == 3, "{name}: expected (theta, rel_grad, rel_loss)");
        let theta = out[0].to_matrix()?;
        let rel_grad = out[1].scalar()?;
        let rel_loss = out[2].scalar()?;
        Ok((theta, rel_grad, rel_loss))
    }

    /// Decompose an iteration request into chunk-n + chunk-1 program calls.
    fn run(&self, mode: &str, w: &Matrix, theta: &Matrix, c: &Matrix,
           iters: usize, args: &[HostTensor]) -> Result<(Matrix, f64, f64)> {
        let chunk = self.manifest.awp_chunk.max(1);
        let mut th = theta.clone();
        let mut remaining = iters;
        let (mut g, mut l) = (f64::NAN, f64::NAN);
        while remaining > 0 {
            let single = remaining < chunk;
            let step = if single { 1 } else { chunk };
            let (t2, g2, l2) = self.call(mode, single, w, &th, c, args.to_vec())?;
            th = t2;
            g = g2;
            l = l2;
            remaining -= step;
        }
        if iters == 0 {
            // stats-only request: run nothing, report via a 1-step call? No —
            // keep semantics: 0 iters returns the input unchanged with NaN
            // stats (the driver never requests 0).
        }
        Ok((th, g, l))
    }

    /// Lower a projection to its AOT program class + scalar argument list.
    /// The artifact set covers the paper's evaluated constraint sets
    /// (row-top-k → `prune`, INT grid → `quant`, their intersection →
    /// `joint`); anything else — N:M, custom operators — has no lowered
    /// program and must run on the CPU backend.
    fn lower(&self, eta: f32, proj: &dyn Projection)
        -> Result<(&'static str, Vec<HostTensor>)> {
        let unsupported = || {
            anyhow::anyhow!("projection '{}' has no AOT chunk program \
                             (use awp-cpu)", proj.describe())
        };
        Ok(match proj.kind() {
            ProjKind::RowTopK { k } => {
                ("prune",
                 vec![HostTensor::scalar_f32(eta), HostTensor::scalar_i32(k as i32)])
            }
            ProjKind::IntGrid { qmax, group } => {
                ensure!(group == self.manifest.awp_group,
                        "group {group} != AOT group {}", self.manifest.awp_group);
                // fail loudly before an off-grid qmax reaches the AOT
                // program and silently quantizes at the wrong bit-width
                crate::compress::awp::qmax_bits(qmax)?;
                ("quant", vec![HostTensor::scalar_f32(eta),
                               HostTensor::scalar_f32(qmax)])
            }
            ProjKind::Intersect { sparse, grid } => {
                match (sparse.kind(), grid.kind()) {
                    (ProjKind::RowTopK { k }, ProjKind::IntGrid { qmax, group }) => {
                        ensure!(group == self.manifest.awp_group,
                                "group {group} != AOT group {}",
                                self.manifest.awp_group);
                        crate::compress::awp::qmax_bits(qmax)?;
                        ("joint", vec![
                            HostTensor::scalar_f32(eta),
                            HostTensor::scalar_i32(k as i32),
                            HostTensor::scalar_f32(qmax),
                        ])
                    }
                    _ => return Err(unsupported()),
                }
            }
            ProjKind::Nm { .. } | ProjKind::Opaque => return Err(unsupported()),
        })
    }
}

impl AwpBackend for HloBackend {
    fn step_chunk(&self, w: &Matrix, c: &Matrix, eta: f32, proj: &dyn Projection,
                  iters: usize, ws: &mut PgdWorkspace) -> Result<(f64, f64)> {
        let (mode, args) = self.lower(eta, proj)?;
        let (th, g, l) = self.run(mode, w, ws.theta(), c, iters, &args)?;
        ws.install(th);
        Ok((g, l))
    }

    fn backend_name(&self) -> &'static str {
        "hlo-pjrt"
    }
}
