//! [`AwpBackend`] over the PJRT actor — the production AWP compute path.
//!
//! Each call binds to the AOT chunk program for the layer's `(d_out, d_in)`
//! shape class; an `iters` request is realised as `⌊iters/chunk⌋` calls of
//! the chunk-`n` program plus single-step calls for the remainder, which
//! composes exactly (verified against the CPU backend in rust/tests/).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use super::manifest::Manifest;
use super::tensor_host::HostTensor;
use super::RuntimeHandle;
use crate::compress::awp::AwpBackend;
use crate::tensor::Matrix;

/// AWP chunk programs executed via PJRT.
pub struct HloBackend {
    pub handle: RuntimeHandle,
    pub manifest: Arc<Manifest>,
    /// `(mode, d_out, d_in)` → resolved program name + path. A chunked PGD
    /// run re-enters [`HloBackend::call`] every `chunk` iterations for
    /// every site; memoizing the manifest resolution keeps those thousands
    /// of calls out of the name-formatting/lookup path (the actor already
    /// caches the compiled executable behind the name).
    programs: Mutex<HashMap<(String, usize, usize), (String, PathBuf)>>,
}

impl HloBackend {
    pub fn new(handle: RuntimeHandle, manifest: Arc<Manifest>) -> Self {
        HloBackend { handle, manifest, programs: Mutex::new(HashMap::new()) }
    }

    /// Resolve (and memoize) the chunk program for `(mode_name, shape)`.
    fn program(&self, mode_name: &str, d_out: usize, d_in: usize)
        -> Result<(String, PathBuf)> {
        let key = (mode_name.to_string(), d_out, d_in);
        if let Some(hit) = self.programs.lock().unwrap().get(&key) {
            return Ok(hit.clone());
        }
        let resolved = self.manifest.awp_program(mode_name, d_out, d_in)?;
        self.programs.lock().unwrap().insert(key, resolved.clone());
        Ok(resolved)
    }

    /// Run one lowered chunk program. `mode` ∈ {prune, quant, joint};
    /// `single` selects the chunk-1 variant.
    fn call(&self, mode: &str, single: bool, w: &Matrix, theta: &Matrix,
            c: &Matrix, mut args: Vec<HostTensor>) -> Result<(Matrix, f64, f64)> {
        let mode_name = if single { format!("{mode}1") } else { mode.to_string() };
        let (name, path) = self.program(&mode_name, w.rows, w.cols)?;
        let mut full = vec![
            HostTensor::from_matrix(w),
            HostTensor::from_matrix(theta),
            HostTensor::from_matrix(c),
        ];
        full.append(&mut args);
        let out = self.handle.execute(&name, path, full)?;
        ensure!(out.len() == 3, "{name}: expected (theta, rel_grad, rel_loss)");
        let theta = out[0].to_matrix()?;
        let rel_grad = out[1].scalar()?;
        let rel_loss = out[2].scalar()?;
        Ok((theta, rel_grad, rel_loss))
    }

    /// Decompose an iteration request into chunk-n + chunk-1 program calls.
    fn run(&self, mode: &str, w: &Matrix, theta: &Matrix, c: &Matrix,
           iters: usize, args: &[HostTensor]) -> Result<(Matrix, f64, f64)> {
        let chunk = self.manifest.awp_chunk.max(1);
        let mut th = theta.clone();
        let mut remaining = iters;
        let (mut g, mut l) = (f64::NAN, f64::NAN);
        while remaining > 0 {
            let single = remaining < chunk;
            let step = if single { 1 } else { chunk };
            let (t2, g2, l2) = self.call(mode, single, w, &th, c, args.to_vec())?;
            th = t2;
            g = g2;
            l = l2;
            remaining -= step;
        }
        if iters == 0 {
            // stats-only request: run nothing, report via a 1-step call? No —
            // keep semantics: 0 iters returns the input unchanged with NaN
            // stats (the driver never requests 0).
        }
        Ok((th, g, l))
    }
}

impl AwpBackend for HloBackend {
    fn prune_chunk(&self, w: &Matrix, theta: &Matrix, c: &Matrix, eta: f32,
                   k: usize, iters: usize) -> Result<(Matrix, f64, f64)> {
        let args = vec![HostTensor::scalar_f32(eta), HostTensor::scalar_i32(k as i32)];
        self.run("prune", w, theta, c, iters, &args)
    }

    fn quant_chunk(&self, w: &Matrix, theta: &Matrix, c: &Matrix, eta: f32,
                   qmax: f32, group: usize, iters: usize)
        -> Result<(Matrix, f64, f64)> {
        ensure!(group == self.manifest.awp_group,
                "group {group} != AOT group {}", self.manifest.awp_group);
        let args = vec![HostTensor::scalar_f32(eta), HostTensor::scalar_f32(qmax)];
        self.run("quant", w, theta, c, iters, &args)
    }

    fn joint_chunk(&self, w: &Matrix, theta: &Matrix, c: &Matrix, eta: f32,
                   k: usize, qmax: f32, group: usize, iters: usize)
        -> Result<(Matrix, f64, f64)> {
        ensure!(group == self.manifest.awp_group,
                "group {group} != AOT group {}", self.manifest.awp_group);
        let args = vec![
            HostTensor::scalar_f32(eta),
            HostTensor::scalar_i32(k as i32),
            HostTensor::scalar_f32(qmax),
        ];
        self.run("joint", w, theta, c, iters, &args)
    }

    fn backend_name(&self) -> &'static str {
        "hlo-pjrt"
    }
}
