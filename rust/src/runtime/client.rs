//! The PJRT actor: one thread owns the client + executable cache; callers
//! submit work through a cloneable [`RuntimeHandle`].
//!
//! Why an actor: the `xla` crate's handles wrap raw C pointers (not `Send`/
//! `Sync`), and XLA's CPU backend already multi-threads each execution via
//! its internal Eigen thread pool — so a single submission queue loses
//! essentially no parallelism while keeping ownership trivially correct.
//! Compilation is cached per program name; HLO text parses + compiles once
//! per process and is then a hash-map lookup.
//!
//! The `xla` dependency is feature-gated (`pjrt`): without it the actor is
//! a stub that answers every `execute` with a clear error, so the CPU-only
//! pipeline (awp-cpu + every baseline) builds and runs on machines without
//! the native XLA toolchain.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;

#[cfg(feature = "pjrt")]
use anyhow::bail;
use anyhow::{anyhow, Context, Result};

use super::tensor_host::HostTensor;

enum Msg {
    Exec {
        /// program name (cache key)
        name: String,
        /// HLO file to compile on miss
        path: PathBuf,
        args: Vec<HostTensor>,
        reply: mpsc::SyncSender<Result<Vec<HostTensor>>>,
    },
    Stats {
        reply: mpsc::SyncSender<RuntimeStats>,
    },
    Shutdown,
}

/// Counters for the perf pass / progress reporting.
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub executions: u64,
    pub compilations: u64,
    pub compile_seconds: f64,
    pub exec_seconds: f64,
    /// execution *attempts* per program name, counted before the program
    /// runs (so the stub actor records them too). Lets callers assert
    /// negative properties — e.g. the calibration cache's "a warm run
    /// submits zero `calib_capture` executions".
    pub attempts: HashMap<String, u64>,
}

impl RuntimeStats {
    /// How many times program `name` was submitted to the actor.
    pub fn attempts_of(&self, name: &str) -> u64 {
        self.attempts.get(name).copied().unwrap_or(0)
    }
}

/// Cloneable handle to the PJRT actor thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: mpsc::Sender<Msg>,
}

/// Owns the actor thread; dropping shuts it down.
pub struct Runtime {
    handle: RuntimeHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Runtime {
    /// Spawn the actor. Fails fast (on first use) if PJRT cannot start.
    pub fn start() -> Result<Runtime> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let join = std::thread::Builder::new()
            .name("pjrt-actor".into())
            .spawn(move || actor_main(rx))
            .context("spawning PJRT actor")?;
        Ok(Runtime { handle: RuntimeHandle { tx }, join: Some(join) })
    }

    pub fn handle(&self) -> RuntimeHandle {
        self.handle.clone()
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl RuntimeHandle {
    /// Execute `name` (compiling `path` on first use) with `args`; returns
    /// the program's outputs (the lowered tuple, already flattened).
    pub fn execute(&self, name: &str, path: PathBuf, args: Vec<HostTensor>)
        -> Result<Vec<HostTensor>> {
        let (rtx, rrx) = mpsc::sync_channel(1);
        self.tx
            .send(Msg::Exec { name: name.to_string(), path, args, reply: rtx })
            .map_err(|_| anyhow!("PJRT actor is gone"))?;
        rrx.recv().map_err(|_| anyhow!("PJRT actor dropped the reply"))?
    }

    pub fn stats(&self) -> Result<RuntimeStats> {
        let (rtx, rrx) = mpsc::sync_channel(1);
        self.tx.send(Msg::Stats { reply: rtx }).map_err(|_| anyhow!("actor gone"))?;
        rrx.recv().map_err(|_| anyhow!("actor dropped reply"))
    }
}

// ---------------------------------------------------------------------------
// actor internals (xla types never leave this thread)

/// Stub actor, compiled when the crate is built without the `pjrt`
/// feature (no native XLA toolchain): every program execution fails with
/// a clear error, stats stay at zero. The CPU-backend pipeline (awp-cpu
/// and all baselines) never submits work here.
#[cfg(not(feature = "pjrt"))]
fn actor_main(rx: mpsc::Receiver<Msg>) {
    let mut stats = RuntimeStats::default();
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Exec { name, reply, .. } => {
                *stats.attempts.entry(name.clone()).or_insert(0) += 1;
                let _ = reply.send(Err(anyhow!(
                    "program '{name}': PJRT runtime unavailable (crate built \
                     without the `pjrt` feature); CPU-backend methods do not \
                     need it"
                )));
            }
            Msg::Stats { reply } => {
                let _ = reply.send(stats.clone());
            }
            Msg::Shutdown => break,
        }
    }
}

#[cfg(feature = "pjrt")]
fn actor_main(rx: mpsc::Receiver<Msg>) {
    let mut state: Option<ActorState> = None;
    let mut stats = RuntimeStats::default();
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Exec { name, path, args, reply } => {
                *stats.attempts.entry(name.clone()).or_insert(0) += 1;
                let result = (|| -> Result<Vec<HostTensor>> {
                    if state.is_none() {
                        let client = xla::PjRtClient::cpu()
                            .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
                        state = Some(ActorState { client, cache: HashMap::new() });
                    }
                    let st = state.as_mut().unwrap();
                    st.execute(&name, &path, args, &mut stats)
                })();
                let _ = reply.send(result);
            }
            Msg::Stats { reply } => {
                let _ = reply.send(stats.clone());
            }
            Msg::Shutdown => break,
        }
    }
}

#[cfg(feature = "pjrt")]
struct ActorState {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl ActorState {
    fn execute(&mut self, name: &str, path: &PathBuf, args: Vec<HostTensor>,
               stats: &mut RuntimeStats) -> Result<Vec<HostTensor>> {
        if !self.cache.contains_key(name) {
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            stats.compilations += 1;
            stats.compile_seconds += t0.elapsed().as_secs_f64();
            self.cache.insert(name.to_string(), exe);
        }
        let exe = self.cache.get(name).unwrap();
        // NOTE: we deliberately avoid `PjRtLoadedExecutable::execute(&[Literal])`:
        // the vendored C wrapper `execute()` leaks every input device buffer
        // (`buffer.release()` with no deleter — ~130 MB/step for the medium
        // train loop, OOM within minutes). `execute_b` borrows buffers WE own,
        // so they are freed by PjRtBuffer::drop; it also skips one host copy
        // (slice → device instead of slice → literal → device).
        let t0 = std::time::Instant::now();
        let buffers: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|t| to_buffer(&self.client, t))
            .collect::<Result<_>>()?;
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&buffers)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        drop(buffers);
        let tuple = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("{name}: no output buffer"))?
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: to_literal: {e:?}"))?;
        stats.executions += 1;
        stats.exec_seconds += t0.elapsed().as_secs_f64();
        // programs are lowered with return_tuple=True ⇒ single tuple output
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("{name}: detuple: {e:?}"))?;
        parts.into_iter().map(|l| from_literal(&l)).collect()
    }
}

#[cfg(feature = "pjrt")]
fn to_buffer(client: &xla::PjRtClient, t: &HostTensor) -> Result<xla::PjRtBuffer> {
    match t {
        HostTensor::F32 { shape, data } => client
            .buffer_from_host_buffer::<f32>(data, shape, None)
            .map_err(|e| anyhow!("host→device f32 {shape:?}: {e:?}")),
        HostTensor::I32 { shape, data } => client
            .buffer_from_host_buffer::<i32>(data, shape, None)
            .map_err(|e| anyhow!("host→device i32 {shape:?}: {e:?}")),
    }
}

#[cfg(feature = "pjrt")]
fn from_literal(l: &xla::Literal) -> Result<HostTensor> {
    let shape = l
        .array_shape()
        .map_err(|e| anyhow!("output shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(HostTensor::F32 {
            shape: dims,
            data: l.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?,
        }),
        xla::ElementType::S32 => Ok(HostTensor::I32 {
            shape: dims,
            data: l.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))?,
        }),
        other => bail!("unsupported output dtype {other:?}"),
    }
}
