//! Transformer architecture config — the Rust mirror of
//! `python/compile/model.py::ModelConfig`. The authoritative copy for a
//! given artifact set is the one embedded in `artifacts/manifest.json`;
//! this struct deserializes it and re-derives the parameter layout.

use anyhow::Result;

use crate::util::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub decode_len: usize,
    pub rope_theta: f64,
}

impl ModelConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("vocab", Json::Num(self.vocab as f64)),
            ("d_model", Json::Num(self.d_model as f64)),
            ("n_heads", Json::Num(self.n_heads as f64)),
            ("n_layers", Json::Num(self.n_layers as f64)),
            ("d_ff", Json::Num(self.d_ff as f64)),
            ("seq_len", Json::Num(self.seq_len as f64)),
            ("batch", Json::Num(self.batch as f64)),
            ("decode_len", Json::Num(self.decode_len as f64)),
            ("rope_theta", Json::Num(self.rope_theta)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: v.expect("name")?.as_str()?.to_string(),
            vocab: v.expect("vocab")?.as_usize()?,
            d_model: v.expect("d_model")?.as_usize()?,
            n_heads: v.expect("n_heads")?.as_usize()?,
            n_layers: v.expect("n_layers")?.as_usize()?,
            d_ff: v.expect("d_ff")?.as_usize()?,
            seq_len: v.expect("seq_len")?.as_usize()?,
            batch: v.expect("batch")?.as_usize()?,
            decode_len: v.expect("decode_len")?.as_usize()?,
            rope_theta: v.expect("rope_theta")?.as_f64()?,
        })
    }

    /// Deterministic (name, shape) parameter list — must match
    /// `model.param_spec` on the python side (asserted against the manifest
    /// at load time in `runtime::manifest`).
    pub fn param_spec(&self) -> Vec<(String, Vec<usize>)> {
        let d = self.d_model;
        let ff = self.d_ff;
        let mut spec: Vec<(String, Vec<usize>)> =
            vec![("embed".into(), vec![self.vocab, d])];
        for i in 0..self.n_layers {
            let p = format!("blocks.{i}.");
            spec.push((format!("{p}ln1"), vec![d]));
            spec.push((format!("{p}wq"), vec![d, d]));
            spec.push((format!("{p}wk"), vec![d, d]));
            spec.push((format!("{p}wv"), vec![d, d]));
            spec.push((format!("{p}wo"), vec![d, d]));
            spec.push((format!("{p}ln2"), vec![d]));
            spec.push((format!("{p}w_up"), vec![ff, d]));
            spec.push((format!("{p}w_down"), vec![d, ff]));
        }
        spec.push(("ln_f".into(), vec![d]));
        spec
    }

    pub fn param_count(&self) -> usize {
        self.param_spec().iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    /// Parameters inside transformer blocks (the compressible fraction).
    pub fn block_param_count(&self) -> usize {
        self.param_spec()
            .iter()
            .filter(|(n, _)| n.contains(".w"))
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ModelConfig {
        ModelConfig {
            name: "small".into(),
            vocab: 256,
            d_model: 256,
            n_heads: 8,
            n_layers: 4,
            d_ff: 1024,
            seq_len: 128,
            batch: 4,
            decode_len: 64,
            rope_theta: 10000.0,
        }
    }

    #[test]
    fn spec_order_matches_python_convention() {
        let spec = small().param_spec();
        assert_eq!(spec[0].0, "embed");
        assert_eq!(spec[1].0, "blocks.0.ln1");
        assert_eq!(spec[2].0, "blocks.0.wq");
        assert_eq!(spec.last().unwrap().0, "ln_f");
        assert_eq!(spec.len(), 1 + 8 * 4 + 1);
    }

    #[test]
    fn param_counts() {
        let c = small();
        // 4 blocks * (4*d*d + 2*d*ff) + vocab*d + norms
        let blocks = 4 * (4 * 256 * 256 + 2 * 256 * 1024);
        assert_eq!(c.block_param_count(), blocks);
        assert!(c.param_count() > blocks);
    }

    #[test]
    fn json_roundtrip() {
        let c = small();
        let s = c.to_json().to_string();
        let back = ModelConfig::from_json(&Json::parse(&s).unwrap()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn from_json_rejects_missing_field() {
        let mut j = small().to_json();
        if let Json::Obj(kvs) = &mut j {
            kvs.retain(|(k, _)| k != "d_model");
        }
        assert!(ModelConfig::from_json(&j).is_err());
    }
}
