//! Compressible weight sites and their activation-Gram keys.
//!
//! A *site* is one linear layer `(d_out, d_in)` inside a transformer block
//! together with the Gram matrix of its input activations. Four sites per
//! block, three distinct input distributions (q/k/v share their input):
//!
//! | kind      | weights         | Gram source (calib_capture output) |
//! |-----------|-----------------|-------------------------------------|
//! | AttnQkv   | wq, wk, wv      | `attn_in[layer]`                    |
//! | AttnOut   | wo              | `attn_out_in[layer]`                |
//! | MlpUp     | w_up            | `mlp_in[layer]`                     |
//! | MlpDown   | w_down          | `mlp_down_in[layer]`                |

use super::ModelConfig;

/// Which of the four per-block Gram tensors a site reads its `C` from.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GramKey {
    AttnIn,
    AttnOutIn,
    MlpIn,
    MlpDownIn,
}

impl GramKey {
    pub fn index(self) -> usize {
        match self {
            GramKey::AttnIn => 0,
            GramKey::AttnOutIn => 1,
            GramKey::MlpIn => 2,
            GramKey::MlpDownIn => 3,
        }
    }

    /// Inverse of [`GramKey::index`] — used by the calibration-cache codec
    /// to rebuild keys from their serialized index.
    pub fn from_index(i: usize) -> Option<GramKey> {
        match i {
            0 => Some(GramKey::AttnIn),
            1 => Some(GramKey::AttnOutIn),
            2 => Some(GramKey::MlpIn),
            3 => Some(GramKey::MlpDownIn),
            _ => None,
        }
    }

    /// All four keys in `calib_capture` output order.
    pub const ALL: [GramKey; 4] =
        [GramKey::AttnIn, GramKey::AttnOutIn, GramKey::MlpIn, GramKey::MlpDownIn];
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SiteKind {
    AttnQ,
    AttnK,
    AttnV,
    AttnOut,
    MlpUp,
    MlpDown,
}

/// One compressible linear layer.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerSite {
    /// parameter name, e.g. `blocks.2.w_up`
    pub param: String,
    pub layer: usize,
    pub kind: SiteKind,
    pub d_out: usize,
    pub d_in: usize,
    pub gram: GramKey,
}

/// Enumerate every compressible site of a model, in pipeline order.
pub fn enumerate_sites(cfg: &ModelConfig) -> Vec<LayerSite> {
    let d = cfg.d_model;
    let ff = cfg.d_ff;
    let mut sites = Vec::with_capacity(cfg.n_layers * 6);
    for l in 0..cfg.n_layers {
        let p = format!("blocks.{l}.");
        sites.push(LayerSite { param: format!("{p}wq"), layer: l, kind: SiteKind::AttnQ, d_out: d, d_in: d, gram: GramKey::AttnIn });
        sites.push(LayerSite { param: format!("{p}wk"), layer: l, kind: SiteKind::AttnK, d_out: d, d_in: d, gram: GramKey::AttnIn });
        sites.push(LayerSite { param: format!("{p}wv"), layer: l, kind: SiteKind::AttnV, d_out: d, d_in: d, gram: GramKey::AttnIn });
        sites.push(LayerSite { param: format!("{p}wo"), layer: l, kind: SiteKind::AttnOut, d_out: d, d_in: d, gram: GramKey::AttnOutIn });
        sites.push(LayerSite { param: format!("{p}w_up"), layer: l, kind: SiteKind::MlpUp, d_out: ff, d_in: d, gram: GramKey::MlpIn });
        sites.push(LayerSite { param: format!("{p}w_down"), layer: l, kind: SiteKind::MlpDown, d_out: d, d_in: ff, gram: GramKey::MlpDownIn });
    }
    sites
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 256,
            d_model: 128,
            n_heads: 4,
            n_layers: 3,
            d_ff: 512,
            seq_len: 64,
            batch: 2,
            decode_len: 32,
            rope_theta: 10000.0,
        }
    }

    #[test]
    fn six_sites_per_block() {
        let sites = enumerate_sites(&cfg());
        assert_eq!(sites.len(), 18);
        // every site's param exists in the model spec
        let spec: Vec<String> =
            cfg().param_spec().into_iter().map(|(n, _)| n).collect();
        for s in &sites {
            assert!(spec.contains(&s.param), "{}", s.param);
        }
    }

    #[test]
    fn shapes_match_spec() {
        let c = cfg();
        let spec: std::collections::HashMap<String, Vec<usize>> =
            c.param_spec().into_iter().collect();
        for s in enumerate_sites(&c) {
            assert_eq!(spec[&s.param], vec![s.d_out, s.d_in], "{}", s.param);
        }
    }

    #[test]
    fn qkv_share_gram() {
        let sites = enumerate_sites(&cfg());
        let q = sites.iter().find(|s| s.kind == SiteKind::AttnQ).unwrap();
        let v = sites.iter().find(|s| s.kind == SiteKind::AttnV).unwrap();
        assert_eq!(q.gram, v.gram);
        let o = sites.iter().find(|s| s.kind == SiteKind::AttnOut).unwrap();
        assert_ne!(q.gram, o.gram);
    }

    #[test]
    fn gram_dims_correct() {
        for s in enumerate_sites(&cfg()) {
            let gram_dim = match s.gram {
                GramKey::MlpDownIn => 512,
                _ => 128,
            };
            assert_eq!(s.d_in, gram_dim);
        }
    }
}
