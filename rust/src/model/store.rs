//! Named-tensor checkpoint format (`.awp` files).
//!
//! A minimal safetensors-like container built from scratch:
//!
//! ```text
//! magic "AWPCKPT1" | u64 json_len | json header | raw f32 LE tensor data
//! ```
//!
//! The JSON header records the model config and an ordered tensor index
//! `{name, shape, offset}` (offsets into the data region, elements not
//! bytes). Tensor order equals the manifest's `param_spec` order so a
//! checkpoint can be streamed straight into an HLO argument list.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::ModelConfig;
use crate::tensor::Matrix;
use crate::util::Json;

const MAGIC: &[u8; 8] = b"AWPCKPT1";

/// An in-memory checkpoint: config + named tensors (flat f32 buffers).
pub struct Checkpoint {
    pub config: ModelConfig,
    pub tensors: Vec<(String, Vec<usize>, Vec<f32>)>,
    pub meta: HashMap<String, String>,
}

impl Checkpoint {
    /// Fresh checkpoint with all tensors zero-initialised in spec order
    /// (used for optimizer state).
    pub fn zeros_like_spec(config: &ModelConfig) -> Self {
        let tensors = config
            .param_spec()
            .into_iter()
            .map(|(n, s)| {
                let len = s.iter().product();
                (n, s, vec![0.0f32; len])
            })
            .collect();
        Checkpoint { config: config.clone(), tensors, meta: HashMap::new() }
    }

    pub fn get(&self, name: &str) -> Option<(&[usize], &[f32])> {
        self.tensors
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, s, d)| (s.as_slice(), d.as_slice()))
    }

    /// Fetch a 2-D tensor as a `Matrix` (copies).
    pub fn matrix(&self, name: &str) -> Result<Matrix> {
        let (shape, data) = self
            .get(name)
            .with_context(|| format!("tensor {name} not in checkpoint"))?;
        if shape.len() != 2 {
            bail!("tensor {name} is not 2-D: {shape:?}");
        }
        Ok(Matrix::from_vec(shape[0], shape[1], data.to_vec()))
    }

    /// Replace a tensor's data (shape must match).
    pub fn set(&mut self, name: &str, data: Vec<f32>) -> Result<()> {
        let entry = self
            .tensors
            .iter_mut()
            .find(|(n, _, _)| n == name)
            .with_context(|| format!("tensor {name} not in checkpoint"))?;
        if entry.2.len() != data.len() {
            bail!("size mismatch for {name}: {} vs {}", entry.2.len(), data.len());
        }
        entry.2 = data;
        Ok(())
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut entries = Vec::new();
        let mut offset = 0usize;
        for (name, shape, data) in &self.tensors {
            entries.push(Json::obj(vec![
                ("name", Json::Str(name.clone())),
                ("shape", Json::arr_usize(shape)),
                ("offset", Json::Num(offset as f64)),
            ]));
            offset += data.len();
        }
        let mut meta_kvs: Vec<(String, Json)> = self
            .meta
            .iter()
            .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
            .collect();
        meta_kvs.sort_by(|a, b| a.0.cmp(&b.0));
        let header = Json::obj(vec![
            ("config", self.config.to_json()),
            ("tensors", Json::Arr(entries)),
            ("meta", Json::Obj(meta_kvs)),
        ]);
        let hjson = header.to_string().into_bytes();
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(hjson.len() as u64).to_le_bytes())?;
        f.write_all(&hjson)?;
        for (_, _, data) in &self.tensors {
            // SAFETY-free little-endian serialisation
            let mut buf = Vec::with_capacity(data.len() * 4);
            for v in data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            f.write_all(&buf)?;
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(&path)
                .with_context(|| format!("open {:?}", path.as_ref()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not an AWP checkpoint (bad magic)");
        }
        let mut lenb = [0u8; 8];
        f.read_exact(&mut lenb)?;
        let hlen = u64::from_le_bytes(lenb) as usize;
        let mut hjson = vec![0u8; hlen];
        f.read_exact(&mut hjson)?;
        let header = Json::parse(std::str::from_utf8(&hjson)?)?;
        let config = ModelConfig::from_json(header.expect("config")?)?;
        let mut meta = HashMap::new();
        if let Some(Json::Obj(kvs)) = header.get("meta") {
            for (k, v) in kvs {
                meta.insert(k.clone(), v.as_str()?.to_string());
            }
        }
        let mut rest = Vec::new();
        f.read_to_end(&mut rest)?;
        let entries = header.expect("tensors")?.as_arr()?;
        let mut tensors = Vec::with_capacity(entries.len());
        for e in entries {
            let name = e.expect("name")?.as_str()?.to_string();
            let shape: Vec<usize> = e
                .expect("shape")?
                .as_arr()?
                .iter()
                .map(|s| s.as_usize())
                .collect::<Result<_>>()?;
            let offset = e.expect("offset")?.as_usize()?;
            let len: usize = shape.iter().product();
            let start = offset * 4;
            let end = start + len * 4;
            if end > rest.len() {
                bail!("truncated checkpoint: {name} needs {end} bytes");
            }
            let data: Vec<f32> = rest[start..end]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            tensors.push((name, shape, data));
        }
        Ok(Checkpoint { config, tensors, meta })
    }

    /// Clone this checkpoint with the named tensors replaced — the
    /// assembly step shared by the artifact warm path and `repro eval
    /// --from-artifact` (base checkpoint + decoded packed sites). Shapes
    /// are checked by [`Checkpoint::set`]; an unknown name is an error.
    pub fn with_tensors(
        &self,
        replacements: impl IntoIterator<Item = (String, Vec<f32>)>,
    ) -> Result<Checkpoint> {
        let mut out = Checkpoint {
            config: self.config.clone(),
            tensors: self.tensors.clone(),
            meta: self.meta.clone(),
        };
        for (name, data) in replacements {
            out.set(&name, data)?;
        }
        Ok(out)
    }

    /// Content fingerprint over config, tensor layout, tensor bits and
    /// meta — the checkpoint component of a calibration-cache key
    /// (`coordinator::cache`). Any change to a weight, the config or the
    /// metadata yields a different fingerprint, so cached Grams are never
    /// served for a retrained or edited checkpoint.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::Fnv64::new();
        h.write_str(&self.config.to_json().to_string());
        h.write_usize(self.tensors.len());
        for (name, shape, data) in &self.tensors {
            h.write_str(name);
            h.write_usize(shape.len());
            for &d in shape {
                h.write_usize(d);
            }
            h.write_f32_slice(data);
        }
        let mut meta: Vec<(&String, &String)> = self.meta.iter().collect();
        meta.sort();
        h.write_usize(meta.len());
        for (k, v) in meta {
            h.write_str(k);
            h.write_str(v);
        }
        h.finish()
    }

    /// Verify tensor order/shapes against the config's spec — checkpoints
    /// must be HLO-argument-ready.
    pub fn validate(&self) -> Result<()> {
        let spec = self.config.param_spec();
        if spec.len() != self.tensors.len() {
            bail!("tensor count {} != spec {}", self.tensors.len(), spec.len());
        }
        for ((sn, ss), (tn, ts, td)) in spec.iter().zip(&self.tensors) {
            if sn != tn || ss != ts {
                bail!("layout mismatch at {sn}: checkpoint has {tn} {ts:?}");
            }
            if td.len() != ss.iter().product::<usize>() {
                bail!("data length mismatch at {sn}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 32,
            d_model: 16,
            n_heads: 2,
            n_layers: 2,
            d_ff: 32,
            seq_len: 8,
            batch: 2,
            decode_len: 8,
            rope_theta: 10000.0,
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = crate::util::tempdir::TempDir::new("store").unwrap();
        let path = dir.path().join("m.awp");
        let mut ck = Checkpoint::zeros_like_spec(&cfg());
        let n = ck.tensors[2].2.len();
        ck.set("blocks.0.wq", (0..n).map(|i| i as f32).collect()).unwrap();
        ck.meta.insert("steps".into(), "123".into());
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        back.validate().unwrap();
        assert_eq!(back.meta["steps"], "123");
        let (shape, data) = back.get("blocks.0.wq").unwrap();
        assert_eq!(shape, &[16, 16]);
        assert_eq!(data[5], 5.0);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = crate::util::tempdir::TempDir::new("store").unwrap();
        let path = dir.path().join("bad.awp");
        std::fs::write(&path, b"NOTAWP00aaaaaaaaaaaa").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn matrix_accessor() {
        let ck = Checkpoint::zeros_like_spec(&cfg());
        let m = ck.matrix("blocks.1.w_up").unwrap();
        assert_eq!(m.shape(), (32, 16));
        assert!(ck.matrix("blocks.0.ln1").is_err()); // 1-D
        assert!(ck.matrix("nope").is_err());
    }

    #[test]
    fn set_checks_size() {
        let mut ck = Checkpoint::zeros_like_spec(&cfg());
        assert!(ck.set("embed", vec![0.0; 3]).is_err());
    }

    #[test]
    fn fingerprint_tracks_content() {
        let base = Checkpoint::zeros_like_spec(&cfg());
        let f0 = base.fingerprint();
        assert_eq!(f0, Checkpoint::zeros_like_spec(&cfg()).fingerprint());
        // one weight bit changes the fingerprint
        let mut ck = Checkpoint::zeros_like_spec(&cfg());
        let n = ck.tensors[2].2.len();
        ck.set("blocks.0.wq", vec![1.0; n]).unwrap();
        assert_ne!(f0, ck.fingerprint());
        // so does metadata
        let mut ck = Checkpoint::zeros_like_spec(&cfg());
        ck.meta.insert("steps".into(), "5".into());
        assert_ne!(f0, ck.fingerprint());
        // and the config
        let mut c2 = cfg();
        c2.rope_theta = 999.0;
        assert_ne!(f0, Checkpoint::zeros_like_spec(&c2).fingerprint());
    }

    #[test]
    fn with_tensors_replaces_and_checks() {
        let ck = Checkpoint::zeros_like_spec(&cfg());
        let n = ck.get("blocks.0.wq").unwrap().1.len();
        let out = ck
            .with_tensors([("blocks.0.wq".to_string(), vec![2.0; n])])
            .unwrap();
        assert_eq!(out.get("blocks.0.wq").unwrap().1[0], 2.0);
        // original untouched
        assert_eq!(ck.get("blocks.0.wq").unwrap().1[0], 0.0);
        assert!(ck.with_tensors([("nope".to_string(), vec![0.0])]).is_err());
        assert!(ck
            .with_tensors([("blocks.0.wq".to_string(), vec![0.0; 3])])
            .is_err());
    }

    #[test]
    fn validate_detects_reorder() {
        let mut ck = Checkpoint::zeros_like_spec(&cfg());
        ck.tensors.swap(0, 1);
        assert!(ck.validate().is_err());
    }
}
