//! Model substrate: transformer configs (mirroring `python/compile/model.py`
//! exactly), a named-tensor checkpoint format, and the enumeration of
//! compressible weight sites that drives the layer-wise pipeline.

pub mod config;
pub mod sites;
pub mod store;

pub use config::ModelConfig;
pub use sites::{GramKey, LayerSite, SiteKind};
pub use store::Checkpoint;
