//! Run configuration: filesystem layout, per-model corpus/training presets,
//! and JSON config-file overrides.
//!
//! Defaults are tuned so the full experiment suite runs on a laptop-class
//! CPU; every field can be overridden by a JSON config file (see
//! `configs/default.json`) or per-run CLI flags.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::data::CorpusConfig;
use crate::trainer::TrainConfig;
use crate::util::Json;

/// Where everything lives.
#[derive(Clone, Debug)]
pub struct Paths {
    pub artifacts: PathBuf,
    pub checkpoints: PathBuf,
    pub reports: PathBuf,
    /// calibration-artifact cache (`coordinator::cache`); `--cache-dir`
    /// overrides, `--no-cache` disables persistence
    pub gram_cache: PathBuf,
    /// compressed-artifact store (`crate::artifact`); `--artifact-dir`
    /// overrides, `--no-artifacts` disables persistence
    pub artifact_cache: PathBuf,
}

impl Default for Paths {
    fn default() -> Self {
        Paths {
            artifacts: "artifacts".into(),
            checkpoints: "checkpoints".into(),
            reports: "reports".into(),
            gram_cache: "cache/grams".into(),
            artifact_cache: "cache/artifacts".into(),
        }
    }
}

impl Paths {
    pub fn checkpoint_file(&self, model: &str) -> PathBuf {
        self.checkpoints.join(format!("{model}.awp"))
    }

    pub fn ensure_dirs(&self) -> Result<()> {
        std::fs::create_dir_all(&self.checkpoints)?;
        std::fs::create_dir_all(&self.reports)?;
        Ok(())
    }
}

/// Full run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub paths: Paths,
    pub corpus: CorpusConfig,
    /// training presets per model size (steps tuned to model cost)
    pub train_steps_tiny: usize,
    pub train_steps_small: usize,
    pub train_steps_medium: usize,
    pub lr_max: f64,
    /// calibration batches (paper: 128 sequences; scaled to model size)
    pub calib_batches: usize,
    /// held-out eval windows per perplexity measurement
    pub eval_batches: usize,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            paths: Paths::default(),
            corpus: CorpusConfig::default(),
            train_steps_tiny: 500,
            train_steps_small: 500,
            train_steps_medium: 300,
            lr_max: 3e-3,
            calib_batches: 16,
            eval_batches: 40,
            seed: 7,
        }
    }
}

impl RunConfig {
    /// Seed for drawing the fixed calibration sample — a stream distinct
    /// from training/eval. Defined once here because it is ALSO part of
    /// the gram-cache key (`coordinator::cache::CalibSpec`): the key and
    /// the sampling must never diverge.
    pub fn calib_seed(&self) -> u64 {
        self.seed ^ 0xCA11B
    }

    pub fn train_config(&self, model: &str) -> TrainConfig {
        let steps = match model {
            "tiny" => self.train_steps_tiny,
            "small" => self.train_steps_small,
            "medium" => self.train_steps_medium,
            _ => self.train_steps_small,
        };
        TrainConfig {
            steps,
            lr_max: self.lr_max,
            warmup: (steps / 10).max(1),
            seed: self.seed,
            log_every: (steps / 20).max(1),
        }
    }

    /// Apply overrides from a JSON config file. Unknown keys are rejected
    /// (typo safety).
    pub fn load_overrides(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        let v = Json::parse(&text)?;
        for (key, val) in v.as_obj()? {
            match key.as_str() {
                "artifacts" => self.paths.artifacts = val.as_str()?.into(),
                "checkpoints" => self.paths.checkpoints = val.as_str()?.into(),
                "reports" => self.paths.reports = val.as_str()?.into(),
                "gram_cache" => self.paths.gram_cache = val.as_str()?.into(),
                "artifact_cache" => self.paths.artifact_cache = val.as_str()?.into(),
                "corpus_bytes" => self.corpus.total_bytes = val.as_usize()?,
                "corpus_seed" => self.corpus.seed = val.as_usize()? as u64,
                "vocab_words" => self.corpus.vocab_words = val.as_usize()?,
                "markov_strength" => self.corpus.markov_strength = val.as_f64()?,
                "train_steps_tiny" => self.train_steps_tiny = val.as_usize()?,
                "train_steps_small" => self.train_steps_small = val.as_usize()?,
                "train_steps_medium" => self.train_steps_medium = val.as_usize()?,
                "lr_max" => self.lr_max = val.as_f64()?,
                "calib_batches" => self.calib_batches = val.as_usize()?,
                "eval_batches" => self.eval_batches = val.as_usize()?,
                "seed" => self.seed = val.as_usize()? as u64,
                other => anyhow::bail!("unknown config key '{other}'"),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = RunConfig::default();
        assert!(c.train_config("tiny").steps >= 100);
        assert!(c.train_config("medium").warmup >= 1);
        assert_eq!(c.paths.checkpoint_file("small"),
                   PathBuf::from("checkpoints/small.awp"));
    }

    #[test]
    fn overrides_apply_and_reject_unknown() {
        let dir = crate::util::tempdir::TempDir::new("cfg").unwrap();
        let p = dir.path().join("c.json");
        std::fs::write(&p, r#"{"train_steps_small": 42, "lr_max": 0.001,
                               "gram_cache": "elsewhere/grams",
                               "artifact_cache": "elsewhere/apacks"}"#).unwrap();
        let mut c = RunConfig::default();
        c.load_overrides(&p).unwrap();
        assert_eq!(c.train_steps_small, 42);
        assert_eq!(c.lr_max, 0.001);
        assert_eq!(c.paths.gram_cache, PathBuf::from("elsewhere/grams"));
        assert_eq!(c.paths.artifact_cache, PathBuf::from("elsewhere/apacks"));
        std::fs::write(&p, r#"{"nope": 1}"#).unwrap();
        assert!(c.load_overrides(&p).is_err());
    }
}
