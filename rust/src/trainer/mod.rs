//! Training loop — Rust drives the AOT-compiled AdamW train step.
//!
//! This satisfies the end-to-end validation mandate (DESIGN.md §6): the
//! transformer that the compression experiments run on is trained *by this
//! system*, with the L2 jax train step executing under PJRT and the loop,
//! data pipeline, LR schedule and checkpointing all in Rust.

use anyhow::{ensure, Context, Result};

use crate::data::{Batcher, Split};
use crate::model::{Checkpoint, ModelConfig};
use crate::runtime::{HostTensor, Manifest, RuntimeHandle};
use crate::util::{Rng, Timer};

/// Training hyper-parameters (AdamW internals are baked into the AOT
/// program; these drive the loop).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr_max: f64,
    pub warmup: usize,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { steps: 600, lr_max: 3e-3, warmup: 60, seed: 7, log_every: 25 }
    }
}

/// Linear warmup → cosine decay to 10% of peak.
pub fn lr_at(cfg: &TrainConfig, step: usize) -> f64 {
    if step < cfg.warmup {
        return cfg.lr_max * (step + 1) as f64 / cfg.warmup as f64;
    }
    let t = (step - cfg.warmup) as f64 / (cfg.steps - cfg.warmup).max(1) as f64;
    let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
    cfg.lr_max * (0.1 + 0.9 * cos)
}

/// He-style init matching `python/compile/model.py::init_params` semantics
/// (norms = 1, embed ~ 0.02·N, linears ~ N/√fan_in). Exact RNG streams
/// differ from jax — irrelevant, we train from scratch here.
pub fn init_checkpoint(cfg: &ModelConfig, seed: u64) -> Checkpoint {
    let mut ck = Checkpoint::zeros_like_spec(cfg);
    let mut rng = Rng::new(seed);
    for (name, shape, data) in ck.tensors.iter_mut() {
        if name.ends_with("ln1") || name.ends_with("ln2") || name.ends_with("ln_f") {
            data.fill(1.0);
        } else if name == "embed" {
            for v in data.iter_mut() {
                *v = 0.02 * rng.normal() as f32;
            }
        } else {
            let fan_in = shape[1] as f64;
            let s = 1.0 / fan_in.sqrt();
            for v in data.iter_mut() {
                *v = (s * rng.normal()) as f32;
            }
        }
    }
    ck
}

/// One (step, loss) sample of the training curve.
pub type LossCurve = Vec<(usize, f64)>;

/// Train `model` for `cfg.steps`; returns the trained checkpoint and the
/// loss curve. The whole state (params + Adam moments) round-trips through
/// the AOT `train_step` executable every step.
pub fn train(handle: &RuntimeHandle, manifest: &Manifest, model: &str,
             batcher: &Batcher, cfg: &TrainConfig) -> Result<(Checkpoint, LossCurve)> {
    let entry = manifest.model(model)?;
    let mcfg = &entry.config;
    ensure!(batcher.batch == mcfg.batch && batcher.seq == mcfg.seq_len,
            "batcher geometry {}x{} != model AOT geometry {}x{}",
            batcher.batch, batcher.seq, mcfg.batch, mcfg.seq_len);
    let path = manifest.model_program_path(model, "train_step")?;
    let timer = Timer::start("train");

    let ck = init_checkpoint(mcfg, cfg.seed);
    let n = ck.tensors.len();
    let mut params: Vec<HostTensor> = ck
        .tensors
        .iter()
        .map(|(_, s, d)| HostTensor::vec_f32(d.clone(), s.clone()))
        .collect();
    let mut m: Vec<HostTensor> = ck
        .tensors
        .iter()
        .map(|(_, s, d)| HostTensor::vec_f32(vec![0.0; d.len()], s.clone()))
        .collect();
    let mut v = m.clone();

    let mut rng = Rng::new(cfg.seed ^ 0xDA7A);
    let mut curve = Vec::new();
    for step in 0..cfg.steps {
        let batch = batcher.sample(Split::Train, &mut rng);
        let mut args = Vec::with_capacity(3 * n + 3);
        args.extend(params.iter().cloned());
        args.extend(m.iter().cloned());
        args.extend(v.iter().cloned());
        args.push(HostTensor::vec_i32(batch.tokens, vec![batch.batch, batch.seq]));
        args.push(HostTensor::scalar_f32(lr_at(cfg, step) as f32));
        args.push(HostTensor::scalar_f32(step as f32));
        let mut out = handle.execute("train_step", path.clone(), args)?;
        ensure!(out.len() == 3 * n + 1, "train_step returned {} outputs", out.len());
        let loss = out.pop().unwrap().scalar()?;
        v = out.split_off(2 * n);
        m = out.split_off(n);
        params = out;
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            curve.push((step, loss));
            eprintln!("[train {model}] step {step:5}  loss {loss:.4}  lr {:.2e}  ({:.1}s)",
                      lr_at(cfg, step), timer.elapsed_s());
        }
    }

    // write params back into a checkpoint
    let mut out_ck = Checkpoint::zeros_like_spec(mcfg);
    for ((name, _, _), t) in out_ck.tensors.clone().iter().zip(&params) {
        out_ck
            .set(name, t.as_f32()?.to_vec())
            .with_context(|| format!("storing {name}"))?;
    }
    out_ck.meta.insert("steps".into(), cfg.steps.to_string());
    out_ck.meta.insert("final_loss".into(),
                       format!("{:.4}", curve.last().map(|(_, l)| *l).unwrap_or(0.0)));
    Ok((out_ck, curve))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let cfg = TrainConfig { steps: 100, lr_max: 1e-3, warmup: 10, ..Default::default() };
        assert!(lr_at(&cfg, 0) < lr_at(&cfg, 9));
        assert!((lr_at(&cfg, 9) - 1e-3).abs() < 1e-4);
        assert!(lr_at(&cfg, 99) < 0.2 * 1e-3);
        // monotone decay after warmup
        let mut prev = f64::MAX;
        for s in 10..100 {
            let lr = lr_at(&cfg, s);
            assert!(lr <= prev + 1e-12);
            prev = lr;
        }
    }

    #[test]
    fn init_checkpoint_statistics() {
        let cfg = ModelConfig {
            name: "t".into(), vocab: 256, d_model: 64, n_heads: 4, n_layers: 2,
            d_ff: 128, seq_len: 32, batch: 2, decode_len: 16, rope_theta: 1e4,
        };
        let ck = init_checkpoint(&cfg, 0);
        ck.validate().unwrap();
        let (_, ln) = ck.get("blocks.0.ln1").map(|(s, d)| (s, d)).unwrap();
        assert!(ln.iter().all(|&v| v == 1.0));
        let (_, wq) = ck.get("blocks.0.wq").unwrap();
        let var: f32 = wq.iter().map(|v| v * v).sum::<f32>() / wq.len() as f32;
        assert!((var - 1.0 / 64.0).abs() < 0.2 / 64.0, "var {var}");
        // deterministic
        let ck2 = init_checkpoint(&cfg, 0);
        assert_eq!(ck.get("embed").unwrap().1, ck2.get("embed").unwrap().1);
    }
}
