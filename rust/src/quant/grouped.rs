//! Grouped affine quantization (the `Proj_{C_INTb}` of Algorithm 1).

use crate::tensor::Matrix;

/// Static description of a quantization grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantSpec {
    pub bits: u8,
    /// group size along `d_in`; must divide the layer's `d_in`.
    pub group: usize,
}

impl QuantSpec {
    pub fn new(bits: u8, group: usize) -> Self {
        assert!((1..=8).contains(&bits), "bits must be in 1..=8");
        assert!(group > 0);
        QuantSpec { bits, group }
    }

    pub fn qmax(&self) -> f32 {
        ((1u32 << self.bits) - 1) as f32
    }

    /// Effective storage bits per weight including per-group overhead
    /// (f32 scale + f32 zero-point per group) — used by the report module
    /// for the §4.3 bits-equivalent accounting.
    pub fn bits_per_weight(&self) -> f64 {
        self.bits as f64 + 64.0 / self.group as f64
    }
}

/// A quantized matrix: integer codes + per-group (scale, zero-point).
#[derive(Clone, Debug)]
pub struct GroupedQuant {
    pub spec: QuantSpec,
    pub rows: usize,
    pub cols: usize,
    /// row-major codes in `0..=qmax`
    pub codes: Vec<u8>,
    /// per (row, group): scale
    pub scales: Vec<f32>,
    /// per (row, group): integer zero-point (stored as f32 for exact math)
    pub zps: Vec<f32>,
}

/// Quantize `w` onto the grouped affine grid.
pub fn quantize(w: &Matrix, spec: QuantSpec) -> GroupedQuant {
    assert_eq!(
        w.cols % spec.group,
        0,
        "d_in={} not a multiple of group={}",
        w.cols,
        spec.group
    );
    let ngroups = w.cols / spec.group;
    let qmax = spec.qmax();
    let mut codes = vec![0u8; w.rows * w.cols];
    let mut scales = vec![0.0f32; w.rows * ngroups];
    let mut zps = vec![0.0f32; w.rows * ngroups];
    for i in 0..w.rows {
        for g in 0..ngroups {
            let s = &w.row(i)[g * spec.group..(g + 1) * spec.group];
            let lo = s.iter().cloned().fold(f32::MAX, f32::min);
            let hi = s.iter().cloned().fold(f32::MIN, f32::max);
            let scale = (hi - lo) / qmax;
            let (scale, zp) = if scale > 0.0 {
                // round-half-to-even to match the L1 kernel (numpy/jnp
                // semantics) bit-for-bit on tie cases
                (scale, (-lo / scale).round_ties_even())
            } else {
                // flat group: single grid point at lo ⇒ encode zeros, keep lo
                // in the scale slot trick: scale=0 with zp storing nothing;
                // we store scale=0, zp=0 and remember lo via scales==0 path
                (0.0, 0.0)
            };
            scales[i * ngroups + g] = if scale > 0.0 { scale } else { lo };
            zps[i * ngroups + g] = if scale > 0.0 { zp } else { f32::NAN };
            for (t, &v) in s.iter().enumerate() {
                let code = if scale > 0.0 {
                    ((v / scale).round_ties_even() + zp).clamp(0.0, qmax) as u8
                } else {
                    0
                };
                codes[i * w.cols + g * spec.group + t] = code;
            }
        }
    }
    GroupedQuant { spec, rows: w.rows, cols: w.cols, codes, scales, zps }
}

/// Reconstruct the dequantized matrix.
pub fn dequantize(q: &GroupedQuant) -> Matrix {
    let ngroups = q.cols / q.spec.group;
    let mut out = Matrix::zeros(q.rows, q.cols);
    for i in 0..q.rows {
        for g in 0..ngroups {
            let scale = q.scales[i * ngroups + g];
            let zp = q.zps[i * ngroups + g];
            for t in 0..q.spec.group {
                let idx = i * q.cols + g * q.spec.group + t;
                out.data[idx] = if zp.is_nan() {
                    scale // flat group: scale slot holds the constant
                } else {
                    (q.codes[idx] as f32 - zp) * scale
                };
            }
        }
    }
    out
}

/// One-shot RTN: quantize then dequantize (the paper's non-activation-aware
/// baseline and AWP's quantization initialiser).
pub fn quantize_dequantize(w: &Matrix, spec: QuantSpec) -> Matrix {
    dequantize(&quantize(w, spec))
}

/// Grid projection with a *fractional-free dynamic* `qmax` (`2^bits − 1` as
/// f32) — the exact mirror of the L1 Pallas kernel
/// `python/compile/kernels/quant_project.py`, used by the CPU AWP backend
/// so both backends share semantics bit-for-bit.
pub fn project_qmax(z: &Matrix, qmax: f32, group: usize) -> Matrix {
    assert!(qmax >= 1.0);
    assert_eq!(z.cols % group, 0);
    let mut out = Matrix::zeros(z.rows, z.cols);
    for i in 0..z.rows {
        let src = z.row(i);
        let dst = out.row_mut(i);
        for g in (0..src.len()).step_by(group) {
            let s = &src[g..g + group];
            let lo = s.iter().cloned().fold(f32::MAX, f32::min);
            let hi = s.iter().cloned().fold(f32::MIN, f32::max);
            let scale = (hi - lo) / qmax;
            if scale > 0.0 {
                let zp = (-lo / scale).round_ties_even();
                for (t, &v) in s.iter().enumerate() {
                    let q = ((v / scale).round_ties_even() + zp).clamp(0.0, qmax);
                    dst[g + t] = (q - zp) * scale;
                }
            } else {
                for t in 0..s.len() {
                    dst[g + t] = lo;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_bounded() {
        let w = Matrix::randn(16, 64, 0);
        for bits in [2u8, 3, 4, 8] {
            let spec = QuantSpec::new(bits, 32);
            let deq = quantize_dequantize(&w, spec);
            let q = quantize(&w, spec);
            let ngroups = w.cols / spec.group;
            for i in 0..w.rows {
                for g in 0..ngroups {
                    let s = &w.row(i)[g * 32..(g + 1) * 32];
                    let lo = s.iter().cloned().fold(f32::MAX, f32::min);
                    let hi = s.iter().cloned().fold(f32::MIN, f32::max);
                    let step = (hi - lo) / spec.qmax();
                    for t in 0..32 {
                        let err = (deq.at(i, g * 32 + t) - s[t]).abs();
                        assert!(err <= step / 2.0 + 1e-5,
                                "bits={bits} err={err} step={step}");
                    }
                    let _ = &q;
                }
            }
        }
    }

    #[test]
    fn idempotent() {
        let w = Matrix::randn(8, 32, 1);
        let spec = QuantSpec::new(4, 16);
        let d1 = quantize_dequantize(&w, spec);
        let d2 = quantize_dequantize(&d1, spec);
        for (a, b) in d1.data.iter().zip(&d2.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn grid_cardinality() {
        let w = Matrix::randn(4, 32, 2);
        let spec = QuantSpec::new(2, 16);
        let deq = quantize_dequantize(&w, spec);
        for i in 0..4 {
            for g in 0..2 {
                let mut vals: Vec<f32> =
                    deq.row(i)[g * 16..(g + 1) * 16].to_vec();
                vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
                vals.dedup_by(|a, b| (*a - *b).abs() < 1e-7);
                assert!(vals.len() <= 4, "INT2 group has {} levels", vals.len());
            }
        }
    }

    #[test]
    fn flat_group_survives() {
        let w = Matrix::from_fn(2, 32, |_, _| 0.7);
        let deq = quantize_dequantize(&w, QuantSpec::new(4, 32));
        for v in &deq.data {
            assert!((v - 0.7).abs() < 1e-7);
        }
    }

    #[test]
    fn zero_exactly_representable() {
        // the integer zero-point guarantees exact zeros whenever the group
        // straddles 0 — essential for joint pruning+quantization (§4.3):
        // pruned (zero) weights must survive the INT projection.
        let mut w = Matrix::randn(6, 32, 3);
        for i in 0..6 {
            w.row_mut(i)[5 * i] = 0.0;
        }
        let deq = quantize_dequantize(&w, QuantSpec::new(3, 32));
        for i in 0..6 {
            let row = w.row(i);
            let straddles = row.iter().any(|&v| v < 0.0) && row.iter().any(|&v| v > 0.0);
            if straddles {
                assert_eq!(deq.at(i, 5 * i), 0.0, "row {i}");
            }
        }
    }

    #[test]
    fn matches_l1_kernel_semantics() {
        // Identical formula to python/compile/kernels/quant_project.py —
        // fixed vector cross-checked against a value computed by ref.py.
        let w = Matrix::from_vec(1, 4, vec![-1.0, -0.5, 0.25, 1.0]);
        let deq = quantize_dequantize(&w, QuantSpec::new(2, 4));
        // scale = 2/3, zp = round(1.5)=2 ⇒ grid {-4/3,-2/3,0,2/3}+... compute:
        // codes: round(v/scale)+zp clamped to [0,3]
        let scale = 2.0f32 / 3.0;
        let expect: Vec<f32> = vec![
            ((-1.0f32 / scale).round() + 2.0 - 2.0) * scale, // -0.666..
            ((-0.5f32 / scale).round() + 2.0 - 2.0) * scale, // -0.666..
            ((0.25f32 / scale).round() + 2.0 - 2.0) * scale, // 0
            ((1.0f32 / scale).round().min(1.0) + 2.0 - 2.0) * scale, // clamp hits 3-2=1 ⇒ 0.666
        ];
        for (a, b) in deq.data.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn project_qmax_matches_quantize_dequantize() {
        let w = Matrix::randn(8, 64, 13);
        for bits in [2u8, 3, 4] {
            let a = project_qmax(&w, (1u32 << bits) as f32 - 1.0, 32);
            let b = quantize_dequantize(&w, QuantSpec::new(bits, 32));
            for (x, y) in a.data.iter().zip(&b.data) {
                assert!((x - y).abs() < 1e-6, "bits={bits}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn bits_per_weight_accounting() {
        let s = QuantSpec::new(4, 32);
        assert!((s.bits_per_weight() - 6.0).abs() < 1e-9);
        let s = QuantSpec::new(4, 128);
        assert!((s.bits_per_weight() - 4.5).abs() < 1e-9);
    }
}
