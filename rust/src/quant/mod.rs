//! Quantization substrate: grouped affine INT quantization and bit-packed
//! storage.
//!
//! Matches the constraint set `C_INTb` of the paper (and the L1 kernel
//! `quant_project.py`) exactly: per-group (along `d_in`) affine grids with
//! `2^bits` levels, min/max-fitted scale and integer zero-point. `pack.rs`
//! provides the bit-packed on-disk representation used to report real
//! compressed sizes (the paper's "4 bits + 1 mask bit ≈ 2-bit equivalent"
//! accounting in §4.3).

pub mod grouped;
pub mod pack;

pub use grouped::{
    dequantize, project_qmax, quantize, quantize_dequantize, GroupedQuant, QuantSpec,
};
pub use pack::{pack_bits, packed_size_bytes, unpack_bits, unpack_bits_into};
