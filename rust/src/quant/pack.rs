//! Bit-packing for quantized codes: `bits`-wide codes packed little-endian
//! into a byte stream. This is what makes the compressed-size numbers in the
//! experiment reports real rather than notional.

/// Pack `codes` (each `< 2^bits`) into a little-endian bitstream.
pub fn pack_bits(codes: &[u8], bits: u8) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(bits == 8 || (c as u16) < (1u16 << bits), "code {c} overflows {bits} bits");
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + bits as usize > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += bits as usize;
    }
    out
}

/// Inverse of [`pack_bits`]; `n` is the number of codes to recover.
/// Delegates to [`unpack_bits_into`] so the full-array and streaming
/// (random-access) decodes are one implementation — the packed GEMM's
/// bit-identity contract depends on them agreeing.
pub fn unpack_bits(packed: &[u8], bits: u8, n: usize) -> Vec<u8> {
    let mut out = vec![0u8; n];
    unpack_bits_into(packed, bits, 0, &mut out);
    out
}

/// Bytes needed for `n` codes at `bits` each.
pub fn packed_size_bytes(n: usize, bits: u8) -> usize {
    (n * bits as usize).div_ceil(8)
}

/// Unpack the `out.len()` codes starting at code index `start` into `out`
/// — the random-access form of [`unpack_bits`] the streaming packed-GEMM
/// path uses to decode one coefficient row at a time without materialising
/// the full code array.
pub fn unpack_bits_into(packed: &[u8], bits: u8, start: usize, out: &mut [u8]) {
    assert!((1..=8).contains(&bits));
    let mask = if bits == 8 { 0xFFu16 } else { (1u16 << bits) - 1 };
    let mut bitpos = start * bits as usize;
    for slot in out.iter_mut() {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = (packed[byte] as u16) >> off;
        if off + bits as usize > 8 && byte + 1 < packed.len() {
            v |= (packed[byte + 1] as u16) << (8 - off);
        }
        *slot = (v & mask) as u8;
        bitpos += bits as usize;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = Rng::new(0);
        for bits in 1..=8u8 {
            let maxc = if bits == 8 { 256 } else { 1usize << bits };
            let codes: Vec<u8> =
                (0..1000).map(|_| rng.below(maxc) as u8).collect();
            let packed = pack_bits(&codes, bits);
            assert_eq!(packed.len(), packed_size_bytes(codes.len(), bits));
            let back = unpack_bits(&packed, bits, codes.len());
            assert_eq!(codes, back, "bits={bits}");
        }
    }

    #[test]
    fn packing_is_dense() {
        // 8 codes at 3 bits = 24 bits = 3 bytes
        let codes = vec![7u8; 8];
        assert_eq!(pack_bits(&codes, 3).len(), 3);
    }

    #[test]
    fn empty() {
        assert!(pack_bits(&[], 4).is_empty());
        assert!(unpack_bits(&[], 4, 0).is_empty());
    }

    #[test]
    fn known_pattern_int4() {
        let codes = vec![0x1u8, 0x2, 0x3, 0x4];
        let packed = pack_bits(&codes, 4);
        assert_eq!(packed, vec![0x21, 0x43]);
    }

    #[test]
    fn ranged_unpack_matches_full_unpack() {
        let mut rng = Rng::new(7);
        for bits in 1..=8u8 {
            let maxc = if bits == 8 { 256 } else { 1usize << bits };
            let codes: Vec<u8> = (0..301).map(|_| rng.below(maxc) as u8).collect();
            let packed = pack_bits(&codes, bits);
            let full = unpack_bits(&packed, bits, codes.len());
            for (start, len) in [(0usize, 301usize), (7, 64), (300, 1), (13, 0)] {
                let mut out = vec![0u8; len];
                unpack_bits_into(&packed, bits, start, &mut out);
                assert_eq!(out, full[start..start + len], "bits={bits} @{start}");
            }
        }
    }
}
