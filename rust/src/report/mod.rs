//! Paper-style table rendering for the experiment harness.
//!
//! Formats results the way the paper's tables do — including the
//! order-of-magnitude shorthand for blown-up perplexities ("4e3", "1e4") —
//! and emits both aligned console text and markdown for EXPERIMENTS.md.
//!
//! [`perf`] is the machine-readable side: the `repro bench-json` suite
//! that snapshots kernel-tier GFLOP/s and native tokens/sec.

pub mod perf;

/// A rendered experiment table.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub col_header: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<Option<f64>>)>,
}

/// Format a perplexity the way the paper's tables do: two decimals below
/// 100, order-of-magnitude shorthand above.
pub fn paper_number(v: f64) -> String {
    if !v.is_finite() {
        return "NAN".into();
    }
    if v < 100.0 {
        format!("{v:.2}")
    } else {
        let exp = v.abs().log10().floor() as i32;
        let mant = (v / 10f64.powi(exp)).round() as i64;
        if mant == 10 {
            format!("1e{}", exp + 1)
        } else {
            format!("{mant}e{exp}")
        }
    }
}

impl Table {
    pub fn new(title: impl Into<String>, col_header: impl Into<String>,
               columns: Vec<String>) -> Self {
        Table { title: title.into(), col_header: col_header.into(),
                columns, rows: Vec::new() }
    }

    pub fn push_row(&mut self, label: impl Into<String>, cells: Vec<Option<f64>>) {
        assert_eq!(cells.len(), self.columns.len());
        self.rows.push((label.into(), cells));
    }

    fn cell(&self, v: &Option<f64>) -> String {
        match v {
            Some(x) => paper_number(*x),
            None => "-".into(),
        }
    }

    /// Aligned console rendering.
    pub fn to_console(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        let mut label_w = self.col_header.len();
        for (label, cells) in &self.rows {
            label_w = label_w.max(label.len());
            for (i, c) in cells.iter().enumerate() {
                widths[i] = widths[i].max(self.cell(c).len());
            }
        }
        let mut out = format!("# {}\n", self.title);
        out += &format!("{:label_w$}", self.col_header);
        for (c, w) in self.columns.iter().zip(&widths) {
            out += &format!("  {c:>w$}");
        }
        out.push('\n');
        for (label, cells) in &self.rows {
            out += &format!("{label:label_w$}");
            for (c, w) in cells.iter().zip(&widths) {
                out += &format!("  {:>w$}", self.cell(c));
            }
            out.push('\n');
        }
        out
    }

    /// Markdown rendering (for EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut out = format!("**{}**\n\n", self.title);
        out += &format!("| {} |", self.col_header);
        for c in &self.columns {
            out += &format!(" {c} |");
        }
        out += "\n|---|";
        for _ in &self.columns {
            out += "---|";
        }
        out.push('\n');
        for (label, cells) in &self.rows {
            out += &format!("| {label} |");
            for c in cells {
                out += &format!(" {} |", self.cell(c));
            }
            out.push('\n');
        }
        out
    }

    /// CSV rendering (raw values, full precision — for plotting).
    pub fn to_csv(&self) -> String {
        let mut out = format!("{}", self.col_header);
        for c in &self.columns {
            out += &format!(",{c}");
        }
        out.push('\n');
        for (label, cells) in &self.rows {
            out += label;
            for c in cells {
                match c {
                    Some(v) => out += &format!(",{v}"),
                    None => out.push(','),
                }
            }
            out.push('\n');
        }
        out
    }
}

/// A text-celled sibling of [`Table`] for reports whose cells are not
/// paper-style numbers (byte counts, mode tags, ratios): same aligned
/// console / markdown / CSV renderings, string cells. Used by the
/// compressed-artifact footprint table (`repro inspect`,
/// `repro compress --pack-out`).
#[derive(Clone, Debug)]
pub struct TextTable {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(title: impl Into<String>, headers: Vec<String>) -> Self {
        TextTable { title: title.into(), headers, rows: Vec::new() }
    }

    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    /// Aligned console rendering (first column left-aligned, rest right).
    pub fn to_console(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = format!("# {}\n", self.title);
        for (i, (h, w)) in self.headers.iter().zip(&widths).enumerate() {
            if i == 0 {
                out += &format!("{h:w$}");
            } else {
                out += &format!("  {h:>w$}");
            }
        }
        out.push('\n');
        for row in &self.rows {
            for (i, (c, w)) in row.iter().zip(&widths).enumerate() {
                if i == 0 {
                    out += &format!("{c:w$}");
                } else {
                    out += &format!("  {c:>w$}");
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn to_markdown(&self) -> String {
        let mut out = format!("**{}**\n\n|", self.title);
        for h in &self.headers {
            out += &format!(" {h} |");
        }
        out += "\n|";
        for _ in &self.headers {
            out += "---|";
        }
        out.push('\n');
        for row in &self.rows {
            out.push('|');
            for c in row {
                out += &format!(" {c} |");
            }
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out += &row.join(",");
            out.push('\n');
        }
        out
    }
}

/// Per-job wall-clock telemetry from an executor run (`repro compress
/// --timings`): one row per layer job with its seconds and share of the
/// summed job time (> 100%·wall-clock total means the pool overlapped work).
pub fn timing_table(title: impl Into<String>, jobs: &[(String, f64)]) -> Table {
    let total: f64 = jobs.iter().map(|(_, s)| *s).sum();
    let mut t = Table::new(title, "job",
                           vec!["seconds".into(), "share %".into()]);
    for (label, secs) in jobs {
        let share = if total > 0.0 { 100.0 * secs / total } else { 0.0 };
        t.push_row(label.clone(), vec![Some(*secs), Some(share)]);
    }
    t.push_row("TOTAL", vec![Some(total), Some(100.0)]);
    t
}

/// [`timing_table`] with the executor's cost weights: one row per job with
/// its wall-clock share *and* its share of the plan's predicted cost
/// (`Job::cost`). Comparing the two columns shows how well the FLOP-ish
/// cost model tracks reality — the same model the live progress/ETA line
/// ([`progress_line`]) is driven by.
pub fn timing_table_weighted(title: impl Into<String>,
                             jobs: &[(String, f64, u64)]) -> Table {
    let total_s: f64 = jobs.iter().map(|(_, s, _)| *s).sum();
    let total_c: u64 = jobs.iter().map(|(_, _, c)| *c).sum();
    let mut t = Table::new(title, "job",
                           vec!["seconds".into(), "time %".into(), "cost %".into()]);
    for (label, secs, cost) in jobs {
        let time_share = if total_s > 0.0 { 100.0 * secs / total_s } else { 0.0 };
        let cost_share = if total_c > 0 {
            100.0 * *cost as f64 / total_c as f64
        } else {
            0.0
        };
        t.push_row(label.clone(),
                   vec![Some(*secs), Some(time_share), Some(cost_share)]);
    }
    t.push_row("TOTAL", vec![Some(total_s), Some(100.0), Some(100.0)]);
    t
}

/// One cost-weighted progress/ETA line, emitted by the executor as jobs
/// complete (`Executor::with_progress`). The completed-cost fraction is
/// the estimator: with LPT scheduling, "80% of the cost done" predicts
/// remaining wall-clock far better than "80% of the jobs done".
pub fn progress_line(done_jobs: usize, total_jobs: usize, done_cost: u64,
                     total_cost: u64, elapsed_s: f64) -> String {
    let frac = if total_cost > 0 {
        done_cost as f64 / total_cost as f64
    } else if total_jobs > 0 {
        done_jobs as f64 / total_jobs as f64
    } else {
        1.0
    };
    let eta = if frac > 0.0 && frac < 1.0 {
        elapsed_s * (1.0 - frac) / frac
    } else {
        0.0
    };
    format!(
        "[progress] {done_jobs}/{total_jobs} jobs · {:.1}% of cost · \
         {elapsed_s:.1}s elapsed · eta {eta:.1}s",
        100.0 * frac
    )
}

/// A simple (x, y) series (Figure 1).
pub fn series_csv(header: (&str, &str), points: &[(f64, f64)]) -> String {
    let mut out = format!("{},{}\n", header.0, header.1);
    for (x, y) in points {
        out += &format!("{x},{y}\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_numbers_match_table_style() {
        assert_eq!(paper_number(6.48), "6.48");
        assert_eq!(paper_number(70.04), "70.04");
        assert_eq!(paper_number(4212.0), "4e3");
        assert_eq!(paper_number(14503.0), "1e4");
        assert_eq!(paper_number(96400.0), "1e5"); // 9.64e4 rounds to 10e4 = 1e5
        assert_eq!(paper_number(f64::NAN), "NAN");
        assert_eq!(paper_number(123.0), "1e2");
    }

    #[test]
    fn table_renders_all_formats() {
        let mut t = Table::new("Test", "method",
                               vec!["50%".into(), "90%".into()]);
        t.push_row("wanda", vec![Some(6.48), Some(14000.0)]);
        t.push_row("magnitude", vec![Some(14.89), None]);
        let con = t.to_console();
        assert!(con.contains("6.48") && con.contains("1e4") && con.contains("-"));
        let md = t.to_markdown();
        assert!(md.starts_with("**Test**"));
        assert!(md.contains("| wanda | 6.48 | 1e4 |"));
        let csv = t.to_csv();
        assert!(csv.contains("wanda,6.48,14000"));
    }

    #[test]
    fn timing_table_shares_sum() {
        let t = timing_table("T", &[("a".into(), 3.0), ("b".into(), 1.0)]);
        assert_eq!(t.rows.len(), 3); // two jobs + TOTAL
        assert_eq!(t.rows[0].1[1], Some(75.0));
        assert_eq!(t.rows[1].1[1], Some(25.0));
        assert_eq!(t.rows[2].1[0], Some(4.0));
        // no jobs ⇒ no division by zero
        let empty = timing_table("E", &[]);
        assert_eq!(empty.rows.len(), 1);
        assert_eq!(empty.rows[0].1[0], Some(0.0));
    }

    #[test]
    fn weighted_timing_table_has_both_shares() {
        let t = timing_table_weighted("T", &[("a".into(), 3.0, 900),
                                             ("b".into(), 1.0, 100)]);
        assert_eq!(t.rows.len(), 3);
        assert_eq!(t.rows[0].1[1], Some(75.0)); // time share
        assert_eq!(t.rows[0].1[2], Some(90.0)); // cost share
        assert_eq!(t.rows[2].1[0], Some(4.0));
        let empty = timing_table_weighted("E", &[]);
        assert_eq!(empty.rows.len(), 1);
    }

    #[test]
    fn progress_line_reports_cost_fraction_and_eta() {
        let s = progress_line(1, 4, 250, 1000, 10.0);
        assert!(s.contains("1/4 jobs"), "{s}");
        assert!(s.contains("25.0% of cost"), "{s}");
        assert!(s.contains("eta 30.0s"), "{s}");
        // complete run: eta 0
        let s = progress_line(4, 4, 1000, 1000, 12.0);
        assert!(s.contains("eta 0.0s"), "{s}");
        // degenerate zero-cost plan falls back to job counts
        let s = progress_line(1, 2, 0, 0, 1.0);
        assert!(s.contains("50.0% of cost"), "{s}");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("T", "m", vec!["a".into()]);
        t.push_row("x", vec![Some(1.0), Some(2.0)]);
    }

    #[test]
    fn text_table_renders_all_formats() {
        let mut t = TextTable::new("Footprint",
                                   vec!["site".into(), "bytes".into()]);
        t.push_row(vec!["blocks.0.wq".into(), "1024".into()]);
        t.push_row(vec!["TOTAL".into(), "2048".into()]);
        let con = t.to_console();
        assert!(con.starts_with("# Footprint"));
        assert!(con.contains("blocks.0.wq") && con.contains("2048"));
        let md = t.to_markdown();
        assert!(md.contains("| blocks.0.wq | 1024 |"));
        assert!(t.to_csv().contains("blocks.0.wq,1024"));
    }

    #[test]
    #[should_panic]
    fn text_table_row_width_checked() {
        let mut t = TextTable::new("T", vec!["a".into()]);
        t.push_row(vec!["x".into(), "y".into()]);
    }
}
