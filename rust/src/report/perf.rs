//! The `repro bench-json` perf trajectory — a machine-readable snapshot of
//! the kernel-tier speedups (`BENCH_<pr>.json` at the repo root).
//!
//! Two sections:
//!
//! * `kernels` — GEMM GFLOP/s per compression family × serving shape,
//!   measured three ways: the dense row-panel kernel over the decoded
//!   weights, the reference packed kernel (streaming dequant /
//!   survivor-only), and the fast compressed-domain kernel
//!   ([`KernelTier::Fast`]). `fast_vs_reference` is the headline ratio the
//!   perf acceptance bar reads.
//! * `native` — end-to-end tokens/sec of [`NativeModel::forward`] on a
//!   small synthetic LM: dense, packed reference tier, packed fast tier.
//! * `decode` — greedy decode tokens/sec on the packed fast-tier model
//!   (the serving configuration), KV-cached
//!   ([`NativeModel::prefill`]/[`NativeModel::decode_step`]) vs the old
//!   full-window re-forward per token; `cached_vs_uncached` records the
//!   O(ctx²) → O(ctx) win.
//! * `decode_batch` — continuous-batching throughput: N concurrent
//!   sessions decoded serially (N independent `decode_step` loops) vs
//!   fused ([`NativeModel::decode_step_batch`], one forward per tick
//!   carrying all N), at batch 1/4/16; `batched_vs_serial` records how
//!   much of the packed kernels' per-launch decode aux the batch
//!   amortises.
//! * `obs_overhead` — the observability gate (OBSERVABILITY.md): the same
//!   KV-cached greedy decode with the metrics registry enabled (the
//!   default) vs force-disabled; `enabled_vs_disabled` near 1.0 is the
//!   "instrumentation is free" acceptance bar.
//! * `artifact_load` — the cold-open story behind `--weight-budget-mb`:
//!   eager whole-payload [`read_artifact`] vs a header-only
//!   [`ArtifactPager::open`] vs open-plus-paging-in every site, and the
//!   `AWPPACK1` vs `AWPPACK2` on-disk byte counts for the same artifact.
//!
//! The harness is [`crate::util::bench`] (no criterion in the image); the
//! same measurements back `benches/kernels.rs`, which adds the
//! baseline-gating workflow described in KERNELS.md.

use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use crate::artifact::{read_artifact, write_artifact_opts, ArtifactPager,
                      ArtifactSite, ModelArtifact, PackedLinear};
use crate::compress::traits::CompressionSpec;
use crate::eval::reconstruction::LayerReport;
use crate::infer::{DecodeSession, NativeModel, SiteWeights};
use crate::model::{sites, ModelConfig};
use crate::proj::{NmStructured, ProjScratch, Projection};
use crate::quant::project_qmax;
use crate::tensor::{ops, simd, KernelTier, Matrix};
use crate::trainer::init_checkpoint;
use crate::util::bench::bench;
use crate::util::parallel::num_threads;
use crate::util::tempdir::TempDir;
use crate::util::Json;

/// Compression families measured by the kernel section. Every family's
/// `k` must divide by its group/M (the shapes below all satisfy 32 | k
/// and 8 | k).
const FAMILIES: [&str; 3] = ["int4-g32", "nm-2:4", "nm-4:8"];

/// One measured GEMM row: `(m, k, n)` under one family, GFLOP/s on all
/// three execution strategies.
struct KernelRow {
    family: &'static str,
    mode: String,
    m: usize,
    k: usize,
    n: usize,
    dense_gflops: f64,
    reference_gflops: f64,
    fast_gflops: f64,
}

/// Build a weight matrix already on the family's constraint set, plus the
/// spec that packs it into that family's `PackedLinear` mode.
fn family_theta(family: &str, m: usize, k: usize, seed: u64)
    -> (Matrix, CompressionSpec) {
    match family {
        "int4-g32" => (project_qmax(&Matrix::randn(m, k, seed), 15.0, 32),
                       CompressionSpec::quant(4, 32)),
        "nm-2:4" => {
            let mut t = Matrix::randn(m, k, seed);
            NmStructured::new(2, 4).project_rows(&mut t, &mut ProjScratch::new());
            (t, CompressionSpec::structured_nm(2, 4))
        }
        "nm-4:8" => {
            let mut t = Matrix::randn(m, k, seed);
            NmStructured::new(4, 8).project_rows(&mut t, &mut ProjScratch::new());
            (t, CompressionSpec::structured_nm(4, 8))
        }
        other => unreachable!("unknown bench family {other}"),
    }
}

fn kernel_row(family: &'static str, m: usize, k: usize, n: usize,
              budget_s: f64, seed: u64) -> KernelRow {
    let (theta, spec) = family_theta(family, m, k, seed);
    let packed = PackedLinear::encode(&theta, &spec).prepare();
    let b = Matrix::randn(k, n, seed + 1);
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    let mut out = Matrix::zeros(m, n);
    let label = |kind: &str| format!("{family} {m}x{k}x{n} {kind}");
    let dense = bench(&label("dense"), budget_s, || {
        ops::matmul_tier_into(&theta, &b, KernelTier::Reference, &mut out);
        std::hint::black_box(&out);
    });
    let reference = bench(&label("reference"), budget_s, || {
        packed.matmul_tier_into(&b, KernelTier::Reference, &mut out);
        std::hint::black_box(&out);
    });
    let fast = bench(&label("fast"), budget_s, || {
        packed.matmul_tier_into(&b, KernelTier::Fast, &mut out);
        std::hint::black_box(&out);
    });
    KernelRow {
        family,
        mode: packed.mode_name().to_string(),
        m,
        k,
        n,
        dense_gflops: dense.gflops(flops),
        reference_gflops: reference.gflops(flops),
        fast_gflops: fast.gflops(flops),
    }
}

/// The synthetic serving LM behind the `native` section. Small enough for
/// a CI smoke in `--quick` mode; big enough full-size that the site GEMMs
/// dominate the forward pass.
fn native_cfg(quick: bool) -> ModelConfig {
    if quick {
        ModelConfig {
            name: "bench-quick".into(), vocab: 64, d_model: 32, n_heads: 2,
            n_layers: 2, d_ff: 64, seq_len: 16, batch: 1, decode_len: 8,
            rope_theta: 1e4,
        }
    } else {
        ModelConfig {
            name: "bench".into(), vocab: 256, d_model: 128, n_heads: 4,
            n_layers: 2, d_ff: 256, seq_len: 32, batch: 2, decode_len: 8,
            rope_theta: 1e4,
        }
    }
}

/// Dense / packed-reference / packed-fast models over the *same* projected
/// weights, so the three throughput numbers serve identical math.
fn native_models(cfg: &ModelConfig) -> Result<(NativeModel, NativeModel, NativeModel)> {
    let ck = init_checkpoint(cfg, 11);
    let mut dense_sw = Vec::new();
    let mut ref_sw = Vec::new();
    let mut fast_sw = Vec::new();
    for s in sites::enumerate_sites(cfg) {
        let theta = project_qmax(&ck.matrix(&s.param)?, 15.0, 32);
        let packed = PackedLinear::encode(&theta, &CompressionSpec::quant(4, 32));
        ref_sw.push((s.param.clone(), SiteWeights::packed(packed.clone())));
        fast_sw.push((s.param.clone(), SiteWeights::packed(packed)));
        dense_sw.push((s.param.clone(), SiteWeights::Dense(theta)));
    }
    let dense = NativeModel::with_site_weights(&ck, dense_sw)?;
    let reference = NativeModel::with_site_weights(&ck, ref_sw)?;
    let mut fast = NativeModel::with_site_weights(&ck, fast_sw)?;
    fast.set_tier(KernelTier::Fast);
    Ok((dense, reference, fast))
}

fn tokens_per_s(name: &str, m: &NativeModel, tokens: &[i32], batch: usize,
                seq: usize, budget_s: f64) -> Result<f64> {
    m.forward(tokens, batch, seq)?; // surface errors before the timed loop
    let r = bench(name, budget_s, || {
        std::hint::black_box(m.forward(tokens, batch, seq).unwrap());
    });
    Ok(batch as f64 * seq as f64 / r.median_s)
}

/// Greedy-decode throughput: extend `prompt` by `n_new` tokens, KV-cached
/// (one prefill + O(ctx) `decode_step`s) or uncached (the pre-KV path: a
/// full forward over the growing context per token). Returns generated
/// tokens/sec; both variants produce the same tokens — only the cost
/// model differs.
fn decode_tok_s(name: &str, m: &NativeModel, prompt: &[i32], n_new: usize,
                cached: bool, budget_s: f64) -> Result<f64> {
    use crate::eval::argmax;
    let run = || -> Result<()> {
        if cached {
            let mut sess = m.new_session(prompt.len() + n_new);
            let mut logits = m.prefill(&mut sess, prompt)?;
            for _ in 0..n_new {
                let next = argmax(&logits);
                logits = m.decode_step(&mut sess, next)?;
            }
            std::hint::black_box(&logits);
        } else {
            let mut ctx = prompt.to_vec();
            for _ in 0..n_new {
                let logits = m.forward(&ctx, 1, ctx.len())?;
                ctx.push(argmax(logits.row(ctx.len() - 1)));
            }
            std::hint::black_box(&ctx);
        }
        Ok(())
    };
    run()?; // surface errors before the timed loop
    let r = bench(name, budget_s, || run().unwrap());
    Ok(n_new as f64 / r.median_s)
}

/// Multi-session decode throughput at one batch size: `bs` sessions with
/// ragged prompts generate `n_new` tokens each, serially (`bs` independent
/// prefill + `decode_step` loops — what the server did per request before
/// continuous batching) vs fused (`bs` prefills, then `n_new` ticks of
/// [`NativeModel::decode_step_batch`] carrying all `bs` sessions). Both
/// include the prefills in the timed region; both produce identical tokens
/// on the reference tier.
fn batch_decode_row(m: &NativeModel, vocab: usize, bs: usize, n_new: usize,
                    budget_s: f64) -> Result<Json> {
    use crate::eval::argmax;
    let prompts: Vec<Vec<i32>> = (0..bs)
        .map(|s| {
            (0..4 + s % 3)
                .map(|i| ((i * 5 + s * 11) % vocab) as i32)
                .collect()
        })
        .collect();
    let cap = prompts.iter().map(|p| p.len()).max().unwrap() + n_new + 1;
    let serial = || -> Result<()> {
        for p in &prompts {
            let mut sess = m.new_session(cap);
            let mut logits = m.prefill(&mut sess, p)?;
            for _ in 0..n_new {
                let next = argmax(&logits);
                logits = m.decode_step(&mut sess, next)?;
            }
            std::hint::black_box(&logits);
        }
        Ok(())
    };
    let batched = || -> Result<()> {
        let mut sessions = Vec::with_capacity(bs);
        let mut pending = Vec::with_capacity(bs);
        for p in &prompts {
            let mut sess = m.new_session(cap);
            let logits = m.prefill(&mut sess, p)?;
            pending.push(argmax(&logits));
            sessions.push(sess);
        }
        for _ in 0..n_new {
            let mut refs: Vec<&mut DecodeSession> =
                sessions.iter_mut().collect();
            let logits = m.decode_step_batch(&mut refs, &pending)?;
            drop(refs);
            for (p, l) in pending.iter_mut().zip(&logits) {
                *p = argmax(l);
            }
        }
        std::hint::black_box(&pending);
        Ok(())
    };
    serial()?; // surface errors before the timed loops
    batched()?;
    let rs = bench(&format!("decode serial x{bs}"), budget_s,
                   || serial().unwrap());
    let rb = bench(&format!("decode batched x{bs}"), budget_s,
                   || batched().unwrap());
    let tok = (bs * n_new) as f64;
    Ok(Json::obj(vec![
        ("batch", Json::Num(bs as f64)),
        ("new_tokens", Json::Num(n_new as f64)),
        ("serial_tok_s", Json::Num(tok / rs.median_s)),
        ("batched_tok_s", Json::Num(tok / rb.median_s)),
        ("batched_vs_serial", Json::Num(rs.median_s / rb.median_s)),
    ]))
}

/// The observability-overhead gate: KV-cached greedy decode throughput on
/// the serving model with the metrics registry enabled vs force-disabled.
/// The registry's hot-path cost is one relaxed load + branch per observe
/// when disabled and one relaxed add (plus a clock read per histogram)
/// when enabled, so the ratio should sit within bench noise of 1.0 —
/// that's the policy OBSERVABILITY.md states and CI eyeballs.
fn obs_overhead(fast: &NativeModel, vocab: usize, quick: bool, budget_s: f64)
    -> Result<Json> {
    use crate::obs::metrics;
    let (p_len, n_new) = if quick { (8, 8) } else { (32, 32) };
    let prompt: Vec<i32> =
        (0..p_len).map(|i| (i * 3 % vocab) as i32).collect();
    // serialise against any concurrent test toggling the global flag
    let _g = metrics::enable_guard();
    let was = metrics::enabled();
    metrics::set_enabled(true);
    let on = decode_tok_s("decode metrics-on", fast, &prompt, n_new, true,
                          budget_s);
    metrics::set_enabled(false);
    let off = decode_tok_s("decode metrics-off", fast, &prompt, n_new, true,
                           budget_s);
    metrics::set_enabled(was);
    let (on, off) = (on?, off?);
    Ok(Json::obj(vec![
        ("new_tokens", Json::Num(n_new as f64)),
        ("enabled_tok_s", Json::Num(on)),
        ("disabled_tok_s", Json::Num(off)),
        ("enabled_vs_disabled", Json::Num(on / off)),
    ]))
}

/// The artifact cold-open / page-in rows: how much work a process does
/// before it can serve. `eager_open_s` is the legacy whole-payload
/// [`read_artifact`]; `pager_open_s` is the header-only
/// [`ArtifactPager::open`] behind `repro serve`; `page_in_all_s` adds a
/// first touch (decode + validate + prepare) of every site. The byte
/// columns record the lossless second stage's win — `AWPPACK2` on disk vs
/// `AWPPACK1` for the same payload.
fn artifact_load_section(quick: bool, budget_s: f64) -> Result<Json> {
    let (m, k, n_sites) = if quick { (32, 64, 4) } else { (128, 256, 9) };
    let mut sites = Vec::with_capacity(n_sites);
    for i in 0..n_sites {
        let (theta, spec) =
            family_theta(FAMILIES[i % FAMILIES.len()], m, k, 500 + i as u64);
        let param = format!("site{i}");
        sites.push(ArtifactSite {
            param: param.clone(),
            packed: PackedLinear::encode(&theta, &spec),
            report: LayerReport {
                param, d_out: m, d_in: k, rel_loss: 0.0, sparsity: 0.0,
                row_uniform: true, iterations: 1, seconds: 0.0,
            },
        });
    }
    let art = ModelArtifact {
        model: "bench".into(), checkpoint: 1, calib: 2, method: "rtn".into(),
        spec: 3, spec_desc: "bench".into(), params: 4,
        compressed_with: "rtn".into(), sites,
    };
    let dir = TempDir::new("bench-apack")?;
    let v1 = dir.path().join("bench.apack");
    let v2 = dir.path().join("bench.apack2");
    write_artifact_opts(&v1, &art, false)?;
    write_artifact_opts(&v2, &art, true)?;
    let file_bytes =
        |p: &Path| fs::metadata(p).map(|md| md.len()).unwrap_or(0);
    // surface errors before the timed loops
    read_artifact(&v1)?;
    ArtifactPager::open(&v1, None)?.site(0)?;
    let eager = bench("artifact eager open", budget_s, || {
        std::hint::black_box(read_artifact(&v1).unwrap());
    });
    let cold = bench("artifact pager open", budget_s, || {
        std::hint::black_box(ArtifactPager::open(&v1, None).unwrap());
    });
    let paged = bench("artifact pager page-in all", budget_s, || {
        let pager = ArtifactPager::open(&v1, None).unwrap();
        for i in 0..pager.site_count() {
            std::hint::black_box(pager.site(i).unwrap());
        }
    });
    Ok(Json::obj(vec![
        ("sites", Json::Num(n_sites as f64)),
        ("packed_bytes", Json::Num(art.packed_bytes() as f64)),
        ("pack1_file_bytes", Json::Num(file_bytes(&v1) as f64)),
        ("pack2_file_bytes", Json::Num(file_bytes(&v2) as f64)),
        ("eager_open_s", Json::Num(eager.median_s)),
        ("pager_open_s", Json::Num(cold.median_s)),
        ("page_in_all_s", Json::Num(paged.median_s)),
        ("pager_vs_eager_open",
         Json::Num(eager.median_s / cold.median_s)),
    ]))
}

/// Run the full suite and assemble the `awp-bench/1` document. `quick`
/// shrinks shapes and budgets to CI-smoke scale (~a second) — same schema,
/// not comparable numbers.
pub fn bench_report(quick: bool) -> Result<Json> {
    let shapes: &[(usize, usize, usize)] = if quick {
        &[(64, 64, 32)]
    } else {
        &[(256, 256, 128), (1024, 256, 128), (256, 1024, 128)]
    };
    let budget = if quick { 0.02 } else { 0.25 };
    let mut rows = Vec::new();
    let mut seed = 100u64;
    for family in FAMILIES {
        for &(m, k, n) in shapes {
            rows.push(kernel_row(family, m, k, n, budget, seed));
            seed += 7;
        }
    }
    let kernels = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("family", Json::Str(r.family.to_string())),
                    ("mode", Json::Str(r.mode.clone())),
                    ("m", Json::Num(r.m as f64)),
                    ("k", Json::Num(r.k as f64)),
                    ("n", Json::Num(r.n as f64)),
                    ("dense_gflops", Json::Num(r.dense_gflops)),
                    ("reference_gflops", Json::Num(r.reference_gflops)),
                    ("fast_gflops", Json::Num(r.fast_gflops)),
                    ("fast_vs_reference",
                     Json::Num(r.fast_gflops / r.reference_gflops)),
                ])
            })
            .collect(),
    );
    let cfg = native_cfg(quick);
    let (batch, seq) = (cfg.batch, cfg.seq_len);
    let tokens: Vec<i32> = (0..batch * seq)
        .map(|i| (i * 7 % cfg.vocab) as i32)
        .collect();
    let (dense, reference, fast) = native_models(&cfg)?;
    let nb = if quick { 0.05 } else { 0.3 };
    let d = tokens_per_s("native dense forward", &dense, &tokens, batch, seq, nb)?;
    let r = tokens_per_s("native packed reference forward", &reference, &tokens,
                         batch, seq, nb)?;
    let f = tokens_per_s("native packed fast forward", &fast, &tokens, batch,
                         seq, nb)?;
    let native = Json::obj(vec![
        ("d_model", Json::Num(cfg.d_model as f64)),
        ("n_layers", Json::Num(cfg.n_layers as f64)),
        ("batch", Json::Num(batch as f64)),
        ("seq", Json::Num(seq as f64)),
        ("dense_tok_s", Json::Num(d)),
        ("packed_reference_tok_s", Json::Num(r)),
        ("packed_fast_tok_s", Json::Num(f)),
        ("fast_vs_reference", Json::Num(f / r)),
    ]);
    // decode throughput on the serving configuration (packed, fast tier):
    // KV-cached vs the old full-window re-forward per generated token
    let (p_len, n_new) = if quick { (8, 8) } else { (32, 32) };
    let prompt: Vec<i32> =
        (0..p_len).map(|i| (i * 5 % cfg.vocab) as i32).collect();
    let cached =
        decode_tok_s("decode cached", &fast, &prompt, n_new, true, nb)?;
    let uncached =
        decode_tok_s("decode uncached", &fast, &prompt, n_new, false, nb)?;
    let decode = Json::obj(vec![
        ("prompt_tokens", Json::Num(p_len as f64)),
        ("new_tokens", Json::Num(n_new as f64)),
        ("cached_tok_s", Json::Num(cached)),
        ("uncached_tok_s", Json::Num(uncached)),
        ("cached_vs_uncached", Json::Num(cached / uncached)),
    ]);
    // continuous batching: fused multi-session decode vs per-session serial
    // loops on the serving model (packed, fast tier)
    let (batch_sizes, bd_new): (&[usize], usize) =
        if quick { (&[1, 4], 4) } else { (&[1, 4, 16], 16) };
    let decode_batch = Json::Arr(
        batch_sizes
            .iter()
            .map(|&bs| batch_decode_row(&fast, cfg.vocab, bs, bd_new, nb))
            .collect::<Result<Vec<_>>>()?,
    );
    // the observability gate rides the same serving model
    let obs = obs_overhead(&fast, cfg.vocab, quick, nb)?;
    // artifact cold-open vs pager page-in (the serve startup path)
    let artifact_load = artifact_load_section(quick, budget)?;
    Ok(Json::obj(vec![
        ("schema", Json::Str("awp-bench/1".into())),
        ("pr", Json::Num(10.0)),
        ("quick", Json::Bool(quick)),
        ("threads", Json::Num(num_threads() as f64)),
        ("simd", Json::Str(simd::backend_name().into())),
        ("kernels", kernels),
        ("native", native),
        ("decode", decode),
        ("decode_batch", decode_batch),
        ("obs_overhead", obs),
        ("artifact_load", artifact_load),
    ]))
}

/// Run [`bench_report`] and write it to `path` (the CLI default is
/// `BENCH_10.json` at the repo root).
pub fn write_bench_json(path: &Path, quick: bool) -> Result<()> {
    let report = bench_report(quick)?;
    fs::write(path, report.to_string() + "\n")
        .with_context(|| format!("writing bench report {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_report_has_schema_and_positive_ratios() {
        let report = bench_report(true).unwrap();
        assert_eq!(report.expect("schema").unwrap().as_str().unwrap(),
                   "awp-bench/1");
        let kernels = report.expect("kernels").unwrap().as_arr().unwrap();
        assert_eq!(kernels.len(), FAMILIES.len());
        for row in kernels {
            assert!(row.expect("fast_gflops").unwrap().as_f64().unwrap() > 0.0);
            assert!(row.expect("fast_vs_reference").unwrap().as_f64().unwrap()
                    > 0.0);
        }
        let native = report.expect("native").unwrap();
        assert!(native.expect("packed_fast_tok_s").unwrap().as_f64().unwrap()
                > 0.0);
        let decode = report.expect("decode").unwrap();
        assert!(decode.expect("cached_tok_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(decode.expect("uncached_tok_s").unwrap().as_f64().unwrap()
                > 0.0);
        assert!(decode.expect("cached_vs_uncached").unwrap().as_f64().unwrap()
                > 0.0);
        let decode_batch = report.expect("decode_batch").unwrap()
            .as_arr().unwrap();
        assert_eq!(decode_batch.len(), 2); // quick mode: batch 1 and 4
        for row in decode_batch {
            assert!(row.expect("batch").unwrap().as_usize().unwrap() >= 1);
            assert!(row.expect("serial_tok_s").unwrap().as_f64().unwrap()
                    > 0.0);
            assert!(row.expect("batched_tok_s").unwrap().as_f64().unwrap()
                    > 0.0);
            assert!(row.expect("batched_vs_serial").unwrap().as_f64().unwrap()
                    > 0.0);
        }
        let obs = report.expect("obs_overhead").unwrap();
        assert!(obs.expect("enabled_tok_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(obs.expect("disabled_tok_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(obs.expect("enabled_vs_disabled").unwrap().as_f64().unwrap()
                > 0.0);
        let load = report.expect("artifact_load").unwrap();
        assert!(load.expect("sites").unwrap().as_usize().unwrap() >= 1);
        assert!(load.expect("packed_bytes").unwrap().as_usize().unwrap() > 0);
        assert!(load.expect("pack1_file_bytes").unwrap().as_usize().unwrap()
                > 0);
        assert!(load.expect("pack2_file_bytes").unwrap().as_usize().unwrap()
                > 0);
        assert!(load.expect("eager_open_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(load.expect("pager_open_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(load.expect("page_in_all_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(load.expect("pager_vs_eager_open").unwrap().as_f64().unwrap()
                > 0.0);
        // round-trips through the hand-rolled JSON parser
        let parsed = Json::parse(&report.to_string()).unwrap();
        assert_eq!(parsed.expect("pr").unwrap().as_usize().unwrap(), 10);
    }
}
