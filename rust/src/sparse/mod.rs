//! Sparsity substrate: masks, statistics, CSR export and the 2:4 structured
//! pattern the paper names as future work (§5) — implemented here as an
//! extension so the ablation benches can compare unstructured vs 2:4.

pub mod mask;
pub mod structured;

pub use mask::{csr_from_dense, SparsityStats, SparseCsr};
pub use structured::{project_2_4, check_2_4};
