//! Sparsity statistics and CSR export.

use crate::tensor::Matrix;

/// Per-matrix sparsity report used by the coordinator's assembly step and
/// the experiment tables ("pruning ratio" columns).
#[derive(Clone, Debug, PartialEq)]
pub struct SparsityStats {
    pub total: usize,
    pub zeros: usize,
    pub row_min_nnz: usize,
    pub row_max_nnz: usize,
}

impl SparsityStats {
    pub fn of(w: &Matrix) -> Self {
        let mut zeros = 0usize;
        let mut row_min = usize::MAX;
        let mut row_max = 0usize;
        for i in 0..w.rows {
            let nnz = w.row(i).iter().filter(|&&v| v != 0.0).count();
            zeros += w.cols - nnz;
            row_min = row_min.min(nnz);
            row_max = row_max.max(nnz);
        }
        SparsityStats {
            total: w.rows * w.cols,
            zeros,
            row_min_nnz: if w.rows == 0 { 0 } else { row_min },
            row_max_nnz: row_max,
        }
    }

    pub fn ratio(&self) -> f64 {
        self.zeros as f64 / self.total.max(1) as f64
    }

    /// True when every row has the same nnz — the paper's semi-structured
    /// uniform-per-row property.
    pub fn is_row_uniform(&self) -> bool {
        self.row_min_nnz == self.row_max_nnz
    }
}

/// Compressed Sparse Row view of a pruned matrix — what a deployment stack
/// (e.g. the Cerebras-style sparse engine the paper cites) would ingest.
#[derive(Clone, Debug)]
pub struct SparseCsr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<u32>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

pub fn csr_from_dense(w: &Matrix) -> SparseCsr {
    let mut indptr = Vec::with_capacity(w.rows + 1);
    let mut indices = Vec::new();
    let mut values = Vec::new();
    indptr.push(0u32);
    for i in 0..w.rows {
        for (j, &v) in w.row(i).iter().enumerate() {
            if v != 0.0 {
                indices.push(j as u32);
                values.push(v);
            }
        }
        indptr.push(indices.len() as u32);
    }
    SparseCsr { rows: w.rows, cols: w.cols, indptr, indices, values }
}

impl SparseCsr {
    /// Dense reconstruction (for tests / eval).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for t in self.indptr[i] as usize..self.indptr[i + 1] as usize {
                *out.at_mut(i, self.indices[t] as usize) = self.values[t];
            }
        }
        out
    }

    /// y = A·x
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0f32; self.rows];
        for i in 0..self.rows {
            let mut s = 0.0f32;
            for t in self.indptr[i] as usize..self.indptr[i + 1] as usize {
                s += self.values[t] * x[self.indices[t] as usize];
            }
            y[i] = s;
        }
        y
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Storage bytes: values(f32) + indices(u32) + indptr(u32).
    pub fn size_bytes(&self) -> usize {
        4 * (self.values.len() + self.indices.len() + self.indptr.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::topk::hard_threshold_rows;

    #[test]
    fn stats_on_row_topk() {
        let w = hard_threshold_rows(&Matrix::randn(10, 20, 0), 5);
        let s = SparsityStats::of(&w);
        assert_eq!(s.total, 200);
        assert_eq!(s.zeros, 150);
        assert!(s.is_row_uniform());
        assert!((s.ratio() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn csr_roundtrip() {
        let w = hard_threshold_rows(&Matrix::randn(7, 13, 1), 4);
        let csr = csr_from_dense(&w);
        assert_eq!(csr.nnz(), 28);
        assert_eq!(csr.to_dense(), w);
    }

    #[test]
    fn csr_matvec_matches_dense() {
        let w = hard_threshold_rows(&Matrix::randn(5, 8, 2), 3);
        let csr = csr_from_dense(&w);
        let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.5 - 2.0).collect();
        let y = csr.matvec(&x);
        for i in 0..5 {
            let want: f32 = w.row(i).iter().zip(&x).map(|(a, b)| a * b).sum();
            assert!((y[i] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn empty_matrix() {
        let w = Matrix::zeros(3, 4);
        let s = SparsityStats::of(&w);
        assert_eq!(s.ratio(), 1.0);
        assert_eq!(csr_from_dense(&w).nnz(), 0);
    }
}
