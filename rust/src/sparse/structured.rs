//! NVIDIA-style 2:4 semi-structured sparsity — the paper's §5 future-work
//! direction, implemented as an extension: in every aligned group of 4
//! consecutive weights along `d_in`, at most 2 are non-zero.

use crate::tensor::Matrix;

/// Project onto the 2:4 pattern: keep the 2 largest-|.| entries of each
/// aligned 4-group. `d_in` must be a multiple of 4.
pub fn project_2_4(z: &Matrix) -> Matrix {
    assert_eq!(z.cols % 4, 0, "2:4 needs d_in % 4 == 0");
    let mut out = z.clone();
    for i in 0..z.rows {
        let row = out.row_mut(i);
        for g in (0..row.len()).step_by(4) {
            let quad = &mut row[g..g + 4];
            // indices of the two smallest magnitudes
            let mut idx = [0usize, 1, 2, 3];
            idx.sort_by(|&a, &b| {
                quad[b].abs().partial_cmp(&quad[a].abs()).unwrap()
            });
            quad[idx[2]] = 0.0;
            quad[idx[3]] = 0.0;
        }
    }
    out
}

/// Check the 2:4 invariant.
pub fn check_2_4(w: &Matrix) -> bool {
    if w.cols % 4 != 0 {
        return false;
    }
    for i in 0..w.rows {
        for g in (0..w.cols).step_by(4) {
            let nnz = w.row(i)[g..g + 4].iter().filter(|&&v| v != 0.0).count();
            if nnz > 2 {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn projection_satisfies_pattern() {
        let z = Matrix::randn(6, 16, 0);
        let p = project_2_4(&z);
        assert!(check_2_4(&p));
        assert!(!check_2_4(&z)); // randn almost surely violates it
    }

    #[test]
    fn projection_keeps_largest_two() {
        let z = Matrix::from_vec(1, 4, vec![1.0, -3.0, 0.5, 2.0]);
        let p = project_2_4(&z);
        assert_eq!(p.data, vec![0.0, -3.0, 0.0, 2.0]);
    }

    #[test]
    fn projection_idempotent() {
        let z = Matrix::randn(3, 8, 1);
        let p1 = project_2_4(&z);
        assert_eq!(project_2_4(&p1), p1);
    }

    #[test]
    fn exactly_half_sparsity() {
        let z = Matrix::randn(4, 32, 2);
        let p = project_2_4(&z);
        assert!((p.sparsity() - 0.5).abs() < 1e-9);
    }
}
