//! Span/trace layer: request trace IDs, RAII span timers, ring-buffer
//! span sink, Chrome trace-event export.
//!
//! Two independent facilities live here:
//!
//! * **Trace IDs** — [`next_request_id`] hands out process-unique request
//!   identifiers from one relaxed atomic; [`request_tag`] renders them as
//!   the `t-N` tokens that appear in every per-request log line (legacy
//!   text and `--log-json` alike). IDs are always on — they cost one
//!   `fetch_add` per request and make concurrent keep-alive connections
//!   distinguishable in the logs.
//! * **Spans** — [`span`] returns an RAII guard that, when tracing is
//!   enabled ([`set_enabled`]), records a completed-span event
//!   (name, category, start, duration, thread, args) into a bounded
//!   in-memory ring buffer on drop. When tracing is disabled (the
//!   default) the guard is inert: construction is one relaxed load, no
//!   clock read, no allocation. `repro serve --trace-out <file>` and
//!   `repro compress --trace-out <file>` enable the sink and export it as
//!   Chrome trace-event JSON ([`export_chrome`]) on exit — loadable in
//!   `chrome://tracing` / Perfetto.
//!
//! The sink keeps the most recent [`CAPACITY`] spans (oldest dropped
//! first). Spans record on *drop*, so a child span always lands in the
//! buffer before its parent — consumers that want the tree re-nest by
//! `[start, start+dur)` containment per thread, which is exactly what the
//! Chrome viewer does.

use std::collections::VecDeque;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::Json;

// ------------------------------------------------------------- trace ids

static NEXT_REQUEST_ID: AtomicU64 = AtomicU64::new(1);

/// Process-unique request id (one relaxed `fetch_add`; always on).
pub fn next_request_id() -> u64 {
    NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed)
}

/// The `t-N` token a request id carries in log lines and span args.
pub fn request_tag(id: u64) -> String {
    format!("t-{id}")
}

// ---------------------------------------------------------------- enable

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn the span sink on/off (default: off — spans are inert guards).
pub fn set_enabled(on: bool) {
    if on {
        epoch(); // pin the time origin before the first span
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether spans are currently recorded.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn thread_tag() -> u64 {
    static NEXT_TID: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

// ------------------------------------------------------------------ sink

/// Maximum retained spans; older spans are dropped first.
pub const CAPACITY: usize = 16384;

/// One completed span, as recorded into the sink.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub name: &'static str,
    /// Coarse subsystem category (`serve`, `batch`, `infer`, `coord`).
    pub cat: &'static str,
    /// Microseconds since the process trace epoch.
    pub start_us: u64,
    pub dur_us: u64,
    /// Small per-process thread tag (not the OS tid).
    pub tid: u64,
    pub args: Vec<(&'static str, String)>,
}

static SINK: Mutex<VecDeque<SpanRecord>> = Mutex::new(VecDeque::new());

fn push(rec: SpanRecord) {
    let mut sink = SINK.lock().unwrap();
    if sink.len() >= CAPACITY {
        sink.pop_front();
    }
    sink.push_back(rec);
}

/// Number of spans currently buffered.
pub fn len() -> usize {
    SINK.lock().unwrap().len()
}

/// Drain the sink (tests; export uses a non-draining snapshot).
pub fn take_records() -> Vec<SpanRecord> {
    SINK.lock().unwrap().drain(..).collect()
}

/// Copy of the buffered spans, oldest first.
pub fn records() -> Vec<SpanRecord> {
    SINK.lock().unwrap().iter().cloned().collect()
}

// ------------------------------------------------------------------ span

/// RAII span timer: records into the sink on drop when tracing is
/// enabled, inert otherwise. Create via [`span`].
pub struct Span {
    start: Option<Instant>,
    name: &'static str,
    cat: &'static str,
    args: Vec<(&'static str, String)>,
}

/// Open a span; the guard records `[construction, drop)` when enabled.
#[inline]
pub fn span(name: &'static str, cat: &'static str) -> Span {
    let start = if enabled() { Some(Instant::now()) } else { None };
    Span { start, name, cat, args: Vec::new() }
}

impl Span {
    /// Attach a key/value argument (no-op while the sink is disabled).
    pub fn arg(mut self, key: &'static str, value: impl Into<String>) -> Span {
        if self.start.is_some() {
            self.args.push((key, value.into()));
        }
        self
    }

    /// Attach an argument after construction (for values only known at
    /// the end of the spanned section, e.g. a tick's batch width).
    pub fn set_arg(&mut self, key: &'static str, value: impl Into<String>) {
        if self.start.is_some() {
            self.args.push((key, value.into()));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            let dur_us = start.elapsed().as_micros() as u64;
            let start_us = start.saturating_duration_since(epoch()).as_micros() as u64;
            push(SpanRecord {
                name: self.name,
                cat: self.cat,
                start_us,
                dur_us,
                tid: thread_tag(),
                args: std::mem::take(&mut self.args),
            });
        }
    }
}

// ---------------------------------------------------------------- export

/// The buffered spans as a Chrome trace-event JSON object
/// (`{"traceEvents": [...]}`, complete `ph:"X"` events, µs timestamps).
pub fn export_chrome() -> Json {
    let events = records()
        .into_iter()
        .map(|rec| {
            let args =
                Json::Obj(rec.args.into_iter().map(|(k, v)| (k.to_string(), Json::Str(v))).collect());
            Json::obj(vec![
                ("name", Json::Str(rec.name.to_string())),
                ("cat", Json::Str(rec.cat.to_string())),
                ("ph", Json::Str("X".to_string())),
                ("ts", Json::Num(rec.start_us as f64)),
                ("dur", Json::Num(rec.dur_us as f64)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(rec.tid as f64)),
                ("args", args),
            ])
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

/// Write the Chrome trace to `path` and report the span count.
pub fn write_chrome_trace(path: &Path) -> Result<usize> {
    let n = len();
    std::fs::write(path, export_chrome().to_string())
        .with_context(|| format!("writing trace to {}", path.display()))?;
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tests share the process-global sink; serialise via the metrics
    // enable lock (same discipline as the registry tests).
    use crate::obs::metrics::enable_guard;

    #[test]
    fn request_ids_are_unique_and_tagged() {
        let a = next_request_id();
        let b = next_request_id();
        assert_ne!(a, b);
        assert_eq!(request_tag(7), "t-7");
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = enable_guard();
        set_enabled(false);
        let before = len();
        {
            let _s = span("noop", "test").arg("k", "v");
        }
        assert_eq!(len(), before);
    }

    #[test]
    fn spans_nest_child_before_parent() {
        let _g = enable_guard();
        set_enabled(true);
        take_records();
        {
            let _parent = span("parent", "test");
            std::thread::sleep(std::time::Duration::from_millis(2));
            {
                let _child = span("child", "test").arg("n", "1");
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        set_enabled(false);
        // Other tests may emit spans concurrently; look at ours only.
        let recs: Vec<SpanRecord> = take_records()
            .into_iter()
            .filter(|r| r.name == "parent" || r.name == "child")
            .collect();
        assert_eq!(recs.len(), 2);
        // Drop order: child lands first.
        assert_eq!(recs[0].name, "child");
        assert_eq!(recs[1].name, "parent");
        let (child, parent) = (&recs[0], &recs[1]);
        assert!(parent.start_us <= child.start_us);
        assert!(child.start_us + child.dur_us <= parent.start_us + parent.dur_us + 1);
        assert_eq!(child.args, vec![("n", "1".to_string())]);
        assert_eq!(child.tid, parent.tid);
    }

    #[test]
    fn chrome_export_roundtrips_through_parser() {
        let _g = enable_guard();
        set_enabled(true);
        take_records();
        {
            let _s = span("tick", "batch").arg("occupancy", "3");
        }
        set_enabled(false);
        let json = export_chrome();
        let back = Json::parse(&json.to_string()).unwrap();
        let events = back.expect("traceEvents").unwrap().as_arr().unwrap();
        let ev = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str().ok()) == Some("tick"))
            .expect("tick span exported");
        assert_eq!(ev.expect("name").unwrap().as_str().unwrap(), "tick");
        assert_eq!(ev.expect("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(
            ev.expect("args").unwrap().expect("occupancy").unwrap().as_str().unwrap(),
            "3"
        );
        take_records();
    }

    #[test]
    fn sink_is_bounded() {
        let _g = enable_guard();
        set_enabled(true);
        take_records();
        for _ in 0..CAPACITY + 10 {
            let _s = span("spin", "test");
        }
        assert_eq!(len(), CAPACITY);
        set_enabled(false);
        take_records();
    }
}
