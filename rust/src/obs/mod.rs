//! Observability: process-global metrics registry + span/trace layer.
//!
//! This is the cross-cutting layer every subsystem emits into (see
//! OBSERVABILITY.md for the full metric inventory, span hierarchy, and
//! overhead policy):
//!
//! * [`metrics`] — dependency-free counters/gauges/fixed-bucket
//!   histograms behind typed handles on one `static` [`metrics::REGISTRY`];
//!   hot paths pay a single relaxed atomic add. Exported as Prometheus
//!   text (`GET /metrics`) and JSON (`GET /v1/stats`) by the server.
//! * [`trace`] — per-request trace IDs, RAII span timers over the
//!   serve → batcher → infer → kernel path, a bounded in-memory span
//!   sink, and Chrome trace-event JSON export
//!   (`repro serve|compress --trace-out <file>`).
//!
//! Both layers are observation-only: they wrap existing calls with
//! timing and counting, never change arithmetic, and are individually
//! disableable down to one relaxed load per site — so the reference-tier
//! bit-identity contracts (KERNELS.md, SERVING.md) hold with
//! instrumentation on or off, and the residual cost is tracked by the
//! `obs_overhead` section of `repro bench-json`.

pub mod metrics;
pub mod trace;
