//! Lock-cheap metrics registry: counters, gauges, fixed-bucket histograms.
//!
//! The registry is dependency-free (no prometheus crate on the image, just
//! as `util::json` carries no serde) and built so hot paths pay **one
//! relaxed atomic add** per observation:
//!
//! * [`Counter`] / [`Gauge`] — a single `AtomicU64` each;
//! * [`Histogram`] — a fixed bound slice chosen at construction plus one
//!   atomic per bucket; `observe` is a linear scan over ≤ 15 bounds, one
//!   `fetch_add` on the bucket, count, and micro-scaled sum;
//! * [`LabeledCounter`] — a small mutex-guarded cell list for the one
//!   *request-rate* metric with dynamic labels (route × status). Request
//!   arrival is thousands/sec at most; the token-rate and GEMM-rate paths
//!   never touch a lock.
//!
//! Every handle lives in the process-global [`REGISTRY`] (`static`,
//! const-initialised — no lazy-init branch on the hot path). All metric
//! names carry the `awp_` prefix on the wire.
//!
//! ## Disabling
//!
//! [`set_enabled`]`(false)` turns every observation into a single relaxed
//! load + predictable branch — the no-op tier the `obs_overhead` bench
//! section compares against (see OBSERVABILITY.md for the overhead
//! policy). Instrumentation never changes math: timing wraps existing
//! calls, so the reference-tier bit-identity contracts are untouched
//! either way.
//!
//! ## Snapshots
//!
//! Reads are relaxed loads per atomic — a scrape is monotonic per metric
//! but not a consistent cut across metrics, which is exactly the
//! Prometheus contract. [`render_prometheus`] emits the text exposition
//! format (`text/plain; version=0.0.4`); [`snapshot_json`] the same data
//! as one JSON object for `/v1/stats`.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

// ---------------------------------------------------------------- enable

static ENABLED: AtomicBool = AtomicBool::new(true);

/// Serialises code that toggles [`set_enabled`] against tests that assert
/// observation behaviour (the flag is process-global, tests run
/// concurrently). Runtime serving code never takes this lock.
#[doc(hidden)]
pub static ENABLE_LOCK: Mutex<()> = Mutex::new(());

/// Hold this guard for the whole enabled-state-sensitive section (a test
/// asserting counts, or a bench toggling the flag).
#[doc(hidden)]
pub fn enable_guard() -> std::sync::MutexGuard<'static, ()> {
    ENABLE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Globally enable/disable all metric observations (default: enabled).
/// Disabled observations cost one relaxed load and a predictable branch.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether observations are currently recorded.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// `Some(Instant::now())` when metrics are enabled, `None` otherwise —
/// lets callers skip the clock read entirely on the disabled tier.
#[inline]
pub fn timer() -> Option<Instant> {
    if enabled() {
        Some(Instant::now())
    } else {
        None
    }
}

// --------------------------------------------------------------- counter

/// Monotonic counter; one relaxed `fetch_add` per increment.
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if enabled() {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Add seconds scaled to integer microseconds (for busy-time counters).
    #[inline]
    pub fn add_seconds(&self, s: f64) {
        if enabled() {
            self.0.fetch_add((s * 1e6) as u64, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Value of a micro-scaled counter back in seconds.
    pub fn seconds(&self) -> f64 {
        self.get() as f64 / 1e6
    }
}

// ----------------------------------------------------------------- gauge

/// Last-write-wins gauge (u64 values: bytes, session counts, …).
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: u64) {
        if enabled() {
            self.0.store(v, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

// ------------------------------------------------------------- histogram

/// Upper bound on buckets per histogram (bounds ≤ 15, plus the implicit
/// `+Inf` overflow bucket).
pub const MAX_BUCKETS: usize = 16;

const ZERO: AtomicU64 = AtomicU64::new(0);

/// Fixed-bucket histogram with Prometheus `le` semantics: bucket `i`
/// counts observations `v <= bounds[i]`; everything above the last bound
/// lands in the overflow (`+Inf`) bucket. The sum is kept micro-scaled in
/// a u64 so observation stays a handful of relaxed adds.
pub struct Histogram {
    bounds: &'static [f64],
    buckets: [AtomicU64; MAX_BUCKETS],
    count: AtomicU64,
    sum_micros: AtomicU64,
}

/// Point-in-time copy of a histogram (raw, non-cumulative buckets).
#[derive(Clone, Debug, PartialEq)]
pub struct HistSnapshot {
    pub bounds: &'static [f64],
    /// Raw per-bucket counts; `buckets[bounds.len()]` is the overflow.
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

impl Histogram {
    /// `bounds` must be strictly increasing and at most `MAX_BUCKETS - 1`
    /// long; checked by the registry unit test rather than at runtime so
    /// construction stays `const`.
    pub const fn new(bounds: &'static [f64]) -> Histogram {
        Histogram {
            bounds,
            buckets: [ZERO; MAX_BUCKETS],
            count: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn observe(&self, v: f64) {
        if !enabled() {
            return;
        }
        let mut idx = self.bounds.len(); // overflow slot
        for (i, &b) in self.bounds.iter().enumerate() {
            if v <= b {
                idx = i;
                break;
            }
        }
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micros.fetch_add((v * 1e6) as u64, Ordering::Relaxed);
    }

    /// Observe the elapsed time of a [`timer`] started earlier, if any.
    #[inline]
    pub fn observe_since(&self, start: Option<Instant>) {
        if let Some(t) = start {
            self.observe(t.elapsed().as_secs_f64());
        }
    }

    pub fn snapshot(&self) -> HistSnapshot {
        let n = self.bounds.len() + 1;
        HistSnapshot {
            bounds: self.bounds,
            buckets: (0..n).map(|i| self.buckets[i].load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum_micros.load(Ordering::Relaxed) as f64 / 1e6,
        }
    }
}

impl HistSnapshot {
    /// Cumulative counts in `le` order (Prometheus exposition form); the
    /// final entry is the `+Inf` bucket and equals `count` as sampled.
    pub fn cumulative(&self) -> Vec<u64> {
        let mut acc = 0u64;
        self.buckets
            .iter()
            .map(|&b| {
                acc += b;
                acc
            })
            .collect()
    }
}

// ------------------------------------------------------- labeled counter

/// Counter keyed by (route, status). Cells are registered on first use
/// under a mutex; the cell list is tiny (routes × statuses actually
/// seen), so an increment is one short critical section. Only the
/// request-rate path uses this — never the per-token or per-GEMM paths.
pub struct LabeledCounter {
    cells: Mutex<Vec<((&'static str, u16), u64)>>,
}

impl LabeledCounter {
    pub const fn new() -> LabeledCounter {
        LabeledCounter { cells: Mutex::new(Vec::new()) }
    }

    pub fn inc(&self, route: &'static str, status: u16) {
        if !enabled() {
            return;
        }
        let mut cells = self.cells.lock().unwrap();
        if let Some(cell) = cells.iter_mut().find(|(k, _)| *k == (route, status)) {
            cell.1 += 1;
        } else {
            cells.push(((route, status), 1));
        }
    }

    /// Cells sorted by (route, status) for deterministic rendering.
    pub fn snapshot(&self) -> Vec<((&'static str, u16), u64)> {
        let mut cells = self.cells.lock().unwrap().clone();
        cells.sort_unstable_by_key(|&((r, s), _)| (r, s));
        cells
    }

    /// Sum across all cells.
    pub fn total(&self) -> u64 {
        self.cells.lock().unwrap().iter().map(|(_, n)| n).sum()
    }
}

// -------------------------------------------------------------- registry

/// Latency-style bounds (seconds) for sub-second request/tick paths.
pub const TICK_BOUNDS: &[f64] = &[
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
];

/// Request-latency bounds (seconds) — generate requests span ms to tens
/// of seconds depending on `max_tokens`.
pub const REQUEST_BOUNDS: &[f64] =
    &[0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0];

/// Batch-occupancy bounds (stream count per decode tick).
pub const OCCUPANCY_BOUNDS: &[f64] = &[1.0, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0, 16.0, 32.0];

/// Executor-job duration bounds (seconds) — layer jobs run ms to minutes.
pub const JOB_BOUNDS: &[f64] =
    &[0.001, 0.005, 0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0];

/// Every metric the system exports, as typed handles. Fields are grouped
/// by emitting subsystem; OBSERVABILITY.md carries the full inventory
/// with wire names and label sets.
pub struct Registry {
    // serve/server.rs
    /// `awp_requests_total{route,status}`
    pub requests: LabeledCounter,
    /// `awp_request_seconds`
    pub request_seconds: Histogram,
    // serve/batcher.rs
    /// `awp_decode_ticks_total`
    pub decode_ticks: Counter,
    /// `awp_decode_tick_seconds`
    pub decode_tick_seconds: Histogram,
    /// `awp_batch_occupancy`
    pub batch_occupancy: Histogram,
    /// `awp_queue_wait_seconds`
    pub queue_wait_seconds: Histogram,
    /// `awp_generated_tokens_total`
    pub generated_tokens: Counter,
    // serve/session.rs
    /// `awp_kv_bytes`
    pub kv_bytes: Gauge,
    /// `awp_sessions_live`
    pub sessions_live: Gauge,
    /// `awp_session_evictions_total`
    pub session_evictions: Counter,
    // coordinator/cache.rs
    /// `awp_gram_cache_hits_total{layer="mem"|"disk"}`
    pub gram_mem_hits: Counter,
    pub gram_disk_hits: Counter,
    /// `awp_gram_cache_misses_total`
    pub gram_misses: Counter,
    // artifact/store.rs
    /// `awp_artifact_cache_hits_total` / `_misses_total` / `_stores_total`
    pub artifact_hits: Counter,
    pub artifact_misses: Counter,
    pub artifact_stores: Counter,
    // artifact/pager.rs
    /// `awp_pager_hits_total` / `_misses_total` / `_evictions_total`
    pub pager_hits: Counter,
    pub pager_misses: Counter,
    pub pager_evictions: Counter,
    /// `awp_weight_resident_bytes`
    pub weight_resident_bytes: Gauge,
    // coordinator/executor.rs
    /// `awp_executor_jobs_total`
    pub executor_jobs: Counter,
    /// `awp_executor_job_seconds`
    pub executor_job_seconds: Histogram,
    // infer/linear.rs + artifact/packed.rs
    /// `awp_kernel_calls_total{tier}` and busy-time (micro-scaled)
    /// `awp_kernel_busy_seconds_total{tier}`
    pub kernel_reference_calls: Counter,
    pub kernel_reference_micros: Counter,
    pub kernel_fast_calls: Counter,
    pub kernel_fast_micros: Counter,
}

impl Registry {
    pub const fn new() -> Registry {
        Registry {
            requests: LabeledCounter::new(),
            request_seconds: Histogram::new(REQUEST_BOUNDS),
            decode_ticks: Counter::new(),
            decode_tick_seconds: Histogram::new(TICK_BOUNDS),
            batch_occupancy: Histogram::new(OCCUPANCY_BOUNDS),
            queue_wait_seconds: Histogram::new(TICK_BOUNDS),
            generated_tokens: Counter::new(),
            kv_bytes: Gauge::new(),
            sessions_live: Gauge::new(),
            session_evictions: Counter::new(),
            gram_mem_hits: Counter::new(),
            gram_disk_hits: Counter::new(),
            gram_misses: Counter::new(),
            artifact_hits: Counter::new(),
            artifact_misses: Counter::new(),
            artifact_stores: Counter::new(),
            pager_hits: Counter::new(),
            pager_misses: Counter::new(),
            pager_evictions: Counter::new(),
            weight_resident_bytes: Gauge::new(),
            executor_jobs: Counter::new(),
            executor_job_seconds: Histogram::new(JOB_BOUNDS),
            kernel_reference_calls: Counter::new(),
            kernel_reference_micros: Counter::new(),
            kernel_fast_calls: Counter::new(),
            kernel_fast_micros: Counter::new(),
        }
    }
}

/// The process-global registry every subsystem emits into.
pub static REGISTRY: Registry = Registry::new();

// ------------------------------------------------------------- rendering

fn fmt_bound(b: f64) -> String {
    if b.fract() == 0.0 {
        format!("{}", b as i64)
    } else {
        format!("{b}")
    }
}

fn fmt_val(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn render_histogram(out: &mut String, name: &str, help: &str, h: &Histogram) {
    let snap = h.snapshot();
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
    let cum = snap.cumulative();
    for (i, &b) in snap.bounds.iter().enumerate() {
        out.push_str(&format!("{name}_bucket{{le=\"{}\"}} {}\n", fmt_bound(b), cum[i]));
    }
    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", cum[snap.bounds.len()]));
    out.push_str(&format!("{name}_sum {}\n", fmt_val(snap.sum)));
    out.push_str(&format!("{name}_count {}\n", snap.count));
}

fn render_counter(out: &mut String, name: &str, help: &str, v: u64) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"));
}

fn render_gauge(out: &mut String, name: &str, help: &str, v: u64) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"));
}

/// Content-Type for the Prometheus text exposition format.
pub const PROMETHEUS_CONTENT_TYPE: &str = "text/plain; version=0.0.4";

/// Render the whole registry in the Prometheus text exposition format.
pub fn render_prometheus() -> String {
    let r = &REGISTRY;
    let mut out = String::with_capacity(4096);

    out.push_str(
        "# HELP awp_requests_total HTTP requests served, by route and status.\n\
         # TYPE awp_requests_total counter\n",
    );
    for ((route, status), n) in r.requests.snapshot() {
        out.push_str(&format!(
            "awp_requests_total{{route=\"{route}\",status=\"{status}\"}} {n}\n"
        ));
    }
    render_histogram(
        &mut out,
        "awp_request_seconds",
        "Wall-clock request latency in seconds.",
        &r.request_seconds,
    );

    render_counter(
        &mut out,
        "awp_decode_ticks_total",
        "Batched decode ticks executed.",
        r.decode_ticks.get(),
    );
    render_histogram(
        &mut out,
        "awp_decode_tick_seconds",
        "Latency of one batched decode tick in seconds.",
        &r.decode_tick_seconds,
    );
    render_histogram(
        &mut out,
        "awp_batch_occupancy",
        "Streams fused per decode tick.",
        &r.batch_occupancy,
    );
    render_histogram(
        &mut out,
        "awp_queue_wait_seconds",
        "Wait from stream submission to its first decode tick.",
        &r.queue_wait_seconds,
    );
    render_counter(
        &mut out,
        "awp_generated_tokens_total",
        "Tokens generated across all streams.",
        r.generated_tokens.get(),
    );

    render_gauge(&mut out, "awp_kv_bytes", "Resident KV-cache bytes.", r.kv_bytes.get());
    render_gauge(&mut out, "awp_sessions_live", "Live sessions in the store.", r.sessions_live.get());
    render_counter(
        &mut out,
        "awp_session_evictions_total",
        "Idle sessions evicted (LRU or KV budget).",
        r.session_evictions.get(),
    );

    out.push_str(
        "# HELP awp_gram_cache_hits_total Gram calibration cache hits, by layer.\n\
         # TYPE awp_gram_cache_hits_total counter\n",
    );
    out.push_str(&format!(
        "awp_gram_cache_hits_total{{layer=\"mem\"}} {}\n",
        r.gram_mem_hits.get()
    ));
    out.push_str(&format!(
        "awp_gram_cache_hits_total{{layer=\"disk\"}} {}\n",
        r.gram_disk_hits.get()
    ));
    render_counter(
        &mut out,
        "awp_gram_cache_misses_total",
        "Gram calibration cache misses (recomputed).",
        r.gram_misses.get(),
    );
    render_counter(
        &mut out,
        "awp_artifact_cache_hits_total",
        "Artifact store hits (warm compression reruns).",
        r.artifact_hits.get(),
    );
    render_counter(
        &mut out,
        "awp_artifact_cache_misses_total",
        "Artifact store misses.",
        r.artifact_misses.get(),
    );
    render_counter(
        &mut out,
        "awp_artifact_cache_stores_total",
        "Artifacts persisted to the store.",
        r.artifact_stores.get(),
    );

    render_counter(
        &mut out,
        "awp_pager_hits_total",
        "Weight-pager site touches served from residency.",
        r.pager_hits.get(),
    );
    render_counter(
        &mut out,
        "awp_pager_misses_total",
        "Weight-pager site touches paged in from disk.",
        r.pager_misses.get(),
    );
    render_counter(
        &mut out,
        "awp_pager_evictions_total",
        "Weight-pager sites evicted under the byte budget.",
        r.pager_evictions.get(),
    );
    render_gauge(
        &mut out,
        "awp_weight_resident_bytes",
        "Prepared model-weight bytes resident in the pager.",
        r.weight_resident_bytes.get(),
    );

    render_counter(
        &mut out,
        "awp_executor_jobs_total",
        "Executor jobs completed.",
        r.executor_jobs.get(),
    );
    render_histogram(
        &mut out,
        "awp_executor_job_seconds",
        "Executor job duration in seconds.",
        &r.executor_job_seconds,
    );

    out.push_str(
        "# HELP awp_kernel_calls_total Linear-site GEMM launches, by kernel tier.\n\
         # TYPE awp_kernel_calls_total counter\n",
    );
    out.push_str(&format!(
        "awp_kernel_calls_total{{tier=\"reference\"}} {}\n",
        r.kernel_reference_calls.get()
    ));
    out.push_str(&format!(
        "awp_kernel_calls_total{{tier=\"fast\"}} {}\n",
        r.kernel_fast_calls.get()
    ));
    out.push_str(
        "# HELP awp_kernel_busy_seconds_total Time spent inside linear-site GEMMs, by tier.\n\
         # TYPE awp_kernel_busy_seconds_total counter\n",
    );
    out.push_str(&format!(
        "awp_kernel_busy_seconds_total{{tier=\"reference\"}} {}\n",
        fmt_val(r.kernel_reference_micros.seconds())
    ));
    out.push_str(&format!(
        "awp_kernel_busy_seconds_total{{tier=\"fast\"}} {}\n",
        fmt_val(r.kernel_fast_micros.seconds())
    ));
    out
}

fn hist_json(h: &Histogram) -> Json {
    let snap = h.snapshot();
    Json::obj(vec![
        ("bounds", Json::arr_f64(snap.bounds)),
        (
            "buckets",
            Json::Arr(snap.buckets.iter().map(|&b| Json::Num(b as f64)).collect()),
        ),
        ("count", Json::Num(snap.count as f64)),
        ("sum", Json::Num(snap.sum)),
    ])
}

/// The whole registry as one JSON object (the `/v1/stats` body).
pub fn snapshot_json() -> Json {
    let r = &REGISTRY;
    let requests = Json::Arr(
        r.requests
            .snapshot()
            .into_iter()
            .map(|((route, status), n)| {
                Json::obj(vec![
                    ("route", Json::Str(route.to_string())),
                    ("status", Json::Num(status as f64)),
                    ("count", Json::Num(n as f64)),
                ])
            })
            .collect(),
    );
    Json::obj(vec![
        ("requests", requests),
        ("request_seconds", hist_json(&r.request_seconds)),
        ("decode_ticks", Json::Num(r.decode_ticks.get() as f64)),
        ("decode_tick_seconds", hist_json(&r.decode_tick_seconds)),
        ("batch_occupancy", hist_json(&r.batch_occupancy)),
        ("queue_wait_seconds", hist_json(&r.queue_wait_seconds)),
        ("generated_tokens", Json::Num(r.generated_tokens.get() as f64)),
        ("kv_bytes", Json::Num(r.kv_bytes.get() as f64)),
        ("sessions_live", Json::Num(r.sessions_live.get() as f64)),
        ("session_evictions", Json::Num(r.session_evictions.get() as f64)),
        (
            "gram_cache",
            Json::obj(vec![
                ("mem_hits", Json::Num(r.gram_mem_hits.get() as f64)),
                ("disk_hits", Json::Num(r.gram_disk_hits.get() as f64)),
                ("misses", Json::Num(r.gram_misses.get() as f64)),
            ]),
        ),
        (
            "artifact_cache",
            Json::obj(vec![
                ("hits", Json::Num(r.artifact_hits.get() as f64)),
                ("misses", Json::Num(r.artifact_misses.get() as f64)),
                ("stores", Json::Num(r.artifact_stores.get() as f64)),
            ]),
        ),
        (
            "pager",
            Json::obj(vec![
                ("hits", Json::Num(r.pager_hits.get() as f64)),
                ("misses", Json::Num(r.pager_misses.get() as f64)),
                ("evictions", Json::Num(r.pager_evictions.get() as f64)),
                ("resident_bytes", Json::Num(r.weight_resident_bytes.get() as f64)),
            ]),
        ),
        ("executor_jobs", Json::Num(r.executor_jobs.get() as f64)),
        ("executor_job_seconds", hist_json(&r.executor_job_seconds)),
        (
            "kernels",
            Json::obj(vec![
                (
                    "reference",
                    Json::obj(vec![
                        ("calls", Json::Num(r.kernel_reference_calls.get() as f64)),
                        ("busy_s", Json::Num(r.kernel_reference_micros.seconds())),
                    ]),
                ),
                (
                    "fast",
                    Json::obj(vec![
                        ("calls", Json::Num(r.kernel_fast_calls.get() as f64)),
                        ("busy_s", Json::Num(r.kernel_fast_micros.seconds())),
                    ]),
                ),
            ]),
        ),
    ])
}

/// Record one kernel-tier GEMM launch of `seconds` on `fast`'s tier.
#[inline]
pub fn observe_kernel(fast: bool, start: Option<Instant>) {
    if let Some(t) = start {
        let s = t.elapsed().as_secs_f64();
        if fast {
            REGISTRY.kernel_fast_calls.inc();
            REGISTRY.kernel_fast_micros.add_seconds(s);
        } else {
            REGISTRY.kernel_reference_calls.inc();
            REGISTRY.kernel_reference_micros.add_seconds(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let _g = enable_guard();
        set_enabled(true);
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(17);
        g.set(3);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn histogram_le_semantics() {
        let _g = enable_guard();
        set_enabled(true);
        static BOUNDS: &[f64] = &[1.0, 2.0, 5.0];
        let h = Histogram::new(BOUNDS);
        for v in [0.5, 1.0, 1.5, 2.0, 4.9, 5.0, 5.1, 100.0] {
            h.observe(v);
        }
        let snap = h.snapshot();
        // le=1: {0.5, 1.0}; le=2: {1.5, 2.0}; le=5: {4.9, 5.0}; +Inf: rest
        assert_eq!(snap.buckets, vec![2, 2, 2, 2]);
        assert_eq!(snap.cumulative(), vec![2, 4, 6, 8]);
        assert_eq!(snap.count, 8);
        assert!((snap.sum - 120.0).abs() < 1e-3, "sum {}", snap.sum);
    }

    #[test]
    fn registry_bounds_are_valid() {
        for bounds in [TICK_BOUNDS, REQUEST_BOUNDS, OCCUPANCY_BOUNDS, JOB_BOUNDS] {
            assert!(bounds.len() < MAX_BUCKETS, "too many bounds");
            for w in bounds.windows(2) {
                assert!(w[0] < w[1], "bounds not increasing: {bounds:?}");
            }
        }
    }

    #[test]
    fn labeled_counter_sorts_deterministically() {
        let _g = enable_guard();
        set_enabled(true);
        let c = LabeledCounter::new();
        c.inc("/v1/generate", 200);
        c.inc("/healthz", 200);
        c.inc("/v1/generate", 429);
        c.inc("/v1/generate", 200);
        let snap = c.snapshot();
        assert_eq!(
            snap,
            vec![
                (("/healthz", 200), 1),
                (("/v1/generate", 200), 2),
                (("/v1/generate", 429), 1),
            ]
        );
        assert_eq!(c.total(), 4);
    }

    #[test]
    fn disabled_observations_are_dropped() {
        let _g = enable_guard();
        let c = Counter::new();
        let h = Histogram::new(TICK_BOUNDS);
        set_enabled(false);
        c.inc();
        h.observe(0.01);
        assert!(timer().is_none());
        set_enabled(true);
        assert_eq!(c.get(), 0);
        assert_eq!(h.snapshot().count, 0);
        c.inc();
        assert_eq!(c.get(), 1);
    }

    #[test]
    fn prometheus_render_has_required_families() {
        let _g = enable_guard();
        set_enabled(true);
        // Touch one cell so requests_total renders at least one sample.
        REGISTRY.requests.inc("/healthz", 200);
        let text = render_prometheus();
        for needle in [
            "# TYPE awp_requests_total counter",
            "awp_requests_total{route=\"/healthz\",status=\"200\"}",
            "# TYPE awp_decode_tick_seconds histogram",
            "awp_decode_tick_seconds_bucket{le=\"+Inf\"}",
            "# TYPE awp_batch_occupancy histogram",
            "# TYPE awp_kv_bytes gauge",
            "awp_session_evictions_total",
            "awp_gram_cache_hits_total{layer=\"mem\"}",
            "awp_artifact_cache_misses_total",
            "awp_pager_hits_total",
            "awp_pager_evictions_total",
            "# TYPE awp_weight_resident_bytes gauge",
            "# TYPE awp_executor_job_seconds histogram",
            "awp_kernel_calls_total{tier=\"fast\"}",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }

    #[test]
    fn stats_json_parses_back() {
        let j = snapshot_json();
        let back = Json::parse(&j.to_string()).unwrap();
        assert!(back.get("decode_tick_seconds").is_some());
        assert!(back.get("gram_cache").unwrap().get("misses").is_some());
    }
}
