//! Typed routing: a static route table mapping `(method, path)` to handler
//! functions over [`ServeState`], with errors as [`ApiError`] values that
//! render to JSON error responses. Handlers are plain `fn`s — no macros, no
//! extractors — and every endpoint's request/response schema is documented
//! in SERVING.md with worked examples.

use std::time::Instant;

use crate::coordinator::Executor;
use crate::data::ByteTokenizer;
use crate::eval::argmax;
use crate::infer::NativeModel;
use crate::util::json::Json;

use super::http::{Request, Response};
use super::session::{ServeSession, SessionStore, TakeError};

/// Static facts about the artifact being served, shown by `/v1/inspect`
/// and the startup log (computed once in `main.rs` from the loaded
/// artifact; the model itself holds only the packed sites).
#[derive(Debug, Clone)]
pub struct ServeInfo {
    /// Model name (`ModelConfig::name`).
    pub model: String,
    /// Artifact path the server was started from.
    pub source: String,
    /// Compression method label ("awp", "rtn", …).
    pub method: String,
    /// Human-readable compression spec (`CompressionSpec::describe`).
    pub spec: String,
    /// Bit-packed payload bytes across all sites.
    pub packed_bytes: usize,
}

/// Everything a handler can touch: the model (read-only — all mutable
/// per-connection state lives in sessions), the session store, and the
/// serving limits.
pub struct ServeState {
    pub model: NativeModel,
    pub info: ServeInfo,
    pub exec: Executor,
    pub sessions: SessionStore,
    /// Per-session context window (K/V rows a session can hold).
    pub max_ctx: usize,
    pub started: Instant,
}

impl ServeState {
    pub fn new(model: NativeModel, info: ServeInfo, exec: Executor,
               max_ctx: usize, max_sessions: usize) -> ServeState {
        ServeState {
            model,
            info,
            exec,
            sessions: SessionStore::new(max_sessions),
            max_ctx: max_ctx.max(2),
            started: Instant::now(),
        }
    }
}

/// A handler failure: HTTP status plus a message the client sees as
/// `{"error": message}`.
#[derive(Debug)]
pub struct ApiError {
    pub status: u16,
    pub message: String,
}

impl ApiError {
    pub fn new(status: u16, message: impl Into<String>) -> ApiError {
        ApiError { status, message: message.into() }
    }

    pub fn bad_request(message: impl Into<String>) -> ApiError {
        ApiError::new(400, message)
    }

    pub fn to_response(&self) -> Response {
        Response::json(
            self.status,
            &Json::obj(vec![("error", Json::Str(self.message.clone()))]),
        )
    }
}

impl From<anyhow::Error> for ApiError {
    fn from(e: anyhow::Error) -> ApiError {
        ApiError::new(500, format!("{e:#}"))
    }
}

type Handler = fn(&ServeState, &Request) -> Result<Response, ApiError>;

/// One row of the route table.
pub struct Route {
    pub method: &'static str,
    pub path: &'static str,
    pub handler: Handler,
}

/// The server's whole API surface, in match order.
pub const ROUTES: &[Route] = &[
    Route { method: "GET", path: "/healthz", handler: healthz },
    Route { method: "GET", path: "/v1/inspect", handler: inspect },
    Route { method: "POST", path: "/v1/generate", handler: generate },
    Route { method: "POST", path: "/v1/perplexity", handler: perplexity },
];

/// Dispatch `req` against [`ROUTES`]: unknown path → 404, known path with
/// the wrong method → 405, handler error → its status. Never panics on
/// client input.
pub fn handle(state: &ServeState, req: &Request) -> Response {
    let mut path_known = false;
    for route in ROUTES {
        if route.path != req.path {
            continue;
        }
        path_known = true;
        if route.method == req.method {
            return match (route.handler)(state, req) {
                Ok(resp) => resp,
                Err(e) => e.to_response(),
            };
        }
    }
    let status = if path_known { 405 } else { 404 };
    ApiError::new(status, format!("no route for {} {}", req.method, req.path))
        .to_response()
}

// --------------------------------------------------------------- handlers

/// `GET /healthz` — liveness plus the numbers a load balancer would scrape.
fn healthz(state: &ServeState, _req: &Request) -> Result<Response, ApiError> {
    let body = Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("model", Json::Str(state.info.model.clone())),
        ("tier", Json::Str(state.model.tier().describe().into())),
        ("sessions", Json::Num(state.sessions.len() as f64)),
        ("uptime_s", Json::Num(state.started.elapsed().as_secs_f64())),
    ]);
    Ok(Response::json(200, &body))
}

/// `GET /v1/inspect` — identity and footprint of the artifact being served.
fn inspect(state: &ServeState, _req: &Request) -> Result<Response, ApiError> {
    let body = Json::obj(vec![
        ("model", Json::Str(state.info.model.clone())),
        ("source", Json::Str(state.info.source.clone())),
        ("method", Json::Str(state.info.method.clone())),
        ("spec", Json::Str(state.info.spec.clone())),
        ("packed_bytes", Json::Num(state.info.packed_bytes as f64)),
        ("packed_sites", Json::Num(state.model.packed_site_count() as f64)),
        ("dense_sites", Json::Num(state.model.dense_site_count() as f64)),
        ("tier", Json::Str(state.model.tier().describe().into())),
        ("max_ctx", Json::Num(state.max_ctx as f64)),
        ("max_sessions", Json::Num(state.sessions.cap() as f64)),
        ("sessions", Json::Num(state.sessions.len() as f64)),
        ("evicted", Json::Num(state.sessions.evicted() as f64)),
    ]);
    Ok(Response::json(200, &body))
}

/// `POST /v1/generate` `{prompt, max_tokens?, session?}` — greedy
/// generation through the KV-cached decode path. Without `session` a fresh
/// [`crate::infer::DecodeSession`] is created and its id returned; with
/// one, generation *continues* the cached context — the prompt is appended
/// to everything the session has seen, at O(new tokens) cost, and the
/// result is bit-identical (reference tier) to replaying the whole
/// concatenated history.
fn generate(state: &ServeState, req: &Request) -> Result<Response, ApiError> {
    let body = req.json_body().map_err(|e| ApiError::bad_request(format!("{e:#}")))?;
    let prompt = body
        .get("prompt")
        .and_then(|v| v.as_str().ok())
        .ok_or_else(|| ApiError::bad_request("'prompt' (string) is required"))?;
    if prompt.is_empty() {
        return Err(ApiError::bad_request("'prompt' must be non-empty"));
    }
    let max_tokens = match body.get("max_tokens") {
        Some(v) => v
            .as_usize()
            .map_err(|e| ApiError::bad_request(format!("'max_tokens': {e:#}")))?,
        None => 16,
    };
    if max_tokens == 0 {
        return Err(ApiError::bad_request("'max_tokens' must be >= 1"));
    }
    let tok = ByteTokenizer;
    let prompt_tokens: Vec<i32> = tok.encode(prompt.as_bytes());
    let vocab = state.model.config().vocab;
    if prompt_tokens.iter().any(|&t| t as usize >= vocab) {
        return Err(ApiError::new(
            422,
            format!("prompt contains bytes outside the model vocab ({vocab})"),
        ));
    }
    // acquire a session: continuation checks the id out (exclusive), a
    // fresh request allocates KV buffers for the full context window
    let (id, mut sess) = match body.get("session") {
        Some(v) => {
            let id = v
                .as_str()
                .map_err(|e| ApiError::bad_request(format!("'session': {e:#}")))?;
            let sess = state.sessions.take(id).map_err(|e| match e {
                TakeError::Unknown => ApiError::new(
                    404,
                    format!("unknown session '{id}' (expired or evicted)"),
                ),
                TakeError::Busy => ApiError::new(
                    409,
                    format!("session '{id}' has a request in flight"),
                ),
            })?;
            (id.to_string(), sess)
        }
        None => state.sessions.create(state.model.new_session(state.max_ctx)),
    };
    // the cache must cover prompt + every generated token so a follow-up
    // request can continue exactly
    let need = prompt_tokens.len() + max_tokens;
    if need > sess.kv.remaining() {
        let msg = format!(
            "context window full: {} cached + {} requested > max_ctx {}",
            sess.kv.len(), need, sess.kv.capacity(),
        );
        state.sessions.put(&id, sess); // unchanged — hand it back
        return Err(ApiError::new(422, msg));
    }
    let mut run = || -> anyhow::Result<Vec<i32>> {
        let mut logits = state.model.prefill(&mut sess.kv, &prompt_tokens)?;
        let mut generated = Vec::with_capacity(max_tokens);
        for _ in 0..max_tokens {
            let next = argmax(&logits);
            generated.push(next);
            logits = state.model.decode_step(&mut sess.kv, next)?;
        }
        Ok(generated)
    };
    let generated = match run() {
        Ok(g) => g,
        Err(e) => {
            // KV state no longer matches the token history — discard
            state.sessions.remove(&id);
            return Err(e.into());
        }
    };
    sess.tokens.extend_from_slice(&prompt_tokens);
    sess.tokens.extend_from_slice(&generated);
    let context_tokens = sess.kv.len();
    let text = tok.decode_lossy_string(&generated);
    state.sessions.put(&id, sess);
    let processed = prompt_tokens.len() + generated.len();
    let body = Json::obj(vec![
        ("session", Json::Str(id.clone())),
        ("text", Json::Str(text)),
        ("prompt_tokens", Json::Num(prompt_tokens.len() as f64)),
        ("generated_tokens", Json::Num(generated.len() as f64)),
        ("context_tokens", Json::Num(context_tokens as f64)),
    ]);
    Ok(Response::json(200, &body).logged(&id, processed))
}

/// `POST /v1/perplexity` `{text}` — held-out NLL/perplexity of `text`
/// under the served model, scored over non-overlapping `seq_len` windows
/// (the same protocol as `repro eval`'s batcher) fanned out through the
/// executor pool.
fn perplexity(state: &ServeState, req: &Request) -> Result<Response, ApiError> {
    let body = req.json_body().map_err(|e| ApiError::bad_request(format!("{e:#}")))?;
    let text = body
        .get("text")
        .and_then(|v| v.as_str().ok())
        .ok_or_else(|| ApiError::bad_request("'text' (string) is required"))?;
    let tok = ByteTokenizer;
    let tokens: Vec<i32> = tok.encode(text.as_bytes());
    let vocab = state.model.config().vocab;
    if tokens.iter().any(|&t| t as usize >= vocab) {
        return Err(ApiError::new(
            422,
            format!("text contains bytes outside the model vocab ({vocab})"),
        ));
    }
    let seq = state.model.config().seq_len.max(2);
    let windows: Vec<&[i32]> =
        tokens.chunks(seq).filter(|w| w.len() >= 2).collect();
    if windows.is_empty() {
        return Err(ApiError::bad_request(
            "'text' must be at least 2 tokens (bytes) long",
        ));
    }
    let report = state
        .exec
        .run(windows.len(), |i| format!("ppl-window-{i}"), |i| {
            state.model.nll(windows[i], 1, windows[i].len())
        })
        .map_err(ApiError::from)?;
    let (mut nll, mut count) = (0.0f64, 0usize);
    for (n, c) in &report.results {
        nll += n;
        count += c;
    }
    let per_token = nll / count.max(1) as f64;
    let body = Json::obj(vec![
        ("ppl", Json::Num(per_token.exp())),
        ("nll_per_token", Json::Num(per_token)),
        ("tokens", Json::Num(tokens.len() as f64)),
        ("scored_tokens", Json::Num(count as f64)),
        ("windows", Json::Num(windows.len() as f64)),
    ]);
    Ok(Response::json(200, &body).logged("-", tokens.len()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::trainer::init_checkpoint;

    fn state() -> ServeState {
        let cfg = ModelConfig {
            name: "t".into(), vocab: 256, d_model: 16, n_heads: 2, n_layers: 1,
            d_ff: 24, seq_len: 8, batch: 1, decode_len: 8, rope_theta: 1e4,
        };
        let ck = init_checkpoint(&cfg, 3);
        let model = NativeModel::from_checkpoint(&ck).unwrap();
        let info = ServeInfo {
            model: "t".into(),
            source: "test.apack".into(),
            method: "proj".into(),
            spec: "int4-g32".into(),
            packed_bytes: 0,
        };
        ServeState::new(model, info, Executor::with_workers(2), 64, 4)
    }

    fn req(method: &str, path: &str, body: &str) -> Request {
        Request {
            method: method.into(),
            path: path.into(),
            query: String::new(),
            headers: Vec::new(),
            body: body.as_bytes().to_vec(),
        }
    }

    fn json_of(resp: &Response) -> Json {
        Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap()
    }

    #[test]
    fn healthz_reports_ok() {
        let st = state();
        let resp = handle(&st, &req("GET", "/healthz", ""));
        assert_eq!(resp.status, 200);
        let v = json_of(&resp);
        assert!(v.expect("ok").unwrap().as_bool().unwrap());
        assert_eq!(v.expect("model").unwrap().as_str().unwrap(), "t");
    }

    #[test]
    fn unknown_path_404_wrong_method_405() {
        let st = state();
        assert_eq!(handle(&st, &req("GET", "/nope", "")).status, 404);
        assert_eq!(handle(&st, &req("POST", "/healthz", "")).status, 405);
    }

    #[test]
    fn generate_roundtrip_and_session_continuation() {
        let st = state();
        let resp = handle(&st, &req("POST", "/v1/generate",
                                    r#"{"prompt":"ab","max_tokens":3}"#));
        assert_eq!(resp.status, 200, "{:?}", String::from_utf8_lossy(&resp.body));
        let v = json_of(&resp);
        let sid = v.expect("session").unwrap().as_str().unwrap().to_string();
        assert_eq!(v.expect("prompt_tokens").unwrap().as_usize().unwrap(), 2);
        assert_eq!(v.expect("generated_tokens").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.expect("context_tokens").unwrap().as_usize().unwrap(), 5);
        assert_eq!(resp.tokens, 5);
        assert_eq!(resp.session, sid);
        // continuation advances the same cache
        let cont = format!(r#"{{"prompt":"c","max_tokens":2,"session":"{sid}"}}"#);
        let resp2 = handle(&st, &req("POST", "/v1/generate", &cont));
        assert_eq!(resp2.status, 200);
        let v2 = json_of(&resp2);
        assert_eq!(v2.expect("session").unwrap().as_str().unwrap(), sid);
        assert_eq!(v2.expect("context_tokens").unwrap().as_usize().unwrap(), 8);
    }

    #[test]
    fn generate_input_validation() {
        let st = state();
        assert_eq!(handle(&st, &req("POST", "/v1/generate", "")).status, 400);
        assert_eq!(handle(&st, &req("POST", "/v1/generate", "{}")).status, 400);
        assert_eq!(
            handle(&st, &req("POST", "/v1/generate",
                             r#"{"prompt":""}"#)).status, 400);
        assert_eq!(
            handle(&st, &req("POST", "/v1/generate",
                             r#"{"prompt":"a","max_tokens":0}"#)).status, 400);
        assert_eq!(
            handle(&st, &req("POST", "/v1/generate",
                             r#"{"prompt":"a","session":"s-99"}"#)).status, 404);
        // exceeding the context window is a clean 422
        assert_eq!(
            handle(&st, &req("POST", "/v1/generate",
                             r#"{"prompt":"a","max_tokens":9999}"#)).status, 422);
    }

    #[test]
    fn perplexity_scores_text() {
        let st = state();
        let resp = handle(&st, &req("POST", "/v1/perplexity",
                                    r#"{"text":"hello serving world"}"#));
        assert_eq!(resp.status, 200, "{:?}", String::from_utf8_lossy(&resp.body));
        let v = json_of(&resp);
        let ppl = v.expect("ppl").unwrap().as_f64().unwrap();
        assert!(ppl.is_finite() && ppl > 1.0);
        assert_eq!(v.expect("tokens").unwrap().as_usize().unwrap(), 19);
        assert_eq!(v.expect("windows").unwrap().as_usize().unwrap(), 3);
        // matches a direct nll computation over the same windows
        assert_eq!(handle(&st, &req("POST", "/v1/perplexity",
                                    r#"{"text":"x"}"#)).status, 400);
    }
}
