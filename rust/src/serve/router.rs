//! Typed routing: a static route table mapping `(method, path)` to handler
//! functions over [`ServeState`], with errors as [`ApiError`] values that
//! render to JSON error responses. Handlers are plain `fn`s — no macros, no
//! extractors — and every endpoint's request/response schema is documented
//! in SERVING.md with worked examples.

use std::io::Write;
use std::time::Instant;

use crate::coordinator::Executor;
use crate::data::ByteTokenizer;
use crate::eval::argmax;
use crate::infer::NativeModel;
use crate::util::json::Json;

use super::batcher::DecodeBatcher;
use super::http::{write_chunk, write_last_chunk, write_stream_head};
use super::http::{Request, Response};
use super::session::{ServeSession, SessionStore, TakeError};

/// Static facts about the artifact being served, shown by `/v1/inspect`
/// and the startup log (computed once in `main.rs` from the loaded
/// artifact; the model itself holds only the packed sites).
#[derive(Debug, Clone)]
pub struct ServeInfo {
    /// Model name (`ModelConfig::name`).
    pub model: String,
    /// Artifact path the server was started from.
    pub source: String,
    /// Compression method label ("awp", "rtn", …).
    pub method: String,
    /// Human-readable compression spec (`CompressionSpec::describe`).
    pub spec: String,
    /// Bit-packed payload bytes across all sites.
    pub packed_bytes: usize,
}

/// The server's resource bounds, grouped so `main.rs` and tests configure
/// them in one place (`..ServeLimits::default()` for the rest).
#[derive(Debug, Clone, Copy)]
pub struct ServeLimits {
    /// Per-session context window (K/V rows a session can hold).
    pub max_ctx: usize,
    /// Live sessions the store admits (`--max-sessions`).
    pub max_sessions: usize,
    /// Sessions one fused decode tick carries (`--max-batch`).
    pub max_batch: usize,
    /// Resident KV-cache byte budget across all sessions (`--max-kv-mb`;
    /// `usize::MAX` = unlimited).
    pub max_kv_bytes: usize,
}

impl Default for ServeLimits {
    fn default() -> ServeLimits {
        ServeLimits {
            max_ctx: 512,
            max_sessions: 64,
            max_batch: 8,
            max_kv_bytes: usize::MAX,
        }
    }
}

/// Everything a handler can touch: the model (read-only — all mutable
/// per-connection state lives in sessions), the session store, the shared
/// decode scheduler, and the serving limits.
pub struct ServeState {
    pub model: NativeModel,
    pub info: ServeInfo,
    pub exec: Executor,
    pub sessions: SessionStore,
    /// Continuous-batching decode scheduler every generate request joins.
    pub batcher: DecodeBatcher,
    /// Per-session context window (K/V rows a session can hold).
    pub max_ctx: usize,
    pub started: Instant,
    /// Emit one JSONL object per request instead of the legacy text log
    /// line (`repro serve --log-json`).
    pub log_json: bool,
}

impl ServeState {
    pub fn new(model: NativeModel, info: ServeInfo, exec: Executor,
               limits: ServeLimits) -> ServeState {
        ServeState {
            model,
            info,
            exec,
            sessions: SessionStore::with_kv_budget(limits.max_sessions,
                                                   limits.max_kv_bytes),
            batcher: DecodeBatcher::new(limits.max_batch),
            max_ctx: limits.max_ctx.max(2),
            started: Instant::now(),
            log_json: false,
        }
    }

    /// Switch the per-request log to JSONL (`--log-json`).
    pub fn with_log_json(mut self, on: bool) -> ServeState {
        self.log_json = on;
        self
    }
}

/// A handler failure: HTTP status plus a message the client sees as
/// `{"error": message}` (plus overload detail on 429s).
#[derive(Debug)]
pub struct ApiError {
    pub status: u16,
    pub message: String,
    /// `Retry-After` header value (seconds) for retryable overload.
    pub retry_after: Option<u32>,
    /// Busy-session count behind a `StoreFull` rejection, echoed into the
    /// JSON body as `busy_sessions`.
    pub busy: Option<usize>,
}

impl ApiError {
    pub fn new(status: u16, message: impl Into<String>) -> ApiError {
        ApiError { status, message: message.into(), retry_after: None, busy: None }
    }

    pub fn bad_request(message: impl Into<String>) -> ApiError {
        ApiError::new(400, message)
    }

    /// The `429` a session create gets when the store is wall-to-wall
    /// busy sessions: carries `Retry-After: 1` and the busy count, on
    /// both the buffered and streaming create paths (which share this
    /// constructor via `prepare_generate`).
    pub fn store_full(busy: usize) -> ApiError {
        ApiError {
            status: 429,
            message: format!("session store full: {busy} sessions busy; retry later"),
            retry_after: Some(1),
            busy: Some(busy),
        }
    }

    pub fn to_response(&self) -> Response {
        let mut kvs = vec![("error", Json::Str(self.message.clone()))];
        if let Some(busy) = self.busy {
            kvs.push(("busy_sessions", Json::Num(busy as f64)));
        }
        let mut resp = Response::json(self.status, &Json::obj(kvs));
        if let Some(secs) = self.retry_after {
            resp = resp.with_header("Retry-After", secs.to_string());
        }
        resp
    }
}

impl From<anyhow::Error> for ApiError {
    fn from(e: anyhow::Error) -> ApiError {
        ApiError::new(500, format!("{e:#}"))
    }
}

type Handler = fn(&ServeState, &Request) -> Result<Response, ApiError>;

/// One row of the route table.
pub struct Route {
    pub method: &'static str,
    pub path: &'static str,
    pub handler: Handler,
}

/// The server's whole API surface, in match order.
pub const ROUTES: &[Route] = &[
    Route { method: "GET", path: "/healthz", handler: healthz },
    Route { method: "GET", path: "/metrics", handler: metrics },
    Route { method: "GET", path: "/v1/inspect", handler: inspect },
    Route { method: "GET", path: "/v1/stats", handler: stats },
    Route { method: "POST", path: "/v1/generate", handler: generate },
    Route { method: "POST", path: "/v1/perplexity", handler: perplexity },
];

/// Cardinality-bounded route label for the `awp_requests_total` metric:
/// a known [`ROUTES`] path verbatim, anything else collapses to `other`.
pub fn route_label(path: &str) -> &'static str {
    ROUTES.iter().find(|r| r.path == path).map(|r| r.path).unwrap_or("other")
}

/// Dispatch `req` against [`ROUTES`]: unknown path → 404, known path with
/// the wrong method → 405, handler error → its status. Never panics on
/// client input.
pub fn handle(state: &ServeState, req: &Request) -> Response {
    let mut path_known = false;
    for route in ROUTES {
        if route.path != req.path {
            continue;
        }
        path_known = true;
        if route.method == req.method {
            return match (route.handler)(state, req) {
                Ok(resp) => resp,
                Err(e) => e.to_response(),
            };
        }
    }
    let status = if path_known { 405 } else { 404 };
    ApiError::new(status, format!("no route for {} {}", req.method, req.path))
        .to_response()
}

// --------------------------------------------------------------- handlers

/// `GET /healthz` — liveness plus the numbers a load balancer would scrape.
fn healthz(state: &ServeState, _req: &Request) -> Result<Response, ApiError> {
    let body = Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("model", Json::Str(state.info.model.clone())),
        ("tier", Json::Str(state.model.tier().describe().into())),
        ("sessions", Json::Num(state.sessions.len() as f64)),
        ("uptime_s", Json::Num(state.started.elapsed().as_secs_f64())),
    ]);
    Ok(Response::json(200, &body))
}

/// `GET /metrics` — the whole [`crate::obs::metrics::REGISTRY`] in the
/// Prometheus text exposition format, scrape-ready.
fn metrics(_state: &ServeState, _req: &Request) -> Result<Response, ApiError> {
    Ok(Response::text(
        200,
        crate::obs::metrics::PROMETHEUS_CONTENT_TYPE,
        crate::obs::metrics::render_prometheus(),
    ))
}

/// `GET /v1/stats` — the same registry as one JSON object, plus server
/// uptime (programmatic clients; Prometheus scrapes `/metrics`).
fn stats(state: &ServeState, _req: &Request) -> Result<Response, ApiError> {
    let body = Json::obj(vec![
        ("uptime_s", Json::Num(state.started.elapsed().as_secs_f64())),
        ("metrics", crate::obs::metrics::snapshot_json()),
    ]);
    Ok(Response::json(200, &body))
}

/// `GET /v1/inspect` — identity and footprint of the artifact being served.
fn inspect(state: &ServeState, _req: &Request) -> Result<Response, ApiError> {
    let body = Json::obj(vec![
        ("model", Json::Str(state.info.model.clone())),
        ("source", Json::Str(state.info.source.clone())),
        ("method", Json::Str(state.info.method.clone())),
        ("spec", Json::Str(state.info.spec.clone())),
        ("packed_bytes", Json::Num(state.info.packed_bytes as f64)),
        ("packed_sites", Json::Num(state.model.packed_site_count() as f64)),
        ("dense_sites", Json::Num(state.model.dense_site_count() as f64)),
        ("tier", Json::Str(state.model.tier().describe().into())),
        ("max_ctx", Json::Num(state.max_ctx as f64)),
        ("max_sessions", Json::Num(state.sessions.cap() as f64)),
        ("max_batch", Json::Num(state.batcher.max_batch() as f64)),
        // 0 = unlimited (usize::MAX does not survive the f64 round-trip)
        ("max_kv_bytes", Json::Num(
            if state.sessions.max_kv_bytes() == usize::MAX { 0.0 }
            else { state.sessions.max_kv_bytes() as f64 })),
        ("kv_bytes", Json::Num(state.sessions.kv_bytes() as f64)),
        ("sessions", Json::Num(state.sessions.len() as f64)),
        ("evicted", Json::Num(state.sessions.evicted() as f64)),
        ("decode_ticks", Json::Num(state.batcher.stats().0 as f64)),
        ("mean_batch", Json::Num(state.batcher.stats().1)),
    ]);
    Ok(Response::json(200, &body))
}

/// Validate a `/v1/generate` request and check its session out: everything
/// up to (but not including) the first forward pass. Returns
/// `(session id, checked-out session, prompt tokens, max_tokens, fresh)` —
/// shared by the buffered and streaming generate paths, so both reject
/// with identical statuses before any bytes of a streamed response commit.
/// `fresh` is true when this request created the session: error paths that
/// fire before the id reaches the client must `remove` a fresh session
/// (the client can never continue or release an id it was never told, so
/// handing it back would pin a store slot and its KV bytes forever) and
/// `put` back a continuation (the client still holds that id).
fn prepare_generate(state: &ServeState, req: &Request)
    -> Result<(String, ServeSession, Vec<i32>, usize, bool), ApiError> {
    let body = req.json_body().map_err(|e| ApiError::bad_request(format!("{e:#}")))?;
    let prompt = body
        .get("prompt")
        .and_then(|v| v.as_str().ok())
        .ok_or_else(|| ApiError::bad_request("'prompt' (string) is required"))?;
    if prompt.is_empty() {
        return Err(ApiError::bad_request("'prompt' must be non-empty"));
    }
    let max_tokens = match body.get("max_tokens") {
        Some(v) => v
            .as_usize()
            .map_err(|e| ApiError::bad_request(format!("'max_tokens': {e:#}")))?,
        None => 16,
    };
    if max_tokens == 0 {
        return Err(ApiError::bad_request("'max_tokens' must be >= 1"));
    }
    let tok = ByteTokenizer;
    let prompt_tokens: Vec<i32> = tok.encode(prompt.as_bytes());
    let vocab = state.model.config().vocab;
    if prompt_tokens.iter().any(|&t| t as usize >= vocab) {
        return Err(ApiError::new(
            422,
            format!("prompt contains bytes outside the model vocab ({vocab})"),
        ));
    }
    // acquire a session: continuation checks the id out (exclusive), a
    // fresh request allocates KV buffers for the full context window —
    // refused with 429 when the store is wall-to-wall busy sessions
    let (id, sess, fresh) = match body.get("session") {
        Some(v) => {
            let id = v
                .as_str()
                .map_err(|e| ApiError::bad_request(format!("'session': {e:#}")))?;
            let sess = state.sessions.take(id).map_err(|e| match e {
                TakeError::Unknown => ApiError::new(
                    404,
                    format!("unknown session '{id}' (expired or evicted)"),
                ),
                TakeError::Busy => ApiError::new(
                    409,
                    format!("session '{id}' has a request in flight"),
                ),
            })?;
            (id.to_string(), sess, false)
        }
        None => {
            let (id, sess) = state
                .sessions
                .create(state.model.new_session(state.max_ctx))
                .map_err(|e| ApiError::store_full(e.busy))?;
            (id, sess, true)
        }
    };
    // the cache must cover prompt + every generated token so a follow-up
    // request can continue exactly
    let need = prompt_tokens.len() + max_tokens;
    if need > sess.kv.remaining() {
        let msg = format!(
            "context window full: {} cached + {} requested > max_ctx {}",
            sess.kv.len(), need, sess.kv.capacity(),
        );
        if fresh {
            // the 422 body never carries the id, so the client cannot
            // release this session — dropping it is the only non-leak
            state.sessions.remove(&id);
        } else {
            state.sessions.put(&id, sess); // unchanged — hand it back
        }
        return Err(ApiError::new(422, msg));
    }
    Ok((id, sess, prompt_tokens, max_tokens, fresh))
}

/// Run a prepared generate request through the prefill path and the shared
/// [`DecodeBatcher`]: the prompt prefills on this request's thread (ragged
/// prompt lengths don't batch), then the decode loop joins the continuous
/// batch, where concurrent requests' steps fuse into one forward per tick.
/// Each generated token is pushed through `on_token` as its tick produces
/// it (the streaming path's hook; the buffered path passes a no-op).
///
/// Returns the finished session, the generated tokens and the peak batch
/// occupancy the request rode in. Any failure discards the session — its
/// KV state no longer matches the token history.
fn decode_generate(state: &ServeState, id: &str, mut sess: ServeSession,
                   prompt_tokens: &[i32], max_tokens: usize,
                   on_token: &mut dyn FnMut(i32) -> anyhow::Result<()>)
    -> Result<(ServeSession, Vec<i32>, usize), ApiError> {
    let logits = match state.model.prefill(&mut sess.kv, prompt_tokens) {
        Ok(l) => l,
        Err(e) => {
            state.sessions.remove(id);
            return Err(e.into());
        }
    };
    let first = argmax(&logits);
    let mut generated = Vec::with_capacity(max_tokens);
    generated.push(first);
    if let Err(e) = on_token(first) {
        state.sessions.remove(id);
        return Err(ApiError::new(500, format!("token sink failed: {e:#}")));
    }
    let ServeSession { kv, tokens } = sess;
    let mut collect = |t: i32| {
        generated.push(t);
        on_token(t)
    };
    match state.batcher.decode(&state.model, kv, first, max_tokens,
                               &mut collect) {
        Ok((kv, occupancy)) => {
            Ok((ServeSession { kv, tokens }, generated, occupancy))
        }
        Err(msg) => {
            state.sessions.remove(id);
            Err(ApiError::new(500, msg))
        }
    }
}

/// `POST /v1/generate` `{prompt, max_tokens?, session?}` — greedy
/// generation through the KV-cached decode path. Without `session` a fresh
/// [`crate::infer::DecodeSession`] is created and its id returned; with
/// one, generation *continues* the cached context — the prompt is appended
/// to everything the session has seen, at O(new tokens) cost, and the
/// result is bit-identical (reference tier) to replaying the whole
/// concatenated history. Decode steps run through the shared continuous
/// batch; `batch_occupancy` in the response reports the peak number of
/// sessions this request's ticks were fused with.
fn generate(state: &ServeState, req: &Request) -> Result<Response, ApiError> {
    let (id, sess, prompt_tokens, max_tokens, _fresh) =
        prepare_generate(state, req)?;
    let (mut sess, generated, occupancy) =
        decode_generate(state, &id, sess, &prompt_tokens, max_tokens,
                        &mut |_| Ok(()))?;
    sess.tokens.extend_from_slice(&prompt_tokens);
    sess.tokens.extend_from_slice(&generated);
    let context_tokens = sess.kv.len();
    let text = ByteTokenizer.decode_lossy_string(&generated);
    state.sessions.put(&id, sess);
    let processed = prompt_tokens.len() + generated.len();
    let body = Json::obj(vec![
        ("session", Json::Str(id.clone())),
        ("text", Json::Str(text)),
        ("prompt_tokens", Json::Num(prompt_tokens.len() as f64)),
        ("generated_tokens", Json::Num(generated.len() as f64)),
        ("context_tokens", Json::Num(context_tokens as f64)),
        ("batch_occupancy", Json::Num(occupancy as f64)),
    ]);
    Ok(Response::json(200, &body).logged(&id, processed).with_batch(occupancy))
}

/// What a streamed generate did, for the server's structured log line (the
/// wire status of a stream that failed mid-flight is still the committed
/// 200; `status` here records the handler outcome instead).
pub struct StreamOutcome {
    pub status: u16,
    pub session: String,
    pub tokens: usize,
    pub batch: usize,
}

/// `POST /v1/generate?stream=true` — same contract as [`generate`], but
/// each token goes out as its own chunked-transfer JSON line
/// (`{"token":N,"text":"…"}`) the moment the scheduler's tick produces it,
/// followed by a `{"done":true,…}` line carrying the summary fields of the
/// buffered response. Validation failures are rejected as ordinary JSON
/// error responses *before* the stream head commits; a decode failure
/// after commitment terminates the stream with an `{"error":…}` line.
pub fn generate_stream(state: &ServeState, req: &Request,
                       w: &mut dyn Write, keep_alive: bool) -> StreamOutcome {
    let (id, sess, prompt_tokens, max_tokens, fresh) =
        match prepare_generate(state, req) {
            Ok(prepared) => prepared,
            Err(e) => {
                let _ = e.to_response().keep_alive(keep_alive).write_to(&mut *w);
                return StreamOutcome {
                    status: e.status,
                    session: "-".into(),
                    tokens: 0,
                    batch: 0,
                };
            }
        };
    if let Err(e) = write_stream_head(&mut *w, keep_alive) {
        // client went away before the head: nothing decoded. A
        // continuation is unchanged — hand it back; a fresh session's id
        // never reached the client, so keeping it would leak the slot
        if fresh {
            state.sessions.remove(&id);
        } else {
            state.sessions.put(&id, sess);
        }
        let _ = e; // socket is dead; nowhere to report
        return StreamOutcome { status: 500, session: id, tokens: 0, batch: 0 };
    }
    let tok = ByteTokenizer;
    let mut emit = |t: i32| -> anyhow::Result<()> {
        let line = Json::obj(vec![
            ("token", Json::Num(t as f64)),
            ("text", Json::Str(tok.decode_lossy_string(&[t]))),
        ]);
        write_chunk(&mut *w, format!("{line}\n").as_bytes())
    };
    match decode_generate(state, &id, sess, &prompt_tokens, max_tokens,
                          &mut emit) {
        Ok((mut sess, generated, occupancy)) => {
            sess.tokens.extend_from_slice(&prompt_tokens);
            sess.tokens.extend_from_slice(&generated);
            let context_tokens = sess.kv.len();
            state.sessions.put(&id, sess);
            let done = Json::obj(vec![
                ("done", Json::Bool(true)),
                ("session", Json::Str(id.clone())),
                ("prompt_tokens", Json::Num(prompt_tokens.len() as f64)),
                ("generated_tokens", Json::Num(generated.len() as f64)),
                ("context_tokens", Json::Num(context_tokens as f64)),
                ("batch_occupancy", Json::Num(occupancy as f64)),
            ]);
            let _ = write_chunk(&mut *w, format!("{done}\n").as_bytes());
            let _ = write_last_chunk(&mut *w);
            StreamOutcome {
                status: 200,
                session: id,
                tokens: prompt_tokens.len() + generated.len(),
                batch: occupancy,
            }
        }
        Err(e) => {
            // the session is already discarded; tell the client in-band
            let line = Json::obj(vec![
                ("error", Json::Str(e.message.clone())),
            ]);
            let _ = write_chunk(&mut *w, format!("{line}\n").as_bytes());
            let _ = write_last_chunk(&mut *w);
            StreamOutcome { status: e.status, session: id, tokens: 0, batch: 0 }
        }
    }
}

/// `POST /v1/perplexity` `{text}` — held-out NLL/perplexity of `text`
/// under the served model, scored over non-overlapping `seq_len` windows
/// (the same protocol as `repro eval`'s batcher) fanned out through the
/// executor pool.
fn perplexity(state: &ServeState, req: &Request) -> Result<Response, ApiError> {
    let body = req.json_body().map_err(|e| ApiError::bad_request(format!("{e:#}")))?;
    let text = body
        .get("text")
        .and_then(|v| v.as_str().ok())
        .ok_or_else(|| ApiError::bad_request("'text' (string) is required"))?;
    let tok = ByteTokenizer;
    let tokens: Vec<i32> = tok.encode(text.as_bytes());
    let vocab = state.model.config().vocab;
    if tokens.iter().any(|&t| t as usize >= vocab) {
        return Err(ApiError::new(
            422,
            format!("text contains bytes outside the model vocab ({vocab})"),
        ));
    }
    let seq = state.model.config().seq_len.max(2);
    let windows: Vec<&[i32]> =
        tokens.chunks(seq).filter(|w| w.len() >= 2).collect();
    if windows.is_empty() {
        return Err(ApiError::bad_request(
            "'text' must be at least 2 tokens (bytes) long",
        ));
    }
    let report = state
        .exec
        .run(windows.len(), |i| format!("ppl-window-{i}"), |i| {
            state.model.nll(windows[i], 1, windows[i].len())
        })
        .map_err(ApiError::from)?;
    let (mut nll, mut count) = (0.0f64, 0usize);
    for (n, c) in &report.results {
        nll += n;
        count += c;
    }
    let per_token = nll / count.max(1) as f64;
    let body = Json::obj(vec![
        ("ppl", Json::Num(per_token.exp())),
        ("nll_per_token", Json::Num(per_token)),
        ("tokens", Json::Num(tokens.len() as f64)),
        ("scored_tokens", Json::Num(count as f64)),
        ("windows", Json::Num(windows.len() as f64)),
    ]);
    Ok(Response::json(200, &body).logged("-", tokens.len()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::trainer::init_checkpoint;

    fn state() -> ServeState {
        let cfg = ModelConfig {
            name: "t".into(), vocab: 256, d_model: 16, n_heads: 2, n_layers: 1,
            d_ff: 24, seq_len: 8, batch: 1, decode_len: 8, rope_theta: 1e4,
        };
        let ck = init_checkpoint(&cfg, 3);
        let model = NativeModel::from_checkpoint(&ck).unwrap();
        let info = ServeInfo {
            model: "t".into(),
            source: "test.apack".into(),
            method: "proj".into(),
            spec: "int4-g32".into(),
            packed_bytes: 0,
        };
        ServeState::new(model, info, Executor::with_workers(2), ServeLimits {
            max_ctx: 64,
            max_sessions: 4,
            ..ServeLimits::default()
        })
    }

    fn req(method: &str, path: &str, body: &str) -> Request {
        Request {
            method: method.into(),
            path: path.into(),
            query: String::new(),
            headers: Vec::new(),
            body: body.as_bytes().to_vec(),
        }
    }

    fn json_of(resp: &Response) -> Json {
        Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap()
    }

    #[test]
    fn healthz_reports_ok() {
        let st = state();
        let resp = handle(&st, &req("GET", "/healthz", ""));
        assert_eq!(resp.status, 200);
        let v = json_of(&resp);
        assert!(v.expect("ok").unwrap().as_bool().unwrap());
        assert_eq!(v.expect("model").unwrap().as_str().unwrap(), "t");
    }

    #[test]
    fn unknown_path_404_wrong_method_405() {
        let st = state();
        assert_eq!(handle(&st, &req("GET", "/nope", "")).status, 404);
        assert_eq!(handle(&st, &req("POST", "/healthz", "")).status, 405);
    }

    #[test]
    fn generate_roundtrip_and_session_continuation() {
        let st = state();
        let resp = handle(&st, &req("POST", "/v1/generate",
                                    r#"{"prompt":"ab","max_tokens":3}"#));
        assert_eq!(resp.status, 200, "{:?}", String::from_utf8_lossy(&resp.body));
        let v = json_of(&resp);
        let sid = v.expect("session").unwrap().as_str().unwrap().to_string();
        assert_eq!(v.expect("prompt_tokens").unwrap().as_usize().unwrap(), 2);
        assert_eq!(v.expect("generated_tokens").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.expect("context_tokens").unwrap().as_usize().unwrap(), 5);
        // a lone request ticks through the batcher at occupancy 1
        assert_eq!(v.expect("batch_occupancy").unwrap().as_usize().unwrap(), 1);
        assert_eq!(resp.tokens, 5);
        assert_eq!(resp.batch, 1);
        assert_eq!(resp.session, sid);
        // continuation advances the same cache
        let cont = format!(r#"{{"prompt":"c","max_tokens":2,"session":"{sid}"}}"#);
        let resp2 = handle(&st, &req("POST", "/v1/generate", &cont));
        assert_eq!(resp2.status, 200);
        let v2 = json_of(&resp2);
        assert_eq!(v2.expect("session").unwrap().as_str().unwrap(), sid);
        assert_eq!(v2.expect("context_tokens").unwrap().as_usize().unwrap(), 8);
    }

    #[test]
    fn generate_input_validation() {
        let st = state();
        assert_eq!(handle(&st, &req("POST", "/v1/generate", "")).status, 400);
        assert_eq!(handle(&st, &req("POST", "/v1/generate", "{}")).status, 400);
        assert_eq!(
            handle(&st, &req("POST", "/v1/generate",
                             r#"{"prompt":""}"#)).status, 400);
        assert_eq!(
            handle(&st, &req("POST", "/v1/generate",
                             r#"{"prompt":"a","max_tokens":0}"#)).status, 400);
        assert_eq!(
            handle(&st, &req("POST", "/v1/generate",
                             r#"{"prompt":"a","session":"s-99"}"#)).status, 404);
        // exceeding the context window is a clean 422
        assert_eq!(
            handle(&st, &req("POST", "/v1/generate",
                             r#"{"prompt":"a","max_tokens":9999}"#)).status, 422);
    }

    #[test]
    fn generate_429_when_store_is_full_of_busy_sessions() {
        let cfg = ModelConfig {
            name: "t".into(), vocab: 256, d_model: 16, n_heads: 2, n_layers: 1,
            d_ff: 24, seq_len: 8, batch: 1, decode_len: 8, rope_theta: 1e4,
        };
        let model =
            NativeModel::from_checkpoint(&init_checkpoint(&cfg, 3)).unwrap();
        let info = ServeInfo {
            model: "t".into(),
            source: "test.apack".into(),
            method: "proj".into(),
            spec: "int4-g32".into(),
            packed_bytes: 0,
        };
        let st = ServeState::new(model, info, Executor::with_workers(2),
                                 ServeLimits {
                                     max_ctx: 64,
                                     max_sessions: 1,
                                     ..ServeLimits::default()
                                 });
        let resp = handle(&st, &req("POST", "/v1/generate",
                                    r#"{"prompt":"ab","max_tokens":2}"#));
        assert_eq!(resp.status, 200);
        let sid = json_of(&resp)
            .expect("session").unwrap().as_str().unwrap().to_string();
        // check the only slot out: the store is now wall-to-wall busy
        let held = st.sessions.take(&sid).unwrap();
        let resp = handle(&st, &req("POST", "/v1/generate",
                                    r#"{"prompt":"cd","max_tokens":2}"#));
        assert_eq!(resp.status, 429,
                   "{:?}", String::from_utf8_lossy(&resp.body));
        let v = json_of(&resp);
        assert!(v.expect("error").unwrap().as_str().unwrap()
            .contains("session store full"));
        // overload detail rides both the header and the body
        assert_eq!(v.expect("busy_sessions").unwrap().as_usize().unwrap(), 1);
        assert_eq!(resp.extra_headers,
                   vec![("Retry-After", "1".to_string())]);
        // the streaming create path rejects identically
        let mut out = Vec::new();
        let outcome = generate_stream(
            &st, &req("POST", "/v1/generate",
                      r#"{"prompt":"cd","max_tokens":2}"#),
            &mut out, false);
        assert_eq!(outcome.status, 429);
        let raw = String::from_utf8_lossy(&out).into_owned();
        assert!(raw.contains("Retry-After: 1\r\n"), "{raw}");
        assert!(raw.contains("\"busy_sessions\":1"), "{raw}");
        // once the session is idle again, a new request evicts it and runs
        st.sessions.put(&sid, held);
        let resp = handle(&st, &req("POST", "/v1/generate",
                                    r#"{"prompt":"ef","max_tokens":2}"#));
        assert_eq!(resp.status, 200);
    }

    #[test]
    fn context_window_422_drops_the_fresh_session() {
        let st = state();
        assert_eq!(st.sessions.len(), 0);
        // fresh session, request larger than the window: the error body
        // never carries the id, so the slot must not stay behind
        let resp = handle(&st, &req("POST", "/v1/generate",
                                    r#"{"prompt":"a","max_tokens":9999}"#));
        assert_eq!(resp.status, 422);
        assert!(!String::from_utf8_lossy(&resp.body).contains("session"));
        assert_eq!(st.sessions.len(), 0, "fresh session leaked on 422");
        assert_eq!(st.sessions.kv_bytes(), 0, "KV bytes leaked on 422");
        // the streaming create path rejects identically, no leak either
        let mut out = Vec::new();
        let outcome = generate_stream(
            &st, &req("POST", "/v1/generate",
                      r#"{"prompt":"a","max_tokens":9999}"#),
            &mut out, false);
        assert_eq!(outcome.status, 422);
        assert_eq!(st.sessions.len(), 0, "fresh session leaked on stream 422");
        // a continuation hitting the same 422 keeps its session: the
        // client holds the id and can retry with a smaller request
        let resp = handle(&st, &req("POST", "/v1/generate",
                                    r#"{"prompt":"ab","max_tokens":2}"#));
        assert_eq!(resp.status, 200);
        let sid = json_of(&resp)
            .expect("session").unwrap().as_str().unwrap().to_string();
        let over = format!(
            r#"{{"prompt":"a","max_tokens":9999,"session":"{sid}"}}"#);
        assert_eq!(handle(&st, &req("POST", "/v1/generate", &over)).status,
                   422);
        assert_eq!(st.sessions.len(), 1, "continuation must survive its 422");
        // and it went back idle, not stuck busy
        let cont = format!(r#"{{"prompt":"c","max_tokens":1,"session":"{sid}"}}"#);
        assert_eq!(handle(&st, &req("POST", "/v1/generate", &cont)).status,
                   200);
    }

    /// A sink whose first write fails — the "client vanished before the
    /// stream head" case.
    struct FailWriter;

    impl Write for FailWriter {
        fn write(&mut self, _: &[u8]) -> std::io::Result<usize> {
            Err(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "gone"))
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn stream_head_failure_drops_fresh_but_keeps_continuations() {
        let st = state();
        let outcome = generate_stream(
            &st, &req("POST", "/v1/generate",
                      r#"{"prompt":"ab","max_tokens":2}"#),
            &mut FailWriter, false);
        assert_eq!(outcome.status, 500);
        assert_eq!(st.sessions.len(), 0, "fresh session leaked on dead socket");
        assert_eq!(st.sessions.kv_bytes(), 0);
        // a continuation whose head write fails keeps its unchanged session
        let resp = handle(&st, &req("POST", "/v1/generate",
                                    r#"{"prompt":"ab","max_tokens":2}"#));
        assert_eq!(resp.status, 200);
        let sid = json_of(&resp)
            .expect("session").unwrap().as_str().unwrap().to_string();
        let cont = format!(r#"{{"prompt":"c","max_tokens":1,"session":"{sid}"}}"#);
        let outcome = generate_stream(&st, &req("POST", "/v1/generate", &cont),
                                      &mut FailWriter, false);
        assert_eq!(outcome.status, 500);
        assert_eq!(outcome.session, sid);
        assert_eq!(st.sessions.len(), 1);
        // the handed-back session is idle and continues normally
        assert_eq!(handle(&st, &req("POST", "/v1/generate", &cont)).status,
                   200);
    }

    #[test]
    fn metrics_and_stats_routes_serve_the_registry() {
        let st = state();
        let resp = handle(&st, &req("GET", "/metrics", ""));
        assert_eq!(resp.status, 200);
        assert_eq!(resp.content_type, "text/plain; version=0.0.4");
        let text = String::from_utf8(resp.body.clone()).unwrap();
        assert!(text.contains("# TYPE awp_decode_tick_seconds histogram"), "{text}");
        assert!(text.contains("awp_kv_bytes"), "{text}");
        let resp = handle(&st, &req("GET", "/v1/stats", ""));
        assert_eq!(resp.status, 200);
        let v = json_of(&resp);
        assert!(v.expect("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
        assert!(v.expect("metrics").unwrap().get("decode_ticks").is_some());
    }

    #[test]
    fn route_labels_are_cardinality_bounded() {
        assert_eq!(route_label("/v1/generate"), "/v1/generate");
        assert_eq!(route_label("/metrics"), "/metrics");
        assert_eq!(route_label("/nope"), "other");
        assert_eq!(route_label("/v1/generate/../x"), "other");
    }

    #[test]
    fn generate_stream_emits_chunked_token_lines() {
        let st = state();
        // buffered reference for the same prompt on a fresh session
        let buffered = handle(&st, &req("POST", "/v1/generate",
                                        r#"{"prompt":"ab","max_tokens":3}"#));
        assert_eq!(buffered.status, 200);
        let mut out = Vec::new();
        let outcome = generate_stream(
            &st, &req("POST", "/v1/generate", r#"{"prompt":"ab","max_tokens":3}"#),
            &mut out, false);
        assert_eq!(outcome.status, 200);
        assert_eq!(outcome.tokens, 5);
        assert_eq!(outcome.batch, 1);
        let raw = String::from_utf8_lossy(&out).into_owned();
        assert!(raw.starts_with("HTTP/1.1 200 OK\r\n"), "{raw}");
        assert!(raw.contains("Transfer-Encoding: chunked\r\n"));
        assert!(raw.contains("Connection: close\r\n"));
        // one chunk per generated token, then the summary line
        assert_eq!(raw.matches("\"token\":").count(), 3, "{raw}");
        assert!(raw.contains("\"done\":true"), "{raw}");
        assert!(raw.contains("\"generated_tokens\":3"), "{raw}");
        assert!(raw.contains("\"context_tokens\":5"), "{raw}");
        assert!(raw.ends_with("0\r\n\r\n"), "{raw}");
        // the streamed session replays continuations exactly like the
        // buffered one: both stores now hold a 5-token context
        assert_eq!(st.sessions.len(), 2);
    }

    #[test]
    fn generate_stream_rejects_before_committing_the_stream() {
        let st = state();
        let mut out = Vec::new();
        let outcome =
            generate_stream(&st, &req("POST", "/v1/generate", "{}"), &mut out,
                            true);
        assert_eq!(outcome.status, 400);
        let raw = String::from_utf8_lossy(&out).into_owned();
        // an ordinary JSON error response, not a chunked stream
        assert!(raw.starts_with("HTTP/1.1 400 Bad Request\r\n"), "{raw}");
        assert!(!raw.contains("Transfer-Encoding"));
        assert!(raw.contains("Connection: keep-alive\r\n"));
        assert!(raw.contains("\"error\""));
    }

    #[test]
    fn perplexity_scores_text() {
        let st = state();
        let resp = handle(&st, &req("POST", "/v1/perplexity",
                                    r#"{"text":"hello serving world"}"#));
        assert_eq!(resp.status, 200, "{:?}", String::from_utf8_lossy(&resp.body));
        let v = json_of(&resp);
        let ppl = v.expect("ppl").unwrap().as_f64().unwrap();
        assert!(ppl.is_finite() && ppl > 1.0);
        assert_eq!(v.expect("tokens").unwrap().as_usize().unwrap(), 19);
        assert_eq!(v.expect("windows").unwrap().as_usize().unwrap(), 3);
        // matches a direct nll computation over the same windows
        assert_eq!(handle(&st, &req("POST", "/v1/perplexity",
                                    r#"{"text":"x"}"#)).status, 400);
    }
}
