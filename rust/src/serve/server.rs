//! The accept loop: a non-blocking listener polled against a stop flag,
//! feeding connections through a worker pool sized by the same
//! [`Executor`] budget discipline as every other subsystem — `workers`
//! request slots, each running its model math under a nested
//! `with_thread_budget(inner_threads)`, so a serve process never exceeds
//! `AWP_THREADS` no matter how many requests are in flight.
//!
//! Connections are persistent (HTTP/1.1 keep-alive): a worker keeps
//! serving requests off one connection until the client closes, sends
//! `Connection: close`, idles past [`KEEPALIVE_IDLE`], hits the
//! [`MAX_REQUESTS_PER_CONN`] cap, or the server starts draining. Shutdown
//! stays graceful by construction: SIGINT/SIGTERM (or a test's stop flag)
//! only stops *accepting*; the channel to the workers is then dropped,
//! each worker drains the queued connections it can still receive,
//! finishes its in-flight request (answering it `Connection: close`), and
//! the scope join returns. Every request logs one structured line to
//! stderr — `trace` is the request's process-unique trace id (so
//! concurrent keep-alive connections interleave unambiguously), `batch`
//! the peak decode-batch occupancy the request's ticks were fused at (0
//! when the request never decoded):
//!
//! ```text
//! [serve] trace=t-7 method=POST path=/v1/generate status=200 session=s-1 tokens=21 batch=3 ms=4.3
//! ```
//!
//! Under `--log-json` ([`ServeState::with_log_json`]) the same fields go
//! out as one JSONL object per request instead. Either way, every request
//! increments `awp_requests_total{route,status}` and observes
//! `awp_request_seconds` in the [`crate::obs::metrics::REGISTRY`], and —
//! when `--trace-out` enabled the span sink — rides a `request` span
//! nested in its connection's `connection` span.

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::Executor;
use crate::obs::{metrics, trace};
use crate::util::json::Json;
use crate::util::parallel::with_thread_budget;

use super::http::{read_request_opt, HttpError, Response};
use super::router::{generate_stream, handle, route_label, ServeState};

/// How long the accept loop sleeps when no connection is pending — the
/// upper bound on shutdown latency once the stop flag flips.
const ACCEPT_POLL: Duration = Duration::from_millis(20);
/// Per-connection socket read/write timeout: a stalled client cannot pin
/// a worker forever.
const IO_TIMEOUT: Duration = Duration::from_secs(10);
/// How long a keep-alive connection may sit idle between requests before
/// the worker reclaims itself for the accept queue.
const KEEPALIVE_IDLE: Duration = Duration::from_secs(2);
/// Requests one keep-alive connection may carry before the server closes
/// it (bounds how long a single client can monopolise a worker slot).
const MAX_REQUESTS_PER_CONN: usize = 32;

/// Process-wide stop flag the signal handler flips.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

/// The flag [`Server::serve`] should poll when running under
/// [`install_signal_handlers`]. Tests pass their own flag instead.
pub fn shutdown_flag() -> &'static AtomicBool {
    &SHUTDOWN
}

#[cfg(unix)]
extern "C" fn on_signal(_signum: i32) {
    // async-signal-safe: a single atomic store
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Route SIGINT and SIGTERM to [`shutdown_flag`] so Ctrl-C drains the
/// server instead of killing it mid-request. No-op off Unix.
#[cfg(unix)]
pub fn install_signal_handlers() {
    type SigHandler = extern "C" fn(i32);
    extern "C" {
        fn signal(signum: i32, handler: SigHandler) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
}

#[cfg(not(unix))]
pub fn install_signal_handlers() {}

/// A running inference server: shared [`ServeState`] plus the worker-pool
/// geometry.
pub struct Server {
    state: Arc<ServeState>,
    workers: usize,
    inner_threads: usize,
}

impl Server {
    /// `exec` only sizes the pool (`workers × inner_threads`); request
    /// scheduling is a plain queue — requests are heterogeneous and
    /// latency-bound, not a batch with a known plan.
    pub fn new(state: ServeState, exec: Executor) -> Server {
        Server {
            state: Arc::new(state),
            workers: exec.workers().max(1),
            inner_threads: exec.inner_threads().max(1),
        }
    }

    /// Shared handle to the serving state (tests inspect sessions through
    /// this; the handlers own all mutation).
    pub fn state(&self) -> &Arc<ServeState> {
        &self.state
    }

    /// Accept and serve connections on `listener` until `stop` flips true,
    /// then drain: queued and in-flight requests complete before this
    /// returns. Returns the number of requests served.
    pub fn serve(&self, listener: TcpListener, stop: &AtomicBool) -> Result<u64> {
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        eprintln!(
            "[serve] listening on {local} ({} workers x {} threads, tier: {})",
            self.workers,
            self.inner_threads,
            self.state.model.tier().describe(),
        );
        let served = AtomicU64::new(0);
        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Mutex::new(rx);
        std::thread::scope(|scope| {
            for _ in 0..self.workers {
                let rx = &rx;
                let served = &served;
                let state = &self.state;
                let inner = self.inner_threads;
                scope.spawn(move || {
                    with_thread_budget(inner, || loop {
                        // hold the receiver lock only while dequeuing
                        let conn = rx.lock().unwrap().recv();
                        match conn {
                            Ok(stream) => {
                                let n = handle_connection(state, stream, stop);
                                served.fetch_add(n, Ordering::Relaxed);
                            }
                            Err(_) => break, // channel closed: drained
                        }
                    });
                });
            }
            while !stop.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        // a send can only fail if every worker died; surface
                        // that instead of spinning silently
                        if tx.send(stream).is_err() {
                            eprintln!("[serve] worker pool gone; stopping");
                            break;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(ACCEPT_POLL);
                    }
                    Err(e) => {
                        eprintln!("[serve] accept error: {e}");
                        std::thread::sleep(ACCEPT_POLL);
                    }
                }
            }
            eprintln!("[serve] stop requested, draining in-flight sessions");
            drop(tx); // workers exit once the queue is empty
        });
        let total = served.load(Ordering::Relaxed);
        eprintln!(
            "[serve] shutdown: drained, {total} requests served, {} sessions live",
            self.state.sessions.len(),
        );
        Ok(total)
    }
}

/// One structured log line per request: the legacy text format (now
/// carrying the trace id) or, under `--log-json`, one JSONL object.
fn log_request(log_json: bool, trace: &str, method: &str, path: &str,
               status: u16, session: &str, tokens: usize, batch: usize,
               started: Instant) {
    let ms = started.elapsed().as_secs_f64() * 1e3;
    if log_json {
        let ts = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0);
        let line = Json::obj(vec![
            ("ts", Json::Num((ts * 1e3).round() / 1e3)),
            ("trace", Json::Str(trace.to_string())),
            ("method", Json::Str(method.to_string())),
            ("path", Json::Str(path.to_string())),
            ("status", Json::Num(status as f64)),
            ("session", Json::Str(session.to_string())),
            ("tokens", Json::Num(tokens as f64)),
            ("batch", Json::Num(batch as f64)),
            ("ms", Json::Num((ms * 10.0).round() / 10.0)),
        ]);
        eprintln!("{}", line.to_string());
    } else {
        eprintln!(
            "[serve] trace={trace} method={method} path={path} status={status} \
             session={session} tokens={tokens} batch={batch} ms={ms:.1}",
        );
    }
}

/// Per-request registry bookkeeping: the route × status counter and the
/// request-latency histogram.
fn observe_request(path: &str, status: u16, started: Instant) {
    metrics::REGISTRY.requests.inc(route_label(path), status);
    metrics::REGISTRY.request_seconds.observe(started.elapsed().as_secs_f64());
}

/// One connection: parse → route → respond → log, repeated while the
/// client keeps the connection alive. Returns the number of requests
/// served. Parse failures answer 400 (or the typed [`HttpError`] status —
/// 501 for `Transfer-Encoding` request bodies) and close; a clean close
/// (or an idle
/// keep-alive timeout) between requests ends the loop silently; nothing
/// here panics on client input. Streamed generates (`?stream=true`) write
/// the chunked response themselves, straight onto the socket.
fn handle_connection(state: &ServeState, stream: TcpStream,
                     stop: &AtomicBool) -> u64 {
    let mut served = 0u64;
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let Ok(read_half) = stream.try_clone() else { return 0 };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let _conn_span = trace::span("connection", "serve");
    for reqno in 0..MAX_REQUESTS_PER_CONN {
        // the first request gets the full I/O window; between keep-alive
        // requests an idle client is released much sooner
        let idle = if reqno == 0 { IO_TIMEOUT } else { KEEPALIVE_IDLE };
        let _ = reader.get_ref().set_read_timeout(Some(idle));
        let started = Instant::now();
        let trace_id = trace::request_tag(trace::next_request_id());
        let req = match read_request_opt(&mut reader) {
            Ok(Some(req)) => req,
            Ok(None) => break, // clean close or idle timeout between requests
            Err(e) => {
                // refused protocol features carry their own status (501
                // for Transfer-Encoding bodies); plain syntax errors → 400
                let status = e
                    .downcast_ref::<HttpError>()
                    .map(|he| he.status)
                    .unwrap_or(400);
                let body =
                    Json::obj(vec![("error", Json::Str(format!("{e:#}")))]);
                let resp = Response::json(status, &body);
                let _ = resp.write_to(&mut writer);
                log_request(state.log_json, &trace_id, "-", "-", status, "-",
                            0, 0, started);
                observe_request("-", status, started);
                served += 1;
                break;
            }
        };
        let mut req_span = trace::span("request", "serve")
            .arg("trace", trace_id.clone())
            .arg("method", req.method.clone())
            .arg("path", req.path.clone());
        let keep_alive = req.wants_keep_alive()
            && reqno + 1 < MAX_REQUESTS_PER_CONN
            && !stop.load(Ordering::SeqCst);
        if req.method == "POST" && req.path == "/v1/generate"
            && req.query_flag("stream") {
            let outcome = generate_stream(state, &req, &mut writer, keep_alive);
            req_span.set_arg("status", outcome.status.to_string());
            log_request(state.log_json, &trace_id, &req.method, &req.path,
                        outcome.status, &outcome.session, outcome.tokens,
                        outcome.batch, started);
            observe_request(&req.path, outcome.status, started);
            served += 1;
        } else {
            let resp = handle(state, &req).keep_alive(keep_alive);
            let write_err = resp.write_to(&mut writer).err();
            req_span.set_arg("status", resp.status.to_string());
            log_request(state.log_json, &trace_id, &req.method, &req.path,
                        resp.status, &resp.session, resp.tokens, resp.batch,
                        started);
            observe_request(&req.path, resp.status, started);
            served += 1;
            if let Some(e) = write_err {
                eprintln!("[serve] write error on {} {}: {e:#}",
                          req.method, req.path);
                break;
            }
        }
        if !keep_alive {
            break;
        }
    }
    served
}
