//! Per-session KV state with checkout semantics, an LRU eviction cap and a
//! KV-cache byte budget.
//!
//! A session is a [`DecodeSession`] (per-block K/V rows) plus the token
//! history it covers. The store hands a session out to exactly one request
//! at a time: [`SessionStore::take`] removes the state but leaves the id
//! registered as *busy* (a second request for the same id gets a clean
//! `Busy` error instead of corrupting the cache), and
//! [`SessionStore::put`] returns it and bumps its recency. Admission is
//! bounded two ways — a live-entry cap and a resident-KV byte budget
//! ([`DecodeSession::kv_bytes`], which busy sessions count against too,
//! since their buffers are merely checked out, not freed). When a
//! [`SessionStore::create`] would exceed either bound, the
//! least-recently-used *idle* session is evicted to make room; if every
//! resident session is busy there is nothing safe to drop, and create
//! refuses with [`StoreFull`] — the router maps that to `429` so clients
//! retry instead of a running request losing its cache. An evicted id
//! simply reads as unknown afterwards (the client starts a fresh session).

use std::collections::HashMap;
use std::sync::Mutex;

use crate::infer::DecodeSession;
use crate::obs::metrics;

/// One serving session: the KV cache plus the full token history it holds
/// (prompt and generated tokens alike — the cache always covers exactly
/// `tokens`, which is what makes continuation requests exact).
#[derive(Debug)]
pub struct ServeSession {
    pub kv: DecodeSession,
    pub tokens: Vec<i32>,
}

/// Why [`SessionStore::take`] refused.
#[derive(Debug, PartialEq, Eq)]
pub enum TakeError {
    /// Never created, or evicted since.
    Unknown,
    /// Checked out by another in-flight request.
    Busy,
}

/// [`SessionStore::create`] refused: both bounds are exhausted and every
/// resident session is checked out, so nothing can be evicted.
#[derive(Debug, PartialEq, Eq)]
pub struct StoreFull {
    /// Sessions currently checked out by in-flight requests.
    pub busy: usize,
}

struct Slot {
    /// `None` while the session is checked out by a request.
    session: Option<ServeSession>,
    /// Monotone recency stamp (store-local, not wall-clock).
    last_used: u64,
    /// KV bytes this session pins ([`DecodeSession::kv_bytes`] — constant
    /// for a given capacity, and still counted while checked out).
    bytes: usize,
}

struct Inner {
    slots: HashMap<String, Slot>,
    tick: u64,
    next_id: u64,
    evicted: u64,
}

impl Inner {
    fn kv_bytes(&self) -> usize {
        self.slots.values().map(|s| s.bytes).sum()
    }

    /// Mirror the store's occupancy into the process-global gauges. Called
    /// by every mutator while the lock is held, so the gauges always
    /// reflect the last store to change (one store per serve process).
    fn sync_gauges(&self) {
        let m = &metrics::REGISTRY;
        m.kv_bytes.set(self.kv_bytes() as u64);
        m.sessions_live.set(self.slots.len() as u64);
    }

    /// Evict the least-recently-used idle slot (skipping `protect`).
    /// `false` when everything resident is busy.
    fn evict_lru_idle(&mut self, protect: Option<&str>) -> bool {
        let victim = self
            .slots
            .iter()
            .filter(|(k, s)| {
                s.session.is_some() && Some(k.as_str()) != protect
            })
            .min_by_key(|(_, s)| s.last_used)
            .map(|(k, _)| k.clone());
        match victim {
            Some(k) => {
                self.slots.remove(&k);
                self.evicted += 1;
                metrics::REGISTRY.session_evictions.inc();
                true
            }
            None => false,
        }
    }
}

/// Thread-safe registry of [`ServeSession`]s, capped at `cap` live entries
/// and `max_kv_bytes` of resident KV cache.
pub struct SessionStore {
    inner: Mutex<Inner>,
    cap: usize,
    max_kv_bytes: usize,
}

impl SessionStore {
    /// Entry-capped store with an unlimited KV byte budget.
    pub fn new(cap: usize) -> SessionStore {
        SessionStore::with_kv_budget(cap, usize::MAX)
    }

    /// Entry cap plus a resident-KV byte budget (`--max-kv-mb`).
    pub fn with_kv_budget(cap: usize, max_kv_bytes: usize) -> SessionStore {
        SessionStore {
            inner: Mutex::new(Inner {
                slots: HashMap::new(),
                tick: 0,
                next_id: 1,
                evicted: 0,
            }),
            cap: cap.max(1),
            max_kv_bytes,
        }
    }

    /// Register a fresh session around `kv` and check it out to the caller.
    /// The returned id is already reserved (busy) until [`SessionStore::put`].
    /// Evicts LRU idle sessions as needed to fit under both bounds; refuses
    /// with [`StoreFull`] when only busy sessions remain. A lone session
    /// larger than the whole byte budget is still admitted into an empty
    /// store (refusing it forever would brick the endpoint).
    pub fn create(&self, kv: DecodeSession)
        -> Result<(String, ServeSession), StoreFull> {
        let mut inner = self.inner.lock().unwrap();
        let bytes = kv.kv_bytes();
        while inner.slots.len() >= self.cap
            || inner.kv_bytes().saturating_add(bytes) > self.max_kv_bytes
        {
            if inner.evict_lru_idle(None) {
                continue;
            }
            if inner.slots.is_empty() {
                break;
            }
            return Err(StoreFull { busy: inner.slots.len() });
        }
        let id = format!("s-{}", inner.next_id);
        inner.next_id += 1;
        inner.tick += 1;
        let stamp = inner.tick;
        inner.slots.insert(
            id.clone(),
            Slot { session: None, last_used: stamp, bytes },
        );
        inner.sync_gauges();
        Ok((id, ServeSession { kv, tokens: Vec::new() }))
    }

    /// Check session `id` out for exclusive use.
    pub fn take(&self, id: &str) -> Result<ServeSession, TakeError> {
        let mut inner = self.inner.lock().unwrap();
        let slot = inner.slots.get_mut(id).ok_or(TakeError::Unknown)?;
        slot.session.take().ok_or(TakeError::Busy)
    }

    /// Return a checked-out session, bump its recency, and evict beyond the
    /// bounds. A session whose id was dropped meanwhile (a raced
    /// [`SessionStore::remove`]) is re-registered — put never loses state,
    /// so when nothing is evictable the store rides over its bounds until
    /// the in-flight sessions come back idle.
    pub fn put(&self, id: &str, session: ServeSession) {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let stamp = inner.tick;
        let bytes = session.kv.kv_bytes();
        let slot = inner
            .slots
            .entry(id.to_string())
            .and_modify(|s| s.last_used = stamp)
            .or_insert(Slot { session: None, last_used: stamp, bytes });
        slot.bytes = bytes;
        slot.session = Some(session);
        while inner.slots.len() > self.cap
            || inner.kv_bytes() > self.max_kv_bytes
        {
            // oldest idle slot; busy sessions and the one just returned
            // (whose id the client is about to be handed) are untouchable
            if !inner.evict_lru_idle(Some(id)) {
                break;
            }
        }
        inner.sync_gauges();
    }

    /// Drop `id` entirely (a request that failed mid-decode leaves the KV
    /// state inconsistent with the token history — discard, don't reuse).
    pub fn remove(&self, id: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.slots.remove(id);
        inner.sync_gauges();
    }

    /// Live entries (idle + busy).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Sessions evicted by the bounds since startup.
    pub fn evicted(&self) -> u64 {
        self.inner.lock().unwrap().evicted
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Resident KV bytes across all live sessions (busy ones included).
    pub fn kv_bytes(&self) -> usize {
        self.inner.lock().unwrap().kv_bytes()
    }

    pub fn max_kv_bytes(&self) -> usize {
        self.max_kv_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv() -> DecodeSession {
        use crate::infer::NativeModel;
        use crate::model::ModelConfig;
        let cfg = ModelConfig {
            name: "t".into(), vocab: 32, d_model: 16, n_heads: 2, n_layers: 1,
            d_ff: 24, seq_len: 8, batch: 1, decode_len: 8, rope_theta: 1e4,
        };
        let ck = crate::trainer::init_checkpoint(&cfg, 1);
        NativeModel::from_checkpoint(&ck).unwrap().new_session(8)
    }

    #[test]
    fn create_take_put_roundtrip() {
        let store = SessionStore::new(4);
        let (id, mut sess) = store.create(kv()).unwrap();
        assert_eq!(id, "s-1");
        assert_eq!(store.len(), 1);
        // busy while checked out
        assert_eq!(store.take(&id).unwrap_err(), TakeError::Busy);
        sess.tokens.push(7);
        store.put(&id, sess);
        let again = store.take(&id).unwrap();
        assert_eq!(again.tokens, [7]);
        store.put(&id, again);
        assert_eq!(store.take("s-999").unwrap_err(), TakeError::Unknown);
    }

    #[test]
    fn lru_evicts_oldest_idle_session() {
        let store = SessionStore::new(2);
        let mut ids = Vec::new();
        for _ in 0..3 {
            let (id, sess) = store.create(kv()).unwrap();
            store.put(&id, sess);
            ids.push(id);
        }
        assert_eq!(store.len(), 2);
        assert_eq!(store.evicted(), 1);
        // the first (oldest) session went; the newer two survive
        assert_eq!(store.take(&ids[0]).unwrap_err(), TakeError::Unknown);
        assert!(store.take(&ids[1]).is_ok());
        assert!(store.take(&ids[2]).is_ok());
    }

    #[test]
    fn touching_a_session_protects_it_from_eviction() {
        let store = SessionStore::new(2);
        let (a, sa) = store.create(kv()).unwrap();
        store.put(&a, sa);
        let (b, sb) = store.create(kv()).unwrap();
        store.put(&b, sb);
        // touch a so b becomes the LRU
        let sa = store.take(&a).unwrap();
        store.put(&a, sa);
        let (c, sc) = store.create(kv()).unwrap();
        store.put(&c, sc);
        assert_eq!(store.take(&b).unwrap_err(), TakeError::Unknown);
        assert!(store.take(&a).is_ok());
    }

    #[test]
    fn create_refuses_when_store_is_full_of_busy_sessions() {
        let store = SessionStore::new(1);
        let (a, sa) = store.create(kv()).unwrap();
        store.put(&a, sa);
        let held = store.take(&a).unwrap(); // a is busy now
        // over cap with only a busy session resident: nothing evictable, so
        // create refuses instead of breaking the live request
        let err = store.create(kv()).unwrap_err();
        assert_eq!(err, StoreFull { busy: 1 });
        assert_eq!(store.len(), 1);
        store.put(&a, held); // a comes back idle → now it can be chosen
        let (b, sb) = store.create(kv()).unwrap();
        store.put(&b, sb);
        assert_eq!(store.len(), 1);
        assert_eq!(store.evicted(), 1);
        assert!(store.take(&b).is_ok());
        assert_eq!(store.take(&a).unwrap_err(), TakeError::Unknown);
    }

    #[test]
    fn kv_byte_budget_evicts_idle_and_refuses_when_busy() {
        let one = kv().kv_bytes();
        assert!(one > 0);
        // room for exactly two sessions' KV
        let store = SessionStore::with_kv_budget(8, 2 * one + 1);
        let (a, sa) = store.create(kv()).unwrap();
        store.put(&a, sa);
        let (b, sb) = store.create(kv()).unwrap();
        store.put(&b, sb);
        // a third would exceed the budget → LRU idle (a) makes room
        let (c, sc) = store.create(kv()).unwrap();
        store.put(&c, sc);
        assert_eq!(store.len(), 2);
        assert_eq!(store.evicted(), 1);
        assert_eq!(store.kv_bytes(), 2 * one);
        assert_eq!(store.take(&a).unwrap_err(), TakeError::Unknown);
        // busy sessions still pin their bytes: with both survivors checked
        // out there is nothing safe to evict
        let hb = store.take(&b).unwrap();
        let hc = store.take(&c).unwrap();
        let err = store.create(kv()).unwrap_err();
        assert_eq!(err, StoreFull { busy: 2 });
        store.put(&b, hb);
        store.put(&c, hc);
        // a lone session larger than the whole budget is still admitted
        let tiny = SessionStore::with_kv_budget(4, 1);
        assert!(tiny.create(kv()).is_ok());
    }

    #[test]
    fn remove_discards_failed_sessions() {
        let store = SessionStore::new(4);
        let (id, _sess) = store.create(kv()).unwrap();
        store.remove(&id);
        assert_eq!(store.take(&id).unwrap_err(), TakeError::Unknown);
        assert!(store.is_empty());
    }
}
