//! Per-session KV state with checkout semantics and an LRU eviction cap.
//!
//! A session is a [`DecodeSession`] (per-block K/V rows) plus the token
//! history it covers. The store hands a session out to exactly one request
//! at a time: [`SessionStore::take`] removes the state but leaves the id
//! registered as *busy* (a second request for the same id gets a clean
//! `Busy` error instead of corrupting the cache), and
//! [`SessionStore::put`] returns it and bumps its recency. When the store
//! grows past its cap, the least-recently-used idle session is evicted —
//! busy sessions are never evicted out from under a running request, and
//! an evicted id simply reads as unknown afterwards (the client starts a
//! fresh session).

use std::collections::HashMap;
use std::sync::Mutex;

use crate::infer::DecodeSession;

/// One serving session: the KV cache plus the full token history it holds
/// (prompt and generated tokens alike — the cache always covers exactly
/// `tokens`, which is what makes continuation requests exact).
#[derive(Debug)]
pub struct ServeSession {
    pub kv: DecodeSession,
    pub tokens: Vec<i32>,
}

/// Why [`SessionStore::take`] refused.
#[derive(Debug, PartialEq, Eq)]
pub enum TakeError {
    /// Never created, or evicted since.
    Unknown,
    /// Checked out by another in-flight request.
    Busy,
}

struct Slot {
    /// `None` while the session is checked out by a request.
    session: Option<ServeSession>,
    /// Monotone recency stamp (store-local, not wall-clock).
    last_used: u64,
}

struct Inner {
    slots: HashMap<String, Slot>,
    tick: u64,
    next_id: u64,
    evicted: u64,
}

/// Thread-safe registry of [`ServeSession`]s, capped at `cap` live entries.
pub struct SessionStore {
    inner: Mutex<Inner>,
    cap: usize,
}

impl SessionStore {
    pub fn new(cap: usize) -> SessionStore {
        SessionStore {
            inner: Mutex::new(Inner {
                slots: HashMap::new(),
                tick: 0,
                next_id: 1,
                evicted: 0,
            }),
            cap: cap.max(1),
        }
    }

    /// Register a fresh session around `kv` and check it out to the caller.
    /// The returned id is already reserved (busy) until [`SessionStore::put`].
    pub fn create(&self, kv: DecodeSession) -> (String, ServeSession) {
        let mut inner = self.inner.lock().unwrap();
        let id = format!("s-{}", inner.next_id);
        inner.next_id += 1;
        inner.tick += 1;
        let stamp = inner.tick;
        inner
            .slots
            .insert(id.clone(), Slot { session: None, last_used: stamp });
        (id, ServeSession { kv, tokens: Vec::new() })
    }

    /// Check session `id` out for exclusive use.
    pub fn take(&self, id: &str) -> Result<ServeSession, TakeError> {
        let mut inner = self.inner.lock().unwrap();
        let slot = inner.slots.get_mut(id).ok_or(TakeError::Unknown)?;
        slot.session.take().ok_or(TakeError::Busy)
    }

    /// Return a checked-out session, bump its recency, and evict beyond the
    /// cap. A session whose id was dropped meanwhile (a raced
    /// [`SessionStore::remove`]) is re-registered — put never loses state.
    pub fn put(&self, id: &str, session: ServeSession) {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let stamp = inner.tick;
        inner
            .slots
            .entry(id.to_string())
            .and_modify(|s| s.last_used = stamp)
            .or_insert(Slot { session: None, last_used: stamp })
            .session = Some(session);
        while inner.slots.len() > self.cap {
            // oldest idle slot; busy sessions and the one just returned
            // (whose id the client is about to be handed) are untouchable
            let victim = inner
                .slots
                .iter()
                .filter(|(k, s)| s.session.is_some() && k.as_str() != id)
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    inner.slots.remove(&k);
                    inner.evicted += 1;
                }
                None => break, // everything else is in flight; stay over cap
            }
        }
    }

    /// Drop `id` entirely (a request that failed mid-decode leaves the KV
    /// state inconsistent with the token history — discard, don't reuse).
    pub fn remove(&self, id: &str) {
        self.inner.lock().unwrap().slots.remove(id);
    }

    /// Live entries (idle + busy).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Sessions evicted by the LRU cap since startup.
    pub fn evicted(&self) -> u64 {
        self.inner.lock().unwrap().evicted
    }

    pub fn cap(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv() -> DecodeSession {
        use crate::infer::NativeModel;
        use crate::model::ModelConfig;
        let cfg = ModelConfig {
            name: "t".into(), vocab: 32, d_model: 16, n_heads: 2, n_layers: 1,
            d_ff: 24, seq_len: 8, batch: 1, decode_len: 8, rope_theta: 1e4,
        };
        let ck = crate::trainer::init_checkpoint(&cfg, 1);
        NativeModel::from_checkpoint(&ck).unwrap().new_session(8)
    }

    #[test]
    fn create_take_put_roundtrip() {
        let store = SessionStore::new(4);
        let (id, mut sess) = store.create(kv());
        assert_eq!(id, "s-1");
        assert_eq!(store.len(), 1);
        // busy while checked out
        assert_eq!(store.take(&id).unwrap_err(), TakeError::Busy);
        sess.tokens.push(7);
        store.put(&id, sess);
        let again = store.take(&id).unwrap();
        assert_eq!(again.tokens, [7]);
        store.put(&id, again);
        assert_eq!(store.take("s-999").unwrap_err(), TakeError::Unknown);
    }

    #[test]
    fn lru_evicts_oldest_idle_session() {
        let store = SessionStore::new(2);
        let mut ids = Vec::new();
        for _ in 0..3 {
            let (id, sess) = store.create(kv());
            store.put(&id, sess);
            ids.push(id);
        }
        assert_eq!(store.len(), 2);
        assert_eq!(store.evicted(), 1);
        // the first (oldest) session went; the newer two survive
        assert_eq!(store.take(&ids[0]).unwrap_err(), TakeError::Unknown);
        assert!(store.take(&ids[1]).is_ok());
        assert!(store.take(&ids[2]).is_ok());
    }

    #[test]
    fn touching_a_session_protects_it_from_eviction() {
        let store = SessionStore::new(2);
        let (a, sa) = store.create(kv());
        store.put(&a, sa);
        let (b, sb) = store.create(kv());
        store.put(&b, sb);
        // touch a so b becomes the LRU
        let sa = store.take(&a).unwrap();
        store.put(&a, sa);
        let (c, sc) = store.create(kv());
        store.put(&c, sc);
        assert_eq!(store.take(&b).unwrap_err(), TakeError::Unknown);
        assert!(store.take(&a).is_ok());
    }

    #[test]
    fn busy_sessions_are_never_evicted() {
        let store = SessionStore::new(1);
        let (a, sa) = store.create(kv());
        store.put(&a, sa);
        let held = store.take(&a).unwrap(); // a is busy now
        let (b, sb) = store.create(kv());
        // over cap, but a is busy and b was just returned: nothing evictable,
        // so the store rides over cap rather than breaking a live request
        store.put(&b, sb);
        assert_eq!(store.len(), 2);
        assert_eq!(store.evicted(), 0);
        store.put(&a, held); // a comes back idle → now it can be chosen
        let (c, sc) = store.create(kv());
        store.put(&c, sc);
        assert_eq!(store.len(), 1);
        assert!(store.evicted() >= 2);
        assert!(store.take(&c).is_ok());
    }

    #[test]
    fn remove_discards_failed_sessions() {
        let store = SessionStore::new(4);
        let (id, _sess) = store.create(kv());
        store.remove(&id);
        assert_eq!(store.take(&id).unwrap_err(), TakeError::Unknown);
        assert!(store.is_empty());
    }
}
