//! Dependency-free HTTP/1.1 request/response layer.
//!
//! The build is fully offline (no hyper/axum on the image), so the server
//! carries its own wire protocol the same way `util::json` carries its own
//! codec: a strict, bounded parser for the fragment of HTTP/1.1 the
//! endpoints need (request line + headers + `Content-Length` body), a
//! response writer with exact `Content-Length` framing, and chunked
//! transfer-encoding writers for the streaming generate path. Connections
//! follow HTTP/1.1 persistence semantics: keep-alive by default
//! ([`Request::wants_keep_alive`]), `Connection: close` when the client
//! asks for it or the server's per-connection request cap is reached —
//! the [`Response::keep_alive`] flag picks the header the writer emits.
//!
//! Bounds are enforced while reading, not after: header bytes are capped at
//! [`MAX_HEADER_BYTES`] (checked *before* each byte is consumed) and bodies
//! at [`MAX_BODY_BYTES`], so a misbehaving client cannot balloon memory.
//! Anything malformed is an `Err` the server maps to a `400` — parsing
//! never panics. Protocol features the parser deliberately refuses carry a
//! typed [`HttpError`] so the server can answer with the right status:
//! `Transfer-Encoding` request bodies get `501 Not Implemented` (framing
//! this parser does not speak — silently ignoring it would desync the
//! keep-alive byte stream), duplicate `Content-Length` headers get `400`.

use std::io::{BufRead, Read, Write};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Cap on the request line + all header lines, bytes.
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Cap on a request body (`Content-Length`), bytes.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// A request-parse failure that maps to a specific HTTP status code.
/// [`read_request`] wraps refusals that are not the client's syntax's
/// fault — protocol features this parser intentionally does not implement
/// — so the server's error arm can pick `501` over the generic `400` via
/// `downcast_ref`.
#[derive(Debug)]
pub struct HttpError {
    pub status: u16,
    pub message: String,
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for HttpError {}

/// A parsed request: method, split target, lower-cased headers, raw body.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path component of the target (query string stripped).
    pub path: String,
    /// Raw query string after `?`, empty when absent.
    pub query: String,
    /// `(name, value)` pairs; names are lower-cased at parse time.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First value of header `name` (case-insensitive).
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == want)
            .map(|(_, v)| v.as_str())
    }

    /// HTTP/1.1 connection persistence: keep the connection open unless
    /// the client sent `Connection: close` (token-matched, case-insensitive
    /// — `keep-alive` and absence both mean persistent on HTTP/1.1).
    pub fn wants_keep_alive(&self) -> bool {
        match self.header("connection") {
            Some(v) => !v
                .split(',')
                .any(|t| t.trim().eq_ignore_ascii_case("close")),
            None => true,
        }
    }

    /// `true` when query parameter `name` is present as `name`, `name=1`
    /// or `name=true` (e.g. `/v1/generate?stream=true`).
    pub fn query_flag(&self, name: &str) -> bool {
        self.query.split('&').any(|pair| {
            let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
            k == name && matches!(v, "" | "1" | "true")
        })
    }

    /// Body parsed as a JSON object (the POST endpoints' input contract).
    pub fn json_body(&self) -> Result<Json> {
        let text =
            std::str::from_utf8(&self.body).context("request body is not UTF-8")?;
        if text.trim().is_empty() {
            bail!("request body is empty (expected a JSON object)");
        }
        Json::parse(text).context("request body is not valid JSON")
    }
}

/// Read one line terminated by `\n`, stripping the trailing `\r\n`/`\n`.
/// `budget` counts down the shared header-byte cap.
fn read_line<R: BufRead>(reader: &mut R, budget: &mut usize) -> Result<String> {
    let mut raw = Vec::new();
    loop {
        // cap first, read second: a head that would need byte
        // MAX_HEADER_BYTES + 1 is rejected without consuming it, so the
        // boundary is exact — a head of exactly the cap still parses
        if *budget == 0 {
            bail!("request head exceeds {MAX_HEADER_BYTES} bytes");
        }
        let mut byte = [0u8; 1];
        if reader.read(&mut byte)? == 0 {
            bail!("connection closed mid-line");
        }
        *budget -= 1;
        if byte[0] == b'\n' {
            break;
        }
        raw.push(byte[0]);
    }
    if raw.last() == Some(&b'\r') {
        raw.pop();
    }
    String::from_utf8(raw).context("request head is not UTF-8")
}

/// Parse one HTTP/1.1 request off `reader` (blocking, bounded).
pub fn read_request<R: BufRead>(reader: &mut R) -> Result<Request> {
    let mut budget = MAX_HEADER_BYTES;
    let line = read_line(reader, &mut budget)?;
    let mut parts = line.split(' ');
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().context("malformed request line")?.to_string();
    let version = parts.next().context("malformed request line")?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol version {version}");
    }
    if method.is_empty() || !target.starts_with('/') {
        bail!("malformed request line '{line}'");
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    let mut headers = Vec::new();
    loop {
        let line = read_line(reader, &mut budget)?;
        if line.is_empty() {
            break;
        }
        let (name, value) = line.split_once(':').context("malformed header")?;
        headers.push((name.trim().to_ascii_lowercase(),
                      value.trim().to_string()));
    }
    let mut req =
        Request { method, path, query, headers, body: Vec::new() };
    // `Transfer-Encoding` request bodies use framing this parser does not
    // speak. Taking any Content-Length that rides along (or assuming "no
    // body") would leave the chunked body bytes unread, and the keep-alive
    // loop would parse them as the next request's head — a connection
    // desync. Refuse loudly with a typed 501 instead.
    if req.header("transfer-encoding").is_some() {
        return Err(HttpError {
            status: 501,
            message: "Transfer-Encoding request bodies are not supported"
                .into(),
        }
        .into());
    }
    // Multiple Content-Length headers (even identical ones) are the
    // classic request-smuggling / desync vector: different parsers pick
    // different values. Reject the request outright.
    let mut lengths = req
        .headers
        .iter()
        .filter(|(k, _)| k == "content-length")
        .map(|(_, v)| v.clone());
    let content_length = lengths.next();
    if lengths.next().is_some() {
        bail!("duplicate Content-Length headers");
    }
    if let Some(len) = content_length {
        let len: usize =
            len.parse().context("malformed Content-Length header")?;
        if len > MAX_BODY_BYTES {
            bail!("request body of {len} bytes exceeds cap {MAX_BODY_BYTES}");
        }
        let mut body = vec![0u8; len];
        reader
            .read_exact(&mut body)
            .context("connection closed mid-body")?;
        req.body = body;
    }
    Ok(req)
}

/// An outgoing response with exact `Content-Length` framing, plus the
/// structured-log fields the server's per-request line reports (`session`,
/// `tokens`, `batch`) and the connection-persistence decision.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Session id this request touched, `"-"` when none.
    pub session: String,
    /// Tokens processed (prompt + generated, or scored), 0 when n/a.
    pub tokens: usize,
    /// Peak decode-batch occupancy this request's ticks rode in, 0 when
    /// the request never decoded.
    pub batch: usize,
    /// Emit `Connection: keep-alive` instead of `close`. Defaults to
    /// `false`; the server sets it per connection state.
    pub keep_alive: bool,
    /// Extra response headers beyond the framing trio (e.g.
    /// `Retry-After` on 429s); written verbatim after `Connection:`.
    pub extra_headers: Vec<(&'static str, String)>,
}

impl Response {
    pub fn json(status: u16, value: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: value.to_string().into_bytes(),
            session: "-".into(),
            tokens: 0,
            batch: 0,
            keep_alive: false,
            extra_headers: Vec::new(),
        }
    }

    /// A non-JSON body with an explicit content type (the `/metrics`
    /// Prometheus text exposition).
    pub fn text(status: u16, content_type: &'static str, body: String) -> Response {
        Response {
            status,
            content_type,
            body: body.into_bytes(),
            session: "-".into(),
            tokens: 0,
            batch: 0,
            keep_alive: false,
            extra_headers: Vec::new(),
        }
    }

    /// Attach an extra response header.
    pub fn with_header(mut self, name: &'static str, value: String) -> Response {
        self.extra_headers.push((name, value));
        self
    }

    /// Attach the structured-log fields to this response.
    pub fn logged(mut self, session: &str, tokens: usize) -> Response {
        self.session = session.to_string();
        self.tokens = tokens;
        self
    }

    /// Record the decode-batch occupancy for the structured log line.
    pub fn with_batch(mut self, batch: usize) -> Response {
        self.batch = batch;
        self
    }

    /// Set the connection-persistence header this response will carry.
    pub fn keep_alive(mut self, keep_alive: bool) -> Response {
        self.keep_alive = keep_alive;
        self
    }

    pub fn reason(status: u16) -> &'static str {
        match status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            422 => "Unprocessable Entity",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            501 => "Not Implemented",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }

    /// Serialise onto `w` (head + body, then flush).
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n\
             Connection: {}\r\n",
            self.status,
            Self::reason(self.status),
            self.content_type,
            self.body.len(),
            if self.keep_alive { "keep-alive" } else { "close" },
        )?;
        for (name, value) in &self.extra_headers {
            write!(w, "{name}: {value}\r\n")?;
        }
        w.write_all(b"\r\n")?;
        w.write_all(&self.body)?;
        w.flush()?;
        Ok(())
    }
}

/// Parse one request off `reader`, distinguishing "connection is done"
/// from "request is malformed": `Ok(None)` when the peer closed (or an
/// idle read timed out) *before sending any bytes* of a next request,
/// `Err` for garbage after bytes started flowing. This is what lets the
/// keep-alive loop wait quietly for a pipelined request without turning
/// every clean close into a spurious 400.
pub fn read_request_opt<R: BufRead>(reader: &mut R) -> Result<Option<Request>> {
    match reader.fill_buf() {
        Ok([]) => return Ok(None),
        Ok(_) => {}
        Err(e) if matches!(e.kind(),
                           std::io::ErrorKind::WouldBlock
                               | std::io::ErrorKind::TimedOut
                               | std::io::ErrorKind::ConnectionReset) => {
            return Ok(None)
        }
        Err(e) => return Err(e.into()),
    }
    read_request(reader).map(Some)
}

/// Write the head of a chunked streaming response (the `stream=true`
/// generate path): committed status 200, newline-delimited JSON body,
/// `Transfer-Encoding: chunked` framing so each token flushes as its own
/// chunk the moment the scheduler emits it.
pub fn write_stream_head<W: Write>(w: &mut W, keep_alive: bool) -> Result<()> {
    write!(
        w,
        "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\n\
         Transfer-Encoding: chunked\r\nConnection: {}\r\n\r\n",
        if keep_alive { "keep-alive" } else { "close" },
    )?;
    w.flush()?;
    Ok(())
}

/// Write one chunk (hex length, payload, CRLF) and flush so the client
/// sees it immediately. Empty payloads are skipped — a zero-length chunk
/// is the stream terminator, which only [`write_last_chunk`] may emit.
pub fn write_chunk<W: Write>(w: &mut W, data: &[u8]) -> Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    write!(w, "{:x}\r\n", data.len())?;
    w.write_all(data)?;
    w.write_all(b"\r\n")?;
    w.flush()?;
    Ok(())
}

/// Terminate a chunked stream (`0\r\n\r\n`).
pub fn write_last_chunk<W: Write>(w: &mut W) -> Result<()> {
    w.write_all(b"0\r\n\r\n")?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(raw: &str) -> Result<Request> {
        read_request(&mut BufReader::new(raw.as_bytes()))
    }

    #[test]
    fn parses_get_with_query_and_headers() {
        let req = parse("GET /v1/inspect?verbose=1 HTTP/1.1\r\n\
                         Host: localhost\r\nX-Test: a b\r\n\r\n")
            .unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/v1/inspect");
        assert_eq!(req.query, "verbose=1");
        assert_eq!(req.header("host"), Some("localhost"));
        assert_eq!(req.header("X-TEST"), Some("a b"));
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_post_body_by_content_length() {
        let body = r#"{"prompt":"hi"}"#;
        let raw = format!("POST /v1/generate HTTP/1.1\r\n\
                           Content-Length: {}\r\n\r\n{body}",
                          body.len());
        let req = parse(&raw).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, body.as_bytes());
        let json = req.json_body().unwrap();
        assert_eq!(json.expect("prompt").unwrap().as_str().unwrap(), "hi");
    }

    #[test]
    fn rejects_malformed_and_oversized_requests() {
        assert!(parse("").is_err());
        assert!(parse("GARBAGE\r\n\r\n").is_err());
        assert!(parse("GET noslash HTTP/1.1\r\n\r\n").is_err());
        assert!(parse("GET / SPDY/3\r\n\r\n").is_err());
        assert!(parse("GET / HTTP/1.1\r\nbadheader\r\n\r\n").is_err());
        // body longer than advertised cap
        let raw = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
                          MAX_BODY_BYTES + 1);
        assert!(parse(&raw).is_err());
        // header flood
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        while raw.len() <= MAX_HEADER_BYTES {
            raw.push_str("X-Pad: aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa\r\n");
        }
        raw.push_str("\r\n");
        assert!(parse(&raw).is_err());
        // truncated body
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc")
            .is_err());
    }

    #[test]
    fn header_cap_boundary_is_exact() {
        // a head of exactly MAX_HEADER_BYTES parses; one byte more fails
        let base = "GET / HTTP/1.1\r\nX-Pad: ";
        let tail = "\r\n\r\n";
        let pad = MAX_HEADER_BYTES - base.len() - tail.len();
        let at_cap = format!("{base}{}{tail}", "a".repeat(pad));
        assert_eq!(at_cap.len(), MAX_HEADER_BYTES);
        assert_eq!(parse(&at_cap).unwrap().path, "/");
        let over_cap = format!("{base}{}{tail}", "a".repeat(pad + 1));
        let err = parse(&over_cap).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds"), "{err:#}");
    }

    #[test]
    fn transfer_encoding_bodies_get_a_typed_501() {
        let err = parse("POST /v1/generate HTTP/1.1\r\n\
                         Transfer-Encoding: chunked\r\n\r\n\
                         5\r\nhello\r\n0\r\n\r\n")
            .unwrap_err();
        let he = err.downcast_ref::<HttpError>().expect("typed HttpError");
        assert_eq!(he.status, 501);
        assert!(he.message.contains("Transfer-Encoding"));
        assert_eq!(Response::reason(501), "Not Implemented");
    }

    #[test]
    fn duplicate_content_length_is_rejected() {
        // conflicting values: a desync waiting to happen
        let conflicting = "POST / HTTP/1.1\r\nContent-Length: 3\r\n\
                           Content-Length: 5\r\n\r\nabcde";
        let err = parse(conflicting).unwrap_err();
        assert!(format!("{err:#}").contains("duplicate Content-Length"));
        // even identical duplicates are refused (smuggling vector) and the
        // refusal is a plain parse error → the generic 400 path
        let identical = "POST / HTTP/1.1\r\nContent-Length: 3\r\n\
                         Content-Length: 3\r\n\r\nabc";
        let err = parse(identical).unwrap_err();
        assert!(err.downcast_ref::<HttpError>().is_none());
    }

    #[test]
    fn response_wire_format_is_exact() {
        let resp = Response::json(
            200,
            &Json::obj(vec![("ok", Json::Bool(true))]),
        );
        let mut out = Vec::new();
        resp.write_to(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Type: application/json\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        let body = text.split("\r\n\r\n").nth(1).unwrap();
        assert_eq!(body, r#"{"ok":true}"#);
        assert!(text.contains(&format!("Content-Length: {}\r\n", body.len())));
    }

    #[test]
    fn empty_json_body_is_rejected() {
        let req = parse("POST /v1/generate HTTP/1.1\r\n\r\n").unwrap();
        assert!(req.json_body().is_err());
    }

    #[test]
    fn connection_persistence_follows_http11_semantics() {
        // absent header → persistent (HTTP/1.1 default)
        assert!(parse("GET / HTTP/1.1\r\n\r\n").unwrap().wants_keep_alive());
        assert!(parse("GET / HTTP/1.1\r\nConnection: keep-alive\r\n\r\n")
            .unwrap()
            .wants_keep_alive());
        // explicit close, any case, possibly in a token list
        assert!(!parse("GET / HTTP/1.1\r\nConnection: close\r\n\r\n")
            .unwrap()
            .wants_keep_alive());
        assert!(!parse("GET / HTTP/1.1\r\nConnection: Close\r\n\r\n")
            .unwrap()
            .wants_keep_alive());
        assert!(!parse("GET / HTTP/1.1\r\nConnection: TE, close\r\n\r\n")
            .unwrap()
            .wants_keep_alive());
    }

    #[test]
    fn query_flags_parse_all_spellings() {
        let req = |q: &str| {
            parse(&format!("POST /v1/generate{q} HTTP/1.1\r\n\r\n")).unwrap()
        };
        assert!(req("?stream=true").query_flag("stream"));
        assert!(req("?stream=1").query_flag("stream"));
        assert!(req("?stream").query_flag("stream"));
        assert!(req("?a=b&stream=true").query_flag("stream"));
        assert!(!req("?stream=false").query_flag("stream"));
        assert!(!req("?streaming=true").query_flag("stream"));
        assert!(!req("").query_flag("stream"));
    }

    #[test]
    fn keep_alive_response_carries_the_header() {
        let resp = Response::json(200, &Json::obj(vec![("ok", Json::Bool(true))]))
            .keep_alive(true);
        let mut out = Vec::new();
        resp.write_to(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(!text.contains("Connection: close"));
        assert_eq!(Response::reason(429), "Too Many Requests");
    }

    #[test]
    fn extra_headers_are_written_after_the_framing_trio() {
        let resp = Response::json(429, &Json::obj(vec![("error", Json::Str("full".into()))]))
            .with_header("Retry-After", "1".to_string());
        let mut out = Vec::new();
        resp.write_to(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let head = text.split_once("\r\n\r\n").unwrap().0;
        assert!(head.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(head.ends_with("Retry-After: 1"));
        assert!(head.contains("Connection: close\r\n"));
    }

    #[test]
    fn text_response_carries_the_given_content_type() {
        let resp = Response::text(200, "text/plain; version=0.0.4",
                                  "awp_requests_total 1\n".to_string());
        let mut out = Vec::new();
        resp.write_to(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Content-Type: text/plain; version=0.0.4\r\n"));
        assert!(text.ends_with("awp_requests_total 1\n"));
    }

    #[test]
    fn read_request_opt_distinguishes_close_from_garbage() {
        // clean EOF before any bytes → None, not an error
        let mut empty = BufReader::new(&b""[..]);
        assert!(read_request_opt(&mut empty).unwrap().is_none());
        // a complete request parses as usual
        let mut ok = BufReader::new(&b"GET /healthz HTTP/1.1\r\n\r\n"[..]);
        let req = read_request_opt(&mut ok).unwrap().unwrap();
        assert_eq!(req.path, "/healthz");
        // bytes started flowing, then garbage → a real error (→ 400)
        let mut bad = BufReader::new(&b"GARBAGE\r\n\r\n"[..]);
        assert!(read_request_opt(&mut bad).is_err());
    }

    #[test]
    fn chunked_stream_wire_format_is_exact() {
        let mut out = Vec::new();
        write_stream_head(&mut out, false).unwrap();
        write_chunk(&mut out, br#"{"token":7}"#).unwrap();
        write_chunk(&mut out, b"").unwrap(); // skipped, not a terminator
        write_chunk(&mut out, b"0123456789abcdef").unwrap(); // 16 → "10"
        write_last_chunk(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Transfer-Encoding: chunked\r\n"));
        assert!(text.contains("Content-Type: application/x-ndjson\r\n"));
        let body = text.split_once("\r\n\r\n").unwrap().1;
        assert_eq!(body,
                   "b\r\n{\"token\":7}\r\n10\r\n0123456789abcdef\r\n0\r\n\r\n");
    }
}
