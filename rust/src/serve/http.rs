//! Dependency-free HTTP/1.1 request/response layer.
//!
//! The build is fully offline (no hyper/axum on the image), so the server
//! carries its own wire protocol the same way `util::json` carries its own
//! codec: a strict, bounded parser for the fragment of HTTP/1.1 the
//! endpoints need (request line + headers + `Content-Length` body), and a
//! writer that always answers `Connection: close` — one request per
//! connection keeps the server state machine trivial and is plenty for an
//! inference endpoint whose per-request work dwarfs connection setup.
//!
//! Bounds are enforced while reading, not after: header bytes are capped at
//! [`MAX_HEADER_BYTES`] and bodies at [`MAX_BODY_BYTES`], so a misbehaving
//! client cannot balloon memory. Anything malformed is an `Err` the server
//! maps to a `400` — parsing never panics.

use std::io::{BufRead, Read, Write};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Cap on the request line + all header lines, bytes.
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Cap on a request body (`Content-Length`), bytes.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// A parsed request: method, split target, lower-cased headers, raw body.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path component of the target (query string stripped).
    pub path: String,
    /// Raw query string after `?`, empty when absent.
    pub query: String,
    /// `(name, value)` pairs; names are lower-cased at parse time.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First value of header `name` (case-insensitive).
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == want)
            .map(|(_, v)| v.as_str())
    }

    /// Body parsed as a JSON object (the POST endpoints' input contract).
    pub fn json_body(&self) -> Result<Json> {
        let text =
            std::str::from_utf8(&self.body).context("request body is not UTF-8")?;
        if text.trim().is_empty() {
            bail!("request body is empty (expected a JSON object)");
        }
        Json::parse(text).context("request body is not valid JSON")
    }
}

/// Read one line terminated by `\n`, stripping the trailing `\r\n`/`\n`.
/// `budget` counts down the shared header-byte cap.
fn read_line<R: BufRead>(reader: &mut R, budget: &mut usize) -> Result<String> {
    let mut raw = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        if reader.read(&mut byte)? == 0 {
            bail!("connection closed mid-line");
        }
        if *budget == 0 {
            bail!("request head exceeds {MAX_HEADER_BYTES} bytes");
        }
        *budget -= 1;
        if byte[0] == b'\n' {
            break;
        }
        raw.push(byte[0]);
    }
    if raw.last() == Some(&b'\r') {
        raw.pop();
    }
    String::from_utf8(raw).context("request head is not UTF-8")
}

/// Parse one HTTP/1.1 request off `reader` (blocking, bounded).
pub fn read_request<R: BufRead>(reader: &mut R) -> Result<Request> {
    let mut budget = MAX_HEADER_BYTES;
    let line = read_line(reader, &mut budget)?;
    let mut parts = line.split(' ');
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().context("malformed request line")?.to_string();
    let version = parts.next().context("malformed request line")?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol version {version}");
    }
    if method.is_empty() || !target.starts_with('/') {
        bail!("malformed request line '{line}'");
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    let mut headers = Vec::new();
    loop {
        let line = read_line(reader, &mut budget)?;
        if line.is_empty() {
            break;
        }
        let (name, value) = line.split_once(':').context("malformed header")?;
        headers.push((name.trim().to_ascii_lowercase(),
                      value.trim().to_string()));
    }
    let mut req =
        Request { method, path, query, headers, body: Vec::new() };
    if let Some(len) = req.header("content-length") {
        let len: usize =
            len.parse().context("malformed Content-Length header")?;
        if len > MAX_BODY_BYTES {
            bail!("request body of {len} bytes exceeds cap {MAX_BODY_BYTES}");
        }
        let mut body = vec![0u8; len];
        reader
            .read_exact(&mut body)
            .context("connection closed mid-body")?;
        req.body = body;
    }
    Ok(req)
}

/// An outgoing response. Every response closes the connection and carries
/// an exact `Content-Length`, plus the structured-log fields the server's
/// per-request line reports (`session`, `tokens`).
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Session id this request touched, `"-"` when none.
    pub session: String,
    /// Tokens processed (prompt + generated, or scored), 0 when n/a.
    pub tokens: usize,
}

impl Response {
    pub fn json(status: u16, value: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: value.to_string().into_bytes(),
            session: "-".into(),
            tokens: 0,
        }
    }

    /// Attach the structured-log fields to this response.
    pub fn logged(mut self, session: &str, tokens: usize) -> Response {
        self.session = session.to_string();
        self.tokens = tokens;
        self
    }

    pub fn reason(status: u16) -> &'static str {
        match status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            422 => "Unprocessable Entity",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }

    /// Serialise onto `w` (head + body, then flush).
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n",
            self.status,
            Self::reason(self.status),
            self.content_type,
            self.body.len(),
        )?;
        w.write_all(&self.body)?;
        w.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(raw: &str) -> Result<Request> {
        read_request(&mut BufReader::new(raw.as_bytes()))
    }

    #[test]
    fn parses_get_with_query_and_headers() {
        let req = parse("GET /v1/inspect?verbose=1 HTTP/1.1\r\n\
                         Host: localhost\r\nX-Test: a b\r\n\r\n")
            .unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/v1/inspect");
        assert_eq!(req.query, "verbose=1");
        assert_eq!(req.header("host"), Some("localhost"));
        assert_eq!(req.header("X-TEST"), Some("a b"));
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_post_body_by_content_length() {
        let body = r#"{"prompt":"hi"}"#;
        let raw = format!("POST /v1/generate HTTP/1.1\r\n\
                           Content-Length: {}\r\n\r\n{body}",
                          body.len());
        let req = parse(&raw).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, body.as_bytes());
        let json = req.json_body().unwrap();
        assert_eq!(json.expect("prompt").unwrap().as_str().unwrap(), "hi");
    }

    #[test]
    fn rejects_malformed_and_oversized_requests() {
        assert!(parse("").is_err());
        assert!(parse("GARBAGE\r\n\r\n").is_err());
        assert!(parse("GET noslash HTTP/1.1\r\n\r\n").is_err());
        assert!(parse("GET / SPDY/3\r\n\r\n").is_err());
        assert!(parse("GET / HTTP/1.1\r\nbadheader\r\n\r\n").is_err());
        // body longer than advertised cap
        let raw = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
                          MAX_BODY_BYTES + 1);
        assert!(parse(&raw).is_err());
        // header flood
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        while raw.len() <= MAX_HEADER_BYTES {
            raw.push_str("X-Pad: aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa\r\n");
        }
        raw.push_str("\r\n");
        assert!(parse(&raw).is_err());
        // truncated body
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc")
            .is_err());
    }

    #[test]
    fn response_wire_format_is_exact() {
        let resp = Response::json(
            200,
            &Json::obj(vec![("ok", Json::Bool(true))]),
        );
        let mut out = Vec::new();
        resp.write_to(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Type: application/json\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        let body = text.split("\r\n\r\n").nth(1).unwrap();
        assert_eq!(body, r#"{"ok":true}"#);
        assert!(text.contains(&format!("Content-Length: {}\r\n", body.len())));
    }

    #[test]
    fn empty_json_body_is_rejected() {
        let req = parse("POST /v1/generate HTTP/1.1\r\n\r\n").unwrap();
        assert!(req.json_body().is_err());
    }
}
