//! Long-lived HTTP inference server over the native packed engine —
//! `repro serve --from-artifact <file.apack>`.
//!
//! The serving hot path is the KV-cached decode the `infer` module grew
//! for this subsystem: each connection's context lives in a
//! [`crate::infer::DecodeSession`] (per-block K/V rows + RoPE offset), so
//! a request pays one batched prefill for its prompt and O(ctx) per
//! generated token — and a *continuation* request against the same
//! session id pays nothing for the history at all. Artifacts serve
//! packed (zero decode-to-dense assemblies), on the fast kernel tier by
//! default. Concurrent requests' decode steps fuse into one batched
//! forward per tick through the [`batcher`] — continuous batching that
//! amortises every packed site's per-launch decode aux over the whole
//! batch without changing any session's reference-tier bits.
//!
//! Layering, bottom to top:
//!
//! * [`http`] — bounded, dependency-free HTTP/1.1 parsing and writing
//!   (the image carries no HTTP crate, as `util::json` carries no serde),
//!   keep-alive negotiation, chunked streaming writers;
//! * [`router`] — the static route table and typed handlers
//!   (`/healthz`, `/v1/inspect`, `/v1/generate` (buffered or
//!   `?stream=true`), `/v1/perplexity`, plus the observability surface
//!   `/metrics` (Prometheus text) and `/v1/stats` (JSON) over the
//!   [`crate::obs::metrics`] registry) over [`ServeState`], with
//!   [`ApiError`] → JSON error mapping;
//! * [`session`] — [`SessionStore`]: per-session KV state, exclusive
//!   checkout, LRU eviction cap, resident-KV byte budget;
//! * [`batcher`] — [`DecodeBatcher`]: the continuous-batching decode
//!   scheduler every generate request joins;
//! * [`server`] — the accept loop and worker pool (sized by the
//!   coordinator [`crate::coordinator::Executor`] budget), persistent
//!   connections, structured per-request log lines, graceful
//!   SIGINT/SIGTERM drain.
//!
//! Operational reference — endpoints, JSON schemas, curl quickstart, tier
//! and thread knobs — lives in SERVING.md; the metric inventory, span
//! hierarchy and `--trace-out`/`--log-json` knobs in OBSERVABILITY.md.

pub mod batcher;
pub mod http;
pub mod router;
pub mod server;
pub mod session;

pub use batcher::DecodeBatcher;
pub use http::{HttpError, Request, Response};
pub use router::{generate_stream, handle, ApiError, Route, ServeInfo,
                 ServeLimits, ServeState, StreamOutcome, ROUTES};
pub use server::{install_signal_handlers, shutdown_flag, Server};
pub use session::{ServeSession, SessionStore, StoreFull, TakeError};
