//! Continuous-batching decode scheduler: fuses concurrent requests' decode
//! steps into one [`NativeModel::decode_step_batch`] launch per tick.
//!
//! Each `/v1/generate` request enqueues its session as a *stream*
//! (session + pending token + remaining steps) and then drives the shared
//! queue in a leader/follower discipline: whichever request thread finds no
//! tick in flight elects itself leader, drains up to `max_batch` streams
//! off the queue front — its own and anyone else's — runs **one** batched
//! forward outside the lock, pushes the survivors to the back of the queue
//! and hands leadership on. Followers sleep on the condvar and wake to
//! collect the tokens the tick produced for them. Streams join and leave
//! the batch *between ticks* as requests arrive and complete — continuous
//! batching, not static batches — and round-robin rotation keeps every
//! stream progressing when more than `max_batch` are live.
//!
//! Electing a request thread as leader (instead of parking a dedicated
//! decode thread) keeps the worker-pool thread budget exact, makes the
//! scheduler trivially correct under the server's drain (the last request
//! out finishes its own decode), and lets the router's unit tests exercise
//! the real scheduling path with no thread setup.
//!
//! Because the batched step is bit-identical per session to serial
//! [`crate::infer::NativeModel::decode_step`] at the reference tier (see
//! `infer::model`), scheduling is *invisible* in the output: whatever
//! interleaving the ticks happen to take, every request's generation
//! matches a serial replay of that session alone. The fast tier obeys the
//! usual KERNELS.md tolerance.

use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::eval::argmax;
use crate::infer::{DecodeSession, NativeModel};
use crate::obs::{metrics, trace};

/// One request's decode stream while it sits in the scheduler.
struct Stream {
    id: u64,
    sess: DecodeSession,
    /// Last generated token, not yet appended to the KV cache.
    pending: i32,
    /// Decode steps left (the final step appends `pending` and emits
    /// nothing, so the cache covers every generated token — exactly the
    /// serial loop's contract).
    remaining: usize,
    /// Tokens decoded but not yet collected by the request thread.
    out: Vec<i32>,
    /// Largest tick occupancy this stream rode in.
    occupancy: usize,
    /// Submission time for the queue-wait histogram (`None` when metrics
    /// are disabled — no clock read at all).
    enqueued: Option<Instant>,
}

/// Terminal state of a stream, parked until its request thread collects it.
enum Outcome {
    Finished { sess: Box<DecodeSession>, out: Vec<i32>, occupancy: usize },
    Failed { error: String },
}

struct BatchState {
    next_id: u64,
    /// Live streams in round-robin order (front = next to tick).
    queue: VecDeque<Stream>,
    /// Completed/failed streams keyed by id.
    done: HashMap<u64, Outcome>,
    /// A leader is running a tick outside the lock.
    leading: bool,
    /// Fused forwards run since startup (occupancy telemetry).
    ticks: u64,
    /// Sum of per-tick occupancies (mean occupancy = sum / ticks).
    occupancy_sum: u64,
}

/// The shared decode scheduler. One per [`super::ServeState`]; handlers
/// call [`DecodeBatcher::decode`] and get continuous batching for free.
pub struct DecodeBatcher {
    max_batch: usize,
    inner: Mutex<BatchState>,
    cv: Condvar,
}

impl DecodeBatcher {
    /// `max_batch` bounds how many sessions one fused forward carries
    /// (`--max-batch`; at 1 the scheduler degenerates to serial decode).
    pub fn new(max_batch: usize) -> DecodeBatcher {
        DecodeBatcher {
            max_batch: max_batch.max(1),
            inner: Mutex::new(BatchState {
                next_id: 1,
                queue: VecDeque::new(),
                done: HashMap::new(),
                leading: false,
                ticks: 0,
                occupancy_sum: 0,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// `(ticks, mean occupancy)` since startup.
    pub fn stats(&self) -> (u64, f64) {
        let st = self.inner.lock().unwrap();
        let mean = if st.ticks == 0 {
            0.0
        } else {
            st.occupancy_sum as f64 / st.ticks as f64
        };
        (st.ticks, mean)
    }

    /// Run `steps` greedy decode steps of `sess` through the shared batch
    /// (the caller already appended the prompt via prefill and picked
    /// `first` off the prefill logits). Emits each generated token through
    /// `on_token` as its tick produces it — `steps − 1` tokens, matching
    /// the serial loop, whose last step appends the final token's KV rows
    /// and discards the logits. Returns the session and the largest batch
    /// occupancy any of its ticks reached.
    ///
    /// On `Err` the session is gone — a failed model step leaves the KV
    /// rows inconsistent with the token history (the caller must drop the
    /// store entry), and a failed `on_token` sink means tokens the cache
    /// already covers were never delivered.
    pub fn decode(&self, model: &NativeModel, sess: DecodeSession, first: i32,
                  steps: usize,
                  on_token: &mut dyn FnMut(i32) -> anyhow::Result<()>)
        -> Result<(DecodeSession, usize), String> {
        let id = {
            let mut st = self.inner.lock().unwrap();
            let id = st.next_id;
            st.next_id += 1;
            st.queue.push_back(Stream {
                id,
                sess,
                pending: first,
                remaining: steps.max(1),
                out: Vec::new(),
                occupancy: 0,
                enqueued: metrics::timer(),
            });
            id
        };
        self.cv.notify_all();
        let mut st = self.inner.lock().unwrap();
        loop {
            // deliver tokens already decoded for this stream (streaming
            // callers flush them to the socket outside the lock)
            let waiting: Option<Vec<i32>> = st
                .queue
                .iter_mut()
                .find(|s| s.id == id)
                .filter(|s| !s.out.is_empty())
                .map(|s| std::mem::take(&mut s.out));
            if let Some(tokens) = waiting {
                drop(st);
                for t in tokens {
                    if let Err(e) = on_token(t) {
                        self.abandon(id);
                        return Err(format!("token sink failed: {e:#}"));
                    }
                }
                st = self.inner.lock().unwrap();
                continue;
            }
            if let Some(outcome) = st.done.remove(&id) {
                drop(st);
                return match outcome {
                    Outcome::Finished { sess, out, occupancy } => {
                        for t in out {
                            if let Err(e) = on_token(t) {
                                return Err(format!("token sink failed: {e:#}"));
                            }
                        }
                        Ok((*sess, occupancy))
                    }
                    Outcome::Failed { error } => Err(error),
                };
            }
            if !st.leading && !st.queue.is_empty() {
                // become leader: tick the queue front (which may or may not
                // include this thread's own stream) outside the lock
                st.leading = true;
                let take = st.queue.len().min(self.max_batch);
                let mut batch: Vec<Stream> = st.queue.drain(..take).collect();
                drop(st);
                // queue wait: submission → first tick (occupancy 0 means
                // this stream has never ridden a tick yet)
                let m = &metrics::REGISTRY;
                for s in &batch {
                    if s.occupancy == 0 {
                        if let Some(t0) = s.enqueued {
                            m.queue_wait_seconds
                                .observe(t0.elapsed().as_secs_f64());
                        }
                    }
                }
                let tick_timer = metrics::timer();
                let failure = {
                    let mut span = trace::span("decode_tick", "batch");
                    if trace::enabled() {
                        span.set_arg("occupancy", batch.len().to_string());
                    }
                    tick(model, &mut batch)
                };
                m.decode_tick_seconds.observe_since(tick_timer);
                m.decode_ticks.inc();
                m.batch_occupancy.observe(batch.len() as f64);
                if failure.is_none() {
                    let emitted =
                        batch.iter().filter(|s| s.remaining > 0).count();
                    m.generated_tokens.add(emitted as u64);
                }
                st = self.inner.lock().unwrap();
                st.leading = false;
                st.ticks += 1;
                st.occupancy_sum += batch.len() as u64;
                for s in batch {
                    if let Some(error) = &failure {
                        st.done.insert(s.id, Outcome::Failed {
                            error: error.clone(),
                        });
                    } else if s.remaining == 0 {
                        st.done.insert(s.id, Outcome::Finished {
                            sess: Box::new(s.sess),
                            out: s.out,
                            occupancy: s.occupancy,
                        });
                    } else {
                        st.queue.push_back(s);
                    }
                }
                self.cv.notify_all();
            } else {
                st = self.cv.wait(st).unwrap();
            }
        }
    }

    /// Forget stream `id` after a sink failure: wait until it is back under
    /// the lock (it may be mid-tick) and drop it.
    fn abandon(&self, id: u64) {
        let mut st = self.inner.lock().unwrap();
        loop {
            if st.done.remove(&id).is_some() {
                return;
            }
            if let Some(pos) = st.queue.iter().position(|s| s.id == id) {
                st.queue.remove(pos);
                return;
            }
            st = self.cv.wait(st).unwrap();
        }
    }
}

/// One fused decode step over every stream in `batch`. On success each
/// stream's pending token is appended to its cache and — unless it was the
/// stream's final step — the next greedy token is emitted into its `out`
/// buffer. On failure every rider's session is poisoned (mid-forward state
/// cannot be resumed), so all of them fail together.
fn tick(model: &NativeModel, batch: &mut [Stream]) -> Option<String> {
    let n = batch.len();
    let tokens: Vec<i32> = batch.iter().map(|s| s.pending).collect();
    let mut refs: Vec<&mut DecodeSession> =
        batch.iter_mut().map(|s| &mut s.sess).collect();
    let result = model.decode_step_batch(&mut refs, &tokens);
    drop(refs);
    match result {
        Ok(logits) => {
            for (s, l) in batch.iter_mut().zip(&logits) {
                s.occupancy = s.occupancy.max(n);
                s.remaining -= 1;
                if s.remaining > 0 {
                    let next = argmax(l);
                    s.out.push(next);
                    s.pending = next;
                }
            }
            None
        }
        Err(e) => Some(format!("batched decode step failed: {e:#}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::trainer::init_checkpoint;

    fn model() -> NativeModel {
        let cfg = ModelConfig {
            name: "t".into(), vocab: 32, d_model: 16, n_heads: 2, n_layers: 2,
            d_ff: 24, seq_len: 8, batch: 1, decode_len: 8, rope_theta: 1e4,
        };
        NativeModel::from_checkpoint(&init_checkpoint(&cfg, 41)).unwrap()
    }

    /// Serial replay of the handler's greedy loop: prefill + decode_step.
    fn serial(m: &NativeModel, prompt: &[i32], steps: usize) -> Vec<i32> {
        let mut sess = m.new_session(32);
        let mut logits = m.prefill(&mut sess, prompt).unwrap();
        let mut out = Vec::new();
        for _ in 0..steps {
            let next = argmax(&logits);
            out.push(next);
            logits = m.decode_step(&mut sess, next).unwrap();
        }
        out
    }

    #[test]
    fn single_stream_decode_matches_serial_replay() {
        let m = model();
        let batcher = DecodeBatcher::new(4);
        let prompt = [1i32, 2, 3];
        let steps = 5;
        let mut sess = m.new_session(32);
        let logits = m.prefill(&mut sess, &prompt).unwrap();
        let first = argmax(&logits);
        let mut got = vec![first];
        let (sess, occupancy) = batcher
            .decode(&m, sess, first, steps, &mut |t| {
                got.push(t);
                Ok(())
            })
            .unwrap();
        assert_eq!(got, serial(&m, &prompt, steps));
        assert_eq!(sess.len(), prompt.len() + steps);
        assert_eq!(occupancy, 1);
        let (ticks, mean) = batcher.stats();
        assert_eq!(ticks, steps as u64);
        assert_eq!(mean, 1.0);
    }

    #[test]
    fn concurrent_streams_batch_and_match_serial_replays() {
        let m = model();
        let batcher = DecodeBatcher::new(4);
        let prompts: [&[i32]; 4] = [&[1, 2], &[3], &[4, 5, 6], &[7, 8]];
        let steps = 6;
        let outputs = std::thread::scope(|scope| {
            let handles: Vec<_> = prompts
                .iter()
                .map(|prompt| {
                    let (m, batcher) = (&m, &batcher);
                    scope.spawn(move || {
                        let mut sess = m.new_session(32);
                        let logits = m.prefill(&mut sess, prompt).unwrap();
                        let first = argmax(&logits);
                        let mut got = vec![first];
                        let (sess, occupancy) = batcher
                            .decode(m, sess, first, steps, &mut |t| {
                                got.push(t);
                                Ok(())
                            })
                            .unwrap();
                        assert_eq!(sess.len(), prompt.len() + steps);
                        (got, occupancy)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
        });
        // whatever interleaving the ticks took, every stream's generation
        // is bit-identical to a serial replay of that session alone
        for (prompt, (got, _occ)) in prompts.iter().zip(&outputs) {
            assert_eq!(got, &serial(&m, prompt, steps));
        }
        let (ticks, _mean) = batcher.stats();
        assert!(ticks >= steps as u64, "at least one stream's worth of ticks");
    }

    #[test]
    fn sink_failure_abandons_the_stream() {
        let m = model();
        let batcher = DecodeBatcher::new(2);
        let mut sess = m.new_session(32);
        let logits = m.prefill(&mut sess, &[1, 2]).unwrap();
        let first = argmax(&logits);
        let mut seen = 0usize;
        let err = batcher
            .decode(&m, sess, first, 6, &mut |_t| {
                seen += 1;
                anyhow::ensure!(seen < 2, "client went away");
                Ok(())
            })
            .unwrap_err();
        assert!(err.contains("token sink failed"), "{err}");
        // the scheduler is empty again: a fresh stream still completes
        let mut sess = m.new_session(32);
        let logits = m.prefill(&mut sess, &[3]).unwrap();
        let first = argmax(&logits);
        assert!(batcher.decode(&m, sess, first, 2, &mut |_| Ok(())).is_ok());
    }
}
