//! Triangular solves (forward/back substitution).

use crate::tensor::Matrix;

/// Solve `L·x = b` for lower-triangular `L`.
pub fn solve_lower(l: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut x = vec![0.0f32; n];
    for i in 0..n {
        let mut s = b[i] as f64;
        for j in 0..i {
            s -= (l.at(i, j) as f64) * (x[j] as f64);
        }
        x[i] = (s / l.at(i, i) as f64) as f32;
    }
    x
}

/// Solve `U·x = b` for upper-triangular `U`.
pub fn solve_upper(u: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = u.rows;
    assert_eq!(b.len(), n);
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = b[i] as f64;
        for j in i + 1..n {
            s -= (u.at(i, j) as f64) * (x[j] as f64);
        }
        x[i] = (s / u.at(i, i) as f64) as f32;
    }
    x
}

/// Solve `Lᵀ·x = b` given lower-triangular `L` (without materialising `Lᵀ`).
pub fn solve_upper_transposed(l: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = b[i] as f64;
        for j in i + 1..n {
            // (Lᵀ)[i,j] = L[j,i]
            s -= (l.at(j, i) as f64) * (x[j] as f64);
        }
        x[i] = (s / l.at(i, i) as f64) as f32;
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky;

    #[test]
    fn lower_solve_roundtrip() {
        let c = Matrix::randn_gram(12, 0);
        let l = cholesky(&c).unwrap().l;
        let x_true: Vec<f32> = (0..12).map(|i| (i as f32) * 0.3 - 1.0).collect();
        // b = L x
        let mut b = vec![0.0f32; 12];
        for i in 0..12 {
            for j in 0..=i {
                b[i] += l.at(i, j) * x_true[j];
            }
        }
        let x = solve_lower(&l, &b);
        for (a, t) in x.iter().zip(&x_true) {
            assert!((a - t).abs() < 1e-3);
        }
    }

    #[test]
    fn upper_transposed_matches_explicit_transpose() {
        let c = Matrix::randn_gram(9, 1);
        let l = cholesky(&c).unwrap().l;
        let b: Vec<f32> = (0..9).map(|i| (i as f32).sin()).collect();
        let x1 = solve_upper_transposed(&l, &b);
        let x2 = solve_upper(&l.transpose(), &b);
        for (a, bb) in x1.iter().zip(&x2) {
            assert!((a - bb).abs() < 1e-5);
        }
    }

    #[test]
    fn diagonal_system() {
        let mut d = Matrix::zeros(3, 3);
        *d.at_mut(0, 0) = 2.0;
        *d.at_mut(1, 1) = 4.0;
        *d.at_mut(2, 2) = 8.0;
        let x = solve_lower(&d, &[2.0, 4.0, 8.0]);
        assert_eq!(x, vec![1.0, 1.0, 1.0]);
        let y = solve_upper(&d, &[2.0, 4.0, 8.0]);
        assert_eq!(y, vec![1.0, 1.0, 1.0]);
    }
}
