//! Linear-algebra substrate: Cholesky factorization, triangular solves and
//! SPD inversion.
//!
//! The paper's strongest baselines (SparseGPT, GPTQ, both re-implemented in
//! `compress/`) need the *inverse Hessian* `(C + λI)⁻¹` and its Cholesky
//! factor — the exact computation the paper contrasts AWP against ("more
//! efficient than inverting XXᵀ required in OBC, SparseGPT, GPTQ"). We build
//! it from scratch so the cost comparison in `benches/compression.rs` is
//! apples-to-apples on the same substrate.

pub mod cholesky;
pub mod solve;

pub use cholesky::{cholesky, cholesky_damped, spd_inverse, Cholesky};
pub use solve::{solve_lower, solve_upper};
