//! Cholesky factorization of symmetric positive-definite matrices.

use crate::tensor::Matrix;

/// Lower-triangular Cholesky factor `L` with `L·Lᵀ = A`.
#[derive(Clone, Debug)]
pub struct Cholesky {
    pub l: Matrix,
}

/// Factor an SPD matrix. Returns `None` when a non-positive pivot appears
/// (matrix not positive definite to working precision) — callers then retry
/// with damping via [`cholesky_damped`].
pub fn cholesky(a: &Matrix) -> Option<Cholesky> {
    assert_eq!(a.rows, a.cols, "cholesky needs a square matrix");
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // accumulate in f64: calibration Grams are badly conditioned
            let mut s = a.at(i, j) as f64;
            for k in 0..j {
                s -= (l.at(i, k) as f64) * (l.at(j, k) as f64);
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                *l.at_mut(i, j) = s.sqrt() as f32;
            } else {
                *l.at_mut(i, j) = (s / l.at(j, j) as f64) as f32;
            }
        }
    }
    Some(Cholesky { l })
}

/// Factor `A + λ·mean(diag A)·I`, escalating `λ` by 10× until the
/// factorization succeeds — the same "percent-damping" trick SparseGPT and
/// GPTQ apply to their Hessians. Returns the factor and the λ used.
pub fn cholesky_damped(a: &Matrix, lambda0: f64) -> (Cholesky, f64) {
    let n = a.rows;
    let mean_diag =
        (0..n).map(|i| a.at(i, i) as f64).sum::<f64>() / n as f64;
    let mut lambda = lambda0;
    for _ in 0..24 {
        let mut damped = a.clone();
        let add = (lambda * mean_diag.max(1e-12)) as f32;
        for i in 0..n {
            *damped.at_mut(i, i) += add;
        }
        if let Some(ch) = cholesky(&damped) {
            return (ch, lambda);
        }
        lambda = if lambda == 0.0 { 1e-8 } else { lambda * 10.0 };
    }
    panic!("cholesky_damped failed to stabilise after 24 escalations");
}

/// Inverse of an SPD matrix via Cholesky: `A⁻¹ = L⁻ᵀ·L⁻¹`.
pub fn spd_inverse(a: &Matrix, lambda0: f64) -> Matrix {
    let n = a.rows;
    let (ch, _) = cholesky_damped(a, lambda0);
    // solve L·Y = I column by column, then Lᵀ·X = Y
    let mut inv = Matrix::zeros(n, n);
    for col in 0..n {
        let mut e = vec![0.0f32; n];
        e[col] = 1.0;
        let y = super::solve::solve_lower(&ch.l, &e);
        let x = super::solve::solve_upper_transposed(&ch.l, &y);
        for i in 0..n {
            *inv.at_mut(i, col) = x[i];
        }
    }
    // symmetrise (numerical hygiene)
    for i in 0..n {
        for j in 0..i {
            let v = 0.5 * (inv.at(i, j) + inv.at(j, i));
            *inv.at_mut(i, j) = v;
            *inv.at_mut(j, i) = v;
        }
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul;

    fn assert_close(a: &Matrix, b: &Matrix, tol: f32) {
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol, "{x} vs {y}");
        }
    }

    #[test]
    fn factor_reconstructs() {
        let c = Matrix::randn_gram(24, 0);
        let ch = cholesky(&c).expect("gram is SPD");
        let rec = matmul(&ch.l, &ch.l.transpose());
        assert_close(&rec, &c, 1e-3);
    }

    #[test]
    fn factor_is_lower_triangular() {
        let c = Matrix::randn_gram(8, 1);
        let ch = cholesky(&c).unwrap();
        for i in 0..8 {
            for j in i + 1..8 {
                assert_eq!(ch.l.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = Matrix::eye(3);
        *a.at_mut(2, 2) = -1.0;
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn damping_rescues_singular() {
        // rank-deficient Gram: duplicate dimension
        let mut c = Matrix::randn_gram(6, 2);
        for j in 0..6 {
            let v = c.at(0, j);
            *c.at_mut(1, j) = v;
        }
        for i in 0..6 {
            let v = c.at(i, 0);
            *c.at_mut(i, 1) = v;
        }
        let (ch, lambda) = cholesky_damped(&c, 0.01);
        assert!(lambda >= 0.01);
        assert!(ch.l.at(5, 5).is_finite());
    }

    #[test]
    fn inverse_times_matrix_is_identity() {
        let c = Matrix::randn_gram(16, 3);
        let inv = spd_inverse(&c, 0.0);
        let prod = matmul(&inv, &c);
        let eye = Matrix::eye(16);
        for i in 0..16 {
            for j in 0..16 {
                let tol = if i == j { 2e-2 } else { 2e-2 };
                assert!((prod.at(i, j) - eye.at(i, j)).abs() < tol,
                        "({i},{j}): {}", prod.at(i, j));
            }
        }
    }

    #[test]
    fn inverse_is_symmetric() {
        let c = Matrix::randn_gram(10, 4);
        let inv = spd_inverse(&c, 0.0);
        for i in 0..10 {
            for j in 0..10 {
                assert!((inv.at(i, j) - inv.at(j, i)).abs() < 1e-6);
            }
        }
    }
}
