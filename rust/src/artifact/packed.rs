//! Packed execution — GEMM kernels that consume [`PackedLinear`] weights
//! without ever materialising the dense Θ.
//!
//! Two tiers ([`crate::tensor::KernelTier`]):
//!
//! **Reference tier** (the oracle, bit-identical to dense):
//!
//! * [`PackedLinear::matmul`] — **streaming dequant GEMM**. Decodes one
//!   coefficient row at a time (O(d_in) scratch, never O(d_out·d_in)) and
//!   feeds it through [`ops::matmul_row_panel`] — the *same* inner kernel
//!   the dense [`ops::matmul`] runs — so the result is bit-identical to
//!   `ops::matmul(&packed.decode(), b)` by code sharing, not by tolerance.
//! * [`PackedLinear::matmul_sparse`] — **survivor-only sparse GEMM** for
//!   `SparseMask` sites: iterates the packed mask and accumulates only
//!   surviving weights, skipping pruned groups entirely (the N:M payoff).
//!   Accumulation visits survivors in ascending column order — the same
//!   order the dense kernel adds their products — so it agrees bit-for-bit
//!   with the dense result whenever no accumulator passes through ±0.0
//!   mid-chain (with nonzero survivors that requires exact cancellation;
//!   the packed-exec tests pin equality on random inputs).
//!
//! **Fast tier** (compressed-domain + SIMD, tolerance-validated —
//! KERNELS.md):
//!
//! * `GroupedInt` — **integer-accumulate GEMM**: multiplies activations
//!   against the b-bit codes directly and applies the per-(row, group)
//!   scale/zero-point once per group, using the identity
//!   `Σ_t (q_t−zp)·s·B[t] = s·(Σ_t q_t·B[t] − zp·Σ_t B[t])`; the per-group
//!   activation column sums `Σ_t B[t]` are computed once per launch and
//!   amortised over every output row. No per-element dequant at all.
//! * `SparseMask` — **cache-blocked survivor-only GEMM** over a prepared
//!   CSR view (values + column indices), SIMD 4-survivor panels, output
//!   processed in column blocks so wide activations stay L1/L2-resident.
//! * `Palette`/`Dense` — **LUT-decode + SIMD row panel**: the per-group
//!   table decode is already a LUT gather; the panel switches to
//!   [`simd::row_panel_fast`].
//!
//! Per-launch decode offsets (palette table starts, sparse row starts, the
//! CSR column index list) are precomputed once in [`PreparedPacked`] —
//! [`PackedLinear::prepare`] — so serving does no per-call aux work; the
//! reference-tier entry points on `PackedLinear` itself keep computing aux
//! per call for one-shot users.

use std::cell::RefCell;

use crate::obs::metrics;
use crate::quant::pack::unpack_bits_into;
use crate::tensor::simd::{self, KernelTier};
use crate::tensor::{ops, Matrix};
use crate::util::parallel::par_chunks_mut;

use super::codec::PackedLinear;

thread_local! {
    /// Per-thread decode scratch (dequantized row + unpacked codes), grown
    /// once and reused across rows — the kernels are allocation-free after
    /// warm-up (the repo's usual inner-loop discipline, cf.
    /// `proj::PgdWorkspace`).
    static SCRATCH: RefCell<(Vec<f32>, Vec<u8>)> =
        RefCell::new((Vec::new(), Vec::new()));
    /// Per-thread integer-GEMM scratch: codes as f32, raw codes, and two
    /// per-group accumulators (groups are retired pairwise through the
    /// fused batched rescale epilogue).
    static INT_SCRATCH: RefCell<(Vec<f32>, Vec<u8>, Vec<f32>, Vec<f32>)> =
        RefCell::new((Vec::new(), Vec::new(), Vec::new(), Vec::new()));
}

/// Per-matrix decode offsets computed once per kernel launch (palette
/// tables and sparse values are variable-length, so row starts need a
/// prefix pass).
#[derive(Clone, Debug)]
enum DecodeAux {
    None,
    /// `Palette`: start offset into `values` for each (row, group)
    TableStarts(Vec<usize>),
    /// `SparseMask`: start offset into `values` for each row
    RowStarts(Vec<usize>),
}

impl PackedLinear {
    fn aux(&self) -> DecodeAux {
        match self {
            PackedLinear::Dense { .. } | PackedLinear::GroupedInt { .. } => {
                DecodeAux::None
            }
            PackedLinear::Palette { counts, .. } => {
                let mut starts = Vec::with_capacity(counts.len());
                let mut acc = 0usize;
                for &c in counts {
                    starts.push(acc);
                    acc += c as usize + 1;
                }
                DecodeAux::TableStarts(starts)
            }
            PackedLinear::SparseMask { rows, cols, mask, .. } => {
                let mut starts = Vec::with_capacity(*rows);
                let mut acc = 0usize;
                for i in 0..*rows {
                    starts.push(acc);
                    for idx in i * cols..(i + 1) * cols {
                        acc += (mask[idx / 8] >> (idx % 8) & 1) as usize;
                    }
                }
                DecodeAux::RowStarts(starts)
            }
        }
    }

    /// Decode row `i` into `out` (length `cols`), bit-identical to the
    /// corresponding row of [`PackedLinear::decode`]. `qbuf` is the code
    /// scratch (grown once per thread, reused across rows).
    fn decode_row_into(&self, i: usize, aux: &DecodeAux, qbuf: &mut Vec<u8>,
                       out: &mut [f32]) {
        match (self, aux) {
            (PackedLinear::Dense { cols, data, .. }, _) => {
                out.copy_from_slice(&data[i * cols..(i + 1) * cols]);
            }
            (
                PackedLinear::GroupedInt {
                    cols, bits, group, scales, zps, codes, ..
                },
                _,
            ) => {
                let ng = cols / group;
                qbuf.resize(*cols, 0);
                let q = &mut qbuf[..*cols];
                unpack_bits_into(codes, *bits, i * cols, q);
                for g in 0..ng {
                    let scale = scales[i * ng + g];
                    let zp = zps[i * ng + g];
                    for t in 0..*group {
                        out[g * group + t] = (q[g * group + t] as f32 - zp) * scale;
                    }
                }
            }
            (
                PackedLinear::Palette { cols, bits, group, counts, values, codes, .. },
                DecodeAux::TableStarts(starts),
            ) => {
                let ng = cols / group;
                qbuf.resize(*cols, 0);
                let q = &mut qbuf[..*cols];
                unpack_bits_into(codes, *bits, i * cols, q);
                for g in 0..ng {
                    let start = starts[i * ng + g];
                    let len = counts[i * ng + g] as usize + 1;
                    let table = &values[start..start + len];
                    for t in 0..*group {
                        out[g * group + t] = table[q[g * group + t] as usize];
                    }
                }
            }
            (
                PackedLinear::SparseMask { cols, mask, values, .. },
                DecodeAux::RowStarts(starts),
            ) => {
                out.fill(0.0);
                let mut v = starts[i];
                for t in 0..*cols {
                    let idx = i * cols + t;
                    if mask[idx / 8] >> (idx % 8) & 1 == 1 {
                        out[t] = values[v];
                        v += 1;
                    }
                }
            }
            _ => unreachable!("decode aux does not match the packed variant"),
        }
    }

    /// Streaming dequant GEMM `Θ·B`: bit-identical to
    /// `ops::matmul(&self.decode(), b)` (shared row-panel kernel) with
    /// O(d_in) decode scratch per worker thread instead of a dense Θ.
    /// Computes decode aux per call; serving paths hold a
    /// [`PreparedPacked`] instead.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(
            self.cols(),
            b.rows,
            "packed matmul {}x{} · {}x{}",
            self.rows(),
            self.cols(),
            b.rows,
            b.cols
        );
        let aux = self.aux();
        let mut out = Matrix::zeros(self.rows(), b.cols);
        streaming_matmul_into(self, &aux, b, &mut out);
        out
    }

    /// Survivor-only sparse GEMM for `SparseMask` sites: walks the packed
    /// mask and accumulates surviving weights only — a fully pruned 4-quad
    /// (every aligned group under 2:4) costs nothing, and mixed quads cost
    /// one multiply per survivor instead of four. The quad sums mirror the
    /// dense kernel's `a0·b0 + a1·b1 + a2·b2 + a3·b3` expression with its
    /// zero terms dropped (left-associated in the same column order), which
    /// is what keeps the result bit-identical to the dense GEMM. Panics on
    /// non-mask variants (callers dispatch on [`PackedLinear::mode_name`]).
    pub fn matmul_sparse(&self, b: &Matrix) -> Matrix {
        let PackedLinear::SparseMask { rows, cols, .. } = self else {
            panic!("matmul_sparse needs a SparseMask site, got {}", self.mode_name());
        };
        assert_eq!(*cols, b.rows, "packed sparse matmul dimension mismatch");
        let DecodeAux::RowStarts(starts) = self.aux() else { unreachable!() };
        let mut out = Matrix::zeros(*rows, b.cols);
        sparse_matmul_into(self, &starts, b, &mut out);
        out
    }

    /// Precompute the per-launch decode offsets (and, for masks, the CSR
    /// column index list) once, yielding the serving-ready form every
    /// repeated-matmul consumer should hold.
    pub fn prepare(self) -> PreparedPacked {
        PreparedPacked::new(self)
    }
}

/// Reference streaming-dequant body over precomputed aux; `out` must
/// arrive zeroed at `(rows, b.cols)`.
fn streaming_matmul_into(p: &PackedLinear, aux: &DecodeAux, b: &Matrix,
                         out: &mut Matrix) {
    let (k, n) = (p.cols(), b.cols);
    par_chunks_mut(&mut out.data, n, |i, orow| {
        SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            let (arow, qbuf) = &mut *scratch;
            arow.resize(k, 0.0);
            p.decode_row_into(i, aux, qbuf, &mut arow[..k]);
            ops::matmul_row_panel(&arow[..k], b, orow);
        });
    });
}

/// Reference survivor-only body over precomputed row starts; `out` must
/// arrive zeroed at `(rows, b.cols)`.
fn sparse_matmul_into(p: &PackedLinear, starts: &[usize], b: &Matrix,
                      out: &mut Matrix) {
    let PackedLinear::SparseMask { cols, mask, values, .. } = p else {
        unreachable!()
    };
    let n = b.cols;
    par_chunks_mut(&mut out.data, n, |i, orow| {
        let mut v = starts[i];
        let row_base = i * cols;
        let mut kk = 0usize;
        // 4-quads aligned exactly like the dense kernel's k-unroll
        // (KB = 64 is a multiple of 4, so dense quad boundaries are
        // global multiples of 4 too)
        while kk + 4 <= *cols {
            let mut avs = [0.0f32; 4];
            let mut bcol = [0usize; 4];
            let mut cnt = 0usize;
            for t in 0..4 {
                let idx = row_base + kk + t;
                if mask[idx / 8] >> (idx % 8) & 1 == 1 {
                    avs[cnt] = values[v];
                    bcol[cnt] = kk + t;
                    v += 1;
                    cnt += 1;
                }
            }
            if cnt > 0 {
                for j in 0..n {
                    let mut acc = avs[0] * b.data[bcol[0] * n + j];
                    for s in 1..cnt {
                        acc += avs[s] * b.data[bcol[s] * n + j];
                    }
                    orow[j] += acc;
                }
            }
            kk += 4;
        }
        // tail columns: single adds, like the dense remainder loop
        while kk < *cols {
            let idx = row_base + kk;
            if mask[idx / 8] >> (idx % 8) & 1 == 1 {
                let av = values[v];
                v += 1;
                let brow = &b.data[kk * n..kk * n + n];
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
            kk += 1;
        }
    });
}

/// A [`PackedLinear`] with its per-launch decode state precomputed — the
/// form the serving path ([`crate::infer::LinearOp`]) holds, so repeated
/// matmuls do zero aux work and zero allocations after warm-up.
///
/// Dispatches both kernel tiers: [`KernelTier::Reference`] runs the exact
/// streaming-dequant / survivor-only kernels above (bit-identical to the
/// one-shot `PackedLinear` entry points), [`KernelTier::Fast`] runs the
/// compressed-domain SIMD kernels (tolerance-validated, KERNELS.md).
#[derive(Clone, Debug)]
pub struct PreparedPacked {
    packed: PackedLinear,
    aux: DecodeAux,
    /// `SparseMask` only: survivor column indices aligned with the packed
    /// `values` (the CSR companion the cache-blocked fast kernel walks);
    /// empty for other variants.
    sparse_cols: Vec<u32>,
}

impl PreparedPacked {
    pub fn new(packed: PackedLinear) -> PreparedPacked {
        let aux = packed.aux();
        let sparse_cols = match &packed {
            PackedLinear::SparseMask { rows, cols, mask, values } => {
                let mut sc = Vec::with_capacity(values.len());
                for idx in 0..rows * cols {
                    if mask[idx / 8] >> (idx % 8) & 1 == 1 {
                        sc.push((idx % cols) as u32);
                    }
                }
                debug_assert_eq!(sc.len(), values.len());
                sc
            }
            _ => Vec::new(),
        };
        PreparedPacked { packed, aux, sparse_cols }
    }

    /// The underlying packed payload (for footprint/mode inspection).
    pub fn packed(&self) -> &PackedLinear {
        &self.packed
    }

    pub fn rows(&self) -> usize {
        self.packed.rows()
    }

    pub fn cols(&self) -> usize {
        self.packed.cols()
    }

    pub fn mode_name(&self) -> &'static str {
        self.packed.mode_name()
    }

    /// Approximate heap footprint of this prepared site: the packed
    /// payload plus the precomputed decode aux and CSR companion. The
    /// pager's byte-budgeted eviction charges sites at this size.
    pub fn resident_bytes(&self) -> usize {
        let aux = match &self.aux {
            DecodeAux::None => 0,
            DecodeAux::TableStarts(v) | DecodeAux::RowStarts(v) => {
                v.len() * std::mem::size_of::<usize>()
            }
        };
        self.packed.packed_bytes() + aux + self.sparse_cols.len() * 4
    }

    /// `Θ·B` on the selected tier (allocating form).
    pub fn matmul_tier(&self, b: &Matrix, tier: KernelTier) -> Matrix {
        let mut out = Matrix::zeros(self.rows(), b.cols);
        self.matmul_tier_into(b, tier, &mut out);
        out
    }

    /// `Θ·B` on the selected tier, into a caller-owned buffer (resized and
    /// zeroed via [`Matrix::reset_zeroed`]). Reference tier dispatches
    /// exactly like the one-shot entry points — survivor-only kernel for
    /// masks, streaming dequant otherwise — so its output is bit-identical
    /// to them (and therefore to the dense GEMM on the decoded weights).
    pub fn matmul_tier_into(&self, b: &Matrix, tier: KernelTier,
                            out: &mut Matrix) {
        assert_eq!(
            self.cols(),
            b.rows,
            "packed matmul {}x{} · {}x{}",
            self.rows(),
            self.cols(),
            b.rows,
            b.cols
        );
        out.reset_zeroed(self.rows(), b.cols);
        // kernel-tier busy accounting: every packed launch (both tiers,
        // allocating or into-buffer) funnels through this dispatch
        let t = metrics::timer();
        match (tier, &self.packed) {
            (KernelTier::Reference, PackedLinear::SparseMask { .. }) => {
                let DecodeAux::RowStarts(starts) = &self.aux else {
                    unreachable!()
                };
                sparse_matmul_into(&self.packed, starts, b, out);
            }
            (KernelTier::Reference, _) => {
                streaming_matmul_into(&self.packed, &self.aux, b, out);
            }
            (KernelTier::Fast, PackedLinear::GroupedInt { .. }) => {
                self.int_matmul_fast_into(b, out);
            }
            (KernelTier::Fast, PackedLinear::SparseMask { .. }) => {
                self.sparse_matmul_fast_into(b, out);
            }
            // palette + dense payloads: LUT/copy row decode, SIMD panel
            (KernelTier::Fast, _) => self.decode_matmul_fast_into(b, out),
        }
        metrics::observe_kernel(matches!(tier, KernelTier::Fast), t);
    }

    /// Fast integer-accumulate GEMM for `GroupedInt`: per output row,
    /// accumulate raw codes against B one group at a time
    /// (`gacc = Σ_t q_t·B[t]`), then fold in scale and zero-point once per
    /// group: `orow += s·gacc − s·zp·colsum_g`. The per-group activation
    /// column sums `colsum_g = Σ_{t∈g} B[t]` cost one pass over B and are
    /// shared by all `rows` output rows — work that amortises over however
    /// many activation columns (a decode batch of sessions) ride through
    /// one launch. Groups retire pairwise through the fused
    /// [`simd::rescale_add2_fast`] epilogue, halving the output-row
    /// read/write traffic that dominates the epilogue at wide batch
    /// widths; the fused pass is bit-identical to two unfused ones. The
    /// flat-group encoding (scale = v, zp = −1, codes = 0) falls out
    /// correctly: `s·(0 − (−1)·colsum) = v·colsum`.
    fn int_matmul_fast_into(&self, b: &Matrix, out: &mut Matrix) {
        let PackedLinear::GroupedInt { cols, bits, group, scales, zps, codes, .. } =
            &self.packed
        else {
            unreachable!()
        };
        let (k, n) = (*cols, b.cols);
        let ng = k / group;
        let mut colsum = Matrix::zeros(ng, n);
        par_chunks_mut(&mut colsum.data, n, |g, srow| {
            for t in 0..*group {
                let base = (g * group + t) * n;
                simd::add_assign_fast(srow, &b.data[base..base + n]);
            }
        });
        par_chunks_mut(&mut out.data, n, |i, orow| {
            INT_SCRATCH.with(|cell| {
                let mut scratch = cell.borrow_mut();
                let (qf, qbuf, gacc_a, gacc_b) = &mut *scratch;
                qbuf.resize(k, 0);
                unpack_bits_into(codes, *bits, i * k, &mut qbuf[..k]);
                qf.resize(k, 0.0);
                for t in 0..k {
                    qf[t] = qbuf[t] as f32;
                }
                gacc_a.resize(n, 0.0);
                gacc_b.resize(n, 0.0);
                let accumulate = |g: usize, gacc: &mut [f32]| {
                    gacc.fill(0.0);
                    simd::row_panel_fast(&qf[g * group..(g + 1) * group],
                                         &b.data[g * group * n..(g + 1) * group * n],
                                         n, gacc);
                };
                let mut g = 0usize;
                while g + 2 <= ng {
                    accumulate(g, &mut gacc_a[..n]);
                    accumulate(g + 1, &mut gacc_b[..n]);
                    let sa = scales[i * ng + g];
                    let sb = scales[i * ng + g + 1];
                    simd::rescale_add2_fast(
                        orow,
                        &gacc_a[..n], &colsum.data[g * n..(g + 1) * n],
                        sa, sa * zps[i * ng + g],
                        &gacc_b[..n], &colsum.data[(g + 1) * n..(g + 2) * n],
                        sb, sb * zps[i * ng + g + 1],
                    );
                    g += 2;
                }
                if g < ng {
                    accumulate(g, &mut gacc_a[..n]);
                    let s = scales[i * ng + g];
                    simd::rescale_add_fast(orow, &gacc_a[..n],
                                           &colsum.data[g * n..(g + 1) * n],
                                           s, s * zps[i * ng + g]);
                }
            });
        });
    }

    /// Fast cache-blocked survivor-only GEMM for `SparseMask`: walks the
    /// prepared CSR view (values + column indices — no mask bit tests on
    /// the hot path) in SIMD 4-survivor panels, processing the output row
    /// in column blocks so the active orow slice and its B-row slices stay
    /// cache-resident even for wide activations.
    fn sparse_matmul_fast_into(&self, b: &Matrix, out: &mut Matrix) {
        let PackedLinear::SparseMask { rows, values, .. } = &self.packed else {
            unreachable!()
        };
        let DecodeAux::RowStarts(starts) = &self.aux else { unreachable!() };
        let n = b.cols;
        const JB: usize = 512; // output-column block (KERNELS.md)
        par_chunks_mut(&mut out.data, n, |i, orow| {
            let v0 = starts[i];
            let v1 = if i + 1 < *rows { starts[i + 1] } else { values.len() };
            let vals = &values[v0..v1];
            let cls = &self.sparse_cols[v0..v1];
            let mut jb = 0usize;
            while jb < n {
                let je = (jb + JB).min(n);
                let ob = &mut orow[jb..je];
                let brow = |c: u32| {
                    let base = c as usize * n;
                    &b.data[base + jb..base + je]
                };
                let mut t = 0usize;
                while t + 4 <= vals.len() {
                    simd::panel4_fast(
                        [vals[t], vals[t + 1], vals[t + 2], vals[t + 3]],
                        brow(cls[t]), brow(cls[t + 1]), brow(cls[t + 2]),
                        brow(cls[t + 3]), ob,
                    );
                    t += 4;
                }
                while t < vals.len() {
                    simd::axpy_fast(vals[t], brow(cls[t]), ob);
                    t += 1;
                }
                jb = je;
            }
        });
    }

    /// Fast path for `Palette` (LUT gather decode) and `Dense` (row copy)
    /// payloads: decode one row, run the SIMD panel over it.
    fn decode_matmul_fast_into(&self, b: &Matrix, out: &mut Matrix) {
        let (k, n) = (self.cols(), b.cols);
        par_chunks_mut(&mut out.data, n, |i, orow| {
            SCRATCH.with(|cell| {
                let mut scratch = cell.borrow_mut();
                let (arow, qbuf) = &mut *scratch;
                arow.resize(k, 0.0);
                self.packed.decode_row_into(i, &self.aux, qbuf, &mut arow[..k]);
                simd::row_panel_fast(&arow[..k], &b.data, n, orow);
            });
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::traits::CompressionSpec;
    use crate::proj::{NmStructured, ProjScratch, Projection};
    use crate::quant::project_qmax;

    fn assert_bits_eq(a: &Matrix, b: &Matrix) {
        assert_eq!(a.shape(), b.shape());
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "entry {i}: {x} vs {y}");
        }
    }

    fn assert_close(a: &Matrix, b: &Matrix, what: &str) {
        assert_eq!(a.shape(), b.shape(), "{what}");
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            let tol = 1e-4 * (1.0 + x.abs() + y.abs());
            assert!((x - y).abs() <= tol, "{what} entry {i}: {x} vs {y}");
        }
    }

    #[test]
    fn streaming_matmul_is_bit_identical_for_every_mode() {
        let b = Matrix::randn(64, 24, 100);
        // grouped-int site
        let q = project_qmax(&Matrix::randn(8, 64, 0), 15.0, 32);
        let p = PackedLinear::encode(&q, &CompressionSpec::quant(4, 32));
        assert_eq!(p.mode_name(), "int");
        assert_bits_eq(&p.matmul(&b), &ops::matmul(&p.decode(), &b));
        // mask site
        let mut nm = Matrix::randn(8, 64, 1);
        NmStructured::new(2, 4).project_rows(&mut nm, &mut ProjScratch::new());
        let p = PackedLinear::encode(&nm, &CompressionSpec::structured_nm(2, 4));
        assert_eq!(p.mode_name(), "mask");
        assert_bits_eq(&p.matmul(&b), &ops::matmul(&p.decode(), &b));
        // dense fallback site
        let d = Matrix::randn(8, 64, 2);
        let p = PackedLinear::encode(&d, &CompressionSpec::quant(4, 32));
        assert_eq!(p.mode_name(), "dense");
        assert_bits_eq(&p.matmul(&b), &ops::matmul(&d, &b));
    }

    #[test]
    fn sparse_kernel_matches_dense_matmul() {
        let b = Matrix::randn(64, 16, 200);
        for seed in 0..4u64 {
            let mut nm = Matrix::randn(6, 64, seed);
            NmStructured::new(2, 4).project_rows(&mut nm, &mut ProjScratch::new());
            let p = PackedLinear::encode(&nm, &CompressionSpec::structured_nm(2, 4));
            assert_bits_eq(&p.matmul_sparse(&b), &ops::matmul(&nm, &b));
        }
    }

    #[test]
    #[should_panic(expected = "needs a SparseMask")]
    fn sparse_kernel_rejects_other_modes() {
        let q = project_qmax(&Matrix::randn(2, 32, 0), 15.0, 32);
        let p = PackedLinear::encode(&q, &CompressionSpec::quant(4, 32));
        p.matmul_sparse(&Matrix::randn(32, 4, 1));
    }

    #[test]
    fn palette_rows_decode_identically() {
        let theta = Matrix::from_fn(3, 32, |i, j| match (i + j) % 3 {
            0 => 0.25,
            1 => -1.5,
            _ => 3.0,
        });
        let p = PackedLinear::encode(&theta, &CompressionSpec::quant(2, 16));
        assert_eq!(p.mode_name(), "palette");
        let full = p.decode();
        assert_bits_eq(&full, &theta);
        let b = Matrix::randn(32, 8, 5);
        assert_bits_eq(&p.matmul(&b), &ops::matmul(&theta, &b));
    }

    #[test]
    fn prepared_reference_tier_is_bitwise_one_shot() {
        // cached aux must not change a single bit on the reference tier
        let b = Matrix::randn(64, 9, 300);
        let q = project_qmax(&Matrix::randn(5, 64, 10), 15.0, 32);
        let p = PackedLinear::encode(&q, &CompressionSpec::quant(4, 32));
        let want = p.matmul(&b);
        let prep = p.prepare();
        assert_bits_eq(&prep.matmul_tier(&b, KernelTier::Reference), &want);
        let mut nm = Matrix::randn(5, 64, 11);
        NmStructured::new(2, 4).project_rows(&mut nm, &mut ProjScratch::new());
        let p = PackedLinear::encode(&nm, &CompressionSpec::structured_nm(2, 4));
        let want = p.matmul_sparse(&b);
        let prep = p.prepare();
        assert_bits_eq(&prep.matmul_tier(&b, KernelTier::Reference), &want);
    }

    #[test]
    fn fast_int_gemm_matches_reference_within_tol() {
        for (rows, cols, group, n) in
            [(8usize, 64usize, 32usize, 24usize), (5, 96, 32, 7), (3, 32, 32, 17)]
        {
            let q = project_qmax(&Matrix::randn(rows, cols, n as u64), 15.0, group);
            let p = PackedLinear::encode(&q, &CompressionSpec::quant(4, group));
            assert_eq!(p.mode_name(), "int");
            let b = Matrix::randn(cols, n, (rows + n) as u64);
            let prep = p.prepare();
            assert_close(&prep.matmul_tier(&b, KernelTier::Fast),
                         &prep.matmul_tier(&b, KernelTier::Reference),
                         &format!("int {rows}x{cols} g{group} n{n}"));
        }
    }

    #[test]
    fn fast_int_gemm_handles_flat_groups() {
        // group-constant values encode as (scale = v, zp = −1, codes = 0);
        // the zp-correction identity must reproduce v·colsum exactly-ish
        let theta = Matrix::from_fn(4, 64, |i, j| (i as f32) - (j / 32) as f32 * 0.5);
        let p = PackedLinear::encode(&theta, &CompressionSpec::quant(4, 32));
        assert_eq!(p.mode_name(), "int");
        let b = Matrix::randn(64, 13, 77);
        let prep = p.prepare();
        assert_close(&prep.matmul_tier(&b, KernelTier::Fast),
                     &ops::matmul(&theta, &b), "flat groups");
    }

    #[test]
    fn fast_sparse_gemm_matches_reference_within_tol() {
        // quad tail (cols % 4 != 0) and a column count above the JB block
        for (rows, cols, n) in [(6usize, 64usize, 16usize), (3, 30, 520), (4, 64, 1)] {
            let mut w = Matrix::randn(rows, cols, (cols + n) as u64);
            if cols % 4 == 0 {
                NmStructured::new(2, 4).project_rows(&mut w, &mut ProjScratch::new());
                let p = PackedLinear::encode(&w, &CompressionSpec::structured_nm(2, 4));
                assert_eq!(p.mode_name(), "mask");
                let b = Matrix::randn(cols, n, rows as u64);
                let prep = p.prepare();
                assert_close(&prep.matmul_tier(&b, KernelTier::Fast),
                             &prep.matmul_tier(&b, KernelTier::Reference),
                             &format!("nm mask {rows}x{cols} n{n}"));
            } else {
                // unstructured zeros with a ragged tail
                for (i, v) in w.data.iter_mut().enumerate() {
                    if i % 3 == 0 {
                        *v = 0.0;
                    }
                }
                let p = PackedLinear::encode(&w, &CompressionSpec::prune(0.3));
                assert_eq!(p.mode_name(), "mask");
                let b = Matrix::randn(cols, n, rows as u64);
                let prep = p.prepare();
                assert_close(&prep.matmul_tier(&b, KernelTier::Fast),
                             &prep.matmul_tier(&b, KernelTier::Reference),
                             &format!("ragged mask {rows}x{cols} n{n}"));
            }
        }
    }

    #[test]
    fn fast_palette_and_dense_match_reference_within_tol() {
        let theta = Matrix::from_fn(3, 32, |i, j| match (i + j) % 3 {
            0 => 0.25,
            1 => -1.5,
            _ => 3.0,
        });
        let p = PackedLinear::encode(&theta, &CompressionSpec::quant(2, 16));
        assert_eq!(p.mode_name(), "palette");
        let b = Matrix::randn(32, 11, 8);
        let prep = p.prepare();
        assert_close(&prep.matmul_tier(&b, KernelTier::Fast),
                     &prep.matmul_tier(&b, KernelTier::Reference), "palette");
        let d = Matrix::randn(6, 33, 9); // odd k: quad + lane tails
        let p = PackedLinear::encode(&d, &CompressionSpec::quant(4, 32));
        assert_eq!(p.mode_name(), "dense");
        let b = Matrix::randn(33, 10, 12);
        let prep = p.prepare();
        assert_close(&prep.matmul_tier(&b, KernelTier::Fast),
                     &prep.matmul_tier(&b, KernelTier::Reference), "dense");
    }

    #[test]
    fn fast_tier_is_thread_count_invariant() {
        use crate::util::parallel::with_thread_budget;
        let q = project_qmax(&Matrix::randn(8, 64, 21), 15.0, 32);
        let p = PackedLinear::encode(&q, &CompressionSpec::quant(4, 32)).prepare();
        let b = Matrix::randn(64, 24, 22);
        let one = with_thread_budget(1, || p.matmul_tier(&b, KernelTier::Fast));
        let four = with_thread_budget(4, || p.matmul_tier(&b, KernelTier::Fast));
        assert_bits_eq(&one, &four);
    }
}
