//! Packed execution — GEMM kernels that consume [`PackedLinear`] weights
//! without ever materialising the dense Θ.
//!
//! Two kernel families:
//!
//! * [`PackedLinear::matmul`] — **streaming dequant GEMM**. Decodes one
//!   coefficient row at a time (O(d_in) scratch, never O(d_out·d_in)) and
//!   feeds it through [`ops::matmul_row_panel`] — the *same* inner kernel
//!   the dense [`ops::matmul`] runs — so the result is bit-identical to
//!   `ops::matmul(&packed.decode(), b)` by code sharing, not by tolerance.
//! * [`PackedLinear::matmul_sparse`] — **survivor-only sparse GEMM** for
//!   `SparseMask` sites: iterates the packed mask and accumulates only
//!   surviving weights, skipping pruned groups entirely (the N:M payoff).
//!   Accumulation visits survivors in ascending column order — the same
//!   order the dense kernel adds their products — so it agrees bit-for-bit
//!   with the dense result whenever no accumulator passes through ±0.0
//!   mid-chain (with nonzero survivors that requires exact cancellation;
//!   the packed-exec tests pin equality on random inputs).

use crate::quant::pack::unpack_bits_into;
use crate::tensor::{ops, Matrix};
use crate::util::parallel::par_chunks_mut;

use super::codec::PackedLinear;

/// Per-matrix decode offsets computed once per kernel launch (palette
/// tables and sparse values are variable-length, so row starts need a
/// prefix pass).
enum DecodeAux {
    None,
    /// `Palette`: start offset into `values` for each (row, group)
    TableStarts(Vec<usize>),
    /// `SparseMask`: start offset into `values` for each row
    RowStarts(Vec<usize>),
}

impl PackedLinear {
    fn aux(&self) -> DecodeAux {
        match self {
            PackedLinear::Dense { .. } | PackedLinear::GroupedInt { .. } => {
                DecodeAux::None
            }
            PackedLinear::Palette { counts, .. } => {
                let mut starts = Vec::with_capacity(counts.len());
                let mut acc = 0usize;
                for &c in counts {
                    starts.push(acc);
                    acc += c as usize + 1;
                }
                DecodeAux::TableStarts(starts)
            }
            PackedLinear::SparseMask { rows, cols, mask, .. } => {
                let mut starts = Vec::with_capacity(*rows);
                let mut acc = 0usize;
                for i in 0..*rows {
                    starts.push(acc);
                    for idx in i * cols..(i + 1) * cols {
                        acc += (mask[idx / 8] >> (idx % 8) & 1) as usize;
                    }
                }
                DecodeAux::RowStarts(starts)
            }
        }
    }

    /// Decode row `i` into `out` (length `cols`), bit-identical to the
    /// corresponding row of [`PackedLinear::decode`]. `qbuf` is the code
    /// scratch (grown once per thread, reused across rows).
    fn decode_row_into(&self, i: usize, aux: &DecodeAux, qbuf: &mut Vec<u8>,
                       out: &mut [f32]) {
        match (self, aux) {
            (PackedLinear::Dense { cols, data, .. }, _) => {
                out.copy_from_slice(&data[i * cols..(i + 1) * cols]);
            }
            (
                PackedLinear::GroupedInt {
                    cols, bits, group, scales, zps, codes, ..
                },
                _,
            ) => {
                let ng = cols / group;
                qbuf.resize(*cols, 0);
                let q = &mut qbuf[..*cols];
                unpack_bits_into(codes, *bits, i * cols, q);
                for g in 0..ng {
                    let scale = scales[i * ng + g];
                    let zp = zps[i * ng + g];
                    for t in 0..*group {
                        out[g * group + t] = (q[g * group + t] as f32 - zp) * scale;
                    }
                }
            }
            (
                PackedLinear::Palette { cols, bits, group, counts, values, codes, .. },
                DecodeAux::TableStarts(starts),
            ) => {
                let ng = cols / group;
                qbuf.resize(*cols, 0);
                let q = &mut qbuf[..*cols];
                unpack_bits_into(codes, *bits, i * cols, q);
                for g in 0..ng {
                    let start = starts[i * ng + g];
                    let len = counts[i * ng + g] as usize + 1;
                    let table = &values[start..start + len];
                    for t in 0..*group {
                        out[g * group + t] = table[q[g * group + t] as usize];
                    }
                }
            }
            (
                PackedLinear::SparseMask { cols, mask, values, .. },
                DecodeAux::RowStarts(starts),
            ) => {
                out.fill(0.0);
                let mut v = starts[i];
                for t in 0..*cols {
                    let idx = i * cols + t;
                    if mask[idx / 8] >> (idx % 8) & 1 == 1 {
                        out[t] = values[v];
                        v += 1;
                    }
                }
            }
            _ => unreachable!("decode aux does not match the packed variant"),
        }
    }

    /// Streaming dequant GEMM `Θ·B`: bit-identical to
    /// `ops::matmul(&self.decode(), b)` (shared row-panel kernel) with
    /// O(d_in) decode scratch per worker thread instead of a dense Θ —
    /// the scratch lives in a thread-local and grows once, so the row
    /// loop is allocation-free after warm-up (the repo's usual inner-loop
    /// discipline, cf. `proj::PgdWorkspace`).
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        use std::cell::RefCell;
        thread_local! {
            static SCRATCH: RefCell<(Vec<f32>, Vec<u8>)> =
                RefCell::new((Vec::new(), Vec::new()));
        }
        assert_eq!(
            self.cols(),
            b.rows,
            "packed matmul {}x{} · {}x{}",
            self.rows(),
            self.cols(),
            b.rows,
            b.cols
        );
        let (k, n) = (self.cols(), b.cols);
        let aux = self.aux();
        let mut out = Matrix::zeros(self.rows(), n);
        par_chunks_mut(&mut out.data, n, |i, orow| {
            SCRATCH.with(|cell| {
                let mut scratch = cell.borrow_mut();
                let (arow, qbuf) = &mut *scratch;
                arow.resize(k, 0.0);
                self.decode_row_into(i, &aux, qbuf, &mut arow[..k]);
                ops::matmul_row_panel(&arow[..k], b, orow);
            });
        });
        out
    }

    /// Survivor-only sparse GEMM for `SparseMask` sites: walks the packed
    /// mask and accumulates surviving weights only — a fully pruned 4-quad
    /// (every aligned group under 2:4) costs nothing, and mixed quads cost
    /// one multiply per survivor instead of four. The quad sums mirror the
    /// dense kernel's `a0·b0 + a1·b1 + a2·b2 + a3·b3` expression with its
    /// zero terms dropped (left-associated in the same column order), which
    /// is what keeps the result bit-identical to the dense GEMM. Panics on
    /// non-mask variants (callers dispatch on [`PackedLinear::mode_name`]).
    pub fn matmul_sparse(&self, b: &Matrix) -> Matrix {
        let PackedLinear::SparseMask { rows, cols, mask, values } = self else {
            panic!("matmul_sparse needs a SparseMask site, got {}", self.mode_name());
        };
        assert_eq!(*cols, b.rows, "packed sparse matmul dimension mismatch");
        let n = b.cols;
        let DecodeAux::RowStarts(starts) = self.aux() else { unreachable!() };
        let mut out = Matrix::zeros(*rows, n);
        par_chunks_mut(&mut out.data, n, |i, orow| {
            let mut v = starts[i];
            let row_base = i * cols;
            let mut kk = 0usize;
            // 4-quads aligned exactly like the dense kernel's k-unroll
            // (KB = 64 is a multiple of 4, so dense quad boundaries are
            // global multiples of 4 too)
            while kk + 4 <= *cols {
                let mut avs = [0.0f32; 4];
                let mut bcol = [0usize; 4];
                let mut cnt = 0usize;
                for t in 0..4 {
                    let idx = row_base + kk + t;
                    if mask[idx / 8] >> (idx % 8) & 1 == 1 {
                        avs[cnt] = values[v];
                        bcol[cnt] = kk + t;
                        v += 1;
                        cnt += 1;
                    }
                }
                if cnt > 0 {
                    for j in 0..n {
                        let mut acc = avs[0] * b.data[bcol[0] * n + j];
                        for s in 1..cnt {
                            acc += avs[s] * b.data[bcol[s] * n + j];
                        }
                        orow[j] += acc;
                    }
                }
                kk += 4;
            }
            // tail columns: single adds, like the dense remainder loop
            while kk < *cols {
                let idx = row_base + kk;
                if mask[idx / 8] >> (idx % 8) & 1 == 1 {
                    let av = values[v];
                    v += 1;
                    let brow = &b.data[kk * n..kk * n + n];
                    for j in 0..n {
                        orow[j] += av * brow[j];
                    }
                }
                kk += 1;
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::traits::CompressionSpec;
    use crate::proj::{NmStructured, ProjScratch, Projection};
    use crate::quant::project_qmax;

    fn assert_bits_eq(a: &Matrix, b: &Matrix) {
        assert_eq!(a.shape(), b.shape());
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "entry {i}: {x} vs {y}");
        }
    }

    #[test]
    fn streaming_matmul_is_bit_identical_for_every_mode() {
        let b = Matrix::randn(64, 24, 100);
        // grouped-int site
        let q = project_qmax(&Matrix::randn(8, 64, 0), 15.0, 32);
        let p = PackedLinear::encode(&q, &CompressionSpec::quant(4, 32));
        assert_eq!(p.mode_name(), "int");
        assert_bits_eq(&p.matmul(&b), &ops::matmul(&p.decode(), &b));
        // mask site
        let mut nm = Matrix::randn(8, 64, 1);
        NmStructured::new(2, 4).project_rows(&mut nm, &mut ProjScratch::new());
        let p = PackedLinear::encode(&nm, &CompressionSpec::structured_nm(2, 4));
        assert_eq!(p.mode_name(), "mask");
        assert_bits_eq(&p.matmul(&b), &ops::matmul(&p.decode(), &b));
        // dense fallback site
        let d = Matrix::randn(8, 64, 2);
        let p = PackedLinear::encode(&d, &CompressionSpec::quant(4, 32));
        assert_eq!(p.mode_name(), "dense");
        assert_bits_eq(&p.matmul(&b), &ops::matmul(&d, &b));
    }

    #[test]
    fn sparse_kernel_matches_dense_matmul() {
        let b = Matrix::randn(64, 16, 200);
        for seed in 0..4u64 {
            let mut nm = Matrix::randn(6, 64, seed);
            NmStructured::new(2, 4).project_rows(&mut nm, &mut ProjScratch::new());
            let p = PackedLinear::encode(&nm, &CompressionSpec::structured_nm(2, 4));
            assert_bits_eq(&p.matmul_sparse(&b), &ops::matmul(&nm, &b));
        }
    }

    #[test]
    #[should_panic(expected = "needs a SparseMask")]
    fn sparse_kernel_rejects_other_modes() {
        let q = project_qmax(&Matrix::randn(2, 32, 0), 15.0, 32);
        let p = PackedLinear::encode(&q, &CompressionSpec::quant(4, 32));
        p.matmul_sparse(&Matrix::randn(32, 4, 1));
    }

    #[test]
    fn palette_rows_decode_identically() {
        let theta = Matrix::from_fn(3, 32, |i, j| match (i + j) % 3 {
            0 => 0.25,
            1 => -1.5,
            _ => 3.0,
        });
        let p = PackedLinear::encode(&theta, &CompressionSpec::quant(2, 16));
        assert_eq!(p.mode_name(), "palette");
        let full = p.decode();
        assert_bits_eq(&full, &theta);
        let b = Matrix::randn(32, 8, 5);
        assert_bits_eq(&p.matmul(&b), &ops::matmul(&theta, &b));
    }
}
