//! The model-weight pager: a `Reader`-backed lazy view of an artifact
//! file that makes serving independent of artifact size.
//!
//! [`ArtifactPager::open`] reads **only the container header** — magic,
//! header length, header JSON — and nothing of the payload. Every site is
//! then an offset-addressed byte range ([`SiteMeta`]): first touch seeks
//! to `payload_start + offset`, reads exactly `stored_len` bytes into a
//! reused buffer, range-decodes transparently for `AWPPACK2` `rc` sites,
//! runs the structural validation the eager loader used to do up front
//! ([`decode_site_bytes`] — palette code bounds, mask popcounts,
//! allocation-free via the pager's scratch), and materialises a
//! [`PreparedPacked`] ready for both kernel tiers. Later touches are
//! cache hits handing out the same `Arc`.
//!
//! With a byte budget (`--weight-budget-mb`) the pager LRU-evicts
//! resident sites once the prepared footprint exceeds it, so `repro
//! serve` / `eval --from-artifact` can run models whose packed form is
//! larger than RAM. The just-touched site is never the victim — a single
//! site larger than the whole budget stays resident while in use. Without
//! a budget the pager is simply a lazy loader: cold start pays one site,
//! not O(model).
//!
//! Identity and shape validation stay eager: the header carries every
//! identity field and each site's shape, so [`crate::infer::NativeModel`]
//! can wire a full model from metadata alone — weights follow on demand.
//! Corrupt payload bytes surface as a clean `Err` on the *request* that
//! first touches the damaged site; intact sites keep serving.

use std::fs::File;
use std::io::{BufReader, Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::obs::metrics::REGISTRY;

use super::packed::PreparedPacked;
use super::store::{decode_site_bytes, read_artifact_header, ArtifactHeader,
                   SiteEnc, SiteMeta};

/// Hit/miss/eviction counters (snapshot of [`ArtifactPager::counts`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PagerCounts {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// Mutable paging state — one lock around the file handle, the residency
/// table and the reusable page-in buffers. Weight materialisation is
/// rare (misses only); the hot path is a lock, a table lookup and an
/// `Arc` clone.
struct PagerState {
    file: File,
    resident: Vec<Option<Arc<PreparedPacked>>>,
    /// LRU stamps, parallel to `resident` (0 = never touched)
    stamp: Vec<u64>,
    tick: u64,
    resident_bytes: usize,
    /// stored-byte read buffer (reused across page-ins)
    stored: Vec<u8>,
    /// range-decode output buffer (reused, `rc` sites only)
    raw: Vec<u8>,
    /// structural-validation scratch handed to [`decode_site_bytes`]
    scratch: Vec<u8>,
}

/// A lazily-paged artifact: header eagerly parsed, sites materialised on
/// first touch, optionally evicted under a byte budget. Cheap to share —
/// serving holds one behind an `Arc` and resolves sites per request.
pub struct ArtifactPager {
    path: PathBuf,
    header: ArtifactHeader,
    /// eviction budget over [`PreparedPacked::resident_bytes`] (`None` =
    /// never evict: plain lazy loading)
    budget: Option<usize>,
    state: Mutex<PagerState>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl ArtifactPager {
    /// Open `path`, reading and validating **only the header**. No
    /// payload byte is read until a site is touched. `budget_bytes`
    /// bounds the total prepared-site footprint (`None` = unbounded).
    pub fn open(path: &Path, budget_bytes: Option<usize>) -> Result<ArtifactPager> {
        let file = File::open(path).with_context(|| format!("open {path:?}"))?;
        // buffer only the header parse: the File (not the BufReader) is
        // kept, so no payload readahead can happen behind our back
        let mut reader = BufReader::new(file);
        let header = read_artifact_header(&mut reader, path)?;
        let file = reader.into_inner();
        let nsites = header.sites.len();
        Ok(ArtifactPager {
            path: path.to_path_buf(),
            header,
            budget: budget_bytes,
            state: Mutex::new(PagerState {
                file,
                resident: vec![None; nsites],
                stamp: vec![0; nsites],
                tick: 0,
                resident_bytes: 0,
                stored: Vec::new(),
                raw: Vec::new(),
                scratch: Vec::new(),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        })
    }

    /// The parsed header (identity fields, site shapes, footprints).
    pub fn header(&self) -> &ArtifactHeader {
        &self.header
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Per-site metadata, index-aligned with [`ArtifactPager::site`].
    pub fn sites(&self) -> &[SiteMeta] {
        &self.header.sites
    }

    pub fn site_count(&self) -> usize {
        self.header.sites.len()
    }

    /// Raw packed payload bytes across all sites (header arithmetic —
    /// equals [`super::ModelArtifact::packed_bytes`] for the same file).
    pub fn packed_bytes(&self) -> usize {
        self.header.packed_bytes()
    }

    /// Dense f32 bytes for the same sites (header arithmetic).
    pub fn dense_bytes(&self) -> usize {
        self.header.dense_bytes()
    }

    /// Current prepared-site footprint charged against the budget.
    pub fn resident_bytes(&self) -> usize {
        self.state.lock().unwrap().resident_bytes
    }

    /// Number of currently resident (materialised) sites.
    pub fn resident_sites(&self) -> usize {
        self.state.lock().unwrap().resident.iter().flatten().count()
    }

    pub fn counts(&self) -> PagerCounts {
        PagerCounts {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// Resolve site `idx`: hand out the resident `Arc`, or page the site
    /// in — seek, bounded read, transparent range-decode, first-touch
    /// structural validation, prepare — then LRU-evict down to the
    /// budget (never the site just touched).
    pub fn site(&self, idx: usize) -> Result<Arc<PreparedPacked>> {
        let meta = &self.header.sites[idx];
        let mut st = self.state.lock().unwrap();
        st.tick += 1;
        let tick = st.tick;
        if let Some(p) = &st.resident[idx] {
            let p = p.clone();
            st.stamp[idx] = tick;
            self.hits.fetch_add(1, Ordering::Relaxed);
            REGISTRY.pager_hits.inc();
            return Ok(p);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        REGISTRY.pager_misses.inc();

        let start = self.header.payload_start + meta.offset as u64;
        let PagerState { file, stored, raw, scratch, .. } = &mut *st;
        file.seek(SeekFrom::Start(start))
            .with_context(|| format!("{:?}: seeking site {}", self.path, meta.param))?;
        stored.resize(meta.stored_len, 0);
        file.read_exact(stored).with_context(|| {
            format!("{:?}: {}: reading {} stored bytes at {start}",
                    self.path, meta.param, meta.stored_len)
        })?;
        let bytes: &[u8] = match meta.enc {
            SiteEnc::Raw => stored,
            SiteEnc::Rc => {
                super::pack2::rc_decode_into(stored, meta.raw_len, raw);
                raw
            }
        };
        let packed = decode_site_bytes(meta, bytes, scratch)
            .with_context(|| format!("{:?}: paging in {}", self.path, meta.param))?;
        let prepared = Arc::new(packed.prepare());

        st.resident_bytes += prepared.resident_bytes();
        st.resident[idx] = Some(prepared.clone());
        st.stamp[idx] = tick;
        if let Some(budget) = self.budget {
            self.evict_over_budget(&mut st, budget, idx);
        }
        REGISTRY.weight_resident_bytes.set(st.resident_bytes as u64);
        Ok(prepared)
    }

    /// Drop least-recently-used sites until the footprint fits `budget`.
    /// `keep` (the site being handed out) is exempt, so one over-budget
    /// site still serves — the budget degrades to "one site at a time".
    fn evict_over_budget(&self, st: &mut PagerState, budget: usize, keep: usize) {
        while st.resident_bytes > budget {
            let victim = st
                .resident
                .iter()
                .enumerate()
                .filter(|(i, p)| *i != keep && p.is_some())
                .min_by_key(|(i, _)| st.stamp[*i])
                .map(|(i, _)| i);
            let Some(v) = victim else { break };
            let p = st.resident[v].take().expect("victim was resident");
            st.resident_bytes -= p.resident_bytes();
            self.evictions.fetch_add(1, Ordering::Relaxed);
            REGISTRY.pager_evictions.inc();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::store::{write_artifact, write_artifact_opts};
    use crate::artifact::{ModelArtifact, PackedLinear};
    use crate::artifact::store::ArtifactSite;
    use crate::compress::traits::CompressionSpec;
    use crate::eval::reconstruction::LayerReport;
    use crate::proj::{NmStructured, ProjScratch, Projection};
    use crate::quant::project_qmax;
    use crate::tensor::Matrix;
    use crate::util::tempdir::TempDir;

    fn report(param: &str, rows: usize, cols: usize) -> LayerReport {
        LayerReport {
            param: param.into(), d_out: rows, d_in: cols, rel_loss: 0.1,
            sparsity: 0.5, row_uniform: true, iterations: 3, seconds: 0.01,
        }
    }

    /// Three sites covering the int, mask and dense payload modes.
    fn artifact() -> ModelArtifact {
        let q = project_qmax(&Matrix::randn(8, 64, 1), 15.0, 32);
        let int = PackedLinear::encode(&q, &CompressionSpec::quant(4, 32));
        let mut nm = Matrix::randn(8, 64, 2);
        NmStructured::new(2, 4).project_rows(&mut nm, &mut ProjScratch::new());
        let mask = PackedLinear::encode(&nm, &CompressionSpec::structured_nm(2, 4));
        let dense = PackedLinear::encode(&Matrix::randn(4, 32, 3),
                                         &CompressionSpec::quant(4, 32));
        ModelArtifact {
            model: "t".into(),
            checkpoint: 1,
            calib: 2,
            method: "rtn".into(),
            spec: 3,
            spec_desc: "int4-g32".into(),
            params: 4,
            compressed_with: "rtn".into(),
            sites: vec![
                ArtifactSite { param: "a".into(), packed: int,
                               report: report("a", 8, 64) },
                ArtifactSite { param: "b".into(), packed: mask,
                               report: report("b", 8, 64) },
                ArtifactSite { param: "c".into(), packed: dense,
                               report: report("c", 4, 32) },
            ],
        }
    }

    fn write(dir: &TempDir, name: &str, art: &ModelArtifact, pack2: bool)
        -> std::path::PathBuf {
        let path = dir.path().join(name);
        write_artifact_opts(&path, art, pack2).unwrap();
        path
    }

    fn assert_site_bits_equal(a: &PackedLinear, b: &PackedLinear, what: &str) {
        let (da, db) = (a.decode(), b.decode());
        assert_eq!(da.shape(), db.shape(), "{what}");
        for (x, y) in da.data.iter().zip(&db.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}");
        }
    }

    #[test]
    fn paged_sites_are_bit_identical_to_eager_load() {
        let dir = TempDir::new("pager").unwrap();
        let art = artifact();
        for pack2 in [false, true] {
            let path = write(&dir, if pack2 { "v2" } else { "v1" }, &art, pack2);
            let eager = crate::artifact::read_artifact(&path).unwrap();
            let pager = ArtifactPager::open(&path, None).unwrap();
            assert_eq!(pager.site_count(), 3);
            assert_eq!(pager.packed_bytes(), art.packed_bytes());
            for i in 0..3 {
                let p = pager.site(i).unwrap();
                assert_site_bits_equal(p.packed(), &eager.sites[i].packed,
                                       &art.sites[i].param);
            }
            let c = pager.counts();
            assert_eq!((c.hits, c.misses), (0, 3));
            // second touch: all hits, same Arc
            let again = pager.site(1).unwrap();
            assert!(Arc::ptr_eq(&again, &pager.site(1).unwrap()));
            assert_eq!(pager.counts().hits, 2);
        }
    }

    #[test]
    fn open_reads_only_the_header() {
        // truncate the file to the end of the header: open must succeed
        // (no payload byte is needed), site() must fail cleanly
        let dir = TempDir::new("pager").unwrap();
        let art = artifact();
        let path = write(&dir, "t", &art, false);
        let pager = ArtifactPager::open(&path, None).unwrap();
        let head_end = pager.header().payload_start as usize;
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.len() > head_end);
        std::fs::write(&path, &bytes[..head_end]).unwrap();
        let lazy = ArtifactPager::open(&path, None).unwrap();
        assert_eq!(lazy.site_count(), 3);
        assert!(lazy.site(0).is_err(), "payload is gone, touch must fail");
    }

    #[test]
    fn budget_evicts_least_recently_used() {
        let dir = TempDir::new("pager").unwrap();
        let art = artifact();
        let path = write(&dir, "t", &art, false);
        // budget of one byte: after every touch exactly the touched site
        // stays (a lone over-budget site is exempt from eviction)
        let pager = ArtifactPager::open(&path, Some(1)).unwrap();
        for i in 0..3 {
            let p = pager.site(i).unwrap();
            assert_eq!(pager.resident_sites(), 1);
            assert_eq!(pager.resident_bytes(), p.resident_bytes());
        }
        assert_eq!(pager.counts().evictions, 2);
        // re-touching an evicted site is a miss that pages it back in
        pager.site(0).unwrap();
        assert_eq!(pager.counts().misses, 4);
        // a budget large enough for everything never evicts
        let roomy = ArtifactPager::open(&path, Some(1 << 30)).unwrap();
        for i in 0..3 {
            roomy.site(i).unwrap();
        }
        assert_eq!(roomy.resident_sites(), 3);
        assert_eq!(roomy.counts().evictions, 0);
    }

    #[test]
    fn lru_victim_is_the_stalest_site() {
        let dir = TempDir::new("pager").unwrap();
        let art = artifact();
        let path = write(&dir, "t", &art, false);
        let total: usize = {
            let p = ArtifactPager::open(&path, None).unwrap();
            (0..3).map(|i| p.site(i).unwrap().resident_bytes()).sum()
        };
        // room for all but one byte: paging in the third site must evict
        // exactly the least recently touched one (site 1 after we
        // refresh site 0)
        let pager = ArtifactPager::open(&path, Some(total - 1)).unwrap();
        pager.site(0).unwrap();
        pager.site(1).unwrap();
        pager.site(0).unwrap(); // refresh 0 → 1 is now stalest
        pager.site(2).unwrap();
        assert_eq!(pager.counts().evictions, 1);
        assert!(pager.site(0).is_ok() && pager.site(2).is_ok());
        assert_eq!(pager.counts().hits, 3);
        let before = pager.counts().misses;
        pager.site(1).unwrap(); // was evicted → miss
        assert_eq!(pager.counts().misses, before + 1);
    }

    #[test]
    fn corrupt_site_fails_first_touch_but_spares_the_rest() {
        let dir = TempDir::new("pager").unwrap();
        let art = artifact();
        let path = write(&dir, "t", &art, false);
        let probe = ArtifactPager::open(&path, None).unwrap();
        let head = probe.header().payload_start as usize;
        let m0_len = probe.sites()[0].stored_len;
        // flip one mask bit of site 1 (the mask site): the popcount is
        // now off by one, so its first touch must fail; sites 0 and 2
        // stay servable
        let mut bytes = std::fs::read(&path).unwrap();
        let m1 = &probe.sites()[1];
        bytes[head + m1.offset] ^= 1;
        assert_eq!(m1.offset, m0_len, "sites tile contiguously");
        std::fs::write(&path, &bytes).unwrap();
        let pager = ArtifactPager::open(&path, None).unwrap();
        assert!(pager.site(0).is_ok());
        let err = pager.site(1).unwrap_err();
        assert!(format!("{err:#}").contains("paging in b"),
                "error names the site: {err:#}");
        assert!(pager.site(2).is_ok());
        // the failed site is not cached — a healed file would be re-read
        assert_eq!(pager.resident_sites(), 2);
    }

    #[test]
    fn pack2_pager_decodes_rc_sites_transparently() {
        let dir = TempDir::new("pager").unwrap();
        let art = artifact();
        let p2 = write(&dir, "v2", &art, true);
        let pager = ArtifactPager::open(&p2, Some(1)).unwrap();
        // under an eviction budget every touch re-decodes from disk;
        // bits must survive the rc round trip every time
        for _ in 0..2 {
            for i in 0..3 {
                let p = pager.site(i).unwrap();
                assert_site_bits_equal(p.packed(), &art.sites[i].packed,
                                       &art.sites[i].param);
            }
        }
        assert!(pager.header().stored_bytes() <= pager.packed_bytes());
    }
}
