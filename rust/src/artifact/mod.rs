//! Compressed-artifact subsystem: bit-packed weight storage, the
//! `(Gram cache key, spec, method)`-keyed artifact store, and the packed
//! execution path.
//!
//! The compression pipeline produces dense f32 `Matrix` values whose
//! entries live in tiny sets (b-bit grid points, sparse survivors). This
//! module is where that structure becomes *real* savings and *real*
//! incrementality:
//!
//! * [`codec`] — [`PackedLinear`]: each site stored in its natural
//!   representation (grouped b-bit codes + per-group scale/zero-point,
//!   per-group value palettes, packed survivor masks, dense fallback),
//!   with a decode that is **bit-identical** to the encoder's input —
//!   enforced by decode-verification at encode time, not by tolerance.
//! * [`keys`] — [`ArtifactKey`]: artifact identity = Gram cache key ×
//!   [`crate::compress::traits::CompressionSpec::fingerprint`] × method,
//!   re-validated on every load.
//! * [`store`] — the `AWPPACK1`/`AWPPACK2` containers and
//!   [`ArtifactStore`]: rename-atomic writes, corrupt-file → logged
//!   recompute, per-site layer reports persisted alongside the weights so
//!   warm reruns submit **zero** compression jobs
//!   (`coordinator::pipeline::compress_model_cached`). The header alone
//!   locates and sizes every site's payload range.
//! * [`pack2`] — the `AWPPACK2` lossless second stage: a dependency-free
//!   adaptive range coder applied per site, kept only where it shrinks
//!   and round-trips bit-identically (encode-time verified).
//! * [`pager`] — the model-weight pager ([`ArtifactPager`]): opens an
//!   artifact by reading only its header, materialises each site into a
//!   [`PreparedPacked`] on first touch (structural validation included),
//!   and LRU-evicts under a byte budget so serving handles artifacts
//!   larger than RAM.
//! * [`packed`] — the packed execution path, two kernel tiers
//!   ([`crate::tensor::KernelTier`]): the *reference* tier (streaming
//!   dequant GEMM and survivor-only N:M sparse GEMM over [`PackedLinear`],
//!   bit-identical to the dense kernels on the decoded weights) and the
//!   *fast* tier (integer-accumulate / palette-LUT / cache-blocked sparse
//!   SIMD GEMMs over a [`PreparedPacked`], tolerance-validated — see
//!   KERNELS.md).
//!
//! CLI surface: `repro compress --pack-out <file> [--pack2]`, `repro
//! inspect <file>`, `repro eval --from-artifact <file>
//! [--weight-budget-mb N]`; sweeps consult the store through
//! `--artifact-dir` (default `cache/artifacts`). See ARTIFACTS.md for the
//! container layouts and the bit-packing spec.

pub mod codec;
pub mod keys;
pub mod pack2;
pub mod packed;
pub mod pager;
pub mod store;

pub use codec::PackedLinear;
pub use keys::ArtifactKey;
pub use packed::PreparedPacked;
pub use pager::{ArtifactPager, PagerCounts};
pub use store::{
    load_artifact, read_artifact, store_artifact, write_artifact,
    write_artifact_opts, ArtifactCounts, ArtifactHeader, ArtifactSite,
    ArtifactStore, ModelArtifact, SiteMeta,
};
