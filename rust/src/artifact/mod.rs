//! Compressed-artifact subsystem: bit-packed weight storage, the
//! `(Gram cache key, spec, method)`-keyed artifact store, and the packed
//! execution path.
//!
//! The compression pipeline produces dense f32 `Matrix` values whose
//! entries live in tiny sets (b-bit grid points, sparse survivors). This
//! module is where that structure becomes *real* savings and *real*
//! incrementality:
//!
//! * [`codec`] — [`PackedLinear`]: each site stored in its natural
//!   representation (grouped b-bit codes + per-group scale/zero-point,
//!   per-group value palettes, packed survivor masks, dense fallback),
//!   with a decode that is **bit-identical** to the encoder's input —
//!   enforced by decode-verification at encode time, not by tolerance.
//! * [`keys`] — [`ArtifactKey`]: artifact identity = Gram cache key ×
//!   [`crate::compress::traits::CompressionSpec::fingerprint`] × method,
//!   re-validated on every load.
//! * [`store`] — the `AWPPACK1` container and [`ArtifactStore`]:
//!   rename-atomic writes, corrupt-file → logged recompute, per-site
//!   layer reports persisted alongside the weights so warm reruns submit
//!   **zero** compression jobs (`coordinator::pipeline::compress_model_cached`).
//! * [`packed`] — the packed execution path, two kernel tiers
//!   ([`crate::tensor::KernelTier`]): the *reference* tier (streaming
//!   dequant GEMM and survivor-only N:M sparse GEMM over [`PackedLinear`],
//!   bit-identical to the dense kernels on the decoded weights) and the
//!   *fast* tier (integer-accumulate / palette-LUT / cache-blocked sparse
//!   SIMD GEMMs over a [`PreparedPacked`], tolerance-validated — see
//!   KERNELS.md).
//!
//! CLI surface: `repro compress --pack-out <file>`, `repro inspect
//! <file>`, `repro eval --from-artifact <file>`; sweeps consult the store
//! through `--artifact-dir` (default `cache/artifacts`). See ARTIFACTS.md
//! for the container layout and the bit-packing spec.

pub mod codec;
pub mod keys;
pub mod packed;
pub mod store;

pub use codec::PackedLinear;
pub use keys::ArtifactKey;
pub use packed::PreparedPacked;
pub use store::{
    load_artifact, read_artifact, store_artifact, write_artifact, ArtifactCounts,
    ArtifactSite, ArtifactStore, ModelArtifact,
};
