//! Compressed-artifact identity — `(Gram cache key, spec, method)`.
//!
//! A compressed site is a pure function of the checkpoint, the calibration
//! Grams, the compression spec, the method and its hyperparameters, so an
//! artifact's key is the Gram cache key ([`GramCacheKey`]: model,
//! checkpoint fingerprint, calibration-config fingerprint) extended with
//! [`CompressionSpec::fingerprint`], the method label and a
//! method-parameter fingerprint
//! ([`crate::compress::AwpHyper::fingerprint`]). Same discipline as the
//! Gram cache: the 64-bit hash only names the file; the identity fields
//! are stored inside the artifact and re-validated on load, so a hash
//! collision (or a renamed file) degrades to a recompute, never to
//! serving the wrong weights.

use crate::compress::traits::CompressionSpec;
use crate::coordinator::cache::GramCacheKey;
use crate::util::Fnv64;

/// Full identity of one model's compressed artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactKey {
    /// identity of the calibration inputs (model, checkpoint, calib config)
    pub gram: GramCacheKey,
    /// [`crate::coordinator::Method::label`]
    pub method: String,
    /// [`CompressionSpec::fingerprint`]
    pub spec: u64,
    /// [`CompressionSpec::describe`] — stored in the artifact and compared
    /// on load (human-readable identity, collision backstop)
    pub spec_desc: String,
    /// method-parameter fingerprint (e.g.
    /// [`crate::compress::AwpHyper::fingerprint`]): everything beyond the
    /// spec that changes the produced Θ — step sizes, iteration budgets,
    /// the AOT chunk/group. Defaults to 0 for parameter-free callers.
    pub params: u64,
}

impl ArtifactKey {
    pub fn new(gram: GramCacheKey, method: &str, spec: &CompressionSpec) -> Self {
        ArtifactKey {
            gram,
            method: method.to_string(),
            spec: spec.fingerprint(),
            spec_desc: spec.describe(),
            params: 0,
        }
    }

    /// Attach the method-parameter fingerprint (hyperparameters).
    pub fn with_params(mut self, params: u64) -> Self {
        self.params = params;
        self
    }

    pub fn hash(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_u64(self.gram.hash());
        h.write_str(&self.method);
        h.write_u64(self.spec);
        h.write_u64(self.params);
        h.finish()
    }

    /// Artifact file name: `<model>-<hash:016x>.apack`.
    pub fn file_name(&self) -> String {
        let safe: String = self
            .gram
            .model
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .collect();
        format!("{safe}-{:016x}.apack", self.hash())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gram(model: &str, ck: u64) -> GramCacheKey {
        GramCacheKey { model: model.into(), checkpoint: ck, calib: 7 }
    }

    #[test]
    fn hash_tracks_every_component() {
        let base = ArtifactKey::new(gram("t", 1), "awp", &CompressionSpec::prune(0.5));
        assert_eq!(base.hash(),
                   ArtifactKey::new(gram("t", 1), "awp",
                                    &CompressionSpec::prune(0.5)).hash());
        // checkpoint, method, spec, spec params each move the hash
        assert_ne!(base.hash(),
                   ArtifactKey::new(gram("t", 2), "awp",
                                    &CompressionSpec::prune(0.5)).hash());
        assert_ne!(base.hash(),
                   ArtifactKey::new(gram("t", 1), "wanda",
                                    &CompressionSpec::prune(0.5)).hash());
        assert_ne!(base.hash(),
                   ArtifactKey::new(gram("t", 1), "awp",
                                    &CompressionSpec::prune(0.6)).hash());
        assert_ne!(base.hash(),
                   ArtifactKey::new(gram("t", 1), "awp",
                                    &CompressionSpec::quant(4, 32)).hash());
        let mut seeded = CompressionSpec::prune(0.5);
        seeded.seed = 9;
        assert_ne!(base.hash(),
                   ArtifactKey::new(gram("t", 1), "awp", &seeded).hash());
        // hyperparameters move the hash too (the AwpHyper fingerprint)
        assert_ne!(base.hash(), base.clone().with_params(1).hash());
    }

    #[test]
    fn hyper_fingerprint_tracks_theta_affecting_knobs() {
        use crate::compress::AwpHyper;
        let base = AwpHyper::default().fingerprint();
        assert_eq!(base, AwpHyper::default().fingerprint());
        let mut h = AwpHyper::default();
        h.chunk = 1;
        assert_ne!(base, h.fingerprint());
        let mut h = AwpHyper::default();
        h.group = 64;
        assert_ne!(base, h.fingerprint());
        let mut h = AwpHyper::default();
        h.prune_max_iters = 50;
        assert_ne!(base, h.fingerprint());
        // series tracking is bookkeeping only — same Θ, same key
        let mut h = AwpHyper::default();
        h.track_series = true;
        assert_eq!(base, h.fingerprint());
    }

    #[test]
    fn spec_fingerprint_separates_modes_with_equal_params() {
        // nm(2:4) vs jointnm(2:4, int4): the mode tag must disambiguate
        let a = CompressionSpec::structured_nm(2, 4).fingerprint();
        let b = CompressionSpec::joint_nm(2, 4, 4, 32).fingerprint();
        assert_ne!(a, b);
        assert_ne!(CompressionSpec::quant(4, 32).fingerprint(),
                   CompressionSpec::joint(0.5, 4, 32).fingerprint());
    }

    #[test]
    fn file_names_are_filesystem_safe() {
        let key = ArtifactKey::new(gram("we/ird mo:del", 1), "awp",
                                   &CompressionSpec::prune(0.5));
        let name = key.file_name();
        assert!(!name.contains('/') && !name.contains(':'), "{name}");
        assert!(name.ends_with(".apack"));
    }

    #[test]
    fn describe_is_stored_for_revalidation() {
        let key = ArtifactKey::new(gram("t", 1), "awp",
                                   &CompressionSpec::joint(0.5, 4, 32));
        assert!(key.spec_desc.contains("Joint"), "{}", key.spec_desc);
        assert!(key.spec_desc.contains("seed=0"), "{}", key.spec_desc);
    }
}
