//! Bit-packed site representations and the lossless encoder behind them.
//!
//! A compressed site leaves the pipeline as a dense f32 `Matrix` whose
//! entries happen to live in a tiny set: b-bit grid points for quantized
//! sites, mostly zeros for pruned ones. [`PackedLinear`] stores each site
//! in that natural representation:
//!
//! | variant        | constraint family          | layout                        |
//! |----------------|----------------------------|-------------------------------|
//! | `GroupedInt`   | `C_INTb` (quant, joint)    | b-bit codes + per-group (scale, zp) |
//! | `Palette`      | `C_INTb` fallback          | b-bit codes + per-group value LUT   |
//! | `SparseMask`   | `C_row`, N:M               | packed survivor mask + nonzero f32s |
//! | `Dense`        | anything (fallback)        | raw f32                        |
//!
//! ### The bit-identity contract
//!
//! `decode(encode(Θ)) == Θ` **bit-for-bit**, always. The encoder earns
//! that structurally rather than by hope: every candidate representation
//! is *verified* by decoding and comparing bit patterns before it is
//! accepted, and a candidate that fails (or fails to shrink the site)
//! falls through the lattice `GroupedInt → Palette → SparseMask → Dense`.
//! `Dense` is trivially exact, so the contract holds for arbitrary input —
//! the lattice only decides how small the exact representation gets.
//!
//! ### Recovering the grid from Θ alone
//!
//! `GroupedInt` mirrors [`crate::proj::GroupedIntGrid`]: within each
//! aligned group the values are `(q − zp)·s` for integer codes
//! `q ∈ [0, qmax]`. The projection's `(s, zp)` are not persisted by the
//! pipeline, so the encoder re-derives them from the group's values: the
//! group min/max span divided by each candidate code span `m ∈ [r−1, qmax]`
//! proposes a scale, a few ulp-neighbours of each proposal absorb the
//! float rounding of the original fit, and a proposal is accepted only if
//! **every** distinct value reproduces exactly as `fl(c·s)` with integer
//! codes spanning ≤ qmax. Decode computes `(q − zp)·s` where `q − zp` is
//! an exact small-integer subtraction, i.e. the identical product the
//! verifier checked — which is what makes the verification sound.

use crate::compress::traits::{CompressionMode, CompressionSpec};
use crate::quant::pack::{pack_bits, packed_size_bytes, unpack_bits};
use crate::tensor::Matrix;

/// Largest |integer code| the scale/zp recovery will accept: keeps
/// `q − zp` exact in f32 (integers below 2²⁴) with headroom.
const MAX_CODE_MAG: i64 = 1 << 23;

/// One site's weights in packed form. Construct with
/// [`PackedLinear::encode`]; reconstruct with [`PackedLinear::decode`]
/// (bit-identical) or run the packed kernels in [`super::packed`] directly.
#[derive(Clone, Debug)]
pub enum PackedLinear {
    /// Raw f32 fallback — exact for anything, compresses nothing.
    Dense { rows: usize, cols: usize, data: Vec<f32> },
    /// Grouped b-bit integer codes with per-(row, group) scale and
    /// zero-point; `group` is the *effective* group (already clamped to
    /// the width, so `cols % group == 0` holds).
    GroupedInt {
        rows: usize,
        cols: usize,
        bits: u8,
        group: usize,
        /// per (row, group): scale
        scales: Vec<f32>,
        /// per (row, group): zero-point (integer stored as f32)
        zps: Vec<f32>,
        /// bit-packed row-major codes ([`pack_bits`])
        codes: Vec<u8>,
    },
    /// Grouped b-bit codes indexing a per-group table of distinct values —
    /// the exact fallback when no (scale, zp) reproduces the group.
    Palette {
        rows: usize,
        cols: usize,
        bits: u8,
        group: usize,
        /// per (row, group): number of table entries **minus one** (so a
        /// full 256-entry INT8 table still fits a byte)
        counts: Vec<u8>,
        /// concatenated per-group tables, group-major
        values: Vec<f32>,
        /// bit-packed row-major codes into the group's table
        codes: Vec<u8>,
    },
    /// Packed survivor mask (one bit per weight, row-major) plus the
    /// surviving values in row-major order — `C_row` and N:M sites.
    SparseMask {
        rows: usize,
        cols: usize,
        /// bit `i` set ⇔ element `i` is a survivor (bit pattern ≠ +0.0)
        mask: Vec<u8>,
        values: Vec<f32>,
    },
}

impl PackedLinear {
    pub fn rows(&self) -> usize {
        match self {
            PackedLinear::Dense { rows, .. }
            | PackedLinear::GroupedInt { rows, .. }
            | PackedLinear::Palette { rows, .. }
            | PackedLinear::SparseMask { rows, .. } => *rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            PackedLinear::Dense { cols, .. }
            | PackedLinear::GroupedInt { cols, .. }
            | PackedLinear::Palette { cols, .. }
            | PackedLinear::SparseMask { cols, .. } => *cols,
        }
    }

    /// Stable variant tag (also the on-disk `mode` field).
    pub fn mode_name(&self) -> &'static str {
        match self {
            PackedLinear::Dense { .. } => "dense",
            PackedLinear::GroupedInt { .. } => "int",
            PackedLinear::Palette { .. } => "palette",
            PackedLinear::SparseMask { .. } => "mask",
        }
    }

    /// Human-readable parameterisation for `repro inspect`.
    pub fn describe(&self) -> String {
        match self {
            PackedLinear::Dense { .. } => "dense f32".to_string(),
            PackedLinear::GroupedInt { bits, group, .. } => {
                format!("int{bits} g{group}")
            }
            PackedLinear::Palette { bits, group, .. } => {
                format!("palette{bits} g{group}")
            }
            PackedLinear::SparseMask { rows, cols, values, .. } => {
                let density = values.len() as f64 / (rows * cols).max(1) as f64;
                format!("mask {:.1}% dense", 100.0 * density)
            }
        }
    }

    /// Serialized payload size in bytes (what the artifact file stores for
    /// this site, excluding its header entry).
    pub fn packed_bytes(&self) -> usize {
        match self {
            PackedLinear::Dense { data, .. } => data.len() * 4,
            PackedLinear::GroupedInt { scales, zps, codes, .. } => {
                scales.len() * 4 + zps.len() * 4 + codes.len()
            }
            PackedLinear::Palette { counts, values, codes, .. } => {
                counts.len() + values.len() * 4 + codes.len()
            }
            PackedLinear::SparseMask { mask, values, .. } => {
                mask.len() + values.len() * 4
            }
        }
    }

    /// Size of the same site stored dense (f32 per weight).
    pub fn dense_bytes(&self) -> usize {
        self.rows() * self.cols() * 4
    }

    // -------------------------------------------------------------- encode

    /// Pack `theta` under `spec`'s constraint family, guaranteeing
    /// `decode()` reproduces `theta` bit-for-bit. Candidates are tried in
    /// shrink order and each is decode-verified; `Dense` is the universal
    /// fallback, so this never fails.
    pub fn encode(theta: &Matrix, spec: &CompressionSpec) -> PackedLinear {
        let dense_bytes = theta.rows * theta.cols * 4;
        let mut candidates: Vec<PackedLinear> = Vec::new();
        if let Some(qs) = spec.quant_spec() {
            match encode_grouped_int(theta, qs.bits, qs.group) {
                Some(p) => candidates.push(p),
                None => {
                    if let Some(p) = encode_palette(theta, qs.bits, qs.group) {
                        candidates.push(p);
                    }
                }
            }
        }
        if matches!(
            spec.mode,
            CompressionMode::Prune { .. }
                | CompressionMode::StructuredNm { .. }
                | CompressionMode::Joint { .. }
                | CompressionMode::JointNm { .. }
        ) {
            candidates.push(encode_sparse_mask(theta));
        }
        candidates.sort_by_key(PackedLinear::packed_bytes);
        for cand in candidates {
            if cand.packed_bytes() < dense_bytes && cand.reconstructs(theta) {
                return cand;
            }
        }
        PackedLinear::Dense {
            rows: theta.rows,
            cols: theta.cols,
            data: theta.data.clone(),
        }
    }

    /// `decode() == theta`, bit-for-bit — the encoder's acceptance gate
    /// and the tests' oracle.
    pub fn reconstructs(&self, theta: &Matrix) -> bool {
        if (self.rows(), self.cols()) != theta.shape() {
            return false;
        }
        let back = self.decode();
        back.data
            .iter()
            .zip(&theta.data)
            .all(|(a, b)| a.to_bits() == b.to_bits())
    }

    // -------------------------------------------------------------- decode

    /// Reconstruct the dense matrix, bit-identical to the encoder's input.
    pub fn decode(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows(), self.cols());
        match self {
            PackedLinear::Dense { data, .. } => out.data.copy_from_slice(data),
            PackedLinear::GroupedInt {
                rows, cols, bits, group, scales, zps, codes,
            } => {
                let ng = cols / group;
                let q = unpack_bits(codes, *bits, rows * cols);
                for i in 0..*rows {
                    for g in 0..ng {
                        let scale = scales[i * ng + g];
                        let zp = zps[i * ng + g];
                        for t in 0..*group {
                            let idx = i * cols + g * group + t;
                            out.data[idx] = (q[idx] as f32 - zp) * scale;
                        }
                    }
                }
            }
            PackedLinear::Palette {
                rows, cols, bits, group, counts, values, codes,
            } => {
                let ng = cols / group;
                let q = unpack_bits(codes, *bits, rows * cols);
                let mut start = 0usize;
                for i in 0..*rows {
                    for g in 0..ng {
                        let len = counts[i * ng + g] as usize + 1;
                        let table = &values[start..start + len];
                        for t in 0..*group {
                            let idx = i * cols + g * group + t;
                            out.data[idx] = table[q[idx] as usize];
                        }
                        start += len;
                    }
                }
            }
            PackedLinear::SparseMask { rows, cols, mask, values } => {
                let mut v = 0usize;
                for idx in 0..rows * cols {
                    if mask[idx / 8] >> (idx % 8) & 1 == 1 {
                        out.data[idx] = values[v];
                        v += 1;
                    }
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// per-variant encoders

/// Effective group width: the projection clamps its configured group to
/// the site width (`GroupedIntGrid` semantics), so the codec does too.
fn effective_group(cols: usize, group: usize) -> usize {
    group.min(cols).max(1)
}

/// Neighbouring f32 toward −∞ / +∞ (one representable step; callers only
/// pass nonzero finite values whose neighbours don't cross zero).
fn f32_pred(x: f32) -> f32 {
    if x > 0.0 {
        f32::from_bits(x.to_bits() - 1)
    } else {
        f32::from_bits(x.to_bits() + 1)
    }
}

fn f32_succ(x: f32) -> f32 {
    if x > 0.0 {
        f32::from_bits(x.to_bits() + 1)
    } else {
        f32::from_bits(x.to_bits() - 1)
    }
}

/// Try to represent one group as `(q − zp)·s`: returns `(scale, zp,
/// codes)` such that the decode expression reproduces every element
/// bit-for-bit, or `None` if no candidate grid does.
///
/// For each candidate code span `m`, the approximate scale `span/m` fixes
/// the integer code of every distinct value; the set of scales that
/// reproduce a value `v` exactly as `fl(c·s)` is then `v`'s f32-rounding
/// interval divided by `c`, and intersecting those intervals over the
/// group either yields a working scale or proves the span wrong. The
/// final word is always [`verify_grid`] — a candidate is accepted only if
/// every distinct value decodes bit-exact.
fn try_scale_zp(s: &[f32], qmax: u32) -> Option<(f32, f32, Vec<u8>)> {
    if s.iter().any(|v| !v.is_finite()) {
        return None;
    }
    // distinct bit patterns, value-ordered
    let mut distinct: Vec<f32> = s.to_vec();
    distinct.sort_by(f32::total_cmp);
    distinct.dedup_by(|a, b| a.to_bits() == b.to_bits());
    let r = distinct.len();
    if r == 1 {
        // flat group: scale slot carries the constant, code 0, zp −1 ⇒
        // decode (0 − (−1))·v = 1·v = v exactly
        let v = distinct[0];
        return Some((v, -1.0, vec![0u8; s.len()]));
    }
    if r > qmax as usize + 1 {
        return None;
    }
    let span = distinct[r - 1] as f64 - distinct[0] as f64;
    if !(span > 0.0) || !span.is_finite() {
        return None;
    }
    for m in (r as u32 - 1).max(1)..=qmax {
        let s0 = span / m as f64;
        let Some(codes) = integer_codes(&distinct, s0, qmax) else { continue };
        let Some(cand) = scale_interval_mid(&distinct, &codes) else { continue };
        for scale in [cand, f32_pred(cand), f32_succ(cand)] {
            if !(scale > 0.0) || !scale.is_finite() {
                continue;
            }
            if verify_grid(&distinct, &codes, scale) {
                return Some(assign_codes(s, &distinct, &codes, scale));
            }
        }
    }
    None
}

/// Integer code of every distinct value under the approximate scale `s0`,
/// if they are plausible (bounded, span ≤ qmax, zeros only at code 0).
fn integer_codes(distinct: &[f32], s0: f64, qmax: u32) -> Option<Vec<i64>> {
    let mut cs = Vec::with_capacity(distinct.len());
    for &v in distinct {
        let c = (v as f64 / s0).round() as i64;
        if c.abs() > MAX_CODE_MAG {
            return None;
        }
        // code 0 decodes to exactly +0.0 and nothing else, so value and
        // code must agree on zeroness (a kept −0.0 has no code at all and
        // sends the group to the palette encoding)
        if (v.to_bits() == 0) != (c == 0) {
            return None;
        }
        cs.push(c);
    }
    let c_min = *cs.iter().min().unwrap();
    let c_max = *cs.iter().max().unwrap();
    if c_max - c_min > qmax as i64 {
        return None;
    }
    Some(cs)
}

/// Midpoint of the intersection of every value's scale interval: the real
/// scales `s` with `round_f32(c·s) == v` form `v`'s rounding interval
/// divided by `c`; a nonempty intersection over the group yields the
/// candidate.
fn scale_interval_mid(distinct: &[f32], codes: &[i64]) -> Option<f32> {
    let mut s_lo = f64::NEG_INFINITY;
    let mut s_hi = f64::INFINITY;
    for (&v, &c) in distinct.iter().zip(codes) {
        if c == 0 {
            continue; // v is +0.0: satisfied by any scale
        }
        let v64 = v as f64;
        let lo = (v64 + f32_pred(v) as f64) / 2.0;
        let hi = (v64 + f32_succ(v) as f64) / 2.0;
        let (a, b) = if c > 0 {
            (lo / c as f64, hi / c as f64)
        } else {
            (hi / c as f64, lo / c as f64)
        };
        s_lo = s_lo.max(a);
        s_hi = s_hi.min(b);
        if s_lo > s_hi {
            return None;
        }
    }
    let mid = (s_lo + s_hi) / 2.0;
    mid.is_finite().then_some(mid as f32)
}

/// The acceptance gate: every distinct value must be exactly `fl(c·scale)`
/// — the same product the decoder computes.
fn verify_grid(distinct: &[f32], codes: &[i64], scale: f32) -> bool {
    distinct
        .iter()
        .zip(codes)
        .all(|(&v, &c)| (c as f32 * scale).to_bits() == v.to_bits())
}

/// Map each element of `s` to its code `q = c − c_min`; `zp = −c_min`.
fn assign_codes(s: &[f32], distinct: &[f32], grid: &[i64], scale: f32)
    -> (f32, f32, Vec<u8>) {
    let c_min = *grid.iter().min().unwrap();
    let lut: Vec<(u32, u8)> = distinct
        .iter()
        .zip(grid)
        .map(|(v, c)| (v.to_bits(), (c - c_min) as u8))
        .collect();
    let codes = s
        .iter()
        .map(|v| {
            lut.iter()
                .find(|(bits, _)| *bits == v.to_bits())
                .expect("element missing from its own distinct set")
                .1
        })
        .collect();
    (scale, -(c_min as f32), codes)
}

fn encode_grouped_int(theta: &Matrix, bits: u8, group: usize) -> Option<PackedLinear> {
    let geff = effective_group(theta.cols, group);
    if theta.cols % geff != 0 {
        return None;
    }
    let qmax = (1u32 << bits) - 1;
    let ng = theta.cols / geff;
    let mut scales = Vec::with_capacity(theta.rows * ng);
    let mut zps = Vec::with_capacity(theta.rows * ng);
    let mut codes = Vec::with_capacity(theta.rows * theta.cols);
    for i in 0..theta.rows {
        let row = theta.row(i);
        for g in 0..ng {
            let (scale, zp, q) = try_scale_zp(&row[g * geff..(g + 1) * geff], qmax)?;
            scales.push(scale);
            zps.push(zp);
            codes.extend_from_slice(&q);
        }
    }
    Some(PackedLinear::GroupedInt {
        rows: theta.rows,
        cols: theta.cols,
        bits,
        group: geff,
        scales,
        zps,
        codes: pack_bits(&codes, bits),
    })
}

fn encode_palette(theta: &Matrix, bits: u8, group: usize) -> Option<PackedLinear> {
    let geff = effective_group(theta.cols, group);
    if theta.cols % geff != 0 {
        return None;
    }
    let levels = 1usize << bits;
    let ng = theta.cols / geff;
    let mut counts = Vec::with_capacity(theta.rows * ng);
    let mut values = Vec::new();
    let mut codes = Vec::with_capacity(theta.rows * theta.cols);
    for i in 0..theta.rows {
        let row = theta.row(i);
        for g in 0..ng {
            let s = &row[g * geff..(g + 1) * geff];
            let mut distinct: Vec<f32> = s.to_vec();
            distinct.sort_by(f32::total_cmp);
            distinct.dedup_by(|a, b| a.to_bits() == b.to_bits());
            if distinct.len() > levels {
                return None;
            }
            counts.push((distinct.len() - 1) as u8);
            for &v in s {
                let q = distinct
                    .iter()
                    .position(|d| d.to_bits() == v.to_bits())
                    .expect("element missing from its own distinct set");
                codes.push(q as u8);
            }
            values.extend_from_slice(&distinct);
        }
    }
    Some(PackedLinear::Palette {
        rows: theta.rows,
        cols: theta.cols,
        bits,
        group: geff,
        counts,
        values,
        codes: pack_bits(&codes, bits),
    })
}

fn encode_sparse_mask(theta: &Matrix) -> PackedLinear {
    let n = theta.rows * theta.cols;
    let mut mask = vec![0u8; n.div_ceil(8)];
    let mut values = Vec::new();
    for (idx, &v) in theta.data.iter().enumerate() {
        // bit-pattern test, not `v != 0.0`: a kept −0.0 must survive the
        // round-trip exactly, so it counts as a survivor
        if v.to_bits() != 0 {
            mask[idx / 8] |= 1 << (idx % 8);
            values.push(v);
        }
    }
    PackedLinear::SparseMask { rows: theta.rows, cols: theta.cols, mask, values }
}

/// Expected packed-codes byte length for a codes section (shared by the
/// disk reader's bounds checks).
pub fn codes_len(rows: usize, cols: usize, bits: u8) -> usize {
    packed_size_bytes(rows * cols, bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proj::{GroupedIntGrid, NmStructured, ProjScratch, Projection};
    use crate::quant::project_qmax;
    use crate::tensor::topk::hard_threshold_rows;

    fn assert_bit_exact(p: &PackedLinear, theta: &Matrix) {
        assert!(p.reconstructs(theta), "{} does not round-trip", p.describe());
    }

    #[test]
    fn quantized_sites_pack_as_grouped_int() {
        for seed in 0..8u64 {
            for bits in [2u8, 3, 4] {
                let z = Matrix::randn(6, 64, seed);
                let theta = project_qmax(&z, (1u32 << bits) as f32 - 1.0, 32);
                let spec = CompressionSpec::quant(bits, 32);
                let p = PackedLinear::encode(&theta, &spec);
                assert_eq!(p.mode_name(), "int", "seed={seed} bits={bits}");
                assert_bit_exact(&p, &theta);
                assert!(p.packed_bytes() < p.dense_bytes());
            }
        }
    }

    #[test]
    fn joint_sites_pack_with_exact_zeros() {
        for seed in 0..6u64 {
            let z = Matrix::randn(4, 64, seed);
            let spec = CompressionSpec::joint(0.5, 4, 32);
            let mut theta = z.clone();
            spec.projection(theta.cols)
                .project_rows(&mut theta, &mut ProjScratch::new());
            let p = PackedLinear::encode(&theta, &spec);
            assert_bit_exact(&p, &theta);
            assert!(p.packed_bytes() < p.dense_bytes(), "{}", p.describe());
        }
    }

    #[test]
    fn nm_sites_pack_as_mask() {
        for seed in 0..6u64 {
            let mut theta = Matrix::randn(5, 64, seed);
            NmStructured::new(2, 4).project_rows(&mut theta, &mut ProjScratch::new());
            let spec = CompressionSpec::structured_nm(2, 4);
            let p = PackedLinear::encode(&theta, &spec);
            assert_eq!(p.mode_name(), "mask");
            assert_bit_exact(&p, &theta);
            // 2:4 at f32: 1 bit of mask + ~2 bytes of values per weight < 4
            assert!(p.packed_bytes() < p.dense_bytes());
        }
    }

    #[test]
    fn pruned_sites_pack_as_mask() {
        let theta = hard_threshold_rows(&Matrix::randn(8, 32, 3), 16);
        let p = PackedLinear::encode(&theta, &CompressionSpec::prune(0.5));
        assert_eq!(p.mode_name(), "mask");
        assert_bit_exact(&p, &theta);
        assert!(p.packed_bytes() < p.dense_bytes());
    }

    #[test]
    fn off_grid_input_falls_back_to_dense() {
        // raw gaussian under a quant spec: 32 distinct values per group
        // defeat both the 4-bit grid and the 16-entry palette
        let theta = Matrix::randn(4, 64, 9);
        let p = PackedLinear::encode(&theta, &CompressionSpec::quant(4, 32));
        assert_eq!(p.mode_name(), "dense");
        assert_bit_exact(&p, &theta);
    }

    #[test]
    fn negative_zero_survives_the_mask() {
        let mut theta = hard_threshold_rows(&Matrix::randn(2, 16, 1), 8);
        theta.data[3] = -0.0;
        let p = encode_sparse_mask(&theta);
        assert_bit_exact(&p, &theta);
        let back = p.decode();
        assert_eq!(back.data[3].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn flat_groups_encode_exactly() {
        let theta = Matrix::from_fn(3, 32, |i, _| 0.7 + i as f32);
        let p = encode_grouped_int(&theta, 4, 32).unwrap();
        assert_bit_exact(&p, &theta);
    }

    #[test]
    fn grid_projection_operator_output_packs() {
        // the proj:: operator (not just quant::project_qmax) round-trips
        for seed in 0..4u64 {
            let mut theta = Matrix::randn(4, 64, seed);
            GroupedIntGrid::new(7.0, 32)
                .project_rows(&mut theta, &mut ProjScratch::new());
            let p = encode_grouped_int(&theta, 3, 32);
            assert!(p.is_some(), "seed={seed}");
            assert_bit_exact(&p.unwrap(), &theta);
        }
    }

    #[test]
    fn narrow_sites_clamp_the_group() {
        // 16-wide site with group 32 (GroupedIntGrid clamps; so do we)
        let z = Matrix::randn(3, 16, 2);
        let theta = project_qmax(&z, 15.0, 16);
        let p = PackedLinear::encode(&theta, &CompressionSpec::quant(4, 32));
        assert_bit_exact(&p, &theta);
        if let PackedLinear::GroupedInt { group, .. } = &p {
            assert_eq!(*group, 16);
        }
    }

    #[test]
    fn palette_round_trips_hand_built_groups() {
        // 4 distinct values per 16-group, deliberately not an affine grid
        let theta = Matrix::from_fn(2, 32, |_, j| match j % 4 {
            0 => 0.1,
            1 => 0.3,
            2 => 0.7,
            _ => -5.0,
        });
        let p = encode_palette(&theta, 2, 16).unwrap();
        assert_bit_exact(&p, &theta);
        assert!(p.packed_bytes() < p.dense_bytes());
    }

    #[test]
    fn sizes_are_accounted() {
        let z = Matrix::randn(4, 64, 5);
        let theta = project_qmax(&z, 15.0, 32);
        let p = encode_grouped_int(&theta, 4, 32).unwrap();
        // 4 rows × 2 groups × (scale + zp) = 64 bytes, codes 4·64·4 bits
        assert_eq!(p.packed_bytes(), 64 + 128);
        assert_eq!(p.dense_bytes(), 4 * 64 * 4);
    }
}
