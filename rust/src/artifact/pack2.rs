//! The `AWPPACK2` lossless second stage: an adaptive order-0 byte range
//! coder over each site's already-bit-packed payload.
//!
//! Bit-packed quantized weights still carry entropy slack — code
//! distributions are rarely uniform, scale/zero-point f32 streams share
//! exponent bytes, survivor masks are highly structured. A per-site
//! second stage recovers that slack losslessly: the artifact writer codes
//! each site's raw payload through [`rc_encode`] and keeps the coded form
//! only when it is strictly smaller **and** round-trips bit-identically
//! (verified at encode time, mirroring the codec's decode-verification
//! discipline); otherwise the site is stored raw. Per-site fallback means
//! an `AWPPACK2` payload is never larger than its `AWPPACK1` equivalent.
//!
//! The coder is a carryless range coder (Subbotin style, 32-bit state,
//! byte renormalisation) with an adaptive order-0 model: 256 frequencies
//! initialised to 1, incremented per symbol, halved when the total nears
//! the precision bound. Dependency-free like everything else in the crate
//! — no flate/zstd on the image.

/// Renormalisation threshold: the top byte of `low` is settled once the
/// interval no longer straddles a 2²⁴ boundary.
const TOP: u32 = 1 << 24;
/// Underflow threshold: below this the interval is force-aligned so
/// renormalisation can continue without carry propagation.
const BOT: u32 = 1 << 16;
/// Per-symbol frequency increment of the adaptive model.
const INC: u32 = 32;
/// Halve the model when the total reaches this (must stay < [`BOT`] so
/// `range / total >= 1` after renormalisation).
const MAX_TOTAL: u32 = 1 << 15;

/// Adaptive order-0 byte model — identical updates on the encode and
/// decode side keep the two in lockstep.
struct ByteModel {
    freq: [u32; 256],
    total: u32,
}

impl ByteModel {
    fn new() -> ByteModel {
        ByteModel { freq: [1; 256], total: 256 }
    }

    /// Cumulative frequency below `sym`.
    fn cum(&self, sym: usize) -> u32 {
        self.freq[..sym].iter().sum()
    }

    /// Symbol whose cumulative interval contains `dv`, plus the
    /// cumulative frequency below it.
    fn find(&self, dv: u32) -> (usize, u32) {
        let mut cum = 0u32;
        for (sym, &f) in self.freq.iter().enumerate() {
            if dv < cum + f {
                return (sym, cum);
            }
            cum += f;
        }
        (255, cum - self.freq[255])
    }

    fn update(&mut self, sym: usize) {
        self.freq[sym] += INC;
        self.total += INC;
        if self.total >= MAX_TOTAL {
            self.total = 0;
            for f in self.freq.iter_mut() {
                *f = (*f >> 1) | 1;
                self.total += *f;
            }
        }
    }
}

/// Range-code `data` with the adaptive order-0 model. The output carries
/// no length header — callers store the raw length out of band (the
/// artifact header's site entry already knows it).
pub fn rc_encode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 2 + 8);
    let mut model = ByteModel::new();
    let mut low: u32 = 0;
    let mut range: u32 = u32::MAX;
    for &b in data {
        let sym = b as usize;
        let cum = model.cum(sym);
        range /= model.total;
        low = low.wrapping_add(cum.wrapping_mul(range));
        range = range.wrapping_mul(model.freq[sym]);
        loop {
            if (low ^ low.wrapping_add(range)) < TOP {
                // top byte settled: emit it
            } else if range < BOT {
                // interval too small to renormalise but the top byte
                // still straddles a boundary: force-align (carryless
                // underflow handling)
                range = low.wrapping_neg() & (BOT - 1);
            } else {
                break;
            }
            out.push((low >> 24) as u8);
            low <<= 8;
            range <<= 8;
        }
        model.update(sym);
    }
    // flush: enough of `low` for the decoder to disambiguate
    for _ in 0..4 {
        out.push((low >> 24) as u8);
        low <<= 8;
    }
    out
}

/// Decode `n` bytes from a [`rc_encode`] stream into `out` (cleared and
/// refilled — pass a reused buffer for allocation-free paging). A
/// truncated or corrupt stream cannot fail structurally — it decodes to
/// *some* byte string; callers relying on integrity must validate the
/// decoded payload (the artifact reader's per-site structural checks) or
/// compare round-trips (the writer's encode-time verification).
pub fn rc_decode_into(coded: &[u8], n: usize, out: &mut Vec<u8>) {
    out.clear();
    out.reserve(n);
    let mut model = ByteModel::new();
    let mut input = coded.iter().copied();
    let mut next = move || input.next().unwrap_or(0) as u32;
    let mut low: u32 = 0;
    let mut range: u32 = u32::MAX;
    let mut code: u32 = 0;
    for _ in 0..4 {
        code = (code << 8) | next();
    }
    for _ in 0..n {
        range /= model.total;
        let dv = (code.wrapping_sub(low) / range).min(model.total - 1);
        let (sym, cum) = model.find(dv);
        low = low.wrapping_add(cum.wrapping_mul(range));
        range = range.wrapping_mul(model.freq[sym]);
        loop {
            if (low ^ low.wrapping_add(range)) < TOP {
            } else if range < BOT {
                range = low.wrapping_neg() & (BOT - 1);
            } else {
                break;
            }
            code = (code << 8) | next();
            low <<= 8;
            range <<= 8;
        }
        out.push(sym as u8);
        model.update(sym);
    }
}

/// Allocating convenience form of [`rc_decode_into`].
pub fn rc_decode(coded: &[u8], n: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(n);
    rc_decode_into(coded, n, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn round_trip(data: &[u8]) {
        let coded = rc_encode(data);
        let back = rc_decode(&coded, data.len());
        assert_eq!(back, data, "round-trip failed for {} bytes", data.len());
    }

    #[test]
    fn empty_and_tiny_round_trip() {
        round_trip(b"");
        round_trip(b"a");
        round_trip(b"ab");
        round_trip(&[0u8, 255, 0, 255]);
    }

    #[test]
    fn random_bytes_round_trip() {
        let mut rng = Rng::new(11);
        for len in [1usize, 7, 64, 1000, 4096] {
            let data: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            round_trip(&data);
        }
    }

    #[test]
    fn constant_and_skewed_streams_compress() {
        let flat = vec![42u8; 4096];
        let coded = rc_encode(&flat);
        assert!(coded.len() < flat.len() / 8, "constant stream: {} bytes", coded.len());
        round_trip(&flat);
        // 90% zeros, 10% spread: order-0 entropy well under 8 bits/byte
        let mut rng = Rng::new(3);
        let skew: Vec<u8> = (0..4096)
            .map(|_| if rng.below(10) == 0 { rng.below(256) as u8 } else { 0 })
            .collect();
        let coded = rc_encode(&skew);
        assert!(coded.len() < skew.len(), "skewed stream did not shrink");
        round_trip(&skew);
    }

    #[test]
    fn all_byte_values_round_trip() {
        let data: Vec<u8> = (0..=255u8).cycle().take(2048).collect();
        round_trip(&data);
    }

    #[test]
    fn model_halving_keeps_sides_in_sync() {
        // long enough to trigger many MAX_TOTAL halvings
        let mut rng = Rng::new(9);
        let data: Vec<u8> = (0..40_000).map(|_| rng.below(4) as u8).collect();
        round_trip(&data);
    }

    #[test]
    fn decode_into_reuses_the_buffer() {
        let a = rc_encode(b"hello world");
        let b = rc_encode(b"bye");
        let mut buf = Vec::new();
        rc_decode_into(&a, 11, &mut buf);
        assert_eq!(buf, b"hello world");
        rc_decode_into(&b, 3, &mut buf);
        assert_eq!(buf, b"bye");
    }
}
