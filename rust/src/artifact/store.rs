//! The `AWPPACK1` compressed-artifact container and its on-disk store.
//!
//! One file per `(Gram cache key, spec, method)` — a whole model's
//! compressed sites in their packed representations plus their layer
//! reports, so a warm rerun can reproduce both the compressed checkpoint
//! and its per-layer audit trail without submitting a single compression
//! job. Same disk discipline as the Gram cache (`coordinator::cache`):
//!
//! * **rename-atomic writes** — serialise to a unique temp file, then
//!   `rename`, so concurrent sweeps sharing a store never observe a
//!   half-written artifact;
//! * **identity re-validation** — the header stores every identity field
//!   (model, checkpoint/calib fingerprints, method, spec fingerprint and
//!   description); loads compare them against the requested key, so an
//!   FNV collision or a hand-copied file degrades to a recompute;
//! * **corrupt-file recovery** — truncated or inconsistent files produce
//!   a clean `Err`, which [`ArtifactStore::load`] logs and treats as a
//!   miss; the subsequent cold run rewrites (heals) the file.
//!
//! ```text
//! file  = <model>-<key hash:016x>.apack
//!   magic "AWPPACK1" | u64 header_len | header JSON | payload bytes
//!   header: {version, model, checkpoint, calib, method, spec, spec_desc,
//!            compressed_with, sites: [{param, rows, cols, mode, bits,
//!            group, nvalues, offset, report: {...}}, ...]}
//!   payload per site (offset-addressed, layout fixed by its mode):
//!     dense:   rows·cols f32 LE
//!     int:     scales f32 LE | zps f32 LE | bit-packed codes
//!     palette: counts u8     | values f32 LE | bit-packed codes
//!     mask:    mask bytes    | survivor values f32 LE
//! ```

use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, ensure, Context, Result};

use crate::eval::reconstruction::LayerReport;
use crate::util::Json;

use super::codec::{codes_len, PackedLinear};
use super::keys::ArtifactKey;

const MAGIC: &[u8; 8] = b"AWPPACK1";
const VERSION: usize = 1;
/// Implausibility bound for header-declared dimensions (mirrors the Gram
/// cache's untrusted-header discipline).
const MAX_DIM: usize = 1 << 20;

/// One compressed site: its packed weights plus the layer report the
/// pipeline produced when it was compressed.
#[derive(Clone, Debug)]
pub struct ArtifactSite {
    pub param: String,
    pub packed: PackedLinear,
    pub report: LayerReport,
}

/// A whole model's compressed artifact (the unit the store keys).
#[derive(Clone, Debug)]
pub struct ModelArtifact {
    pub model: String,
    /// [`crate::model::Checkpoint::fingerprint`]
    pub checkpoint: u64,
    /// [`crate::coordinator::CalibSpec::fingerprint`]
    pub calib: u64,
    /// [`crate::coordinator::Method::label`]
    pub method: String,
    /// [`crate::compress::traits::CompressionSpec::fingerprint`]
    pub spec: u64,
    pub spec_desc: String,
    /// method-parameter fingerprint ([`ArtifactKey::params`])
    pub params: u64,
    /// compressor name, restored into checkpoint meta (`compressed_with`)
    pub compressed_with: String,
    pub sites: Vec<ArtifactSite>,
}

impl ModelArtifact {
    /// Total serialized payload bytes across sites.
    pub fn packed_bytes(&self) -> usize {
        self.sites.iter().map(|s| s.packed.packed_bytes()).sum()
    }

    /// Total dense f32 bytes for the same sites.
    pub fn dense_bytes(&self) -> usize {
        self.sites.iter().map(|s| s.packed.dense_bytes()).sum()
    }

    /// Identity check against a requested key (the load-time gate).
    pub fn matches_key(&self, key: &ArtifactKey) -> bool {
        self.model == key.gram.model
            && self.checkpoint == key.gram.checkpoint
            && self.calib == key.gram.calib
            && self.method == key.method
            && self.spec == key.spec
            && self.spec_desc == key.spec_desc
            && self.params == key.params
    }

    /// Per-site footprint table: shape, mode, on-disk vs dense bytes and
    /// the compression ratio (`repro inspect`, `--pack-out` summary).
    pub fn footprint_table(&self) -> crate::report::TextTable {
        let mut t = crate::report::TextTable::new(
            format!("Artifact footprint: {} · {} · {}", self.model, self.method,
                    self.spec_desc),
            vec!["site".into(), "shape".into(), "mode".into(), "packed".into(),
                 "dense".into(), "ratio".into()],
        );
        for s in &self.sites {
            let (pb, db) = (s.packed.packed_bytes(), s.packed.dense_bytes());
            t.push_row(vec![
                s.param.clone(),
                format!("{}x{}", s.packed.rows(), s.packed.cols()),
                s.packed.describe(),
                format!("{pb}"),
                format!("{db}"),
                format!("{:.2}x", db as f64 / pb.max(1) as f64),
            ]);
        }
        let (pb, db) = (self.packed_bytes(), self.dense_bytes());
        t.push_row(vec![
            "TOTAL".into(),
            "-".into(),
            format!("packed {pb} bytes"),
            format!("{pb}"),
            format!("{db}"),
            format!("{:.2}x", db as f64 / pb.max(1) as f64),
        ]);
        t
    }
}

// ---------------------------------------------------------------------------
// serialisation

fn f32s_le(data: &[f32]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(data.len() * 4);
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    buf
}

fn site_payload(p: &PackedLinear) -> Vec<u8> {
    let mut buf = Vec::with_capacity(p.packed_bytes());
    match p {
        PackedLinear::Dense { data, .. } => buf.extend_from_slice(&f32s_le(data)),
        PackedLinear::GroupedInt { scales, zps, codes, .. } => {
            buf.extend_from_slice(&f32s_le(scales));
            buf.extend_from_slice(&f32s_le(zps));
            buf.extend_from_slice(codes);
        }
        PackedLinear::Palette { counts, values, codes, .. } => {
            buf.extend_from_slice(counts);
            buf.extend_from_slice(&f32s_le(values));
            buf.extend_from_slice(codes);
        }
        PackedLinear::SparseMask { mask, values, .. } => {
            buf.extend_from_slice(mask);
            buf.extend_from_slice(&f32s_le(values));
        }
    }
    buf
}

fn site_header(s: &ArtifactSite, offset: usize) -> Json {
    let (bits, group, nvalues) = match &s.packed {
        PackedLinear::Dense { .. } => (0usize, 0usize, 0usize),
        PackedLinear::GroupedInt { bits, group, .. } => (*bits as usize, *group, 0),
        PackedLinear::Palette { bits, group, values, .. } => {
            (*bits as usize, *group, values.len())
        }
        PackedLinear::SparseMask { values, .. } => (0, 0, values.len()),
    };
    Json::obj(vec![
        ("param", Json::Str(s.param.clone())),
        ("rows", Json::Num(s.packed.rows() as f64)),
        ("cols", Json::Num(s.packed.cols() as f64)),
        ("mode", Json::Str(s.packed.mode_name().to_string())),
        ("bits", Json::Num(bits as f64)),
        ("group", Json::Num(group as f64)),
        ("nvalues", Json::Num(nvalues as f64)),
        ("offset", Json::Num(offset as f64)),
        ("report", Json::obj(vec![
            ("rel_loss", Json::Num(s.report.rel_loss)),
            ("sparsity", Json::Num(s.report.sparsity)),
            ("row_uniform", Json::Bool(s.report.row_uniform)),
            ("iterations", Json::Num(s.report.iterations as f64)),
            ("seconds", Json::Num(s.report.seconds)),
        ])),
    ])
}

/// Serialise `art` to `path` via a unique temp file + rename (atomic
/// install; concurrent writers of the same artifact are benign because
/// their contents are bit-identical).
pub fn write_artifact(path: &Path, art: &ModelArtifact) -> Result<()> {
    let mut entries = Vec::with_capacity(art.sites.len());
    let mut offset = 0usize;
    for s in &art.sites {
        entries.push(site_header(s, offset));
        offset += s.packed.packed_bytes();
    }
    let header = Json::obj(vec![
        ("version", Json::Num(VERSION as f64)),
        ("model", Json::Str(art.model.clone())),
        ("checkpoint", Json::Str(format!("{:016x}", art.checkpoint))),
        ("calib", Json::Str(format!("{:016x}", art.calib))),
        ("method", Json::Str(art.method.clone())),
        ("spec", Json::Str(format!("{:016x}", art.spec))),
        ("spec_desc", Json::Str(art.spec_desc.clone())),
        ("params", Json::Str(format!("{:016x}", art.params))),
        ("compressed_with", Json::Str(art.compressed_with.clone())),
        ("sites", Json::Arr(entries)),
    ]);
    let hjson = header.to_string().into_bytes();

    // unique per process AND per call: concurrent same-key saves from two
    // executor workers must not interleave writes into one temp file (the
    // gram cache's KeyedOnce dedups same-key computes in-process; the
    // artifact store has no memory layer, so the temp name carries a
    // sequence number too)
    static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = path.parent().unwrap_or_else(|| Path::new("."));
    let stem = path.file_name().and_then(|n| n.to_str()).unwrap_or("artifact");
    let tmp = dir.join(format!("{stem}.tmp.{}.{}", std::process::id(),
                               TMP_SEQ.fetch_add(1, Ordering::Relaxed)));
    {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&(hjson.len() as u64).to_le_bytes())?;
        f.write_all(&hjson)?;
        for s in &art.sites {
            f.write_all(&site_payload(&s.packed))?;
        }
        // explicit flush: a drop-time flush error would be swallowed and a
        // truncated file installed as if the write succeeded
        f.flush().with_context(|| format!("flushing {tmp:?}"))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("installing artifact {path:?}"))?;
    Ok(())
}

fn read_f32s(buf: &[u8]) -> Vec<f32> {
    buf.chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect()
}

/// Take `len` bytes at `*pos` of `payload` with checked arithmetic, so a
/// corrupt header degrades to `Err`, never a panic or a wrapped index.
fn take<'a>(payload: &'a [u8], pos: &mut usize, len: usize, what: &str)
    -> Result<&'a [u8]> {
    let end = pos.checked_add(len).with_context(|| format!("{what}: overflow"))?;
    ensure!(end <= payload.len(),
            "truncated artifact: {what} needs bytes {pos}..{end} of {}",
            payload.len());
    let out = &payload[*pos..end];
    *pos = end;
    Ok(out)
}

fn parse_hex64(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16).with_context(|| format!("bad hex field '{s}'"))
}

fn read_site(e: &Json, payload: &[u8]) -> Result<ArtifactSite> {
    let param = e.expect("param")?.as_str()?.to_string();
    let rows = e.expect("rows")?.as_usize()?;
    let cols = e.expect("cols")?.as_usize()?;
    ensure!(rows >= 1 && rows <= MAX_DIM && cols >= 1 && cols <= MAX_DIM,
            "{param}: implausible shape {rows}x{cols}");
    let n = rows.checked_mul(cols).with_context(|| format!("{param}: size overflow"))?;
    let mode = e.expect("mode")?.as_str()?.to_string();
    let bits = e.expect("bits")?.as_usize()?;
    let group = e.expect("group")?.as_usize()?;
    let nvalues = e.expect("nvalues")?.as_usize()?;
    let mut pos = e.expect("offset")?.as_usize()?;

    let packed = match mode.as_str() {
        "dense" => {
            let data = read_f32s(take(payload, &mut pos, n * 4, &param)?);
            PackedLinear::Dense { rows, cols, data }
        }
        "int" | "palette" => {
            ensure!((1..=8).contains(&bits), "{param}: bad bits {bits}");
            ensure!(group >= 1 && group <= cols && cols % group == 0,
                    "{param}: bad group {group} for width {cols}");
            let ng = rows * (cols / group);
            let clen = codes_len(rows, cols, bits as u8);
            if mode == "int" {
                let scales = read_f32s(take(payload, &mut pos, ng * 4, &param)?);
                let zps = read_f32s(take(payload, &mut pos, ng * 4, &param)?);
                let codes = take(payload, &mut pos, clen, &param)?.to_vec();
                PackedLinear::GroupedInt {
                    rows, cols, bits: bits as u8, group, scales, zps, codes,
                }
            } else {
                let counts = take(payload, &mut pos, ng, &param)?.to_vec();
                let total: usize = counts.iter().map(|&c| c as usize + 1).sum();
                ensure!(total == nvalues,
                        "{param}: palette counts sum {total} != nvalues {nvalues}");
                let values =
                    read_f32s(take(payload, &mut pos, nvalues * 4, &param)?);
                let codes = take(payload, &mut pos, clen, &param)?.to_vec();
                // every code must index inside its group's table, or a
                // later decode would panic on a corrupt file
                let unpacked = crate::quant::pack::unpack_bits(&codes, bits as u8, n);
                for (idx, &q) in unpacked.iter().enumerate() {
                    let gidx = (idx / cols) * (cols / group) + (idx % cols) / group;
                    ensure!((q as usize) <= counts[gidx] as usize,
                            "{param}: code {q} out of table at {idx}");
                }
                PackedLinear::Palette {
                    rows, cols, bits: bits as u8, group, counts, values, codes,
                }
            }
        }
        "mask" => {
            let mask = take(payload, &mut pos, n.div_ceil(8), &param)?.to_vec();
            let set: usize = (0..n)
                .filter(|idx| mask[idx / 8] >> (idx % 8) & 1 == 1)
                .count();
            ensure!(set == nvalues,
                    "{param}: mask popcount {set} != nvalues {nvalues}");
            let values = read_f32s(take(payload, &mut pos, nvalues * 4, &param)?);
            PackedLinear::SparseMask { rows, cols, mask, values }
        }
        other => bail!("{param}: unknown packed mode '{other}'"),
    };

    let r = e.expect("report")?;
    let report = LayerReport {
        param: param.clone(),
        d_out: rows,
        d_in: cols,
        rel_loss: r.expect("rel_loss")?.as_f64()?,
        sparsity: r.expect("sparsity")?.as_f64()?,
        row_uniform: r.expect("row_uniform")?.as_bool()?,
        iterations: r.expect("iterations")?.as_usize()?,
        seconds: r.expect("seconds")?.as_f64()?,
    };
    Ok(ArtifactSite { param, packed, report })
}

/// Parse an artifact file. `Err` on anything inconsistent — callers going
/// through [`ArtifactStore::load`] treat that as a miss; direct consumers
/// (`repro inspect`, `repro eval --from-artifact`) surface it.
pub fn read_artifact(path: &Path) -> Result<ModelArtifact> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic).context("reading magic")?;
    if &magic != MAGIC {
        bail!("{path:?}: not an AWP artifact (bad magic)");
    }
    let mut lenb = [0u8; 8];
    f.read_exact(&mut lenb).context("reading header length")?;
    let hlen = u64::from_le_bytes(lenb) as usize;
    if hlen > 64 << 20 {
        bail!("{path:?}: implausible header length {hlen}");
    }
    let mut hjson = vec![0u8; hlen];
    f.read_exact(&mut hjson).context("reading header")?;
    let header = Json::parse(std::str::from_utf8(&hjson)?)?;
    if header.expect("version")?.as_usize()? != VERSION {
        bail!("{path:?}: unsupported artifact version");
    }
    let mut payload = Vec::new();
    f.read_to_end(&mut payload)?;

    let mut sites = Vec::new();
    for e in header.expect("sites")?.as_arr()? {
        sites.push(read_site(e, &payload).with_context(|| format!("{path:?}"))?);
    }
    Ok(ModelArtifact {
        model: header.expect("model")?.as_str()?.to_string(),
        checkpoint: parse_hex64(header.expect("checkpoint")?.as_str()?)?,
        calib: parse_hex64(header.expect("calib")?.as_str()?)?,
        method: header.expect("method")?.as_str()?.to_string(),
        spec: parse_hex64(header.expect("spec")?.as_str()?)?,
        spec_desc: header.expect("spec_desc")?.as_str()?.to_string(),
        params: parse_hex64(header.expect("params")?.as_str()?)?,
        compressed_with: header.expect("compressed_with")?.as_str()?.to_string(),
        sites,
    })
}

/// Write `art` into `dir` under `key`'s file name (dir created if absent).
pub fn store_artifact(dir: &Path, key: &ArtifactKey, art: &ModelArtifact)
    -> Result<PathBuf> {
    ensure!(art.matches_key(key), "artifact identity does not match its key");
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating artifact dir {dir:?}"))?;
    let path = dir.join(key.file_name());
    write_artifact(&path, art)?;
    Ok(path)
}

/// Load the artifact for `key` from `dir`. `Ok(None)` when absent; `Err`
/// when present but corrupt or belonging to a different identity.
pub fn load_artifact(dir: &Path, key: &ArtifactKey) -> Result<Option<ModelArtifact>> {
    let path = dir.join(key.file_name());
    if !path.exists() {
        return Ok(None);
    }
    let art = read_artifact(&path)?;
    if !art.matches_key(key) {
        bail!("{path:?}: artifact identity mismatch (stale file or hash collision)");
    }
    Ok(Some(art))
}

// ---------------------------------------------------------------------------
// the store

/// Hit/miss counters (snapshot of [`ArtifactStore::counts`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArtifactCounts {
    pub hits: u64,
    pub misses: u64,
    pub stores: u64,
}

/// The on-disk compressed-artifact store: `--artifact-dir` names the
/// directory, `None` disables persistence (every run is cold). Shared
/// across the sweep executor's workers behind an `Arc`; all writes are
/// rename-atomic so the directory can be shared across processes/hosts.
pub struct ArtifactStore {
    dir: Option<PathBuf>,
    hits: AtomicU64,
    misses: AtomicU64,
    stores: AtomicU64,
}

impl ArtifactStore {
    pub fn new(dir: Option<PathBuf>) -> ArtifactStore {
        ArtifactStore {
            dir,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            stores: AtomicU64::new(0),
        }
    }

    /// A store with no disk layer (`--no-artifacts`): loads always miss,
    /// saves are no-ops.
    pub fn disabled() -> ArtifactStore {
        ArtifactStore::new(None)
    }

    pub fn enabled(&self) -> bool {
        self.dir.is_some()
    }

    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    pub fn counts(&self) -> ArtifactCounts {
        ArtifactCounts {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            stores: self.stores.load(Ordering::Relaxed),
        }
    }

    /// Fetch the artifact for `key`, if stored. Corrupt or mismatched
    /// files are logged and treated as a miss (the cold path heals them).
    pub fn load(&self, key: &ArtifactKey) -> Option<ModelArtifact> {
        let dir = self.dir.as_deref()?;
        match load_artifact(dir, key) {
            Ok(Some(art)) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                crate::obs::metrics::REGISTRY.artifact_hits.inc();
                eprintln!("[artifact] hit for '{}' {} {} [{:016x}] — {} sites, \
                           0 compression jobs needed",
                          key.gram.model, key.method, key.spec_desc, key.hash(),
                          art.sites.len());
                Some(art)
            }
            Ok(None) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                crate::obs::metrics::REGISTRY.artifact_misses.inc();
                None
            }
            Err(e) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                crate::obs::metrics::REGISTRY.artifact_misses.inc();
                eprintln!("[artifact] discarding unreadable artifact for '{}' \
                           [{:016x}]: {e:#}", key.gram.model, key.hash());
                None
            }
        }
    }

    /// Persist `art` under `key` (best-effort: failures are logged, the
    /// in-memory result is unaffected).
    pub fn save(&self, key: &ArtifactKey, art: &ModelArtifact) {
        let Some(dir) = self.dir.as_deref() else { return };
        match store_artifact(dir, key, art) {
            Ok(path) => {
                self.stores.fetch_add(1, Ordering::Relaxed);
                crate::obs::metrics::REGISTRY.artifact_stores.inc();
                eprintln!("[artifact] stored '{}' {} at {path:?} ({} → {} bytes, \
                           {:.2}x)",
                          key.gram.model, key.spec_desc, art.dense_bytes(),
                          art.packed_bytes(),
                          art.dense_bytes() as f64 / art.packed_bytes().max(1) as f64);
            }
            Err(e) => eprintln!("[artifact] failed to persist '{}': {e:#}",
                                key.gram.model),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::traits::CompressionSpec;
    use crate::coordinator::cache::GramCacheKey;
    use crate::quant::project_qmax;
    use crate::tensor::Matrix;
    use crate::util::tempdir::TempDir;

    fn key() -> ArtifactKey {
        ArtifactKey::new(
            GramCacheKey { model: "t".into(), checkpoint: 1, calib: 2 },
            "rtn",
            &CompressionSpec::quant(4, 32),
        )
    }

    fn report(param: &str, rows: usize, cols: usize) -> LayerReport {
        LayerReport {
            param: param.into(), d_out: rows, d_in: cols, rel_loss: 0.125,
            sparsity: 0.5, row_uniform: true, iterations: 7, seconds: 0.25,
        }
    }

    fn artifact() -> ModelArtifact {
        let spec = CompressionSpec::quant(4, 32);
        let theta = project_qmax(&Matrix::randn(4, 64, 3), 15.0, 32);
        let packed = PackedLinear::encode(&theta, &spec);
        let k = key();
        ModelArtifact {
            model: "t".into(),
            checkpoint: 1,
            calib: 2,
            method: "rtn".into(),
            spec: k.spec,
            spec_desc: k.spec_desc,
            params: k.params,
            compressed_with: "rtn".into(),
            sites: vec![ArtifactSite {
                param: "blocks.0.wq".into(),
                packed,
                report: report("blocks.0.wq", 4, 64),
            }],
        }
    }

    #[test]
    fn file_round_trip_is_bit_exact() {
        let dir = TempDir::new("apack").unwrap();
        let art = artifact();
        let path = store_artifact(dir.path(), &key(), &art).unwrap();
        let back = read_artifact(&path).unwrap();
        assert_eq!(back.model, "t");
        assert_eq!(back.compressed_with, "rtn");
        assert_eq!(back.sites.len(), 1);
        let (a, b) = (&art.sites[0], &back.sites[0]);
        assert_eq!(a.param, b.param);
        assert_eq!(a.report.rel_loss, b.report.rel_loss);
        assert_eq!(a.report.iterations, b.report.iterations);
        let (da, db) = (a.packed.decode(), b.packed.decode());
        for (x, y) in da.data.iter().zip(&db.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn absent_is_a_clean_miss() {
        let dir = TempDir::new("apack").unwrap();
        assert!(load_artifact(dir.path(), &key()).unwrap().is_none());
    }

    #[test]
    fn corrupt_truncated_and_mismatched_files_error() {
        let dir = TempDir::new("apack").unwrap();
        let k = key();
        // garbage
        std::fs::create_dir_all(dir.path()).unwrap();
        std::fs::write(dir.path().join(k.file_name()), b"garbage").unwrap();
        assert!(load_artifact(dir.path(), &k).is_err());
        // truncated payload
        let art = artifact();
        let path = store_artifact(dir.path(), &k, &art).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 32]).unwrap();
        assert!(load_artifact(dir.path(), &k).is_err());
        // identity mismatch: valid file under another key's name
        store_artifact(dir.path(), &k, &art).unwrap();
        let other = ArtifactKey::new(
            GramCacheKey { model: "t".into(), checkpoint: 9, calib: 2 },
            "rtn",
            &CompressionSpec::quant(4, 32),
        );
        std::fs::rename(dir.path().join(k.file_name()),
                        dir.path().join(other.file_name()))
            .unwrap();
        assert!(load_artifact(dir.path(), &other).is_err());
    }

    #[test]
    fn store_counts_hits_and_heals_corruption() {
        let dir = TempDir::new("apack").unwrap();
        let k = key();
        let store = ArtifactStore::new(Some(dir.path().to_path_buf()));
        assert!(store.load(&k).is_none());
        store.save(&k, &artifact());
        assert!(store.load(&k).is_some());
        let c = store.counts();
        assert_eq!((c.hits, c.misses, c.stores), (1, 1, 1));
        // corrupt the file: next load logs + misses, save heals
        std::fs::write(dir.path().join(k.file_name()), b"AWPPACK1junk").unwrap();
        assert!(store.load(&k).is_none());
        store.save(&k, &artifact());
        assert!(store.load(&k).is_some());
    }

    #[test]
    fn disabled_store_is_inert() {
        let store = ArtifactStore::disabled();
        assert!(!store.enabled());
        assert!(store.load(&key()).is_none());
        store.save(&key(), &artifact());
        assert_eq!(store.counts().stores, 0);
    }

    #[test]
    fn footprint_table_totals() {
        let art = artifact();
        let t = art.footprint_table();
        let con = t.to_console();
        assert!(con.contains("blocks.0.wq"), "{con}");
        assert!(con.contains("TOTAL"), "{con}");
        assert!(art.packed_bytes() < art.dense_bytes());
    }

    #[test]
    fn key_mismatch_rejected_at_store_time() {
        let dir = TempDir::new("apack").unwrap();
        let mut art = artifact();
        art.method = "wanda".into();
        assert!(store_artifact(dir.path(), &key(), &art).is_err());
    }
}
