//! The `AWPPACK1`/`AWPPACK2` compressed-artifact containers and their
//! on-disk store.
//!
//! One file per `(Gram cache key, spec, method)` — a whole model's
//! compressed sites in their packed representations plus their layer
//! reports, so a warm rerun can reproduce both the compressed checkpoint
//! and its per-layer audit trail without submitting a single compression
//! job. Same disk discipline as the Gram cache (`coordinator::cache`):
//!
//! * **rename-atomic writes** — serialise to a unique temp file, then
//!   `rename`, so concurrent sweeps sharing a store never observe a
//!   half-written artifact;
//! * **identity re-validation** — the header stores every identity field
//!   (model, checkpoint/calib fingerprints, method, spec fingerprint and
//!   description); loads compare them against the requested key, so an
//!   FNV collision or a hand-copied file degrades to a recompute;
//! * **corrupt-file recovery** — truncated or inconsistent files produce
//!   a clean `Err`, which [`ArtifactStore::load`] logs and treats as a
//!   miss; the subsequent cold run rewrites (heals) the file.
//!
//! ```text
//! file  = <model>-<key hash:016x>.apack
//!   magic "AWPPACK1" | u64 header_len | header JSON | payload bytes
//!   header: {version, model, checkpoint, calib, method, spec, spec_desc,
//!            compressed_with, sites: [{param, rows, cols, mode, bits,
//!            group, nvalues, offset, report: {...}}, ...]}
//!   payload per site (offset-addressed, layout fixed by its mode):
//!     dense:   rows·cols f32 LE
//!     int:     scales f32 LE | zps f32 LE | bit-packed codes
//!     palette: counts u8     | values f32 LE | bit-packed codes
//!     mask:    mask bytes    | survivor values f32 LE
//! ```
//!
//! `AWPPACK2` is the same container with a lossless second stage: each
//! site's payload may be range-coded ([`super::pack2`]), in which case
//! its header entry carries `enc: "rc"` plus the stored (`clen`) byte
//! length; sites where coding does not win stay `enc: "raw"`. Site
//! offsets address *stored* bytes, so the header alone still locates
//! every site.
//!
//! The header is self-sufficient: every site's stored byte range and raw
//! payload length are computable from its header entry alone. That is
//! the contract the model-weight pager ([`super::pager`]) builds on —
//! [`read_artifact_header`] reads nothing past the header, and
//! [`decode_site_bytes`] materialises one site from its bytes on demand,
//! carrying the structural validation (palette code bounds, mask
//! popcounts) that used to run at load time.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, ensure, Context, Result};

use crate::eval::reconstruction::LayerReport;
use crate::util::Json;

use super::codec::{codes_len, PackedLinear};
use super::keys::ArtifactKey;
use super::pack2::{rc_decode, rc_decode_into, rc_encode};

const MAGIC: &[u8; 8] = b"AWPPACK1";
const MAGIC2: &[u8; 8] = b"AWPPACK2";
const VERSION: usize = 1;
const VERSION2: usize = 2;
/// Implausibility bound for header-declared dimensions (mirrors the Gram
/// cache's untrusted-header discipline).
const MAX_DIM: usize = 1 << 20;

/// One compressed site: its packed weights plus the layer report the
/// pipeline produced when it was compressed.
#[derive(Clone, Debug)]
pub struct ArtifactSite {
    pub param: String,
    pub packed: PackedLinear,
    pub report: LayerReport,
}

/// A whole model's compressed artifact (the unit the store keys).
#[derive(Clone, Debug)]
pub struct ModelArtifact {
    pub model: String,
    /// [`crate::model::Checkpoint::fingerprint`]
    pub checkpoint: u64,
    /// [`crate::coordinator::CalibSpec::fingerprint`]
    pub calib: u64,
    /// [`crate::coordinator::Method::label`]
    pub method: String,
    /// [`crate::compress::traits::CompressionSpec::fingerprint`]
    pub spec: u64,
    pub spec_desc: String,
    /// method-parameter fingerprint ([`ArtifactKey::params`])
    pub params: u64,
    /// compressor name, restored into checkpoint meta (`compressed_with`)
    pub compressed_with: String,
    pub sites: Vec<ArtifactSite>,
}

impl ModelArtifact {
    /// Total serialized payload bytes across sites.
    pub fn packed_bytes(&self) -> usize {
        self.sites.iter().map(|s| s.packed.packed_bytes()).sum()
    }

    /// Total dense f32 bytes for the same sites.
    pub fn dense_bytes(&self) -> usize {
        self.sites.iter().map(|s| s.packed.dense_bytes()).sum()
    }

    /// Identity check against a requested key (the load-time gate).
    pub fn matches_key(&self, key: &ArtifactKey) -> bool {
        self.model == key.gram.model
            && self.checkpoint == key.gram.checkpoint
            && self.calib == key.gram.calib
            && self.method == key.method
            && self.spec == key.spec
            && self.spec_desc == key.spec_desc
            && self.params == key.params
    }

    /// Per-site footprint table: shape, mode, on-disk vs dense bytes and
    /// the compression ratio (`repro inspect`, `--pack-out` summary).
    pub fn footprint_table(&self) -> crate::report::TextTable {
        let mut t = crate::report::TextTable::new(
            format!("Artifact footprint: {} · {} · {}", self.model, self.method,
                    self.spec_desc),
            vec!["site".into(), "shape".into(), "mode".into(), "packed".into(),
                 "dense".into(), "ratio".into()],
        );
        for s in &self.sites {
            let (pb, db) = (s.packed.packed_bytes(), s.packed.dense_bytes());
            t.push_row(vec![
                s.param.clone(),
                format!("{}x{}", s.packed.rows(), s.packed.cols()),
                s.packed.describe(),
                format!("{pb}"),
                format!("{db}"),
                format!("{:.2}x", db as f64 / pb.max(1) as f64),
            ]);
        }
        let (pb, db) = (self.packed_bytes(), self.dense_bytes());
        t.push_row(vec![
            "TOTAL".into(),
            "-".into(),
            format!("packed {pb} bytes"),
            format!("{pb}"),
            format!("{db}"),
            format!("{:.2}x", db as f64 / pb.max(1) as f64),
        ]);
        t
    }
}

// ---------------------------------------------------------------------------
// site metadata (the header's view of a site — no payload bytes)

/// Payload encoding of one site: stored bytes as-is (`raw`, the only v1
/// form) or range-coded through the `AWPPACK2` second stage (`rc`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SiteEnc {
    Raw,
    Rc,
}

/// One site's header entry — everything needed to locate, size and later
/// decode its payload without touching any payload bytes. Header-level
/// (cheap, shape/offset arithmetic) validation happens at parse time;
/// payload-level structural validation (palette code bounds, mask
/// popcounts) is deferred to [`decode_site_bytes`], i.e. first touch.
#[derive(Clone, Debug)]
pub struct SiteMeta {
    pub param: String,
    pub rows: usize,
    pub cols: usize,
    pub mode: String,
    pub bits: usize,
    pub group: usize,
    pub nvalues: usize,
    /// byte offset of this site's stored bytes inside the payload region
    pub offset: usize,
    /// raw (decoded) payload length, computed from shape + mode
    pub raw_len: usize,
    pub enc: SiteEnc,
    /// stored byte length in the file (equals `raw_len` when raw)
    pub stored_len: usize,
    pub report: LayerReport,
}

impl SiteMeta {
    /// Dense f32 footprint of this site (header-only).
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }
}

fn parse_site_meta(e: &Json, pack2: bool) -> Result<SiteMeta> {
    let param = e.expect("param")?.as_str()?.to_string();
    let rows = e.expect("rows")?.as_usize()?;
    let cols = e.expect("cols")?.as_usize()?;
    ensure!(rows >= 1 && rows <= MAX_DIM && cols >= 1 && cols <= MAX_DIM,
            "{param}: implausible shape {rows}x{cols}");
    let n = rows.checked_mul(cols).with_context(|| format!("{param}: size overflow"))?;
    let mode = e.expect("mode")?.as_str()?.to_string();
    let bits = e.expect("bits")?.as_usize()?;
    let group = e.expect("group")?.as_usize()?;
    let nvalues = e.expect("nvalues")?.as_usize()?;
    let offset = e.expect("offset")?.as_usize()?;

    // raw payload length is a pure function of the header entry — the
    // invariant the pager's offset-addressed site ranges rely on
    let raw_len = match mode.as_str() {
        "dense" => n * 4,
        "int" | "palette" => {
            ensure!((1..=8).contains(&bits), "{param}: bad bits {bits}");
            ensure!(group >= 1 && group <= cols && cols % group == 0,
                    "{param}: bad group {group} for width {cols}");
            let ng = rows * (cols / group);
            if mode == "int" {
                ng * 4 + ng * 4 + codes_len(rows, cols, bits as u8)
            } else {
                ensure!(nvalues <= 256 * ng,
                        "{param}: implausible palette size {nvalues}");
                ng + nvalues * 4 + codes_len(rows, cols, bits as u8)
            }
        }
        "mask" => {
            ensure!(nvalues <= n, "{param}: mask nvalues {nvalues} > size {n}");
            n.div_ceil(8) + nvalues * 4
        }
        other => bail!("{param}: unknown packed mode '{other}'"),
    };

    let (enc, stored_len) = if pack2 {
        let enc = match e.expect("enc")?.as_str()? {
            "raw" => SiteEnc::Raw,
            "rc" => SiteEnc::Rc,
            other => bail!("{param}: unknown site encoding '{other}'"),
        };
        let stored_len = e.expect("clen")?.as_usize()?;
        match enc {
            SiteEnc::Raw => ensure!(stored_len == raw_len,
                    "{param}: raw clen {stored_len} != payload {raw_len}"),
            SiteEnc::Rc => ensure!(stored_len <= raw_len,
                    "{param}: coded clen {stored_len} exceeds raw {raw_len}"),
        }
        (enc, stored_len)
    } else {
        (SiteEnc::Raw, raw_len)
    };

    let r = e.expect("report")?;
    let report = LayerReport {
        param: param.clone(),
        d_out: rows,
        d_in: cols,
        rel_loss: r.expect("rel_loss")?.as_f64()?,
        sparsity: r.expect("sparsity")?.as_f64()?,
        row_uniform: r.expect("row_uniform")?.as_bool()?,
        iterations: r.expect("iterations")?.as_usize()?,
        seconds: r.expect("seconds")?.as_f64()?,
    };
    Ok(SiteMeta {
        param, rows, cols, mode, bits, group, nvalues, offset, raw_len,
        enc, stored_len, report,
    })
}

/// Parsed artifact header: identity fields plus per-site metadata and the
/// file offset where the payload region begins. This is everything an
/// open needs — no payload bytes are read to produce one.
#[derive(Clone, Debug)]
pub struct ArtifactHeader {
    pub model: String,
    pub checkpoint: u64,
    pub calib: u64,
    pub method: String,
    pub spec: u64,
    pub spec_desc: String,
    pub params: u64,
    pub compressed_with: String,
    /// true for `AWPPACK2` containers (second-stage coding allowed)
    pub pack2: bool,
    pub sites: Vec<SiteMeta>,
    /// absolute file offset of the payload region
    pub payload_start: u64,
}

impl ArtifactHeader {
    /// Identity check against a requested key (the load-time gate).
    pub fn matches_key(&self, key: &ArtifactKey) -> bool {
        self.model == key.gram.model
            && self.checkpoint == key.gram.checkpoint
            && self.calib == key.gram.calib
            && self.method == key.method
            && self.spec == key.spec
            && self.spec_desc == key.spec_desc
            && self.params == key.params
    }

    /// Raw (decoded) payload bytes across sites — equal to
    /// [`ModelArtifact::packed_bytes`] for the same artifact.
    pub fn packed_bytes(&self) -> usize {
        self.sites.iter().map(|s| s.raw_len).sum()
    }

    /// Stored payload bytes in the file (smaller than
    /// [`ArtifactHeader::packed_bytes`] where the second stage won).
    pub fn stored_bytes(&self) -> usize {
        self.sites.iter().map(|s| s.stored_len).sum()
    }

    /// Dense f32 bytes for the same sites.
    pub fn dense_bytes(&self) -> usize {
        self.sites.iter().map(|s| s.dense_bytes()).sum()
    }
}

/// Read and parse only the container header (magic, length, JSON) from
/// `f`, leaving the reader positioned at the first payload byte. The
/// returned header fully describes every site's stored byte range; no
/// payload bytes are consumed.
pub fn read_artifact_header<R: Read>(f: &mut R, path: &Path) -> Result<ArtifactHeader> {
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic).context("reading magic")?;
    let pack2 = if &magic == MAGIC {
        false
    } else if &magic == MAGIC2 {
        true
    } else {
        bail!("{path:?}: not an AWP artifact (bad magic)");
    };
    let mut lenb = [0u8; 8];
    f.read_exact(&mut lenb).context("reading header length")?;
    let hlen = u64::from_le_bytes(lenb) as usize;
    if hlen > 64 << 20 {
        bail!("{path:?}: implausible header length {hlen}");
    }
    let mut hjson = vec![0u8; hlen];
    f.read_exact(&mut hjson).context("reading header")?;
    let header = Json::parse(std::str::from_utf8(&hjson)?)?;
    let version = header.expect("version")?.as_usize()?;
    let expected = if pack2 { VERSION2 } else { VERSION };
    if version != expected {
        bail!("{path:?}: unsupported artifact version {version}");
    }
    let mut sites = Vec::new();
    for e in header.expect("sites")?.as_arr()? {
        sites.push(parse_site_meta(e, pack2).with_context(|| format!("{path:?}"))?);
    }
    // sites must tile the payload region contiguously — rejects headers
    // whose offsets alias or leave holes, and makes a sequential
    // seek-free read correct by construction
    let mut at = 0usize;
    for s in &sites {
        ensure!(s.offset == at,
                "{path:?}: {}: offset {} != expected {at}", s.param, s.offset);
        at = at.checked_add(s.stored_len)
            .with_context(|| format!("{}: offset overflow", s.param))?;
    }
    Ok(ArtifactHeader {
        model: header.expect("model")?.as_str()?.to_string(),
        checkpoint: parse_hex64(header.expect("checkpoint")?.as_str()?)?,
        calib: parse_hex64(header.expect("calib")?.as_str()?)?,
        method: header.expect("method")?.as_str()?.to_string(),
        spec: parse_hex64(header.expect("spec")?.as_str()?)?,
        spec_desc: header.expect("spec_desc")?.as_str()?.to_string(),
        params: parse_hex64(header.expect("params")?.as_str()?)?,
        compressed_with: header.expect("compressed_with")?.as_str()?.to_string(),
        pack2,
        sites,
        payload_start: (8 + 8 + hlen) as u64,
    })
}

// ---------------------------------------------------------------------------
// serialisation

fn f32s_le(data: &[f32]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(data.len() * 4);
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    buf
}

fn site_payload(p: &PackedLinear) -> Vec<u8> {
    let mut buf = Vec::with_capacity(p.packed_bytes());
    match p {
        PackedLinear::Dense { data, .. } => buf.extend_from_slice(&f32s_le(data)),
        PackedLinear::GroupedInt { scales, zps, codes, .. } => {
            buf.extend_from_slice(&f32s_le(scales));
            buf.extend_from_slice(&f32s_le(zps));
            buf.extend_from_slice(codes);
        }
        PackedLinear::Palette { counts, values, codes, .. } => {
            buf.extend_from_slice(counts);
            buf.extend_from_slice(&f32s_le(values));
            buf.extend_from_slice(codes);
        }
        PackedLinear::SparseMask { mask, values, .. } => {
            buf.extend_from_slice(mask);
            buf.extend_from_slice(&f32s_le(values));
        }
    }
    buf
}

fn site_header(s: &ArtifactSite, offset: usize, enc: Option<(&str, usize)>) -> Json {
    let (bits, group, nvalues) = match &s.packed {
        PackedLinear::Dense { .. } => (0usize, 0usize, 0usize),
        PackedLinear::GroupedInt { bits, group, .. } => (*bits as usize, *group, 0),
        PackedLinear::Palette { bits, group, values, .. } => {
            (*bits as usize, *group, values.len())
        }
        PackedLinear::SparseMask { values, .. } => (0, 0, values.len()),
    };
    let mut fields = vec![
        ("param", Json::Str(s.param.clone())),
        ("rows", Json::Num(s.packed.rows() as f64)),
        ("cols", Json::Num(s.packed.cols() as f64)),
        ("mode", Json::Str(s.packed.mode_name().to_string())),
        ("bits", Json::Num(bits as f64)),
        ("group", Json::Num(group as f64)),
        ("nvalues", Json::Num(nvalues as f64)),
        ("offset", Json::Num(offset as f64)),
    ];
    if let Some((enc, clen)) = enc {
        fields.push(("enc", Json::Str(enc.to_string())));
        fields.push(("clen", Json::Num(clen as f64)));
    }
    fields.push(("report", Json::obj(vec![
        ("rel_loss", Json::Num(s.report.rel_loss)),
        ("sparsity", Json::Num(s.report.sparsity)),
        ("row_uniform", Json::Bool(s.report.row_uniform)),
        ("iterations", Json::Num(s.report.iterations as f64)),
        ("seconds", Json::Num(s.report.seconds)),
    ])));
    Json::obj(fields)
}

/// Serialise `art` to `path` as `AWPPACK1` via a unique temp file +
/// rename (atomic install; concurrent writers of the same artifact are
/// benign because their contents are bit-identical).
pub fn write_artifact(path: &Path, art: &ModelArtifact) -> Result<()> {
    write_artifact_opts(path, art, false)
}

/// [`write_artifact`] with container selection. With `pack2` the file is
/// `AWPPACK2`: each site's payload is offered to the lossless second
/// stage ([`rc_encode`]) and stored coded only when that is strictly
/// smaller *and* verified at encode time to round-trip bit-identically;
/// otherwise the site stays raw — a v2 payload is never larger than its
/// v1 equivalent.
pub fn write_artifact_opts(path: &Path, art: &ModelArtifact, pack2: bool) -> Result<()> {
    let mut entries = Vec::with_capacity(art.sites.len());
    let mut payloads = Vec::with_capacity(art.sites.len());
    let mut offset = 0usize;
    for s in &art.sites {
        let raw = site_payload(&s.packed);
        let (enc, bytes) = if pack2 {
            let coded = rc_encode(&raw);
            if coded.len() < raw.len() && rc_decode(&coded, raw.len()) == raw {
                ("rc", coded)
            } else {
                ("raw", raw)
            }
        } else {
            ("raw", raw)
        };
        entries.push(site_header(s, offset, pack2.then(|| (enc, bytes.len()))));
        offset += bytes.len();
        payloads.push(bytes);
    }
    let header = Json::obj(vec![
        ("version", Json::Num(if pack2 { VERSION2 } else { VERSION } as f64)),
        ("model", Json::Str(art.model.clone())),
        ("checkpoint", Json::Str(format!("{:016x}", art.checkpoint))),
        ("calib", Json::Str(format!("{:016x}", art.calib))),
        ("method", Json::Str(art.method.clone())),
        ("spec", Json::Str(format!("{:016x}", art.spec))),
        ("spec_desc", Json::Str(art.spec_desc.clone())),
        ("params", Json::Str(format!("{:016x}", art.params))),
        ("compressed_with", Json::Str(art.compressed_with.clone())),
        ("sites", Json::Arr(entries)),
    ]);
    let hjson = header.to_string().into_bytes();

    // unique per process AND per call: concurrent same-key saves from two
    // executor workers must not interleave writes into one temp file (the
    // gram cache's KeyedOnce dedups same-key computes in-process; the
    // artifact store has no memory layer, so the temp name carries a
    // sequence number too)
    static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = path.parent().unwrap_or_else(|| Path::new("."));
    let stem = path.file_name().and_then(|n| n.to_str()).unwrap_or("artifact");
    let tmp = dir.join(format!("{stem}.tmp.{}.{}", std::process::id(),
                               TMP_SEQ.fetch_add(1, Ordering::Relaxed)));
    {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?,
        );
        f.write_all(if pack2 { MAGIC2 } else { MAGIC })?;
        f.write_all(&(hjson.len() as u64).to_le_bytes())?;
        f.write_all(&hjson)?;
        for p in &payloads {
            f.write_all(p)?;
        }
        // explicit flush: a drop-time flush error would be swallowed and a
        // truncated file installed as if the write succeeded
        f.flush().with_context(|| format!("flushing {tmp:?}"))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("installing artifact {path:?}"))?;
    Ok(())
}

fn read_f32s(buf: &[u8]) -> Vec<f32> {
    buf.chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect()
}

/// Take `len` bytes at `*pos` of `payload` with checked arithmetic, so a
/// corrupt header degrades to `Err`, never a panic or a wrapped index.
fn take<'a>(payload: &'a [u8], pos: &mut usize, len: usize, what: &str)
    -> Result<&'a [u8]> {
    let end = pos.checked_add(len).with_context(|| format!("{what}: overflow"))?;
    ensure!(end <= payload.len(),
            "truncated artifact: {what} needs bytes {pos}..{end} of {}",
            payload.len());
    let out = &payload[*pos..end];
    *pos = end;
    Ok(out)
}

fn parse_hex64(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16).with_context(|| format!("bad hex field '{s}'"))
}

/// Decode one site's raw payload bytes into a [`PackedLinear`], running
/// the structural validation deferred from header parse time (palette
/// code bounds, mask popcount, palette-count consistency). `scratch` is
/// caller-provided so repeated first-touch validation — the pager's
/// page-in path — allocates nothing beyond the materialised weights
/// themselves.
pub fn decode_site_bytes(meta: &SiteMeta, bytes: &[u8], scratch: &mut Vec<u8>)
    -> Result<PackedLinear> {
    let param = meta.param.as_str();
    let (rows, cols) = (meta.rows, meta.cols);
    let n = rows * cols;
    ensure!(bytes.len() == meta.raw_len,
            "{param}: site payload is {} bytes, expected {}",
            bytes.len(), meta.raw_len);
    let mut pos = 0usize;
    let packed = match meta.mode.as_str() {
        "dense" => {
            let data = read_f32s(take(bytes, &mut pos, n * 4, param)?);
            PackedLinear::Dense { rows, cols, data }
        }
        "int" => {
            let ng = rows * (cols / meta.group);
            let clen = codes_len(rows, cols, meta.bits as u8);
            let scales = read_f32s(take(bytes, &mut pos, ng * 4, param)?);
            let zps = read_f32s(take(bytes, &mut pos, ng * 4, param)?);
            let codes = take(bytes, &mut pos, clen, param)?.to_vec();
            PackedLinear::GroupedInt {
                rows, cols, bits: meta.bits as u8, group: meta.group,
                scales, zps, codes,
            }
        }
        "palette" => {
            let ng = rows * (cols / meta.group);
            let clen = codes_len(rows, cols, meta.bits as u8);
            let counts = take(bytes, &mut pos, ng, param)?.to_vec();
            let total: usize = counts.iter().map(|&c| c as usize + 1).sum();
            ensure!(total == meta.nvalues,
                    "{param}: palette counts sum {total} != nvalues {}",
                    meta.nvalues);
            let values = read_f32s(take(bytes, &mut pos, meta.nvalues * 4, param)?);
            let codes = take(bytes, &mut pos, clen, param)?.to_vec();
            // every code must index inside its group's table, or a later
            // decode would panic on a corrupt file
            scratch.resize(n, 0);
            crate::quant::pack::unpack_bits_into(&codes, meta.bits as u8, 0,
                                                 &mut scratch[..n]);
            for (idx, &q) in scratch[..n].iter().enumerate() {
                let gidx =
                    (idx / cols) * (cols / meta.group) + (idx % cols) / meta.group;
                ensure!((q as usize) <= counts[gidx] as usize,
                        "{param}: code {q} out of table at {idx}");
            }
            PackedLinear::Palette {
                rows, cols, bits: meta.bits as u8, group: meta.group,
                counts, values, codes,
            }
        }
        "mask" => {
            let mask = take(bytes, &mut pos, n.div_ceil(8), param)?.to_vec();
            let set: usize = (0..n)
                .filter(|idx| mask[idx / 8] >> (idx % 8) & 1 == 1)
                .count();
            ensure!(set == meta.nvalues,
                    "{param}: mask popcount {set} != nvalues {}", meta.nvalues);
            let values = read_f32s(take(bytes, &mut pos, meta.nvalues * 4, param)?);
            PackedLinear::SparseMask { rows, cols, mask, values }
        }
        // parse_site_meta already rejected unknown modes; kept for safety
        other => bail!("{param}: unknown packed mode '{other}'"),
    };
    Ok(packed)
}

/// Parse an artifact file eagerly (all sites materialised). `Err` on
/// anything inconsistent — callers going through [`ArtifactStore::load`]
/// treat that as a miss; direct consumers (`repro inspect`, `repro eval
/// --from-artifact`) surface it. Reads the payload site by site into
/// bounded reusable buffers — never the whole payload at once; lazy
/// consumers use [`super::pager::ArtifactPager`] instead and touch no
/// payload bytes at open.
pub fn read_artifact(path: &Path) -> Result<ModelArtifact> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
    );
    let header = read_artifact_header(&mut f, path)?;
    let mut sites = Vec::with_capacity(header.sites.len());
    let mut stored = Vec::new();
    let mut raw = Vec::new();
    let mut scratch = Vec::new();
    for meta in &header.sites {
        // site offsets tile the payload contiguously (checked by the
        // header parse), so a sequential read needs no seeking
        stored.resize(meta.stored_len, 0);
        f.read_exact(&mut stored).with_context(|| {
            format!("{path:?}: {}: reading {} stored bytes",
                    meta.param, meta.stored_len)
        })?;
        let bytes: &[u8] = match meta.enc {
            SiteEnc::Raw => &stored,
            SiteEnc::Rc => {
                rc_decode_into(&stored, meta.raw_len, &mut raw);
                &raw
            }
        };
        let packed = decode_site_bytes(meta, bytes, &mut scratch)
            .with_context(|| format!("{path:?}"))?;
        sites.push(ArtifactSite {
            param: meta.param.clone(),
            packed,
            report: meta.report.clone(),
        });
    }
    Ok(ModelArtifact {
        model: header.model,
        checkpoint: header.checkpoint,
        calib: header.calib,
        method: header.method,
        spec: header.spec,
        spec_desc: header.spec_desc,
        params: header.params,
        compressed_with: header.compressed_with,
        sites,
    })
}

/// Write `art` into `dir` under `key`'s file name (dir created if absent).
pub fn store_artifact(dir: &Path, key: &ArtifactKey, art: &ModelArtifact)
    -> Result<PathBuf> {
    ensure!(art.matches_key(key), "artifact identity does not match its key");
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating artifact dir {dir:?}"))?;
    let path = dir.join(key.file_name());
    write_artifact(&path, art)?;
    Ok(path)
}

/// Load the artifact for `key` from `dir`. `Ok(None)` when absent; `Err`
/// when present but corrupt or belonging to a different identity.
pub fn load_artifact(dir: &Path, key: &ArtifactKey) -> Result<Option<ModelArtifact>> {
    let path = dir.join(key.file_name());
    if !path.exists() {
        return Ok(None);
    }
    let art = read_artifact(&path)?;
    if !art.matches_key(key) {
        bail!("{path:?}: artifact identity mismatch (stale file or hash collision)");
    }
    Ok(Some(art))
}

// ---------------------------------------------------------------------------
// the store

/// Hit/miss counters (snapshot of [`ArtifactStore::counts`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArtifactCounts {
    pub hits: u64,
    pub misses: u64,
    pub stores: u64,
}

/// The on-disk compressed-artifact store: `--artifact-dir` names the
/// directory, `None` disables persistence (every run is cold). Shared
/// across the sweep executor's workers behind an `Arc`; all writes are
/// rename-atomic so the directory can be shared across processes/hosts.
pub struct ArtifactStore {
    dir: Option<PathBuf>,
    hits: AtomicU64,
    misses: AtomicU64,
    stores: AtomicU64,
}

impl ArtifactStore {
    pub fn new(dir: Option<PathBuf>) -> ArtifactStore {
        ArtifactStore {
            dir,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            stores: AtomicU64::new(0),
        }
    }

    /// A store with no disk layer (`--no-artifacts`): loads always miss,
    /// saves are no-ops.
    pub fn disabled() -> ArtifactStore {
        ArtifactStore::new(None)
    }

    pub fn enabled(&self) -> bool {
        self.dir.is_some()
    }

    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    pub fn counts(&self) -> ArtifactCounts {
        ArtifactCounts {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            stores: self.stores.load(Ordering::Relaxed),
        }
    }

    /// Fetch the artifact for `key`, if stored. Corrupt or mismatched
    /// files are logged and treated as a miss (the cold path heals them).
    pub fn load(&self, key: &ArtifactKey) -> Option<ModelArtifact> {
        let dir = self.dir.as_deref()?;
        match load_artifact(dir, key) {
            Ok(Some(art)) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                crate::obs::metrics::REGISTRY.artifact_hits.inc();
                eprintln!("[artifact] hit for '{}' {} {} [{:016x}] — {} sites, \
                           0 compression jobs needed",
                          key.gram.model, key.method, key.spec_desc, key.hash(),
                          art.sites.len());
                Some(art)
            }
            Ok(None) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                crate::obs::metrics::REGISTRY.artifact_misses.inc();
                None
            }
            Err(e) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                crate::obs::metrics::REGISTRY.artifact_misses.inc();
                eprintln!("[artifact] discarding unreadable artifact for '{}' \
                           [{:016x}]: {e:#}", key.gram.model, key.hash());
                None
            }
        }
    }

    /// Persist `art` under `key` (best-effort: failures are logged, the
    /// in-memory result is unaffected).
    pub fn save(&self, key: &ArtifactKey, art: &ModelArtifact) {
        let Some(dir) = self.dir.as_deref() else { return };
        match store_artifact(dir, key, art) {
            Ok(path) => {
                self.stores.fetch_add(1, Ordering::Relaxed);
                crate::obs::metrics::REGISTRY.artifact_stores.inc();
                eprintln!("[artifact] stored '{}' {} at {path:?} ({} → {} bytes, \
                           {:.2}x)",
                          key.gram.model, key.spec_desc, art.dense_bytes(),
                          art.packed_bytes(),
                          art.dense_bytes() as f64 / art.packed_bytes().max(1) as f64);
            }
            Err(e) => eprintln!("[artifact] failed to persist '{}': {e:#}",
                                key.gram.model),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::traits::CompressionSpec;
    use crate::coordinator::cache::GramCacheKey;
    use crate::quant::project_qmax;
    use crate::tensor::Matrix;
    use crate::util::tempdir::TempDir;

    fn key() -> ArtifactKey {
        ArtifactKey::new(
            GramCacheKey { model: "t".into(), checkpoint: 1, calib: 2 },
            "rtn",
            &CompressionSpec::quant(4, 32),
        )
    }

    fn report(param: &str, rows: usize, cols: usize) -> LayerReport {
        LayerReport {
            param: param.into(), d_out: rows, d_in: cols, rel_loss: 0.125,
            sparsity: 0.5, row_uniform: true, iterations: 7, seconds: 0.25,
        }
    }

    fn artifact() -> ModelArtifact {
        let spec = CompressionSpec::quant(4, 32);
        let theta = project_qmax(&Matrix::randn(4, 64, 3), 15.0, 32);
        let packed = PackedLinear::encode(&theta, &spec);
        let k = key();
        ModelArtifact {
            model: "t".into(),
            checkpoint: 1,
            calib: 2,
            method: "rtn".into(),
            spec: k.spec,
            spec_desc: k.spec_desc,
            params: k.params,
            compressed_with: "rtn".into(),
            sites: vec![ArtifactSite {
                param: "blocks.0.wq".into(),
                packed,
                report: report("blocks.0.wq", 4, 64),
            }],
        }
    }

    fn assert_sites_bit_equal(a: &ModelArtifact, b: &ModelArtifact) {
        assert_eq!(a.sites.len(), b.sites.len());
        for (x, y) in a.sites.iter().zip(&b.sites) {
            assert_eq!(x.param, y.param);
            let (da, db) = (x.packed.decode(), y.packed.decode());
            for (u, v) in da.data.iter().zip(&db.data) {
                assert_eq!(u.to_bits(), v.to_bits(), "{}", x.param);
            }
        }
    }

    #[test]
    fn file_round_trip_is_bit_exact() {
        let dir = TempDir::new("apack").unwrap();
        let art = artifact();
        let path = store_artifact(dir.path(), &key(), &art).unwrap();
        let back = read_artifact(&path).unwrap();
        assert_eq!(back.model, "t");
        assert_eq!(back.compressed_with, "rtn");
        assert_eq!(back.sites.len(), 1);
        let (a, b) = (&art.sites[0], &back.sites[0]);
        assert_eq!(a.param, b.param);
        assert_eq!(a.report.rel_loss, b.report.rel_loss);
        assert_eq!(a.report.iterations, b.report.iterations);
        assert_sites_bit_equal(&art, &back);
    }

    #[test]
    fn pack2_round_trips_and_never_stores_more() {
        let dir = TempDir::new("apack2").unwrap();
        let art = artifact();
        let p1 = dir.path().join("v1.apack");
        let p2 = dir.path().join("v2.apack");
        write_artifact(&p1, &art).unwrap();
        write_artifact_opts(&p2, &art, true).unwrap();
        // transparent on read: same artifact bit-for-bit
        let back = read_artifact(&p2).unwrap();
        assert_sites_bit_equal(&art, &back);
        // stored payload never exceeds the raw (v1) payload
        let mut f = std::io::BufReader::new(std::fs::File::open(&p2).unwrap());
        let h = read_artifact_header(&mut f, &p2).unwrap();
        assert!(h.pack2);
        assert!(h.stored_bytes() <= h.packed_bytes(),
                "stored {} > raw {}", h.stored_bytes(), h.packed_bytes());
        assert_eq!(h.packed_bytes(), art.packed_bytes());
    }

    #[test]
    fn header_read_stops_before_the_payload() {
        let dir = TempDir::new("apack").unwrap();
        let art = artifact();
        let path = dir.path().join("a.apack");
        write_artifact(&path, &art).unwrap();
        let mut f = std::io::BufReader::new(std::fs::File::open(&path).unwrap());
        let h = read_artifact_header(&mut f, &path).unwrap();
        use std::io::Seek;
        assert_eq!(f.stream_position().unwrap(), h.payload_start);
        assert_eq!(h.sites.len(), 1);
        assert_eq!(h.sites[0].raw_len, art.sites[0].packed.packed_bytes());
        assert!(h.matches_key(&key()));
    }

    #[test]
    fn absent_is_a_clean_miss() {
        let dir = TempDir::new("apack").unwrap();
        assert!(load_artifact(dir.path(), &key()).unwrap().is_none());
    }

    #[test]
    fn corrupt_truncated_and_mismatched_files_error() {
        let dir = TempDir::new("apack").unwrap();
        let k = key();
        // garbage
        std::fs::create_dir_all(dir.path()).unwrap();
        std::fs::write(dir.path().join(k.file_name()), b"garbage").unwrap();
        assert!(load_artifact(dir.path(), &k).is_err());
        // truncated payload
        let art = artifact();
        let path = store_artifact(dir.path(), &k, &art).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 32]).unwrap();
        assert!(load_artifact(dir.path(), &k).is_err());
        // identity mismatch: valid file under another key's name
        store_artifact(dir.path(), &k, &art).unwrap();
        let other = ArtifactKey::new(
            GramCacheKey { model: "t".into(), checkpoint: 9, calib: 2 },
            "rtn",
            &CompressionSpec::quant(4, 32),
        );
        std::fs::rename(dir.path().join(k.file_name()),
                        dir.path().join(other.file_name()))
            .unwrap();
        assert!(load_artifact(dir.path(), &other).is_err());
    }

    #[test]
    fn store_counts_hits_and_heals_corruption() {
        let dir = TempDir::new("apack").unwrap();
        let k = key();
        let store = ArtifactStore::new(Some(dir.path().to_path_buf()));
        assert!(store.load(&k).is_none());
        store.save(&k, &artifact());
        assert!(store.load(&k).is_some());
        let c = store.counts();
        assert_eq!((c.hits, c.misses, c.stores), (1, 1, 1));
        // corrupt the file: next load logs + misses, save heals
        std::fs::write(dir.path().join(k.file_name()), b"AWPPACK1junk").unwrap();
        assert!(store.load(&k).is_none());
        store.save(&k, &artifact());
        assert!(store.load(&k).is_some());
    }

    #[test]
    fn disabled_store_is_inert() {
        let store = ArtifactStore::disabled();
        assert!(!store.enabled());
        assert!(store.load(&key()).is_none());
        store.save(&key(), &artifact());
        assert_eq!(store.counts().stores, 0);
    }

    #[test]
    fn footprint_table_totals() {
        let art = artifact();
        let t = art.footprint_table();
        let con = t.to_console();
        assert!(con.contains("blocks.0.wq"), "{con}");
        assert!(con.contains("TOTAL"), "{con}");
        assert!(art.packed_bytes() < art.dense_bytes());
    }

    #[test]
    fn key_mismatch_rejected_at_store_time() {
        let dir = TempDir::new("apack").unwrap();
        let mut art = artifact();
        art.method = "wanda".into();
        assert!(store_artifact(dir.path(), &key(), &art).is_err());
    }
}
