//! Magnitude pruning — the classical non-activation-aware baseline
//! (eq. 1 of the paper): keep the k largest-|w| entries per row. Tables 1–2
//! show it collapsing at ≥60% sparsity, which our Table-1 regeneration
//! reproduces.

use anyhow::{bail, Result};

use super::traits::{CompressedLayer, CompressionMode, CompressionSpec, LayerCompressor};
use crate::proj::{NmStructured, ProjScratch, Projection};
use crate::tensor::{topk, Matrix};
use crate::util::Timer;

#[derive(Default)]
pub struct MagnitudePrune;

impl LayerCompressor for MagnitudePrune {
    fn name(&self) -> &'static str {
        "magnitude"
    }

    fn compress(&self, w: &Matrix, c: &Matrix, spec: &CompressionSpec)
        -> Result<CompressedLayer> {
        let t = Timer::start("magnitude");
        let theta = match spec.mode {
            CompressionMode::Prune { .. } => {
                topk::hard_threshold_rows(w, spec.keep_k(w.cols).unwrap())
            }
            CompressionMode::StructuredNm { n, m } => {
                let mut theta = w.clone();
                NmStructured::new(n, m)
                    .project_rows(&mut theta, &mut ProjScratch::new());
                theta
            }
            _ => bail!("magnitude pruning supports Prune/StructuredNm only"),
        };
        Ok(CompressedLayer::from_theta(w, c, theta, 0, t.elapsed_s()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prunes_to_exact_row_sparsity() {
        let w = Matrix::randn(16, 32, 0);
        let c = Matrix::randn_gram(32, 1);
        let out = MagnitudePrune
            .compress(&w, &c, &CompressionSpec::prune(0.75))
            .unwrap();
        for i in 0..16 {
            assert_eq!(out.theta.row(i).iter().filter(|&&v| v != 0.0).count(), 8);
        }
        assert!(out.stats.final_loss > 0.0);
    }

    #[test]
    fn keeps_largest_magnitudes() {
        let w = Matrix::from_vec(1, 4, vec![0.1, -9.0, 5.0, 0.2]);
        let c = Matrix::eye(4);
        let out = MagnitudePrune
            .compress(&w, &c, &CompressionSpec::prune(0.5))
            .unwrap();
        assert_eq!(out.theta.data, vec![0.0, -9.0, 5.0, 0.0]);
    }

    #[test]
    fn rejects_quant_mode() {
        let w = Matrix::randn(4, 32, 2);
        let c = Matrix::randn_gram(32, 3);
        assert!(MagnitudePrune
            .compress(&w, &c, &CompressionSpec::quant(4, 32))
            .is_err());
    }
}
