//! SparseGPT (Frantar & Alistarh, 2023), re-implemented from scratch.
//!
//! Per row: sweep columns left → right in blocks; inside each block, rank
//! columns by the OBS saliency `w_j² / U[j,j]²`, prune the lowest-scoring
//! ones up to the block's share of the row budget, and redistribute each
//! frozen column's error onto the remaining columns via the inverse-Hessian
//! Cholesky factor (see `obs.rs`). Rows are independent and run on the
//! thread pool — the same parallelism the original exploits on GPU.

use anyhow::{bail, Result};

use super::obs;
use super::traits::{CompressedLayer, CompressionMode, CompressionSpec, LayerCompressor};
use crate::tensor::Matrix;
use crate::util::parallel::par_map;
use crate::util::Timer;

pub struct SparseGpt {
    /// lazy mask-selection block width (columns)
    pub block: usize,
    /// Hessian damping fraction (SparseGPT's `percdamp`)
    pub percdamp: f64,
}

impl Default for SparseGpt {
    fn default() -> Self {
        SparseGpt { block: 64, percdamp: 0.01 }
    }
}

impl LayerCompressor for SparseGpt {
    fn name(&self) -> &'static str {
        "sparsegpt"
    }

    fn compress(&self, w: &Matrix, c: &Matrix, spec: &CompressionSpec)
        -> Result<CompressedLayer> {
        let t = Timer::start("sparsegpt");
        let CompressionMode::Prune { .. } = spec.mode else {
            bail!("sparsegpt implemented for Prune mode (GPTQ covers quant)");
        };
        let k = spec.keep_k(w.cols).unwrap();
        let n = w.cols;
        let total_prune = n - k;
        let (u, _) = obs::hinv_upper_chol(c, self.percdamp);
        let block = self.block.min(n).max(1);

        let rows: Vec<Vec<f32>> = par_map(w.rows, |i| {
            let mut row = w.row(i).to_vec();
            let mut pruned = 0usize;
            let mut col = 0usize;
            let mut out = vec![0.0f32; n];
            while col < n {
                let end = (col + block).min(n);
                // block-local saliency from the *current* residual values
                let budget = obs::block_prune_budget(total_prune, n, end, pruned);
                let mut idx: Vec<usize> = (col..end).collect();
                idx.sort_by(|&a, &b| {
                    let sa = row[a] * row[a] / (u.at(a, a) * u.at(a, a));
                    let sb = row[b] * row[b] / (u.at(b, b) * u.at(b, b));
                    sa.partial_cmp(&sb).unwrap()
                });
                let prune_set: std::collections::HashSet<usize> =
                    idx.into_iter().take(budget).collect();
                pruned += prune_set.len();
                // OBS sweep across this block with compensation into the
                // whole remaining row
                for j in col..end {
                    let q = row[j];
                    let qc = if prune_set.contains(&j) { 0.0 } else { q };
                    out[j] = qc;
                    let d = u.at(j, j);
                    if d.abs() < 1e-12 {
                        continue;
                    }
                    let err = (q - qc) / d;
                    if err == 0.0 {
                        continue;
                    }
                    let urow = u.row(j);
                    for t in j + 1..n {
                        row[t] -= err * urow[t];
                    }
                }
                col = end;
            }
            out
        });

        let mut theta = Matrix::zeros(w.rows, n);
        for (i, row) in rows.into_iter().enumerate() {
            theta.row_mut(i).copy_from_slice(&row);
        }
        Ok(CompressedLayer::from_theta(w, c, theta, 0, t.elapsed_s()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::magnitude::MagnitudePrune;
    use crate::compress::wanda::WandaPrune;

    #[test]
    fn exact_row_sparsity() {
        let w = Matrix::randn(8, 64, 0);
        let c = Matrix::randn_gram(64, 1);
        let out = SparseGpt::default()
            .compress(&w, &c, &CompressionSpec::prune(0.5))
            .unwrap();
        for i in 0..8 {
            let nnz = out.theta.row(i).iter().filter(|&&v| v != 0.0).count();
            assert_eq!(nnz, 32, "row {i}");
        }
    }

    #[test]
    fn beats_magnitude_and_wanda_on_correlated_gram() {
        // SparseGPT updates surviving weights, so on correlated C it should
        // beat both mask-only methods in activation loss (Table 1, 50-60%).
        let mut beat_mag = 0;
        let mut beat_wanda = 0;
        for seed in 0..6 {
            let w = Matrix::randn(24, 48, seed);
            let c = Matrix::randn_gram(48, 50 + seed);
            let spec = CompressionSpec::prune(0.6);
            let sg = SparseGpt::default().compress(&w, &c, &spec).unwrap();
            let mag = MagnitudePrune.compress(&w, &c, &spec).unwrap();
            let wd = WandaPrune.compress(&w, &c, &spec).unwrap();
            if sg.stats.final_loss < mag.stats.final_loss {
                beat_mag += 1;
            }
            if sg.stats.final_loss < wd.stats.final_loss {
                beat_wanda += 1;
            }
        }
        assert!(beat_mag >= 5, "{beat_mag}/6 vs magnitude");
        assert!(beat_wanda >= 5, "{beat_wanda}/6 vs wanda");
    }

    #[test]
    fn isotropic_gram_reduces_to_magnitude_mask() {
        // with C = I the saliency is w², no compensation happens between
        // independent columns ⇒ same mask as magnitude (weights unchanged).
        let w = Matrix::randn(4, 32, 7);
        let c = Matrix::eye(32);
        let spec = CompressionSpec::prune(0.5);
        let sg = SparseGpt { block: 32, percdamp: 1e-6 }
            .compress(&w, &c, &spec)
            .unwrap();
        let mag = MagnitudePrune.compress(&w, &c, &spec).unwrap();
        // masks agree on clear (tie-free) rows; values nearly unchanged
        let mut agree = 0;
        for (a, b) in sg.theta.data.iter().zip(&mag.theta.data) {
            if (*a == 0.0) == (*b == 0.0) {
                agree += 1;
            }
        }
        assert!(agree as f64 / (4.0 * 32.0) > 0.9, "agree {agree}/128");
    }
}
