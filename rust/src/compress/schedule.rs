//! The §4.3 joint-compression schedule: "in the first 25 iterations of
//! purely pruning, we linearly increase the pruning ratio from 0% to the
//! target pruning ratio, then keep this pruning ratio unchanged in the
//! remaining 75 iterations", with quantization switched on from iteration
//! 50 onward.

/// Phase of one joint-compression iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JointPhase {
    /// pruning only, ratio ramping up
    Ramp,
    /// pruning only, at target ratio
    PruneHold,
    /// joint pruning + quantization at target ratio
    Joint,
}

/// The iteration schedule for joint pruning + quantization.
#[derive(Clone, Copy, Debug)]
pub struct JointSchedule {
    pub total_iters: usize,
    pub ramp_iters: usize,
    pub prune_only_iters: usize,
}

impl Default for JointSchedule {
    fn default() -> Self {
        // paper §4.3: 25 ramp, 50 prune-only total, 100 overall
        JointSchedule { total_iters: 100, ramp_iters: 25, prune_only_iters: 50 }
    }
}

impl JointSchedule {
    pub fn phase(&self, iter: usize) -> JointPhase {
        if iter < self.ramp_iters {
            JointPhase::Ramp
        } else if iter < self.prune_only_iters {
            JointPhase::PruneHold
        } else {
            JointPhase::Joint
        }
    }

    /// Per-row keep count at `iter`, ramping linearly from `d_in` down to
    /// `k_target` over the first `ramp_iters` iterations.
    pub fn k_at(&self, iter: usize, d_in: usize, k_target: usize) -> usize {
        if iter + 1 >= self.ramp_iters {
            return k_target;
        }
        let frac = (iter + 1) as f64 / self.ramp_iters as f64;
        let k = d_in as f64 - frac * (d_in - k_target) as f64;
        (k.round() as usize).clamp(k_target, d_in)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_follow_paper() {
        let s = JointSchedule::default();
        assert_eq!(s.phase(0), JointPhase::Ramp);
        assert_eq!(s.phase(24), JointPhase::Ramp);
        assert_eq!(s.phase(25), JointPhase::PruneHold);
        assert_eq!(s.phase(49), JointPhase::PruneHold);
        assert_eq!(s.phase(50), JointPhase::Joint);
        assert_eq!(s.phase(99), JointPhase::Joint);
    }

    #[test]
    fn ramp_monotone_to_target() {
        let s = JointSchedule::default();
        let d_in = 256;
        let k_target = 64;
        let mut prev = d_in + 1;
        for it in 0..s.total_iters {
            let k = s.k_at(it, d_in, k_target);
            assert!(k <= prev, "k must not increase");
            assert!(k >= k_target);
            prev = k;
        }
        assert_eq!(s.k_at(24, d_in, k_target), k_target);
        assert_eq!(s.k_at(99, d_in, k_target), k_target);
        // starts near full density
        assert!(s.k_at(0, d_in, k_target) > d_in * 9 / 10);
    }

    #[test]
    fn degenerate_ramp() {
        let s = JointSchedule { total_iters: 10, ramp_iters: 1, prune_only_iters: 2 };
        assert_eq!(s.k_at(0, 100, 30), 30);
    }
}
