//! GPTQ (Frantar et al., 2022), re-implemented from scratch.
//!
//! The quantization twin of SparseGPT: sweep columns left → right, freeze
//! each column to its grouped-grid point, and push the rounding error onto
//! the not-yet-quantized columns through the inverse-Hessian Cholesky
//! factor. Group scale/zero-point are fitted from the *original* weights of
//! each group (per row), as in the reference implementation with
//! `groupsize` set.

use anyhow::{bail, Result};

use super::obs;
use super::traits::{CompressedLayer, CompressionMode, CompressionSpec, LayerCompressor};
use crate::quant::QuantSpec;
use crate::tensor::Matrix;
use crate::util::parallel::par_map;
use crate::util::Timer;

pub struct Gptq {
    pub percdamp: f64,
}

impl Default for Gptq {
    fn default() -> Self {
        Gptq { percdamp: 0.01 }
    }
}

/// Per-group affine grid fitted to a slice (same formula as quant::grouped
/// and the L1 kernel).
fn fit_grid(vals: &[f32], qmax: f32) -> (f32, f32) {
    let lo = vals.iter().cloned().fold(f32::MAX, f32::min);
    let hi = vals.iter().cloned().fold(f32::MIN, f32::max);
    let scale = (hi - lo) / qmax;
    if scale > 0.0 {
        (scale, (-lo / scale).round_ties_even())
    } else {
        (0.0, lo) // flat group: remember the constant in the zp slot
    }
}

fn project(v: f32, scale: f32, zp: f32, qmax: f32) -> f32 {
    if scale > 0.0 {
        let q = ((v / scale).round_ties_even() + zp).clamp(0.0, qmax);
        (q - zp) * scale
    } else {
        zp // the constant
    }
}

impl LayerCompressor for Gptq {
    fn name(&self) -> &'static str {
        "gptq"
    }

    fn grid_refit_checkable(&self) -> bool {
        false
    }

    fn compress(&self, w: &Matrix, c: &Matrix, spec: &CompressionSpec)
        -> Result<CompressedLayer> {
        let t = Timer::start("gptq");
        let CompressionMode::Quant { spec: qs } = spec.mode else {
            bail!("gptq only supports Quant mode");
        };
        if w.cols % qs.group != 0 {
            bail!("d_in={} not a multiple of group={}", w.cols, qs.group);
        }
        let (u, _) = obs::hinv_upper_chol(c, self.percdamp);
        let qmax = qs.qmax();
        let n = w.cols;

        let rows: Vec<Vec<f32>> = par_map(w.rows, |i| {
            let orig = w.row(i);
            let mut row = orig.to_vec();
            let mut out = vec![0.0f32; n];
            let mut scale = 0.0f32;
            let mut zp = 0.0f32;
            for j in 0..n {
                if j % qs.group == 0 {
                    // fit the grid on the original weights of this group
                    let g = &orig[j..j + qs.group];
                    let (s, z) = fit_grid(g, qmax);
                    scale = s;
                    zp = z;
                }
                let q = row[j];
                let qc = project(q, scale, zp, qmax);
                out[j] = qc;
                let d = u.at(j, j);
                if d.abs() < 1e-12 {
                    continue;
                }
                let err = (q - qc) / d;
                if err == 0.0 {
                    continue;
                }
                let urow = u.row(j);
                for t in j + 1..n {
                    row[t] -= err * urow[t];
                }
            }
            out
        });

        let mut theta = Matrix::zeros(w.rows, n);
        for (i, row) in rows.into_iter().enumerate() {
            theta.row_mut(i).copy_from_slice(&row);
        }
        Ok(CompressedLayer::from_theta(w, c, theta, 0, t.elapsed_s()))
    }
}

/// Re-quantization helper used by constraint checks: GPTQ output lies on
/// per-group grids fitted to the *original* W, so `check_constraints`'s
/// refit-based check can disagree on groups whose min/max moved. This
/// verifies grid membership against the original grids instead.
pub fn on_original_grid(w: &Matrix, theta: &Matrix, qs: QuantSpec) -> bool {
    let qmax = qs.qmax();
    for i in 0..w.rows {
        for g in (0..w.cols).step_by(qs.group) {
            let (scale, zp) = fit_grid(&w.row(i)[g..g + qs.group], qmax);
            for j in g..g + qs.group {
                let v = theta.at(i, j);
                let p = project(v, scale, zp, qmax);
                if (v - p).abs() > 1e-4 * v.abs().max(1e-3) {
                    return false;
                }
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::rtn::RtnQuant;

    #[test]
    fn output_on_original_grid() {
        let w = Matrix::randn(8, 64, 0);
        let c = Matrix::randn_gram(64, 1);
        let spec = CompressionSpec::quant(4, 32);
        let out = Gptq::default().compress(&w, &c, &spec).unwrap();
        assert!(on_original_grid(&w, &out.theta,
                                 QuantSpec::new(4, 32)));
    }

    #[test]
    fn beats_rtn_on_correlated_gram() {
        // error compensation through H⁻¹ must reduce activation loss vs
        // plain round-to-nearest (Table 3 mechanism: GPTQ < RTN).
        let mut wins = 0;
        for seed in 0..6 {
            let w = Matrix::randn(16, 64, seed);
            let c = Matrix::randn_gram(64, 30 + seed);
            let spec = CompressionSpec::quant(3, 32);
            let g = Gptq::default().compress(&w, &c, &spec).unwrap();
            let r = RtnQuant.compress(&w, &c, &spec).unwrap();
            if g.stats.final_loss < r.stats.final_loss {
                wins += 1;
            }
        }
        assert!(wins >= 5, "gptq won {wins}/6 vs rtn");
    }

    #[test]
    fn int8_nearly_lossless() {
        let w = Matrix::randn(4, 32, 5);
        let c = Matrix::randn_gram(32, 6);
        let out = Gptq::default()
            .compress(&w, &c, &CompressionSpec::quant(8, 32))
            .unwrap();
        assert!(out.stats.rel_loss < 0.02, "{}", out.stats.rel_loss);
    }

    #[test]
    fn rejects_prune_mode() {
        let w = Matrix::randn(4, 32, 7);
        let c = Matrix::randn_gram(32, 8);
        assert!(Gptq::default()
            .compress(&w, &c, &CompressionSpec::prune(0.5))
            .is_err());
    }
}
