//! Pure-Rust AWP backend — the CPU mirror of the AOT-compiled L2/L1 chunk
//! programs, sharing exact semantics (same projection formulas, same stats)
//! so the two backends are interchangeable and cross-checkable.
//!
//! Production uses `runtime::HloBackend`; this backend is the reference for
//! tests/property sweeps and the fallback when `artifacts/` is absent.
//! Every constraint set runs through the one [`PgdWorkspace`]-driven loop:
//! the fused gradient step writes into the spare buffer, the
//! [`Projection`] mutates it in place, the buffers swap — zero `Matrix`
//! allocations per iteration (`benches/compression.rs` tracks the win over
//! the historical alloc-per-iteration path).

use anyhow::Result;

use super::awp::{AwpBackend, AwpDriver};
use crate::proj::{PgdWorkspace, Projection};
use crate::tensor::{ops, Matrix};

/// Pure-Rust chunked-PGD backend.
#[derive(Default, Clone, Copy)]
pub struct CpuBackend;

/// AWP with the CPU backend (paper hyper-parameters).
pub type AwpCpu = AwpDriver<CpuBackend>;

impl Default for AwpCpu {
    fn default() -> Self {
        AwpDriver::new(CpuBackend)
    }
}

fn stats(w: &Matrix, theta: &Matrix, c: &Matrix) -> (f64, f64) {
    let wn = w.frob_norm().max(1e-30);
    let rel_grad = ops::grad_frob_norm(w, theta, c) / wn;
    let rel_loss = ops::activation_loss(w, theta, c).sqrt() / wn;
    (rel_grad, rel_loss)
}

impl AwpBackend for CpuBackend {
    fn step_chunk(&self, w: &Matrix, c: &Matrix, eta: f32, proj: &dyn Projection,
                  iters: usize, ws: &mut PgdWorkspace) -> Result<(f64, f64)> {
        for _ in 0..iters {
            ws.step(w, c, eta, proj);
        }
        Ok(stats(w, ws.theta(), c))
    }

    fn backend_name(&self) -> &'static str {
        "cpu"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::traits::{check_constraints, CompressionSpec, LayerCompressor};
    use crate::compress::wanda;
    use crate::proj::RowTopK;
    use crate::quant;

    fn problem(seed: u64) -> (Matrix, Matrix) {
        (Matrix::randn(24, 64, seed), Matrix::randn_gram(64, seed + 1000))
    }

    #[test]
    fn prune_improves_on_wanda_init() {
        // the core paper claim (Tables 1–2 / Figure 1): AWP's PGD iterations
        // reduce the activation-aware loss below the Wanda starting point.
        for ratio in [0.5, 0.7, 0.9] {
            let mut improved = 0;
            for seed in 0..5 {
                let (w, c) = problem(seed);
                let out = AwpCpu::default()
                    .compress(&w, &c, &CompressionSpec::prune(ratio))
                    .unwrap();
                let wl = wanda::wanda_loss(&w, &c, ratio);
                if out.stats.final_loss <= wl * 1.0001 {
                    improved += 1;
                }
            }
            assert!(improved >= 4, "ratio {ratio}: improved {improved}/5");
        }
    }

    #[test]
    fn prune_satisfies_constraints_and_stops() {
        let (w, c) = problem(42);
        let spec = CompressionSpec::prune(0.6);
        let out = AwpCpu::default().compress(&w, &c, &spec).unwrap();
        check_constraints(&out.theta, &spec).unwrap();
        assert!(out.stats.iterations <= 200);
        assert!(out.stats.iterations >= 8);
    }

    #[test]
    fn quant_beats_rtn_init() {
        let mut wins = 0;
        for seed in 0..5 {
            let (w, c) = problem(seed + 10);
            let spec = CompressionSpec::quant(3, 32);
            let out = AwpCpu::default().compress(&w, &c, &spec).unwrap();
            let rtn = quant::quantize_dequantize(&w, quant::QuantSpec::new(3, 32));
            let rtn_loss = ops::activation_loss(&w, &rtn, &c);
            if out.stats.final_loss <= rtn_loss {
                wins += 1;
            }
        }
        // best-iterate tracking can never be worse than the RTN init
        assert_eq!(wins, 5);
    }

    #[test]
    fn quant_output_on_grid() {
        let (w, c) = problem(77);
        let spec = CompressionSpec::quant(4, 32);
        let out = AwpCpu::default().compress(&w, &c, &spec).unwrap();
        check_constraints(&out.theta, &spec).unwrap();
    }

    #[test]
    fn joint_satisfies_both_constraints() {
        let (w, c) = problem(5);
        let spec = CompressionSpec::joint(0.5, 4, 32);
        let out = AwpCpu::default().compress(&w, &c, &spec).unwrap();
        check_constraints(&out.theta, &spec).unwrap();
        // actually sparse
        let stats = crate::sparse::SparsityStats::of(&out.theta);
        assert!(stats.ratio() >= 0.45, "sparsity {}", stats.ratio());
    }

    #[test]
    fn joint_beats_sequential_wanda_then_rtn() {
        // §4.3's headline: joint optimization beats naive sequential
        // composition in activation loss (averaged over seeds).
        let mut wins = 0;
        for seed in 0..5 {
            let (w, c) = problem(seed + 20);
            let spec = CompressionSpec::joint(0.5, 4, 32);
            let joint = AwpCpu::default().compress(&w, &c, &spec).unwrap();
            // sequential: wanda prune then RTN on survivors + mask
            let k = spec.keep_k(w.cols).unwrap();
            let pruned = wanda::wanda_prune(&w, &c, k);
            let mut seq = quant::project_qmax(&pruned, 15.0, 32);
            for (q, p) in seq.data.iter_mut().zip(&pruned.data) {
                if *p == 0.0 {
                    *q = 0.0;
                }
            }
            let seq_loss = ops::activation_loss(&w, &seq, &c);
            if joint.stats.final_loss <= seq_loss {
                wins += 1;
            }
        }
        assert!(wins >= 4, "joint won {wins}/5");
    }

    #[test]
    fn fig1_series_is_recorded_and_decreasing_overall() {
        let (w, c) = problem(9);
        let mut hyper = super::super::awp::AwpHyper::default();
        hyper.track_series = true;
        hyper.prune_max_iters = 30;
        let drv = AwpDriver::with_hyper(CpuBackend, hyper);
        let out = drv.compress(&w, &c, &CompressionSpec::prune(0.6)).unwrap();
        let s = &out.stats.loss_series;
        assert!(s.len() >= 10, "series {}", s.len());
        assert!(s.last().unwrap() <= s.first().unwrap());
    }

    #[test]
    fn chunked_equals_unchunked() {
        // 8 chunk-1 calls == 1 chunk-8 call (mirrors the python test, and
        // guarantees the HLO chunk=8 artifacts compose correctly).
        let (w, c) = problem(33);
        let b = CpuBackend;
        let k = 32;
        let proj = RowTopK::new(k);
        let eta = (2.0 / c.frob_norm()) as f32;
        let th0 = wanda::wanda_prune(&w, &c, k);
        let mut th_a = th0.clone();
        for _ in 0..8 {
            th_a = b.step_chunk_from(&w, &th_a, &c, eta, &proj, 1).unwrap().0;
        }
        let th_b = b.step_chunk_from(&w, &th0, &c, eta, &proj, 8).unwrap().0;
        for (x, y) in th_a.data.iter().zip(&th_b.data) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn nm_modes_compress_end_to_end() {
        // the §5 generalisation: 4:8 (and the 2:4 special case) run through
        // the full driver and land in their constraint sets
        let (w, c) = problem(55);
        for spec in [CompressionSpec::structured_nm(4, 8),
                     CompressionSpec::structured24(),
                     CompressionSpec::joint_nm(4, 8, 4, 32)] {
            let out = AwpCpu::default().compress(&w, &c, &spec).unwrap();
            check_constraints(&out.theta, &spec)
                .unwrap_or_else(|e| panic!("{spec:?}: {e}"));
            let stats = crate::sparse::SparsityStats::of(&out.theta);
            assert!(stats.ratio() >= 0.45, "{spec:?}: sparsity {}", stats.ratio());
            assert!(out.stats.final_loss.is_finite());
        }
    }

    #[test]
    fn nm_24_not_worse_than_wanda_24_init() {
        // the §4.1 claim carried to the structured set: PGD improves on the
        // Wanda-2:4 initialiser (averaged over seeds)
        let mut ok = 0;
        for seed in 0..5 {
            let (w, c) = problem(seed + 60);
            let out = AwpCpu::default()
                .compress(&w, &c, &CompressionSpec::structured24())
                .unwrap();
            let init = wanda::wanda_prune_2_4(&w, &c);
            let init_loss = ops::activation_loss(&w, &init, &c);
            if out.stats.final_loss <= init_loss * 1.0001 {
                ok += 1;
            }
        }
        assert!(ok >= 4, "improved on wanda-2:4 only {ok}/5");
    }
}
